"""Paper section 2 workload envelope: scaling in matrix size and
permutation count ("1k^2..100k^2 elements, 1k..1M permutations").

Verifies the implementation's scaling laws on host CPU: brute is linear in
n^2 * perms; the matmul form amortizes mat2 reads over the perm block.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fstat, permutations
from repro.utils.timing import time_fn


def _instance(n, p, g=8, seed=0):
    rng = np.random.default_rng(seed)
    d = rng.random((n, n)).astype(np.float32)
    d = (d + d.T) / 2
    np.fill_diagonal(d, 0.0)
    grouping = rng.integers(0, g, size=n).astype(np.int32)
    grouping[:g] = np.arange(g)
    inv_gs = permutations.inv_group_sizes(jnp.asarray(grouping), g)
    gperms = permutations.permutation_batch(jax.random.key(0),
                                            jnp.asarray(grouping), 0, p)
    return jnp.asarray(d * d), gperms, inv_gs


def run(emit):
    fn = jax.jit(lambda m, g, w: fstat.sw_matmul(m, g, w, perm_block=32))
    for n in (256, 512, 1024):
        m2, gp, ig = _instance(n, 32)
        t = time_fn(fn, m2, gp, ig, iters=3, warmup=1)
        emit(f"sweep/n{n}_perms32", t * 1e6,
             f"per_perm_us={t/32*1e6:.1f}")
    for p in (16, 64, 256):
        m2, gp, ig = _instance(512, p)
        t = time_fn(fn, m2, gp, ig, iters=3, warmup=1)
        emit(f"sweep/n512_perms{p}", t * 1e6,
             f"per_perm_us={t/p*1e6:.1f}")
