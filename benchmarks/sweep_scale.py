"""Paper section 2 workload envelope: scaling in matrix size and
permutation count ("1k^2..100k^2 elements, 1k..1M permutations").

Verifies the implementation's scaling laws on host CPU: brute is linear in
n^2 * perms; the matmul form amortizes mat2 reads over the perm block. The
large-permutation rows go through the engine's streaming scheduler, which
executes the sweep in fixed-memory chunks (labels regenerated on device per
chunk) — the path that makes 100k..1M permutation runs single-host viable.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.core import permutations
from repro.utils.timing import time_fn


def _instance(n, p, g=8, seed=0):
    rng = np.random.default_rng(seed)
    d = rng.random((n, n)).astype(np.float32)
    d = (d + d.T) / 2
    np.fill_diagonal(d, 0.0)
    grouping = rng.integers(0, g, size=n).astype(np.int32)
    grouping[:g] = np.arange(g)
    inv_gs = permutations.inv_group_sizes(jnp.asarray(grouping), g)
    gperms = permutations.permutation_batch(jax.random.key(0),
                                            jnp.asarray(grouping), 0, p)
    return jnp.asarray(d * d), gperms, inv_gs, jnp.asarray(grouping)


def run(emit):
    fn = jax.jit(engine.get("matmul").bound(perm_block=32))
    for n in (256, 512, 1024):
        m2, gp, ig, _ = _instance(n, 32)
        t = time_fn(fn, m2, gp, ig, iters=3, warmup=1).median
        emit(f"sweep/n{n}_perms32", t * 1e6,
             f"per_perm_us={t/32*1e6:.1f}")
    for p in (16, 64, 256):
        m2, gp, ig, _ = _instance(512, p)
        t = time_fn(fn, m2, gp, ig, iters=3, warmup=1).median
        emit(f"sweep/n512_perms{p}", t * 1e6,
             f"per_perm_us={t/p*1e6:.1f}")

    # streaming scheduler: fixed-memory chunked sweep, labels never
    # materialized as an (n_perms, n) tensor
    n, n_perms = 512, 8192
    m2, _, ig, grouping = _instance(n, 1)
    key = jax.random.key(0)
    for chunk in (512, 2048):
        # warm the jitted step (one chunk) so rows time steady state, like
        # the time_fn(warmup=1) rows above
        engine.sw_streaming(m2, grouping, ig, key, chunk, fn, chunk=chunk)
        t0 = time.perf_counter()
        _, stats = engine.sw_streaming(m2, grouping, ig, key, n_perms,
                                       fn, chunk=chunk)
        t = time.perf_counter() - t0
        emit(f"sweep/stream_perms{n_perms}_chunk{chunk}", t * 1e6,
             f"per_perm_us={t/n_perms*1e6:.2f} chunks={stats.n_chunks} "
             f"peak_label_mb={stats.peak_label_bytes/2**20:.2f}")
