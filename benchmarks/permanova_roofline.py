"""Roofline accounting for the PERMANOVA kernels on the TARGET chip
(TPU v5e): arithmetic intensity per variant at the paper's shape, and the
predicted time per 1000 permutations. This is the quantitative version of
the paper's CPU-vs-GPU finding, recast for VPU vs MXU (DESIGN.md sec. 2-3).
"""

from __future__ import annotations

from repro import hw

N = hw.PAPER_N_DIMS
PERMS = 1000
GROUPS = 8


def run(emit):
    chip = hw.TPU_V5E
    mat_bytes = 4.0 * N * N
    ridge = hw.ridge_point_bf16(chip)
    emit("pa_roofline/ridge_point_bf16", 0.0,
         f"{ridge:.1f} flop/byte (v5e)")

    cases = {
        # (flops per perm, mat2 bytes streamed per perm)
        "brute":     (3.0 * N * N / 2, mat_bytes / 2),   # triangle
        "permblock16": (3.0 * N * N / 2, mat_bytes / 2 / 16),
        "matmul_pb64": (2.0 * N * N * GROUPS + 2.0 * N * N * GROUPS,
                        mat_bytes / 64),
    }
    for name, (flops, bytes_) in cases.items():
        ai = flops / bytes_
        t_mem = bytes_ * PERMS / chip.hbm_bandwidth
        t_cmp = flops * PERMS / chip.peak_flops_bf16
        bound = "compute" if t_cmp > t_mem else "memory"
        emit(f"pa_roofline/{name}", max(t_mem, t_cmp) / PERMS * 1e6,
             f"ai={ai:.1f} flop/B mem_s={t_mem:.3f} compute_s={t_cmp:.3f} "
             f"per 1k perms -> {bound}-bound")
