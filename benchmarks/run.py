# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness (deliverable d).

  PYTHONPATH=src python -m benchmarks.run [--only fig1,stream,...] [--json]

Suites:
  fig1         paper Figure 1 analogue — s_W variants by algorithm
  stream       paper Appendix A2 — STREAM copy/scale/add/triad
  sweep        paper section 2 workload envelope (n, n_perms scaling)
  pa_roofline  PERMANOVA arithmetic-intensity roofline on TPU v5e
  roofline     LM-zoo roofline table from dry-run artifacts (deliverable g)
  serve        always-on PERMANOVA serving: studies/sec vs latency SLO,
               p99 from serve.step spans, worker-death recovery overhead

--json writes one BENCH_<suite>.json per suite (rows + host metadata) into
--json-dir (default: cwd) — the machine-readable perf trajectory consumed
by CI across PRs.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time
import traceback

import jax

from benchmarks import (fig1_sw_variants, permanova_roofline,
                        pipeline_scale, roofline_report, serve_bench,
                        stream_triad, sweep_scale)
from repro import obs

SUITES = {
    "fig1": fig1_sw_variants.run,
    "stream": stream_triad.run,
    "sweep": sweep_scale.run,
    "pipeline": pipeline_scale.run,
    "pa_roofline": permanova_roofline.run,
    "roofline": roofline_report.run,
    "serve": serve_bench.run,
}


def _host_meta() -> dict:
    return {
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "device_kind": jax.devices()[0].device_kind,
        "jax_version": jax.__version__,
        "platform": platform.platform(),
        "python": platform.python_version(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    ap.add_argument("--json", action="store_true",
                    help="also write BENCH_<suite>.json per suite")
    ap.add_argument("--json-dir", default=".",
                    help="directory for BENCH_<suite>.json files")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(SUITES)

    # counters only (no spans): retraces/compiles and traffic counters per
    # suite get stamped into BENCH_*.json without perturbing the timings
    obs.enable(trace=False, metrics=True)

    print("name,us_per_call,derived")
    failed = []
    for name in names:
        rows = []

        def emit(row_name, us, derived, extra=None, _rows=rows):
            print(f"{row_name},{us:.1f},{derived}")
            row = {"name": row_name, "us_per_call": round(us, 1),
                   "derived": derived}
            if extra:
                row.update(extra)   # machine-readable columns (precision,
                                    # feat_bytes_mib, ...) for CI trending
            _rows.append(row)

        t0 = time.time()
        before = obs.metrics.snapshot()
        ok = True
        try:
            SUITES[name](emit)
        except Exception:  # noqa: BLE001
            ok = False
            failed.append(name)
            traceback.print_exc()
        obs.record_device_memory()
        if args.json:
            os.makedirs(args.json_dir, exist_ok=True)
            payload = {
                "suite": name,
                "ok": ok,
                "wall_s": round(time.time() - t0, 2),
                "host": _host_meta(),
                "obs": obs.metrics.counter_delta(before),
                "rows": rows,
            }
            path = os.path.join(args.json_dir, f"BENCH_{name}.json")
            with open(path, "w") as f:
                json.dump(payload, f, indent=2)
            print(f"# wrote {path}", file=sys.stderr)
    if failed:
        print(f"# FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()
