# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness (deliverable d).

  PYTHONPATH=src python -m benchmarks.run [--only fig1,stream,...]

Suites:
  fig1         paper Figure 1 analogue — s_W variants by algorithm
  stream       paper Appendix A2 — STREAM copy/scale/add/triad
  sweep        paper section 2 workload envelope (n, n_perms scaling)
  pa_roofline  PERMANOVA arithmetic-intensity roofline on TPU v5e
  roofline     LM-zoo roofline table from dry-run artifacts (deliverable g)
"""

from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import (fig1_sw_variants, permanova_roofline,
                        roofline_report, stream_triad, sweep_scale)

SUITES = {
    "fig1": fig1_sw_variants.run,
    "stream": stream_triad.run,
    "sweep": sweep_scale.run,
    "pa_roofline": permanova_roofline.run,
    "roofline": roofline_report.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(SUITES)

    print("name,us_per_call,derived")
    failed = []
    for name in names:
        try:
            SUITES[name](lambda n, us, d: print(f"{n},{us:.1f},{d}"))
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"# FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()
