"""Deliverable (g): the roofline table, read from dry-run artifacts.

Emits one row per (arch x shape x mesh) record under results/dryrun:
all three terms (seconds), dominant bottleneck, MODEL_FLOPS ratio, and
whether the cell fits HBM. benchmarks/run.py prints it as CSV; the same
data renders EXPERIMENTS.md section Roofline.
"""

from __future__ import annotations

import json
import pathlib

RESULTS = pathlib.Path("results/dryrun_final")
if not RESULTS.exists():  # fall back to ad-hoc runs
    RESULTS = pathlib.Path("results/dryrun")


def iter_records(mesh: str | None = None):
    if not RESULTS.exists():
        return
    for f in sorted(RESULTS.glob("*.json")):
        r = json.loads(f.read_text())
        if mesh and r.get("mesh") != mesh:
            continue
        yield r


def run(emit):
    n = 0
    for r in iter_records():
        key = f"roofline/{r['arch']}|{r['shape']}|{r['mesh']}"
        if r["status"] == "skip":
            emit(key, 0.0, "SKIP (long_500k needs sub-quadratic attention)")
            continue
        if r["status"] != "ok":
            emit(key, 0.0, f"ERROR {r.get('error', '?')[:80]}")
            continue
        t = r["roofline"]
        dom_s = {"compute": t["compute_s"], "memory": t["memory_s"],
                 "collective": t["collective_s"]}[t["dominant"]]
        emit(key, dom_s * 1e6,
             f"compute_s={t['compute_s']:.4f} memory_s={t['memory_s']:.4f} "
             f"collective_s={t['collective_s']:.4f} "
             f"dominant={t['dominant']} "
             f"useful_flops_ratio={t['useful_flops_ratio']:.3f} "
             f"hbm_gib={r['per_device_hbm_bytes']/2**30:.2f} "
             f"fits={r['fits_hbm']}")
        n += 1
    if n == 0:
        emit("roofline/none", 0.0,
             "no dry-run artifacts found — run `python -m "
             "repro.launch.dryrun --all` first")
