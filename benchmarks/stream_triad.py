"""Paper Appendix A2: STREAM bandwidth probe (copy/scale/add/triad).

The paper calibrates its roofline with measured STREAM numbers (CPU 0.2,
GPU 3.0, datasheet 5.3 TB/s). We run the same probe on this host via jnp
(XLA-compiled) and report achieved GB/s; on a real TPU the Pallas kernels
in repro/kernels/stream run the identical probe against HBM.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import hw
from repro.kernels.stream.ops import BYTES_PER_ELEM
from repro.utils.timing import time_fn

N = 4_000_000   # 16 MB/array: fits host caches poorly, like STREAM intends


def run(emit):
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(N,)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(N,)).astype(np.float32))
    s = 3.0

    ops = {
        "copy": jax.jit(lambda a, b: a + 0.0),
        "scale": jax.jit(lambda a, b: s * a),
        "add": jax.jit(lambda a, b: a + b),
        "triad": jax.jit(lambda a, b: a + s * b),
    }
    for name, fn in ops.items():
        # trim=1 drops the slowest/fastest repeat: STREAM-style numbers on
        # a shared host are scheduler-noise-sensitive
        stats = time_fn(fn, a, b, iters=5, warmup=2, trim=1)
        t = stats.median
        bytes_moved = BYTES_PER_ELEM[name] * 4 * N
        gbps = bytes_moved / t / 1e9
        emit(f"stream/{name}", t * 1e6,
             f"host_gbps={gbps:.2f} "
             f"(paper MI300A: cpu={hw.MI300A_CPU_STREAM_TRIAD/1e12:.2f} "
             f"gpu={hw.MI300A_GPU_STREAM_TRIAD/1e12:.2f} TB/s; "
             f"target v5e HBM={hw.TPU_V5E.hbm_bandwidth/1e12:.2f} TB/s)")
