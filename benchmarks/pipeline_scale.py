"""Pipeline suite: the full features→p-value path under each
materialization bridge, plus the stage-1 distance impls head-to-head.

The ROADMAP flagged the distance stage as the wall-clock bottleneck for
large n; this suite tracks (a) how the blocked/pallas stage-1 forms compare
to dense, and (b) what the stream / fused bridges cost relative to dense
materialization — the trade the MI300A unified-memory literature says
decides memory-heavy pipelines on APU-class parts.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import pipeline
from repro.utils.timing import time_fn


def _study(n, d, g=8, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.gamma(1.0, 1.0, size=(n, d)).astype(np.float32)
    grouping = rng.integers(0, g, size=n).astype(np.int32)
    grouping[:g] = np.arange(g)
    return jnp.asarray(x), jnp.asarray(grouping)


def run(emit):
    # stage 1 head-to-head: dense vs blocked vs pallas (interpret off TPU)
    n, d = 512, 128
    x, grouping = _study(n, d)
    for name in ("braycurtis.dense", "braycurtis.blocked",
                 "euclidean.dense", "euclidean.blocked"):
        spec = pipeline.get(name)
        _, _, dense_fn = spec.bound()
        fn = jax.jit(dense_fn)
        t = time_fn(fn, x, iters=3, warmup=1)
        emit(f"pipeline/dist_{name}", t * 1e6,
             f"n={n} d={d} gb_s={(4*n*n)/t/1e9:.2f}")

    # full pipeline under each bridge (one plan each)
    perms = 199
    for mat in ("dense", "stream", "fused"):
        t0 = time.perf_counter()
        res = pipeline.pipeline(x, grouping, metric="braycurtis",
                                n_perms=perms, materialize=mat,
                                key=jax.random.key(0))
        jax.block_until_ready(res.f_perms)
        t = time.perf_counter() - t0
        emit(f"pipeline/e2e_{mat}", t * 1e6,
             f"n={n} perms={perms} perms_s={perms/t:.0f} "
             f"p={float(res.p_value):.3f}")

    # batched studies through one plan (serving scenario)
    s_count, nb = 4, 128
    xs = jnp.stack([_study(nb, 64, seed=s)[0] for s in range(s_count)])
    gs = jnp.stack([_study(nb, 64, seed=s)[1] for s in range(s_count)])
    t0 = time.perf_counter()
    many = pipeline.pipeline_many(xs, gs, n_groups=8, metric="braycurtis",
                                  n_perms=99, key=jax.random.key(0))
    jax.block_until_ready(many.f_perms)
    t = time.perf_counter() - t0
    emit("pipeline/many_4x128", t * 1e6,
         f"studies={s_count} perms=99 studies_s={s_count/t:.1f}")
