"""Pipeline suite: the full features→p-value path under each
materialization bridge, plus the stage-1 distance impls head-to-head.

The ROADMAP flagged the distance stage as the wall-clock bottleneck for
large n; this suite tracks (a) how the blocked/pallas stage-1 forms compare
to dense, (b) what the stream / fused bridges cost relative to dense
materialization — the trade the MI300A unified-memory literature says
decides memory-heavy pipelines on APU-class parts — and (c) the fused-
kernel smoke config: the single-pass sweep vs the PR 2 fused bridge at
scale, with the peak-device-memory model columns in the JSON artifact
(peak_mib scales with n while mat2_mib is the n² the plan never holds).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import pipeline
from repro.utils.timing import time_fn


def _study(n, d, g=8, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.gamma(1.0, 1.0, size=(n, d)).astype(np.float32)
    grouping = rng.integers(0, g, size=n).astype(np.int32)
    grouping[:g] = np.arange(g)
    return jnp.asarray(x), jnp.asarray(grouping)


def run(emit):
    # stage 1 head-to-head: dense vs blocked vs pallas (interpret off TPU)
    n, d = 512, 128
    x, grouping = _study(n, d)
    for name in ("braycurtis.dense", "braycurtis.blocked",
                 "euclidean.dense", "euclidean.blocked"):
        spec = pipeline.get(name)
        _, _, dense_fn = spec.bound()
        fn = jax.jit(dense_fn)
        t = time_fn(fn, x, iters=3, warmup=1).median
        emit(f"pipeline/dist_{name}", t * 1e6,
             f"n={n} d={d} gb_s={(4*n*n)/t/1e9:.2f}")

    # full pipeline under each bridge (one plan each)
    perms = 199
    for mat in ("dense", "stream", "fused", "fused-kernel"):
        t0 = time.perf_counter()
        res = pipeline.pipeline(x, grouping, metric="braycurtis",
                                n_perms=perms, materialize=mat,
                                key=jax.random.key(0))
        jax.block_until_ready(res.f_perms)
        t = time.perf_counter() - t0
        emit(f"pipeline/e2e_{mat}", t * 1e6,
             f"n={n} perms={perms} perms_s={perms/t:.0f} "
             f"p={float(res.p_value):.3f}")

    # precision knobs on the fused-kernel sweep: measured wall-clock per
    # feature-slab precision plus the kernel-path traffic model columns
    # (feat_bytes_mib is the Pallas megakernel's predicted feature-slab HBM
    # bytes per permutation chunk at this precision — the knob's whole
    # point; off-TPU the measured path is the XLA value-parity sweep, so
    # the wall-clock tracks quantization cost, not the traffic win)
    prec_cases = [("braycurtis", "f32"), ("braycurtis", "bf16"),
                  ("braycurtis", "fp8"), ("jaccard", "f32"),
                  ("jaccard", "packed")]
    perms_p = 99
    for metric_p, tag in prec_cases:
        ptuning = pipeline.registry.precision_tuning(tag)

        def go_p():
            r = pipeline.pipeline(x, grouping, metric=metric_p,
                                  n_perms=perms_p,
                                  materialize="fused-kernel",
                                  fused_tuning=ptuning,
                                  key=jax.random.key(0))
            jax.block_until_ready(r.f_perms)
            return r
        go_p()                                 # compile + warm
        t0 = time.perf_counter()
        res_p = go_p()
        t = time.perf_counter() - t0
        kspec = pipeline.get_fused(f"{metric_p}.fusedk.pallas")
        feat_bytes = pipeline.registry.fused_feat_traffic_bytes(
            kspec, n, d, {**dict(kspec.tuning), **ptuning})
        emit(f"pipeline/prec_{metric_p}_{tag}", t * 1e6,
             f"n={n} perms={perms_p} perms_s={perms_p/t:.0f} "
             f"feat_mib={feat_bytes/2**20:.2f} "
             f"p={float(res_p.p_value):.3f}",
             extra={"precision": tag,
                    "feat_bytes_mib": round(feat_bytes / 2**20, 3),
                    "traffic_model": "pallas"})

    # fused-kernel smoke at scale (CI config): the single-pass sweep vs the
    # PR 2 fused bridge, WARM wall-clock (serving-relevant; compile paid
    # once), plus the peak-device-memory model columns — peak_mib must
    # track n, not n² (mat2_mib is the n² reference the plan never holds).
    perms_s = 199
    for ns in (768, 1536):
        xs_, gs_ = _study(ns, 64)
        for mat in ("fused", "fused-kernel"):
            def go():
                r = pipeline.pipeline(xs_, gs_, metric="braycurtis",
                                      n_perms=perms_s, materialize=mat,
                                      key=jax.random.key(0))
                jax.block_until_ready(r.f_perms)
                return r
            go()                                   # compile + warm
            t0 = time.perf_counter()
            res = go()
            t = time.perf_counter() - t0
            pl = pipeline.plan_pipeline(ns, 64, perms_s + 1, 8,
                                        materialize=mat)
            if mat == "fused-kernel":
                spec = pipeline.get_fused(pl.fused_impl)
                peak = spec.workset_bytes(ns, 64, pl.sw.chunk, 8,
                                          pl.row_block)
            else:
                peak = 4 * pl.row_block * ns + 4 * pl.sw.chunk * ns * 17
            emit(f"pipeline/scale_n{ns}_{mat}", t * 1e6,
                 f"n={ns} perms={perms_s} perms_s={perms_s/t:.0f} "
                 f"peak_mib={peak/2**20:.1f} "
                 f"mat2_mib={4*ns*ns/2**20:.1f} "
                 f"p={float(res.p_value):.3f}")

    # out-of-core slab streaming: the same fused sweeps with the feature
    # table on DISK (tiny device budget forces residency below hbm), WARM
    # wall-clock. rows_s is the sweep's sample-row throughput; stall_frac
    # is prefetcher wait time over sweep wall-clock — the double-buffered
    # overlap claim is real only while it stays well under 1 (CI gates the
    # smoke config at < 0.2).
    import tempfile
    from repro import obs as _obs
    from repro.data import slabcache as _slabcache
    from repro.obs import metrics as _ometrics
    n_ooc, d_ooc, perms_o = 768, 64, 199
    x_ooc, g_ooc = _study(n_ooc, d_ooc)
    with tempfile.TemporaryDirectory() as td, _obs.session():
        cache = _slabcache.build_slab_cache(td + "/cache",
                                            np.asarray(x_ooc),
                                            slab_rows=256)
        for mat, row_name in (("fused", "ooc_stream"),
                              ("fused-kernel", "ooc_fused-kernel")):
            def go_o():
                r = pipeline.pipeline(cache, g_ooc, metric="braycurtis",
                                      n_perms=perms_o, materialize=mat,
                                      device_budget_bytes=1024,
                                      key=jax.random.key(0))
                jax.block_until_ready(r.f_perms)
                return r
            go_o()                             # compile + warm
            before = _ometrics.snapshot()["counters"]
            t0 = time.perf_counter()
            res_o = go_o()
            t = time.perf_counter() - t0
            stall_s = (_ometrics.value("prefetch.stall_ms")
                       - before.get("prefetch.stall_ms", 0.0)) / 1e3
            read_b = (_ometrics.value("prefetch.bytes")
                      - before.get("prefetch.bytes", 0.0))
            stall_frac = stall_s / t if t > 0 else 0.0
            emit(f"pipeline/{row_name}", t * 1e6,
                 f"n={n_ooc} perms={perms_o} rows_s={n_ooc/t:.0f} "
                 f"read_mib={read_b/2**20:.1f} "
                 f"stall_frac={stall_frac:.3f} "
                 f"p={float(res_o.p_value):.3f}",
                 extra={"rows_per_s": round(n_ooc / t, 1),
                        "stall_frac": round(stall_frac, 4),
                        "disk_read_mib": round(read_b / 2**20, 2),
                        "slab_rows": cache.slab_rows,
                        "n_slabs": cache.n_slabs})

    # partial/covariate designs: 1 factor + 2 covariates through the same
    # bridges (the design subsystem's per-column contraction) — wall-clock
    # + the peak-memory model columns, mirroring the scale rows above
    rng_d = np.random.default_rng(7)
    nd, dd, gd, kcols = 384, 64, 8, 10   # basis: 1 + 2 cov + (g-1)
    xd, gdg = _study(nd, dd, g=gd, seed=7)
    cov_d = rng_d.normal(size=(nd, 2))
    st_d = (np.arange(nd) % 4).astype(np.int32)
    perms_d = 199
    for mat in ("dense", "stream", "fused-kernel"):
        def go_d():
            r = pipeline.pipeline(xd, gdg, metric="braycurtis",
                                  n_perms=perms_d, materialize=mat,
                                  covariates=cov_d, strata=st_d,
                                  n_groups=gd, key=jax.random.key(0))
            jax.block_until_ready(r.f_perms)
            return r
        go_d()                                 # compile + warm
        t0 = time.perf_counter()
        res_d = go_d()
        t = time.perf_counter() - t0
        pl = pipeline.plan_pipeline(nd, dd, perms_d + 1, gd,
                                    materialize=mat, design_cols=kcols)
        if mat == "fused-kernel":
            peak = 4 * pl.row_block * nd + 4 * pl.sw.chunk * nd * (kcols + 1)
        else:
            peak = 4 * nd * nd + 4 * pl.sw.chunk * nd * (kcols + 1)
        emit(f"pipeline/design_1f2c_{mat}", t * 1e6,
             f"n={nd} perms={perms_d} cols={kcols} perms_s={perms_d/t:.0f} "
             f"peak_mib={peak/2**20:.1f} mat2_mib={4*nd*nd/2**20:.1f} "
             f"p={float(res_d.p_value):.3f}")

    # batched studies through one plan (serving scenario)
    s_count, nb = 4, 128
    xs = jnp.stack([_study(nb, 64, seed=s)[0] for s in range(s_count)])
    gs = jnp.stack([_study(nb, 64, seed=s)[1] for s in range(s_count)])
    t0 = time.perf_counter()
    many = pipeline.pipeline_many(xs, gs, n_groups=8, metric="braycurtis",
                                  n_perms=99, key=jax.random.key(0))
    jax.block_until_ready(many.f_perms)
    t = time.perf_counter() - t0
    emit("pipeline/many_4x128", t * 1e6,
         f"studies={s_count} perms=99 studies_s={s_count/t:.1f}")

    # matrix-input multi-study engine, study axis over the 'data' mesh
    # (smoke: on a 1-device CI host the mesh degenerates to the vmap path;
    # the multidevice CI job asserts sharded == single-host bit-equality)
    from repro import engine
    from repro.core.distance import distance_matrix
    from repro.launch.mesh import make_host_mesh
    dms = jnp.stack([distance_matrix(xs[s], "braycurtis")
                     for s in range(s_count)])
    mesh = make_host_mesh()
    t0 = time.perf_counter()
    manym = engine.permanova_many(dms, gs, n_groups=8, n_perms=99,
                                  key=jax.random.key(0), mesh=mesh)
    jax.block_until_ready(manym.f_perms)
    t = time.perf_counter() - t0
    emit("pipeline/many_sharded_4x128", t * 1e6,
         f"studies={s_count} perms=99 data_ways={mesh.shape['data']} "
         f"studies_s={s_count/t:.1f}")

    # PCoA ordination consumer riding the stream bridge (implicit centered
    # operator — mat2 stays the only (n, n) array) and the fused bridge
    # (matvecs re-streamed from features; nothing (n, n)-shaped)
    for mat in ("stream", "fused-kernel"):
        t0 = time.perf_counter()
        res = pipeline.pipeline(x, grouping, metric="braycurtis",
                                n_perms=99, materialize=mat, ordination=3,
                                key=jax.random.key(0))
        jax.block_until_ready(res.ordination.coords)
        t = time.perf_counter() - t0
        expl = float(res.ordination.explained[0])
        emit(f"pipeline/pcoa3_{mat}", t * 1e6,
             f"n={n} perms=99 method={res.ordination.method} "
             f"expl0={expl:.3f} r2={float(res.r2):.3f}")
