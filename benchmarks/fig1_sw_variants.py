"""Paper Figure 1 analogue: PERMANOVA s_W execution time by algorithm.

The paper benchmarks brute-force vs tiled on MI300A CPU/GPU at
n=25145, perms=3999. This container is a 1-core CPU host, so we run a
scaled-down shape and report:
  * wall time per variant (host-CPU numbers, labeled as such),
  * effective matrix-stream bandwidth (bytes of mat2 consumed / s),
  * the projected time at the paper's full shape (linear in n^2 * perms).

Variants come from the engine registry (the unified s_W impl table): the
jnp brute / tiled / permblock-matmul forms, plus the Pallas kernels in
interpret mode (correctness-path timing, not TPU performance). The suite
also reports what the hardware-aware planner picks for this backend/shape.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro import engine, hw
from repro.core import permutations
from repro.utils.timing import time_fn

N = 1024
N_PERMS = 64
N_GROUPS = 8

# fig1/jnp_* CSV names are stable across PRs; tuning mirrors the pre-engine
# hand-picked values.
JNP_TUNING = {
    "brute": {"block": 16},
    "tiled": {"tile": 256, "block": 4},
    "matmul": {"perm_block": 32},
}


def _instance(n=N, p=N_PERMS, g=N_GROUPS, seed=0):
    rng = np.random.default_rng(seed)
    d = rng.random((n, n)).astype(np.float32)
    d = (d + d.T) / 2
    np.fill_diagonal(d, 0.0)
    grouping = rng.integers(0, g, size=n).astype(np.int32)
    grouping[:g] = np.arange(g)
    inv_gs = permutations.inv_group_sizes(jnp.asarray(grouping), g)
    gperms = permutations.permutation_batch(jax.random.key(0),
                                            jnp.asarray(grouping), 0, p)
    return jnp.asarray(d * d), gperms, inv_gs


def run(emit):
    mat2, gperms, inv_gs = _instance()
    n, p = mat2.shape[0], gperms.shape[0]
    stream_bytes = 4.0 * n * n * p          # brute-force mat2 traffic

    results = {}
    for name in engine.names(kind="jnp"):
        fn = jax.jit(engine.get(name).bound(**JNP_TUNING.get(name, {})))
        t = time_fn(fn, mat2, gperms, inv_gs, iters=3, warmup=1).median
        results[name] = t
        gbps = stream_bytes / t / 1e9
        scale = (hw.PAPER_N_DIMS / n) ** 2 * (hw.PAPER_N_PERMS / p)
        emit(f"fig1/jnp_{name}", t * 1e6, f"host_gbps={gbps:.2f} "
             f"projected_paper_shape_s={t * scale:.1f}")

    speedup = results["brute"] / results["matmul"]
    emit("fig1/matmul_speedup_over_brute", 0.0, f"x{speedup:.2f} "
         f"(paper: GPU brute 6x over CPU brute; here the MXU-form "
         f"reformulation is the analogous winner)")

    # What would the planner run here? (the paper's finding as dispatch)
    pl = engine.plan(n, p, N_GROUPS)
    emit("fig1/planner_pick", 0.0, f"impl={pl.impl} ({pl.reason})")
    for backend in ("cpu", "gpu", "tpu"):
        pl_b = engine.plan(hw.PAPER_N_DIMS, hw.PAPER_N_PERMS, N_GROUPS,
                           backend=backend)
        emit(f"fig1/planner_paper_shape_{backend}", 0.0, f"impl={pl_b.impl}")

    # Pallas kernels, interpret mode, smaller shape (interpreter overhead)
    m2s, gps, igs = _instance(n=256, p=8)
    for name in engine.names(kind="pallas"):
        fn = engine.get(name).bound(tile_r=128, tile_c=128, perm_block=4)
        t = time_fn(fn, m2s, gps, igs, iters=2, warmup=1).median
        emit(f"fig1/{name}_interpret", t * 1e6,
             "correctness-path timing (CPU interpreter, not TPU)")
