"""Paper Figure 1 analogue: PERMANOVA s_W execution time by algorithm.

The paper benchmarks brute-force vs tiled on MI300A CPU/GPU at
n=25145, perms=3999. This container is a 1-core CPU host, so we run a
scaled-down shape and report:
  * wall time per variant (host-CPU numbers, labeled as such),
  * effective matrix-stream bandwidth (bytes of mat2 consumed / s),
  * the projected time at the paper's full shape (linear in n^2 * perms).

Variants: jnp brute / tiled / permblock-matmul, plus the Pallas kernels in
interpret mode (correctness-path timing, not TPU performance).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro import hw
from repro.core import fstat, permutations
from repro.utils.timing import time_fn

N = 1024
N_PERMS = 64
N_GROUPS = 8


def _instance(n=N, p=N_PERMS, g=N_GROUPS, seed=0):
    rng = np.random.default_rng(seed)
    d = rng.random((n, n)).astype(np.float32)
    d = (d + d.T) / 2
    np.fill_diagonal(d, 0.0)
    grouping = rng.integers(0, g, size=n).astype(np.int32)
    grouping[:g] = np.arange(g)
    inv_gs = permutations.inv_group_sizes(jnp.asarray(grouping), g)
    gperms = permutations.permutation_batch(jax.random.key(0),
                                            jnp.asarray(grouping), 0, p)
    return jnp.asarray(d * d), gperms, inv_gs


def run(emit):
    mat2, gperms, inv_gs = _instance()
    n, p = mat2.shape[0], gperms.shape[0]
    stream_bytes = 4.0 * n * n * p          # brute-force mat2 traffic

    variants = {
        "fig1/jnp_brute": jax.jit(lambda m, g, w: fstat.sw_brute(
            m, g, w, block=16)),
        "fig1/jnp_tiled": jax.jit(lambda m, g, w: fstat.sw_tiled(
            m, g, w, tile=256, block=4)),
        "fig1/jnp_matmul": jax.jit(lambda m, g, w: fstat.sw_matmul(
            m, g, w, perm_block=32)),
    }
    results = {}
    for name, fn in variants.items():
        t = time_fn(fn, mat2, gperms, inv_gs, iters=3, warmup=1)
        results[name] = t
        gbps = stream_bytes / t / 1e9
        scale = (hw.PAPER_N_DIMS / n) ** 2 * (hw.PAPER_N_PERMS / p)
        emit(name, t * 1e6, f"host_gbps={gbps:.2f} "
             f"projected_paper_shape_s={t * scale:.1f}")

    speedup = results["fig1/jnp_brute"] / results["fig1/jnp_matmul"]
    emit("fig1/matmul_speedup_over_brute", 0.0, f"x{speedup:.2f} "
         f"(paper: GPU brute 6x over CPU brute; here the MXU-form "
         f"reformulation is the analogous winner)")

    # Pallas kernels, interpret mode, smaller shape (interpreter overhead)
    from repro.kernels.permanova_sw import ops
    m2s, gps, igs = _instance(n=256, p=8)
    for variant in ops.VARIANTS:
        fn = lambda a, b, c: ops.permanova_sw(
            a, b, c, variant=variant, tile_r=128, tile_c=128, perm_block=4)
        t = time_fn(fn, m2s, gps, igs, iters=2, warmup=1)
        emit(f"fig1/pallas_{variant}_interpret", t * 1e6,
             "correctness-path timing (CPU interpreter, not TPU)")
