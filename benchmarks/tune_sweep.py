"""Real autotune sweep for the fused-kernel sweep's joint tuning space.

  PYTHONPATH=src python -m benchmarks.tune_sweep             # full sweep
  PYTHONPATH=src python -m benchmarks.tune_sweep --smoke     # CI config

`autotune_fused` times each fused IMPL at its registry-default tiles; this
harness searches the actual knob space per (backend, metric, impl):
tile_r x tile_c x feat_block x perm_block crossed with the feature-slab
precision (f32 / bf16 / fp8 / packed-bit jaccard). The winning tuning per
(impl, precision) is persisted into the SAME per-host autotune cache the
planners read (engine.planner.record_entry, key
'fusedk|<backend>|<metric>|<impl>[|<precision>]'), so a subsequent
plan_pipeline() with those precision knobs picks the measured tiles up as
its defaults — the sweep then REPLANS and verifies that round trip,
exiting nonzero if any recorded winner fails to feed the planner.

--smoke shrinks the problem and the grid to a seconds-scale CI step and
points the cache at a temp file unless --cache is given, asserting the
same round-trip contract on every entry it wrote.
"""

from __future__ import annotations

import argparse
import itertools
import os
import sys
import tempfile
import time


def _tile_grid(smoke: bool):
    """(tile_r, tile_c, feat_block, perm_block) candidates."""
    if smoke:
        return [(16, 16, 8, 4), (32, 32, 8, 4)]
    return [(tr, tc, fb, pb)
            for tr, tc in ((32, 32), (64, 64), (128, 128), (64, 128))
            for fb in (32, 128)
            for pb in (8, 16)]


def _precisions(metric: str, kernel_metric: str, smoke: bool):
    tags = ["f32", "fp8"] if smoke else ["f32", "bf16", "fp8"]
    if kernel_metric == "jaccard":
        tags.append("packed")
    return tags


def sweep(metric: str, backend: str, *, n: int, d: int, g: int,
          sample_perms: int, smoke: bool, emit=print):
    """Sweep one (backend, metric); returns the recorded cache keys."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import distance as _dist
    from repro.core import permutations as _perms
    from repro.engine import planner as _eplanner
    from repro.pipeline import planner as _pplanner
    from repro.pipeline import registry as _dreg
    from repro.pipeline import streaming as _streaming

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.gamma(1.0, 1.0, size=(n, d)).astype(np.float32))
    grouping = jnp.asarray(
        np.concatenate([np.arange(g), rng.integers(0, g, n - g)]),
        jnp.int32)
    inv_gs = _perms.inv_group_sizes(grouping, g)
    mdef = _dist.ROW_METRICS[metric]
    xprep = mdef.prepare(x)
    key = jax.random.key(0)
    row_block = min(256, n)

    recorded = []
    for name in _dreg.fused_names(metric=metric):
        spec = _dreg.get_fused(name)
        if backend not in spec.backends and \
                not (smoke and spec.kind == "pallas"):
            # smoke keeps the megakernel in (interpret mode off TPU) so CI
            # exercises the tile grid + precision kernel bodies end to end
            continue
        # xla has no tile knobs: one config per precision
        tiles = (_tile_grid(smoke) if spec.kind == "pallas"
                 else [None])
        for tag in _precisions(metric, spec.kernel_metric, smoke):
            best_t, best_tuning = float("inf"), None
            for tile in tiles:
                tuning = dict(spec.tuning)
                tuning.update(_dreg.precision_tuning(tag))
                tuning = {k: v for k, v in tuning.items()
                          if k in spec.tuning}
                if tile is not None:
                    tuning.update(zip(("tile_r", "tile_c", "feat_block",
                                       "perm_block"), tile))

                def run(_tuning=tuning):
                    return _streaming.fused_kernel_sw(
                        xprep, mdef.rows, grouping, inv_gs, key,
                        sample_perms, impl=spec.kind,
                        kernel_metric=spec.kernel_metric,
                        row_block=row_block, chunk=sample_perms,
                        tuning=_tuning)

                try:
                    run()                      # compile + warm
                    t0 = time.perf_counter()
                    run()
                    t = time.perf_counter() - t0
                except Exception as exc:  # noqa: BLE001 — skip non-lowering
                    emit(f"# skip {name}[{tag}] tile={tile}: {exc}")
                    continue
                emit(f"tune/{backend}/{name}/{tag}/"
                     f"{'x'.join(map(str, tile)) if tile else 'default'},"
                     f"{t*1e6:.1f}")
                if t < best_t:
                    best_t, best_tuning = t, tuning
            if best_tuning is None:
                continue
            ckey = _pplanner._fused_key(backend, metric, name, best_tuning)
            _eplanner.record_entry(ckey, {
                "impl": name, "us": round(best_t * 1e6, 1), "n": n, "d": d,
                "bucket": _eplanner._bucket(n), "tuning": best_tuning})
            recorded.append((ckey, name, best_tuning))
            emit(f"tune/winner {ckey} -> "
                 f"{sorted(best_tuning.items())} ({best_t*1e6:.0f}us)")
    return recorded


def verify_roundtrip(recorded, metric: str, backend: str, *, n: int,
                     d: int, g: int, sample_perms: int, emit=print) -> int:
    """Replan with each recorded entry's precision knobs and check the
    persisted winner's tiles came back as the plan's defaults."""
    from repro.engine import planner as _eplanner
    from repro.pipeline import planner as _pplanner

    _eplanner.load_autotune_cache(reload=True)   # from disk, like a fresh
    failures = 0                                 # process would
    for ckey, name, tuning in recorded:
        entry = _eplanner.measured_entry(ckey)
        if not entry or entry.get("schema") != _eplanner.CACHE_SCHEMA \
                or entry.get("tuning") != tuning:
            emit(f"# FAIL {ckey}: entry did not round-trip the cache "
                 f"(got {entry})")
            failures += 1
            continue
        prec = {k: v for k, v in tuning.items()
                if k.startswith("feat_") and k != "feat_block"}
        pl = _pplanner.plan_pipeline(
            n, d, sample_perms, g, metric=metric, backend=backend,
            materialize="fused-kernel", fused_impl=name, fused_tuning=prec)
        if pl.fused_tuning != tuning:
            emit(f"# FAIL {ckey}: planner defaults {pl.fused_tuning} != "
                 f"recorded winner {tuning}")
            failures += 1
        else:
            emit(f"tune/verified {ckey} feeds planner defaults")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--metric", default=None,
                    help="comma-separated metrics (default: all fused)")
    ap.add_argument("--backend", default=None)
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--d", type=int, default=None)
    ap.add_argument("--groups", type=int, default=8)
    ap.add_argument("--perms", type=int, default=None,
                    help="permutation sample per timing")
    ap.add_argument("--cache", default=None,
                    help="autotune cache file (default: the per-host "
                         "cache; --smoke defaults to a temp file)")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI config: tiny problem, 2-point "
                         "tile grid, temp cache unless --cache")
    args = ap.parse_args()

    if args.cache or args.smoke:
        cache = args.cache or os.path.join(
            tempfile.mkdtemp(prefix="repro-tune-"), "autotune.json")
        os.environ["REPRO_AUTOTUNE_CACHE"] = cache
        print(f"# cache: {cache}")

    # env must be set before the planner first loads the cache
    from repro.engine import planner as _eplanner
    from repro.pipeline import registry as _dreg
    _eplanner.load_autotune_cache(reload=True)

    backend = args.backend or _eplanner.default_backend()
    n = args.n or (64 if args.smoke else 1024)
    d = args.d or (32 if args.smoke else 256)
    perms = args.perms or (4 if args.smoke else 16)
    metrics = (args.metric.split(",") if args.metric
               else sorted({_dreg.get_fused(f).metric
                            for f in _dreg.fused_names()}))

    failures = 0
    for metric in metrics:
        recorded = sweep(metric, backend, n=n, d=d, g=args.groups,
                         sample_perms=perms, smoke=args.smoke)
        if not recorded:
            print(f"# FAIL {metric}: sweep recorded no cache entries")
            failures += 1
            continue
        failures += verify_roundtrip(recorded, metric, backend, n=n, d=d,
                                     g=args.groups, sample_perms=perms)
    print(f"# tune_sweep: {'FAILED' if failures else 'ok'} "
          f"({failures} failures)")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
