"""Always-on PERMANOVA serving throughput (robustness PR deliverable).

Drives `repro.serve.permanova.PermanovaServer` with a mixed-shape study
stream and reports studies/sec against a fixed per-request latency SLO,
with p50/p99 derived from the `serve.step` trace spans — the same
telemetry a production deployment would alarm on. Buckets are warmed
before the measured stream so rows time steady-state serving (the warm
path re-traces zero jaxprs); a separate row measures the cold first
request to show what the bucket cache saves. A chaos row replays the
stream with one injected worker death and reports the recovery overhead
relative to the clean run (results are bit-identical by construction —
the chaos suite asserts it; here we only price it).
"""

from __future__ import annotations

import time

import numpy as np

from repro import obs
from repro.core.distance import distance_matrix
from repro.runtime.faultinject import FaultInjector
from repro.serve.permanova import (PermanovaServer, StudyRequest,
                                   serve_stats_from_events)

SLO_S = 0.25          # per-request latency objective for the throughput row
N_PERMS = 199
STREAM = 24           # measured requests per row
BATCH = 8             # max_batch for the same-bucket coalescing row
SAME_BUCKET = 16      # same-bucket requests per batched row


def _stream(seed=0, n_studies=STREAM):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_studies):
        n = int(rng.integers(18, 41))
        x = rng.normal(size=(n, 5)).astype(np.float32)
        g = rng.integers(0, 3, size=n).astype(np.int32)
        reqs.append(StudyRequest(
            grouping=g, dm=np.asarray(distance_matrix(x, "euclidean")),
            n_perms=N_PERMS, seed=i, request_id=f"bench{i}"))
    return reqs


def _bucket_stream(seed=1, n_studies=SAME_BUCKET):
    """Mixed-n studies that all land in the same shape bucket (n_pad=32)."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_studies):
        n = int(rng.integers(20, 31))
        x = rng.normal(size=(n, 5)).astype(np.float32)
        g = rng.integers(0, 3, size=n).astype(np.int32)
        reqs.append(StudyRequest(
            grouping=g, dm=np.asarray(distance_matrix(x, "euclidean")),
            n_perms=N_PERMS, seed=100 + i, request_id=f"bucket{i}"))
    return reqs


def _measure(srv, reqs, **kw):
    obs.clear()
    t0 = time.perf_counter()
    out = srv.serve(reqs, **kw)
    wall = time.perf_counter() - t0
    stats = serve_stats_from_events(obs.events())
    assert all(r.ok for r in out), [r.error for r in out if not r.ok]
    lat = sorted(r.wall_s for r in out)
    in_slo = sum(1 for s in lat if s <= SLO_S)
    return out, wall, stats, in_slo


def run(emit):
    with obs.session():
        reqs = _stream()

        # cold: first-ever request pays bucket compile + plan measurement
        srv = PermanovaServer(workers=3, block=64)
        t0 = time.perf_counter()
        r0 = srv.process(reqs[0])
        cold = time.perf_counter() - t0
        assert r0.ok
        emit("serve/cold_first_request", cold * 1e6,
             f"bucket={r0.bucket}")

        # warm the remaining shape buckets out-of-band, then measure
        for r in srv.serve(reqs):
            assert r.ok
        out, wall, stats, in_slo = _measure(srv, reqs)
        emit("serve/warm_stream", wall / len(out) * 1e6,
             f"studies_per_s={len(out)/wall:.2f} "
             f"slo_{int(SLO_S*1e3)}ms={in_slo}/{len(out)} "
             f"p50_ms={stats['p50_s']*1e3:.1f} "
             f"p99_ms={stats['p99_s']*1e3:.1f}",
             extra={"studies_per_s": round(len(out) / wall, 2),
                    "slo_s": SLO_S, "in_slo": in_slo,
                    "requests": len(out),
                    "p50_s": round(stats["p50_s"], 5),
                    "p99_s": round(stats["p99_s"], 5)})

        # same-bucket coalescing: identical stream served request-at-a-time
        # vs batched into one vmapped dispatch per <=BATCH same-sig group
        # (per-request key folding keeps the two bit-identical; the chaos
        # suite asserts it, here we price the admission win)
        bucket_reqs = _bucket_stream()
        srv_s = PermanovaServer(workers=3, block=64)
        for r in srv_s.serve(bucket_reqs):          # warm the serial bucket
            assert r.ok
        out_s, wall_s, _, _ = _measure(srv_s, bucket_reqs)
        emit("serve/serial_same_bucket", wall_s / len(out_s) * 1e6,
             f"studies_per_s={len(out_s)/wall_s:.2f} batch=1",
             extra={"studies_per_s": round(len(out_s) / wall_s, 2),
                    "batch": 1, "requests": len(out_s)})

        srv_b = PermanovaServer(workers=3, block=64, max_batch=BATCH)
        for r in srv_b.serve(bucket_reqs, batched=True):  # warm batched jaxprs
            assert r.ok
        out_b, wall_b, _, _ = _measure(srv_b, bucket_reqs, batched=True)
        speedup = wall_s / wall_b
        emit("serve/batched_same_bucket", wall_b / len(out_b) * 1e6,
             f"studies_per_s={len(out_b)/wall_b:.2f} batch={BATCH} "
             f"speedup_vs_serial={speedup:.2f}x",
             extra={"studies_per_s": round(len(out_b) / wall_b, 2),
                    "batch": BATCH, "requests": len(out_b),
                    "speedup_vs_serial": round(speedup, 2)})

        # chaos: same stream, one worker killed mid-bag on a warm server;
        # the delta over warm_stream is the price of re-dispatching the
        # dead worker's blocks
        inj = FaultInjector(seed=0).kill_worker_after_blocks(0, 1)
        srv_f = PermanovaServer(workers=3, block=64, injector=inj)
        for r in srv_f.serve(reqs):        # warm the faulty server too
            assert r.ok
        inj.kill_worker_after_blocks(0, 1)
        out_f, wall_f, stats_f, _ = _measure(srv_f, reqs)
        emit("serve/worker_death_stream", wall_f / len(out_f) * 1e6,
             f"studies_per_s={len(out_f)/wall_f:.2f} "
             f"p99_ms={stats_f['p99_s']*1e3:.1f} "
             f"overhead_pct={(wall_f/wall-1)*100:.1f}",
             extra={"studies_per_s": round(len(out_f) / wall_f, 2),
                    "p99_s": round(stats_f["p99_s"], 5)})
