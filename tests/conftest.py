import os
import subprocess
import sys

import numpy as np
import pytest

# NOTE: no XLA_FLAGS here — tests must see the real (single) device.
# Multi-device behaviour is tested via run_subprocess(..., devices=N).

# Planner dispatch assertions must exercise the heuristics, not whatever
# autotune winners a previous run persisted on this host. Tests that cover
# persistence point this at a tmp path explicitly.
os.environ.setdefault("REPRO_AUTOTUNE_CACHE", "off")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_subprocess(code: str, *, devices: int = 8, timeout: int = 600):
    """Run python code in a fresh interpreter with N fake devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{proc.stdout}\n"
            f"STDERR:\n{proc.stderr}")
    return proc.stdout


@pytest.fixture(scope="session")
def small_study():
    """(dm, grouping, inv_gs, mat2) for a 48-sample 3-group study."""
    import jax.numpy as jnp
    from repro.core import distance, permutations
    from repro.data.microbiome import synthetic_study

    x, grouping = synthetic_study(48, 32, 3, effect_size=0.0, seed=7)
    dm = np.asarray(distance.braycurtis(jnp.asarray(x)))
    inv_gs = np.asarray(permutations.inv_group_sizes(jnp.asarray(grouping), 3))
    return dm, grouping, inv_gs, (dm * dm).astype(np.float32)
