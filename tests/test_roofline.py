"""Roofline machinery: loop-aware HLO costing and collective parsing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo_cost import loop_aware_cost
from repro.roofline.analysis import parse_collective_bytes


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


class TestLoopAwareCost:
    def test_scan_matches_unroll(self):
        def scanned(x, w):
            def body(c, _):
                return c @ w, None
            out, _ = jax.lax.scan(body, x, None, length=10)
            return out

        def unrolled(x, w):
            def body(c, _):
                return c @ w, None
            out, _ = jax.lax.scan(body, x, None, length=10, unroll=True)
            return out

        xs = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        c_s = loop_aware_cost(_compile(scanned, xs, xs).as_text())
        c_u = loop_aware_cost(_compile(unrolled, xs, xs).as_text())
        expect = 10 * 2 * 128 ** 3
        assert c_s.flops == pytest.approx(expect, rel=0.01)
        assert c_u.flops == pytest.approx(expect, rel=0.01)

    def test_nested_loops_multiply(self):
        def nested(x, w):
            def outer(c, _):
                def inner(c2, _):
                    return c2 @ w, None
                c2, _ = jax.lax.scan(inner, c, None, length=5)
                return c2, None
            out, _ = jax.lax.scan(outer, x, None, length=4)
            return out

        xs = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        c = loop_aware_cost(_compile(nested, xs, xs).as_text())
        assert c.flops == pytest.approx(20 * 2 * 64 ** 3, rel=0.01)

    def test_dot_flops_with_batch_dims(self):
        def f(a, b):
            return jnp.einsum("bij,bjk->bik", a, b)

        a = jax.ShapeDtypeStruct((4, 32, 16), jnp.float32)
        b = jax.ShapeDtypeStruct((4, 16, 8), jnp.float32)
        c = loop_aware_cost(_compile(f, a, b).as_text())
        assert c.flops == pytest.approx(2 * 4 * 32 * 16 * 8, rel=0.2)

    def test_model_flops_close_to_6nd(self):
        from repro.configs.registry import SMOKES
        from repro.models.model import build_model
        cfg = SMOKES["internlm2-1.8b"]
        model = build_model(cfg)
        params = model.abstract_params()
        batch = {"tokens": jax.ShapeDtypeStruct((4, 64), jnp.int32),
                 "targets": jax.ShapeDtypeStruct((4, 64), jnp.int32)}

        def grad_fn(p, b):
            return jax.grad(lambda pp: model.loss(pp, b)[0])(p)

        c = loop_aware_cost(_compile(grad_fn, params, batch).as_text())
        n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
        six_nd = 6 * n * 4 * 64
        assert 0.8 * six_nd < c.flops < 2.0 * six_nd


class TestCollectiveParsing:
    @pytest.mark.multidevice
    def test_psum_produces_all_reduce_bytes(self):
        from conftest import run_subprocess
        code = r"""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_mesh     # AxisType shim (jax 0.4.x)
from repro.roofline.hlo_cost import loop_aware_cost
try:  # jax >= 0.5 exposes shard_map at top level
    _shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map
mesh = make_mesh((8,), ("data",))
def f(x):
    return _shard_map(lambda y: jax.lax.psum(y, "data"), mesh=mesh,
                      in_specs=P("data"), out_specs=P())(x)
xs = jax.ShapeDtypeStruct((1024,), jnp.float32)
c = jax.jit(f).lower(xs).compile()
cost = loop_aware_cost(c.as_text())
assert cost.coll_bytes > 0, cost
assert cost.coll_by_kind["all-reduce"] > 0, cost.coll_by_kind
print("COLLECTIVE-OK", cost.coll_bytes)
"""
        out = run_subprocess(code, devices=8)
        assert "COLLECTIVE-OK" in out

    def test_text_parser_units(self):
        text = (" %ar = f32[256]{0} all-reduce(f32[256]{0} %x), "
                "replica_groups={}\n")
        out = parse_collective_bytes(text)
        assert out["all-reduce"] == 1024  # operand bytes
