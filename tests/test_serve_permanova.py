"""Multi-tenant PERMANOVA serving: shape buckets + compiled-program
reuse (zero warm retraces), admission control/backpressure, deadline
policy, plan persistence, and serving telemetry."""

import numpy as np
import pytest

from repro import obs
from repro.core.distance import distance_matrix
from repro.core.permanova import permanova
from repro.obs import jaxhooks
from repro.serve.permanova import (PermanovaServer, ServerOverloaded,
                                   StudyRequest, _next_bucket,
                                   serve_stats_from_events)


@pytest.fixture(scope="module")
def studies():
    rng = np.random.default_rng(7)
    out = []
    for n in (23, 19, 30):
        x = rng.normal(size=(n, 5)).astype(np.float32)
        g = rng.integers(0, 3, size=n).astype(np.int32)
        out.append((np.asarray(distance_matrix(x, "euclidean")), g))
    return out


class TestStatistics:
    def test_observed_matches_reference_labels(self, studies):
        dm, g = studies[0]
        srv = PermanovaServer(workers=2, block=64)
        res = srv.process(StudyRequest(grouping=g, dm=dm, n_perms=99))
        ref = permanova(dm, g, n_perms=9)
        # padded matmul reduction order differs from the unpadded
        # reference in the last bits; the statistic itself must agree
        assert float(res.result.f_stat) == pytest.approx(
            float(ref.f_stat), rel=1e-5)
        assert res.result.n_objects == dm.shape[0]

    def test_observed_matches_reference_dense(self, studies):
        dm, g = studies[0]
        rng = np.random.default_rng(0)
        cov = rng.normal(size=dm.shape[0])
        srv = PermanovaServer(workers=2, block=64)
        res = srv.process(StudyRequest(grouping=g, dm=dm, covariates=cov,
                                       n_perms=99))
        ref = permanova(dm, g, covariates=cov, n_perms=9)
        # dense-mode pads are exactly-zero basis rows, but the padded
        # reduction tree differs from the unpadded reference by ULPs;
        # bit-identity is the serve-vs-serve contract (chaos suite)
        assert float(res.result.f_stat) == pytest.approx(
            float(ref.f_stat), rel=1e-5)
        assert [t.name for t in res.result.terms] == ["cov0", "grouping"]

    def test_strata_and_weights_modes(self, studies):
        dm, g = studies[0]
        n = dm.shape[0]
        srv = PermanovaServer(workers=2, block=32)
        strata = (np.arange(n) % 2).astype(np.int32)
        r1 = srv.process(StudyRequest(grouping=g, dm=dm, strata=strata,
                                      n_perms=63))
        assert r1.status == "ok" and "labels_strata" in r1.bucket
        w = np.linspace(0.5, 1.5, n)
        r2 = srv.process(StudyRequest(grouping=g, dm=dm, weights=w,
                                      n_perms=63))
        assert r2.status == "ok" and "cols" in r2.bucket

    def test_features_path(self, studies):
        rng = np.random.default_rng(1)
        x = np.abs(rng.normal(size=(23, 6))).astype(np.float32)
        g = rng.integers(0, 2, size=23).astype(np.int32)
        srv = PermanovaServer(workers=2, block=64)
        res = srv.process(StudyRequest(grouping=g, x=x,
                                       metric="braycurtis", n_perms=49))
        assert res.status == "ok"
        ref = permanova(np.asarray(distance_matrix(x, "braycurtis")), g,
                        n_perms=9)
        assert float(res.result.f_stat) == pytest.approx(
            float(ref.f_stat), rel=1e-5)

    def test_bad_request_fails_not_raises(self, studies):
        dm, g = studies[0]
        srv = PermanovaServer()
        res = srv.process(StudyRequest(grouping=g))          # no dm, no x
        assert res.status == "failed" and "dm" in res.error


class TestBuckets:
    def test_warm_bucket_retraces_zero_jaxprs(self, studies):
        # different n, different n_perms, different seed — same bucket:
        # a warm server must not trace a single new jaxpr (the PR 7
        # retrace counter is the witness).
        (dm1, g1), (dm2, g2), _ = studies
        obs.enable(trace=False, metrics=True)
        try:
            srv = PermanovaServer(workers=2, block=32)
            srv.process(StudyRequest(grouping=g1, dm=dm1, n_perms=31,
                                     seed=1))
            before = obs.metrics.value(jaxhooks.RETRACES, 0.0)
            r = srv.process(StudyRequest(grouping=g2, dm=dm2, n_perms=63,
                                         seed=2))
            after = obs.metrics.value(jaxhooks.RETRACES, 0.0)
        finally:
            obs.disable()
        assert r.status == "ok"
        assert after - before == 0.0
        assert srv._buckets[(32, 3, "labels", 0)].hits == 2

    def test_bucket_sizing(self, studies):
        dm, g = studies[0]
        srv = PermanovaServer(workers=1, block=32,
                              bucket_sizes=[24, 64])
        res = srv.process(StudyRequest(grouping=g, dm=dm, n_perms=15))
        assert "n=24" in res.bucket
        ref = permanova(dm, g, n_perms=9)
        assert float(res.result.f_stat) == pytest.approx(
            float(ref.f_stat), rel=1e-5)

    def test_plan_persisted_and_reused(self, studies, tmp_path,
                                       monkeypatch):
        from repro.engine import planner
        dm, g = studies[0]
        monkeypatch.setenv(planner.AUTOTUNE_CACHE_ENV,
                           str(tmp_path / "tune.json"))
        planner.load_autotune_cache(reload=True)
        srv1 = PermanovaServer(workers=1, block=32, backend="cpu")
        srv1.process(StudyRequest(grouping=g, dm=dm, n_perms=15))
        key = "serveplan|cpu|n32|g3|labels|k0"
        entry = planner.measured_entry(key)
        assert entry is not None and "impl" in entry
        # a fresh server (warm restart) pins the persisted plan
        srv2 = PermanovaServer(workers=1, block=32, backend="cpu")
        res = srv2.process(StudyRequest(grouping=g, dm=dm, n_perms=15))
        assert f"->{entry['impl']}" in res.bucket
        planner.load_autotune_cache(reload=True)


class TestAdmission:
    def test_bounded_queue_sheds(self, studies):
        dm, g = studies[0]
        srv = PermanovaServer(workers=1, queue_limit=2)
        reqs = [StudyRequest(grouping=g, dm=dm, n_perms=9, seed=i)
                for i in range(4)]
        out = srv.serve(reqs)
        assert [r.status for r in out] == ["ok", "ok", "shed", "shed"]
        assert all(r.request_id for r in out)

    def test_backpressure_signal_and_raise(self, studies):
        dm, g = studies[0]
        srv = PermanovaServer(workers=1, queue_limit=2)
        assert not srv.backpressure
        srv.submit(StudyRequest(grouping=g, dm=dm, n_perms=9))
        srv.submit(StudyRequest(grouping=g, dm=dm, n_perms=9))
        assert srv.backpressure
        with pytest.raises(ServerOverloaded):
            srv.submit(StudyRequest(grouping=g, dm=dm, n_perms=9),
                       shed="raise")
        assert len(srv.pump()) == 2
        assert not srv.backpressure


class TestTelemetry:
    def test_serve_step_spans_and_stats(self, studies, tmp_path):
        dm, g = studies[0]
        srv = PermanovaServer(workers=2, block=32)
        obs.clear()
        with obs.session(str(tmp_path / "serve_trace.json")):
            for i in range(4):
                srv.process(StudyRequest(grouping=g, dm=dm, n_perms=15,
                                         seed=i))
            evs = obs.events()
            stats = serve_stats_from_events(evs)
        assert stats["requests"] == 4
        assert stats["requests_per_s"] > 0
        assert stats["p99_s"] >= stats["p50_s"] > 0
        # block spans nest under the request step spans
        assert any(e["name"] == "serve.block" for e in evs)
        s = srv.stats()
        assert s["requests"] == 4 and s["p99_s"] >= s["p50_s"]
        assert s["buckets"] == 1
        assert (tmp_path / "serve_trace.json").exists()

    def test_serving_counters(self, studies):
        dm, g = studies[0]
        obs.enable(trace=False, metrics=True)
        try:
            snap0 = obs.metrics.snapshot()
            srv = PermanovaServer(workers=1, queue_limit=1)
            srv.submit(StudyRequest(grouping=g, dm=dm, n_perms=9))
            srv.submit(StudyRequest(grouping=g, dm=dm, n_perms=9))  # shed
            srv.pump()
            d = obs.metrics.counter_delta(snap0)
        finally:
            obs.disable()
        assert d.get("serve.requests_admitted") == 1.0
        assert d.get("serve.requests_shed") == 1.0
        assert d.get("serve.requests_completed") == 1.0


def _same_bucket_reqs(studies, n_perms=63):
    """Six requests with mixed n (23/19/30) that all land in the n=32
    power-of-two bucket — the coalescing unit."""
    return [StudyRequest(grouping=g, dm=dm, n_perms=n_perms, seed=i)
            for i, (dm, g) in enumerate(studies * 2)]


class TestBatched:
    def test_batched_bit_identical_to_pump(self, studies):
        serial = PermanovaServer(workers=2, block=16).serve(
            _same_bucket_reqs(studies))
        srv = PermanovaServer(workers=2, block=16, max_batch=8)
        batched = srv.serve(_same_bucket_reqs(studies), batched=True)
        assert [r.status for r in serial] == ["ok"] * 6
        for a, b in zip(serial, batched):
            assert b.status == "ok" and b.batched and not a.batched
            # bit-identity: full permutation set, not just the summary
            assert np.array_equal(np.asarray(a.result.f_perms),
                                  np.asarray(b.result.f_perms))
            assert float(a.result.p_value) == float(b.result.p_value)
            assert float(a.result.f_stat) == float(b.result.f_stat)
        # one bucket, one hit per request — same accounting as serial
        assert srv._buckets[(32, 3, "labels", 0)].hits == 6

    def test_mixed_n_perms_same_bucket(self, studies):
        # blocks span the longest sweep; shorter members' tails are
        # computed-and-discarded without perturbing their draws
        dm, g = studies[0]
        reqs = [StudyRequest(grouping=g, dm=dm, n_perms=np_, seed=s)
                for s, np_ in enumerate((31, 63, 15))]
        serial = PermanovaServer(workers=2, block=16).serve(
            [StudyRequest(grouping=g, dm=dm, n_perms=np_, seed=s)
                for s, np_ in enumerate((31, 63, 15))])
        batched = PermanovaServer(workers=2, block=16).serve(
            reqs, batched=True)
        for a, b in zip(serial, batched):
            assert b.status == "ok" and b.n_perms_done == a.n_perms_done
            assert np.array_equal(np.asarray(a.result.f_perms),
                                  np.asarray(b.result.f_perms))

    def test_batched_zero_warm_retraces(self, studies):
        obs.enable(trace=False, metrics=True)
        try:
            srv = PermanovaServer(workers=2, block=16, max_batch=3)
            srv.serve(_same_bucket_reqs(studies)[:3], batched=True)
            before = obs.metrics.value(jaxhooks.RETRACES, 0.0)
            out = srv.serve(_same_bucket_reqs(studies)[3:], batched=True)
            after = obs.metrics.value(jaxhooks.RETRACES, 0.0)
        finally:
            obs.disable()
        assert [r.status for r in out] == ["ok"] * 3
        assert after - before == 0.0

    def test_submit_returns_future_completed_by_pump(self, studies):
        dm, g = studies[0]
        srv = PermanovaServer(workers=1)
        fut = srv.submit(StudyRequest(grouping=g, dm=dm, n_perms=9))
        assert not fut.done()
        (res,) = srv.pump()
        assert fut.done() and fut.result() is res
        assert res.status == "ok"

    def test_async_worker_threads(self, studies):
        srv = PermanovaServer(workers=2, block=16, max_batch=4)
        srv.start(threads=2)
        try:
            futs = [srv.submit(r) for r in _same_bucket_reqs(studies)]
            out = [f.result(timeout=300) for f in futs]
        finally:
            srv.stop()
        assert [r.status for r in out] == ["ok"] * 6
        serial = PermanovaServer(workers=2, block=16).serve(
            _same_bucket_reqs(studies))
        for a, b in zip(serial, out):
            assert np.array_equal(np.asarray(a.result.f_perms),
                                  np.asarray(b.result.f_perms))

    def test_batch_telemetry(self, studies):
        obs.enable(trace=True, metrics=True)
        try:
            obs.clear()
            snap0 = obs.metrics.snapshot()
            srv = PermanovaServer(workers=2, block=16, max_batch=8)
            srv.serve(_same_bucket_reqs(studies), batched=True)
            d = obs.metrics.counter_delta(snap0)
            evs = obs.events()
        finally:
            obs.disable()
            obs.clear()
        assert d.get("serve.batches", 0) >= 1
        assert d.get("serve.batched_requests") == 6.0
        hist = obs.metrics.REGISTRY.histogram("serve.batch_size")
        assert hist.count >= 1 and hist.max <= 8
        # one serve.step event per request, sharing the batch window —
        # coalesced throughput is visible to serve_stats_from_events
        stats = serve_stats_from_events(evs)
        assert stats["requests"] == 6
        assert np.isfinite(stats["requests_per_s"])
        assert any(e["name"] == "serve.batch" for e in evs)

    def test_cols_mode_batched_matches_serial(self, studies):
        dm, g = studies[0]
        rng = np.random.default_rng(3)
        cov = rng.normal(size=dm.shape[0])
        reqs = lambda: [StudyRequest(grouping=g, dm=dm, covariates=cov,
                                     n_perms=31, seed=s) for s in range(3)]
        serial = PermanovaServer(workers=2, block=16).serve(reqs())
        batched = PermanovaServer(workers=2, block=16).serve(
            reqs(), batched=True)
        for a, b in zip(serial, batched):
            assert a.status == b.status == "ok"
            assert np.array_equal(np.asarray(a.result.f_perms),
                                  np.asarray(b.result.f_perms))
            for ta, tb in zip(a.result.terms, b.result.terms):
                assert float(ta.p_value) == float(tb.p_value)


class TestBucketOverflow:
    def test_next_bucket_overflow_raises(self):
        with pytest.raises(ValueError, match="largest configured bucket"):
            _next_bucket(40, [16, 32])
        assert _next_bucket(40, None) == 64        # open-ended default
        assert _next_bucket(30, [16, 32]) == 32

    def test_process_overflow_fails_cleanly(self, studies):
        dm, g = studies[2]                         # n=30
        srv = PermanovaServer(bucket_sizes=[16, 24])
        res = srv.process(StudyRequest(grouping=g, dm=dm, n_perms=9))
        assert res.status == "failed"
        assert "bucket" in res.error

    def test_submit_overflow_fails_future_pump_survives(self, studies):
        (dm_ok, g_ok), _, (dm_big, g_big) = studies   # n=23 / n=30
        srv = PermanovaServer(bucket_sizes=[24])
        f_bad = srv.submit(StudyRequest(grouping=g_big, dm=dm_big,
                                        n_perms=9))
        f_ok = srv.submit(StudyRequest(grouping=g_ok, dm=dm_ok, n_perms=9))
        assert f_bad.done()
        assert f_bad.result().status == "failed"
        assert "bucket" in f_bad.result().error
        out = srv.pump()                           # loop must not crash
        assert len(out) == 1 and out[0].status == "ok"
        assert f_ok.result().status == "ok"

    def test_batched_stream_with_overflow_member(self, studies):
        (dm_ok, g_ok), _, (dm_big, g_big) = studies
        srv = PermanovaServer(bucket_sizes=[24], max_batch=4)
        out = srv.serve([StudyRequest(grouping=g_big, dm=dm_big, n_perms=9),
                         StudyRequest(grouping=g_ok, dm=dm_ok, n_perms=9)],
                        batched=True)
        assert [r.status for r in out] == ["failed", "ok"]


class TestStatsEdgeCases:
    def test_stats_empty_window(self):
        s = PermanovaServer().stats()
        assert s["requests"] == 0 and s["requests_per_s"] == 0.0
        assert s["p50_s"] == 0.0 and s["p99_s"] == 0.0

    def test_stats_single_sample_not_inf(self, studies):
        from repro.runtime.faultinject import VirtualClock
        dm, g = studies[0]
        # virtual clock: zero-width window — the old span formula
        # reported rps=inf here
        srv = PermanovaServer(workers=1, clock=VirtualClock())
        srv.process(StudyRequest(grouping=g, dm=dm, n_perms=9))
        s = srv.stats()
        assert s["requests"] == 1
        assert np.isfinite(s["requests_per_s"])
        assert s["p50_s"] == s["p99_s"]

    def test_stats_single_sample_real_clock(self, studies):
        dm, g = studies[0]
        srv = PermanovaServer(workers=1)
        srv.process(StudyRequest(grouping=g, dm=dm, n_perms=9))
        s = srv.stats()
        assert np.isfinite(s["requests_per_s"])
        assert s["requests_per_s"] > 0.0

    def test_event_stats_empty_and_tiny_windows(self):
        assert serve_stats_from_events([]) == {
            "requests": 0, "requests_per_s": 0.0,
            "p50_s": 0.0, "p99_s": 0.0}
        one = [{"name": "serve.step", "ph": "X", "ts": 5.0, "dur": 2.0}]
        s = serve_stats_from_events(one)
        assert s["requests"] == 1 and np.isfinite(s["requests_per_s"])
        assert s["p50_s"] == s["p99_s"] == pytest.approx(2.0 / 1e6)
        zero = [{"name": "serve.step", "ph": "X", "ts": 5.0, "dur": 0.0}]
        s = serve_stats_from_events(zero)
        assert s["requests"] == 1 and s["requests_per_s"] == 0.0
