"""Sharding rules + multi-device behaviour (subprocess with fake devices)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding.rules import (RULES_MULTI_POD, RULES_SINGLE_POD,
                                  logical_to_spec)


def _mesh_1():
    from repro.launch.mesh import make_mesh  # jax 0.4.x AxisType shim
    return make_mesh((1, 1), ("data", "model"))


class TestLogicalToSpec:
    def test_basic_mapping(self):
        mesh = _mesh_1()
        spec = logical_to_spec(("embed", "mlp"), (64, 128), mesh,
                               RULES_SINGLE_POD)
        assert spec == P("data", "model")

    def test_indivisible_dim_dropped(self):
        mesh = _mesh_1()
        # sizes are 1 so everything divides; simulate with a fake mesh of 2
        # via the rules path in a subprocess instead — here check None axes
        spec = logical_to_spec((None, "mlp"), (7, 128), mesh,
                               RULES_SINGLE_POD)
        assert spec == P(None, "model")

    def test_trailing_nones_trimmed(self):
        mesh = _mesh_1()
        spec = logical_to_spec(("batch", None, None), (8, 4, 4), mesh,
                               RULES_SINGLE_POD)
        assert spec == P("data")

    def test_multi_pod_batch_axes(self):
        assert RULES_MULTI_POD.rules["batch"] == ("pod", "data")


MULTI_DEVICE_CODE = r"""
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.registry import SMOKES
from repro.launch.mesh import make_mesh
from repro.models.model import build_model
from repro.sharding.rules import set_active, rules_for_mesh
from repro.sharding.state import axes_to_shardings, batch_axes, train_state_axes
from repro.train.step import make_train_state_init, make_train_step
from repro.optim import adamw

assert len(jax.devices()) == 8, jax.devices()
mesh = make_mesh((4, 2), ("data", "model"))
cfg = SMOKES["internlm2-1.8b"].replace(attn_q_chunk=8)
model = build_model(cfg)
opt = adamw()
step = make_train_step(model, opt)
init = make_train_state_init(model, opt)
state = init(jax.random.key(0))
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, size=(8, 32)).astype(np.int32)),
         "targets": jnp.asarray(rng.integers(0, cfg.vocab, size=(8, 32)).astype(np.int32))}

# single-device reference
ref_state, ref_metrics = jax.jit(step)(state, batch)

state_abs = jax.eval_shape(init, jax.random.key(0))
rules = rules_for_mesh(mesh)
state_sh = axes_to_shardings(train_state_axes(model, opt, state_abs), state_abs, mesh, rules)
batch_sh = axes_to_shardings(batch_axes(batch), batch, mesh, rules)
with set_active(mesh):
    sharded_step = jax.jit(step, in_shardings=(state_sh, batch_sh), out_shardings=(state_sh, NamedSharding(mesh, P())))
    state_in = jax.device_put(state, state_sh)
    batch_in = jax.device_put(batch, batch_sh)
    out_state, metrics = sharded_step(state_in, batch_in)

err = abs(float(metrics["loss"]) - float(ref_metrics["loss"]))
assert err < 5e-3, f"sharded loss mismatch: {err}"
for a, b in zip(jax.tree.leaves(ref_state.params), jax.tree.leaves(out_state.params)):
    d = np.max(np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32)))
    assert d < 5e-2, f"param mismatch {d}"
print("SHARDED-TRAIN-OK", float(metrics["loss"]))
"""


@pytest.mark.multidevice
def test_sharded_train_step_matches_single_device(run=None):
    from conftest import run_subprocess
    out = run_subprocess(MULTI_DEVICE_CODE, devices=8, timeout=600)
    assert "SHARDED-TRAIN-OK" in out


DISTRIBUTED_PERMANOVA_CODE = r"""
import jax, jax.numpy as jnp
import numpy as np
from repro.core import distance, permanova
from repro.core.distributed import permanova_distributed
from repro.data.microbiome import synthetic_study
from repro.launch.mesh import make_mesh

x, grouping = synthetic_study(48, 32, 3, effect_size=0.0, seed=7)
dm = distance.braycurtis(jnp.asarray(x))
ref = permanova(dm, jnp.asarray(grouping), n_perms=99, sw_impl="brute")
for shape, names in [((4, 2), ("data", "model")),
                     ((2, 2, 2), ("pod", "data", "model"))]:
    mesh = make_mesh(shape, names)
    for impl in ("brute", "matmul"):
        r = permanova_distributed(mesh, dm, jnp.asarray(grouping),
                                  n_perms=99, impl=impl)
        assert abs(float(r.f_stat) - float(ref.f_stat)) < 1e-4
        assert abs(float(r.p_value) - float(ref.p_value)) < 1e-6
print("DIST-PERMANOVA-OK")
"""


@pytest.mark.multidevice
def test_distributed_permanova_multi_device():
    from conftest import run_subprocess
    out = run_subprocess(DISTRIBUTED_PERMANOVA_CODE, devices=8, timeout=600)
    assert "DIST-PERMANOVA-OK" in out
