"""Pipeline subsystem: registry capability metadata, joint planner rules,
two-stage parity (the acceptance bar: pipeline(features, labels) ==
distance() -> permanova() for every registered metric, under every
materialization), fused/streaming equivalence, Gower centering, the
batched multi-study API, and persisted autotune measurements."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine, pipeline
from repro.core import distance as dist
from repro.core.permanova import permanova
from repro.engine import planner as eplanner

N, D, G = 53, 24, 4   # prime n: every block/tile pad path exercised


def _study(seed=0, n=N, d=D, g=G):
    rng = np.random.default_rng(seed)
    x = rng.gamma(1.0, 1.0, size=(n, d)).astype(np.float32)
    x *= rng.random(size=(n, d)) < 0.5        # sparsity: jaccard informative
    x[:, 0] = np.maximum(x[:, 0], 1e-3)       # no all-zero samples
    grouping = rng.integers(0, g, size=n).astype(np.int32)
    grouping[:g] = np.arange(g)
    return x, grouping


class TestRegistry:
    def test_all_metrics_have_dense_and_blocked(self):
        for metric in pipeline.metrics():
            kinds = {pipeline.get(nm).kind
                     for nm in pipeline.names(metric=metric)}
            assert {"dense", "blocked"} <= kinds, metric

    def test_metadata_complete(self):
        for name in pipeline.names():
            spec = pipeline.get(name)
            assert spec.backends, name
            assert callable(spec.workset_bytes)
            ws = spec.workset_bytes(1024, 128, 256)
            assert ws > 0, name
            prepare, rows, dense = spec.bound()
            assert callable(prepare) and callable(rows) and callable(dense)

    def test_every_impl_serves_rows_and_dense(self):
        x, _ = _study(1)
        xj = jnp.asarray(x)
        for name in pipeline.names():
            spec = pipeline.get(name)
            tuning = ({"tile_r": 16, "tile_c": 16, "feat_block": 16}
                      if spec.kind == "pallas" else {})
            prepare, rows, dense = spec.bound(**tuning)
            xp = prepare(xj)
            full = np.asarray(dense(xj))
            slab = np.asarray(rows(xp[:8], xp))
            # rows slab must agree with the dense matrix off-diagonal
            mask = ~np.eye(N, dtype=bool)[:8]
            np.testing.assert_allclose(slab[mask], full[:8][mask],
                                       rtol=1e-4, atol=1e-5)

    def test_capability_filters(self):
        assert pipeline.names(metric="braycurtis", kind="pallas")
        # every metric carries a tiled stage-1 impl (jaccard rides the
        # presence/absence matmul form)
        assert pipeline.names(metric="jaccard", kind="pallas")
        assert "euclidean.dense" in pipeline.names(backend="gpu")

    def test_fused_registry_complete(self):
        for metric in pipeline.metrics():
            kinds = {pipeline.get_fused(nm).kind
                     for nm in pipeline.fused_names(metric=metric)}
            assert kinds == {"pallas", "xla"}, metric
        for name in pipeline.fused_names():
            spec = pipeline.get_fused(name)
            assert spec.workset_bytes(4096, 128, 512, 8, 256) > 0
            # the megakernel's working set must not scale with n
            if spec.kind == "pallas":
                assert spec.workset_bytes(4096, 128, 512, 8, 256) == \
                    spec.workset_bytes(65536, 128, 512, 8, 256)


class TestPlanner:
    def test_materialization_by_budget(self):
        n = 1024
        mat2 = 4 * n * n
        dense = pipeline.plan_pipeline(n, 64, 1000, 8, backend="cpu",
                                       matrix_budget_bytes=3 * mat2)
        assert dense.materialize == "dense"
        stream = pipeline.plan_pipeline(n, 64, 1000, 8, backend="cpu",
                                        matrix_budget_bytes=1.5 * mat2)
        assert stream.materialize == "stream"
        # over-budget problems land on the single-pass fused-kernel sweep
        fused = pipeline.plan_pipeline(n, 64, 1000, 8, backend="cpu",
                                       matrix_budget_bytes=0.5 * mat2)
        assert fused.materialize == "fused-kernel"
        assert fused.fused_impl == "braycurtis.fusedk.xla"
        # the two-dispatch fused bridge stays reachable by pinning
        pinned = pipeline.plan_pipeline(n, 64, 1000, 8, backend="cpu",
                                        materialize="fused")
        assert pinned.materialize == "fused"

    def test_backend_dispatch(self):
        tpu = pipeline.plan_pipeline(1024, 128, 1000, 8, backend="tpu",
                                     metric="braycurtis")
        assert tpu.dist_impl == "braycurtis.pallas"
        gpu = pipeline.plan_pipeline(512, 64, 1000, 8, backend="gpu",
                                     metric="euclidean")
        assert gpu.dist_impl == "euclidean.dense"
        # broadcast-metric transients blow the slab budget on cpu -> blocked
        cpu = pipeline.plan_pipeline(4096, 512, 1000, 8, backend="cpu",
                                     metric="braycurtis")
        assert cpu.dist_impl == "braycurtis.blocked"

    def test_fused_pins_matmul_sw(self):
        pl = pipeline.plan_pipeline(512, 64, 1000, 8, backend="cpu",
                                    materialize="fused")
        assert pl.sw.impl == "matmul"
        # fused chunk honors the G-fold one-hot footprint
        assert 4.0 * 512 * (2 * 8 + 1) * pl.sw.chunk <= \
            eplanner.DEFAULT_STREAM_BUDGET_BYTES

    def test_joint_plan_includes_both_stages(self):
        pl = pipeline.plan_pipeline(256, 32, 100, 4, backend="cpu")
        desc = pl.describe()
        assert pl.dist_impl.split(".")[0] == "braycurtis"
        assert pl.sw.impl in engine.names()
        assert "->" in desc and pl.sw.impl in desc

    def test_pinned_fields_respected(self):
        pl = pipeline.plan_pipeline(
            256, 32, 100, 4, backend="cpu", dist_impl="euclidean.blocked",
            metric="euclidean", materialize="stream", row_block=32,
            sw_impl="brute", chunk=10)
        assert (pl.dist_impl, pl.materialize, pl.row_block) == \
            ("euclidean.blocked", "stream", 32)
        assert (pl.sw.impl, pl.sw.chunk) == ("brute", 10)

    def test_fused_cannot_honor_pinned_sw_impl(self):
        # both pinned: hard error (either fused bridge)
        with pytest.raises(ValueError, match="one-hot matmul"):
            pipeline.plan_pipeline(512, 64, 100, 8, backend="cpu",
                                   materialize="fused", sw_impl="tiled")
        with pytest.raises(ValueError, match="one-hot matmul"):
            pipeline.plan_pipeline(512, 64, 100, 8, backend="cpu",
                                   materialize="fused-kernel",
                                   sw_impl="brute")
        # bridge auto-chosen: downgrade to stream, honor the pinned impl
        pl = pipeline.plan_pipeline(512, 64, 100, 8, backend="cpu",
                                    sw_impl="tiled",
                                    matrix_budget_bytes=1000)
        assert pl.materialize == "stream"
        assert pl.sw.impl == "tiled"
        assert "downgraded" in pl.reason

    def test_row_block_threaded_into_blocked_tuning(self):
        # the dense bridge over a blocked impl must honor the planned slab
        pl = pipeline.plan_pipeline(4096, 512, 100, 8, backend="cpu",
                                    metric="braycurtis", row_block=32)
        assert pl.dist_impl == "braycurtis.blocked"
        assert pl.dist_tuning["block"] == 32

    def test_metric_impl_mismatch_rejected(self):
        with pytest.raises(ValueError, match="computes"):
            pipeline.plan_pipeline(256, 32, 100, 4, metric="braycurtis",
                                   dist_impl="euclidean.dense")


class TestPipelineParity:
    """Acceptance bar: pipeline(features) == distance() -> permanova()."""

    @pytest.mark.parametrize("metric", sorted(dist.METRICS))
    @pytest.mark.parametrize("materialize",
                             ["dense", "stream", "fused", "fused-kernel"])
    def test_matches_two_stage(self, metric, materialize):
        x, grouping = _study(seed=11)
        key = jax.random.key(5)
        dm = dist.distance_matrix(jnp.asarray(x), metric)
        ref = permanova(dm, jnp.asarray(grouping), n_perms=99, key=key)
        assert np.isfinite(float(ref.f_stat))  # degenerate data would
        # make every comparison below vacuous (NaN == NaN passes allclose)
        res = pipeline.pipeline(x, grouping, metric=metric, n_perms=99,
                                key=key, materialize=materialize,
                                row_block=16, chunk=25)
        np.testing.assert_allclose(float(res.f_stat), float(ref.f_stat),
                                   rtol=1e-4)
        assert float(res.p_value) == float(ref.p_value)
        np.testing.assert_allclose(np.asarray(res.f_perms),
                                   np.asarray(ref.f_perms), rtol=1e-4)

    def test_stream_matches_dense_plan(self):
        x, grouping = _study(seed=12)
        key = jax.random.key(6)
        outs = [pipeline.pipeline(x, grouping, n_perms=199, key=key,
                                  materialize=m, row_block=16)
                for m in ("dense", "stream", "fused", "fused-kernel")]
        for other in outs[1:]:
            np.testing.assert_allclose(np.asarray(other.f_perms),
                                       np.asarray(outs[0].f_perms),
                                       rtol=1e-4)
            assert float(other.p_value) == float(outs[0].p_value)

    def test_fused_ragged_blocks_and_chunks(self):
        # block/chunk sizes that divide NOTHING evenly
        x, grouping = _study(seed=13)
        key = jax.random.key(7)
        a = pipeline.pipeline(x, grouping, n_perms=100, key=key,
                              materialize="fused", row_block=13, chunk=17)
        b = pipeline.pipeline(x, grouping, n_perms=100, key=key,
                              materialize="dense")
        np.testing.assert_allclose(np.asarray(a.f_perms),
                                   np.asarray(b.f_perms), rtol=1e-4)

    def test_plan_recorded_on_result(self):
        x, grouping = _study(seed=14)
        res = pipeline.pipeline(x, grouping, n_perms=19)
        assert res.method.startswith("pipeline[")
        assert "->" in res.plan

    def test_permanova_accepts_features(self):
        x, grouping = _study(seed=15)
        key = jax.random.key(8)
        via_features = permanova(jnp.asarray(x), jnp.asarray(grouping),
                                 n_perms=49, key=key, metric="braycurtis")
        dm = dist.distance_matrix(jnp.asarray(x), "braycurtis")
        via_dm = permanova(dm, jnp.asarray(grouping), n_perms=49, key=key)
        np.testing.assert_allclose(float(via_features.f_stat),
                                   float(via_dm.f_stat), rtol=1e-4)
        assert float(via_features.p_value) == float(via_dm.p_value)
        # non-square 2-D input auto-routes (no metric kwarg needed)
        auto = permanova(jnp.asarray(x), jnp.asarray(grouping),
                         n_perms=49, key=key)
        assert float(auto.p_value) == float(via_dm.p_value)


class TestGowerCentering:
    def test_centered_matrix_properties(self):
        x, _ = _study(seed=16)
        dm = dist.distance_matrix(jnp.asarray(x), "euclidean")
        g = np.asarray(pipeline.gower_center(dm * dm))
        np.testing.assert_allclose(g.sum(axis=0), 0.0, atol=1e-3)
        np.testing.assert_allclose(g.sum(axis=1), 0.0, atol=1e-3)
        # trace(G) = s_T * n / n = sum d^2 / n ... trace identity:
        mat2 = np.asarray(dm * dm)
        s_t = mat2.sum() / 2 / N
        np.testing.assert_allclose(np.trace(g), s_t, rtol=1e-5)

    def test_streaming_stats_feed_centering(self):
        x, _ = _study(seed=17)
        mdef = dist.ROW_METRICS["braycurtis"]
        xp = mdef.prepare(jnp.asarray(x))
        mat2, stats = pipeline.build_mat2_streaming(xp, mdef.rows, block=16)
        a = np.asarray(pipeline.gower_center(jnp.asarray(mat2), stats))
        b = np.asarray(pipeline.gower_center(jnp.asarray(mat2)))
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


class TestPipelineMany:
    def test_matches_independent_pipelines(self):
        s_count = 3
        xs, gs = zip(*[_study(seed=20 + s, n=32, g=3) for s in range(s_count)])
        xs = jnp.stack([jnp.asarray(x) for x in xs])
        gs = jnp.stack([jnp.asarray(g) for g in gs])
        key = jax.random.key(9)
        many = pipeline.pipeline_many(xs, gs, n_groups=3, n_perms=49,
                                      key=key, sw_impl="matmul")
        assert len(many) == s_count
        for s in range(s_count):
            single = pipeline.pipeline(
                xs[s], gs[s], n_groups=3, n_perms=49,
                key=jax.random.fold_in(key, s), sw_impl="matmul",
                materialize="dense")
            np.testing.assert_allclose(np.asarray(many.f_perms[s]),
                                       np.asarray(single.f_perms),
                                       rtol=1e-4)
            assert float(many.p_value[s]) == float(single.p_value)

    def test_records_joint_plan(self):
        xs = jnp.stack([jnp.asarray(_study(seed=s, n=24, g=3)[0])
                        for s in range(2)])
        gs = jnp.stack([jnp.asarray(_study(seed=s, n=24, g=3)[1])
                        for s in range(2)])
        many = pipeline.pipeline_many(xs, gs, n_groups=3, n_perms=19)
        assert "->" in many.plan


class TestAutotunePersistence:
    """Satellite: measurements survive to disk and feed plan() heuristics."""

    def test_roundtrip_and_heuristic_feedback(self, tmp_path, monkeypatch):
        cache = tmp_path / "autotune.json"
        monkeypatch.setenv(eplanner.AUTOTUNE_CACHE_ENV, str(cache))
        eplanner.load_autotune_cache(reload=True)
        try:
            rng = np.random.default_rng(0)
            d = rng.random((32, 32)).astype(np.float32)
            d = (d + d.T) / 2
            np.fill_diagonal(d, 0.0)
            grouping = np.arange(32) % 3
            inv_gs = np.full((3,), 3.0 / 32, np.float32)
            winner = eplanner.autotune(
                jnp.asarray(d * d), jnp.asarray(grouping.astype(np.int32)),
                jnp.asarray(inv_gs), sample_perms=4, backend="cpu")
            # measurement persisted with per-candidate timings
            data = json.loads(cache.read_text())
            (key_str, entry), = data.items()
            assert key_str == "cpu|n32|g3"
            assert entry["impl"] == winner
            assert set(entry["candidates"]) == \
                set(eplanner._default_candidates("cpu"))
            assert set(entry["times_us"]) <= set(entry["candidates"])
            # a FRESH load (new process analogue) feeds the heuristics
            eplanner.load_autotune_cache(reload=True)
            pl = eplanner.plan(32, 100, 3, backend="cpu")
            assert pl.impl == winner
            assert "autotune" in pl.reason
            # different bucket: heuristics, not the cache
            pl2 = eplanner.plan(8192, 100, 8, backend="cpu")
            assert pl2.impl == "tiled"
        finally:
            monkeypatch.setenv(eplanner.AUTOTUNE_CACHE_ENV, "off")
            eplanner.load_autotune_cache(reload=True)

    def test_off_disables_persistence(self, monkeypatch):
        monkeypatch.setenv(eplanner.AUTOTUNE_CACHE_ENV, "off")
        assert eplanner.autotune_cache_path() is None
        eplanner.load_autotune_cache(reload=True)
        assert eplanner.measured_impl("cpu", 32, 3) is None

    def test_stale_or_restricted_entries_ignored(self, tmp_path, monkeypatch):
        full = sorted(eplanner._default_candidates("cpu"))
        cache = tmp_path / "autotune.json"
        cache.write_text(json.dumps({
            # impl no longer registered (measured over the full set)
            "cpu|n64|g4": {"impl": "renamed_away",
                           "candidates": full + ["renamed_away"],
                           "times_us": {}},
            # winner from a RESTRICTED shoot-out must not feed plan()
            "cpu|n32|g4": {"impl": "brute", "candidates": ["brute"],
                           "times_us": {}},
        }))
        monkeypatch.setenv(eplanner.AUTOTUNE_CACHE_ENV, str(cache))
        eplanner.load_autotune_cache(reload=True)
        try:
            assert eplanner.measured_impl("cpu", 64, 4) is None
            assert eplanner.plan(64, 100, 4, backend="cpu").impl == "matmul"
            assert eplanner.measured_impl("cpu", 32, 4) is None
            assert eplanner.measured_impl("cpu", 32, 4,
                                          candidates=["brute"]) == "brute"
        finally:
            monkeypatch.setenv(eplanner.AUTOTUNE_CACHE_ENV, "off")
            eplanner.load_autotune_cache(reload=True)
