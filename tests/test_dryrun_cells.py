"""Dry-run machinery on reduced configs in a small-mesh subprocess: every
family's cell kinds lower + compile, and roofline terms come out sane."""

import json

import pytest

CODE = r"""
import jax, json
from repro.launch.cells import build_cell, input_specs
from repro.launch.mesh import make_mesh
from repro.sharding.rules import set_active
from repro.roofline.analysis import analyze_compiled

mesh = make_mesh((2, 2), ("data", "model"))
results = {}
cells = [
    ("internlm2-1.8b", "train_4k"),      # dense train
    ("grok-1-314b", "train_4k"),         # moe train (scan experts)
    ("zamba2-1.2b", "decode_32k"),       # hybrid decode
    ("xlstm-350m", "decode_32k"),        # xlstm decode
    ("whisper-base", "prefill_32k"),     # encdec prefill
    ("internvl2-76b", "train_4k"),       # vlm train
    ("qwen1.5-110b", "long_500k"),       # skip rule
]
for arch, shape in cells:
    cell = build_cell(arch, shape, mesh, smoke=True)
    if cell.kind == "skip":
        results[f"{arch}|{shape}"] = {"status": "skip"}
        continue
    with set_active(mesh):
        c = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                    out_shardings=cell.out_shardings,
                    donate_argnums=cell.donate_argnums
                    ).lower(*cell.args_abs).compile()
    terms = analyze_compiled(c, chips=4)
    assert terms.flops > 0, (arch, shape)
    assert terms.hbm_bytes > 0, (arch, shape)
    assert terms.dominant in ("compute", "memory", "collective")
    results[f"{arch}|{shape}"] = {
        "status": "ok", "dominant": terms.dominant,
        "flops": terms.flops, "coll": terms.collective_bytes}
print("CELLS-JSON:" + json.dumps(results))
"""


@pytest.mark.multidevice
def test_smoke_cells_lower_compile_and_analyze():
    from conftest import run_subprocess
    out = run_subprocess(CODE, devices=4, timeout=900)
    payload = [l for l in out.splitlines() if l.startswith("CELLS-JSON:")]
    assert payload, out
    results = json.loads(payload[0][len("CELLS-JSON:"):])
    assert results["qwen1.5-110b|long_500k"]["status"] == "skip"
    ok = [k for k, v in results.items() if v["status"] == "ok"]
    assert len(ok) == 6, results
    # sharded programs must actually communicate
    assert any(v.get("coll", 0) > 0 for v in results.values()), results


def test_input_specs_cover_all_cells():
    from repro.configs.base import SHAPES, shape_applicable
    from repro.configs.registry import ARCHS, list_archs
    from repro.launch.cells import input_specs

    n_cells = n_skip = 0
    for arch in list_archs():
        for shape_name, shape in SHAPES.items():
            runs, _ = shape_applicable(ARCHS[arch], shape)
            if not runs:
                n_skip += 1
                continue
            specs = input_specs(arch, shape_name, smoke=True)
            assert specs, (arch, shape_name)
            n_cells += 1
    assert n_cells + n_skip == 40
    assert n_skip == 8   # 8 full-attention archs skip long_500k
