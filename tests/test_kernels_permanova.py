"""Pallas permanova_sw kernels vs the pure-jnp oracle: shape/dtype sweeps
in interpret mode (per-kernel allclose deliverable)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import permutations
from repro.kernels.permanova_sw import ops
from repro.kernels.permanova_sw.ref import sw_ref, sw_ref_f64

SHAPES = [
    # (n, n_groups, n_perms, tile, perm_block)
    (32, 2, 4, 16, 2),
    (48, 3, 7, 16, 4),
    (64, 5, 16, 32, 8),
    (96, 4, 6, 32, 3),
    (130, 2, 5, 32, 4),     # ragged: padding path
    (57, 7, 9, 16, 16),     # perm_block > n_perms
]


def _instance(n, g, p, seed=0):
    rng = np.random.default_rng(seed)
    d = rng.random((n, n)).astype(np.float32)
    d = (d + d.T) / 2
    np.fill_diagonal(d, 0.0)
    grouping = rng.integers(0, g, size=n).astype(np.int32)
    grouping[:g] = np.arange(g)
    inv_gs = np.asarray(permutations.inv_group_sizes(
        jnp.asarray(grouping), g))
    gperms = np.stack([rng.permutation(grouping) for _ in range(p)])
    gperms[0] = grouping
    return jnp.asarray(d * d), jnp.asarray(gperms), jnp.asarray(inv_gs)


@pytest.mark.parametrize("variant", ops.VARIANTS)
@pytest.mark.parametrize("n,g,p,tile,pb", SHAPES)
def test_kernel_matches_oracle(variant, n, g, p, tile, pb):
    mat2, gperms, inv_gs = _instance(n, g, p, seed=n + g + p)
    ref = np.asarray(sw_ref(mat2, gperms, inv_gs))
    got = np.asarray(ops.permanova_sw(mat2, gperms, inv_gs, variant=variant,
                                      tile_r=tile, tile_c=tile,
                                      perm_block=pb))
    np.testing.assert_allclose(got, ref, rtol=5e-5, atol=1e-5)


@pytest.mark.parametrize("variant", ["matmul"])
def test_kernel_bf16_within_tolerance(variant):
    mat2, gperms, inv_gs = _instance(64, 4, 8, seed=3)
    ref64 = sw_ref_f64(mat2, gperms, inv_gs)
    got = np.asarray(ops.permanova_sw(
        mat2.astype(jnp.bfloat16), gperms, inv_gs, variant=variant,
        tile_r=32, tile_c=32, perm_block=4))
    rel = np.max(np.abs(got - ref64) / np.maximum(np.abs(ref64), 1e-6))
    assert rel < 5e-3, f"bf16 matmul rel err {rel}"


def test_kernels_agree_with_each_other():
    mat2, gperms, inv_gs = _instance(96, 3, 12, seed=9)
    outs = [np.asarray(ops.permanova_sw(mat2, gperms, inv_gs, variant=v,
                                        tile_r=32, tile_c=32, perm_block=4))
            for v in ops.VARIANTS]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=5e-5)


def test_kernel_plugs_into_full_test(small_study):
    import jax.numpy as jnp
    from repro.core import permanova
    dm, grouping, _, _ = small_study
    res_ref = permanova(jnp.asarray(dm), jnp.asarray(grouping), n_perms=19,
                        sw_impl="brute")
    res_k = permanova(jnp.asarray(dm), jnp.asarray(grouping), n_perms=19,
                      sw_fn=ops.make_sw_fn("matmul", tile_r=32, tile_c=32,
                                           perm_block=4))
    np.testing.assert_allclose(float(res_k.f_stat), float(res_ref.f_stat),
                               rtol=1e-4)
    assert float(res_k.p_value) == float(res_ref.p_value)
