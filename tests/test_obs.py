"""Observability subsystem: span nesting + Chrome export, counter
byte-accuracy against the streaming bridge, the retrace counter (catches
shape-polymorphic re-jits; warm calls report zero), allocation-free
disabled mode, predicted-vs-measured report content, psum-free snapshot
merging, and the autotune cache counters + warn-once."""

import json
import tracemalloc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs, pipeline
from repro.engine import planner as eplanner
from repro.obs import jaxhooks

N, D, G = 53, 24, 4


def _study(seed=0, n=N, d=D, g=G):
    rng = np.random.default_rng(seed)
    x = rng.gamma(1.0, 1.0, size=(n, d)).astype(np.float32)
    x[:, 0] = np.maximum(x[:, 0], 1e-3)
    grouping = rng.integers(0, g, size=n).astype(np.int32)
    grouping[:g] = np.arange(g)
    return x, grouping


@pytest.fixture(autouse=True)
def _clean_obs():
    """Every test starts and ends with telemetry off and buffers empty."""
    obs.disable()
    obs.clear()
    obs.metrics.reset()
    yield
    obs.disable()
    obs.clear()
    obs.metrics.reset()


class TestSpans:
    def test_nesting_depth_and_parent(self):
        obs.enable(trace=True, metrics=False)
        with obs.span("outer"):
            with obs.span("inner", {"k": 1}):
                pass
        evs = {e["name"]: e for e in obs.events()}
        assert evs["outer"]["args"]["depth"] == 0
        assert "parent" not in evs["outer"]["args"]
        assert evs["inner"]["args"]["depth"] == 1
        assert evs["inner"]["args"]["parent"] == "outer"
        assert evs["inner"]["args"]["k"] == 1
        # inner completes first and nests inside outer's window
        assert evs["inner"]["ts"] >= evs["outer"]["ts"]
        assert (evs["inner"]["ts"] + evs["inner"]["dur"]
                <= evs["outer"]["ts"] + evs["outer"]["dur"] + 1e-3)

    def test_export_chrome_trace_shape(self, tmp_path):
        obs.enable(trace=True, metrics=False)
        with obs.span("stage1.test", {"predicted_bytes": 64.0}):
            pass
        path = str(tmp_path / "trace.json")
        obs.trace.export(path, extra_metadata={"run": "t"})
        with open(path) as f:
            doc = json.load(f)
        assert doc["displayTimeUnit"] == "ms"
        assert doc["otherData"]["source"] == "repro.obs"
        assert doc["otherData"]["run"] == "t"
        (ev,) = doc["traceEvents"]
        # the golden trace_event fields chrome://tracing requires
        assert ev["ph"] == "X" and ev["cat"] == "repro"
        assert {"name", "ts", "dur", "pid", "tid", "args"} <= set(ev)
        assert ev["args"]["predicted_bytes"] == 64.0

    def test_stage_table_aggregates(self):
        obs.enable(trace=True, metrics=False)
        for _ in range(3):
            with obs.span("s", {"predicted_bytes": 10.0}):
                pass
        row = obs.trace.stage_table()["s"]
        assert row["calls"] == 3
        assert row["predicted_bytes"] == 30.0
        assert row["total_s"] >= 0.0 and row["mean_s"] >= 0.0

    def test_session_restores_prior_state(self, tmp_path):
        assert not obs.enabled()
        path = str(tmp_path / "t.json")
        with obs.session(path):
            assert obs.trace_enabled()
            with obs.span("inside"):
                pass
        assert not obs.enabled()
        assert json.load(open(path))["traceEvents"]


class TestDisabledMode:
    def test_span_is_shared_noop_singleton(self):
        assert obs.span("a") is obs.span("b", {"x": 1})

    def test_no_events_no_counters(self):
        with obs.span("ghost"):
            pass
        obs.metrics.inc("ghost.counter")
        assert obs.events() == []
        assert obs.metrics.value("ghost.counter") == 0.0

    def test_hot_path_allocation_free(self):
        # warm every lazy path, then assert the steady state allocates
        # nothing: this is the per-chunk cost the scheduler loop pays
        for _ in range(4):
            with obs.span("warm"):
                pass
            obs.metrics.inc("warm")
        tracemalloc.start()
        try:
            before = tracemalloc.take_snapshot()
            for _ in range(100):
                with obs.span("hot", {"lo": 0}):
                    pass
                obs.metrics.inc("hot", 1.0)
            after = tracemalloc.take_snapshot()
        finally:
            tracemalloc.stop()
        grown = sum(s.size_diff for s in after.compare_to(before, "lineno")
                    if s.size_diff > 0 and any(
                        "obs" in (fr.filename or "")
                        for fr in s.traceback))
        assert grown == 0, f"disabled obs hot path allocated {grown} bytes"


class TestCounters:
    def test_mat2_bytes_built_exact(self):
        from repro.pipeline.streaming import build_mat2_streaming
        n, d = 96, 16
        x = jnp.asarray(np.random.default_rng(0).random((n, d)), jnp.float32)
        prepare, rows_fn, _ = pipeline.get("braycurtis.blocked").bound(
            block=32)
        obs.enable(trace=True, metrics=True)
        mat2, stats = build_mat2_streaming(prepare(x), rows_fn, block=32)
        assert obs.metrics.value("pipeline.mat2_bytes_built") == 4.0 * n * n
        assert mat2.shape == (n, n)
        # one span per 32-row block
        tbl = obs.trace.stage_table()
        assert tbl["stream.mat2_block"]["calls"] == n // 32

    def test_retrace_counter_catches_shape_polymorphic_rejit(self):
        obs.enable(trace=False, metrics=True)

        @jax.jit
        def f(v):
            return jnp.sum(v * 2.0)

        f(jnp.ones((8,))).block_until_ready()
        before = obs.metrics.value(jaxhooks.RETRACES)
        f(jnp.ones((8,))).block_until_ready()      # warm: same shape
        assert obs.metrics.value(jaxhooks.RETRACES) == before
        f(jnp.ones((9,))).block_until_ready()      # new shape: re-jit
        assert obs.metrics.value(jaxhooks.RETRACES) >= before + 1

    def test_merge_snapshots_psum_free(self):
        hosts = [
            {"counters": {"engine.perm_chunks": 3.0},
             "gauges": {"device0.peak_bytes_in_use": 100.0},
             "histograms": {"t": {"count": 2, "total": 4.0,
                                  "min": 1.0, "max": 3.0}}},
            {"counters": {"engine.perm_chunks": 5.0},
             "gauges": {"device0.peak_bytes_in_use": 250.0},
             "histograms": {"t": {"count": 1, "total": 9.0,
                                  "min": 9.0, "max": 9.0}}},
        ]
        m = obs.metrics.merge_snapshots(hosts)
        assert m["counters"]["engine.perm_chunks"] == 8.0        # sum
        assert m["gauges"]["device0.peak_bytes_in_use"] == 250.0  # peak
        h = m["histograms"]["t"]
        assert (h["count"], h["total"], h["min"], h["max"]) == (3, 13.0,
                                                                1.0, 9.0)

    def test_counter_delta(self):
        obs.enable(trace=False, metrics=True)
        obs.metrics.inc("a", 2.0)
        before = obs.metrics.snapshot()
        obs.metrics.inc("a", 3.0)
        obs.metrics.inc("b", 1.0)
        assert obs.metrics.counter_delta(before) == {"a": 3.0, "b": 1.0}


class TestWarmPipeline:
    @pytest.mark.parametrize("mat", ["dense", "stream", "fused-kernel"])
    def test_second_call_zero_retraces_and_report(self, mat, capsys):
        x, grouping = _study()
        xj, gj = jnp.asarray(x), jnp.asarray(grouping)
        kw = dict(metric="braycurtis", n_perms=39, key=jax.random.key(0),
                  materialize=mat)
        obs.enable(trace=True, metrics=True)
        r1 = pipeline.pipeline(xj, gj, **kw)
        jax.block_until_ready(r1.f_perms)
        before = obs.metrics.value(jaxhooks.RETRACES)
        r2 = pipeline.pipeline(xj, gj, **kw)
        jax.block_until_ready(r2.f_perms)
        delta = obs.metrics.value(jaxhooks.RETRACES) - before
        assert delta == 0, (f"warm {mat} pipeline re-traced {delta} "
                            "jaxprs on an identical second call")
        assert float(r1.f_stat) == pytest.approx(float(r2.f_stat))
        # the reconciliation table names the stage and a bandwidth column
        text = obs.report(file=None)
        assert "GB/s" in text
        expect = {"dense": "stage1.braycurtis",
                  "stream": "stage1.braycurtis",
                  "fused-kernel": "bridge.fused-kernel"}[mat]
        assert expect in text

    def test_trace_kwarg_exports_without_global_enable(self, tmp_path):
        x, grouping = _study()
        path = str(tmp_path / "pipe.json")
        res = pipeline.pipeline(jnp.asarray(x), jnp.asarray(grouping),
                                metric="braycurtis", n_perms=19,
                                key=jax.random.key(0), materialize="stream",
                                trace=path)
        assert 0.0 <= float(res.p_value) <= 1.0
        names = {e["name"] for e in json.load(open(path))["traceEvents"]}
        assert "stage1.braycurtis" in names
        assert "engine.sw" in names
        assert not obs.enabled()   # session restored the disabled state


class TestAutotuneCacheCounters:
    def test_hit_miss_and_disabled_warn_once(self, tmp_path, monkeypatch,
                                             caplog):
        path = str(tmp_path / "tune.json")
        monkeypatch.setenv(eplanner.AUTOTUNE_CACHE_ENV, path)
        eplanner.load_autotune_cache(reload=True)
        obs.enable(trace=False, metrics=True)
        assert eplanner.measured_impl("cpu", 64, 4) is None
        assert obs.metrics.value("autotune.cache.miss") == 1.0
        cands = list(eplanner._default_candidates("cpu"))
        eplanner.record_entry(eplanner._persist_key("cpu", 64, 4),
                              {"impl": "matmul", "candidates": cands})
        assert eplanner.measured_impl("cpu", 64, 4) == "matmul"
        assert obs.metrics.value("autotune.cache.hit") == 1.0

        # disabled path warns exactly once (logging, not warnings: tier-1
        # runs with -W error semantics on the library surface)
        monkeypatch.setenv(eplanner.AUTOTUNE_CACHE_ENV, "off")
        eplanner.load_autotune_cache(reload=True)
        eplanner._WARNED.discard("disabled")
        import logging
        with caplog.at_level(logging.WARNING, logger=eplanner.__name__):
            eplanner._save_autotune_cache()
            eplanner._save_autotune_cache()
        msgs = [r for r in caplog.records
                if "autotune cache disabled" in r.message]
        assert len(msgs) == 1

    def test_stale_schema_dropped_counter(self, tmp_path, monkeypatch):
        path = str(tmp_path / "tune.json")
        # dist| keys require the current schema stamp; a schema-less one
        # (pre-PR6 format) must be dropped on load, not silently trusted
        stale = {"dist|cpu|braycurtis|blocked": {"impl": "blocked"}}
        with open(path, "w") as f:
            json.dump(stale, f)
        monkeypatch.setenv(eplanner.AUTOTUNE_CACHE_ENV, path)
        obs.enable(trace=False, metrics=True)
        eplanner._WARNED.discard("stale")
        cache = eplanner.load_autotune_cache(reload=True)
        assert cache == {}
        assert obs.metrics.value("autotune.cache.stale_dropped") == 1.0


class TestSpanRingBuffer:
    """An always-on server traces indefinitely: the completed-span buffer
    is a ring capped at `set_buffer_cap(n)` — oldest spans drop first,
    drops are counted, and export keeps the most recent COMPLETE spans."""

    def test_cap_keeps_most_recent_spans(self, tmp_path):
        prev = obs.buffer_cap()
        obs.clear()
        obs.enable(trace=True, metrics=False)
        try:
            obs.set_buffer_cap(10)
            for i in range(25):
                with obs.span(f"serve.step{i}"):
                    pass
            evs = obs.events()
            assert len(evs) == 10
            # the survivors are exactly the 10 most recent, in order
            assert [e["name"] for e in evs] == [
                f"serve.step{i}" for i in range(15, 25)]
            assert obs.dropped_events() == 15
            # export under cap writes the surviving spans
            out = tmp_path / "ring.json"
            obs.trace.export(str(out))
            data = json.loads(out.read_text())
            names = [e["name"] for e in data["traceEvents"]
                     if e.get("name", "").startswith("serve.step")]
            assert names == [f"serve.step{i}" for i in range(15, 25)]
        finally:
            obs.disable()
            obs.clear()
            obs.set_buffer_cap(prev)

    def test_shrinking_cap_trims_immediately(self):
        prev = obs.buffer_cap()
        obs.clear()
        obs.enable(trace=True, metrics=False)
        try:
            obs.set_buffer_cap(None)          # unbounded
            for i in range(8):
                with obs.span(f"s{i}"):
                    pass
            assert len(obs.events()) == 8
            obs.set_buffer_cap(3)
            assert [e["name"] for e in obs.events()] == ["s5", "s6", "s7"]
            assert obs.dropped_events() == 5
            # clear() resets the drop counter with the buffer
            obs.clear()
            assert obs.dropped_events() == 0
        finally:
            obs.disable()
            obs.clear()
            obs.set_buffer_cap(prev)
