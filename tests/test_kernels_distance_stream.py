"""Distance + STREAM Pallas kernels vs oracles (interpret mode sweeps)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distance import validate_distance_matrix
from repro.kernels.distance import ops as dops
from repro.kernels.distance import ref as dref
from repro.kernels.stream import ops as sops
from repro.kernels.stream import ref as sref

SHAPES = [(32, 16), (48, 20), (64, 130), (130, 64), (96, 96)]


@pytest.mark.parametrize("metric,ref", [("braycurtis", dref.braycurtis_ref),
                                        ("euclidean", dref.euclidean_ref)])
@pytest.mark.parametrize("n,d", SHAPES)
def test_distance_kernel_matches(metric, ref, n, d):
    rng = np.random.default_rng(n * d)
    x = jnp.asarray(rng.gamma(1.0, 1.0, size=(n, d)).astype(np.float32))
    got = np.asarray(dops.pairwise_distance(x, metric=metric, tile_r=32,
                                            tile_c=32, feat_block=32))
    want = np.asarray(ref(x))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("metric", ["braycurtis", "euclidean"])
def test_distance_output_is_valid_permanova_input(metric):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.gamma(1.0, 1.0, size=(40, 24)).astype(np.float32))
    d = dops.pairwise_distance(x, metric=metric, tile_r=16, tile_c=16,
                               feat_block=16)
    checks = validate_distance_matrix(d)
    assert checks["ok"], checks


@pytest.mark.parametrize("op", sops.OPS)
@pytest.mark.parametrize("n,block", [(1000, 256), (4096, 1024), (777, 128)])
def test_stream_kernels(op, n, block):
    rng = np.random.default_rng(n)
    a = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    got = np.asarray(sops.stream_op(a, b, 3.0, op=op, block=block))
    want = np.asarray(sref.REFS[op](a, b, 3.0))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
