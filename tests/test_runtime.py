"""Fault tolerance: heartbeats, elastic/idempotent permutation execution,
straggler re-dispatch, and checkpoint-restart end-state equivalence."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fstat, permutations
from repro.runtime import (ElasticPermutationRunner, HeartbeatMonitor,
                           FaultTolerantTrainer)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


class TestHeartbeat:
    def test_failure_detection_and_recovery(self):
        clock = FakeClock()
        mon = HeartbeatMonitor(4, timeout=5.0, clock=clock)
        dead, recovered = [], []
        mon.on_failure.append(dead.append)
        mon.on_recovery.append(recovered.append)

        clock.t = 3.0
        for w in (0, 1, 2):
            mon.beat(w)
        clock.t = 6.0
        assert mon.check() == [3]
        assert mon.alive_workers == [0, 1, 2]
        mon.beat(3)
        assert recovered == [3]
        assert 3 in mon.alive_workers
        assert dead == [3]


def _block_fn(dm, grouping, inv_gs, key):
    mat2 = jnp.asarray(dm * dm)
    g = jnp.asarray(grouping)
    w = jnp.asarray(inv_gs)

    def compute(worker_id, lo, hi):
        # worker identity must NOT matter — global index folding
        perms = permutations.permutation_batch(key, g, lo, hi)
        return np.asarray(fstat.sw_brute(mat2, perms, w), np.float64)

    return compute


class TestElasticRunner:
    def test_failure_recovery_is_bit_identical(self, small_study):
        dm, grouping, inv_gs, _ = small_study
        key = jax.random.key(0)
        fn = _block_fn(dm, grouping, inv_gs, key)

        clean = ElasticPermutationRunner(64, block_size=16)
        ref = clean.run(fn, workers=[0, 1, 2, 3])

        faulty = ElasticPermutationRunner(64, block_size=16)
        got = faulty.run(fn, workers=[0, 1, 2, 3], fail_at={1: 0})
        np.testing.assert_array_equal(ref, got)
        assert any("fail" in h for h in faulty.history)

    def test_elastic_scale_down_and_up(self, small_study):
        dm, grouping, inv_gs, _ = small_study
        key = jax.random.key(0)
        fn = _block_fn(dm, grouping, inv_gs, key)
        two = ElasticPermutationRunner(48, block_size=8).run(
            fn, workers=[0, 1])
        eight = ElasticPermutationRunner(48, block_size=8).run(
            fn, workers=list(range(8)))
        np.testing.assert_array_equal(two, eight)

    def test_straggler_redispatch(self, small_study):
        dm, grouping, inv_gs, _ = small_study
        key = jax.random.key(0)
        fn = _block_fn(dm, grouping, inv_gs, key)
        r = ElasticPermutationRunner(48, block_size=8,
                                     straggler_factor=0.5)
        got = r.run(fn, workers=[0, 1], slow_workers={1: 100.0})
        clean = ElasticPermutationRunner(48, block_size=8).run(
            fn, workers=[0])
        np.testing.assert_array_equal(got, clean)
        assert any("straggler" in h for h in r.history)


class TestFaultTolerantTrainer:
    def _build(self, tmp_path, tag):
        from repro.configs.registry import SMOKES
        from repro.data.tokens import SyntheticTokenDataset
        from repro.models.model import build_model
        from repro.optim import adamw
        from repro.train.step import make_train_step, make_train_state_init

        cfg = SMOKES["internlm2-1.8b"]
        model = build_model(cfg)
        opt = adamw()
        ds = SyntheticTokenDataset(vocab=cfg.vocab, seq_len=16,
                                   global_batch=4, seed=5)
        return FaultTolerantTrainer(
            train_step=jax.jit(make_train_step(model, opt)),
            init_state=make_train_state_init(model, opt),
            dataset=ds, ckpt_dir=tmp_path / tag, checkpoint_every=5)

    def test_restart_equals_uninterrupted(self, tmp_path):
        clean = self._build(tmp_path, "clean")
        rep_clean = clean.run(n_steps=12, seed=0)
        assert rep_clean.restarts == 0

        faulty = self._build(tmp_path, "faulty")
        rep = faulty.run(n_steps=12, seed=0, fail_at_step=8)
        assert rep.restarts == 1
        assert rep.final_step == 12

        s_clean, _ = clean.manager.restore(
            clean.init_state(jax.random.key(0)))
        s_faulty, _ = faulty.manager.restore(
            faulty.init_state(jax.random.key(0)))
        for a, b in zip(jax.tree.leaves(s_clean.params),
                        jax.tree.leaves(s_faulty.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
