"""Fault tolerance: heartbeats, elastic/idempotent permutation execution,
straggler re-dispatch, and checkpoint-restart end-state equivalence."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fstat, permutations
from repro.runtime import (ElasticPermutationRunner, HeartbeatMonitor,
                           FaultTolerantTrainer)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


class TestHeartbeat:
    def test_failure_detection_and_recovery(self):
        clock = FakeClock()
        mon = HeartbeatMonitor(4, timeout=5.0, clock=clock)
        dead, recovered = [], []
        mon.on_failure.append(dead.append)
        mon.on_recovery.append(recovered.append)

        clock.t = 3.0
        for w in (0, 1, 2):
            mon.beat(w)
        clock.t = 6.0
        assert mon.check() == [3]
        assert mon.alive_workers == [0, 1, 2]
        mon.beat(3)
        assert recovered == [3]
        assert 3 in mon.alive_workers
        assert dead == [3]


def _block_fn(dm, grouping, inv_gs, key):
    mat2 = jnp.asarray(dm * dm)
    g = jnp.asarray(grouping)
    w = jnp.asarray(inv_gs)

    def compute(worker_id, lo, hi):
        # worker identity must NOT matter — global index folding
        perms = permutations.permutation_batch(key, g, lo, hi)
        return np.asarray(fstat.sw_brute(mat2, perms, w), np.float64)

    return compute


class TestElasticRunner:
    def test_failure_recovery_is_bit_identical(self, small_study):
        dm, grouping, inv_gs, _ = small_study
        key = jax.random.key(0)
        fn = _block_fn(dm, grouping, inv_gs, key)

        clean = ElasticPermutationRunner(64, block_size=16)
        ref = clean.run(fn, workers=[0, 1, 2, 3])

        faulty = ElasticPermutationRunner(64, block_size=16)
        got = faulty.run(fn, workers=[0, 1, 2, 3], fail_at={1: 0})
        np.testing.assert_array_equal(ref, got)
        assert any("fail" in h for h in faulty.history)

    def test_elastic_scale_down_and_up(self, small_study):
        dm, grouping, inv_gs, _ = small_study
        key = jax.random.key(0)
        fn = _block_fn(dm, grouping, inv_gs, key)
        two = ElasticPermutationRunner(48, block_size=8).run(
            fn, workers=[0, 1])
        eight = ElasticPermutationRunner(48, block_size=8).run(
            fn, workers=list(range(8)))
        np.testing.assert_array_equal(two, eight)

    def test_straggler_redispatch(self, small_study):
        dm, grouping, inv_gs, _ = small_study
        key = jax.random.key(0)
        fn = _block_fn(dm, grouping, inv_gs, key)
        r = ElasticPermutationRunner(48, block_size=8,
                                     straggler_factor=0.5)
        got = r.run(fn, workers=[0, 1], slow_workers={1: 100.0})
        clean = ElasticPermutationRunner(48, block_size=8).run(
            fn, workers=[0])
        np.testing.assert_array_equal(got, clean)
        assert any("straggler" in h for h in r.history)


class TestFaultTolerantTrainer:
    def _build(self, tmp_path, tag):
        from repro.configs.registry import SMOKES
        from repro.data.tokens import SyntheticTokenDataset
        from repro.models.model import build_model
        from repro.optim import adamw
        from repro.train.step import make_train_step, make_train_state_init

        cfg = SMOKES["internlm2-1.8b"]
        model = build_model(cfg)
        opt = adamw()
        ds = SyntheticTokenDataset(vocab=cfg.vocab, seq_len=16,
                                   global_batch=4, seed=5)
        return FaultTolerantTrainer(
            train_step=jax.jit(make_train_step(model, opt)),
            init_state=make_train_state_init(model, opt),
            dataset=ds, ckpt_dir=tmp_path / tag, checkpoint_every=5)

    def test_restart_equals_uninterrupted(self, tmp_path):
        clean = self._build(tmp_path, "clean")
        rep_clean = clean.run(n_steps=12, seed=0)
        assert rep_clean.restarts == 0

        faulty = self._build(tmp_path, "faulty")
        rep = faulty.run(n_steps=12, seed=0, fail_at_step=8)
        assert rep.restarts == 1
        assert rep.final_step == 12

        s_clean, _ = clean.manager.restore(
            clean.init_state(jax.random.key(0)))
        s_faulty, _ = faulty.manager.restore(
            faulty.init_state(jax.random.key(0)))
        for a, b in zip(jax.tree.leaves(s_clean.params),
                        jax.tree.leaves(s_faulty.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestIncarnationFencing:
    """Recovery/zombie semantics of the heartbeat monitor: incarnations
    bump on every dead->alive transition and on fence(); stale beats are
    rejected without refreshing liveness; on_recovery fires exactly once
    per transition."""

    def _mon(self):
        clock = FakeClock()
        mon = HeartbeatMonitor(2, timeout=5.0, clock=clock)
        events = {"dead": [], "recovered": []}
        mon.on_failure.append(events["dead"].append)
        mon.on_recovery.append(events["recovered"].append)
        return clock, mon, events

    def test_recovery_bumps_incarnation_and_fires_once(self):
        clock, mon, ev = self._mon()
        assert mon.incarnation(0) == 0
        clock.t = 6.0
        assert mon.check() == [0, 1]
        mon.beat(0)                      # rejoin
        assert ev["recovered"] == [0]
        assert mon.incarnation(0) == 1   # new incarnation
        mon.beat(0)                      # steady-state beat: no re-fire,
        mon.beat(0, incarnation=1)       # no extra bump
        assert ev["recovered"] == [0]
        assert mon.incarnation(0) == 1

    def test_stale_incarnation_rejected_no_liveness_refresh(self):
        clock, mon, ev = self._mon()
        clock.t = 3.0
        mon.beat(0, incarnation=0)
        fenced = mon.fence(0)            # re-dispatch invalidates inc 0
        assert fenced == 1
        clock.t = 4.0
        # zombie beat with the pre-fence incarnation: rejected, and the
        # worker's last_beat must NOT move (else a zombie keeps a dead
        # worker looking alive forever)
        assert mon.beat(0, incarnation=0) is False
        assert mon.workers[0].stale_beats == 1
        assert mon.workers[0].last_beat == 3.0
        # current-incarnation beat is accepted as usual
        assert mon.beat(0, incarnation=1) is True
        assert mon.workers[0].last_beat == 4.0

    def test_zombie_cannot_double_report_after_recovery(self):
        clock, mon, ev = self._mon()
        clock.t = 6.0
        mon.check()                      # 0 and 1 die
        mon.fence(0)                     # scheduler re-dispatches 0's work
        mon.beat(0)                      # genuine rejoin: alive again...
        assert ev["recovered"] == [0]
        inc = mon.incarnation(0)
        assert inc == 2                  # fence bump + recovery bump
        # ...but its PRE-DEATH incarnation stays fenced: a late report
        # from the old life is still rejected after the recovery
        assert mon.beat(0, incarnation=0) is False
        assert mon.beat(0, incarnation=inc) is True

    def test_unclaimed_beat_is_always_a_rejoin(self):
        # beats with no incarnation claim (legacy callers / fresh joins)
        # can never be rejected — backward-compatible liveness
        clock, mon, ev = self._mon()
        clock.t = 6.0
        mon.check()
        mon.fence(1)
        assert mon.beat(1) is True
        assert ev["recovered"] == [1]

    def test_fleet_snapshot_merges_worker_beats(self):
        clock, mon, _ = self._mon()
        mon.beat(0, snapshot={"counters": {"blocks": 3.0},
                              "gauges": {"mem": 10.0}})
        mon.beat(1, snapshot={"counters": {"blocks": 4.0},
                              "gauges": {"mem": 7.0}})
        merged = mon.fleet_snapshot()
        assert merged["counters"]["blocks"] == 7.0   # counters sum
        assert merged["gauges"]["mem"] == 10.0       # gauges max
