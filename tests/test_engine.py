"""Hardware-aware execution engine: registry parity against the Algorithm 1
oracle (every registered impl, awkward shapes included), planner dispatch
rules, streaming scheduler equivalence + fixed-memory contract, and the
batched multi-study API."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.core import fstat, permutations
from repro.core.permanova import permanova

# (n, n_groups) — prime n exercises pad paths; the (9, 8) case has
# singleton groups (inv size 1.0, no within-group pairs contributed).
SHAPES = [
    (32, 3),
    (37, 4),    # prime n: tiled + pallas padding paths
    (53, 5),    # prime n
    (9, 8),     # singleton groups
]


def _instance(n, g, seed=0, n_perms=6):
    rng = np.random.default_rng(seed)
    d = rng.random((n, n)).astype(np.float32)
    d = (d + d.T) / 2
    np.fill_diagonal(d, 0.0)
    grouping = rng.integers(0, g, size=n).astype(np.int32)
    grouping[:g] = np.arange(g)
    inv_gs = np.asarray(permutations.inv_group_sizes(jnp.asarray(grouping), g))
    gperms = np.asarray(permutations.permutation_batch(
        jax.random.key(seed + 1), jnp.asarray(grouping), 0, n_perms))
    return d, grouping, inv_gs, gperms


class TestRegistryParity:
    """Every registered impl must match the literal Algorithm 1 oracle."""

    @pytest.mark.parametrize("name", engine.names())
    @pytest.mark.parametrize("n,g", SHAPES)
    def test_matches_algorithm1(self, name, n, g):
        d, grouping, inv_gs, gperms = _instance(n, g, seed=n + g)
        oracle = fstat.sw_algorithm1_numpy(d, gperms, inv_gs)
        spec = engine.get(name)
        # shrink pallas/tiled tiles for these small shapes
        overrides = {"tile_r": 16, "tile_c": 16, "perm_block": 2,
                     "tile": 16, "block": 2}
        fn = spec.bound(**overrides)
        got = np.asarray(fn(jnp.asarray(d * d), jnp.asarray(gperms),
                            jnp.asarray(inv_gs)))
        np.testing.assert_allclose(got, oracle, rtol=5e-5, atol=1e-5)

    def test_registry_metadata_complete(self):
        assert set(engine.names()) == {
            "brute", "tiled", "matmul",
            "pallas_brute", "pallas_permblock", "pallas_matmul"}
        for name in engine.names():
            spec = engine.get(name)
            assert spec.backends, name
            assert spec.pad_contract in ("none", "internal")
        # every impl resolves to some row-sharded companion
        for name in engine.names():
            assert callable(engine.get_sharded(name))

    def test_sharded_partials_sum_to_oracle(self):
        d, grouping, inv_gs, gperms = _instance(48, 3, seed=2)
        oracle = fstat.sw_algorithm1_numpy(d, gperms, inv_gs)
        for name in ("brute", "matmul", "tiled", "pallas_matmul"):
            fn = engine.get_sharded(name)
            parts = [np.asarray(fn(jnp.asarray((d * d)[o:o + 16]), o,
                                   jnp.asarray(gperms), jnp.asarray(inv_gs)))
                     for o in (0, 16, 32)]
            np.testing.assert_allclose(sum(parts), oracle, rtol=5e-5)


class TestTiledPadding:
    """Satellite fix: prime n must pad to the requested tile (sentinel
    group), not degrade toward a tile=1 scalar scan."""

    @pytest.mark.parametrize("n", [37, 53, 61])
    def test_prime_n_matches_oracle(self, n):
        d, grouping, inv_gs, gperms = _instance(n, 4, seed=n)
        oracle = fstat.sw_algorithm1_numpy(d, gperms, inv_gs)
        got = np.asarray(fstat.sw_tiled(
            jnp.asarray(d * d), jnp.asarray(gperms), jnp.asarray(inv_gs),
            tile=16))
        np.testing.assert_allclose(got, oracle, rtol=5e-5, atol=1e-5)

    def test_pad_region_contributes_zero(self):
        # padding a matrix with explicit zeros must not change the result
        d, grouping, inv_gs, gperms = _instance(30, 3, seed=1)
        a = np.asarray(fstat.sw_tiled_one(
            jnp.asarray((d * d)), jnp.asarray(gperms[1]),
            jnp.asarray(inv_gs), tile=16))
        b = np.asarray(fstat.sw_tiled_one(
            jnp.asarray((d * d)), jnp.asarray(gperms[1]),
            jnp.asarray(inv_gs), tile=15))  # 30 % 15 == 0: no-pad path
        np.testing.assert_allclose(a, b, rtol=1e-5)


class TestPlanner:
    """backend -> impl dispatch must encode the paper's Fig. 1 result."""

    def test_gpu_prefers_brute(self):
        assert engine.plan(4096, 1000, 8, backend="gpu").impl == "brute"

    def test_cpu_large_prefers_tiled(self):
        # mat2 spills the modeled LLC -> cache-tiled Algorithm 2
        assert engine.plan(8192, 1000, 8, backend="cpu").impl == "tiled"

    def test_cpu_small_prefers_matmul(self):
        assert engine.plan(256, 1000, 8, backend="cpu").impl == "matmul"

    def test_tpu_prefers_pallas_matmul(self):
        assert engine.plan(4096, 1000, 8, backend="tpu").impl == "pallas_matmul"
        assert engine.plan(64, 1000, 8, backend="tpu").impl == "matmul"

    def test_pinned_impl_respected(self):
        pl = engine.plan(512, 1000, 8, backend="cpu", impl="brute")
        assert pl.impl == "brute"

    def test_chunk_respects_budget(self):
        spec = engine.get("matmul")
        n = 1024
        chunk = engine.chunk_for_budget(n, 10 ** 6, spec, 8,
                                        budget_bytes=64 * 2 ** 20)
        # label tensor for the chunk must fit comfortably in the budget
        assert 4 * n * chunk <= 64 * 2 ** 20
        assert chunk >= 64
        # bigger budget, bigger chunk
        bigger = engine.chunk_for_budget(n, 10 ** 6, spec, 8,
                                         budget_bytes=512 * 2 ** 20)
        assert bigger > chunk

    def test_plan_streaming_flag(self):
        pl = engine.plan(512, 100_001, 8, backend="cpu",
                         memory_budget_bytes=4 * 2 ** 20)
        assert pl.streaming and pl.chunk < 100_001
        small = engine.plan(512, 100, 8, backend="cpu")
        assert not small.streaming

    def test_autotune_returns_registered_impl(self):
        d, grouping, inv_gs, _ = _instance(32, 3)
        name = engine.autotune(jnp.asarray(d * d), jnp.asarray(grouping),
                               jnp.asarray(inv_gs), sample_perms=4,
                               use_cache=False)
        assert name in engine.names()


class TestStreamingScheduler:
    def test_stream_equals_batch(self):
        d, grouping, _, _ = _instance(37, 4, seed=5)
        dm = jnp.asarray(d)
        key = jax.random.key(9)
        batch = engine.run(dm, grouping, n_perms=200, impl="matmul", key=key)
        stream = engine.run(dm, grouping, n_perms=200, impl="matmul",
                            key=key, chunk=33)  # ragged last chunk
        assert "stream" in stream.plan and "chunks=7" in stream.plan
        np.testing.assert_allclose(np.asarray(stream.f_perms),
                                   np.asarray(batch.f_perms), rtol=1e-5)
        assert float(stream.p_value) == float(batch.p_value)

    def test_fixed_memory_contract(self):
        """Large sweep under a small budget: label footprint stays bounded
        and the (n_perms, n) tensor is never materialized."""
        d, grouping, _, _ = _instance(64, 4, seed=6)
        mat2 = jnp.asarray(d * d)
        inv_gs = permutations.inv_group_sizes(jnp.asarray(grouping), 4)
        fn = engine.get("matmul").bound()
        n_total = 100_001
        budget = 1 * 2 ** 20
        chunk = engine.chunk_for_budget(64, n_total, engine.get("matmul"),
                                        4, budget_bytes=budget)
        s_w, stats = engine.sw_streaming(mat2, jnp.asarray(grouping), inv_gs,
                                         jax.random.key(0), n_total, fn,
                                         chunk=chunk)
        assert stats.n_total == n_total
        assert stats.n_chunks == -(-n_total // stats.chunk) > 1
        assert stats.peak_label_bytes <= budget
        assert s_w.shape == (n_total,)
        # spot-check a mid-stream chunk against direct generation
        lo = stats.chunk * 2
        g = permutations.permutation_batch(jax.random.key(0),
                                           jnp.asarray(grouping), lo, lo + 8)
        np.testing.assert_allclose(s_w[lo:lo + 8],
                                   np.asarray(fn(mat2, g, inv_gs)), rtol=1e-5)

    def test_identity_perm_first(self):
        d, grouping, _, _ = _instance(32, 3, seed=7)
        res = engine.run(jnp.asarray(d), grouping, n_perms=100, chunk=17,
                         impl="brute", key=jax.random.key(1))
        inv_gs = permutations.inv_group_sizes(jnp.asarray(grouping), 3)
        obs = fstat.sw_brute_one(jnp.asarray(d * d), jnp.asarray(grouping),
                                 inv_gs)
        np.testing.assert_allclose(float(res.s_w), float(obs), rtol=1e-5)


class TestEntryPoints:
    def test_core_permanova_routes_through_engine(self, small_study):
        dm, grouping, _, _ = small_study
        res = permanova(jnp.asarray(dm), jnp.asarray(grouping), n_perms=29)
        assert res.method.startswith("permanova[")
        assert res.plan  # engine always records its plan

    def test_auto_matches_pinned(self, small_study):
        dm, grouping, _, _ = small_study
        auto = permanova(jnp.asarray(dm), jnp.asarray(grouping), n_perms=29,
                         sw_impl="auto")
        pinned = permanova(jnp.asarray(dm), jnp.asarray(grouping), n_perms=29,
                           sw_impl="brute")
        np.testing.assert_allclose(float(auto.f_stat), float(pinned.f_stat),
                                   rtol=1e-4)
        assert float(auto.p_value) == float(pinned.p_value)

    def test_budget_kwarg_streams(self, small_study):
        dm, grouping, _, _ = small_study
        res = permanova(jnp.asarray(dm), jnp.asarray(grouping),
                        n_perms=2000, sw_impl="matmul",
                        memory_budget_bytes=48 * 48 * 4 * 2 + 40000)
        assert "stream" in res.plan

    def test_pallas_impl_name_accepted(self, small_study):
        dm, grouping, _, _ = small_study
        ref = permanova(jnp.asarray(dm), jnp.asarray(grouping), n_perms=19,
                        sw_impl="brute")
        res = permanova(jnp.asarray(dm), jnp.asarray(grouping), n_perms=19,
                        sw_impl="pallas_matmul")
        np.testing.assert_allclose(float(res.f_stat), float(ref.f_stat),
                                   rtol=1e-4)


class TestPermanovaMany:
    def test_matches_independent_runs(self):
        g = 4
        studies = [_instance(32, g, seed=s)[0] for s in range(3)]
        groupings = [_instance(32, g, seed=s)[1] for s in range(3)]
        dms = jnp.stack([jnp.asarray(d) for d in studies])
        gs = jnp.stack([jnp.asarray(x) for x in groupings])
        key = jax.random.key(11)
        many = engine.permanova_many(dms, gs, n_groups=g, n_perms=49,
                                     key=key, impl="matmul")
        assert len(many) == 3
        for s in range(3):
            single = engine.run(dms[s], gs[s], n_perms=49,
                                key=jax.random.fold_in(key, s),
                                impl="matmul")
            np.testing.assert_allclose(np.asarray(many.f_perms[s]),
                                       np.asarray(single.f_perms), rtol=1e-4)
            assert float(many.p_value[s]) == float(single.p_value)

    def test_chunked_scan_inside_jit(self):
        d0, g0, _, _ = _instance(24, 3, seed=1)
        d1, g1, _, _ = _instance(24, 3, seed=2)
        dms = jnp.stack([jnp.asarray(d0), jnp.asarray(d1)])
        gs = jnp.stack([jnp.asarray(g0), jnp.asarray(g1)])
        a = engine.permanova_many(dms, gs, n_groups=3, n_perms=99, chunk=100)
        b = engine.permanova_many(dms, gs, n_groups=3, n_perms=99, chunk=13)
        np.testing.assert_allclose(np.asarray(a.f_perms),
                                   np.asarray(b.f_perms), rtol=1e-5)

    def test_study_view(self):
        d, g, _, _ = _instance(24, 3, seed=4)
        dms = jnp.stack([jnp.asarray(d)] * 2)
        gs = jnp.stack([jnp.asarray(g)] * 2)
        many = engine.permanova_many(dms, gs, n_groups=3, n_perms=19)
        one = many.study(0)
        assert one.n_objects == 24 and one.f_perms.shape == (20,)
