"""Low-precision & sparse megakernel arithmetic (tentpole of the
precision PR): packed-bit jaccard bit-equality against the fp32 matmul
form, fp8 feature slabs against an fp64 oracle under pinned per-metric
tolerances, block-sparse design-basis contraction bit-matching dense,
and the precision-aware traffic/workset models the planner reports."""

import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import pipeline
from repro.core import distance as dist
from repro.core import fstat, permutations
from repro.kernels.distance import ops as dops
from repro.kernels.fused_sw import ops as fops
from repro.pipeline import registry as dreg
from repro.pipeline import streaming

N, D, G = 53, 24, 5   # prime n, ragged groups (same envelope as fused_sw)


def _study(seed=0, n=N, d=D, g=G, sparsity=0.5):
    rng = np.random.default_rng(seed)
    x = rng.gamma(1.0, 1.0, size=(n, d)).astype(np.float32)
    x *= rng.random(size=(n, d)) < sparsity
    x[:, 0] = np.maximum(x[:, 0], 1e-3)
    grouping = rng.integers(0, g, size=n).astype(np.int32)
    grouping[:g] = np.arange(g)
    return x, grouping


def _perm_batch(grouping, n_perms, seed=3):
    rng = np.random.default_rng(seed)
    return np.stack([rng.permutation(grouping) for _ in range(n_perms)])


def _sw_oracle_f64(xprep64, metric, g_batch, inv_gs):
    """fp64 numpy PERMANOVA s_W for explicit label batches — the oracle
    the quantized paths are pinned against."""
    n = xprep64.shape[0]
    if metric == "euclidean":
        sq = (xprep64 * xprep64).sum(axis=1)
        dm2 = sq[:, None] + sq[None, :] - 2.0 * xprep64 @ xprep64.T
        dm2 = np.maximum(dm2, 0.0)
    elif metric == "braycurtis":
        num = np.abs(xprep64[:, None, :] - xprep64[None, :, :]).sum(-1)
        den = (xprep64[:, None, :] + xprep64[None, :, :]).sum(-1)
        dm = num / np.maximum(den, 1e-30)
        dm2 = dm * dm
    elif metric == "jaccard":
        b = (xprep64 > 0).astype(np.float64)
        inter = b @ b.T
        card = b.sum(axis=1)
        union = card[:, None] + card[None, :] - inter
        dm = 1.0 - inter / np.maximum(union, 1.0)
        dm2 = dm * dm
    else:
        raise ValueError(metric)
    np.fill_diagonal(dm2, 0.0)
    sws = []
    for g in np.asarray(g_batch):
        s = 0.0
        for k in range(len(inv_gs)):
            mask = g == k
            s += inv_gs[k] * dm2[np.ix_(mask, mask)].sum()
        sws.append(0.5 * s)
    return np.asarray(sws)


# ---------------------------------------------------------------------------
# Quantization primitives
# ---------------------------------------------------------------------------

class TestPrimitives:
    def test_pack_presence_bits_matches_manual(self):
        x, _ = _study(seed=1, d=70)       # 70 features -> 3 ragged words
        packed = np.asarray(dist.pack_presence_bits(jnp.asarray(x)))
        assert packed.shape == (N, 3) and packed.dtype == np.uint32
        bits = (x > 0).astype(np.uint64)
        for w in range(3):
            block = bits[:, 32 * w: 32 * (w + 1)]
            manual = sum(block[:, b].astype(np.uint64) << b
                         for b in range(block.shape[1]))
            np.testing.assert_array_equal(packed[:, w],
                                          manual.astype(np.uint32))

    def test_fp8_scale_calibration(self):
        x = jnp.asarray([[0.5, -900.0, 3.0]], jnp.float32)
        s = float(dist.fp8_scale(x))
        assert s == pytest.approx(900.0 / dist.FP8_MAX)
        # presence tables are exactly representable: jaccard pins scale 1
        assert float(dist.fp8_metric_scale(x, "jaccard")) == 1.0
        # all-zero input must not divide by zero
        assert float(dist.fp8_scale(jnp.zeros((2, 2)))) == \
            pytest.approx(1e-12)

    def test_fp8_roundtrip_error_bound(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.gamma(2.0, 5.0, (64, 32)).astype(np.float32))
        rt = np.asarray(dist.fp8_roundtrip(x))
        rel = np.abs(rt - np.asarray(x)) / np.maximum(np.asarray(x), 1e-9)
        assert rel.max() < 0.07            # e4m3: 3 mantissa bits ~ 2^-4

    def test_precision_tag_tuning_roundtrip(self):
        for tag in dreg.PRECISIONS:
            assert dreg.precision_tag(dreg.precision_tuning(tag)) == tag
        assert dreg.precision_tag(None) == "f32"
        with pytest.raises(ValueError, match="unknown precision"):
            dreg.precision_tuning("int4")


# ---------------------------------------------------------------------------
# Packed-bit jaccard: exact integer counts -> bit-identical everything
# ---------------------------------------------------------------------------

class TestPackedJaccard:
    @pytest.mark.parametrize("shape", [(53, 24), (31, 70), (17, 5)])
    def test_stage1_bit_identical(self, shape):
        n, d = shape
        x, _ = _study(seed=2, n=n, d=d, g=3)
        xprep = dist.ROW_METRICS["jaccard"].prepare(jnp.asarray(x))
        dm = dops.pairwise_distance(xprep, metric="jaccard", tile_r=16,
                                    tile_c=16, feat_block=8)
        dmp = dops.pairwise_distance(xprep, metric="jaccard", tile_r=16,
                                     tile_c=16, feat_block=8, packed=1)
        np.testing.assert_array_equal(np.asarray(dm), np.asarray(dmp))
        rows = dops.pairwise_distance_rows(xprep[:7], xprep,
                                           metric="jaccard", tile_r=8,
                                           tile_c=16, feat_block=8)
        rowsp = dops.pairwise_distance_rows(xprep[:7], xprep,
                                            metric="jaccard", tile_r=8,
                                            tile_c=16, feat_block=8,
                                            packed=1)
        np.testing.assert_array_equal(np.asarray(rows), np.asarray(rowsp))

    @pytest.mark.parametrize("tiles", [
        dict(tile_r=16, tile_c=16, feat_block=8, perm_block=4),
        dict(tile_r=8, tile_c=32, feat_block=16, perm_block=3),
    ])
    def test_fused_bit_identical(self, tiles):
        x, grouping = _study(seed=3)
        xprep = dist.ROW_METRICS["jaccard"].prepare(jnp.asarray(x))
        inv_gs = permutations.inv_group_sizes(jnp.asarray(grouping), G)
        g = jnp.asarray(_perm_batch(grouping, 9))
        sw, rs = fops.fused_sw_rows(xprep, xprep, g, g, inv_gs, 0,
                                    metric="jaccard", **tiles)
        swp, rsp = fops.fused_sw_rows(xprep, xprep, g, g, inv_gs, 0,
                                      metric="jaccard", feat_packed=1,
                                      **tiles)
        np.testing.assert_array_equal(np.asarray(sw), np.asarray(swp))
        np.testing.assert_array_equal(np.asarray(rs), np.asarray(rsp))

    def test_pipeline_f_bit_identical(self):
        """Acceptance: the packed fused path returns the IDENTICAL F."""
        x, grouping = _study(seed=4)
        tiles = dict(tile_r=16, tile_c=16, feat_block=8, perm_block=4)
        base = pipeline.pipeline(jnp.asarray(x), jnp.asarray(grouping),
                                 metric="jaccard", n_perms=29,
                                 materialize="fused-kernel",
                                 fused_impl="pallas", fused_tuning=tiles)
        packed = pipeline.pipeline(jnp.asarray(x), jnp.asarray(grouping),
                                   metric="jaccard", n_perms=29,
                                   materialize="fused-kernel",
                                   fused_impl="pallas",
                                   fused_tuning={**tiles, "feat_packed": 1})
        assert float(packed.f_stat) == float(base.f_stat)
        np.testing.assert_array_equal(np.asarray(packed.f_perms),
                                      np.asarray(base.f_perms))

    def test_packed_requires_jaccard(self):
        x, grouping = _study(seed=5)
        xprep = jnp.asarray(x)
        with pytest.raises(ValueError, match="jaccard"):
            dops.pairwise_distance(xprep, metric="euclidean", packed=1)
        inv_gs = permutations.inv_group_sizes(jnp.asarray(grouping), G)
        g = jnp.asarray(_perm_batch(grouping, 2))
        with pytest.raises(ValueError, match="jaccard"):
            fops.fused_sw_rows(xprep, xprep, g, g, inv_gs, 0,
                               metric="euclidean", feat_packed=1)
        with pytest.raises(ValueError, match="mutually exclusive"):
            fops.fused_sw_rows(xprep, xprep, g, g, inv_gs, 0,
                               metric="jaccard", feat_packed=1, feat_fp8=1)


# ---------------------------------------------------------------------------
# fp8 slabs vs the fp64 oracle (pinned per-metric tolerances)
# ---------------------------------------------------------------------------

class TestFp8Parity:
    # pinned: quantization error through each metric's finalize arithmetic
    # on raw s_W (F ratios cancel most of it — the e2e pipeline check in
    # the benchmarks sees ~1e-3); jaccard presence bits are exactly
    # representable in e4m3 -> near-exact
    TOLS = {"euclidean": 2e-2, "braycurtis": 2e-2, "jaccard": 1e-5}

    @pytest.mark.parametrize("metric", ["euclidean", "braycurtis",
                                        "jaccard"])
    def test_fused_fp8_vs_f64_oracle(self, metric):
        x, grouping = _study(seed=6)
        mdef = dist.ROW_METRICS[metric]
        xprep = mdef.prepare(jnp.asarray(x))
        inv_gs = permutations.inv_group_sizes(jnp.asarray(grouping), G)
        g = jnp.asarray(_perm_batch(grouping, 8))
        sw8, _ = fops.fused_sw_rows(
            xprep, xprep, g, g, inv_gs, 0, metric=metric, feat_fp8=1,
            tile_r=16, tile_c=16, feat_block=8, perm_block=4)
        oracle = _sw_oracle_f64(np.asarray(xprep, np.float64), metric,
                                g, np.asarray(inv_gs, np.float64))
        np.testing.assert_allclose(np.asarray(sw8), oracle,
                                   rtol=self.TOLS[metric])

    def test_megakernel_matches_xla_at_fp8(self):
        """Both fused impls quantize identically (shared calibration), so
        they agree to accumulation order at fp8 too."""
        x, grouping = _study(seed=7)
        mdef = dist.ROW_METRICS["braycurtis"]
        xprep = mdef.prepare(jnp.asarray(x))
        inv_gs = permutations.inv_group_sizes(jnp.asarray(grouping), G)
        key = jax.random.key(11)
        tuning = dict(tile_r=16, tile_c=16, feat_block=8, perm_block=4,
                      feat_fp8=1)
        sw_p, st_p, _ = streaming.fused_kernel_sw(
            xprep, mdef.rows, jnp.asarray(grouping), inv_gs, key, 21,
            impl="pallas", kernel_metric="braycurtis", row_block=16,
            chunk=7, tuning=tuning)
        sw_x, st_x, _ = streaming.fused_kernel_sw(
            xprep, mdef.rows, jnp.asarray(grouping), inv_gs, key, 21,
            impl="xla", kernel_metric="braycurtis", row_block=16,
            chunk=7, tuning={"feat_fp8": 1})
        np.testing.assert_allclose(sw_p, sw_x, rtol=1e-4)
        assert st_p == pytest.approx(st_x, rel=1e-4)


# ---------------------------------------------------------------------------
# Block-sparse design-basis contraction
# ---------------------------------------------------------------------------

def _block_design(n=23, k_per=2, n_strata=3, seed=8):
    """Strata-blocked basis: each column is supported on ONE stratum."""
    rng = np.random.default_rng(seed)
    strata = np.sort(rng.integers(0, n_strata, n)).astype(np.int32)
    strata[:n_strata] = np.arange(n_strata)
    strata.sort()
    k = k_per * n_strata
    basis = np.zeros((n, k), np.float32)
    for s in range(n_strata):
        rows = np.flatnonzero(strata == s)
        basis[np.ix_(rows, range(k_per * s, k_per * (s + 1)))] = \
            rng.normal(size=(len(rows), k_per)).astype(np.float32)
    return basis, strata


class TestBlockSparse:
    def test_sparse_col_groups_structure(self):
        basis, strata = _block_design()
        groups = fstat.sparse_col_groups(basis, strata)
        assert len(groups) == 3
        cols_seen = sorted(c for cols, _ in groups for c in cols)
        assert cols_seen == list(range(basis.shape[1]))
        for cols, rows in groups:
            sup = {int(strata[r]) for r in rows}
            assert len(sup) == 1           # one stratum per group here
            assert np.all(basis[np.ix_(
                [r for r in range(len(strata)) if r not in rows],
                cols)] == 0)

    def test_contract_sparse_bit_matches_dense(self):
        basis, strata = _block_design()
        n, k = basis.shape
        rng = np.random.default_rng(9)
        m2 = rng.random((n, n)).astype(np.float32)
        m2 = m2 + m2.T
        np.fill_diagonal(m2, 0.0)
        groups = fstat.sparse_col_groups(basis, strata)
        # the permuted operand keeps the column support: rows permute
        # WITHIN strata (what strata-restricted draws guarantee)
        perms = np.stack([
            np.concatenate([rng.permutation(np.flatnonzero(strata == s))
                            for s in range(3)]) for _ in range(5)])
        v = jnp.asarray(np.stack([basis[p] for p in perms]))  # (P, n, K)
        dense = fstat.sw_cols_contract(jnp.asarray(m2), v, v)
        sparse = fstat.sw_cols_contract_sparse(jnp.asarray(m2), v, v,
                                               groups)
        np.testing.assert_array_equal(np.asarray(dense),
                                      np.asarray(sparse))
        # slab-partial form (the fused bridge's unit) is exact too
        dense_s = fstat.sw_cols_contract(jnp.asarray(m2[:9]), v, v[:, :9])
        sparse_s = fstat.sw_cols_contract_sparse(jnp.asarray(m2[:9]), v,
                                                 v[:, :9], groups)
        np.testing.assert_array_equal(np.asarray(dense_s),
                                      np.asarray(sparse_s))

    def test_fused_design_sparse_bit_matches_dense(self):
        basis, strata = _block_design(n=29)
        x, _ = _study(seed=10, n=29)
        mdef = dist.ROW_METRICS["braycurtis"]
        xprep = mdef.prepare(jnp.asarray(x))
        design = types.SimpleNamespace(
            k_cols=basis.shape[1], basis=jnp.asarray(basis),
            strata=jnp.asarray(strata))
        key = jax.random.key(3)
        dense = streaming.fused_sw_design(
            xprep, mdef.rows, design, key, 17, row_block=8, chunk=5,
            block_sparse=False)
        sparse = streaming.fused_sw_design(
            xprep, mdef.rows, design, key, 17, row_block=8, chunk=5,
            block_sparse=True)
        np.testing.assert_array_equal(dense[0], sparse[0])
        assert dense[1] == sparse[1]


# ---------------------------------------------------------------------------
# Precision-aware traffic / workset models (what plan.explain() reports)
# ---------------------------------------------------------------------------

class TestTrafficModel:
    def test_packed_moves_32x_fewer_feature_bytes(self):
        """Acceptance: >= 8x fewer feature-slab bytes (model gives 32x)."""
        spec = dreg.get_fused("jaccard.fusedk.pallas")
        n, d = 1024, 512
        f32 = dreg.fused_feat_traffic_bytes(spec, n, d,
                                            dreg.precision_tuning("f32"))
        packed = dreg.fused_feat_traffic_bytes(
            spec, n, d, dreg.precision_tuning("packed"))
        assert f32 / packed == 32.0
        assert f32 / packed >= 8.0

    def test_precision_ordering(self):
        spec = dreg.get_fused("braycurtis.fusedk.pallas")
        t = {tag: dreg.fused_feat_traffic_bytes(
                spec, 512, 256, dreg.precision_tuning(tag))
             for tag in ("f32", "bf16", "fp8")}
        assert t["fp8"] < t["bf16"] < t["f32"]
        assert t["f32"] == 4 * t["fp8"] and t["f32"] == 2 * t["bf16"]
        w = {tag: dreg.fused_workset_bytes(
                spec, 512, 256, 64, 8, 256,
                dreg.precision_tuning(tag))
             for tag in ("f32", "bf16", "fp8")}
        assert w["fp8"] < w["bf16"] < w["f32"]

    def test_xla_kind_gets_no_precision_credit(self):
        """The one-jit sweep streams f32 regardless — the model must not
        flatter it (value parity only)."""
        spec = dreg.get_fused("braycurtis.fusedk.xla")
        f32 = dreg.fused_feat_traffic_bytes(spec, 512, 256,
                                            dreg.precision_tuning("f32"))
        fp8 = dreg.fused_feat_traffic_bytes(spec, 512, 256,
                                            dreg.precision_tuning("fp8"))
        assert f32 == fp8
        # and the round-tripped copy COSTS workset instead
        assert dreg.fused_workset_bytes(spec, 512, 256, 64, 8, 256,
                                        dreg.precision_tuning("fp8")) > \
            dreg.fused_workset_bytes(spec, 512, 256, 64, 8, 256,
                                     dreg.precision_tuning("f32"))

    def test_plan_explain_reports_precisions(self):
        pl = pipeline.plan_pipeline(512, 64, 100, 8, backend="cpu",
                                    metric="jaccard",
                                    materialize="fused-kernel",
                                    fused_impl="pallas",
                                    fused_tuning={"feat_packed": 1})
        text = pl.explain()
        for tag in ("f32", "bf16", "fp8", "packed"):
            assert tag in text
        assert "packed" in text.split("<- planned")[0].splitlines()[-1]
        # non-jaccard metrics must not advertise a packed row
        pl2 = pipeline.plan_pipeline(512, 64, 100, 8, backend="cpu",
                                     metric="euclidean",
                                     materialize="fused-kernel",
                                     fused_impl="pallas")
        assert "packed" not in pl2.explain()
