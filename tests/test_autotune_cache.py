"""Persisted autotune cache: concurrent writers must never leave a
partial/interleaved JSON document (write-temp + os.replace publish), and
merge-on-save must keep both writers' keys."""

import json
import os
import subprocess
import sys
import time

from conftest import SRC

WRITER = r"""
import os, sys, time
from repro.engine import planner

name = sys.argv[1]
n_writes = int(sys.argv[2])
settle = float(sys.argv[3])
for i in range(n_writes):
    planner.record_entry(f"dist|cpu|stress|{name}", {
        "impl": name, "us": float(i), "bucket": 32, "i": i})
# staggered final write: re-read the file (fresh merge base) so the last
# publisher has seen the other writer's keys
time.sleep(settle)
planner.load_autotune_cache(reload=True)
planner.record_entry(f"dist|cpu|stress|{name}", {
    "impl": name, "us": -1.0, "bucket": 32})
print("WRITER-DONE", name)
"""


def test_two_writers_never_corrupt_cache(tmp_path):
    """Two processes hammering record_entry against one cache file: every
    concurrent read parses as complete JSON (atomic publish), no temp
    files are left behind, and both writers' keys survive the race."""
    cache = tmp_path / "autotune.json"
    env = dict(os.environ)
    env["REPRO_AUTOTUNE_CACHE"] = str(cache)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["JAX_PLATFORMS"] = "cpu"

    procs = [
        subprocess.Popen(
            [sys.executable, "-c", WRITER, name, "40", settle],
            env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE,
            text=True)
        for name, settle in (("writerA", "0.3"), ("writerB", "0.9"))
    ]

    # concurrent reader: every observable state of the file must be a
    # complete JSON document — a non-atomic writer fails this immediately
    deadline = time.time() + 120
    parses = 0
    while any(p.poll() is None for p in procs):
        if time.time() > deadline:
            for p in procs:
                p.kill()
            raise AssertionError("writers did not finish in time")
        if cache.exists():
            try:
                data = json.loads(cache.read_text())
            except ValueError as e:  # pragma: no cover - the regression
                for p in procs:
                    p.kill()
                raise AssertionError(
                    f"cache file observed mid-write / corrupt: {e}")
            assert isinstance(data, dict)
            parses += 1
        time.sleep(0.005)

    for p in procs:
        out, err = p.communicate(timeout=60)
        assert p.returncode == 0, f"writer failed:\n{out}\n{err}"
        assert "WRITER-DONE" in out
    assert parses > 0, "reader never saw the cache file"

    data = json.loads(cache.read_text())
    # merge-on-save: the staggered final writes guarantee the last
    # publisher merged the other's key from disk
    assert "dist|cpu|stress|writerA" in data
    assert "dist|cpu|stress|writerB" in data
    for v in data.values():
        assert "impl" in v
    # atomic publish leaves no temp droppings
    leftovers = [p for p in os.listdir(tmp_path) if ".tmp." in p]
    assert not leftovers, leftovers


def test_schema_migrate_or_drop(tmp_path, monkeypatch):
    """Schema bump regression: pre-precision dist|/fusedk| entries (no
    schema field, or a stale one) must be dropped on load — their tuning
    payloads predate the precision knobs and would pin fp32 tile shapes
    onto fp8/packed runs — while the s_W shoot-out keys, which the schema
    does not govern, survive untouched. record_entry stamps the current
    schema so fresh entries round-trip."""
    import repro.engine.planner as planner
    cache = tmp_path / "autotune.json"
    cache.write_text(json.dumps({
        # pre-schema entries: dropped
        "fusedk|cpu|jaccard|jaccard.fusedk.pallas": {
            "impl": "jaccard.fusedk.pallas", "us": 1.0, "bucket": 64,
            "tuning": {"tile_r": 128}},
        "dist|cpu|jaccard|jaccard.blocked": {
            "impl": "jaccard.blocked", "us": 2.0, "bucket": 64},
        # stale schema: dropped
        "fusedk|cpu|euclidean|euclidean.fusedk.xla": {
            "impl": "euclidean.fusedk.xla", "us": 3.0, "bucket": 64,
            "schema": 1},
        # current schema: kept
        "fusedk|cpu|braycurtis|braycurtis.fusedk.xla|fp8": {
            "impl": "braycurtis.fusedk.xla", "us": 4.0, "bucket": 64,
            "schema": planner.CACHE_SCHEMA},
        # s_W shoot-out key: schema-exempt, kept
        "cpu|n64|g8": {"impl": "matmul", "us": 5.0},
    }))
    monkeypatch.setenv(planner.AUTOTUNE_CACHE_ENV, str(cache))
    try:
        data = planner.load_autotune_cache(reload=True)
        assert "fusedk|cpu|jaccard|jaccard.fusedk.pallas" not in data
        assert "dist|cpu|jaccard|jaccard.blocked" not in data
        assert "fusedk|cpu|euclidean|euclidean.fusedk.xla" not in data
        assert "fusedk|cpu|braycurtis|braycurtis.fusedk.xla|fp8" in data
        assert "cpu|n64|g8" in data

        # fresh entries are stamped and survive a reload from disk
        planner.record_entry("fusedk|cpu|jaccard|jaccard.fusedk.pallas", {
            "impl": "jaccard.fusedk.pallas", "us": 6.0, "bucket": 64,
            "tuning": {"tile_r": 64, "feat_packed": 1}})
        data = planner.load_autotune_cache(reload=True)
        entry = data["fusedk|cpu|jaccard|jaccard.fusedk.pallas"]
        assert entry["schema"] == planner.CACHE_SCHEMA
        assert entry["tuning"]["feat_packed"] == 1
        # the dropped pre-schema keys were not resurrected by the save
        on_disk = json.loads(cache.read_text())
        assert "dist|cpu|jaccard|jaccard.blocked" not in on_disk
    finally:
        monkeypatch.setenv(planner.AUTOTUNE_CACHE_ENV, "off")
        planner.load_autotune_cache(reload=True)


def test_failed_write_leaves_no_temp(tmp_path, monkeypatch):
    """A writer that dies mid-serialization must not leave a partial temp
    file (the unlink-on-failure path in _save_autotune_cache)."""
    import repro.engine.planner as planner
    cache = tmp_path / "autotune.json"
    monkeypatch.setenv(planner.AUTOTUNE_CACHE_ENV, str(cache))
    planner.load_autotune_cache(reload=True)
    try:
        real_dump = json.dump

        def boom(*a, **k):
            raise KeyboardInterrupt("simulated death mid-write")

        monkeypatch.setattr(json, "dump", boom)
        try:
            planner.record_entry("dist|cpu|x|doomed", {
                "impl": "doomed", "us": 1.0, "bucket": 32})
        except KeyboardInterrupt:
            pass
        monkeypatch.setattr(json, "dump", real_dump)
        leftovers = [p for p in os.listdir(tmp_path) if ".tmp." in p]
        assert not leftovers, leftovers
        assert not cache.exists()
    finally:
        monkeypatch.setenv(planner.AUTOTUNE_CACHE_ENV, "off")
        planner.load_autotune_cache(reload=True)


def test_truncated_cache_quarantined_and_served_empty(tmp_path,
                                                      monkeypatch, caplog):
    """Regression (fault-tolerant serving PR): a crash mid-write leaves a
    truncated JSON document. The loader must warn ONCE, quarantine the
    file under `.corrupt` (evidence survives, next writer starts clean),
    and continue with an empty cache — a serving process never dies over
    a cache. New measurements then persist normally."""
    import logging

    import pytest

    import repro.engine.planner as planner
    from repro import obs
    from repro.runtime.faultinject import FaultInjector

    cache = tmp_path / "autotune.json"
    monkeypatch.setenv(planner.AUTOTUNE_CACHE_ENV, str(cache))
    planner.load_autotune_cache(reload=True)
    try:
        planner.record_entry("dist|cpu|x|ok", {
            "impl": "ok", "us": 1.0, "bucket": 32})
        assert cache.exists()
        FaultInjector.corrupt_cache_file(str(cache))
        with open(cache) as f:
            with pytest.raises(json.JSONDecodeError):
                json.load(f)     # the fault really is a truncated doc

        obs.enable(trace=False, metrics=True)
        planner._WARNED.discard("corrupt")
        with caplog.at_level(logging.WARNING, logger=planner.__name__):
            assert planner.load_autotune_cache(reload=True) == {}
            planner.load_autotune_cache(reload=True)  # no second warning
        msgs = [r for r in caplog.records if "corrupt" in r.message]
        assert len(msgs) == 1
        assert obs.metrics.value(
            "autotune.cache.corrupt_quarantined") >= 1.0
        obs.disable()

        quarantined = tmp_path / "autotune.json.corrupt"
        assert quarantined.exists()
        assert not cache.exists()

        # the cache keeps working: a fresh entry persists and reloads
        planner.record_entry("dist|cpu|x|fresh", {
            "impl": "fresh", "us": 2.0, "bucket": 32})
        assert planner.load_autotune_cache(
            reload=True)["dist|cpu|x|fresh"]["impl"] == "fresh"
    finally:
        monkeypatch.setenv(planner.AUTOTUNE_CACHE_ENV, "off")
        planner.load_autotune_cache(reload=True)
