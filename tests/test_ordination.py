"""PCoA ordination: every execution path against a dense float64 eigh
oracle (up to sign / near-degenerate column order), residency contracts,
masked ragged studies, and the pipeline/engine integration surfaces."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import engine, pipeline
from repro.core import distance as dist
from repro.pipeline import ordination as ordn

N, D, G, K = 37, 12, 4, 3
METRICS = ("euclidean", "braycurtis", "jaccard", "aitchison")


def _study(seed=3, n=N, d=D, g=G):
    rng = np.random.default_rng(seed)
    x = rng.gamma(1.0, 1.0, size=(n, d)).astype(np.float32)
    x *= rng.random(size=(n, d)) < 0.6
    x[:, 0] = np.maximum(x[:, 0], 1e-3)
    grouping = rng.integers(0, g, size=n).astype(np.int32)
    grouping[:g] = np.arange(g)
    return x, grouping


def _oracle(mat2: np.ndarray, k: int):
    """Dense float64 Gower-center + eigh: the scipy-equivalent reference."""
    n = mat2.shape[0]
    m = np.asarray(mat2, np.float64)
    j = np.eye(n) - np.ones((n, n)) / n
    g = -0.5 * j @ m @ j
    w, v = np.linalg.eigh(g)
    order = np.argsort(-w)[:k]
    wk, vk = w[order], v[:, order]
    return wk, vk * np.sqrt(np.maximum(wk, 0.0)), np.trace(g)


def _assert_matches_oracle(res, wk, coords_ref, s_t, *, rtol=2e-4):
    scale = np.abs(wk).max()
    np.testing.assert_allclose(np.asarray(res.eigvals), wk,
                               rtol=rtol, atol=rtol * scale)
    c = np.asarray(res.coords)
    # align per-column signs (eigenvectors are sign-free)
    sgn = np.sign(np.sum(c * coords_ref, axis=0))
    sgn[sgn == 0] = 1.0
    np.testing.assert_allclose(
        c * sgn, coords_ref, rtol=rtol,
        atol=rtol * np.abs(coords_ref).max())
    np.testing.assert_allclose(np.asarray(res.explained), wk / s_t,
                               rtol=1e-3, atol=1e-5)


class TestPathsVsOracle:
    """eigh / subspace / feature-streamed paths vs the dense fp64 oracle,
    for every registered metric (the acceptance criterion)."""

    @pytest.mark.parametrize("metric", METRICS)
    def test_all_paths_match(self, metric):
        x, _ = _study()
        mdef = dist.ROW_METRICS[metric]
        xp = mdef.prepare(jnp.asarray(x))
        dmat = np.array(mdef.rows(xp, xp))
        np.fill_diagonal(dmat, 0.0)
        mat2 = (dmat * dmat).astype(np.float32)
        wk, coords_ref, s_t = _oracle(mat2, K)

        for res in (
            ordn.pcoa_eigh(jnp.asarray(mat2), K),
            ordn.pcoa_subspace(jnp.asarray(mat2), K),
            ordn.pcoa_features(xp, mdef.rows, K, row_block=13),
        ):
            _assert_matches_oracle(res, wk, coords_ref, s_t)

    def test_methods_recorded(self):
        x, _ = _study()
        mdef = dist.ROW_METRICS["euclidean"]
        xp = mdef.prepare(jnp.asarray(x))
        dmat = np.array(mdef.rows(xp, xp))
        mat2 = jnp.asarray((dmat * dmat).astype(np.float32))
        assert ordn.pcoa_eigh(mat2, 2).method == "eigh"
        assert ordn.pcoa_subspace(mat2, 2).method == "subspace"
        assert ordn.pcoa_features(xp, mdef.rows, 2,
                                  row_block=8).method == "subspace-stream"

    def test_trace_is_s_total(self):
        """trace(G) == s_T: the explained-variance denominator is the
        PERMANOVA total sum of squares."""
        x, grouping = _study(seed=5)
        res = pipeline.pipeline(jnp.asarray(x), jnp.asarray(grouping),
                                n_groups=G, n_perms=9,
                                materialize="stream", ordination=K)
        total = np.asarray(res.ordination.eigvals /
                           res.ordination.explained)
        np.testing.assert_allclose(total, float(res.s_t), rtol=1e-4)


class TestPipelineIntegration:
    def test_every_bridge_agrees(self):
        """pipeline(..., ordination=k) under all four bridges produces the
        same embedding (up to sign) — the stream/fused paths never build a
        second (n, n) array yet match the dense eigendecomposition."""
        x, grouping = _study(seed=7)
        ref = None
        for mat in ("dense", "stream", "fused", "fused-kernel"):
            res = pipeline.pipeline(jnp.asarray(x), jnp.asarray(grouping),
                                    n_groups=G, n_perms=9,
                                    materialize=mat, ordination=K)
            assert res.ordination is not None
            c = np.asarray(res.ordination.coords)
            assert c.shape == (N, K)
            if ref is None:
                ref = c
                continue
            sgn = np.sign(np.sum(c * ref, axis=0))
            sgn[sgn == 0] = 1.0
            np.testing.assert_allclose(c * sgn, ref, rtol=2e-3,
                                       atol=2e-4 * np.abs(ref).max())

    def test_off_by_default(self):
        x, grouping = _study()
        res = pipeline.pipeline(jnp.asarray(x), jnp.asarray(grouping),
                                n_groups=G, n_perms=9)
        assert res.ordination is None

    def test_pipeline_many_fused_matches_dense(self):
        x0, g0 = _study(seed=11, n=32)
        x1, g1 = _study(seed=12, n=32)
        xs = jnp.asarray(np.stack([x0, x1]))
        gs = jnp.asarray(np.stack([g0, g1]))
        md = pipeline.pipeline_many(xs, gs, n_groups=G, n_perms=9,
                                    materialize="dense", ordination=2)
        mf = pipeline.pipeline_many(xs, gs, n_groups=G, n_perms=9,
                                    materialize="fused-kernel", ordination=2)
        assert md.ordination.coords.shape == (2, 32, 2)
        np.testing.assert_allclose(np.abs(np.asarray(mf.ordination.coords)),
                                   np.abs(np.asarray(md.ordination.coords)),
                                   rtol=2e-3, atol=1e-4)
        np.testing.assert_allclose(np.asarray(mf.ordination.eigvals),
                                   np.asarray(md.ordination.eigvals),
                                   rtol=1e-3)


class TestEngineManyOrdination:
    def test_stacked_and_study_view(self):
        x0, g0 = _study(seed=21, n=24)
        mdef = dist.ROW_METRICS["braycurtis"]
        xp = mdef.prepare(jnp.asarray(x0))
        dmat = np.array(mdef.rows(xp, xp))
        np.fill_diagonal(dmat, 0.0)
        dms = jnp.asarray(np.stack([dmat, dmat]).astype(np.float32))
        gs = jnp.asarray(np.stack([g0, g0]))
        many = engine.permanova_many(dms, gs, n_groups=G, n_perms=9,
                                     ordination=2)
        wk, coords_ref, s_t = _oracle((dmat * dmat).astype(np.float32), 2)
        _assert_matches_oracle(many.ordination.study(0), wk, coords_ref,
                               s_t, rtol=5e-4)
        one = many.study(1)
        assert one.ordination is not None and one.ordination.k == 2
        # r2 on the shared result contract
        np.testing.assert_allclose(np.asarray(many.r2),
                                   1.0 - np.asarray(many.s_w)
                                   / np.asarray(many.s_t), rtol=1e-6)

    def test_ragged_pad_coords_zero(self):
        """Masked studies: pad coordinates exactly zero, valid block
        matching the unpadded embedding."""
        sizes = (14, 23, 17)
        studies = [_study(seed=30 + i, n=m) for i, m in enumerate(sizes)]
        mdef = dist.ROW_METRICS["euclidean"]
        dms, gs = [], []
        for x, g in studies:
            xp = mdef.prepare(jnp.asarray(x))
            dmat = np.array(mdef.rows(xp, xp))
            np.fill_diagonal(dmat, 0.0)
            dms.append(dmat.astype(np.float32))
            gs.append(g)
        many = engine.permanova_many(dms, gs, n_groups=G, n_perms=9,
                                     ordination=2)
        coords = np.asarray(many.ordination.coords)
        for s, m in enumerate(sizes):
            assert np.all(coords[s, m:] == 0.0), s
            wk, coords_ref, s_t = _oracle(dms[s] * dms[s], 2)
            res_s = many.ordination.study(s)
            res_valid = ordn.PCoAResult(
                coords=res_s.coords[:m], eigvals=res_s.eigvals,
                explained=res_s.explained, method=res_s.method)
            _assert_matches_oracle(res_valid, wk, coords_ref, s_t,
                                   rtol=1e-3)
