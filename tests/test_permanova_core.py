"""Core PERMANOVA correctness: every s_W variant against the literal
Algorithm 1 transcription, full-test statistics, p-value semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fstat, permutations, s_total, f_from_sw, \
    p_value_from_null, permanova
from repro.core.permanova import SW_IMPLS

N_PERMS = 9


def _perms(grouping, n):
    return np.asarray(permutations.permutation_batch(
        jax.random.key(3), jnp.asarray(grouping), 0, n))


class TestSwVariants:
    @pytest.mark.parametrize("impl", sorted(SW_IMPLS))
    def test_matches_algorithm1(self, small_study, impl):
        dm, grouping, inv_gs, mat2 = small_study
        gperms = _perms(grouping, N_PERMS)
        oracle = fstat.sw_algorithm1_numpy(dm, gperms, inv_gs)
        got = np.asarray(SW_IMPLS[impl](
            jnp.asarray(mat2), jnp.asarray(gperms), jnp.asarray(inv_gs)))
        np.testing.assert_allclose(got, oracle, rtol=2e-5)

    def test_full_matrix_form_equals_triangle(self, small_study):
        dm, grouping, inv_gs, mat2 = small_study
        gperms = _perms(grouping, 4)
        tri = np.asarray(fstat.sw_brute(jnp.asarray(mat2),
                                        jnp.asarray(gperms),
                                        jnp.asarray(inv_gs)))
        full = np.asarray(jax.vmap(
            lambda g: fstat.sw_full_one(jnp.asarray(mat2), g,
                                        jnp.asarray(inv_gs)))(
            jnp.asarray(gperms)))
        np.testing.assert_allclose(full, tri, rtol=2e-5)

    def test_row_partials_sum_to_total(self, small_study):
        dm, grouping, inv_gs, mat2 = small_study
        gperms = _perms(grouping, 5)
        oracle = fstat.sw_algorithm1_numpy(dm, gperms, inv_gs)
        for fn in (fstat.sw_rows_partial, fstat.sw_matmul_rows_partial):
            parts = [np.asarray(fn(jnp.asarray(mat2[o:o + 16]), o,
                                   jnp.asarray(gperms),
                                   jnp.asarray(inv_gs)))
                     for o in (0, 16, 32)]
            np.testing.assert_allclose(sum(parts), oracle, rtol=2e-5)


class TestFullTest:
    def test_identity_perm_first(self, small_study):
        dm, grouping, _, _ = small_study
        gperms = _perms(grouping, 3)
        np.testing.assert_array_equal(gperms[0], grouping)

    def test_partition_identity(self, small_study):
        """s_A + s_W = s_T for every permutation."""
        dm, grouping, inv_gs, mat2 = small_study
        gperms = _perms(grouping, N_PERMS)
        s_w = np.asarray(fstat.sw_brute(jnp.asarray(mat2),
                                        jnp.asarray(gperms),
                                        jnp.asarray(inv_gs)))
        st = float(s_total(jnp.asarray(mat2)))
        # s_A is defined as s_T - s_W: check s_W <= s_T (non-negativity
        # of the between-group term) for the observed grouping
        assert np.all(s_w <= st + 1e-4)

    def test_p_value_bounds_and_f_positive(self, small_study):
        dm, grouping, _, _ = small_study
        res = permanova(jnp.asarray(dm), jnp.asarray(grouping),
                        n_perms=49, sw_impl="brute")
        assert 1.0 / 50 <= float(res.p_value) <= 1.0
        assert float(res.f_stat) > 0
        assert res.f_perms.shape == (50,)

    def test_impls_agree_end_to_end(self, small_study):
        dm, grouping, _, _ = small_study
        results = {impl: permanova(jnp.asarray(dm), jnp.asarray(grouping),
                                   n_perms=29, sw_impl=impl)
                   for impl in sorted(SW_IMPLS)}
        f = [float(r.f_stat) for r in results.values()]
        p = [float(r.p_value) for r in results.values()]
        np.testing.assert_allclose(f, f[0], rtol=1e-4)
        np.testing.assert_allclose(p, p[0], atol=1e-6)

    def test_planted_effect_gives_small_p(self):
        from repro.core import distance
        from repro.data.microbiome import synthetic_study
        x, grouping = synthetic_study(60, 40, 2, effect_size=5.0, seed=1)
        dm = distance.braycurtis(jnp.asarray(x))
        res = permanova(dm, jnp.asarray(grouping), n_perms=99)
        assert float(res.p_value) <= 0.05

    def test_null_p_is_not_extreme(self):
        from repro.core import distance
        from repro.data.microbiome import synthetic_study
        x, grouping = synthetic_study(60, 40, 2, effect_size=0.0, seed=2)
        dm = distance.braycurtis(jnp.asarray(x))
        res = permanova(dm, jnp.asarray(grouping), n_perms=99,
                        key=jax.random.key(11))
        assert float(res.p_value) > 0.05


class TestPermutations:
    def test_group_sizes_invariant(self, small_study):
        _, grouping, _, _ = small_study
        gperms = _perms(grouping, 20)
        base = np.bincount(grouping, minlength=3)
        for g in gperms:
            np.testing.assert_array_equal(np.bincount(g, minlength=3), base)

    def test_global_index_folding_shard_equivalence(self, small_study):
        """Any shard holding range [lo,hi) generates the same labels."""
        _, grouping, _, _ = small_study
        key = jax.random.key(5)
        g = jnp.asarray(grouping)
        full = np.asarray(permutations.permutation_batch(key, g, 0, 16))
        lo_hi = np.asarray(permutations.permutation_batch(key, g, 4, 12))
        np.testing.assert_array_equal(full[4:12], lo_hi)
