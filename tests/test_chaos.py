"""Deterministic chaos suite for the always-on PERMANOVA server.

Every injected fault — worker death, stragglers, dropped heartbeats
(zombies), simulated OOM, full fleet loss, server restart, corrupted plan
cache — must converge to the SAME F statistic and permutation set as the
failure-free serving run: recovery is bit-identical recomputation via
global-index key folding, never approximate reconciliation. All chaos is
seeded and applied against a virtual clock, so any failure replays
exactly.
"""

import numpy as np
import pytest

from repro.core.distance import distance_matrix
from repro.runtime.elastic import AllWorkersDead, ElasticBlockExecutor
from repro.runtime.faultinject import FaultInjector, VirtualClock
from repro.serve.permanova import (PermanovaServer, RetryPolicy,
                                   StudyRequest, mc_pvalue_ci)


@pytest.fixture(scope="module")
def study():
    rng = np.random.default_rng(42)
    x = rng.normal(size=(23, 6)).astype(np.float32)
    g = rng.integers(0, 3, size=23).astype(np.int32)
    dm = np.asarray(distance_matrix(x, "euclidean"))
    return dm, g


def _serve(study, injector=None, *, workers=3, block=16, n_perms=127,
           seed=0, **kw):
    dm, g = study
    srv = PermanovaServer(workers=workers, block=block,
                          clock=VirtualClock(), injector=injector, **kw)
    return srv.process(StudyRequest(grouping=g, dm=dm, n_perms=n_perms,
                                    seed=seed))


@pytest.fixture(scope="module")
def clean(study):
    """The failure-free serving run every chaos case must reproduce."""
    return _serve(study)


def _assert_identical(res, clean):
    assert res.status == "ok"
    assert float(res.result.f_stat) == float(clean.result.f_stat)
    assert float(res.result.p_value) == float(clean.result.p_value)
    assert np.array_equal(np.asarray(res.result.f_perms),
                          np.asarray(clean.result.f_perms))


class TestFaultConvergence:
    def test_kill_one_worker(self, study, clean):
        inj = FaultInjector(seed=1).kill_worker_after_blocks(0, 1)
        res = _serve(study, inj)
        _assert_identical(res, clean)
        assert any("kill worker=0" in h for h in res.report.history)

    def test_kill_majority_of_fleet(self, study, clean):
        inj = (FaultInjector(seed=2)
               .kill_worker_after_blocks(0, 0)
               .kill_worker_after_blocks(2, 1))
        res = _serve(study, inj)
        _assert_identical(res, clean)

    def test_straggler_speculation(self, study, clean):
        # worker 1 takes 50x the others' block time: past the straggler
        # factor its blocks are speculatively recomputed elsewhere and
        # the duplicate completions must agree bit-for-bit (asserted
        # inside the executor; a mismatch raises).
        inj = (FaultInjector(seed=3)
               .delay_block(None, 0.01).delay_block(1, 0.5))
        res = _serve(study, inj)
        _assert_identical(res, clean)
        assert res.report.speculative >= 1

    def test_dropped_heartbeats_zombie_fenced(self, study, clean):
        # worker 0's beats are lost long enough for the monitor to
        # declare it dead while it computed a block: the late report
        # carries a stale incarnation, is rejected, and the block is
        # recomputed bit-identically (the zombie's value is checked
        # against the committed one inside the executor).
        inj = (FaultInjector(seed=4)
               .delay_block(None, 2.0)          # clock moves; timeout=5
               .drop_heartbeats(0, 12))
        res = _serve(study, inj)
        _assert_identical(res, clean)
        assert 0 in res.report.workers_died
        assert res.report.recomputed + res.report.stale_beats_rejected >= 1

    def test_simulated_oom_retried(self, study, clean):
        # block 0 OOMs once on EVERY worker (specs are (worker, block)
        # keyed, so at least the first two attempts fail under any
        # round-robin routing): jittered backoff + requeue each time,
        # then success within the block-level retry budget.
        inj = FaultInjector(seed=5)
        for w in range(3):
            inj.oom_at_block(w, 0)
        res = _serve(study, inj)
        _assert_identical(res, clean)
        assert res.report.transient_failures >= 2

    def test_seeded_random_chaos(self, study, clean):
        # a different storm per seed, all replayable: each must converge
        for seed in range(5):
            rng = np.random.default_rng(seed)
            inj = FaultInjector(seed=seed)
            inj.delay_block(None, float(rng.uniform(0.01, 0.1)))
            if rng.random() < 0.8:
                inj.kill_worker_after_blocks(int(rng.integers(0, 3)),
                                             int(rng.integers(0, 3)))
            if rng.random() < 0.8:
                inj.drop_heartbeats(int(rng.integers(0, 3)),
                                    int(rng.integers(1, 8)))
            if rng.random() < 0.8:
                inj.oom_at_block(int(rng.integers(0, 3)),
                                 int(rng.integers(0, 8)))
            res = _serve(study, inj)
            _assert_identical(res, clean)


class TestRequestRetries:
    def test_fleet_loss_restarts_and_recovers(self, study, clean):
        # every worker dies before finishing: attempt 1 raises
        # AllWorkersDead; the jittered-backoff retry restarts a fresh
        # fleet (kill declarations are consumed) and must reproduce the
        # failure-free result exactly.
        inj = FaultInjector(seed=6)
        for w in range(3):
            inj.kill_worker_after_blocks(w, 0)
        res = _serve(study, inj)
        _assert_identical(res, clean)
        assert res.retries == 1

    def test_oom_escalates_to_request_retry(self, study, clean):
        # the same block OOMs on every worker more times than the
        # block-level retry budget: SimulatedOOM escapes the executor,
        # the request retries with a fresh fleet and drains the fault.
        inj = FaultInjector(seed=7)
        for w in range(3):
            inj.oom_at_block(w, 0, times=2)
        res = _serve(study, inj, max_transient_retries=2)
        _assert_identical(res, clean)
        assert res.retries >= 1

    def test_retry_exhaustion_fails_cleanly(self, study):
        dm, g = study
        inj = FaultInjector(seed=8)
        for w in range(2):
            inj.kill_worker_after_blocks(w, 0)
        srv = PermanovaServer(workers=2, block=16, clock=VirtualClock(),
                              injector=inj,
                              retry=RetryPolicy(max_retries=0))
        res = srv.process(StudyRequest(grouping=g, dm=dm, n_perms=63))
        assert res.status == "failed"
        assert not res.ok
        assert "AllWorkersDead" in res.error


class TestDeadlineDegradation:
    def test_degraded_ci_contains_full_p(self, study):
        dm, g = study
        inj = FaultInjector(seed=9).delay_block(None, 0.2)
        srv = PermanovaServer(workers=2, block=16, clock=VirtualClock(),
                              injector=inj)
        res = srv.process(StudyRequest(grouping=g, dm=dm, n_perms=255,
                                       seed=0, deadline_s=1.0))
        assert res.status == "degraded" and res.degraded
        assert 0 < res.n_perms_done < 255
        assert res.result.method.endswith("+degraded")

        full = PermanovaServer(workers=2, block=16).process(
            StudyRequest(grouping=g, dm=dm, n_perms=255, seed=0))
        # the degraded null is a PREFIX of the full run's (same stream)
        m = res.n_perms_done
        assert np.array_equal(
            np.asarray(res.result.f_perms),
            np.asarray(full.result.f_perms)[: m + 1])
        # and the attached 95% Monte-Carlo CI covers the p-value the
        # full-n_perms run reports (deterministic for this seed)
        lo, hi = res.p_ci
        assert lo <= float(full.result.p_value) <= hi
        assert lo <= float(res.result.p_value) <= hi

    def test_deadline_before_observed_fails(self, study):
        dm, g = study
        inj = FaultInjector(seed=10).delay_block(None, 1.0)
        srv = PermanovaServer(workers=2, block=16, clock=VirtualClock(),
                              injector=inj)
        res = srv.process(StudyRequest(grouping=g, dm=dm, n_perms=63,
                                       deadline_s=0.0))
        assert res.status == "failed"
        assert "observed" in res.error

    def test_mc_pvalue_ci_properties(self):
        lo, hi = mc_pvalue_ci(10, 50, 999)
        assert 0.0 < lo <= hi < 1.0
        # finished sweep: degenerate point interval at the exact p
        lo, hi = mc_pvalue_ci(42, 255, 255)
        assert lo == hi == pytest.approx(43.0 / 256.0)
        # extremes stay inside (0, 1]
        lo0, hi0 = mc_pvalue_ci(0, 20, 999)
        assert lo0 >= 1.0 / 1000.0 and hi0 < 1.0
        lom, him = mc_pvalue_ci(20, 20, 999)
        assert him <= 1.0 and lom > 0.5


class TestRestartResume:
    def test_server_restart_finishes_in_flight_request(self, study,
                                                       tmp_path):
        dm, g = study
        full = PermanovaServer(workers=2, block=16).process(
            StudyRequest(grouping=g, dm=dm, n_perms=255, seed=0))

        # phase 1: deadline kills the request mid-flight; partial s_W
        # accumulators are checkpointed through checkpoint/manager.py
        inj = FaultInjector(seed=11).delay_block(None, 0.2)
        srv1 = PermanovaServer(workers=2, block=16, clock=VirtualClock(),
                               injector=inj, ckpt_dir=tmp_path,
                               checkpoint_every=2)
        r1 = srv1.process(StudyRequest(grouping=g, dm=dm, n_perms=255,
                                       seed=0, deadline_s=1.0,
                                       request_id="restart-me"))
        assert r1.status == "degraded"
        assert (tmp_path / "restart-me").exists()

        # phase 2: a NEW server (fresh process stand-in) resumes from the
        # checkpoint — only the missing blocks run, and the end state is
        # bit-identical to the uninterrupted run
        srv2 = PermanovaServer(workers=2, block=16, ckpt_dir=tmp_path)
        r2 = srv2.process(StudyRequest(grouping=g, dm=dm, n_perms=255,
                                       seed=0, request_id="restart-me"))
        assert r2.status == "ok"
        assert r2.report.committed < r2.report.n_blocks
        assert np.array_equal(np.asarray(r2.result.f_perms),
                              np.asarray(full.result.f_perms))
        # finished request's checkpoint state is cleaned up
        assert not (tmp_path / "restart-me").exists()

    def test_mismatched_checkpoint_ignored(self, study, tmp_path):
        # a checkpoint from a DIFFERENT request config (other seed) must
        # not be resumed into this request
        dm, g = study
        inj = FaultInjector(seed=12).delay_block(None, 0.2)
        srv1 = PermanovaServer(workers=2, block=16, clock=VirtualClock(),
                               injector=inj, ckpt_dir=tmp_path,
                               checkpoint_every=1)
        srv1.process(StudyRequest(grouping=g, dm=dm, n_perms=255, seed=5,
                                  deadline_s=1.0, request_id="shared-id"))
        srv2 = PermanovaServer(workers=2, block=16, ckpt_dir=tmp_path)
        r = srv2.process(StudyRequest(grouping=g, dm=dm, n_perms=255,
                                      seed=0, request_id="shared-id"))
        assert r.status == "ok"
        assert r.report.committed == r.report.n_blocks   # full recompute
        full = PermanovaServer(workers=2, block=16).process(
            StudyRequest(grouping=g, dm=dm, n_perms=255, seed=0))
        assert np.array_equal(np.asarray(r.result.f_perms),
                              np.asarray(full.result.f_perms))


class TestCorruptPlanCache:
    def test_corrupt_cache_entry_degrades_to_heuristic(self, study,
                                                       tmp_path,
                                                       monkeypatch, clean):
        # chaos case 'corrupt-cache-entry': a served request persists its
        # bucket plan; the cache file is then truncated mid-document (as
        # a crash mid-write would). A fresh server must quarantine the
        # corrupt file, fall back to the plan heuristic, and serve
        # bit-identical results.
        from repro.engine import planner
        path = tmp_path / "autotune.json"
        monkeypatch.setenv(planner.AUTOTUNE_CACHE_ENV, str(path))
        planner.load_autotune_cache(reload=True)
        res1 = _serve(study)
        _assert_identical(res1, clean)
        assert path.exists()

        FaultInjector.corrupt_cache_file(str(path))
        planner._WARNED.discard("corrupt")
        planner.load_autotune_cache(reload=True)
        res2 = _serve(study)
        _assert_identical(res2, clean)
        assert path.with_suffix(".json.corrupt").exists()
        planner.load_autotune_cache(reload=True)


def _batch_reqs(study, *, n_perms=127, deadline_idx=None,
                deadline_s=None):
    """Four same-bucket requests with distinct seeds (the coalescing
    unit for the batched chaos cases)."""
    dm, g = study
    out = []
    for s in range(4):
        r = StudyRequest(grouping=g, dm=dm, n_perms=n_perms, seed=s,
                         request_id=f"b{s}")
        if deadline_idx == s:
            r.deadline_s = deadline_s
        out.append(r)
    return out


class TestBatchedChaos:
    @pytest.fixture(scope="class")
    def clean_batch(self, study):
        """Failure-free SERIAL results — the reference every batched and
        faulted run must reproduce bit-for-bit."""
        srv = PermanovaServer(workers=3, block=16, clock=VirtualClock())
        return srv.serve(_batch_reqs(study))

    def test_batched_matches_serial(self, study, clean_batch):
        srv = PermanovaServer(workers=3, block=16, clock=VirtualClock())
        out = srv.serve(_batch_reqs(study), batched=True)
        for a, c in zip(out, clean_batch):
            assert a.batched
            _assert_identical(a, c)

    def test_batched_survives_worker_death(self, study, clean_batch):
        inj = FaultInjector(seed=21).kill_worker_after_blocks(0, 1)
        srv = PermanovaServer(workers=3, block=16, clock=VirtualClock(),
                              injector=inj)
        out = srv.serve(_batch_reqs(study), batched=True)
        for a, c in zip(out, clean_batch):
            _assert_identical(a, c)
        assert any(any("kill worker=0" in h for h in a.report.history)
                   for a in out)

    def test_batched_survives_fleet_loss_via_retry(self, study,
                                                   clean_batch):
        inj = FaultInjector(seed=22)
        for w in range(3):
            inj.kill_worker_after_blocks(w, 0)
        srv = PermanovaServer(workers=3, block=16, clock=VirtualClock(),
                              injector=inj)
        out = srv.serve(_batch_reqs(study), batched=True)
        for a, c in zip(out, clean_batch):
            _assert_identical(a, c)
        assert all(a.retries >= 1 for a in out)

    def test_batched_deadline_degrades_one_member(self, study,
                                                  clean_batch):
        # one member carries a deadline; it degrades while the other
        # three finish exactly — then idle-capacity resume pushes the
        # EXACT result to the degraded caller's `final` future.
        inj = FaultInjector(seed=23).delay_block(None, 0.2)
        srv = PermanovaServer(workers=3, block=16, clock=VirtualClock(),
                              injector=inj)
        out = srv.serve(_batch_reqs(study, deadline_idx=1, deadline_s=1.0),
                        batched=True)
        assert [r.status for r in out] == ["ok", "degraded", "ok", "ok"]
        for i in (0, 2, 3):
            _assert_identical(out[i], clean_batch[i])
        deg = out[1]
        assert 0 < deg.n_perms_done < 127 and deg.p_ci is not None
        # degraded null is a prefix of the clean full null (same stream)
        m = deg.n_perms_done
        assert np.array_equal(
            np.asarray(deg.result.f_perms),
            np.asarray(clean_batch[1].result.f_perms)[: m + 1])
        lo, hi = deg.p_ci
        assert lo <= float(clean_batch[1].result.p_value) <= hi
        # opportunistic resume: the permutation tail completes exactly
        assert deg.final is not None and srv.resume_backlog == 1
        (exact,) = srv.resume_degraded()
        _assert_identical(exact, clean_batch[1])
        assert exact.n_perms_done == 127
        assert deg.final.done() and deg.final.result() is exact

    def test_serial_degraded_resume_exact(self, study, clean):
        # the serial path gets the same opportunistic-resume contract
        inj = FaultInjector(seed=24).delay_block(None, 0.2)
        dm, g = study
        srv = PermanovaServer(workers=3, block=16, clock=VirtualClock(),
                              injector=inj)
        res = srv.process(StudyRequest(grouping=g, dm=dm, n_perms=127,
                                       seed=0, deadline_s=1.0))
        assert res.status == "degraded" and res.final is not None
        (exact,) = srv.resume_degraded()
        _assert_identical(exact, clean)
        assert res.final.result() is exact


class TestBucketDriftRestart:
    def test_restart_with_changed_buckets_recomputes(self, study,
                                                     tmp_path):
        from repro import obs
        dm, g = study
        # phase 1: bucket_sizes=[32] — deadline kills the request
        # mid-flight, partial s_W checkpointed under n_pad=32
        inj = FaultInjector(seed=31).delay_block(None, 0.2)
        srv1 = PermanovaServer(workers=2, block=16, bucket_sizes=[32],
                               clock=VirtualClock(), injector=inj,
                               ckpt_dir=tmp_path, checkpoint_every=2)
        r1 = srv1.process(StudyRequest(grouping=g, dm=dm, n_perms=255,
                                       seed=0, deadline_s=1.0,
                                       request_id="drift-me"))
        assert r1.status == "degraded"
        assert (tmp_path / "drift-me").exists()

        # phase 2: restart with bucket_sizes=[24] — the padded mask
        # changed, so the checkpointed s_W stream is NOT resumable; the
        # server must ignore it (counter, no crash) and recompute
        obs.enable(trace=False, metrics=True)
        try:
            snap0 = obs.metrics.snapshot()
            srv2 = PermanovaServer(workers=2, block=16, bucket_sizes=[24],
                                   ckpt_dir=tmp_path)
            r2 = srv2.process(StudyRequest(grouping=g, dm=dm, n_perms=255,
                                           seed=0, request_id="drift-me"))
            d = obs.metrics.counter_delta(snap0)
        finally:
            obs.disable()
        assert r2.status == "ok"
        assert d.get("serve.ckpt_bucket_drift", 0) >= 1.0
        assert not d.get("serve.resumed_requests")
        assert r2.report.committed == r2.report.n_blocks  # full recompute
        clean24 = PermanovaServer(workers=2, block=16,
                                  bucket_sizes=[24]).process(
            StudyRequest(grouping=g, dm=dm, n_perms=255, seed=0))
        assert np.array_equal(np.asarray(r2.result.f_perms),
                              np.asarray(clean24.result.f_perms))

    def test_same_buckets_still_resume(self, study, tmp_path):
        # control: identical bucket_sizes across the restart DOES resume
        dm, g = study
        inj = FaultInjector(seed=32).delay_block(None, 0.2)
        srv1 = PermanovaServer(workers=2, block=16, bucket_sizes=[32],
                               clock=VirtualClock(), injector=inj,
                               ckpt_dir=tmp_path, checkpoint_every=2)
        r1 = srv1.process(StudyRequest(grouping=g, dm=dm, n_perms=255,
                                       seed=0, deadline_s=1.0,
                                       request_id="stay-me"))
        assert r1.status == "degraded"
        srv2 = PermanovaServer(workers=2, block=16, bucket_sizes=[32],
                               ckpt_dir=tmp_path)
        r2 = srv2.process(StudyRequest(grouping=g, dm=dm, n_perms=255,
                                       seed=0, request_id="stay-me"))
        assert r2.status == "ok"
        assert r2.report.committed < r2.report.n_blocks   # real resume


class TestDegradedCiExtremes:
    """Satellite: the beta-binomial predictive CI must stay clamped and
    ordered at the extremes (0 hits / all hits) on BOTH quantile paths,
    and always bracket the degraded point estimate (k+1)/(m+1)."""

    def _paths(self):
        paths = [False]            # normal-approx fallback, always on
        try:
            import scipy.stats  # noqa: F401
            paths.append(True)
        except ImportError:
            pass
        return paths

    def _check(self, k, m, n_full, use_scipy):
        lo, hi = mc_pvalue_ci(k, m, n_full, use_scipy=use_scipy)
        p_hat = (k + 1.0) / (m + 1.0)
        assert lo <= hi, (k, m, n_full, use_scipy)
        assert lo <= p_hat <= hi, (k, m, n_full, use_scipy, lo, hi)
        assert lo >= 1.0 / (n_full + 1.0)
        assert hi <= 1.0

    def test_extremes_both_paths(self):
        for use_scipy in self._paths():
            for m, n_full in [(1, 999), (10, 999), (255, 999), (1, 2),
                              (50, 51)]:
                self._check(0, m, n_full, use_scipy)     # zero hits
                self._check(m, m, n_full, use_scipy)     # all hits
            self._check(0, 1, 10 ** 6, use_scipy)        # tiny m, huge n

    def test_property_lo_p_hi(self):
        try:
            from hypothesis import given, settings
            from hypothesis import strategies as st
        except ImportError:
            rng = np.random.default_rng(0)
            for _ in range(300):
                n_full = int(rng.integers(1, 10000))
                m = int(rng.integers(0, n_full + 1))
                k = int(rng.integers(0, m + 1))
                for use_scipy in self._paths():
                    self._check(k, m, n_full, use_scipy)
            return

        paths = self._paths()

        @settings(max_examples=200, deadline=None)
        @given(data=st.data(), n_full=st.integers(1, 10000))
        def prop(data, n_full):
            m = data.draw(st.integers(0, n_full))
            k = data.draw(st.integers(0, m))
            for use_scipy in paths:
                self._check(k, m, n_full, use_scipy)

        prop()


def _sum_blocks(lo, hi):
    """Deterministic stand-in for an s_W block: value = f(global index)."""
    return np.sqrt(np.arange(lo, hi, dtype=np.float32) + 1.0)


class TestKillPointProperty:
    """Property: killing ANY worker at ANY block boundary (under any
    speculative-duplicate completion order the executor produces) yields
    s_W partials bit-identical to the single-worker run. Uses Hypothesis
    when installed; otherwise sweeps the full (worker, kill point, fleet)
    grid — the domain is small enough to enumerate."""

    N_BLOCKS = 7

    def _reference(self):
        exe = ElasticBlockExecutor(self.N_BLOCKS, workers=1,
                                   clock=VirtualClock())
        out, done, _ = exe.run(_sum_blocks,
                               [(i * 4, i * 4 + 4)
                                for i in range(self.N_BLOCKS)])
        assert done.all()
        return out

    def _run_case(self, n_workers, victim, kill_at, delay_victim):
        ref = self._reference()
        inj = FaultInjector(seed=0)
        inj.kill_worker_after_blocks(victim, kill_at)
        if delay_victim:        # also make the victim a straggler first
            inj.delay_block(None, 0.01).delay_block(victim, 0.2)
        exe = ElasticBlockExecutor(self.N_BLOCKS, workers=n_workers,
                                   clock=VirtualClock(), injector=inj)
        try:
            out, done, rep = exe.run(
                _sum_blocks, [(i * 4, i * 4 + 4)
                              for i in range(self.N_BLOCKS)])
        except AllWorkersDead:
            assert n_workers == 1    # only a lone fleet can fully die
            return
        assert done.all()
        np.testing.assert_array_equal(out, ref)

    def test_kill_anywhere_bit_identical(self):
        try:
            from hypothesis import given, settings
            from hypothesis import strategies as st
        except ImportError:
            for n_workers in (2, 3, 4):
                for victim in range(n_workers):
                    for kill_at in range(self.N_BLOCKS + 1):
                        for delay in (False, True):
                            self._run_case(n_workers, victim, kill_at,
                                           delay)
            return

        @settings(max_examples=120, deadline=None)
        @given(n_workers=st.integers(2, 4),
               victim=st.integers(0, 3),
               kill_at=st.integers(0, self.N_BLOCKS + 1),
               delay=st.booleans())
        def prop(n_workers, victim, kill_at, delay):
            self._run_case(n_workers, victim % n_workers, kill_at, delay)

        prop()
