"""Out-of-core slab streaming: cache round-trips (dense + csr), corrupt
slab quarantine, the async prefetcher's exception-safety and accounting,
residency-tiered planning, and the acceptance bar — pipeline(cache) is
bit-identical to the in-memory fused bridge at the same slab boundaries
for every metric, on both OOC materialize forms, including odd slab
sizes, the csr/jaccard path and covariate+strata designs."""

import json
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs, pipeline
from repro.data import microbiome, slabcache
from repro.pipeline import planner as pplanner
from repro.pipeline import registry as preg

N, D, G = 100, 24, 4
SLAB = 32            # 100/32 -> 4 slabs, ragged tail of 4 rows
PERMS = 49


def _study(seed=0, n=N, d=D, g=G):
    rng = np.random.default_rng(seed)
    x = rng.gamma(1.0, 1.0, size=(n, d)).astype(np.float32)
    x *= rng.random(size=(n, d)) < 0.5        # sparsity: jaccard informative
    x[:, 0] = np.maximum(x[:, 0], 1e-3)       # no all-zero samples
    grouping = rng.integers(0, g, size=n).astype(np.int32)
    grouping[:g] = np.arange(g)
    return x, grouping


def _no_prefetch_threads(timeout=5.0):
    """True once no slab-prefetch worker thread remains alive."""
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if not [t for t in threading.enumerate()
                if t.name == "slab-prefetch"]:
            return True
        time.sleep(0.01)
    return False


@pytest.fixture(autouse=True)
def _clean(tmp_path):
    """Telemetry off/empty around every test; warn-once set reset so each
    quarantine test observes its own warning; no leaked worker threads."""
    obs.disable()
    obs.clear()
    obs.metrics.reset()
    slabcache._WARNED.clear()
    yield
    assert _no_prefetch_threads(), "slab-prefetch thread leaked"
    obs.disable()
    obs.clear()
    obs.metrics.reset()


class TestCacheRoundTrip:
    def test_dense_round_trip(self, tmp_path):
        x, _ = _study()
        cache = slabcache.build_slab_cache(tmp_path / "c", x,
                                           slab_rows=SLAB)
        assert (cache.n, cache.d) == (N, D)
        assert cache.n_slabs == -(-N // SLAB)
        assert cache.rows_in_slab(cache.n_slabs - 1) == N % SLAB or SLAB
        assert cache.disk_bytes == 4 * N * D
        assert cache.feature_bytes == 4 * N * D
        np.testing.assert_array_equal(cache.to_array(), x)
        s0 = cache.read_slab(0)
        np.testing.assert_array_equal(s0, x[:SLAB])

    def test_reopen_and_staging_read(self, tmp_path):
        x, _ = _study(1)
        slabcache.build_slab_cache(tmp_path / "c", x, slab_rows=SLAB)
        cache = slabcache.SlabCache.open(tmp_path / "c")
        buf = np.full((SLAB, D), 9.0, np.float32)
        tail = cache.read_slab(cache.n_slabs - 1, out=buf)
        np.testing.assert_array_equal(
            tail, x[(cache.n_slabs - 1) * SLAB:])
        with pytest.raises(IndexError):
            cache.read_slab(cache.n_slabs)

    def test_odd_slab_rows(self, tmp_path):
        x, _ = _study(2)
        cache = slabcache.build_slab_cache(tmp_path / "c", x, slab_rows=7)
        assert cache.n_slabs == -(-N // 7)
        np.testing.assert_array_equal(cache.to_array(), x)

    def test_writer_uneven_appends_match_oneshot(self, tmp_path):
        x, _ = _study(3)
        with slabcache.SlabCacheWriter(tmp_path / "w", d=D,
                                       slab_rows=SLAB) as w:
            for lo, hi in ((0, 3), (3, 53), (53, N)):
                w.append(x[lo:hi])
        cache = slabcache.SlabCache.open(tmp_path / "w")
        ref = slabcache.build_slab_cache(tmp_path / "ref", x,
                                         slab_rows=SLAB)
        assert cache.n_slabs == ref.n_slabs
        np.testing.assert_array_equal(cache.to_array(), ref.to_array())

    def test_csr_round_trip_presence_only(self, tmp_path):
        x, _ = _study(4)
        cache = slabcache.build_slab_cache(tmp_path / "c", x,
                                           slab_rows=SLAB, fmt="csr")
        assert cache.fmt == "csr"
        np.testing.assert_array_equal(cache.to_array(),
                                      (x > 0).astype(np.float32))
        # structure-only storage beats the dense footprint at ~50% density
        assert cache.disk_bytes < 4 * N * D

    def test_empty_finalize_rejected(self, tmp_path):
        w = slabcache.SlabCacheWriter(tmp_path / "w", d=D)
        with pytest.raises(slabcache.SlabCacheError, match="empty"):
            w.finalize()


class TestQuarantine:
    def test_truncated_slab_quarantined(self, tmp_path):
        x, _ = _study()
        slabcache.build_slab_cache(tmp_path / "c", x, slab_rows=SLAB)
        victim = tmp_path / "c" / "slab_00001.bin"
        victim.write_bytes(victim.read_bytes()[:100])
        obs.enable(trace=False, metrics=True)
        with pytest.raises(slabcache.SlabCacheError, match="truncated"):
            slabcache.SlabCache.open(tmp_path / "c")
        assert (tmp_path / "c" / "slab_00001.bin.corrupt").exists()
        assert not victim.exists()
        assert obs.metrics.value("slabcache.corrupt_quarantined") == 1

    def test_missing_meta_is_clear_error(self, tmp_path):
        with pytest.raises(slabcache.SlabCacheError, match="no slab cache"):
            slabcache.SlabCache.open(tmp_path / "nothing")

    def test_missing_slab_file(self, tmp_path):
        x, _ = _study()
        slabcache.build_slab_cache(tmp_path / "c", x, slab_rows=SLAB)
        os.remove(tmp_path / "c" / "slab_00002.bin")
        with pytest.raises(slabcache.SlabCacheError, match="missing"):
            slabcache.SlabCache.open(tmp_path / "c")

    def test_garbled_manifest_quarantined(self, tmp_path):
        x, _ = _study()
        slabcache.build_slab_cache(tmp_path / "c", x, slab_rows=SLAB)
        (tmp_path / "c" / slabcache.META_NAME).write_text("{not json")
        with pytest.raises(slabcache.SlabCacheError, match="unreadable"):
            slabcache.SlabCache.open(tmp_path / "c")
        assert (tmp_path / "c"
                / (slabcache.META_NAME + ".corrupt")).exists()


class TestSyntheticSparseCounts:
    def test_deterministic_and_slabwise(self, tmp_path):
        a, ga = microbiome.synthetic_sparse_counts(
            90, 16, density=0.2, seed=5, cache_dir=tmp_path / "a",
            slab_rows=32, n_groups=G)
        b, gb = microbiome.synthetic_sparse_counts(
            90, 16, density=0.2, seed=5, cache_dir=tmp_path / "b",
            slab_rows=32, n_groups=G)
        np.testing.assert_array_equal(a.to_array(), b.to_array())
        np.testing.assert_array_equal(ga, gb)
        c, _ = microbiome.synthetic_sparse_counts(
            90, 16, density=0.2, seed=6, cache_dir=tmp_path / "d",
            slab_rows=32, n_groups=G)
        assert not np.array_equal(a.to_array(), c.to_array())
        assert set(np.asarray(ga)[:G]) == set(range(G))


class TestPrefetcher:
    def test_full_iteration_accounting(self, tmp_path):
        x, _ = _study()
        cache = slabcache.build_slab_cache(tmp_path / "c", x,
                                           slab_rows=SLAB)
        sched = list(slabcache.ooc_schedule(cache.n_slabs))
        assert len(sched) == cache.n_slabs * (cache.n_slabs + 1)
        seen = []
        with slabcache.SlabPrefetcher(cache, sched) as pf:
            for idx, dev in pf:
                assert dev.shape == (SLAB, D)
                seen.append(idx)
        assert seen == sched
        assert pf.slabs_fetched == len(sched)
        assert pf.bytes_read == (cache.n_slabs + 1) * cache.disk_bytes
        assert pf.stall_s >= 0.0
        assert _no_prefetch_threads()

    def test_clean_shutdown_on_midsweep_exception(self, tmp_path):
        x, _ = _study()
        cache = slabcache.build_slab_cache(tmp_path / "c", x,
                                           slab_rows=SLAB)
        with pytest.raises(RuntimeError, match="sweep died"):
            with slabcache.SlabPrefetcher(
                    cache, list(range(cache.n_slabs)) * 4) as pf:
                next(pf)
                raise RuntimeError("sweep died")
        assert _no_prefetch_threads(), \
            "prefetch worker survived a mid-sweep exception"

    def test_worker_error_surfaces_to_consumer(self, tmp_path):
        x, _ = _study()
        cache = slabcache.build_slab_cache(tmp_path / "c", x,
                                           slab_rows=SLAB)
        os.remove(tmp_path / "c" / "slab_00001.bin")   # after validation
        with slabcache.SlabPrefetcher(cache, [0, 1, 2]) as pf:
            next(pf)
            with pytest.raises(slabcache.SlabCacheError,
                               match="prefetch failed"):
                for _ in pf:
                    pass
        assert _no_prefetch_threads()

    def test_pad_to_smaller_than_slab_rejected(self, tmp_path):
        x, _ = _study()
        cache = slabcache.build_slab_cache(tmp_path / "c", x,
                                           slab_rows=SLAB)
        with pytest.raises(ValueError, match="pad_to"):
            slabcache.SlabPrefetcher(cache, [0], pad_to=SLAB - 1)
        assert _no_prefetch_threads()


class TestResidencyPlanning:
    def test_tier_grading(self):
        kw = dict(device_budget_bytes=2**20, host_budget_bytes=2**30)
        assert preg.residency_tier(2**10, **kw) == "hbm"
        assert preg.residency_tier(2**25, **kw) == "host"
        assert preg.residency_tier(2**31, **kw) == "disk"

    def test_tier_bandwidth_env_override(self, monkeypatch):
        monkeypatch.setenv("REPRO_TIER_GBPS_DISK", "5.5")
        assert preg.tier_bandwidth_gbps("disk") == 5.5
        assert preg.tier_bandwidth_gbps("host") > \
            preg.tier_bandwidth_gbps("disk")

    def test_disk_traffic_model(self):
        # (n_slabs + 1) full passes: row operand once + column stream
        # once per row slab
        assert preg.ooc_disk_traffic_bytes(4, 1000) == 5000.0

    def test_ooc_plan_forces_slab_geometry(self):
        pl = pplanner.plan_pipeline(
            N, D, PERMS + 1, G, features_on_disk=True, slab_rows=SLAB,
            features_disk_bytes=4 * N * D, device_budget_bytes=1024)
        assert pl.residency == "host"
        assert pl.materialize == "fused-kernel"
        assert pl.row_block == SLAB
        assert preg.get_fused(pl.fused_impl).kind == "xla"
        text = pl.explain()
        assert "residency: host" in text
        assert "slab-cache traffic" in text
        assert "tier bandwidth model" in text

    def test_ooc_plan_disk_tier_and_pins_rejected(self):
        pl = pplanner.plan_pipeline(
            N, D, PERMS + 1, G, features_on_disk=True, slab_rows=SLAB,
            features_disk_bytes=4 * N * D, device_budget_bytes=1024,
            host_budget_bytes=2048)
        assert pl.residency == "disk"
        for bad in ("dense", "stream"):
            with pytest.raises(ValueError, match="resident"):
                pplanner.plan_pipeline(
                    N, D, PERMS + 1, G, features_on_disk=True,
                    slab_rows=SLAB, features_disk_bytes=4 * N * D,
                    device_budget_bytes=1024, materialize=bad)
        with pytest.raises(ValueError, match="XLA"):
            pplanner.plan_pipeline(
                N, D, PERMS + 1, G, features_on_disk=True,
                slab_rows=SLAB, features_disk_bytes=4 * N * D,
                device_budget_bytes=1024,
                fused_impl="braycurtis.fusedk.pallas")
        with pytest.raises(ValueError, match="f32"):
            pplanner.plan_pipeline(
                N, D, PERMS + 1, G, features_on_disk=True,
                slab_rows=SLAB, features_disk_bytes=4 * N * D,
                device_budget_bytes=1024,
                fused_tuning=preg.precision_tuning("fp8"))

    def test_plan_slab_rows_scales_with_budget(self):
        small = pplanner.plan_slab_rows(100_000, 4096,
                                        device_budget_bytes=2 * 2**30)
        large = pplanner.plan_slab_rows(100_000, 4096,
                                        device_budget_bytes=64 * 2**30)
        assert small < large
        assert small & (small - 1) == 0      # power of two


class TestOocPipeline:
    def test_bit_identity_all_metrics_both_forms(self, tmp_path):
        """The acceptance bar: OOC F/p == the in-memory fused bridge at
        row_block == slab_rows, bit for bit, for every metric, on both
        OOC materialize forms (chunked 'fused' and onepass
        'fused-kernel' — both accumulate f64 host-side in fused order)."""
        x, g = _study()
        key = jax.random.key(0)
        cache = slabcache.build_slab_cache(tmp_path / "c", x,
                                           slab_rows=SLAB)
        for metric in pipeline.metrics():
            ref = pipeline.pipeline(
                jnp.asarray(x), g, metric=metric, n_perms=PERMS,
                materialize="fused", row_block=SLAB, key=key)
            for mat in ("fused", "fused-kernel"):
                res = pipeline.pipeline(
                    cache, g, metric=metric, n_perms=PERMS,
                    materialize=mat, device_budget_bytes=1024, key=key)
                assert f"ooc-{mat}" in res.method, res.method
                np.testing.assert_array_equal(
                    np.asarray(res.f_perms), np.asarray(ref.f_perms),
                    err_msg=f"{metric}/{mat}")
                assert float(res.f_stat) == float(ref.f_stat)
                assert float(res.p_value) == float(ref.p_value)
                assert float(res.s_t) == float(ref.s_t)

    def test_bit_identity_odd_slab(self, tmp_path):
        x, g = _study(7)
        key = jax.random.key(3)
        cache = slabcache.build_slab_cache(tmp_path / "c", x,
                                           slab_rows=23)
        ref = pipeline.pipeline(jnp.asarray(x), g, n_perms=PERMS,
                                materialize="fused", row_block=23,
                                key=key)
        res = pipeline.pipeline(cache, g, n_perms=PERMS,
                                materialize="fused",
                                device_budget_bytes=1024, key=key)
        np.testing.assert_array_equal(np.asarray(res.f_perms),
                                      np.asarray(ref.f_perms))

    def test_csr_jaccard_and_metric_guard(self, tmp_path):
        x, g = _study(8)
        key = jax.random.key(1)
        cache = slabcache.build_slab_cache(tmp_path / "c", x,
                                           slab_rows=SLAB, fmt="csr")
        presence = (x > 0).astype(np.float32)
        ref = pipeline.pipeline(jnp.asarray(presence), g,
                                metric="jaccard", n_perms=PERMS,
                                materialize="fused", row_block=SLAB,
                                key=key)
        res = pipeline.pipeline(cache, g, metric="jaccard",
                                n_perms=PERMS, materialize="fused",
                                device_budget_bytes=1024, key=key)
        np.testing.assert_array_equal(np.asarray(res.f_perms),
                                      np.asarray(ref.f_perms))
        with pytest.raises(ValueError, match="presence"):
            pipeline.pipeline(cache, g, metric="braycurtis",
                              n_perms=PERMS, device_budget_bytes=1024,
                              key=key)

    def test_directory_path_input(self, tmp_path):
        x, g = _study(9)
        key = jax.random.key(2)
        slabcache.build_slab_cache(tmp_path / "c", x, slab_rows=SLAB)
        res = pipeline.pipeline(str(tmp_path / "c"), g, n_perms=PERMS,
                                device_budget_bytes=1024, key=key)
        ref = pipeline.pipeline(jnp.asarray(x), g, n_perms=PERMS,
                                materialize="fused", row_block=SLAB,
                                key=key)
        np.testing.assert_array_equal(np.asarray(res.f_perms),
                                      np.asarray(ref.f_perms))

    def test_hbm_residency_short_circuit(self, tmp_path):
        x, g = _study(10)
        key = jax.random.key(4)
        cache = slabcache.build_slab_cache(tmp_path / "c", x,
                                           slab_rows=SLAB)
        res = pipeline.pipeline(cache, g, n_perms=PERMS, key=key)
        assert "residency=hbm" in res.plan
        ref = pipeline.pipeline(jnp.asarray(x), g, n_perms=PERMS,
                                key=key)
        np.testing.assert_array_equal(np.asarray(res.f_perms),
                                      np.asarray(ref.f_perms))

    def test_design_terms_bit_identical(self, tmp_path):
        x, g = _study(11)
        rng = np.random.default_rng(11)
        cov = rng.normal(size=(N, 2))
        st = (np.arange(N) % 4).astype(np.int32)
        key = jax.random.key(5)
        cache = slabcache.build_slab_cache(tmp_path / "c", x,
                                           slab_rows=SLAB)
        ref = pipeline.pipeline(jnp.asarray(x), g, n_perms=PERMS,
                                covariates=cov, strata=st, n_groups=G,
                                materialize="fused", row_block=SLAB,
                                key=key)
        res = pipeline.pipeline(cache, g, n_perms=PERMS,
                                covariates=cov, strata=st, n_groups=G,
                                materialize="fused",
                                device_budget_bytes=1024, key=key)
        assert len(res.terms) == len(ref.terms)
        for t_ooc, t_ref in zip(res.terms, ref.terms):
            assert t_ooc.name == t_ref.name
            np.testing.assert_array_equal(np.asarray(t_ooc.f_perms),
                                          np.asarray(t_ref.f_perms))
            assert float(t_ooc.p_value) == float(t_ref.p_value)

    def test_ordination_and_autotune_guards(self, tmp_path):
        x, g = _study(12)
        cache = slabcache.build_slab_cache(tmp_path / "c", x,
                                           slab_rows=SLAB)
        with pytest.raises(ValueError, match="resident"):
            pipeline.pipeline(cache, g, n_perms=PERMS, ordination=2,
                              device_budget_bytes=1024,
                              key=jax.random.key(0))
        with pytest.warns(UserWarning, match="autotune"):
            pipeline.pipeline(cache, g, n_perms=PERMS, autotune=True,
                              device_budget_bytes=1024,
                              key=jax.random.key(0))

    def test_trace_and_counters(self, tmp_path):
        """The trace artifact carries the overlap evidence: a bridge.ooc
        span with measured stall_ms + the predicted disk traffic, and
        the prefetch counters account for every scheduled slab."""
        x, g = _study(13)
        cache = slabcache.build_slab_cache(tmp_path / "c", x,
                                           slab_rows=SLAB)
        obs.enable(trace=False, metrics=True)
        out = tmp_path / "trace.json"
        pipeline.pipeline(cache, g, n_perms=PERMS,
                          device_budget_bytes=1024,
                          key=jax.random.key(0), trace=str(out))
        doc = json.loads(out.read_text())
        spans = {e["name"]: e for e in doc["traceEvents"]}
        assert {"bridge.ooc", "prefetch.fetch",
                "prefetch.wait"} <= set(spans)
        args = spans["bridge.ooc"]["args"]
        assert args["stall_ms"] >= 0.0
        assert args["predicted_bytes"] == preg.ooc_disk_traffic_bytes(
            cache.n_slabs, cache.disk_bytes)
        assert args["disk_bytes_read"] == \
            (cache.n_slabs + 1) * cache.disk_bytes
        n_sched = cache.n_slabs * (cache.n_slabs + 1)
        assert obs.metrics.value("prefetch.slabs") == n_sched
        assert obs.metrics.value("prefetch.stall_ms") >= 0.0


class TestSloBudgets:
    def test_violation_detection(self):
        obs.enable(trace=True, metrics=False)
        with obs.span("stage1.braycurtis"):
            time.sleep(0.01)
        viol = obs.budget_violations({"stage1.*": 0.0})
        assert len(viol) == 1
        assert viol[0]["pattern"] == "stage1.*"
        assert viol[0]["measured_s"] >= 0.01
        assert viol[0]["stages"] == ["stage1.braycurtis"]
        assert obs.budget_violations({"stage1.*": 60.0}) == []
        # a pattern matching no spans is "not run", never a violation
        assert obs.budget_violations({"fusedk.*": 0.0}) == []

    def test_report_renders_budget_section(self):
        obs.enable(trace=True, metrics=False)
        with obs.span("stage1.braycurtis"):
            time.sleep(0.005)
        text = obs.report(budgets={"stage1.*": 0.0, "fusedk.*": 1.0},
                          file=None)
        assert "wall-clock SLO budgets" in text
        assert "[OVER]" in text
        assert "[not run]" in text
