"""Design-matrix subsystem: partial/covariate PERMANOVA, strata-restricted
permutations, weighted designs.

Contracts under test:
  * the plain single-factor path routed through Design.from_labels is
    BIT-identical to the raw-label path across impls (and compiles to the
    same HLO — the tentpole's fast-path regression),
  * per-term partial F matches a dense fp64 explicit-projection oracle on
    all four metrics, for every impl and materialization bridge,
  * strata-restricted permutations preserve within-stratum multisets
    (hypothesis, ragged/prime shapes) and ride global-index key folding,
  * per-term F is invariant under covariate rescaling, and the adjusted
    factor term under covariate reordering,
  * ragged/padded permanova_many observed per-term F bit-matches the
    unpadded study; stacked == loop of singles,
  * bf16 feature slabs in the fused megakernel stay within tolerance.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import engine, pipeline
from repro.core import design as dsg
from repro.core import fstat, permutations
from repro.core.distance import distance_matrix
from repro.engine import registry, scheduler

G = 4
METRICS = ("braycurtis", "euclidean", "jaccard", "aitchison")


def _study(n, d=12, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.gamma(1.0, 1.0, size=(n, d)).astype(np.float32)
    x[rng.random((n, d)) < 0.4] = 0.0   # sparsity: jaccard stays nontrivial
    labels = rng.integers(0, G, size=n).astype(np.int32)
    labels[:G] = np.arange(G)
    cov = rng.normal(size=(n, 2))
    strata = (np.arange(n) % 3).astype(np.int32)
    return x, labels, cov, strata


def _sym_dm(n, seed):
    rng = np.random.default_rng(seed)
    d = rng.random((n, n)).astype(np.float32)
    d = (d + d.T) / 2
    np.fill_diagonal(d, 0.0)
    return d


def oracle_term_f_fp64(dm, labels, cov, *, n_groups=G, weights=None):
    """Explicit sequential-projection oracle (fp64 hat matrices, pinv):
    residual SS of cumulative model t is 0.5 * tr(H_t W^1/2 mat2 W^1/2);
    term SS are the telescoped differences. Independent of the production
    basis/QR code on purpose."""
    n = dm.shape[0]
    m2 = np.asarray(dm, np.float64) ** 2
    w = np.ones(n) if weights is None else np.asarray(weights, np.float64)
    sw = np.sqrt(w)
    mt = sw[:, None] * m2 * sw[None, :]
    one = np.ones((n, 1))
    onehot = np.zeros((n, n_groups))
    onehot[np.arange(n), labels] = 1.0
    blocks = [one] + [np.asarray(cov)[:, j:j + 1]
                      for j in range(np.asarray(cov).shape[1])] + [onehot]
    resid, dfs = [], []
    rank_prev = 0
    for t in range(1, len(blocks) + 1):
        xt = sw[:, None] * np.concatenate(blocks[:t], axis=1)
        hat = xt @ np.linalg.pinv(xt)
        resid.append(0.5 * np.sum(hat * mt))
        rank = np.linalg.matrix_rank(xt)
        dfs.append(rank - rank_prev)
        rank_prev = rank
    ss = [resid[t] - resid[t + 1] for t in range(len(resid) - 1)]
    dof_resid = n - rank_prev
    denom = resid[-1] / dof_resid
    return [s / max(df, 1) / denom for s, df in zip(ss, dfs[1:])]


class TestDesignBuild:
    def test_single_factor_is_labels_mode(self):
        _, labels, _, _ = _study(20, seed=1)
        d = dsg.build(grouping=labels, n_groups=G)
        assert d.mode == dsg.MODE_LABELS and d.is_plain_labels
        assert [t.df for t in d.terms] == [1, G - 1]
        assert d.dof_resid == 20 - G
        ops = d.operands
        assert ops.mode == dsg.MODE_LABELS
        assert np.array_equal(np.asarray(ops.grouping), labels)

    def test_covariates_force_dense_orthonormal_basis(self):
        _, labels, cov, _ = _study(23, seed=2)
        d = dsg.build(grouping=labels, covariates=cov, n_groups=G)
        assert d.mode == dsg.MODE_DENSE
        assert [t.df for t in d.terms] == [1, 1, 1, G - 1]
        b = d.basis64
        np.testing.assert_allclose(b.T @ b, np.eye(d.rank), atol=1e-9)
        assert d.dof_resid == 23 - d.rank

    def test_collinear_covariate_gets_df_zero(self):
        _, labels, cov, _ = _study(21, seed=3)
        cov2 = {"a": cov[:, 0], "a_scaled": 3.0 * cov[:, 0]}
        d = dsg.build(grouping=labels, covariates=cov2, n_groups=G)
        assert [t.df for t in d.terms] == [1, 1, 0, G - 1]

    def test_weights_validated(self):
        _, labels, _, _ = _study(16, seed=4)
        with pytest.raises(ValueError, match="non-negative"):
            dsg.build(grouping=labels, covariates=None, n_groups=G,
                      weights=-np.ones(16))
        with pytest.raises(ValueError, match="weights must be"):
            dsg.build(grouping=labels, n_groups=G, weights=np.ones(7))

    def test_saturated_design_rejected(self):
        labels = np.arange(5).astype(np.int32)
        with pytest.raises(ValueError, match="saturated"):
            dsg.build(grouping=labels, n_groups=5,
                      weights=np.ones(5))

    def test_uniform_weights_reduce_to_unweighted(self):
        dm = _sym_dm(19, seed=5)
        _, labels, _, _ = _study(19, seed=5)
        r_plain = engine.run(jnp.asarray(dm), jnp.asarray(labels),
                             n_perms=0, n_groups=G)
        d = dsg.build(grouping=labels, n_groups=G, weights=np.ones(19))
        r_w = engine.run_design(jnp.asarray(dm), d, n_perms=0)
        np.testing.assert_allclose(float(r_w.f_stat), float(r_plain.f_stat),
                                   rtol=1e-5)
        np.testing.assert_allclose(float(r_w.s_t), float(r_plain.s_t),
                                   rtol=1e-5)


class TestPlainFastPathRegression:
    """The compat shim: single-factor label-array call sites route through
    Design.from_labels with ZERO behavior change."""

    @pytest.mark.parametrize("impl", ["brute", "tiled", "matmul",
                                      "pallas_matmul"])
    def test_design_route_bit_identical(self, impl):
        dm = _sym_dm(18, seed=6)
        _, labels, _, _ = _study(18, seed=6)
        kw = dict(n_perms=19, key=jax.random.key(2), impl=impl)
        raw = engine.run(jnp.asarray(dm), jnp.asarray(labels),
                         n_groups=G, **kw)
        via = engine.run(jnp.asarray(dm),
                         dsg.Design.from_labels(labels, n_groups=G), **kw)
        assert np.array_equal(np.asarray(raw.f_perms),
                              np.asarray(via.f_perms))
        assert raw.method == via.method and raw.plan == via.plan
        assert via.terms is None       # exactly today's output contract

    @pytest.mark.parametrize("mat", ["dense", "stream", "fused",
                                     "fused-kernel"])
    def test_plain_design_through_bridges_bit_identical(self, mat):
        x, labels, _, _ = _study(22, d=8, seed=9)
        kw = dict(metric="braycurtis", n_perms=9, key=jax.random.key(5),
                  materialize=mat)
        raw = pipeline.pipeline(jnp.asarray(x), labels, n_groups=G, **kw)
        via = pipeline.pipeline(jnp.asarray(x),
                                dsg.Design.from_labels(labels, n_groups=G),
                                **kw)
        assert np.array_equal(np.asarray(raw.f_perms),
                              np.asarray(via.f_perms)), mat
        assert raw.method == via.method and via.terms is None

    def test_fast_path_compiles_to_same_hlo(self):
        """The single-factor fast path must compile to the SAME HLO shape
        as the pre-design repo: the scheduler step lowered with operands
        arriving through Design.from_labels is textually identical to the
        raw-label lowering (no basis gathers, no strata argsorts)."""
        dm = _sym_dm(16, seed=7)
        _, labels, _, _ = _study(16, seed=7)
        mat2 = jnp.asarray(dm * dm)
        raw_g = jnp.asarray(labels, jnp.int32)
        design = dsg.Design.from_labels(labels, n_groups=G)
        inv = permutations.inv_group_sizes(raw_g, G)
        fn = registry.get("matmul").bound()
        key = jax.random.key(0)

        def lower(g):
            return scheduler._step.lower(
                mat2, g, inv, key, jnp.int32(0), fn=fn, chunk=8,
                identity_first=True).as_text()

        txt = lower(design.operands.grouping)
        assert txt == lower(raw_g)
        # no float-basis gathers in the fast path — the (chunk, n, K)
        # dense operand is a design-mode-only construct
        assert "gather" not in txt or "f32[8,16," not in txt
        # the strata generator's argsorts must NOT leak into the plain
        # program — it lowers sort ops the label path never uses
        strata_txt = jax.jit(
            permutations.strata_permutation_batch_dyn,
            static_argnames=("chunk", "identity_first")).lower(
            key, jnp.zeros((16,), jnp.int32), jnp.int32(0),
            chunk=8).as_text()
        assert strata_txt.count("sort") > txt.count("sort")


class TestStrataPermutations:
    def test_within_stratum_multiset_invariance(self):
        hypothesis = pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=25, deadline=None)
        @given(n=st.sampled_from([7, 11, 13, 17, 23, 29]),
               n_strata=st.integers(1, 4), seed=st.integers(0, 10))
        def check(n, n_strata, seed):
            rng = np.random.default_rng(seed)
            strata = jnp.asarray(
                rng.integers(0, n_strata, n).astype(np.int32))
            perms = np.asarray(permutations.strata_permutation_batch(
                jax.random.key(seed), strata, 0, 6))
            sarr = np.asarray(strata)
            assert (perms[0] == np.arange(n)).all()    # identity first
            for p in perms:
                assert sorted(p) == list(range(n))     # a permutation
                assert (sarr[p] == sarr).all()         # strata preserved

        check()

    def test_global_index_key_folding_shard_independent(self):
        strata = jnp.asarray((np.arange(19) % 3).astype(np.int32))
        key = jax.random.key(9)
        full = np.asarray(permutations.strata_permutation_batch(
            key, strata, 0, 12))
        shard = np.asarray(permutations.strata_permutation_batch(
            key, strata, 5, 12))
        np.testing.assert_array_equal(full[5:], shard[:7])

    def test_masked_strata_keeps_pads_in_place(self):
        strata = jnp.asarray((np.arange(15) % 2).astype(np.int32))
        eff = permutations.masked_strata(strata, jnp.int32(11))
        perms = np.asarray(permutations.strata_permutation_batch(
            jax.random.key(1), eff, 0, 8))
        for p in perms:
            assert set(p[11:]) == set(range(11, 15))   # pads stay pads

    def test_masked_strata_sentinel_cannot_collide_with_user_labels(self):
        """Strata labels are arbitrary ints — a block labeled n (the old
        fixed sentinel) must NOT merge with the pad stratum, or valid
        samples would permute onto zero-basis pad slots."""
        n, nv = 15, 11
        strata = jnp.full((n,), n, jnp.int32)      # one block, labeled n
        eff = permutations.masked_strata(strata, jnp.int32(nv))
        perms = np.asarray(permutations.strata_permutation_batch(
            jax.random.key(2), eff, 0, 16))
        for p in perms:
            assert set(p[nv:]) == set(range(nv, n))       # pads stay pads
            assert set(p[:nv]) == set(range(nv))          # valid stay valid

    def test_observed_f_unchanged_p_value_differs_from_free(self):
        dm = _sym_dm(27, seed=8)
        _, labels, _, strata = _study(27, seed=8)
        free = engine.run(jnp.asarray(dm), jnp.asarray(labels),
                          n_perms=99, n_groups=G, key=jax.random.key(3))
        from repro.core.permanova import permanova
        res = permanova(jnp.asarray(dm), labels, n_perms=99, n_groups=G,
                        key=jax.random.key(3), strata=strata)
        assert "strata" in res.method
        np.testing.assert_allclose(float(res.f_stat), float(free.f_stat),
                                   rtol=1e-5)
        assert res.terms is not None and res.terms[0].df == G - 1
        # the restricted null is a different draw stream
        assert not np.array_equal(np.asarray(res.f_perms[1:]),
                                  np.asarray(free.f_perms[1:]))


class TestPartialFOracle:
    @pytest.mark.parametrize("metric", METRICS)
    def test_engine_matches_fp64_projection_oracle(self, metric):
        x, labels, cov, _ = _study(26, seed=10)
        dm = np.asarray(distance_matrix(jnp.asarray(x), metric))
        res = engine.run(jnp.asarray(dm), jnp.asarray(labels),
                         n_perms=5, n_groups=G, covariates=cov,
                         key=jax.random.key(0))
        want = oracle_term_f_fp64(dm, labels, cov)
        got = [float(t.f_stat) for t in res.terms]
        np.testing.assert_allclose(got, want, rtol=5e-4, atol=1e-5)

    def test_row_sharded_cols_partials_reconstruct_full(self):
        """fstat.sw_cols_rows_partial: summing disjoint row-block partials
        reconstructs the full per-column statistic (the shard_map building
        block for matrix-resident dense-design sharding)."""
        dm = _sym_dm(20, seed=18)
        _, labels, cov, _ = _study(20, seed=18)
        des = dsg.build(grouping=labels, covariates=cov, n_groups=G)
        mat2 = jnp.asarray(dm * dm)
        perms = permutations.strata_permutation_batch(
            jax.random.key(6), jnp.zeros((20,), jnp.int32), 0, 5)
        v = fstat.basis_perm_factors(jnp.asarray(des.basis), perms)
        full = np.asarray(fstat.sw_cols_matmul(mat2, v))
        acc = np.zeros_like(full)
        for lo in (0, 8, 16):
            hi = min(lo + 8, 20)
            acc += np.asarray(fstat.sw_cols_rows_partial(
                mat2[lo:hi], jnp.int32(lo), v))
        np.testing.assert_allclose(acc, full, rtol=1e-5, atol=1e-6)

    @pytest.mark.parametrize("impl", ["matmul", "brute"])
    def test_cols_impls_agree(self, impl):
        dm = _sym_dm(22, seed=11)
        _, labels, cov, _ = _study(22, seed=11)
        res = engine.run(jnp.asarray(dm), jnp.asarray(labels), n_perms=9,
                         n_groups=G, covariates=cov, impl=impl,
                         key=jax.random.key(1))
        assert impl in res.method
        want = oracle_term_f_fp64(dm, labels, cov)
        got = [float(t.f_stat) for t in res.terms]
        np.testing.assert_allclose(got, want, rtol=5e-4, atol=1e-5)

    @pytest.mark.parametrize("metric", METRICS)
    def test_bridges_match_oracle(self, metric):
        x, labels, cov, strata = _study(24, d=10, seed=12)
        dm = np.asarray(distance_matrix(jnp.asarray(x), metric))
        want = oracle_term_f_fp64(dm, labels, cov)
        for mat in ("dense", "stream", "fused", "fused-kernel"):
            res = pipeline.pipeline(
                jnp.asarray(x), labels, metric=metric, n_perms=5,
                materialize=mat, covariates=cov, n_groups=G,
                key=jax.random.key(0))
            got = [float(t.f_stat) for t in res.terms]
            np.testing.assert_allclose(got, want, rtol=2e-3, atol=1e-4,
                                       err_msg=f"{metric}/{mat}")

    def test_pallas_megakernel_dense_variant_matches_oracle(self):
        x, labels, cov, _ = _study(24, d=8, seed=13)
        dm = np.asarray(distance_matrix(jnp.asarray(x), "braycurtis"))
        want = oracle_term_f_fp64(dm, labels, cov)
        res = pipeline.pipeline(
            jnp.asarray(x), labels, metric="braycurtis", n_perms=3,
            materialize="fused-kernel", fused_impl="pallas",
            fused_tuning={"tile_r": 8, "tile_c": 8, "feat_block": 8,
                          "perm_block": 2},
            covariates=cov, n_groups=G, key=jax.random.key(0))
        assert "pallas" in res.method
        got = [float(t.f_stat) for t in res.terms]
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=1e-4)

    def test_weighted_matches_weighted_oracle(self):
        x, labels, cov, _ = _study(21, seed=14)
        dm = _sym_dm(21, seed=14)
        w = np.random.default_rng(14).gamma(4.0, 0.25, size=21)
        res = engine.run(jnp.asarray(dm), jnp.asarray(labels), n_perms=5,
                         n_groups=G, covariates=cov, weights=w,
                         key=jax.random.key(0))
        want = oracle_term_f_fp64(dm, labels, cov, weights=w)
        got = [float(t.f_stat) for t in res.terms]
        np.testing.assert_allclose(got, want, rtol=5e-4, atol=1e-5)


class TestCovariateInvariance:
    def test_rescaling_leaves_per_term_f_unchanged(self):
        hypothesis = pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st
        dm = _sym_dm(20, seed=15)
        _, labels, cov, _ = _study(20, seed=15)
        base = engine.run(jnp.asarray(dm), jnp.asarray(labels), n_perms=0,
                          n_groups=G, covariates=cov)
        base_f = [float(t.f_stat) for t in base.terms]

        @settings(max_examples=10, deadline=None)
        @given(s0=st.floats(0.01, 100.0), s1=st.floats(0.01, 100.0),
               shift=st.floats(-5.0, 5.0))
        def check(s0, s1, shift):
            cov2 = np.stack([cov[:, 0] * s0 + shift, cov[:, 1] * s1],
                            axis=1)
            res = engine.run(jnp.asarray(dm), jnp.asarray(labels),
                             n_perms=0, n_groups=G, covariates=cov2)
            got = [float(t.f_stat) for t in res.terms]
            np.testing.assert_allclose(got, base_f, rtol=1e-3, atol=1e-5)

        check()

    def test_reordering_covariates_keeps_adjusted_factor_f(self):
        dm = _sym_dm(25, seed=16)
        _, labels, cov, _ = _study(25, seed=16)
        a = engine.run(jnp.asarray(dm), jnp.asarray(labels), n_perms=0,
                       n_groups=G,
                       covariates={"u": cov[:, 0], "v": cov[:, 1]})
        b = engine.run(jnp.asarray(dm), jnp.asarray(labels), n_perms=0,
                       n_groups=G,
                       covariates={"v": cov[:, 1], "u": cov[:, 0]})
        # the factor term is adjusted for BOTH covariates either way, and
        # the full-model residual is order-free
        np.testing.assert_allclose(float(a.terms[-1].f_stat),
                                   float(b.terms[-1].f_stat), rtol=1e-4)
        np.testing.assert_allclose(float(a.s_w), float(b.s_w), rtol=1e-5)


class TestManyDesign:
    def _mk(self, n, seed):
        rng = np.random.default_rng(seed)
        d = _sym_dm(n, seed)
        g = rng.integers(0, G, n).astype(np.int32)
        g[:G] = np.arange(G)
        cov = rng.normal(size=(n, 2))
        st = (np.arange(n) % 3).astype(np.int32)
        return d, g, cov, st

    def test_stacked_matches_single_runs(self):
        key = jax.random.key(21)
        studies = [self._mk(20, 30 + s) for s in range(3)]
        many = engine.permanova_many(
            np.stack([s[0] for s in studies]),
            np.stack([s[1] for s in studies]), n_groups=G, n_perms=29,
            key=key, covariates=np.stack([s[2] for s in studies]),
            strata=np.stack([s[3] for s in studies]))
        assert [t.name for t in many.terms] == ["cov0", "cov1", "grouping"]
        for s, (d, g, cov, stv) in enumerate(studies):
            des = dsg.build(grouping=g, covariates=cov, strata=stv,
                            n_groups=G, force_dense=True)
            single = engine.run_design(jnp.asarray(d), des, n_perms=29,
                                       key=jax.random.fold_in(key, s))
            np.testing.assert_allclose(
                [float(t.f_stat[s]) for t in many.terms],
                [float(t.f_stat) for t in single.terms], rtol=1e-4)
            assert ([float(t.p_value[s]) for t in many.terms]
                    == [float(t.p_value) for t in single.terms]), s

    def test_ragged_observed_per_term_f_bit_matches_unpadded(self):
        """The acceptance criterion: padded sentinel rows carry ZERO
        design rows, so every padded contraction term adds exactly +0.0 —
        the observed per-term F is bit-identical to the unpadded study."""
        key = jax.random.key(22)
        sizes = (14, 23, 17)
        studies = [self._mk(m, 40 + i) for i, m in enumerate(sizes)]
        many = engine.permanova_many(
            [s[0] for s in studies], [s[1] for s in studies], n_groups=G,
            n_perms=9, key=key, covariates=[s[2] for s in studies],
            strata=[s[3] for s in studies])
        for s, (d, g, cov, stv) in enumerate(studies):
            solo = engine.permanova_many(
                [d], [g], n_groups=G, n_perms=9, key=key,
                covariates=[cov], strata=[stv])
            assert ([float(t.f_stat[s]) for t in many.terms]
                    == [float(t.f_stat[0]) for t in solo.terms]), s
            assert many.study(s).n_objects == sizes[s]

    def test_mismatched_design_structure_rejected(self):
        d1, g1, c1, _ = self._mk(15, 50)
        d2, g2, c2, _ = self._mk(15, 51)
        c2 = np.stack([c2[:, 0], 2.0 * c2[:, 0]], axis=1)  # collinear
        with pytest.raises(ValueError, match="different design"):
            engine.permanova_many([d1, d2], [g1, g2], n_groups=G,
                                  n_perms=5, covariates=[c1, c2])

    def test_pipeline_many_fused_design_matches_dense(self):
        rng = np.random.default_rng(23)
        S, n, d = 3, 24, 8
        xs = rng.gamma(1.0, 1.0, size=(S, n, d)).astype(np.float32)
        gs = rng.integers(0, G, size=(S, n)).astype(np.int32)
        gs[:, :G] = np.arange(G)
        covs = rng.normal(size=(S, n, 2))
        key = jax.random.key(4)
        kw = dict(n_groups=G, metric="braycurtis", n_perms=19, key=key,
                  covariates=covs)
        mf = pipeline.pipeline_many(xs, gs, materialize="fused-kernel",
                                    **kw)
        md = pipeline.pipeline_many(xs, gs, materialize="dense", **kw)
        for tf, td in zip(mf.terms, md.terms):
            np.testing.assert_allclose(np.asarray(tf.f_stat),
                                       np.asarray(td.f_stat), rtol=1e-3)
            np.testing.assert_array_equal(np.asarray(tf.p_value),
                                          np.asarray(td.p_value))


class TestBf16FeatureSlabs:
    def test_megakernel_bf16_parity(self):
        from repro.kernels.fused_sw import ops as fops
        x, labels, _, _ = _study(30, d=16, seed=17)
        xp = jnp.asarray(x)
        inv = permutations.inv_group_sizes(jnp.asarray(labels), G)
        gperms = permutations.permutation_batch(
            jax.random.key(2), jnp.asarray(labels), 0, 6)
        kw = dict(metric="euclidean", tile_r=16, tile_c=16, feat_block=8,
                  perm_block=2)
        sw32, rs32 = fops.fused_sw_rows(xp, xp, gperms, gperms, inv, 0,
                                        **kw)
        sw16, rs16 = fops.fused_sw_rows(xp, xp, gperms, gperms, inv, 0,
                                        feat_bf16=1, **kw)
        np.testing.assert_allclose(np.asarray(sw16), np.asarray(sw32),
                                   rtol=2e-2)
        np.testing.assert_allclose(np.asarray(rs16), np.asarray(rs32),
                                   rtol=2e-2)

    def test_planner_toggle_flows_into_fused_tuning(self):
        pl = pipeline.plan_pipeline(
            512, 64, 100, G, metric="euclidean",
            materialize="fused-kernel", fused_impl="pallas",
            fused_tuning={"feat_bf16": 1})
        assert pl.fused_tuning["feat_bf16"] == 1
        # default off
        pl0 = pipeline.plan_pipeline(
            512, 64, 100, G, metric="euclidean",
            materialize="fused-kernel", fused_impl="pallas")
        assert pl0.fused_tuning["feat_bf16"] == 0
