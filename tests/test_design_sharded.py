"""Sharded multi-study DESIGN path: bit-equality between the forced
8-device CPU mesh and the single-host vmap for covariate + strata +
weighted designs (stacked, non-divisible, and ragged study lists) — the
acceptance criterion `sharded == single-host bit-identical with strata=`.
"""

import pytest

MULTI_DEVICE_DESIGN = r"""
import jax, jax.numpy as jnp
import numpy as np
from repro import engine, pipeline
from repro.launch.mesh import make_mesh

G = 4
def mk(n, seed):
    rng = np.random.default_rng(seed)
    d = rng.random((n, n)).astype(np.float32)
    d = (d + d.T) / 2
    np.fill_diagonal(d, 0.0)
    g = rng.integers(0, G, size=n).astype(np.int32)
    g[:G] = np.arange(G)
    cov = rng.normal(size=(n, 2))
    st = rng.integers(0, 3, size=n).astype(np.int32)
    st[:3] = np.arange(3)
    w = rng.gamma(4.0, 0.25, size=n)
    return d, g, cov, st, w

assert len(jax.devices()) == 8, jax.devices()
key = jax.random.key(23)

def assert_many_equal(got, ref, tag):
    assert np.array_equal(np.asarray(got.f_perms), np.asarray(ref.f_perms)), tag
    assert np.array_equal(np.asarray(got.p_value), np.asarray(ref.p_value)), tag
    assert np.array_equal(np.asarray(got.s_t), np.asarray(ref.s_t)), tag
    for tg, tr in zip(got.terms, ref.terms):
        assert np.array_equal(np.asarray(tg.f_perms), np.asarray(tr.f_perms)), (tag, tg.name)
        assert np.array_equal(np.asarray(tg.p_value), np.asarray(tr.p_value)), (tag, tg.name)

# --- stacked S=6 (divisible by 2, padded on 4/8), covariates + strata ---
S = 6
studies = [mk(21, seed=s) for s in range(S)]
dms = np.stack([s[0] for s in studies]); grps = np.stack([s[1] for s in studies])
covs = np.stack([s[2] for s in studies]); sts = np.stack([s[3] for s in studies])
ws = np.stack([s[4] for s in studies])
kw = dict(n_groups=G, n_perms=49, key=key, covariates=covs, strata=sts, weights=ws)
ref = engine.permanova_many(dms, grps, **kw)
for shape in ((2, 4), (4, 2), (8, 1)):
    mesh = make_mesh(shape, ("data", "model"))
    got = engine.permanova_many(dms, grps, mesh=mesh, **kw)
    assert f"data[{shape[0]}]" in got.plan, got.plan
    assert_many_equal(got, ref, shape)
print("OK stacked")

# --- ragged list (5 studies: not divisible by 2 or 8) ---
sizes = (14, 23, 17, 21, 9)
rag = [mk(m, seed=70 + i) for i, m in enumerate(sizes)]
kwr = dict(n_groups=G, n_perms=49, key=key,
           covariates=[s[2] for s in rag], strata=[s[3] for s in rag])
refr = engine.permanova_many([s[0] for s in rag], [s[1] for s in rag], **kwr)
for shape in ((8, 1), (2, 4)):
    mesh = make_mesh(shape, ("data", "model"))
    gotr = engine.permanova_many([s[0] for s in rag], [s[1] for s in rag],
                                 mesh=mesh, **kwr)
    assert_many_equal(gotr, refr, shape)
print("OK ragged")

# --- pipeline_many fused-kernel design sweep over 'data' ---
rng = np.random.default_rng(99)
S2, n2, d2 = 4, 24, 8
xs = rng.gamma(1.0, 1.0, size=(S2, n2, d2)).astype(np.float32)
gs = rng.integers(0, G, size=(S2, n2)).astype(np.int32); gs[:, :G] = np.arange(G)
cv = rng.normal(size=(S2, n2, 2))
stv = np.tile((np.arange(n2) % 3).astype(np.int32), (S2, 1))
kwp = dict(n_groups=G, metric="braycurtis", n_perms=29, key=key,
           covariates=cv, strata=stv, materialize="fused-kernel")
refp = pipeline.pipeline_many(xs, gs, **kwp)
mesh = make_mesh((4, 2), ("data", "model"))
gotp = pipeline.pipeline_many(xs, gs, mesh=mesh, **kwp)
assert "data[4]" in gotp.plan, gotp.plan
assert_many_equal(gotp, refp, "pipeline_many")
print("OK pipeline_many")
"""


@pytest.mark.multidevice
def test_sharded_design_many_matches_single_host():
    """Per-term F/p bit-equality between the 8-device 'data'-sharded
    design program and the single-host vmap, with strata-restricted
    permutations and weighted designs, stacked and ragged."""
    from conftest import run_subprocess
    out = run_subprocess(MULTI_DEVICE_DESIGN, devices=8, timeout=900)
    assert "OK stacked" in out
    assert "OK ragged" in out
    assert "OK pipeline_many" in out
