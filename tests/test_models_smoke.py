"""Per-architecture smoke tests (deliverable f): reduced config of the same
family, one forward/train step on CPU, output shapes + no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import SMOKES, ARCHS, list_archs
from repro.models.model import build_model
from repro.optim import adamw
from repro.train.step import make_train_step, make_train_state_init

B, S = 2, 32


def make_batch(cfg, rng):
    if cfg.family == "encdec":
        return {
            "frames": jnp.asarray(rng.normal(
                size=(B, S, cfg.d_model)).astype(np.float32)),
            "tokens": jnp.asarray(rng.integers(
                0, cfg.vocab, size=(B, S)).astype(np.int32)),
            "targets": jnp.asarray(rng.integers(
                0, cfg.vocab, size=(B, S)).astype(np.int32)),
        }
    if cfg.family == "vlm":
        nv = cfg.n_vision_tokens
        return {
            "vision_embeds": jnp.asarray(rng.normal(
                size=(B, nv, cfg.d_model)).astype(np.float32)),
            "tokens": jnp.asarray(rng.integers(
                0, cfg.vocab, size=(B, S - nv)).astype(np.int32)),
            "targets": jnp.asarray(rng.integers(
                0, cfg.vocab, size=(B, S - nv)).astype(np.int32)),
        }
    return {
        "tokens": jnp.asarray(rng.integers(
            0, cfg.vocab, size=(B, S)).astype(np.int32)),
        "targets": jnp.asarray(rng.integers(
            0, cfg.vocab, size=(B, S)).astype(np.int32)),
    }


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_forward_and_train_step(arch):
    cfg = SMOKES[arch]
    rng = np.random.default_rng(42)
    model = build_model(cfg)
    batch = make_batch(cfg, rng)

    opt = adamw()
    init = make_train_state_init(model, opt)
    state = init(jax.random.key(0))
    step = jax.jit(make_train_step(model, opt))
    state2, metrics = step(state, batch)

    loss = float(metrics["loss"])
    assert np.isfinite(loss) and 0.0 < loss < 20.0, loss
    assert int(state2.step) == 1
    # params changed and stayed finite
    moved = False
    for a, b in zip(jax.tree.leaves(state.params),
                    jax.tree.leaves(state2.params)):
        assert np.isfinite(np.asarray(b, dtype=np.float32)).all()
        moved |= not np.array_equal(np.asarray(a), np.asarray(b))
    assert moved


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_decode_step_shapes(arch):
    cfg = SMOKES[arch]
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    caches = model.init_caches(batch=B, max_len=S)
    token = jnp.zeros((B, 1), jnp.int32)
    logits, caches2 = jax.jit(model.decode_step)(
        params, token, caches, jnp.asarray(0, jnp.int32))
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    assert jax.tree.structure(caches) == jax.tree.structure(caches2)


@pytest.mark.parametrize("arch", list_archs())
def test_full_config_matches_assignment(arch):
    """The full (non-smoke) configs carry the assigned hyperparameters."""
    cfg = ARCHS[arch]
    expected = {
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expected, (arch, got, expected)


def test_moe_assignment_extras():
    g = ARCHS["grok-1-314b"]
    assert (g.moe_n_experts, g.moe_top_k) == (8, 2)
    q = ARCHS["qwen2-moe-a2.7b"]
    assert (q.moe_n_experts, q.moe_top_k, q.moe_n_shared) == (60, 4, 4)
    z = ARCHS["zamba2-1.2b"]
    assert z.ssm_state == 64
