"""Optimizers, schedules, clipping, and gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim


def _quadratic_problem(seed=0):
    rng = np.random.default_rng(seed)
    target = {"a": jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32)),
              "b": jnp.asarray(rng.normal(size=(4,)).astype(np.float32))}
    params = jax.tree.map(jnp.zeros_like, target)

    def loss_fn(p):
        return sum(jnp.sum((x - t) ** 2)
                   for x, t in zip(jax.tree.leaves(p),
                                   jax.tree.leaves(target)))

    return params, target, loss_fn


@pytest.mark.parametrize("make_opt,lr", [
    (optim.adamw, 0.05),
    (optim.adafactor, 0.5),
    (optim.sgdm, 0.02),
])
def test_optimizer_descends(make_opt, lr):
    params, target, loss_fn = _quadratic_problem()
    opt = make_opt()
    state = opt.init(params)
    l0 = float(loss_fn(params))
    for _ in range(60):
        grads = jax.grad(loss_fn)(params)
        updates, state = opt.update(grads, state, params, lr)
        params = optim.apply_updates(params, updates)
    l1 = float(loss_fn(params))
    assert l1 < 0.2 * l0, (opt.name, l0, l1)


def test_adafactor_state_is_factored():
    params = {"w": jnp.zeros((16, 8)), "b": jnp.zeros((8,))}
    st = optim.adafactor().init(params)
    assert st["f"]["w"]["vr"].shape == (16,)
    assert st["f"]["w"]["vc"].shape == (8,)
    assert st["f"]["b"]["v"].shape == (8,)


def test_clip_by_global_norm():
    grads = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, gnorm = optim.clip_by_global_norm(grads, 1.0)
    assert abs(float(gnorm) - 10.0) < 1e-5
    total = sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(clipped))
    assert abs(float(jnp.sqrt(total)) - 1.0) < 1e-5


def test_warmup_cosine_schedule():
    from repro.optim import warmup_cosine
    sch = warmup_cosine(peak=1.0, warmup_steps=10, total_steps=100)
    assert float(sch(0)) == 0.0
    assert abs(float(sch(10)) - 1.0) < 1e-6
    assert float(sch(5)) == pytest.approx(0.5)
    assert float(sch(100)) == pytest.approx(0.1, abs=1e-3)
    assert float(sch(50)) < float(sch(20))


def test_int8_compression_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
    q, s = optim.compress_int8(x)
    back = optim.decompress_int8(q, s)
    assert q.dtype == jnp.int8
    err = np.max(np.abs(np.asarray(back - x)))
    assert err <= float(s) / 2 + 1e-7    # half-ulp of the quant grid


def test_error_feedback_accumulates_residual():
    """Sum of decompressed updates converges to the true sum (EF-SGD)."""
    rng = np.random.default_rng(1)
    grads_seq = [
        {"w": jnp.asarray(rng.normal(size=(64,)).astype(np.float32)
                          * 1e-3)}
        for _ in range(50)]
    state = optim.init_error_feedback(grads_seq[0])
    total_sent = np.zeros(64, np.float32)
    total_true = np.zeros(64, np.float32)
    for g in grads_seq:
        quantized, state = optim.error_feedback_compress(g, state)
        q, s = quantized["w"]
        total_sent += np.asarray(optim.decompress_int8(q, s))
        total_true += np.asarray(g["w"])
    # residual bounds the gap
    gap = np.abs(total_sent + np.asarray(state.residual["w"]) - total_true)
    assert np.max(gap) < 1e-5
