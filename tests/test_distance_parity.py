"""Distance metrics vs scipy.spatial.distance references (satellite).

Covers every registered metric: dense jnp builders against scipy's pdist
forms, blocked-vs-dense consistency for n NOT a multiple of the block size
(bit-match where the math is elementwise — Bray-Curtis, Jaccard — and fp32
tolerance for the Gram-trick metrics, whose matmul reduction order is
blocking-dependent), and the Pallas row-slab kernels against the dense
forms.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro import pipeline
from repro.core import distance as dist

scipy_dist = pytest.importorskip("scipy.spatial.distance")

# n deliberately prime (never a multiple of any block size used below).
N, D = 53, 24
ODD_BLOCKS = [7, 17, 50]
# metrics whose entries are elementwise reductions — identical floating
# point work regardless of row blocking, so blocked == dense BITWISE.
ELEMENTWISE = ("braycurtis", "jaccard")


def _features(seed=0, sparse=False):
    rng = np.random.default_rng(seed)
    x = rng.gamma(1.0, 1.0, size=(N, D)).astype(np.float32)
    if sparse:  # knock out entries so presence/absence is informative
        x *= rng.random(size=(N, D)) < 0.4
    return x


def _scipy_reference(x, metric):
    if metric == "euclidean":
        return scipy_dist.squareform(scipy_dist.pdist(x, "euclidean"))
    if metric == "braycurtis":
        return scipy_dist.squareform(scipy_dist.pdist(x, "braycurtis"))
    if metric == "jaccard":
        return scipy_dist.squareform(scipy_dist.pdist(x > 0, "jaccard"))
    if metric == "aitchison":  # clr then euclidean (scipy has no aitchison)
        logx = np.log(x.astype(np.float64) + 0.5)
        clr = logx - logx.mean(axis=1, keepdims=True)
        return scipy_dist.squareform(scipy_dist.pdist(clr, "euclidean"))
    raise ValueError(metric)


@pytest.mark.parametrize("metric", sorted(dist.METRICS))
def test_dense_matches_scipy(metric):
    x = _features(seed=3, sparse=metric == "jaccard")
    got = np.asarray(dist.distance_matrix(jnp.asarray(x), metric))
    want = _scipy_reference(x, metric)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("metric", sorted(dist.METRICS))
@pytest.mark.parametrize("block", ODD_BLOCKS)
def test_blocked_matches_dense_odd_block(metric, block):
    assert N % block != 0  # the satellite's awkward-shape requirement
    x = jnp.asarray(_features(seed=5, sparse=metric == "jaccard"))
    dense = np.asarray(dist.distance_matrix(x, metric))
    _, _, blocked_fn = pipeline.get(f"{metric}.blocked").bound(block=block)
    blocked = np.asarray(blocked_fn(x))
    if metric in ELEMENTWISE:
        np.testing.assert_array_equal(blocked, dense)
    else:  # Gram-trick metrics: matmul reduction order depends on blocking
        np.testing.assert_allclose(blocked, dense, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("metric", ["braycurtis", "euclidean", "jaccard"])
@pytest.mark.parametrize("block", ODD_BLOCKS)
def test_pallas_row_slabs_match_dense(metric, block):
    from repro.kernels.distance import ops as dops

    x = jnp.asarray(_features(seed=7, sparse=metric == "jaccard"))
    dense = np.asarray(dist.distance_matrix(x, metric))
    xp = dist.ROW_METRICS[metric].prepare(x)  # presence cast for jaccard
    out = np.empty((N, N), np.float32)
    for lo in range(0, N, block):
        hi = min(lo + block, N)
        slab = np.array(dops.pairwise_distance_rows(
            xp[lo:hi], xp, metric=metric, tile_r=16, tile_c=16,
            feat_block=16))
        slab[np.arange(lo, hi) - lo, np.arange(lo, hi)] = 0.0  # diag contract
        out[lo:hi] = slab
    np.testing.assert_allclose(out, dense, rtol=1e-4, atol=1e-5)


def test_pallas_jaccard_dense_matches_scipy():
    """Satellite: the presence/absence matmul-form Pallas kernel is a real
    stage-1 impl — full-matrix parity against scipy at prime n."""
    from repro.kernels.distance import ops as dops

    x = _features(seed=11, sparse=True)
    xp = dist.ROW_METRICS["jaccard"].prepare(jnp.asarray(x))
    got = np.asarray(dops.pairwise_distance(
        xp, metric="jaccard", tile_r=16, tile_c=16, feat_block=16))
    want = _scipy_reference(x, "jaccard")
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_streaming_builder_matches_dense_squared():
    x = jnp.asarray(_features(seed=9))
    mdef = dist.ROW_METRICS["braycurtis"]
    mat2, gower = pipeline.build_mat2_streaming(mdef.prepare(x), mdef.rows,
                                                block=17)
    dense = np.asarray(dist.distance_matrix(x, "braycurtis"))
    np.testing.assert_array_equal(mat2, dense * dense)
    # Gower marginals accumulated in the same pass
    np.testing.assert_allclose(gower.row_sums, (dense * dense).sum(axis=1),
                               rtol=1e-6)
    assert gower.s_t == pytest.approx((dense * dense).sum() / 2 / N,
                                      rel=1e-6)
