"""MoE dispatch correctness: capacity semantics, no-drop equivalence with a
dense mixture, scan-experts path, balance loss."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import SMOKES
from repro.models import moe, nn


def _setup(cfg, seed=0):
    spec = moe.moe_spec(cfg, jnp.float32)
    params = nn.init_params(jax.random.key(seed), spec)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model))
                    .astype(np.float32) * 0.5)
    return params, x


def _dense_mixture(params, cfg, x):
    """Ground truth: every expert on every token, weighted by router."""
    b, s, d = x.shape
    x2d = x.reshape(-1, d)
    logits = nn.dense(params["router"], x2d)
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, cfg.moe_top_k)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    g = jnp.einsum("td,edf->tef", x2d, params["w_gate"])
    u = jnp.einsum("td,edf->tef", x2d, params["w_up"])
    h = jax.nn.silu(g) * u
    y_all = jnp.einsum("tef,efd->ted", h, params["w_down"])
    mask = jnp.zeros_like(probs).at[
        jnp.arange(x2d.shape[0])[:, None], ids].set(w)
    y = jnp.einsum("ted,te->td", y_all, mask)
    if "shared" in params:
        from repro.models import mlp
        gate = jax.nn.sigmoid(nn.dense(params["shared_gate"], x2d))
        y = y + mlp.swiglu(params["shared"], x2d) * gate
    return y.reshape(b, s, d)


@pytest.mark.parametrize("arch", ["grok-1-314b", "qwen2-moe-a2.7b"])
def test_no_drop_matches_dense_mixture(arch):
    cfg = SMOKES[arch].replace(moe_capacity_factor=16.0)
    params, x = _setup(cfg)
    got, aux = moe.moe_ffn(params, cfg, x)
    want = _dense_mixture(params, cfg, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)
    assert float(aux) > 0


def test_scan_experts_equals_einsum():
    cfg = SMOKES["grok-1-314b"].replace(moe_capacity_factor=16.0)
    params, x = _setup(cfg, seed=3)
    y_scan, _ = moe.moe_ffn(params, cfg.replace(moe_scan_experts=True), x)
    y_ein, _ = moe.moe_ffn(params, cfg.replace(moe_scan_experts=False), x)
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_ein),
                               rtol=1e-5, atol=1e-6)


def test_dispatch_capacity_drops():
    """With capacity 1, at most 1 slot per expert is used."""
    ids = jnp.asarray([[0], [0], [0], [1]], jnp.int32)
    pos, keep = moe._dispatch_indices(ids, n_experts=2, capacity=1)
    assert int(keep.sum()) == 2            # one per expert survives
    assert int(pos[0, 0]) == 0 and not bool(keep[1, 0])


def test_dispatch_positions_unique_per_expert():
    rng = np.random.default_rng(0)
    ids = jnp.asarray(rng.integers(0, 5, size=(64, 2)).astype(np.int32))
    pos, keep = moe._dispatch_indices(ids, n_experts=5, capacity=1000)
    flat_e = np.asarray(ids).reshape(-1)
    flat_p = np.asarray(pos).reshape(-1)
    for e in range(5):
        ps = np.sort(flat_p[flat_e == e])
        np.testing.assert_array_equal(ps, np.arange(len(ps)))


def test_zero_capacity_factor_drop_keeps_shared_path():
    cfg = SMOKES["qwen2-moe-a2.7b"].replace(moe_capacity_factor=1e-9)
    params, x = _setup(cfg, seed=5)
    y, _ = moe.moe_ffn(params, cfg, x)
    assert np.isfinite(np.asarray(y)).all()
