"""Autoregressive consistency: a decode loop with caches must reproduce the
teacher-forced forward logits at every position, for every family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import SMOKES
from repro.models import attention, blocks, nn
from repro.models.model import build_model, _positions

B, T = 2, 12
TOL = 2e-4   # fp32 accumulation-order differences


def full_hidden(model, params, tokens):
    cfg = model.cfg
    if cfg.family in ("dense", "moe", "vlm"):
        h, _ = model._embed_input(params, {"tokens": tokens})
        h, _, _ = model._backbone(params, h, _positions(*tokens.shape))
        return h
    if cfg.family == "hybrid":
        h = nn.embed(params["embed"], tokens).astype(model.dtype)
        return model._forward(params, h, _positions(*tokens.shape))
    if cfg.family == "xlstm":
        h = nn.embed(params["embed"], tokens).astype(model.dtype)
        return model._forward(params, h)
    raise ValueError(cfg.family)


CASES = [
    ("internlm2-1.8b", {}),
    ("qwen1.5-110b", {}),
    ("command-r-35b", {}),
    ("glm4-9b", {}),
    ("grok-1-314b", {"moe_capacity_factor": 8.0}),   # no-drop for parity
    ("qwen2-moe-a2.7b", {"moe_capacity_factor": 8.0}),
    ("zamba2-1.2b", {}),
    ("xlstm-350m", {}),
]


@pytest.mark.parametrize("arch,over", CASES)
def test_decode_matches_teacher_forced(arch, over):
    cfg = SMOKES[arch].replace(**over) if over else SMOKES[arch]
    model = build_model(cfg)
    rng = np.random.default_rng(1)
    params = model.init(jax.random.key(0))
    tokens = jnp.asarray(rng.integers(0, cfg.vocab,
                                      size=(B, T)).astype(np.int32))
    h = full_hidden(model, params, tokens)
    ref = np.asarray((h @ params["unembed"]["w"]).astype(jnp.float32))
    caches = model.init_caches(batch=B, max_len=T + 4)
    step = jax.jit(model.decode_step)
    for t in range(T):
        logits, caches = step(params, tokens[:, t:t + 1], caches,
                              jnp.asarray(t, jnp.int32))
        err = np.max(np.abs(np.asarray(logits[:, 0]) - ref[:, t]))
        assert err < TOL, f"{arch} step {t}: err={err}"


def test_whisper_decode_matches_teacher_forced():
    cfg = SMOKES["whisper-base"]
    model = build_model(cfg)
    rng = np.random.default_rng(2)
    params = model.init(jax.random.key(0))
    frames = jnp.asarray(rng.normal(size=(B, 16, cfg.d_model))
                         .astype(np.float32))
    tokens = jnp.asarray(rng.integers(0, cfg.vocab,
                                      size=(B, T)).astype(np.int32))
    enc_out = model.encode(params, frames)
    h = nn.embed(params["embed"], tokens).astype(model.dtype) \
        + params["dec_pos"][None, :T, :]
    h, _ = blocks.encdec_stack(params["dec_layers"], cfg, h, enc_out,
                               _positions(B, T), q_chunk=cfg.attn_q_chunk,
                               remat=cfg.remat)
    h = nn.layernorm(params["final_norm"], h, eps=cfg.norm_eps)
    ref = np.asarray((h @ params["unembed"]["w"]).astype(jnp.float32))

    caches = model.init_caches(batch=B, max_len=T + 4, enc_len=16)

    def fill_cross(_, lp):
        return None, attention.cross_kv(lp["cross"], cfg, enc_out)

    _, ckv = jax.lax.scan(fill_cross, None, params["dec_layers"])
    caches = {"self": caches["self"], "cross": ckv}
    step = jax.jit(model.decode_step)
    for t in range(T):
        logits, caches = step(params, tokens[:, t:t + 1], caches,
                              jnp.asarray(t, jnp.int32))
        err = np.max(np.abs(np.asarray(logits[:, 0]) - ref[:, t]))
        assert err < TOL, f"whisper step {t}: err={err}"


def test_prefill_matches_decode_loop():
    """prefill() + one decode == decode loop from scratch (dense)."""
    cfg = SMOKES["internlm2-1.8b"]
    model = build_model(cfg)
    rng = np.random.default_rng(3)
    params = model.init(jax.random.key(0))
    tokens = jnp.asarray(rng.integers(0, cfg.vocab,
                                      size=(B, T)).astype(np.int32))
    logits_p, caches_p = model.prefill(params, {"tokens": tokens},
                                       max_len=T + 4)
    caches = model.init_caches(batch=B, max_len=T + 4)
    step = jax.jit(model.decode_step)
    for t in range(T):
        logits_d, caches = step(params, tokens[:, t:t + 1], caches,
                                jnp.asarray(t, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(logits_d),
                               atol=TOL)
    for a, b in zip(jax.tree.leaves(caches_p), jax.tree.leaves(caches)):
        np.testing.assert_allclose(
            np.asarray(a[:, :, :T]).astype(np.float32),
            np.asarray(b[:, :, :T]).astype(np.float32), atol=TOL)
