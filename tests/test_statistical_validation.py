"""Tier-2 statistical validation (slow-marked): under a null grouping the
permutation p-value must be ~Uniform(0, 1), on both the stacked and the
ragged (masked-permutation) multi-study paths.

Deterministic seeds: a failure is a broken null machinery (key folding,
identity slot, tie handling, masked draws), not bad luck."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import engine

pytestmark = pytest.mark.slow


def test_null_pvalues_uniform_chisquare():
    """Many synthetic null studies through permanova_many: with
    exchangeable samples (iid random distances, arbitrary labels) the
    permutation p-value is uniform on {1/(P+1), ..., 1}. Chi-square
    goodness-of-fit over 10 equiprobable bins."""
    scipy_stats = pytest.importorskip("scipy.stats")
    S, n, g, n_perms = 256, 20, 3, 199
    rng = np.random.default_rng(123)
    dms = rng.random((S, n, n)).astype(np.float32)
    dms = (dms + np.transpose(dms, (0, 2, 1))) / 2
    for s in range(S):
        np.fill_diagonal(dms[s], 0.0)
    groupings = rng.integers(0, g, size=(S, n)).astype(np.int32)
    groupings[:, :g] = np.arange(g)[None, :]
    many = engine.permanova_many(jnp.asarray(dms), jnp.asarray(groupings),
                                 n_groups=g, n_perms=n_perms,
                                 key=jax.random.key(7))
    p = np.asarray(many.p_value)
    # p takes values k/(P+1), k in {1..P+1}: map to 10 equiprobable bins
    k = np.rint(p * (n_perms + 1)).astype(np.int64)
    assert k.min() >= 1 and k.max() <= n_perms + 1
    bins = (k - 1) * 10 // (n_perms + 1)
    counts = np.bincount(bins, minlength=10)
    chi2 = float(((counts - S / 10.0) ** 2 / (S / 10.0)).sum())
    pval = float(scipy_stats.chi2.sf(chi2, df=9))
    assert pval > 1e-3, (chi2, counts.tolist())
    # and the null F distribution is centered where it should be: the
    # dof-normalized ratio has mean ~1 under exchangeability
    assert 0.8 < float(np.mean(many.f_stat)) < 1.2


def test_null_pvalues_uniform_ks_ragged():
    """Same null-uniformity contract through the RAGGED (masked
    permutation) path — the masked generator must not bias the null.
    Kolmogorov-Smirnov against the uniform CDF (the 1/(P+1) grid
    discreteness biases D upward by far less than the threshold)."""
    scipy_stats = pytest.importorskip("scipy.stats")
    S, g, n_perms = 128, 3, 199
    rng = np.random.default_rng(29)
    sizes = rng.integers(12, 24, size=S)
    dms, gss = [], []
    for s in range(S):
        n = int(sizes[s])
        d = rng.random((n, n)).astype(np.float32)
        d = (d + d.T) / 2
        np.fill_diagonal(d, 0.0)
        grp = rng.integers(0, g, size=n).astype(np.int32)
        grp[:g] = np.arange(g)
        dms.append(d)
        gss.append(grp)
    many = engine.permanova_many(dms, gss, n_groups=g, n_perms=n_perms,
                                 key=jax.random.key(11))
    p = np.asarray(many.p_value)
    stat, pval = scipy_stats.kstest(p, "uniform")
    assert pval > 1e-3, (stat, pval)


def test_effect_detected_and_null_not():
    """Power sanity on the end-to-end pipeline: a real group effect drives
    p to the floor; the same features with shuffled labels do not."""
    from repro.data.microbiome import synthetic_study
    from repro import pipeline
    x, grouping = synthetic_study(60, 24, 3, effect_size=2.0, seed=3)
    res = pipeline.pipeline(jnp.asarray(x), jnp.asarray(grouping),
                            n_groups=3, n_perms=199,
                            key=jax.random.key(0))
    assert float(res.p_value) <= 0.02, float(res.p_value)
    assert float(res.r2) > 0.0
    rng = np.random.default_rng(5)
    shuffled = rng.permutation(np.asarray(grouping)).astype(np.int32)
    res0 = pipeline.pipeline(jnp.asarray(x), jnp.asarray(shuffled),
                             n_groups=3, n_perms=199,
                             key=jax.random.key(1))
    assert float(res0.p_value) > 0.05, float(res0.p_value)
