"""Sharded matrix-input permanova_many: bit-equality between the forced
8-device CPU mesh and the single-host path (including study counts that do
not divide the 'data' axis and ragged study lists), plus the single-host
contracts the sharded run must reproduce."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import engine

G = 4


def _dm(n, seed):
    rng = np.random.default_rng(seed)
    d = rng.random((n, n)).astype(np.float32)
    d = (d + d.T) / 2
    np.fill_diagonal(d, 0.0)
    grouping = rng.integers(0, G, size=n).astype(np.int32)
    grouping[:G] = np.arange(G)
    return d, grouping


class TestSingleHostContracts:
    def test_stacked_matches_run_loop(self):
        """Stacked studies draw fold_in(key, s) — the vmapped program
        reproduces S independent run() calls (identical draws; values to
        fp32 reassociation, p-values exactly)."""
        key = jax.random.key(3)
        ds, gs = zip(*[_dm(21, seed=s) for s in range(3)])
        many = engine.permanova_many(
            jnp.asarray(np.stack(ds)), jnp.asarray(np.stack(gs)),
            n_groups=G, n_perms=49, key=key)
        for s in range(3):
            single = engine.run(jnp.asarray(ds[s]), jnp.asarray(gs[s]),
                                n_perms=49, n_groups=G,
                                key=jax.random.fold_in(key, s))
            np.testing.assert_allclose(np.asarray(many.f_perms[s]),
                                       np.asarray(single.f_perms),
                                       rtol=1e-4, atol=1e-5)
            assert float(many.p_value[s]) == float(single.p_value)

    def test_ragged_observed_stats_match_run(self):
        """Ragged studies: the observed F/s_T/R^2 (identity labels at
        index 0) are the unpadded per-study values — the sentinel pad
        contributes exactly nothing."""
        sizes = (14, 23, 17)
        studies = [_dm(m, seed=40 + i) for i, m in enumerate(sizes)]
        key = jax.random.key(9)
        many = engine.permanova_many([d for d, _ in studies],
                                     [g for _, g in studies],
                                     n_groups=G, n_perms=29, key=key)
        assert np.array_equal(np.asarray(many.n_valid), sizes)
        assert "ragged" in many.plan
        for s, (d, g) in enumerate(studies):
            single = engine.run(jnp.asarray(d), jnp.asarray(g),
                                n_perms=0, n_groups=G, key=key)
            np.testing.assert_allclose(float(many.f_perms[s, 0]),
                                       float(single.f_stat), rtol=1e-4)
            np.testing.assert_allclose(float(many.s_t[s]),
                                       float(single.s_t), rtol=1e-5)
            np.testing.assert_allclose(float(many.study(s).r2),
                                       float(single.r2), rtol=1e-3,
                                       atol=1e-5)
            assert many.study(s).n_objects == sizes[s]

    def test_ragged_fixed_bucket_n_pad(self):
        """n_pad= pins the padded width to a serving bucket: results are
        invariant to the extra pad rows (masked draws depend on n_valid,
        not the batch max), and an undersized bucket is a clear error."""
        sizes = (14, 23, 17)
        studies = [_dm(m, seed=60 + i) for i, m in enumerate(sizes)]
        key = jax.random.key(4)
        base = engine.permanova_many([d for d, _ in studies],
                                     [g for _, g in studies],
                                     n_groups=G, n_perms=29, key=key)
        bucket = engine.permanova_many([d for d, _ in studies],
                                       [g for _, g in studies],
                                       n_groups=G, n_perms=29, key=key,
                                       n_pad=32)
        # same n_valid trace, wider pad: the extra zero rows change only
        # the fp32 reduction tree, not the statistics
        assert np.array_equal(np.asarray(bucket.n_valid), sizes)
        np.testing.assert_allclose(np.asarray(bucket.f_perms[:, 0]),
                                   np.asarray(base.f_perms[:, 0]),
                                   rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(np.asarray(bucket.s_t),
                                   np.asarray(base.s_t), rtol=1e-5)
        with pytest.raises(ValueError, match="n_pad"):
            engine.permanova_many([d for d, _ in studies],
                                  [g for _, g in studies],
                                  n_groups=G, n_perms=29, key=key,
                                  n_pad=16)

    def test_ragged_studies_draw_independent_nulls(self):
        d, g = _dm(19, seed=7)
        many = engine.permanova_many([d, d, d], [g, g, g], n_groups=G,
                                     n_perms=29, key=jax.random.key(1))
        f = np.asarray(many.f_perms)
        np.testing.assert_allclose(f[:, 0], f[0, 0], rtol=1e-5)
        for a in range(3):
            for b in range(a + 1, 3):
                assert not np.allclose(f[a, 1:], f[b, 1:]), (a, b)

    def test_ragged_input_validation(self):
        d, g = _dm(12, seed=0)
        with pytest.raises(ValueError, match="ragged input"):
            engine.permanova_many([d, d], [g], n_groups=G, n_perms=9)


MULTI_DEVICE_MANY = r"""
import jax, jax.numpy as jnp
import numpy as np
from repro import engine
from repro.launch.mesh import make_mesh

G = 4
def dm(n, seed):
    rng = np.random.default_rng(seed)
    d = rng.random((n, n)).astype(np.float32)
    d = (d + d.T) / 2
    np.fill_diagonal(d, 0.0)
    g = rng.integers(0, G, size=n).astype(np.int32)
    g[:G] = np.arange(G)
    return d, g

assert len(jax.devices()) == 8, jax.devices()
key = jax.random.key(17)

# --- stacked: S=6 studies; data axes 2 (divisible), 4 and 8 (padded) ---
S = 6
ds, gs = zip(*[dm(21, seed=s) for s in range(S)])
dms = jnp.asarray(np.stack(ds)); grps = jnp.asarray(np.stack(gs))
ref = engine.permanova_many(dms, grps, n_groups=G, n_perms=99, key=key,
                            ordination=2)
for shape in ((2, 4), (4, 2), (8, 1)):
    mesh = make_mesh(shape, ("data", "model"))
    got = engine.permanova_many(dms, grps, n_groups=G, n_perms=99, key=key,
                                mesh=mesh, ordination=2)
    assert f"data[{shape[0]}]" in got.plan, got.plan
    # BIT-identical to the single-host path: same program per study, keys
    # folded by global index once per dispatch before sharding
    assert np.array_equal(np.asarray(got.f_perms), np.asarray(ref.f_perms)), shape
    assert np.array_equal(np.asarray(got.f_stat), np.asarray(ref.f_stat))
    assert np.array_equal(np.asarray(got.p_value), np.asarray(ref.p_value))
    assert np.array_equal(np.asarray(got.s_t), np.asarray(ref.s_t))
    assert np.array_equal(np.asarray(got.ordination.coords),
                          np.asarray(ref.ordination.coords)), shape
print("OK stacked")

# --- per-study parity: sharded == loop of run(fold_in(key, s)) ---
mesh = make_mesh((4, 2), ("data", "model"))
got = engine.permanova_many(dms, grps, n_groups=G, n_perms=99, key=key,
                            mesh=mesh)
for s in range(S):
    single = engine.run(jnp.asarray(ds[s]), jnp.asarray(gs[s]),
                        n_perms=99, n_groups=G,
                        key=jax.random.fold_in(key, s))
    np.testing.assert_allclose(np.asarray(got.f_perms[s]),
                               np.asarray(single.f_perms),
                               rtol=1e-4, atol=1e-5)
    assert float(got.p_value[s]) == float(single.p_value), s
print("OK run-loop")

# --- ragged list: padded under one plan, sharded == single-host ---
sizes = (14, 23, 17, 21, 9)         # 5 studies: does not divide 2 or 8
studies = [dm(m, seed=50 + i) for i, m in enumerate(sizes)]
rd = [d for d, _ in studies]; rg = [g for _, g in studies]
ref = engine.permanova_many(rd, rg, n_groups=G, n_perms=99, key=key,
                            ordination=2)
for shape in ((8, 1), (2, 4)):
    mesh = make_mesh(shape, ("data", "model"))
    got = engine.permanova_many(rd, rg, n_groups=G, n_perms=99, key=key,
                                mesh=mesh, ordination=2)
    assert np.array_equal(np.asarray(got.f_perms), np.asarray(ref.f_perms)), shape
    assert np.array_equal(np.asarray(got.p_value), np.asarray(ref.p_value))
    assert np.array_equal(np.asarray(got.ordination.coords),
                          np.asarray(ref.ordination.coords)), shape
print("OK ragged")
"""


@pytest.mark.multidevice
def test_sharded_permanova_many_matches_single_host():
    """F and p bit-equality: study-axis sharding over a forced 8-device
    CPU mesh vs the single-host vmap, for divisible AND non-divisible
    study counts, stacked AND ragged inputs (the acceptance criterion)."""
    from conftest import run_subprocess
    out = run_subprocess(MULTI_DEVICE_MANY, devices=8, timeout=900)
    assert "OK stacked" in out
    assert "OK run-loop" in out
    assert "OK ragged" in out
