"""Checkpointing: roundtrip, atomicity, retention, async writer."""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(8, 4))
                                    .astype(np.float32)),
                   "b": jnp.asarray(rng.normal(size=(4,))
                                    .astype(np.float32)).astype(
                                        jnp.bfloat16)},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip(tmp_path):
    tree = _tree()
    save_pytree(tree, tmp_path, step=7, extras={"note": "x"})
    restored, manifest = load_pytree(_tree(seed=1), tmp_path)
    assert manifest["step"] == 7
    assert manifest["extras"]["note"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_latest_step_and_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (10, 20, 30):
        mgr.save(_tree(s), step=s, blocking=True)
    assert mgr.latest_step() == 30
    kept = sorted(p.name for p in pathlib.Path(tmp_path).glob("step_*"))
    assert kept == ["step_00000020", "step_00000030"]


def test_async_save_overlaps_and_waits(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(_tree(1), step=1)      # non-blocking
    mgr.save(_tree(2), step=2)      # waits for the first internally
    mgr.wait()
    assert mgr.latest_step() == 2


def test_no_partial_checkpoint_visible(tmp_path):
    """Temp dirs never count as checkpoints (atomic rename contract)."""
    d = pathlib.Path(tmp_path)
    (d / ".tmp_step_00000099_123").mkdir(parents=True)
    mgr = CheckpointManager(tmp_path)
    assert mgr.latest_step() is None


def test_restore_missing_leaf_raises(tmp_path):
    save_pytree({"a": jnp.zeros((2,))}, tmp_path, step=1)
    with pytest.raises(KeyError):
        load_pytree({"a": jnp.zeros((2,)), "c": jnp.zeros((2,))},
                    tmp_path, step=1)


def test_manifest_records_shapes(tmp_path):
    save_pytree(_tree(), tmp_path, step=3)
    manifest = json.loads(
        (pathlib.Path(tmp_path) / "step_00000003" / "manifest.json")
        .read_text())
    assert manifest["leaves"]["params/w"]["shape"] == [8, 4]
