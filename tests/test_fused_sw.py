"""Fused distance→s_W megakernel: kernel-vs-oracle parity (odd tiles,
prime n, ragged groups, row-slab partials), the single-pass drivers,
fused-kernel planner rules, persisted stage-1/fused autotune entries,
multi-device equality under a forced CPU mesh, and pipeline_many's
per-study permutation seeds."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import pipeline
from repro.core import distance as dist
from repro.core import permutations
from repro.engine import planner as eplanner
from repro.kernels.fused_sw import ops as fops
from repro.kernels.fused_sw import ref as fref
from repro.pipeline import planner as pplanner
from repro.pipeline import streaming

N, D, G = 53, 24, 5   # prime n, ragged group count


def _study(seed=0, n=N, d=D, g=G):
    rng = np.random.default_rng(seed)
    x = rng.gamma(1.0, 1.0, size=(n, d)).astype(np.float32)
    x *= rng.random(size=(n, d)) < 0.5
    x[:, 0] = np.maximum(x[:, 0], 1e-3)
    grouping = rng.integers(0, g, size=n).astype(np.int32)
    grouping[:g] = np.arange(g)          # ragged sizes, every group present
    return x, grouping


def _perm_batch(grouping, n_perms, seed=3):
    rng = np.random.default_rng(seed)
    return np.stack([rng.permutation(grouping) for _ in range(n_perms)])


class TestMegakernelParity:
    """ops.fused_sw_rows vs the dense jnp oracle (ref.fused_sw_ref)."""

    @pytest.mark.parametrize("metric", ["euclidean", "braycurtis",
                                        "jaccard"])
    @pytest.mark.parametrize("tiles", [
        dict(tile_r=16, tile_c=16, feat_block=8, perm_block=4),
        dict(tile_r=8, tile_c=32, feat_block=16, perm_block=3),  # odd PB
    ])
    def test_matches_oracle(self, metric, tiles):
        x, grouping = _study(seed=1)
        prep = dist.ROW_METRICS[metric].prepare(jnp.asarray(x))
        inv_gs = permutations.inv_group_sizes(jnp.asarray(grouping), G)
        g = jnp.asarray(_perm_batch(grouping, 10))
        sw, rs = fops.fused_sw_rows(prep, prep, g, g, inv_gs, 0,
                                    metric=metric, **tiles)
        sw_r, rs_r = fref.fused_sw_ref(prep, prep, g, g, inv_gs, 0,
                                       metric=metric)
        np.testing.assert_allclose(np.asarray(sw), np.asarray(sw_r),
                                   rtol=2e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(rs), np.asarray(rs_r),
                                   rtol=2e-4, atol=1e-5)

    def test_aitchison_maps_to_euclidean_body(self):
        x, grouping = _study(seed=2)
        prep = dist.ROW_METRICS["aitchison"].prepare(jnp.asarray(x))
        inv_gs = permutations.inv_group_sizes(jnp.asarray(grouping), G)
        g = jnp.asarray(_perm_batch(grouping, 6))
        sw, _ = fops.fused_sw_rows(prep, prep, g, g, inv_gs, 0,
                                   metric="aitchison", tile_r=16, tile_c=16,
                                   feat_block=8, perm_block=4)
        sw_r, _ = fref.fused_sw_ref(prep, prep, g, g, inv_gs, 0,
                                    metric="euclidean")
        np.testing.assert_allclose(np.asarray(sw), np.asarray(sw_r),
                                   rtol=2e-4, atol=1e-5)

    def test_row_slab_partials_sum_to_full(self):
        """Offset slabs (the 'model'-shard unit) reconstruct the statistic
        exactly — slab pad rows must not leak into neighbouring slabs."""
        x, grouping = _study(seed=3)
        prep = dist.ROW_METRICS["braycurtis"].prepare(jnp.asarray(x))
        inv_gs = permutations.inv_group_sizes(jnp.asarray(grouping), G)
        g = jnp.asarray(_perm_batch(grouping, 7))
        acc, rs_parts = None, []
        for lo in range(0, N, 19):          # 19 divides nothing here
            hi = min(lo + 19, N)
            sw, rs = fops.fused_sw_rows(
                prep[lo:hi], prep, g[:, lo:hi], g, inv_gs, lo,
                metric="braycurtis", tile_r=8, tile_c=16, feat_block=8,
                perm_block=4)
            acc = np.asarray(sw) if acc is None else acc + np.asarray(sw)
            rs_parts.append(np.asarray(rs))
        full, rs_full = fref.fused_sw_ref(prep, prep, g, g, inv_gs, 0,
                                          metric="braycurtis")
        np.testing.assert_allclose(acc, np.asarray(full), rtol=1e-4)
        np.testing.assert_allclose(np.concatenate(rs_parts),
                                   np.asarray(rs_full), rtol=1e-4)


class TestFusedKernelDrivers:
    """The one-jit XLA sweep and the megakernel chunk loop must equal the
    PR 2 fused bridge bit-for-policy (same key → same F, p)."""

    def _common(self, seed=4):
        x, grouping = _study(seed=seed)
        mdef = dist.ROW_METRICS["braycurtis"]
        xp = mdef.prepare(jnp.asarray(x))
        inv_gs = permutations.inv_group_sizes(jnp.asarray(grouping), G)
        key = jax.random.key(7)
        ref_sw, ref_st, _ = streaming.fused_sw(
            xp, mdef.rows, jnp.asarray(grouping), inv_gs, key, 101,
            row_block=13, chunk=17)
        return x, grouping, mdef, xp, inv_gs, key, ref_sw, ref_st

    def test_onepass_matches_fused(self):
        _, grouping, mdef, xp, inv_gs, key, ref_sw, ref_st = self._common()
        sw, s_t, stats = streaming.fused_sw_onepass(
            xp, mdef.rows, jnp.asarray(grouping), inv_gs, key, 101,
            row_block=13, chunk=17)
        np.testing.assert_allclose(sw, ref_sw, rtol=1e-4)
        assert abs(s_t - ref_st) < 1e-3
        assert stats.impl == "xla" and stats.n_chunks == 6

    def test_megakernel_matches_fused(self):
        _, grouping, mdef, xp, inv_gs, key, ref_sw, ref_st = self._common()
        sw, s_t, stats = streaming.fused_kernel_sw(
            xp, mdef.rows, jnp.asarray(grouping), inv_gs, key, 101,
            impl="pallas", kernel_metric="braycurtis", row_block=13,
            chunk=17, tuning=dict(tile_r=16, tile_c=16, feat_block=8,
                                  perm_block=4))
        np.testing.assert_allclose(sw, ref_sw, rtol=1e-4)
        assert abs(s_t - ref_st) < 1e-3
        assert stats.impl == "pallas"

    def test_unknown_impl_rejected(self):
        _, grouping, mdef, xp, inv_gs, key, _, _ = self._common()
        with pytest.raises(ValueError, match="fused-kernel impl"):
            streaming.fused_kernel_sw(
                xp, mdef.rows, jnp.asarray(grouping), inv_gs, key, 10,
                impl="nope", kernel_metric="braycurtis", row_block=13,
                chunk=17)


class TestFusedKernelPlanner:
    def test_over_budget_prefers_fused_kernel(self):
        pl = pipeline.plan_pipeline(2048, 64, 1000, 8, backend="cpu",
                                    matrix_budget_bytes=1000)
        assert pl.materialize == "fused-kernel"
        assert pl.fused_impl == "braycurtis.fusedk.xla"
        assert pl.sw.impl == "matmul"

    def test_tpu_gets_megakernel_with_tile_tuning(self):
        pl = pipeline.plan_pipeline(2048, 64, 1000, 8, backend="tpu",
                                    materialize="fused-kernel")
        assert pl.fused_impl == "braycurtis.fusedk.pallas"
        assert {"tile_r", "tile_c", "feat_block", "perm_block"} <= \
            set(pl.fused_tuning)

    def test_caller_pins_and_overrides(self):
        pl = pipeline.plan_pipeline(
            512, 64, 100, 8, backend="cpu", materialize="fused-kernel",
            fused_impl="pallas", fused_tuning={"tile_r": 32, "bogus": 1})
        assert pl.fused_impl == "braycurtis.fusedk.pallas"
        assert pl.fused_tuning["tile_r"] == 32
        assert "bogus" not in pl.fused_tuning

    def test_metric_mismatch_rejected(self):
        with pytest.raises(ValueError, match="computes"):
            pipeline.plan_pipeline(512, 64, 100, 8, metric="euclidean",
                                   materialize="fused-kernel",
                                   fused_impl="braycurtis.fusedk.xla")

    def test_mesh_requires_fused_kernel(self):
        x, grouping = _study(seed=5)
        with pytest.raises(ValueError, match="fused-kernel only"):
            pipeline.pipeline(x, grouping, n_perms=9, materialize="dense",
                              mesh=object())


class TestAutotunePersistedStage1AndFused:
    """Satellite: the per-host cache extends to stage-1 distance and
    fused-kernel candidates, keyed by (backend, metric, impl), and the
    planner reads the winners back as defaults."""

    def test_roundtrip_feeds_planner(self, tmp_path, monkeypatch):
        cache = tmp_path / "autotune.json"
        monkeypatch.setenv(eplanner.AUTOTUNE_CACHE_ENV, str(cache))
        eplanner.load_autotune_cache(reload=True)
        try:
            x, grouping = _study(seed=6, n=32, d=16, g=3)
            s1 = pplanner.autotune_stage1(x, "euclidean", backend="cpu")
            fk = pplanner.autotune_fused(x, grouping, metric="euclidean",
                                         backend="cpu", n_groups=3)
            data = json.loads(cache.read_text())
            assert f"dist|cpu|euclidean|{s1}" in data
            assert f"fusedk|cpu|euclidean|{fk}" in data
            entry = data[f"fusedk|cpu|euclidean|{fk}"]
            assert entry["impl"] == fk and "us" in entry
            # fresh load (new process analogue) feeds both pickers
            eplanner.load_autotune_cache(reload=True)
            assert pplanner.measured_stage1("cpu", "euclidean", 32) == s1
            assert pplanner.measured_fused("cpu", "euclidean", 32) == fk
            pl = pipeline.plan_pipeline(32, 16, 100, 3, backend="cpu",
                                        metric="euclidean")
            assert pl.dist_impl == s1
            assert "stage-1 autotune" in pl.reason
            # a different n-bucket falls back to the heuristics
            assert pplanner.measured_stage1("cpu", "euclidean", 4096) is None
        finally:
            monkeypatch.setenv(eplanner.AUTOTUNE_CACHE_ENV, "off")
            eplanner.load_autotune_cache(reload=True)

    def test_first_entry_of_fresh_process_persists(self, tmp_path,
                                                   monkeypatch):
        """record_entry must survive being the FIRST cache touch in a
        process (the lazy first load clears the dirty set)."""
        cache = tmp_path / "autotune.json"
        monkeypatch.setenv(eplanner.AUTOTUNE_CACHE_ENV, str(cache))
        monkeypatch.setattr(eplanner, "_PERSIST", None)  # fresh-process view
        eplanner._DIRTY.clear()
        try:
            eplanner.record_entry("dist|cpu|x|first", {
                "impl": "first", "us": 1.0, "bucket": 32})
            data = json.loads(cache.read_text())
            assert "dist|cpu|x|first" in data
        finally:
            monkeypatch.setenv(eplanner.AUTOTUNE_CACHE_ENV, "off")
            eplanner.load_autotune_cache(reload=True)

    def test_partial_shootout_does_not_feed(self, tmp_path, monkeypatch):
        cache = tmp_path / "autotune.json"
        cache.write_text(json.dumps({
            "dist|cpu|euclidean|euclidean.dense": {
                "impl": "euclidean.dense", "us": 1.0, "bucket": 32},
        }))
        monkeypatch.setenv(eplanner.AUTOTUNE_CACHE_ENV, str(cache))
        eplanner.load_autotune_cache(reload=True)
        try:
            # blocked candidate unmeasured -> no winner
            assert pplanner.measured_stage1("cpu", "euclidean", 32) is None
        finally:
            monkeypatch.setenv(eplanner.AUTOTUNE_CACHE_ENV, "off")
            eplanner.load_autotune_cache(reload=True)

    def test_autotune_pipeline_entry(self, tmp_path, monkeypatch):
        cache = tmp_path / "autotune.json"
        monkeypatch.setenv(eplanner.AUTOTUNE_CACHE_ENV, str(cache))
        eplanner.load_autotune_cache(reload=True)
        try:
            x, grouping = _study(seed=7, n=24, d=8, g=3)
            res = pipeline.pipeline(x, grouping, n_groups=3, n_perms=19,
                                    materialize="fused-kernel",
                                    autotune=True)
            assert res.method == "pipeline[fused-kernel]" or \
                res.method.startswith("pipeline[")
            data = json.loads(cache.read_text())
            assert any(k.startswith("fusedk|") for k in data)
        finally:
            monkeypatch.setenv(eplanner.AUTOTUNE_CACHE_ENV, "off")
            eplanner.load_autotune_cache(reload=True)


MULTI_DEVICE_FUSED = r"""
import jax, jax.numpy as jnp
import numpy as np
from repro import pipeline
from repro.launch.mesh import make_mesh

rng = np.random.default_rng(31)
n, d, G = 53, 24, 5
x = rng.gamma(1.0, 1.0, size=(n, d)).astype(np.float32)
grouping = rng.integers(0, G, size=n).astype(np.int32)
grouping[:G] = np.arange(G)
key = jax.random.key(11)
assert len(jax.devices()) == 8, jax.devices()

ref = pipeline.pipeline(x, grouping, n_groups=G, n_perms=99, key=key,
                        materialize="dense")
for shape in ((2, 4), (8, 1), (1, 8)):
    mesh = make_mesh(shape, ("data", "model"))
    got = pipeline.pipeline(x, grouping, n_groups=G, n_perms=99, key=key,
                            mesh=mesh, row_block=13, chunk=25)
    np.testing.assert_allclose(np.asarray(got.f_perms),
                               np.asarray(ref.f_perms), rtol=1e-4)
    assert float(got.p_value) == float(ref.p_value), shape
    assert abs(float(got.f_stat) - float(ref.f_stat)) < 1e-4 * abs(
        float(ref.f_stat))
print("OK single-study")

S = 4
xs = np.stack([rng.gamma(1.0, 1.0, size=(32, 16)).astype(np.float32)
               for _ in range(S)])
gs = np.stack([np.concatenate([np.arange(3),
                               rng.integers(0, 3, 29)]).astype(np.int32)
               for _ in range(S)])
mesh = make_mesh((4, 2), ("data", "model"))
many = pipeline.pipeline_many(jnp.asarray(xs), jnp.asarray(gs), n_groups=3,
                              n_perms=49, key=key,
                              materialize="fused-kernel", mesh=mesh)
for s in range(S):
    single = pipeline.pipeline(xs[s], gs[s], n_groups=3, n_perms=49,
                               key=jax.random.fold_in(key, s),
                               materialize="dense")
    np.testing.assert_allclose(np.asarray(many.f_perms[s]),
                               np.asarray(single.f_perms), rtol=1e-4)
    assert float(many.p_value[s]) == float(single.p_value), s
print("OK many")

# non-divisible study count: S=3 over data=4 wrap-pads and slices (same
# contract as engine.permanova_many), bit-identical to single-host
ref3 = pipeline.pipeline_many(jnp.asarray(xs[:3]), jnp.asarray(gs[:3]),
                              n_groups=3, n_perms=49, key=key,
                              materialize="fused-kernel")
got3 = pipeline.pipeline_many(jnp.asarray(xs[:3]), jnp.asarray(gs[:3]),
                              n_groups=3, n_perms=49, key=key,
                              materialize="fused-kernel", mesh=mesh)
assert "+pad1" in got3.plan, got3.plan
assert np.array_equal(np.asarray(got3.f_perms), np.asarray(ref3.f_perms))
print("OK many-nondivisible")
"""


@pytest.mark.multidevice
def test_sharded_fused_kernel_matches_single_host():
    """F and p-value equality: fused-kernel over a forced 8-device CPU
    mesh (row slabs over 'model', perms/studies over 'data') vs the
    single-host dense plan."""
    from conftest import run_subprocess
    out = run_subprocess(MULTI_DEVICE_FUSED, devices=8, timeout=900)
    assert "OK single-study" in out and "OK many" in out
    assert "OK many-nondivisible" in out


class TestPipelineManySeeds:
    """Satellite: stacked studies must each draw an independent null from
    fold_in(key, global_study_index) on EVERY batched path."""

    @pytest.mark.parametrize("materialize", ["dense", "fused-kernel"])
    def test_identical_studies_draw_independent_nulls(self, materialize):
        x, grouping = _study(seed=8, n=32, g=3)
        xs = jnp.asarray(np.stack([x] * 3))
        gs = jnp.asarray(np.stack([grouping] * 3))
        many = pipeline.pipeline_many(xs, gs, n_groups=3, n_perms=29,
                                      key=jax.random.key(2),
                                      materialize=materialize)
        f = np.asarray(many.f_perms)
        # observed stat identical (same data) ...
        np.testing.assert_allclose(f[:, 0], f[0, 0], rtol=1e-5)
        # ... but the null draws must differ between studies
        for a in range(3):
            for b in range(a + 1, 3):
                assert not np.allclose(f[a, 1:], f[b, 1:]), (a, b)

    def test_fused_kernel_matches_independent_pipelines(self):
        s_count = 3
        xs, gs = zip(*[_study(seed=40 + s, n=32, g=3)
                       for s in range(s_count)])
        xs = jnp.asarray(np.stack(xs))
        gs = jnp.asarray(np.stack(gs))
        key = jax.random.key(13)
        many = pipeline.pipeline_many(xs, gs, n_groups=3, n_perms=49,
                                      key=key, materialize="fused-kernel")
        assert "studies=3" in many.plan
        for s in range(s_count):
            single = pipeline.pipeline(
                xs[s], gs[s], n_groups=3, n_perms=49,
                key=jax.random.fold_in(key, s), materialize="dense")
            np.testing.assert_allclose(np.asarray(many.f_perms[s]),
                                       np.asarray(single.f_perms),
                                       rtol=1e-4)
            assert float(many.p_value[s]) == float(single.p_value)

    def test_auto_upgrades_to_fused_kernel_over_budget(self):
        x, grouping = _study(seed=9, n=48, g=3)
        xs = jnp.asarray(np.stack([x] * 2))
        gs = jnp.asarray(np.stack([grouping] * 2))
        many = pipeline.pipeline_many(xs, gs, n_groups=3, n_perms=19,
                                      matrix_budget_bytes=1000)
        assert "fusedk" in many.plan
        with pytest.raises(ValueError, match="dense"):
            pipeline.pipeline_many(xs, gs, n_groups=3, n_perms=9,
                                   materialize="stream")
