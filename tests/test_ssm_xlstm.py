"""Unit parity for the recurrent mixers: chunked/parallel forms vs the
step-by-step recurrences they must equal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import SMOKES
from repro.models import ssm, xlstm


def test_ssd_chunked_matches_naive_recurrence():
    rng = np.random.default_rng(0)
    b, s, h, p, n = 2, 16, 3, 4, 5
    x = jnp.asarray(rng.normal(size=(b, s, h, p)).astype(np.float32))
    log_a = jnp.asarray(-np.abs(rng.normal(size=(b, s, h))
                                ).astype(np.float32) * 0.1)
    bm = jnp.asarray(rng.normal(size=(b, s, n)).astype(np.float32))
    cm = jnp.asarray(rng.normal(size=(b, s, n)).astype(np.float32))

    # naive: S_t = a_t S_{t-1} + x_t B_t^T ; y_t = S_t C_t
    state = np.zeros((b, h, p, n), np.float64)
    ys = []
    for t in range(s):
        a = np.exp(np.asarray(log_a[:, t], np.float64))
        outer = np.einsum("bhp,bn->bhpn", np.asarray(x[:, t], np.float64),
                          np.asarray(bm[:, t], np.float64))
        state = a[..., None, None] * state + outer
        ys.append(np.einsum("bhpn,bn->bhp", state,
                            np.asarray(cm[:, t], np.float64)))
    want = np.stack(ys, axis=1)

    for chunk in (4, 8, 16):
        got, final = ssm.ssd_chunked(x, log_a, bm, cm, chunk=chunk)
        np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4,
                                   atol=2e-4)
        np.testing.assert_allclose(np.asarray(final), state, rtol=2e-4,
                                   atol=2e-4)


def test_ssd_initial_state_continuation():
    """Splitting a sequence in two with state carry == one pass."""
    rng = np.random.default_rng(1)
    b, s, h, p, n = 1, 12, 2, 4, 3
    x = jnp.asarray(rng.normal(size=(b, s, h, p)).astype(np.float32))
    log_a = jnp.asarray(-np.abs(rng.normal(size=(b, s, h))
                                ).astype(np.float32) * 0.2)
    bm = jnp.asarray(rng.normal(size=(b, s, n)).astype(np.float32))
    cm = jnp.asarray(rng.normal(size=(b, s, n)).astype(np.float32))
    full, _ = ssm.ssd_chunked(x, log_a, bm, cm, chunk=4)
    y1, st = ssm.ssd_chunked(x[:, :8], log_a[:, :8], bm[:, :8], cm[:, :8],
                             chunk=4)
    y2, _ = ssm.ssd_chunked(x[:, 8:], log_a[:, 8:], bm[:, 8:], cm[:, 8:],
                            chunk=4, initial_state=st)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(full), rtol=1e-4, atol=1e-4)


def test_mamba_block_decode_matches_forward():
    cfg = SMOKES["zamba2-1.2b"]
    from repro.models import blocks, nn
    spec = blocks.mamba_block_spec(cfg, jnp.float32)
    params = nn.init_params(jax.random.key(0), spec)
    rng = np.random.default_rng(2)
    b, s = 2, 10
    x = jnp.asarray(rng.normal(size=(b, s, cfg.d_model))
                    .astype(np.float32) * 0.1)
    y_full, _ = blocks.mamba_block(params, cfg, x, chunk=5)

    d_inner = cfg.ssm_expand * cfg.d_model
    nh = d_inner // cfg.ssm_headdim
    state = {
        "conv": jnp.zeros((b, cfg.ssm_conv - 1, d_inner + 2 * cfg.ssm_state),
                          jnp.float32),
        "ssm": jnp.zeros((b, nh, cfg.ssm_headdim, cfg.ssm_state),
                         jnp.float32),
    }
    outs = []
    for t in range(s):
        y, state = blocks.mamba_block_decode(params, cfg, x[:, t:t + 1],
                                             state)
        outs.append(np.asarray(y[:, 0]))
    got = np.stack(outs, axis=1)
    np.testing.assert_allclose(got, np.asarray(y_full), rtol=2e-3,
                               atol=2e-4)


def test_mlstm_chunked_matches_decode_recurrence():
    cfg = SMOKES["xlstm-350m"]
    from repro.models import blocks, nn
    spec = blocks.mlstm_block_spec(cfg, jnp.float32)
    params = nn.init_params(jax.random.key(3), spec)
    rng = np.random.default_rng(4)
    b, s = 2, 12
    x = jnp.asarray(rng.normal(size=(b, s, cfg.d_model))
                    .astype(np.float32) * 0.3)
    y_full = blocks.mlstm_block(params, cfg, x, chunk=4)

    d_inner = cfg.xlstm_pf * cfg.d_model
    h = cfg.n_heads
    dh = d_inner // h
    state = {
        "c": jnp.zeros((b, h, dh, dh), jnp.float32),
        "n": jnp.zeros((b, h, dh), jnp.float32),
        "m": jnp.full((b, h), -1e30, jnp.float32),
        "conv": jnp.zeros((b, cfg.xlstm_conv - 1, d_inner), jnp.float32),
    }
    outs = []
    for t in range(s):
        y, state = blocks.mlstm_block_decode(params, cfg, x[:, t:t + 1],
                                             state)
        outs.append(np.asarray(y[:, 0]))
    got = np.stack(outs, axis=1)
    np.testing.assert_allclose(got, np.asarray(y_full), rtol=2e-3,
                               atol=3e-4)


def test_slstm_forward_matches_stepwise():
    cfg = SMOKES["xlstm-350m"]
    from repro.models import blocks, nn
    spec = blocks.slstm_block_spec(cfg, jnp.float32)
    params = nn.init_params(jax.random.key(5), spec)
    rng = np.random.default_rng(6)
    b, s = 2, 9
    x = jnp.asarray(rng.normal(size=(b, s, cfg.d_model))
                    .astype(np.float32) * 0.3)
    y_full, _ = blocks.slstm_block(params, cfg, x)
    state = {k: jnp.zeros((b, cfg.d_model), jnp.float32)
             for k in ("c", "n", "h", "m")}
    outs = []
    for t in range(s):
        y, state = blocks.slstm_block_decode(params, cfg, x[:, t:t + 1],
                                             state)
        outs.append(np.asarray(y[:, 0]))
    np.testing.assert_allclose(np.stack(outs, 1), np.asarray(y_full),
                               rtol=2e-3, atol=3e-4)
