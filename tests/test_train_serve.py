"""Integration: training reduces loss; microbatch-accumulation equivalence;
serving loop with continuous batching; end-to-end PERMANOVA on embeddings."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import SMOKES
from repro.data.tokens import SyntheticTokenDataset
from repro.models.model import build_model
from repro.optim import adamw, sgdm
from repro.serve.engine import Request, ServeLoop
from repro.train.step import make_train_state_init, make_train_step


def test_training_reduces_loss():
    cfg = SMOKES["internlm2-1.8b"]
    model = build_model(cfg)
    opt = adamw()
    step = jax.jit(make_train_step(
        model, opt, schedule=lambda s: jnp.asarray(3e-3)))
    state = make_train_state_init(model, opt)(jax.random.key(0))
    ds = SyntheticTokenDataset(vocab=cfg.vocab, seq_len=32, global_batch=8,
                               seed=0)
    losses = []
    for i in range(30):
        state, metrics = step(state, ds.batch(i))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])
    assert all(np.isfinite(l) for l in losses)


def test_microbatch_accumulation_matches_full_batch():
    cfg = SMOKES["glm4-9b"]
    model = build_model(cfg)
    opt = sgdm(momentum=0.0)
    sched = lambda s: jnp.asarray(1e-2)
    step1 = jax.jit(make_train_step(model, opt, schedule=sched,
                                    n_microbatches=1))
    step4 = jax.jit(make_train_step(model, opt, schedule=sched,
                                    n_microbatches=4))
    state0 = make_train_state_init(model, opt)(jax.random.key(1))
    ds = SyntheticTokenDataset(vocab=cfg.vocab, seq_len=16, global_batch=8,
                               seed=1)
    batch = ds.batch(0)
    s1, m1 = step1(state0, batch)
    s4, m4 = step4(state0, batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-4
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s4.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=2e-5)


def test_serve_loop_continuous_batching():
    cfg = SMOKES["internlm2-1.8b"]
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, size=(3,))
                    .astype(np.int32), max_new_tokens=5) for _ in range(6)]
    loop = ServeLoop(model, params, batch_size=2, max_len=32)
    done = loop.run(reqs, max_steps=200, key=jax.random.key(1))
    assert all(r.done for r in done)
    assert all(len(r.generated) == 5 for r in done)
    for tok in done[0].generated:
        assert 0 <= tok < cfg.vocab


def test_serve_greedy_is_deterministic():
    cfg = SMOKES["glm4-9b"]
    model = build_model(cfg)
    params = model.init(jax.random.key(2))
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab, size=(4,)).astype(np.int32)

    def gen():
        reqs = [Request(prompt=prompt.copy(), max_new_tokens=6)]
        loop = ServeLoop(model, params, batch_size=1, max_len=32)
        return loop.run(reqs, max_steps=64)[0].generated

    assert gen() == gen()


def test_embedding_permanova_end_to_end():
    """The integration the deployment story rests on: model embeddings ->
    distance matrix -> PERMANOVA (DESIGN.md section 6)."""
    from repro.core import distance, permanova

    cfg = SMOKES["internlm2-1.8b"]
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    n, s = 24, 16
    # two "conditions": broad vocabulary vs a narrow 16-token dialect
    groups = np.repeat([0, 1], n // 2).astype(np.int32)
    toks = np.where(
        (groups[:, None] == 0),
        rng.integers(0, cfg.vocab, size=(n, s)),
        rng.integers(0, 16, size=(n, s))).astype(np.int32)

    from repro.models.model import _positions
    h, _ = model._embed_input(params, {"tokens": jnp.asarray(toks)})
    h, _, _ = model._backbone(params, h, _positions(n, s))
    emb = np.asarray(jnp.mean(h, axis=1), np.float32)   # mean-pooled

    dm = distance.euclidean(jnp.asarray(emb))
    res = permanova(dm, jnp.asarray(groups), n_perms=99)
    assert float(res.p_value) <= 0.05   # condition is detectable
