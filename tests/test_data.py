"""Data pipeline: determinism, host sharding, prefetch, resume."""

import numpy as np

from repro.data import (PrefetchLoader, ShardedLoader, SyntheticTokenDataset,
                        synthetic_study)


def test_batches_deterministic_and_seekable():
    ds = SyntheticTokenDataset(vocab=512, seq_len=32, global_batch=8, seed=3)
    a = ds.batch(5)
    b = ds.batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = ds.batch(6)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_targets_are_shifted_tokens():
    ds = SyntheticTokenDataset(vocab=512, seq_len=32, global_batch=4)
    b = ds.batch(0)
    assert b["tokens"].shape == (4, 32)
    assert b["targets"].shape == (4, 32)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


def test_host_shards_partition_global_batch():
    ds = SyntheticTokenDataset(vocab=128, seq_len=8, global_batch=8, seed=1)
    full = ds.batch(0)
    shards = [ds.batch(0, lo=i * 2, hi=(i + 1) * 2) for i in range(4)]
    np.testing.assert_array_equal(
        np.concatenate([s["tokens"] for s in shards]), full["tokens"])


def test_loader_resume_matches_uninterrupted():
    ds = SyntheticTokenDataset(vocab=128, seq_len=8, global_batch=4)
    ref = ShardedLoader(ds)
    seq_ref = [next(ref)["tokens"] for _ in range(6)]

    l1 = ShardedLoader(ds)
    first = [next(l1)["tokens"] for _ in range(3)]
    state = l1.state()
    l2 = ShardedLoader(ds)
    l2.restore(state)
    rest = [next(l2)["tokens"] for _ in range(3)]
    for a, b in zip(seq_ref, first + rest):
        np.testing.assert_array_equal(a, b)


def test_prefetch_loader_preserves_order():
    ds = SyntheticTokenDataset(vocab=64, seq_len=4, global_batch=2)
    base = [ds.batch(i)["tokens"] for i in range(5)]
    pf = PrefetchLoader(iter([ds.batch(i) for i in range(5)]), depth=2)
    got = [b["tokens"] for b in pf]
    assert len(got) == 5
    for a, b in zip(base, got):
        np.testing.assert_array_equal(a, b)


def test_synthetic_study_effect_controls_structure():
    x0, g0 = synthetic_study(40, 30, 2, effect_size=0.0, seed=0)
    x1, g1 = synthetic_study(40, 30, 2, effect_size=5.0, seed=0)
    np.testing.assert_array_equal(g0, g1)
    assert x1.sum() > x0.sum()          # planted bump adds abundance
    assert x0.shape == (40, 30)
