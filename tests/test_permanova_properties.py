"""Hypothesis property tests on the PERMANOVA engine's invariants, plus
the tier-2 statistical-validation suite (slow-marked): null p-value
uniformity over many synthetic studies and full-test invariance under
group-id relabeling, with strategies over ragged group sizes and prime n."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't crash collection
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import fstat, permutations

jax.config.update("jax_platform_name", "cpu")

PRIMES = (7, 11, 13, 17, 19, 23)


def _random_instance(draw):
    n = draw(st.integers(min_value=6, max_value=24))
    g = draw(st.integers(min_value=2, max_value=4))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.default_rng(seed)
    d = rng.random((n, n)).astype(np.float32)
    d = (d + d.T) / 2
    np.fill_diagonal(d, 0.0)
    grouping = rng.integers(0, g, size=n).astype(np.int32)
    # ensure every group non-empty
    grouping[:g] = np.arange(g)
    return d, grouping, g, rng


@st.composite
def instances(draw):
    return _random_instance(draw)


@settings(max_examples=25, deadline=None)
@given(instances())
def test_variants_agree(inst):
    d, grouping, g, rng = inst
    inv_gs = np.asarray(permutations.inv_group_sizes(
        jnp.asarray(grouping), g))
    gperms = np.stack([rng.permutation(grouping) for _ in range(3)])
    mat2 = jnp.asarray(d * d)
    oracle = fstat.sw_algorithm1_numpy(d, gperms, inv_gs)
    for fn, kw in ((fstat.sw_brute, {}), (fstat.sw_matmul,
                                          {"perm_block": 2})):
        got = np.asarray(fn(mat2, jnp.asarray(gperms),
                            jnp.asarray(inv_gs), **kw))
        np.testing.assert_allclose(got, oracle, rtol=5e-4, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(instances())
def test_distance_scaling(inst):
    """d -> c*d scales s_W by c^2 (pure quadratic statistic)."""
    d, grouping, g, rng = inst
    inv_gs = jnp.asarray(np.asarray(permutations.inv_group_sizes(
        jnp.asarray(grouping), g)))
    gperms = jnp.asarray(grouping[None, :])
    c = 2.5
    s1 = np.asarray(fstat.sw_brute(jnp.asarray(d * d), gperms, inv_gs))
    s2 = np.asarray(fstat.sw_brute(jnp.asarray((c * d) ** 2), gperms,
                                   inv_gs))
    np.testing.assert_allclose(s2, c * c * s1, rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(instances())
def test_label_renaming_invariance(inst):
    """Permuting the group LABEL VALUES (not assignments) leaves s_W
    unchanged: the statistic depends only on the partition."""
    d, grouping, g, rng = inst
    relabel = rng.permutation(g)
    grouping2 = relabel[grouping].astype(np.int32)
    mat2 = jnp.asarray(d * d)
    for gr in (grouping, grouping2):
        pass
    inv1 = permutations.inv_group_sizes(jnp.asarray(grouping), g)
    inv2 = permutations.inv_group_sizes(jnp.asarray(grouping2), g)
    s1 = np.asarray(fstat.sw_brute(mat2, jnp.asarray(grouping[None]), inv1))
    s2 = np.asarray(fstat.sw_brute(mat2, jnp.asarray(grouping2[None]), inv2))
    np.testing.assert_allclose(s1, s2, rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(instances())
def test_sw_nonnegative_and_bounded(inst):
    d, grouping, g, rng = inst
    inv_gs = permutations.inv_group_sizes(jnp.asarray(grouping), g)
    gperms = np.stack([rng.permutation(grouping) for _ in range(4)])
    mat2 = jnp.asarray(d * d)
    s_w = np.asarray(fstat.sw_brute(mat2, jnp.asarray(gperms), inv_gs))
    s_t = float(jnp.sum(mat2) / 2.0 / d.shape[0])
    assert np.all(s_w >= -1e-6)
    assert np.all(s_w <= s_t * d.shape[0] + 1e-4)  # loose upper bound


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_permutation_batch_deterministic(seed):
    rng = np.random.default_rng(seed)
    grouping = jnp.asarray(rng.integers(0, 3, size=12).astype(np.int32))
    key = jax.random.key(seed % 1000)
    a = np.asarray(permutations.permutation_batch(key, grouping, 0, 6))
    b = np.asarray(permutations.permutation_batch(key, grouping, 0, 6))
    np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# Strategies over PRIME n (no tile/block ever divides evenly) and RAGGED
# group sizes (explicitly drawn counts, not uniform assignment).
# ---------------------------------------------------------------------------

@st.composite
def ragged_prime_instances(draw):
    """(dm, grouping, g) with prime n and explicitly ragged group sizes."""
    n = draw(st.sampled_from(PRIMES))
    g = draw(st.integers(min_value=2, max_value=min(4, n - 1)))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.default_rng(seed)
    # ragged sizes: every group >= 1, remainder distributed at random
    sizes = np.ones(g, np.int64)
    extra = rng.multinomial(n - g, np.ones(g) / g)
    sizes += extra
    grouping = np.repeat(np.arange(g), sizes).astype(np.int32)
    rng.shuffle(grouping)
    d = rng.random((n, n)).astype(np.float32)
    d = (d + d.T) / 2
    np.fill_diagonal(d, 0.0)
    return d, grouping, g, seed


@settings(max_examples=12, deadline=None)
@given(ragged_prime_instances())
def test_full_test_invariant_under_group_relabeling(inst):
    """Renaming the group ids (a bijection on label VALUES) leaves the
    whole test invariant: observed F, the entire permutation null, and
    the p-value depend only on the partition. Runs the full engine path
    (planner + scheduler), not just one s_W kernel."""
    from repro import engine
    d, grouping, g, seed = inst
    rng = np.random.default_rng(seed + 1)
    relabel = rng.permutation(g)
    grouping2 = relabel[grouping].astype(np.int32)
    key = jax.random.key(seed % 997)
    r1 = engine.run(jnp.asarray(d), jnp.asarray(grouping), n_perms=19,
                    n_groups=g, key=key)
    r2 = engine.run(jnp.asarray(d), jnp.asarray(grouping2), n_perms=19,
                    n_groups=g, key=key)
    np.testing.assert_allclose(float(r1.f_stat), float(r2.f_stat),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(r1.f_perms),
                               np.asarray(r2.f_perms), rtol=1e-4,
                               atol=1e-5)
    assert float(r1.p_value) == float(r2.p_value)
    np.testing.assert_allclose(float(r1.r2), float(r2.r2), rtol=1e-4,
                               atol=1e-6)


@settings(max_examples=12, deadline=None)
@given(ragged_prime_instances())
def test_sw_impls_agree_on_ragged_prime(inst):
    """Cross-impl agreement on the awkward shapes (prime n defeats every
    even tile; ragged sizes exercise the inv_group_sizes weighting)."""
    d, grouping, g, seed = inst
    rng = np.random.default_rng(seed)
    inv_gs = np.asarray(permutations.inv_group_sizes(
        jnp.asarray(grouping), g))
    gperms = np.stack([rng.permutation(grouping) for _ in range(3)])
    mat2 = jnp.asarray(d * d)
    oracle = fstat.sw_algorithm1_numpy(d, gperms, inv_gs)
    for fn, kw in ((fstat.sw_brute, {}), (fstat.sw_tiled, {"tile": 8}),
                   (fstat.sw_matmul, {"perm_block": 2})):
        got = np.asarray(fn(mat2, jnp.asarray(gperms), jnp.asarray(inv_gs),
                            **kw))
        np.testing.assert_allclose(got, oracle, rtol=5e-4, atol=1e-5)


@st.composite
def feature_instances(draw):
    """(n, d) abundance tables + ragged groupings for the fp8 slab
    properties (features, not distance matrices)."""
    n = draw(st.integers(min_value=6, max_value=20))
    d = draw(st.integers(min_value=3, max_value=12))
    g = draw(st.integers(min_value=2, max_value=3))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.default_rng(seed)
    x = rng.gamma(1.0, 1.0, size=(n, d)).astype(np.float32)
    x *= rng.random(size=(n, d)) < 0.6
    x[:, 0] = np.maximum(x[:, 0], 1e-3)
    grouping = rng.integers(0, g, size=n).astype(np.int32)
    grouping[:g] = np.arange(g)
    return x, grouping, g, rng


@settings(max_examples=15, deadline=None)
@given(feature_instances())
def test_fp8_contract_invariant_under_column_reorder(inst):
    """quantize -> contract -> F: reordering feature COLUMNS must not
    change the statistic. The fp8 calibration is a global max-reduce, so
    the quantized values are bit-identical under reordering; only f32
    accumulation order can move, bounded well below quantization noise."""
    from repro.core import distance as dist_mod
    from repro.kernels.fused_sw import ref as fref
    x, grouping, g, rng = inst
    inv_gs = permutations.inv_group_sizes(jnp.asarray(grouping), g)
    gperms = jnp.asarray(np.stack([rng.permutation(grouping)
                                   for _ in range(3)]))
    col_perm = rng.permutation(x.shape[1])
    sws = []
    for table in (x, x[:, col_perm]):
        xp = dist_mod.ROW_METRICS["braycurtis"].prepare(jnp.asarray(table))
        sw, _ = fref.fused_sw_ref(xp, xp, gperms, gperms, inv_gs, 0,
                                  metric="braycurtis", feat_fp8=1)
        sws.append(np.asarray(sw))
    np.testing.assert_allclose(sws[1], sws[0], rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(feature_instances())
def test_fp8_scale_roundtrip_idempotent(inst):
    """Re-quantizing an fp8-round-tripped table under the SAME pinned
    scale is the identity (every value is already e4m3-representable),
    so the contracted statistic is bit-identical — the scale-calibration
    round-trip property the megakernel driver relies on when it computes
    the per-study scale once and reuses it across permutation chunks."""
    from repro.core import distance as dist_mod
    from repro.kernels.fused_sw import ref as fref
    x, grouping, g, rng = inst
    xp = dist_mod.ROW_METRICS["euclidean"].prepare(jnp.asarray(x))
    s = dist_mod.fp8_scale(xp)
    v1 = dist_mod.fp8_roundtrip(xp, s)
    v2 = dist_mod.fp8_roundtrip(v1, s)
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    inv_gs = permutations.inv_group_sizes(jnp.asarray(grouping), g)
    gperms = jnp.asarray(np.stack([rng.permutation(grouping)
                                   for _ in range(2)]))
    sw1, _ = fref.fused_sw_ref(xp, xp, gperms, gperms, inv_gs, 0,
                               metric="euclidean", feat_fp8=1,
                               feat_scale=s)
    sw2, _ = fref.fused_sw_ref(v1, v1, gperms, gperms, inv_gs, 0,
                               metric="euclidean", feat_fp8=1,
                               feat_scale=s)
    np.testing.assert_array_equal(np.asarray(sw1), np.asarray(sw2))


# The tier-2 statistical-validation suite (null p-value uniformity over
# many synthetic studies, slow-marked) lives in
# tests/test_statistical_validation.py — it needs no hypothesis, so it
# must not sit behind this module's importorskip guard.
