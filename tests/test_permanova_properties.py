"""Hypothesis property tests on the PERMANOVA engine's invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't crash collection
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import fstat, permutations

jax.config.update("jax_platform_name", "cpu")


def _random_instance(draw):
    n = draw(st.integers(min_value=6, max_value=24))
    g = draw(st.integers(min_value=2, max_value=4))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.default_rng(seed)
    d = rng.random((n, n)).astype(np.float32)
    d = (d + d.T) / 2
    np.fill_diagonal(d, 0.0)
    grouping = rng.integers(0, g, size=n).astype(np.int32)
    # ensure every group non-empty
    grouping[:g] = np.arange(g)
    return d, grouping, g, rng


@st.composite
def instances(draw):
    return _random_instance(draw)


@settings(max_examples=25, deadline=None)
@given(instances())
def test_variants_agree(inst):
    d, grouping, g, rng = inst
    inv_gs = np.asarray(permutations.inv_group_sizes(
        jnp.asarray(grouping), g))
    gperms = np.stack([rng.permutation(grouping) for _ in range(3)])
    mat2 = jnp.asarray(d * d)
    oracle = fstat.sw_algorithm1_numpy(d, gperms, inv_gs)
    for fn, kw in ((fstat.sw_brute, {}), (fstat.sw_matmul,
                                          {"perm_block": 2})):
        got = np.asarray(fn(mat2, jnp.asarray(gperms),
                            jnp.asarray(inv_gs), **kw))
        np.testing.assert_allclose(got, oracle, rtol=5e-4, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(instances())
def test_distance_scaling(inst):
    """d -> c*d scales s_W by c^2 (pure quadratic statistic)."""
    d, grouping, g, rng = inst
    inv_gs = jnp.asarray(np.asarray(permutations.inv_group_sizes(
        jnp.asarray(grouping), g)))
    gperms = jnp.asarray(grouping[None, :])
    c = 2.5
    s1 = np.asarray(fstat.sw_brute(jnp.asarray(d * d), gperms, inv_gs))
    s2 = np.asarray(fstat.sw_brute(jnp.asarray((c * d) ** 2), gperms,
                                   inv_gs))
    np.testing.assert_allclose(s2, c * c * s1, rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(instances())
def test_label_renaming_invariance(inst):
    """Permuting the group LABEL VALUES (not assignments) leaves s_W
    unchanged: the statistic depends only on the partition."""
    d, grouping, g, rng = inst
    relabel = rng.permutation(g)
    grouping2 = relabel[grouping].astype(np.int32)
    mat2 = jnp.asarray(d * d)
    for gr in (grouping, grouping2):
        pass
    inv1 = permutations.inv_group_sizes(jnp.asarray(grouping), g)
    inv2 = permutations.inv_group_sizes(jnp.asarray(grouping2), g)
    s1 = np.asarray(fstat.sw_brute(mat2, jnp.asarray(grouping[None]), inv1))
    s2 = np.asarray(fstat.sw_brute(mat2, jnp.asarray(grouping2[None]), inv2))
    np.testing.assert_allclose(s1, s2, rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(instances())
def test_sw_nonnegative_and_bounded(inst):
    d, grouping, g, rng = inst
    inv_gs = permutations.inv_group_sizes(jnp.asarray(grouping), g)
    gperms = np.stack([rng.permutation(grouping) for _ in range(4)])
    mat2 = jnp.asarray(d * d)
    s_w = np.asarray(fstat.sw_brute(mat2, jnp.asarray(gperms), inv_gs))
    s_t = float(jnp.sum(mat2) / 2.0 / d.shape[0])
    assert np.all(s_w >= -1e-6)
    assert np.all(s_w <= s_t * d.shape[0] + 1e-4)  # loose upper bound


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_permutation_batch_deterministic(seed):
    rng = np.random.default_rng(seed)
    grouping = jnp.asarray(rng.integers(0, 3, size=12).astype(np.int32))
    key = jax.random.key(seed % 1000)
    a = np.asarray(permutations.permutation_batch(key, grouping, 0, 6))
    b = np.asarray(permutations.permutation_batch(key, grouping, 0, 6))
    np.testing.assert_array_equal(a, b)
