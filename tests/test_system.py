"""End-to-end behaviour tests for the paper's system: the full PERMANOVA
pipeline (abundance -> distance -> permutation test) reproduces the
statistical behaviour the paper's workload relies on, across every
implementation path (jnp variants, Pallas kernels, distributed runner)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import distance, permanova
from repro.core.permanova import SW_IMPLS
from repro.data.microbiome import synthetic_study
from repro.kernels.permanova_sw.ops import make_sw_fn


def _pipeline(effect, impl="matmul", sw_fn=None, n=60, seed=0, perms=99):
    x, grouping = synthetic_study(n, 48, 3, effect_size=effect, seed=seed)
    dm = distance.braycurtis(jnp.asarray(x))
    return permanova(dm, jnp.asarray(grouping), n_perms=perms,
                     sw_impl=impl, sw_fn=sw_fn, key=jax.random.key(seed))


class TestEndToEnd:
    def test_effect_detected_all_paths(self):
        for impl in sorted(SW_IMPLS):
            res = _pipeline(effect=5.0, impl=impl)
            assert float(res.p_value) <= 0.02, impl

        res_k = _pipeline(effect=5.0, sw_fn=make_sw_fn(
            "matmul", tile_r=32, tile_c=32, perm_block=8))
        assert float(res_k.p_value) <= 0.02

    def test_null_calibration(self):
        """Under the null, p-values should be roughly uniform: check that
        across seeds we don't systematically reject."""
        ps = [float(_pipeline(effect=0.0, seed=s, perms=49).p_value)
              for s in range(6)]
        assert np.mean(ps) > 0.2, ps     # not systematically tiny
        assert min(ps) >= 1.0 / 50

    def test_f_stat_monotone_in_effect(self):
        f_values = [float(_pipeline(effect=e, perms=19).f_stat)
                    for e in (0.0, 2.0, 8.0)]
        assert f_values[0] < f_values[1] < f_values[2], f_values

    def test_paper_workload_shape_scaled(self):
        """The paper's invocation pattern (one matrix, thousands of
        permutations) at a laptop scale — all variants, one result."""
        x, grouping = synthetic_study(128, 64, 8, effect_size=1.0, seed=3)
        dm = distance.braycurtis(jnp.asarray(x))
        base = None
        for impl in sorted(SW_IMPLS):
            res = permanova(dm, jnp.asarray(grouping), n_perms=199,
                            sw_impl=impl)
            if base is None:
                base = res
            assert abs(float(res.f_stat) - float(base.f_stat)) < 1e-4
            assert float(res.p_value) == float(base.p_value)
