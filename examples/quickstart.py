"""Quickstart: the paper's workload in five lines of API.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import permanova
from repro.core.distance import distance_matrix
from repro.data.microbiome import synthetic_study

# 1. a microbiome-style study: 256 samples, 3 groups, planted effect
abundance, grouping = synthetic_study(256, 128, 3, effect_size=2.0, seed=0)

# 2. Bray-Curtis distance matrix (the PERMANOVA input)
dm = distance_matrix(jnp.asarray(abundance), "braycurtis")

# 3. the permutation test — sw_impl picks the hot-loop algorithm:
#    "brute" (paper Alg. 1/3), "tiled" (paper Alg. 2), or "matmul"
#    (this framework's MXU reformulation)
result = permanova(dm, jnp.asarray(grouping), n_perms=999,
                   sw_impl="matmul", key=jax.random.key(0))

print(result)
print(f"pseudo-F = {float(result.f_stat):.4f}")
print(f"p-value  = {float(result.p_value):.4f}  "
      f"({result.n_perms} permutations)")
assert float(result.p_value) < 0.05, "planted effect should be detected"
print("OK: group effect detected, as planted.")
