"""EMP-scale PERMANOVA pipeline (scaled to the host).

The paper's benchmark: a 25145^2 UniFrac matrix x 3999 permutations on one
MI300A. This example runs the same shape — abundance table -> distances ->
thousands of permutations -> p-value — through the pipeline subsystem: ONE
joint plan picks the distance impl, the materialization bridge (dense /
stream / fused), and the s_W dataflow for this backend; the streaming
scheduler executes a large permutation sweep in fixed-memory chunks; and
(when a device mesh is available) the distributed runner shards the same
job over every local device. Pass --full on a real cluster for the paper's
exact size.

  PYTHONPATH=src python examples/emp_scale_permanova.py [--n 1024]
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/emp_scale_permanova.py --n 1024
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine, pipeline
from repro.core import fstat, permutations
from repro.core.distance import distance_matrix
from repro.data.microbiome import synthetic_study
from repro.runtime.elastic import ElasticPermutationRunner


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1024)
    ap.add_argument("--features", type=int, default=256)
    ap.add_argument("--groups", type=int, default=8)
    ap.add_argument("--perms", type=int, default=999)
    ap.add_argument("--stream-perms", type=int, default=20000,
                    help="permutation count for the streaming-scheduler step")
    ap.add_argument("--budget-mb", type=float, default=8.0,
                    help="label-tensor budget for the streaming step")
    ap.add_argument("--full", action="store_true",
                    help="the paper's 25145 x 3999 size (cluster only)")
    args = ap.parse_args()
    n = 25145 if args.full else args.n
    perms = 3999 if args.full else args.perms

    print(f"[1/4] building study: n={n} features={args.features}")
    x, grouping = synthetic_study(n, args.features, args.groups,
                                  effect_size=1.5, seed=0)

    print("[2/4] pipeline: features -> p-value under ONE joint plan")
    t0 = time.time()
    res = pipeline.pipeline(jnp.asarray(x), jnp.asarray(grouping),
                            metric="braycurtis", n_perms=perms,
                            key=jax.random.key(0))
    jax.block_until_ready(res.f_perms)
    dt = time.time() - t0
    print(f"      plan: {res.plan}")
    print(f"      {res.n_perms} permutations in {dt:.1f}s "
          f"({res.n_perms/dt:.0f} perms/s)  F={float(res.f_stat):.4f} "
          f"p={float(res.p_value):.4f}")

    print(f"[3/4] single-pass fused-kernel pipeline: {args.stream_perms} "
          f"permutations under a {args.budget_mb:.0f} MiB label budget, "
          "(n, n) matrix never materialized, D² slabs never re-read")
    t0 = time.time()
    res_s = pipeline.pipeline(jnp.asarray(x), jnp.asarray(grouping),
                              metric="braycurtis",
                              n_perms=args.stream_perms,
                              key=jax.random.key(0),
                              materialize="fused-kernel",
                              memory_budget_bytes=args.budget_mb * 2**20)
    dt = time.time() - t0
    print(f"      plan: {res_s.plan}")
    print(f"      {res_s.n_perms} permutations in {dt:.1f}s "
          f"({res_s.n_perms/dt:.0f} perms/s)  p={float(res_s.p_value):.4f} "
          f"— distance tiles contracted in-program, one feature sweep "
          "per chunk")

    dm = distance_matrix(jnp.asarray(x), "braycurtis")
    print("[4/4] distributed + elastic layers")
    try:
        from repro.core.distributed import permanova_distributed
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh()
        t0 = time.time()
        res_d = permanova_distributed(mesh, dm, jnp.asarray(grouping),
                                      n_perms=perms, impl="auto",
                                      key=jax.random.key(0))
        jax.block_until_ready(res_d.f_perms)
        dt = time.time() - t0
        print(f"      {len(jax.devices())} devices: {res_d.n_perms} perms "
              f"in {dt:.1f}s  F={float(res_d.f_stat):.4f}")
    except Exception as e:  # noqa: BLE001 — mesh layer is version-sensitive
        print(f"      (distributed step skipped: {type(e).__name__}: {e})")

    mat2 = jnp.asarray(dm) * jnp.asarray(dm)
    inv_gs = permutations.inv_group_sizes(jnp.asarray(grouping),
                                          args.groups)
    key = jax.random.key(0)

    def compute(worker_id, lo, hi):
        g = permutations.permutation_batch(key, jnp.asarray(grouping),
                                           lo, hi)
        return np.asarray(fstat.sw_matmul(mat2, g, inv_gs), np.float64)

    runner = ElasticPermutationRunner(min(perms + 1, 257), block_size=64)
    s_w = runner.run(compute, workers=[0, 1, 2, 3], fail_at={2: 1})
    print(f"      elastic runner recovered from injected failure; "
          f"events={[h for h in runner.history]}")
    ref = np.asarray(fstat.sw_matmul(
        mat2, permutations.permutation_batch(key, jnp.asarray(grouping),
                                             0, 8), inv_gs))
    print(f"      block results match engine run: "
          f"{np.allclose(s_w[:8], ref)}")


if __name__ == "__main__":
    main()
