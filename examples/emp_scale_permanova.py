"""EMP-scale PERMANOVA pipeline (scaled to the host).

The paper's benchmark: a 25145^2 UniFrac matrix x 3999 permutations on one
MI300A. This example runs the same pipeline shape — distance matrix ->
thousands of permutations -> p-value — sharded over every local device via
the distributed engine, with the elastic runner providing fault tolerance
on top. Pass --full on a real cluster for the paper's exact size.

  PYTHONPATH=src python examples/emp_scale_permanova.py [--n 1024]
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/emp_scale_permanova.py --n 1024
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fstat, permanova, permutations
from repro.core.distance import distance_matrix
from repro.core.distributed import permanova_distributed
from repro.data.microbiome import synthetic_study
from repro.launch.mesh import make_host_mesh
from repro.runtime.elastic import ElasticPermutationRunner


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1024)
    ap.add_argument("--features", type=int, default=256)
    ap.add_argument("--groups", type=int, default=8)
    ap.add_argument("--perms", type=int, default=999)
    ap.add_argument("--full", action="store_true",
                    help="the paper's 25145 x 3999 size (cluster only)")
    args = ap.parse_args()
    n = 25145 if args.full else args.n
    perms = 3999 if args.full else args.perms

    print(f"[1/3] building study: n={n} features={args.features}")
    x, grouping = synthetic_study(n, args.features, args.groups,
                                  effect_size=1.5, seed=0)
    t0 = time.time()
    dm = distance_matrix(jnp.asarray(x), "braycurtis")
    jax.block_until_ready(dm)
    print(f"      distance matrix in {time.time()-t0:.1f}s")

    print(f"[2/3] distributed PERMANOVA over {len(jax.devices())} devices")
    mesh = make_host_mesh()
    t0 = time.time()
    res = permanova_distributed(mesh, dm, jnp.asarray(grouping),
                                n_perms=perms, impl="matmul",
                                key=jax.random.key(0))
    jax.block_until_ready(res.f_perms)
    dt = time.time() - t0
    print(f"      {res.n_perms} permutations in {dt:.1f}s "
          f"({res.n_perms/dt:.0f} perms/s)  F={float(res.f_stat):.4f} "
          f"p={float(res.p_value):.4f}")

    print("[3/3] elastic layer: same job as idempotent blocks "
          "(one worker killed mid-run)")
    mat2 = jnp.asarray(dm) * jnp.asarray(dm)
    inv_gs = permutations.inv_group_sizes(jnp.asarray(grouping),
                                          args.groups)
    key = jax.random.key(0)

    def compute(worker_id, lo, hi):
        g = permutations.permutation_batch(key, jnp.asarray(grouping),
                                           lo, hi)
        return np.asarray(fstat.sw_matmul(mat2, g, inv_gs), np.float64)

    runner = ElasticPermutationRunner(min(perms + 1, 257), block_size=64)
    s_w = runner.run(compute, workers=[0, 1, 2, 3], fail_at={2: 1})
    print(f"      recovered from injected failure; "
          f"events={[h for h in runner.history]}")
    ref = np.asarray(res.f_perms[:len(s_w)])
    print(f"      block results match distributed run: "
          f"{np.allclose(s_w[:8], np.asarray(fstat.sw_matmul(mat2, permutations.permutation_batch(key, jnp.asarray(grouping), 0, 8), inv_gs)))}")


if __name__ == "__main__":
    main()
