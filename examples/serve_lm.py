"""Batched serving with continuous batching + embedding-PERMANOVA analysis.

Serves a small LM with batched requests, then runs the deployment-shape
integration from DESIGN.md section 6: pooled model embeddings -> distance
matrix -> PERMANOVA group-significance test.

  PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import SMOKES
from repro.core import permanova
from repro.core.distance import distance_matrix
from repro.models.model import build_model, _positions
from repro.serve.engine import Request, ServeLoop, temperature_sample


def main():
    cfg = SMOKES["internlm2-1.8b"].replace(n_layers=4, d_model=128,
                                           d_head=32, vocab=4096)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)

    print("[serve] batched generation with continuous batching")
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, size=(4,))
                    .astype(np.int32), max_new_tokens=12)
            for _ in range(10)]
    loop = ServeLoop(model, params, batch_size=4, max_len=64,
                     sampler=temperature_sample(0.9))
    t0 = time.time()
    done = loop.run(reqs, max_steps=400, key=jax.random.key(1))
    n_tok = sum(len(r.generated) for r in done)
    print(f"[serve] {len(done)} requests, {n_tok} tokens in "
          f"{time.time()-t0:.1f}s; sample: {done[0].generated}")
    assert all(r.done for r in done)

    print("[analysis] embedding PERMANOVA over two prompt populations")
    n, s = 32, 24
    groups = np.repeat([0, 1], n // 2).astype(np.int32)
    toks = np.where(groups[:, None] == 0,
                    rng.integers(0, cfg.vocab, size=(n, s)),
                    rng.integers(0, 16, size=(n, s))).astype(np.int32)
    h, _ = model._embed_input(params, {"tokens": jnp.asarray(toks)})
    h, _, _ = model._backbone(params, h, _positions(n, s))
    emb = jnp.mean(h, axis=1)
    dm = distance_matrix(emb.astype(jnp.float32), "euclidean")
    res = permanova(dm, jnp.asarray(groups), n_perms=199)
    print(f"[analysis] F={float(res.f_stat):.3f} "
          f"p={float(res.p_value):.4f} -> populations "
          f"{'differ' if res.p_value < 0.05 else 'indistinguishable'}")


if __name__ == "__main__":
    main()
