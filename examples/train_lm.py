"""End-to-end training driver: train a small LM for a few hundred steps on
the synthetic token stream, with checkpoints and a mid-run failure+restart.

  PYTHONPATH=src python examples/train_lm.py --steps 300

--size 100m builds a ~100M-param dense model (cluster-scale CPUs/TPUs);
the default ~10M keeps a 1-core CPU run in minutes.
"""

import argparse
import time

import jax
import numpy as np

from repro.configs.registry import SMOKES
from repro.data.tokens import SyntheticTokenDataset
from repro.models.model import build_model
from repro.optim import adamw, warmup_cosine
from repro.runtime.trainer import FaultTolerantTrainer
from repro.train.step import make_train_state_init, make_train_step
from repro.utils.tree import tree_count

SIZES = {
    # name: (layers, d_model, heads, kv, d_ff)
    "10m": (4, 256, 8, 4, 1024),
    "30m": (6, 512, 8, 4, 2048),
    "100m": (12, 768, 12, 6, 3072),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--size", default="10m", choices=sorted(SIZES))
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--fail-at", type=int, default=None)
    args = ap.parse_args()

    l, d, h, kv, ff = SIZES[args.size]
    cfg = SMOKES["internlm2-1.8b"].replace(
        name=f"train-lm-{args.size}", n_layers=l, d_model=d, n_heads=h,
        n_kv_heads=kv, d_head=d // h, d_ff=ff, vocab=8192,
        attn_q_chunk=64)
    model = build_model(cfg)
    opt = adamw()
    schedule = warmup_cosine(peak=args.lr, warmup_steps=args.steps // 20 + 1,
                             total_steps=args.steps)
    step = jax.jit(make_train_step(model, opt, schedule=schedule))
    init = make_train_state_init(model, opt)
    n_params = tree_count(jax.eval_shape(init, jax.random.key(0)).params)
    print(f"[train_lm] {cfg.name}: {n_params/1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.batch} x seq {args.seq}")

    ds = SyntheticTokenDataset(vocab=cfg.vocab, seq_len=args.seq,
                               global_batch=args.batch, seed=0)
    trainer = FaultTolerantTrainer(train_step=step, init_state=init,
                                   dataset=ds, ckpt_dir=args.ckpt_dir,
                                   checkpoint_every=50)
    t0 = time.time()
    report = trainer.run(n_steps=args.steps, seed=0,
                         fail_at_step=args.fail_at)
    dt = time.time() - t0
    losses = report.losses
    k = max(len(losses) // 10, 1)
    print(f"[train_lm] done in {dt:.0f}s "
          f"({report.steps_run * args.batch * args.seq / dt:.0f} tok/s) "
          f"restarts={report.restarts}")
    print(f"[train_lm] loss: start={np.mean(losses[:k]):.3f} "
          f"end={np.mean(losses[-k:]):.3f}")
    assert np.mean(losses[-k:]) < np.mean(losses[:k]) - 0.3, \
        "training should reduce loss"
    print("OK: loss decreased.")


if __name__ == "__main__":
    main()
