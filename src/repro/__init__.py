"""repro — a multi-pod JAX framework reproducing and extending
*Comparing CPU and GPU compute of PERMANOVA on MI300A* (Sfiligoi, PEARC25).

Layers:
  core/       PERMANOVA statistics engine (the paper's contribution)
  engine/     hardware-aware execution layer: s_W impl registry,
              planner/autotuner, streaming permutation scheduler
  pipeline/   end-to-end features->p-value subsystem: distance impl
              registry, joint two-stage planner, dense/stream/fused
              materialization bridges, batched pipeline_many
  kernels/    Pallas TPU kernels for the hot loops (+ jnp oracles)
  obs/        zero-dependency telemetry: trace spans (Chrome/Perfetto
              export), compile/traffic counters, predicted-vs-measured
              bandwidth reconciliation (obs.report)
  models/     assigned LM-architecture zoo (dense / MoE / SSM / hybrid / enc-dec)
  sharding/   logical-axis -> mesh partition rules
  train/      training step, microbatching, remat
  serve/      KV-cache prefill/decode serving
  optim/      optimizers, schedules, gradient compression
  data/       synthetic pipelines (tokens + microbiome abundance)
  checkpoint/ sharded checkpoints with async write + resume
  runtime/    fault tolerance: heartbeats, elastic re-mesh, stragglers
  roofline/   compiled-HLO roofline analysis (compute/memory/collective)
  configs/    architecture + experiment configs
  launch/     mesh construction, dry-run, train/serve/permanova drivers
"""

__version__ = "1.0.0"
