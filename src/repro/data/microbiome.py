"""Synthetic microbiome-style abundance tables for the PERMANOVA pipeline.

The paper's input was the EMP Unweighted-UniFrac matrix (25145 samples).
We generate compositional abundance tables with planted group structure so
the end-to-end pipeline (abundance -> distance -> PERMANOVA) has a known
ground truth: effect_size=0 gives uniform p-values (the null calibration
test), effect_size>>0 gives p ~ 1/(n_perms+1).
"""

from __future__ import annotations

import numpy as np


def synthetic_abundance(n_samples: int, n_features: int, *, seed: int = 0,
                        sparsity: float = 0.7) -> np.ndarray:
    rng = np.random.default_rng(seed)
    x = rng.gamma(0.7, 1.0, size=(n_samples, n_features))
    mask = rng.random((n_samples, n_features)) < sparsity
    x[mask] = 0.0
    return x.astype(np.float32)


def synthetic_study(n_samples: int, n_features: int, n_groups: int, *,
                    effect_size: float = 0.0, seed: int = 0,
                    sparsity: float = 0.7):
    """(abundance (n, d), grouping (n,)) with a planted group effect.

    effect_size shifts each group's mean abundance on a random subset of
    features; 0.0 = exact null (labels independent of data).
    """
    rng = np.random.default_rng(seed)
    x = synthetic_abundance(n_samples, n_features, seed=seed + 1,
                            sparsity=sparsity)
    grouping = rng.integers(0, n_groups, size=n_samples).astype(np.int32)
    if effect_size > 0:
        for g in range(n_groups):
            feat = rng.choice(n_features, size=max(n_features // 10, 1),
                              replace=False)
            bump = rng.gamma(effect_size, 1.0,
                             size=(int((grouping == g).sum()), len(feat)))
            x[np.ix_(grouping == g, feat)] += bump.astype(np.float32)
    return x, grouping


def synthetic_sparse_counts(n_samples: int, n_features: int, *,
                            density: float = 0.1, seed: int = 0,
                            cache_dir=None, slab_rows: int = 1024,
                            fmt: str = "dense", n_groups: int = 8):
    """EMP-scale sparse count table written STRAIGHT into a slab cache.

    Generates one row slab at a time (rng seeded per (seed, slab), so any
    slab is reproducible independently) and appends it to a
    SlabCacheWriter — the dense (n, d) array never exists, which is the
    point: this is the ingestion path for tables bigger than memory.
    fmt='csr' stores presence structure only (the packed-bit jaccard
    diet). Returns (SlabCache, grouping (n,) int32).
    """
    from repro.data import slabcache as _slabcache
    if cache_dir is None:
        raise ValueError("synthetic_sparse_counts writes a slab cache; "
                         "pass cache_dir=")
    slab_rows = max(1, min(int(slab_rows), n_samples))
    writer = _slabcache.SlabCacheWriter(cache_dir, d=n_features,
                                        slab_rows=slab_rows, fmt=fmt)
    for slab_idx, lo in enumerate(range(0, n_samples, slab_rows)):
        rows = min(slab_rows, n_samples - lo)
        rng = np.random.default_rng((seed, slab_idx))
        x = rng.gamma(0.7, 1.0, size=(rows, n_features)).astype(np.float32)
        x[rng.random((rows, n_features)) >= density] = 0.0
        writer.append(x)
    cache = writer.finalize()
    grng = np.random.default_rng((seed, 0x6772))   # distinct label stream
    grouping = grng.integers(0, n_groups, size=n_samples).astype(np.int32)
    grouping[:n_groups] = np.arange(n_groups)   # every group non-empty
    return cache, grouping


def synthetic_design(n_samples: int, *, covariate_names=("age", "depth"),
                     n_strata: int = 0, weighted: bool = False,
                     seed: int = 0):
    """Synthetic design columns to pair with `synthetic_study`.

    Returns (covariates dict name->(n,) f64 | None, strata (n,) int32 |
    None, weights (n,) f64 | None) — the operands of the partial /
    covariate PERMANOVA path (core.design). Covariates are standard
    normals (null: independent of the abundance table); strata are
    balanced blocks; weights are positive gammas. Deterministic per seed.
    """
    rng = np.random.default_rng(seed + 17)
    covariates = None
    if covariate_names:
        covariates = {str(name): rng.normal(size=n_samples)
                      for name in covariate_names}
    strata = None
    if n_strata and n_strata > 1:
        strata = rng.integers(0, n_strata, size=n_samples).astype(np.int32)
        strata[:n_strata] = np.arange(n_strata)     # every block non-empty
    weights = None
    if weighted:
        weights = rng.gamma(4.0, 0.25, size=n_samples)
    return covariates, strata, weights
