"""Disk-backed feature-slab cache + async double-buffered device prefetch.

The out-of-core tier of the residency model: feature tables too large for
device (or host) memory live as one file per ROW SLAB under a cache
directory, written once by a build step and streamed back — slab k+1 is
read and copied to device by a background thread while the fused sweep
contracts slab k, so disk latency hides behind compute exactly as the
HBM→VMEM double-buffering does one tier up.

Layout of a cache directory:

  slabmeta.json      schema/shape/format manifest — written LAST and
                     atomically (tmp + fsync + os.replace), so a crashed
                     build is indistinguishable from no cache at all
  slab_00000.bin …   one file per row slab:
                       dense  raw float32, C-order (rows, d)
                       csr    int64 indptr (rows+1) ++ int32 col indices —
                              presence/absence STRUCTURE only, so
                              presence metrics (packed-bit jaccard) read
                              only the nonzeros from disk

Corrupt or truncated slab files are quarantined to `<file>.corrupt` on
open (warn-once via logging + `slabcache.corrupt_quarantined` counter,
mirroring the autotune-cache loader) and the open fails with a clear
error telling the caller to rebuild.

`SlabPrefetcher` is the host→device half: a background thread reads each
scheduled slab into a small ring of reused staging buffers and copies it
to the device (`jnp.array` — an owning copy, so the ring can recycle;
`jax.device_put` would alias the staging memory on CPU backends). The
consumer's blocking time is metered into the `prefetch.stall_ms` counter
and a `prefetch.wait` span — the overlap proof the bench rows stamp.
"""

from __future__ import annotations

import dataclasses
import json
import logging
import os
import queue
import threading
import time
from typing import Iterable, Optional, Sequence

import numpy as np

from repro.obs import metrics as _metrics

_log = logging.getLogger("repro.data.slabcache")
_WARNED: set = set()

META_NAME = "slabmeta.json"
SCHEMA = 1
FORMATS = ("dense", "csr")
DEFAULT_SLAB_ROWS = 1024


class SlabCacheError(RuntimeError):
    """A slab cache is missing, malformed, or truncated."""


def _warn_once(tag: str, msg: str) -> None:
    """Log a cache-health warning once per process. logging, not warnings —
    tier-1 runs warning-free (same contract as the autotune cache)."""
    if tag in _WARNED:
        return
    _WARNED.add(tag)
    _log.warning(msg)


def _slab_name(i: int) -> str:
    return f"slab_{i:05d}.bin"


def _quarantine(path: str, why: str) -> str:
    """Move a bad slab file aside so the evidence survives and a rebuild
    starts clean; returns the human-readable location note."""
    quarantined = f"{path}.corrupt"
    try:
        os.replace(path, quarantined)
        where = f"; quarantined to {quarantined}"
    except OSError:
        where = " (quarantine rename failed; leaving in place)"
    _metrics.inc("slabcache.corrupt_quarantined")
    _warn_once("corrupt",
               f"slab cache file {path} is corrupt ({why}){where}. "
               "Rebuild the cache with build_slab_cache().")
    return where


@dataclasses.dataclass(frozen=True)
class SlabMeta:
    """Manifest of one cache directory (the slabmeta.json document)."""
    n: int
    d: int
    slab_rows: int
    fmt: str                      # 'dense' | 'csr'
    n_slabs: int
    slab_nnz: Optional[tuple] = None   # csr: nonzeros per slab

    def rows_in_slab(self, i: int) -> int:
        return min(self.slab_rows, self.n - i * self.slab_rows)

    def slab_file_bytes(self, i: int) -> int:
        rows = self.rows_in_slab(i)
        if self.fmt == "dense":
            return rows * self.d * 4
        return 8 * (rows + 1) + 4 * int(self.slab_nnz[i])


class SlabCacheWriter:
    """Append-rows builder: buffers incoming rows and flushes one slab
    file per `slab_rows`, so the full (n, d) table never has to exist —
    `synthetic_sparse_counts` generates and appends slab-sized pieces."""

    def __init__(self, path, *, d: int, slab_rows: int = DEFAULT_SLAB_ROWS,
                 fmt: str = "dense"):
        if fmt not in FORMATS:
            raise ValueError(f"fmt={fmt!r}; expected one of {FORMATS}")
        if slab_rows < 1:
            raise ValueError(f"slab_rows must be >= 1, got {slab_rows}")
        self.path = str(path)
        self.d = int(d)
        self.slab_rows = int(slab_rows)
        self.fmt = fmt
        self._pending: list = []
        self._pending_rows = 0
        self._n = 0
        self._slab_nnz: list = []
        self._n_slabs = 0
        self._finalized = False
        os.makedirs(self.path, exist_ok=True)

    def append(self, rows: np.ndarray) -> None:
        if self._finalized:
            raise SlabCacheError("writer already finalized")
        rows = np.asarray(rows, np.float32)
        if rows.ndim != 2 or rows.shape[1] != self.d:
            raise ValueError(f"expected (r, {self.d}) rows; "
                             f"got shape {rows.shape}")
        self._pending.append(rows)
        self._pending_rows += rows.shape[0]
        self._n += rows.shape[0]
        while self._pending_rows >= self.slab_rows:
            self._flush_slab(self.slab_rows)

    def _take_pending(self, k: int) -> np.ndarray:
        out, taken = [], 0
        while taken < k:
            head = self._pending[0]
            need = k - taken
            if head.shape[0] <= need:
                out.append(head)
                taken += head.shape[0]
                self._pending.pop(0)
            else:
                out.append(head[:need])
                self._pending[0] = head[need:]
                taken = k
        self._pending_rows -= k
        return out[0] if len(out) == 1 else np.concatenate(out, axis=0)

    def _flush_slab(self, k: int) -> None:
        block = np.ascontiguousarray(self._take_pending(k), np.float32)
        fpath = os.path.join(self.path, _slab_name(self._n_slabs))
        if self.fmt == "dense":
            expect = block.shape[0] * self.d * 4
            with open(fpath, "wb") as f:
                block.tofile(f)
                f.flush()
                os.fsync(f.fileno())
        else:
            mask = block > 0
            indptr = np.zeros((block.shape[0] + 1,), np.int64)
            np.cumsum(mask.sum(axis=1), out=indptr[1:])
            indices = np.nonzero(mask)[1].astype(np.int32)
            self._slab_nnz.append(int(indices.shape[0]))
            expect = 8 * indptr.shape[0] + 4 * indices.shape[0]
            with open(fpath, "wb") as f:
                indptr.tofile(f)
                indices.tofile(f)
                f.flush()
                os.fsync(f.fileno())
        got = os.path.getsize(fpath)
        if got != expect:
            raise SlabCacheError(
                f"slab cache build wrote {got} bytes to {fpath}, expected "
                f"{expect} (disk full or interrupted write?); the cache at "
                f"{self.path} is incomplete — rebuild it")
        self._n_slabs += 1

    def finalize(self) -> "SlabCache":
        """Flush the tail slab and publish the manifest (meta is written
        last + atomically: no slabmeta.json, no cache)."""
        if self._finalized:
            raise SlabCacheError("writer already finalized")
        if self._pending_rows:
            self._flush_slab(self._pending_rows)
        if self._n == 0:
            raise SlabCacheError("cannot finalize an empty slab cache")
        self._finalized = True
        meta = {"schema": SCHEMA, "n": self._n, "d": self.d,
                "slab_rows": self.slab_rows, "fmt": self.fmt,
                "n_slabs": self._n_slabs}
        if self.fmt == "csr":
            meta["slab_nnz"] = self._slab_nnz
        tmp = os.path.join(self.path, META_NAME + ".tmp")
        with open(tmp, "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, os.path.join(self.path, META_NAME))
        return SlabCache.open(self.path)

    def __enter__(self):
        return self

    def __exit__(self, exc_type, *exc):
        # publish only on a clean exit; a failed build leaves no manifest
        if exc_type is None and not self._finalized:
            self.finalize()
        return False


def build_slab_cache(path, x, *, slab_rows: int = DEFAULT_SLAB_ROWS,
                     fmt: str = "dense") -> "SlabCache":
    """One-shot build from an in-memory (n, d) array (the migration path;
    generators should append to a SlabCacheWriter slab-by-slab instead)."""
    x = np.asarray(x, np.float32)
    if x.ndim != 2:
        raise ValueError(f"expected (n, d) features; got shape {x.shape}")
    w = SlabCacheWriter(path, d=x.shape[1],
                        slab_rows=min(int(slab_rows), x.shape[0]), fmt=fmt)
    for lo in range(0, x.shape[0], w.slab_rows):
        w.append(x[lo:lo + w.slab_rows])
    return w.finalize()


class SlabCache:
    """Read side of a cache directory: validated manifest + slab reads."""

    def __init__(self, path: str, meta: SlabMeta):
        self.path = path
        self.meta = meta

    # -- properties the planner sizes tiers from --------------------------
    @property
    def n(self) -> int:
        return self.meta.n

    @property
    def d(self) -> int:
        return self.meta.d

    @property
    def slab_rows(self) -> int:
        return self.meta.slab_rows

    @property
    def n_slabs(self) -> int:
        return self.meta.n_slabs

    @property
    def fmt(self) -> str:
        return self.meta.fmt

    @property
    def feature_bytes(self) -> int:
        """Device-resident footprint of the expanded f32 table."""
        return 4 * self.meta.n * self.meta.d

    @property
    def disk_bytes(self) -> int:
        """Bytes actually on disk (csr: structure only — the 'reads only
        nonzeros' win the planner's disk-traffic model charges)."""
        return sum(self.meta.slab_file_bytes(i)
                   for i in range(self.meta.n_slabs))

    @classmethod
    def open(cls, path) -> "SlabCache":
        path = str(path)
        mpath = os.path.join(path, META_NAME)
        try:
            with open(mpath) as f:
                raw = json.load(f)
        except FileNotFoundError:
            raise SlabCacheError(
                f"no slab cache at {path} ({META_NAME} missing); build one "
                "with build_slab_cache()") from None
        except (OSError, ValueError) as e:
            where = _quarantine(mpath, str(e))
            raise SlabCacheError(
                f"slab cache manifest {mpath} is unreadable{where}; "
                "rebuild the cache") from None
        try:
            if int(raw["schema"]) != SCHEMA:
                raise SlabCacheError(
                    f"slab cache {path} has schema {raw['schema']}, this "
                    f"code reads schema {SCHEMA}; rebuild the cache")
            meta = SlabMeta(
                n=int(raw["n"]), d=int(raw["d"]),
                slab_rows=int(raw["slab_rows"]), fmt=str(raw["fmt"]),
                n_slabs=int(raw["n_slabs"]),
                slab_nnz=(tuple(int(v) for v in raw["slab_nnz"])
                          if raw.get("slab_nnz") is not None else None))
        except SlabCacheError:
            raise
        except (KeyError, TypeError, ValueError) as e:
            where = _quarantine(mpath, f"bad manifest field: {e!r}")
            raise SlabCacheError(
                f"slab cache manifest {mpath} is malformed{where}; "
                "rebuild the cache") from None
        if meta.fmt not in FORMATS:
            raise SlabCacheError(f"slab cache {path}: unknown format "
                                 f"{meta.fmt!r}; expected one of {FORMATS}")
        if meta.fmt == "csr" and (meta.slab_nnz is None
                                  or len(meta.slab_nnz) != meta.n_slabs):
            raise SlabCacheError(f"slab cache {path}: csr manifest is "
                                 "missing per-slab nnz; rebuild the cache")
        # Validate every slab file's size against the manifest up front —
        # a truncated slab must fail the open, not corrupt a sweep later.
        for i in range(meta.n_slabs):
            fpath = os.path.join(path, _slab_name(i))
            expect = meta.slab_file_bytes(i)
            try:
                got = os.path.getsize(fpath)
            except OSError:
                raise SlabCacheError(
                    f"slab cache {path} is missing {_slab_name(i)}; "
                    "rebuild the cache") from None
            if got != expect:
                where = _quarantine(fpath,
                                    f"{got} bytes on disk, expected {expect}")
                raise SlabCacheError(
                    f"slab cache {path}: {_slab_name(i)} is truncated "
                    f"({got} bytes, expected {expect}){where}; rebuild "
                    "the cache")
        return cls(path, meta)

    def rows_in_slab(self, i: int) -> int:
        return self.meta.rows_in_slab(i)

    def read_slab(self, i: int, out: Optional[np.ndarray] = None
                  ) -> np.ndarray:
        """Slab i as (rows_i, d) float32 (csr slabs expand to 0/1
        presence). With `out` (a (>=rows_i, d) staging buffer) the read
        fills and returns a view of it — the prefetcher's ring path."""
        if not 0 <= i < self.meta.n_slabs:
            raise IndexError(f"slab {i} out of range "
                             f"[0, {self.meta.n_slabs})")
        rows = self.meta.rows_in_slab(i)
        d = self.meta.d
        if out is None:
            out = np.empty((rows, d), np.float32)
        dst = out[:rows]
        fpath = os.path.join(self.path, _slab_name(i))
        if self.meta.fmt == "dense":
            with open(fpath, "rb") as f:
                flat = np.fromfile(f, np.float32, rows * d)
            dst[:] = flat.reshape(rows, d)
        else:
            with open(fpath, "rb") as f:
                indptr = np.fromfile(f, np.int64, rows + 1)
                indices = np.fromfile(f, np.int32,
                                      int(self.meta.slab_nnz[i]))
            dst[:] = 0.0
            row_ids = np.repeat(np.arange(rows), np.diff(indptr))
            dst[row_ids, indices] = 1.0
        return dst

    def to_array(self) -> np.ndarray:
        """The full (n, d) float32 table — the 'hbm' residency short
        circuit (features fit on device; stream once, then run the
        in-memory bridges)."""
        out = np.empty((self.meta.n, self.meta.d), np.float32)
        for i in range(self.meta.n_slabs):
            lo = i * self.meta.slab_rows
            self.read_slab(i, out=out[lo:lo + self.meta.slab_rows])
        return out


# ---------------------------------------------------------------------------
# Async double-buffered host→device prefetch.
# ---------------------------------------------------------------------------

_DONE = object()


class SlabPrefetcher:
    """Background thread streaming scheduled slabs to the device.

    schedule: slab indices in consumption order (repeats allowed — the OOC
    sweep re-reads the column stream once per row slab). `depth` bounds the
    queue, so at most `depth` device slabs are in flight beyond the one the
    consumer holds: slab k+1 loads while slab k is swept (double-buffered
    at the default depth=2). Each slab is padded to `pad_to` rows with
    zeros (one compiled tile program serves every slab; pad rows are
    masked by global row ids downstream).

    The device copy happens IN the worker thread via `jnp.array` — an
    owning copy (`jax.device_put` of a numpy array may alias its memory on
    CPU backends, and the staging ring reuses buffers) — and is blocked
    until ready there, so consumer stall time measures only what the
    overlap failed to hide. Iteration yields (slab_index, device_array);
    use as a context manager — close() joins the thread even when the
    sweep dies mid-iteration (the exception-safety regression test)."""

    def __init__(self, cache: SlabCache, schedule: Sequence[int], *,
                 depth: int = 2, pad_to: Optional[int] = None):
        self.cache = cache
        self.schedule = list(schedule)
        self.depth = max(1, int(depth))
        self.pad_to = int(pad_to if pad_to is not None else cache.slab_rows)
        if self.pad_to < cache.slab_rows:
            raise ValueError(f"pad_to={self.pad_to} smaller than the "
                             f"cache's slab_rows={cache.slab_rows}")
        self.stall_s = 0.0
        self.bytes_read = 0
        self.slabs_fetched = 0
        self._q: queue.Queue = queue.Queue(maxsize=self.depth)
        self._stop = threading.Event()
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._worker, daemon=True,
                                        name="slab-prefetch")
        self._thread.start()

    # -- worker side ------------------------------------------------------
    def _put(self, item) -> bool:
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def _worker(self):
        import jax
        import jax.numpy as jnp
        from repro import obs as _obs
        cache = self.cache
        # two staging buffers: the ring is safe to recycle because the
        # device copy completes (block_until_ready) before reuse
        ring = [np.zeros((self.pad_to, cache.d), np.float32)
                for _ in range(2)]
        try:
            for pos, idx in enumerate(self.schedule):
                if self._stop.is_set():
                    return
                buf = ring[pos % 2]
                with _obs.span("prefetch.fetch", {"slab": int(idx)}):
                    rows = cache.rows_in_slab(idx)
                    cache.read_slab(idx, out=buf)
                    if rows < self.pad_to:
                        buf[rows:] = 0.0
                    dev = jax.block_until_ready(jnp.array(buf))
                self.bytes_read += cache.meta.slab_file_bytes(idx)
                self.slabs_fetched += 1
                _metrics.inc("prefetch.slabs")
                _metrics.inc("prefetch.bytes",
                             cache.meta.slab_file_bytes(idx))
                if not self._put((int(idx), dev)):
                    return
        except BaseException as e:  # noqa: BLE001 — surfaced to consumer
            self._err = e
            self._put(_DONE)
            return
        self._put(_DONE)

    # -- consumer side ----------------------------------------------------
    def __iter__(self):
        return self

    def __next__(self):
        from repro import obs as _obs
        t0 = time.perf_counter()
        with _obs.span("prefetch.wait"):
            item = self._q.get()
        stall = time.perf_counter() - t0
        self.stall_s += stall
        _metrics.inc("prefetch.stall_ms", stall * 1e3)
        if item is _DONE:
            if self._err is not None:
                err, self._err = self._err, None
                raise SlabCacheError(
                    f"slab prefetch failed: {err!r}") from err
            raise StopIteration
        return item

    def close(self) -> None:
        """Stop the worker and join it (idempotent; safe mid-iteration):
        drain the bounded queue so a blocked put observes the stop flag."""
        self._stop.set()
        while True:
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        if self._thread.is_alive():
            self._thread.join(timeout=10.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def ooc_schedule(n_slabs: int) -> Iterable[int]:
    """The OOC sweep's slab consumption order: for each row slab r, fetch
    r (the row operand), then stream every column slab. Total fetches =
    n_slabs * (n_slabs + 1) — the disk-traffic model's slab count."""
    for r in range(n_slabs):
        yield r
        for c in range(n_slabs):
            yield c
