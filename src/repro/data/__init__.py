from repro.data.tokens import SyntheticTokenDataset, make_token_batches  # noqa: F401
from repro.data.microbiome import (synthetic_abundance,  # noqa: F401
                                   synthetic_sparse_counts, synthetic_study)
from repro.data.loader import PrefetchLoader, ShardedLoader  # noqa: F401
from repro.data.slabcache import (SlabCache, SlabCacheError,  # noqa: F401
                                  SlabCacheWriter, SlabPrefetcher,
                                  build_slab_cache)
