from repro.data.tokens import SyntheticTokenDataset, make_token_batches  # noqa: F401
from repro.data.microbiome import synthetic_abundance, synthetic_study  # noqa: F401
from repro.data.loader import PrefetchLoader, ShardedLoader  # noqa: F401
