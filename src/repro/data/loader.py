"""Host-side loaders: per-host sharding + background prefetch.

ShardedLoader slices each global batch to this host's row range (process
index over the data-parallel axis); PrefetchLoader overlaps host data
generation with device compute via a single background thread — the CPU-host
analogue of overlapping the input pipeline with the step (distributed-
optimization checklist item: overlap compute/IO).
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional


class ShardedLoader:
    def __init__(self, dataset, *, n_hosts: int = 1, host_index: int = 0,
                 start_batch: int = 0):
        self.dataset = dataset
        self.n_hosts = n_hosts
        self.host_index = host_index
        self.index = start_batch   # resumable: checkpoint stores this

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        b = self.dataset.global_batch
        per = b // self.n_hosts
        lo = self.host_index * per
        batch = self.dataset.batch(self.index, lo=lo, hi=lo + per)
        self.index += 1
        return batch

    def state(self) -> dict:
        return {"index": self.index}

    def restore(self, state: dict):
        self.index = int(state["index"])


class PrefetchLoader:
    """Wraps an iterator with a depth-k background prefetch queue."""

    def __init__(self, it: Iterator, depth: int = 2):
        self.it = it
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._done = object()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        try:
            for item in self.it:
                self.q.put(item)
        finally:
            self.q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is self._done:
            raise StopIteration
        return item
