"""Synthetic token pipeline for LM training.

Deterministic, seekable, shardable: batch i is a pure function of
(seed, i), so any host can regenerate any step's data after a failure or an
elastic re-shard — the same idempotence contract the PERMANOVA permutation
engine uses (DESIGN.md section 4).

The stream is a Zipf-ish unigram mixture with short-range repetition so a
trained model shows a decreasing, non-trivial loss curve (pure uniform
tokens would bottom out at log V immediately).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticTokenDataset:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    repeat_p: float = 0.3

    def _unigram(self):
        ranks = np.arange(1, self.vocab + 1, dtype=np.float64)
        probs = ranks ** (-self.zipf_a)
        return probs / probs.sum()

    def batch(self, index: int, *, lo: int = 0, hi: int | None = None):
        """Batch rows [lo, hi) of global batch `index` (host data shard)."""
        hi = self.global_batch if hi is None else hi
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, index]))
        probs = self._unigram()
        b = self.global_batch
        s = self.seq_len + 1
        toks = rng.choice(self.vocab, size=(b, s), p=probs).astype(np.int32)
        # short-range repetition: with prob repeat_p copy the token 2 back
        rep = rng.random((b, s)) < self.repeat_p
        for shift in (2,):
            toks[:, shift:] = np.where(rep[:, shift:],
                                       toks[:, :-shift], toks[:, shift:])
        toks = toks[lo:hi]
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


def make_token_batches(vocab: int, seq_len: int, global_batch: int,
                       n_batches: int, *, seed: int = 0):
    ds = SyntheticTokenDataset(vocab=vocab, seq_len=seq_len,
                               global_batch=global_batch, seed=seed)
    for i in range(n_batches):
        yield ds.batch(i)
