"""Distance-matrix construction — the substrate feeding PERMANOVA.

The paper's input was an Unweighted-UniFrac matrix over EMP data (computed by
a separate tool, ref [9]); the PERMANOVA code path consumes an arbitrary
symmetric zero-diagonal matrix. We provide the standard ecology metrics on
abundance tables plus a blockwise driver so 100k-sample tables stream in row
blocks instead of materializing (n, n, d) intermediates.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array


def euclidean(x: Array) -> Array:
    """Pairwise Euclidean via the Gram trick (MXU-friendly)."""
    sq = jnp.sum(x * x, axis=-1)
    g = x @ x.T
    d2 = sq[:, None] + sq[None, :] - 2.0 * g
    d2 = jnp.maximum(d2, 0.0)
    d = jnp.sqrt(d2)
    return _zero_diag(d)


def braycurtis(x: Array, *, block: int = 256) -> Array:
    """Bray-Curtis dissimilarity: sum|xi-xj| / sum(xi+xj), blocked over rows."""
    def rows(xb):
        num = jnp.sum(jnp.abs(xb[:, None, :] - x[None, :, :]), axis=-1)
        den = jnp.sum(xb[:, None, :] + x[None, :, :], axis=-1)
        return num / jnp.maximum(den, 1e-30)
    return _zero_diag(_blocked_rows(rows, x, block))


def jaccard(x: Array, *, block: int = 256) -> Array:
    """Binary Jaccard distance on presence/absence (x > 0)."""
    b = (x > 0)
    def rows(bb):
        inter = jnp.sum(bb[:, None, :] & b[None, :, :], axis=-1)
        union = jnp.sum(bb[:, None, :] | b[None, :, :], axis=-1)
        return 1.0 - inter / jnp.maximum(union, 1)
    return _zero_diag(_blocked_rows(rows, b, block).astype(jnp.float32))


def aitchison(x: Array, *, pseudocount: float = 0.5) -> Array:
    """Aitchison distance: Euclidean over clr-transformed compositions."""
    xp = x + pseudocount
    logx = jnp.log(xp)
    clr = logx - jnp.mean(logx, axis=-1, keepdims=True)
    return euclidean(clr)


METRICS: dict[str, Callable] = {
    "euclidean": euclidean,
    "braycurtis": braycurtis,
    "jaccard": jaccard,
    "aitchison": aitchison,
}


def distance_matrix(x: Array, metric: str = "braycurtis", **kw) -> Array:
    return METRICS[metric](x, **kw)


def _zero_diag(d: Array) -> Array:
    n = d.shape[0]
    return d * (1.0 - jnp.eye(n, dtype=d.dtype))


def _blocked_rows(row_fn: Callable, x: Array, block: int) -> Array:
    """Apply row_fn to row blocks via scan (bounds peak memory)."""
    n = x.shape[0]
    block = min(block, n)
    pad = (-n) % block
    if pad:
        widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
        xp = jnp.pad(x, widths)
    else:
        xp = x
    blocks = xp.reshape(-1, block, *x.shape[1:])

    def body(_, xb):
        return None, row_fn(xb)

    _, rows = jax.lax.scan(body, None, blocks)
    return rows.reshape(-1, n)[:n]


def validate_distance_matrix(d: Array, *, atol: float = 1e-5) -> dict:
    """Structural checks the PERMANOVA engine relies on."""
    sym = float(jnp.max(jnp.abs(d - d.T)))
    diag = float(jnp.max(jnp.abs(jnp.diagonal(d))))
    neg = float(jnp.min(d))
    ok = sym <= atol and diag <= atol and neg >= -atol
    return {"symmetric_maxerr": sym, "diag_maxabs": diag,
            "min_value": neg, "ok": ok}
