"""Distance-matrix construction — the substrate feeding PERMANOVA.

The paper's input was an Unweighted-UniFrac matrix over EMP data (computed by
a separate tool, ref [9]); the PERMANOVA code path consumes an arbitrary
symmetric zero-diagonal matrix. We provide the standard ecology metrics on
abundance tables in a factored form the pipeline subsystem composes:

  prepare(x)        one-off (n, d) feature transform (clr for Aitchison,
                    presence/absence cast for Jaccard; identity otherwise)
  rows(xb, xprep)   distances for a block of rows against ALL samples —
                    the unit both the dense builders and the pipeline's
                    streaming / fused paths consume

Dense metrics (`euclidean`, `braycurtis`, ...) remain the public API and are
now thin drivers over the row primitives, so a 100k-sample table can stream
in row blocks instead of materializing (n, n, d) intermediates, and the
pipeline registry (repro.pipeline.registry) exposes the same math behind
dense / blocked / Pallas implementations with capability metadata.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# Row primitives: rows(xb, xprep) -> (block, n) distances.
# ---------------------------------------------------------------------------

def _identity_prepare(x: Array) -> Array:
    return jnp.asarray(x, dtype=jnp.float32)


def clr_prepare(x: Array, *, pseudocount: float = 0.5) -> Array:
    """Centered log-ratio transform (Aitchison geometry on compositions)."""
    logx = jnp.log(jnp.asarray(x, jnp.float32) + pseudocount)
    return logx - jnp.mean(logx, axis=-1, keepdims=True)


def presence_prepare(x: Array) -> Array:
    """Presence/absence cast for binary metrics (kept float32 so the same
    row kernels and Pallas tiles apply)."""
    return (jnp.asarray(x) > 0).astype(jnp.float32)


def euclidean_rows(xb: Array, x: Array) -> Array:
    """(block, n) Euclidean distances via the Gram trick (MXU-friendly)."""
    sq_b = jnp.sum(xb * xb, axis=-1)[:, None]
    sq = jnp.sum(x * x, axis=-1)[None, :]
    d2 = sq_b + sq - 2.0 * (xb @ x.T)
    return jnp.sqrt(jnp.maximum(d2, 0.0))


def braycurtis_rows(xb: Array, x: Array) -> Array:
    """(block, n) Bray-Curtis: sum|xi-xj| / sum(xi+xj)."""
    num = jnp.sum(jnp.abs(xb[:, None, :] - x[None, :, :]), axis=-1)
    den = jnp.sum(xb[:, None, :] + x[None, :, :], axis=-1)
    return num / jnp.maximum(den, 1e-30)


def jaccard_rows(xb: Array, x: Array) -> Array:
    """(block, n) binary Jaccard on presence/absence (prepare casts x > 0;
    float multiply = AND, so the same kernel shape works on the MXU)."""
    inter = xb @ x.T                                   # |A & B|
    card_b = jnp.sum(xb, axis=-1)[:, None]
    card = jnp.sum(x, axis=-1)[None, :]
    union = card_b + card - inter                      # |A | B|
    return 1.0 - inter / jnp.maximum(union, 1.0)


# ---------------------------------------------------------------------------
# Precision helpers: fp8 (e4m3) feature-slab quantization and packed-bit
# presence words. These feed the fused megakernel's precision knobs
# (feat_fp8 / feat_packed) and the XLA reference round-trips.
# ---------------------------------------------------------------------------

FP8_MAX = 448.0            # largest finite float8_e4m3fn magnitude


def fp8_scale(xprep: Array) -> Array:
    """Per-slab calibration scale so max|x|/scale hits the e4m3 range.

    Computed ONCE on the prepared feature table (the megakernel driver
    calls this before its chunk loop); a scalar f32."""
    amax = jnp.max(jnp.abs(jnp.asarray(xprep, jnp.float32)))
    return jnp.maximum(amax / FP8_MAX, 1e-12).astype(jnp.float32)


def fp8_metric_scale(xprep: Array, metric: str) -> Array:
    """Metric-aware calibration: presence/absence slabs (jaccard) are
    {0, 1} — exact in fp8 at scale 1 — everything else calibrates to the
    slab's max magnitude."""
    if metric == "jaccard":
        return jnp.float32(1.0)
    return fp8_scale(xprep)


def fp8_roundtrip(xprep: Array, scale: Array | None = None) -> Array:
    """Quantize to float8_e4m3fn and dequantize back to f32 — the exact
    value path the fp8 kernel sees (scale-down, cast, scale-up with fp32
    accumulation). Used by the XLA ref/onepass paths for parity."""
    x = jnp.asarray(xprep, jnp.float32)
    s = fp8_scale(x) if scale is None else jnp.asarray(scale, jnp.float32)
    q = (x / s).astype(jnp.float8_e4m3fn)
    return q.astype(jnp.float32) * s


def pack_presence_bits(xprep: Array) -> Array:
    """Pack a presence/absence slab into uint32 words along features.

    (n, d) floats -> (n, ceil(d/32)) uint32; bit k of word w is
    1[x[:, 32*w + k] > 0]. Pad features are zero words, so popcount
    tiles over padded word blocks stay exact. 32x feature-traffic cut."""
    x = jnp.asarray(xprep)
    n, d = x.shape
    pad = (-d) % 32
    bits = (x > 0).astype(jnp.uint32)
    if pad:
        bits = jnp.pad(bits, ((0, 0), (0, pad)))
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(bits.reshape(n, -1, 32) << shifts, axis=-1,
                   dtype=jnp.uint32)


class MetricDef(NamedTuple):
    """Factored metric: one-off feature transform + row-block kernel."""
    prepare: Callable[[Array], Array]
    rows: Callable[[Array, Array], Array]


ROW_METRICS: dict[str, MetricDef] = {
    "euclidean": MetricDef(_identity_prepare, euclidean_rows),
    "braycurtis": MetricDef(_identity_prepare, braycurtis_rows),
    "jaccard": MetricDef(presence_prepare, jaccard_rows),
    "aitchison": MetricDef(clr_prepare, euclidean_rows),
}


# ---------------------------------------------------------------------------
# Dense metrics (public API) — drivers over the row primitives.
# ---------------------------------------------------------------------------

def euclidean(x: Array) -> Array:
    """Pairwise Euclidean via the Gram trick (single full-matrix form)."""
    xp = _identity_prepare(x)
    return _zero_diag(euclidean_rows(xp, xp))


def braycurtis(x: Array, *, block: int = 256) -> Array:
    """Bray-Curtis dissimilarity, blocked over rows (bounds peak memory)."""
    xp = _identity_prepare(x)
    return _zero_diag(_blocked_rows(braycurtis_rows, xp, block))


def jaccard(x: Array, *, block: int = 256) -> Array:
    """Binary Jaccard distance on presence/absence (x > 0)."""
    xp = presence_prepare(x)
    return _zero_diag(_blocked_rows(jaccard_rows, xp, block))


def aitchison(x: Array, *, pseudocount: float = 0.5) -> Array:
    """Aitchison distance: Euclidean over clr-transformed compositions."""
    xp = clr_prepare(x, pseudocount=pseudocount)
    return _zero_diag(euclidean_rows(xp, xp))


METRICS: dict[str, Callable] = {
    "euclidean": euclidean,
    "braycurtis": braycurtis,
    "jaccard": jaccard,
    "aitchison": aitchison,
}


def distance_matrix(x: Array, metric: str = "braycurtis", **kw) -> Array:
    return METRICS[metric](x, **kw)


def _zero_diag(d: Array) -> Array:
    n = d.shape[0]
    return d * (1.0 - jnp.eye(n, dtype=d.dtype))


def _blocked_rows(row_fn: Callable, x: Array, block: int) -> Array:
    """Apply row_fn to row blocks via scan (bounds peak memory)."""
    n = x.shape[0]
    block = min(block, n)
    pad = (-n) % block
    if pad:
        widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
        xp = jnp.pad(x, widths)
    else:
        xp = x

    def body(_, xb):
        return None, row_fn(xb, x)

    _, rows = jax.lax.scan(body, None, xp.reshape(-1, block, *x.shape[1:]))
    return rows.reshape(-1, n)[:n]


def validate_distance_matrix(d: Array, *, atol: float = 1e-5) -> dict:
    """Structural checks the PERMANOVA engine relies on."""
    sym = float(jnp.max(jnp.abs(d - d.T)))
    diag = float(jnp.max(jnp.abs(jnp.diagonal(d))))
    neg = float(jnp.min(d))
    ok = sym <= atol and diag <= atol and neg >= -atol
    return {"symmetric_maxerr": sym, "diag_maxabs": diag,
            "min_value": neg, "ok": ok}
