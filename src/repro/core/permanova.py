"""Full PERMANOVA test (Anderson 2001), built around the paper's s_W kernel.

The paper benchmarks only `permanova_f_stat_sW` ("the most time-consuming
part ... other steps add minimal overhead"). A deployable engine needs the
whole test, so this module implements it:

  s_T    = sum_{i<j} d_ij^2 / N                       (constant per matrix)
  s_W[p] = sum_{i<j, same perm-group} d_ij^2 / n_g     (the paper's kernel)
  s_A[p] = s_T - s_W[p]
  F[p]   = (s_A[p] / (a - 1)) / (s_W[p] / (N - a))
  p-val  = (#{F[p] >= F[0], p >= 1} + 1) / (n_perms + 1)

with N objects, a groups, permutation 0 = observed labels.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import fstat, permutations

Array = jax.Array

SW_IMPLS = {
    "brute": fstat.sw_brute,
    "tiled": fstat.sw_tiled,
    "matmul": fstat.sw_matmul,
}


@dataclasses.dataclass
class PermanovaResult:
    f_stat: Array          # observed pseudo-F
    p_value: Array
    s_t: Array
    s_w: Array             # observed s_W
    f_perms: Array         # (n_perms,) null distribution incl. observed at 0
    n_objects: int
    n_groups: int
    n_perms: int
    method: str = "permanova"

    def __repr__(self):  # pragma: no cover - cosmetic
        return (f"PermanovaResult(F={float(self.f_stat):.6g}, "
                f"p={float(self.p_value):.6g}, n={self.n_objects}, "
                f"a={self.n_groups}, perms={self.n_perms})")


def s_total(mat2: Array) -> Array:
    """s_T = sum_{i<j} d^2 / N. Uses symmetry: full sum / 2 / N."""
    n = mat2.shape[0]
    return jnp.sum(mat2) / 2.0 / n


def f_from_sw(s_w: Array, s_t: Array, n_objects: int, n_groups: int) -> Array:
    """pseudo-F from the partial statistic (broadcasts over permutations)."""
    s_a = s_t - s_w
    dof_between = n_groups - 1
    dof_within = n_objects - n_groups
    return (s_a / dof_between) / (s_w / dof_within)


def p_value_from_null(f_perms: Array) -> Array:
    """(#{perm F >= observed F} + 1) / (n_perms + 1); index 0 = observed."""
    f_obs = f_perms[0]
    n_perms = f_perms.shape[0] - 1
    greater = jnp.sum(f_perms[1:] >= f_obs)
    return (greater + 1.0) / (n_perms + 1.0)


def permanova(dm: Array, grouping: Array, *, n_perms: int = 999,
              key: Optional[jax.Array] = None, n_groups: Optional[int] = None,
              sw_impl: str = "matmul",
              sw_fn: Optional[Callable] = None) -> PermanovaResult:
    """Run the full PERMANOVA test on one host.

    dm:        (n, n) symmetric distance matrix, zero diagonal.
    grouping:  (n,) int labels in [0, n_groups).
    sw_impl:   'brute' | 'tiled' | 'matmul' (or pass sw_fn directly, e.g. a
               Pallas kernel wrapper from repro.kernels.permanova_sw.ops).
    """
    if key is None:
        key = jax.random.key(0)
    dm = jnp.asarray(dm)
    grouping = jnp.asarray(grouping, dtype=jnp.int32)
    n = dm.shape[0]
    if n_groups is None:
        n_groups = int(jnp.max(grouping)) + 1
    mat2 = dm * dm
    inv_gs = permutations.inv_group_sizes(grouping, n_groups)
    groupings = permutations.permutation_batch(key, grouping, 0, n_perms + 1)
    fn = sw_fn if sw_fn is not None else SW_IMPLS[sw_impl]
    s_w_all = fn(mat2, groupings, inv_gs)
    s_t = s_total(mat2)
    f_all = f_from_sw(s_w_all, s_t, n, n_groups)
    return PermanovaResult(
        f_stat=f_all[0],
        p_value=p_value_from_null(f_all),
        s_t=s_t,
        s_w=s_w_all[0],
        f_perms=f_all,
        n_objects=n,
        n_groups=n_groups,
        n_perms=n_perms,
    )
