"""Full PERMANOVA test (Anderson 2001), built around the paper's s_W kernel.

The paper benchmarks only `permanova_f_stat_sW` ("the most time-consuming
part ... other steps add minimal overhead"). A deployable engine needs the
whole test, so this module implements it:

  s_T    = sum_{i<j} d_ij^2 / N                       (constant per matrix)
  s_W[p] = sum_{i<j, same perm-group} d_ij^2 / n_g     (the paper's kernel)
  s_A[p] = s_T - s_W[p]
  F[p]   = (s_A[p] / (a - 1)) / (s_W[p] / (N - a))
  p-val  = (#{F[p] >= F[0], p >= 1} + 1) / (n_perms + 1)

with N objects, a groups, permutation 0 = observed labels.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import fstat, permutations

Array = jax.Array

# Legacy alias kept for external callers/tests; the authoritative impl table
# (these three + the Pallas variants + sharded partials, with capability
# metadata) lives in repro.engine.registry.
SW_IMPLS = {
    "brute": fstat.sw_brute,
    "tiled": fstat.sw_tiled,
    "matmul": fstat.sw_matmul,
}


@dataclasses.dataclass
class TermResult:
    """Per-term statistics of a multi-term (design) PERMANOVA.

    One entry per non-intercept model term, in sequential (adonis2) order:
    each term's SS is adjusted for everything BEFORE it. Arrays carry a
    leading study axis on the multi-study entry points."""
    name: str
    kind: str              # 'factor' | 'covariate'
    df: int
    ss: Array              # observed explained SS (sequential)
    f_stat: Array          # observed partial pseudo-F
    p_value: Array
    r2: Array              # ss / s_T (variance explained by this term)
    f_perms: Array         # (n_perms + 1,) null incl. observed at 0

    def __repr__(self):  # pragma: no cover - cosmetic
        try:
            return (f"TermResult({self.name}: df={self.df}, "
                    f"F={float(self.f_stat):.6g}, "
                    f"p={float(self.p_value):.6g}, "
                    f"R2={float(self.r2):.4g})")
        except TypeError:   # batched (S,)-leading arrays
            return (f"TermResult({self.name}: df={self.df}, "
                    f"batched x{self.f_stat.shape[0]})")


@dataclasses.dataclass
class PermanovaResult:
    f_stat: Array          # observed pseudo-F
    p_value: Array
    s_t: Array
    s_w: Array             # observed s_W
    f_perms: Array         # (n_perms,) null distribution incl. observed at 0
    n_objects: int
    n_groups: int
    n_perms: int
    method: str = "permanova"
    plan: str = ""         # engine execution plan (impl, tuning, chunking)
    ordination: object = None   # Optional[pipeline.ordination.PCoAResult]
                                # when the caller asked for PCoA axes
    terms: object = None   # Optional[tuple[TermResult, ...]] on the design
                           # path (covariates/strata/weights/multi-factor);
                           # headline f_stat/p_value are the LAST term's
                           # (the covariate-adjusted factor of interest).
                           # None on the classic single-factor path.

    @property
    def r2(self) -> Array:
        """Effect size R^2 = s_A / s_T = 1 - s_W / s_T (variance explained
        by the grouping)."""
        return 1.0 - self.s_w / self.s_t

    def __repr__(self):  # pragma: no cover - cosmetic
        return (f"PermanovaResult(F={float(self.f_stat):.6g}, "
                f"p={float(self.p_value):.6g}, R2={float(self.r2):.4g}, "
                f"n={self.n_objects}, a={self.n_groups}, "
                f"perms={self.n_perms})")


def s_total(mat2: Array) -> Array:
    """s_T = sum_{i<j} d^2 / N. Uses symmetry: full sum / 2 / N."""
    n = mat2.shape[0]
    return jnp.sum(mat2) / 2.0 / n


def f_from_sw(s_w: Array, s_t: Array, n_objects: int, n_groups: int) -> Array:
    """pseudo-F from the partial statistic (broadcasts over permutations)."""
    s_a = s_t - s_w
    dof_between = n_groups - 1
    dof_within = n_objects - n_groups
    return (s_a / dof_between) / (s_w / dof_within)


def p_value_from_null(f_perms: Array) -> Array:
    """(#{perm F >= observed F} + 1) / (n_perms + 1); index 0 = observed."""
    f_obs = f_perms[0]
    n_perms = f_perms.shape[0] - 1
    greater = jnp.sum(f_perms[1:] >= f_obs)
    return (greater + 1.0) / (n_perms + 1.0)


def permanova(dm: Array, grouping: Array = None, *, n_perms: int = 999,
              key: Optional[jax.Array] = None, n_groups: Optional[int] = None,
              sw_impl: str = "auto",
              sw_fn: Optional[Callable] = None,
              memory_budget_bytes: Optional[float] = None,
              chunk: Optional[int] = None,
              metric: Optional[str] = None,
              covariates=None, strata=None, weights=None,
              autotune: bool = False) -> PermanovaResult:
    """Run the full PERMANOVA test on one host (thin engine wrapper).

    dm:        (n, n) symmetric distance matrix, zero diagonal — OR a raw
               (n, d) abundance table. Features route through the pipeline
               subsystem (repro.pipeline), which plans distance
               construction and the permutation sweep jointly. A non-square
               2-D input is always treated as features; a square input is
               treated as a distance matrix unless `metric` is given.
    grouping:  (n,) int labels in [0, n_groups) — or a compiled
               core.design.Design (then covariates/strata/weights must be
               None; the Design already carries them).
    metric:    distance metric for the features path ('braycurtis',
               'euclidean', 'jaccard', 'aitchison'). Passing it forces the
               pipeline path even for square inputs.
    sw_impl:   'auto' (hardware-aware planner; the paper's CPU-tiled vs
               GPU-brute result) or any repro.engine.registry name:
               'brute' | 'tiled' | 'matmul' | 'pallas_{brute,permblock,matmul}'.
    sw_fn:     bypass the registry with a custom batch callable (e.g. a
               Pallas kernel wrapper from repro.kernels.permanova_sw.ops).
    covariates: continuous columns to adjust for — dict name->(n,), list
               of (name, values), or an (n, c) array. Model terms are
               sequential (adonis2): covariates first, the grouping factor
               LAST, so the headline F is the covariate-adjusted factor;
               per-term statistics land in `result.terms`.
    strata:    (n,) int block labels — permutations are restricted WITHIN
               blocks (vegan's strata=). Works with or without covariates.
    weights:   (n,) non-negative sample weights (weighted PERMANOVA; the
               design compiles them into the projection basis).
    memory_budget_bytes / chunk: cap the live label tensor; larger sweeps
               run through the engine's streaming permutation scheduler.

    With none of covariates/strata/weights (and a plain label array), this
    is exactly the pre-design single-factor path — same programs, same
    bits.
    """
    from repro import engine  # deferred: engine imports this module
    from repro.core import design as _design
    if isinstance(grouping, _design.Design):
        if covariates is not None or strata is not None \
                or weights is not None:
            raise ValueError("pass covariates/strata/weights either to "
                             "permanova() or inside the Design, not both")
        # routed below as-is; engine.run and pipeline() accept Designs
    elif covariates is not None or strata is not None or weights is not None:
        grouping = _design.build(
            grouping=None if grouping is None else
            jnp.asarray(grouping, jnp.int32),
            covariates=covariates, strata=strata, weights=weights,
            n_groups=n_groups)
    elif grouping is None:
        raise ValueError("permanova needs grouping labels, covariates, or "
                         "a Design")
    arr = jnp.asarray(dm)
    is_features = metric is not None or (
        arr.ndim == 2 and arr.shape[0] != arr.shape[1])
    if is_features:
        if sw_fn is not None:
            raise ValueError("sw_fn is not supported on the features path; "
                             "precompute the distance matrix instead")
        from repro import pipeline  # deferred: pipeline imports this module
        return pipeline.pipeline(
            arr, grouping, metric=metric or "braycurtis", n_perms=n_perms,
            key=key, n_groups=n_groups, sw_impl=sw_impl,
            memory_budget_bytes=memory_budget_bytes, chunk=chunk,
            autotune=autotune)
    if arr.ndim == 2 and arr.shape[0] >= 2:
        # A square feature table would silently take this branch — an O(n)
        # sampled structural check catches that without materializing an
        # (n, n) transient on the hot path (an (n, n) abundance table is
        # essentially never symmetric with a zero diagonal).
        n = arr.shape[0]
        rows = jnp.asarray([0, n // 2, n - 1])
        diag_err = float(jnp.max(jnp.abs(arr[rows, rows])))
        sym_err = float(jnp.max(jnp.abs(arr[rows, :] - arr[:, rows].T)))
        if diag_err > 1e-5 or sym_err > 1e-4:
            import warnings
            warnings.warn(
                f"square input does not look like a distance matrix "
                f"(sampled diag max {diag_err:.3g}, asymmetry max "
                f"{sym_err:.3g}); if this is an (n, d) feature table with "
                "n == d, pass metric=... to route it through the pipeline",
                stacklevel=2)
    return engine.run(arr, grouping, n_perms=n_perms, key=key,
                      n_groups=n_groups, impl=sw_impl, sw_fn=sw_fn,
                      memory_budget_bytes=memory_budget_bytes, chunk=chunk,
                      autotune=autotune)
