"""Permutation engine for the PERMANOVA permutation test.

Generates batches of permuted grouping vectors. Group sizes are invariant
under label permutation, so `inv_group_sizes` is computed once from the
observed grouping. Permutation 0 is ALWAYS the identity (the observed
grouping), matching the scikit-bio convention where the observed statistic
joins the null distribution denominator.

The generator is deliberately splittable/stateless (one fold of the PRNG key
per permutation index) so that:
  * distributed shards generate their own permutation ranges without
    communication (shard p-range [lo, hi) folds keys lo..hi-1), and
  * straggler re-dispatch / elastic re-meshing re-generates identical
    permutations on a different host (idempotent recovery — DESIGN.md section 4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def group_sizes(grouping: Array, n_groups: int) -> Array:
    """(n_groups,) counts of each label value in the observed grouping."""
    return jnp.bincount(grouping, length=n_groups)


def inv_group_sizes(grouping: Array, n_groups: int) -> Array:
    sizes = group_sizes(grouping, n_groups).astype(jnp.float32)
    return jnp.where(sizes > 0, 1.0 / jnp.maximum(sizes, 1.0), 0.0)


def permute_grouping(key: jax.Array, grouping: Array) -> Array:
    """One random relabeling: grouping composed with a random permutation."""
    perm = jax.random.permutation(key, grouping.shape[0])
    return grouping[perm]


def permutation_batch(key: jax.Array, grouping: Array, lo: int, hi: int,
                      *, identity_first: bool = True) -> Array:
    """Grouping vectors for permutation indices [lo, hi).

    Index 0 is the identity when identity_first. Key folding is by GLOBAL
    permutation index, so any shard holding any index range produces the
    same labels as a single-host run.
    """
    return permutation_batch_dyn(key, grouping, lo, hi - lo,
                                 identity_first=identity_first)


def permutation_batch_dyn(key: jax.Array, grouping: Array, lo: Array,
                          chunk: int, *, identity_first: bool = True) -> Array:
    """permutation_batch with a TRACED start index.

    Same key-folding-by-global-index semantics, but `lo` may be a traced
    scalar, so one jitted program serves every chunk of a streaming sweep
    (the scheduler re-invokes it with lo = 0, chunk, 2*chunk, ... without
    retracing). `chunk` must be static.
    """
    idx = lo + jnp.arange(chunk)
    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(idx)
    perms = jax.vmap(lambda k: permute_grouping(k, grouping))(keys)
    if identity_first:
        perms = jnp.where((idx == 0)[:, None], grouping[None, :], perms)
    return perms


def permutation_batch_host(key: jax.Array, grouping, n_perms: int):
    """Convenience full-batch generator (host-side, small studies)."""
    return permutation_batch(key, jnp.asarray(grouping), 0, n_perms)


# ---------------------------------------------------------------------------
# Strata-restricted permutations (design subsystem).
#
# Restricted permutation tests (vegan's `strata=`) shuffle samples only
# WITHIN blocks — sites, batches, repeated-measure subjects — so the null
# respects the blocking structure. The generators below ride the exact
# global-index key-folding contract of the free generators above: any shard
# holding any index range reproduces the same draws as a single host.
# ---------------------------------------------------------------------------

def strata_permutation(key: jax.Array, strata: Array) -> Array:
    """One uniform permutation restricted within strata blocks.

    Returns an INDEX permutation perm (n,) int32 with strata[perm[i]] ==
    strata[i] for every i, uniformly distributed over all such
    permutations. Construction: two stable argsorts group positions by
    stratum — once in a uniformly-random within-block order, once in the
    original order — and matching them up block-by-block yields a uniform
    within-block bijection (no float-keyed lexsort, so no tie hazards).
    A constant strata vector gives an unrestricted uniform permutation
    (a distinct stream from jax.random.permutation's — documented where
    the dense design path draws from it)."""
    n = strata.shape[0]
    u = jax.random.uniform(key, (n,))
    a = jnp.argsort(u)                              # random position order
    a = a[jnp.argsort(strata[a], stable=True)]      # by stratum, random within
    b = jnp.argsort(strata, stable=True)            # by stratum, original order
    return jnp.zeros((n,), jnp.int32).at[b].set(a.astype(jnp.int32))


def strata_permutation_batch_dyn(key: jax.Array, strata: Array, lo: Array,
                                 chunk: int, *,
                                 identity_first: bool = True) -> Array:
    """(chunk, n) strata-restricted INDEX permutations for global
    permutation indices [lo, lo+chunk). Key folding is by GLOBAL index
    (`lo` may be traced), so sharded sweeps are bit-identical to
    single-host ones. Index 0 is the identity when identity_first."""
    n = strata.shape[0]
    idx = lo + jnp.arange(chunk)
    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(idx)
    perms = jax.vmap(lambda k: strata_permutation(k, strata))(keys)
    if identity_first:
        eye = jnp.arange(n, dtype=jnp.int32)
        perms = jnp.where((idx == 0)[:, None], eye[None, :], perms)
    return perms


def strata_permutation_batch(key: jax.Array, strata: Array, lo: int,
                             hi: int, *, identity_first: bool = True) -> Array:
    """Strata-restricted index permutations for indices [lo, hi)."""
    return strata_permutation_batch_dyn(key, strata, lo, hi - lo,
                                        identity_first=identity_first)


def strata_label_batch_dyn(key: jax.Array, grouping: Array, strata: Array,
                           lo: Array, chunk: int, *,
                           identity_first: bool = True) -> Array:
    """Permuted LABEL vectors under strata restriction — the labels-mode
    generator for `strata=` designs: grouping composed with the index
    permutations, so every label-based s_W impl consumes it unchanged."""
    perms = strata_permutation_batch_dyn(key, strata, lo, chunk,
                                         identity_first=identity_first)
    return grouping[perms]


def masked_strata(strata: Array, n_valid: Array) -> Array:
    """Move the pad suffix [n_valid, n) into its own sentinel stratum so
    padded ragged studies permute pads only among themselves (pad rows
    carry zero design rows, so they contribute exactly nothing). The
    sentinel is max(strata)+1 — strata labels are arbitrary ints, so a
    fixed sentinel could collide with a real block and leak valid samples
    onto zero-basis pad slots. A None-equivalent free permutation is the
    all-zeros strata vector."""
    n = strata.shape[0]
    return jnp.where(jnp.arange(n) < n_valid, strata, jnp.max(strata) + 1)


# ---------------------------------------------------------------------------
# Masked permutations: ragged studies padded to a common length.
# ---------------------------------------------------------------------------

def masked_permute_grouping(key: jax.Array, grouping: Array,
                            n_valid: Array) -> Array:
    """One random relabeling of the VALID PREFIX [0, n_valid) only.

    Pad entries (the suffix, carrying a sentinel group) stay in place, so
    the permutation never mixes pad labels into valid positions — group
    sizes over the valid samples are invariant, exactly as an unpadded
    permutation. Draw: uniform keys on the prefix, +inf on the pad, one
    stable argsort — positions [0, n_valid) receive a uniform random
    permutation of themselves, the pad suffix maps to itself in order.
    `n_valid` may be traced (one program serves every study of a ragged
    batch).
    """
    n = grouping.shape[0]
    u = jax.random.uniform(key, (n,))
    u = jnp.where(jnp.arange(n) < n_valid, u, jnp.inf)
    return grouping[jnp.argsort(u)]


def masked_permutation_batch_dyn(key: jax.Array, grouping: Array,
                                 n_valid: Array, lo: Array, chunk: int, *,
                                 identity_first: bool = True) -> Array:
    """permutation_batch_dyn for a padded ragged study.

    Same global-index key folding (shard-position independent), but each
    draw permutes only the valid prefix via masked_permute_grouping. NOTE:
    the draws differ from the unpadded jax.random.permutation stream, so
    a ragged study's null is deterministic and independent per study but
    not bit-identical to an unpadded single-study run; the observed
    statistic (index 0, identity labels) IS identical.
    """
    idx = lo + jnp.arange(chunk)
    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(idx)
    perms = jax.vmap(
        lambda k: masked_permute_grouping(k, grouping, n_valid))(keys)
    if identity_first:
        perms = jnp.where((idx == 0)[:, None], grouping[None, :], perms)
    return perms
