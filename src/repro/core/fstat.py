"""PERMANOVA pseudo-F partial statistic (`permanova_f_stat_sW`) — the paper's
hot loop — in several algorithmic forms.

The paper (Sfiligoi, PEARC25) studies exactly this computation:

    s_W[p] = sum_{row < col} mat[row,col]^2
             * 1[g_p[row] == g_p[col]] * inv_group_sizes[g_p[row]]

over 1k..1M permutations `p` of the grouping labels, with `mat` a distance
matrix of 1k^2..100k^2 elements. Variants implemented here:

  sw_algorithm1_numpy  literal numpy transcription of the paper's Algorithm 1
                       (brute force, scalar loops) — the correctness oracle.
  sw_brute_one         vectorized brute force for ONE permutation (the
                       GPU-style Algorithm 3: parallel over the (row,col)
                       triangle). jnp, O(n^2) intermediate.
  sw_tiled_one         structural transcription of the paper's Algorithm 2
                       (CPU-tiled): explicit TILE x TILE loop nest with the
                       inv_group_sizes hoist. Same math, tiled dataflow.
  sw_brute             brute force over a batch of permutations (scan over
                       permutation blocks x vmap inside a block).
  sw_matmul            beyond-paper one-hot matmul reformulation: for a block
                       of P permutations build E in {0,sqrt(w_g)}^{n x (P*G)}
                       and compute s_W via M2 @ E on the MXU. Raises the
                       arithmetic intensity per M2 byte from ~3/4 flop/B to
                       ~P*G/2 flop/B (see DESIGN.md section 3).
  sw_rows_partial      row-sharded partial statistic for the distributed
                       runner (each shard owns a row block; triangle masking
                       uses global row offsets).

All functions take `mat2 = mat * mat` precomputed — squaring is a one-off
O(n^2) pass shared by every permutation, mirroring the paper's use of `val*val`
inside the loop only because OpenMP cannot hoist it; in JAX we hoist it.
`sw_*` results are identical either way (tests assert this).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


# ---------------------------------------------------------------------------
# Oracle: literal Algorithm 1 (numpy, scalar loops). Slow; tests only.
# ---------------------------------------------------------------------------

def sw_algorithm1_numpy(mat: np.ndarray, groupings: np.ndarray,
                        inv_group_sizes: np.ndarray) -> np.ndarray:
    """Literal transcription of the paper's ALGORITHM 1 (brute force)."""
    mat = np.asarray(mat, dtype=np.float32)
    groupings = np.asarray(groupings)
    inv_group_sizes = np.asarray(inv_group_sizes, dtype=np.float32)
    n_perms, n_dims = groupings.shape
    out = np.zeros((n_perms,), dtype=np.float32)
    for p in range(n_perms):
        grouping = groupings[p]
        s_w = np.float32(0.0)
        for row in range(n_dims - 1):          # no columns in last row
            group_idx = grouping[row]
            mat_row = mat[row]
            local = np.float32(0.0)
            for col in range(row + 1, n_dims):  # diagonal is always zero
                if grouping[col] == group_idx:
                    val = mat_row[col]
                    local += val * val
            s_w += local * inv_group_sizes[group_idx]
        out[p] = s_w
    return out


# ---------------------------------------------------------------------------
# Brute force (paper Algorithm 3 dataflow), one permutation, vectorized.
# ---------------------------------------------------------------------------

def sw_brute_one(mat2: Array, grouping: Array, inv_group_sizes: Array) -> Array:
    """Vectorized brute force over the strict upper triangle.

    Matches Algorithm 3: every (row < col) pair contributes
    mat2[row,col] * w[g[row]] iff g[col] == g[row].
    """
    n = mat2.shape[0]
    same = grouping[:, None] == grouping[None, :]
    w_row = inv_group_sizes[grouping][:, None]  # hoisted weight per row
    triu = jnp.triu(jnp.ones((n, n), dtype=bool), k=1)
    contrib = jnp.where(same & triu, mat2 * w_row, jnp.zeros((), mat2.dtype))
    return jnp.sum(contrib)


def sw_full_one(mat2: Array, grouping: Array, inv_group_sizes: Array) -> Array:
    """Full-matrix (i != j) form: exploits symmetry, sums everything and
    halves. The distance matrix has a zero diagonal so no correction term.
    This is the dataflow the TPU VPU prefers (no triangle mask)."""
    same = (grouping[:, None] == grouping[None, :]).astype(mat2.dtype)
    w_row = inv_group_sizes[grouping][:, None]
    return 0.5 * jnp.sum(mat2 * same * w_row)


# ---------------------------------------------------------------------------
# Tiled (paper Algorithm 2 dataflow), one permutation.
# ---------------------------------------------------------------------------

def sw_tiled_one(mat2: Array, grouping: Array, inv_group_sizes: Array,
                 *, tile: int = 64) -> Array:
    """Structural transcription of the paper's ALGORITHM 2 (CPU-tiled).

    Explicit TILE x TILE blocking of the upper triangle with the
    inv_group_sizes access hoisted per row-within-tile, expressed as a
    lax.fori_loop nest so the tiled dataflow survives tracing. When n is not
    a multiple of `tile` (e.g. prime n), the matrix is zero-padded up to the
    requested tile and the pad region carries a sentinel group (-1) with
    zero weight, so every pad pair contributes exactly 0 — the tiled
    dataflow is preserved instead of degrading toward tile=1.
    """
    n = mat2.shape[0]
    tile = min(tile, n)
    w = inv_group_sizes[grouping]  # (n,) hoisted per-row weight
    pad = (-n) % tile
    if pad:
        mat2 = jnp.pad(mat2, ((0, pad), (0, pad)))
        grouping = jnp.pad(grouping, (0, pad), constant_values=-1)
        w = jnp.pad(w, (0, pad))
        n = n + pad
    nt = n // tile
    row_ids = jnp.arange(tile)
    col_ids = jnp.arange(tile)

    def tile_body(carry, ij):
        s_w = carry
        ti, tj = ij
        r0 = ti * tile
        c0 = tj * tile
        m_tile = jax.lax.dynamic_slice(mat2, (r0, c0), (tile, tile))
        g_row = jax.lax.dynamic_slice(grouping, (r0,), (tile,))
        g_col = jax.lax.dynamic_slice(grouping, (c0,), (tile,))
        w_row = jax.lax.dynamic_slice(w, (r0,), (tile,))
        # strict upper triangle in GLOBAL coordinates
        gr = r0 + row_ids[:, None]
        gc = c0 + col_ids[None, :]
        mask = (gc > gr) & (g_col[None, :] == g_row[:, None])
        local = jnp.sum(jnp.where(mask, m_tile, 0.0), axis=1)  # per-row local_s_W
        return s_w + jnp.sum(local * w_row), None

    # only tiles with tj >= ti can contain upper-triangle entries
    tis, tjs = jnp.meshgrid(jnp.arange(nt), jnp.arange(nt), indexing="ij")
    keep = (tjs >= tis)
    order = jnp.argsort(~keep.ravel(), stable=True)[: nt * (nt + 1) // 2]
    ij = (tis.ravel()[order], tjs.ravel()[order])
    s_w, _ = jax.lax.scan(tile_body, jnp.zeros((), mat2.dtype), ij)
    return s_w


# ---------------------------------------------------------------------------
# Batched-permutation drivers.
# ---------------------------------------------------------------------------

def _scan_blocks(one_fn: Callable, mat2: Array, groupings: Array,
                 inv_group_sizes: Array, block: int) -> Array:
    """scan over permutation blocks, vmap(one_fn) inside a block."""
    n_perms = groupings.shape[0]
    block = min(block, n_perms)
    pad = (-n_perms) % block
    if pad:
        groupings = jnp.pad(groupings, ((0, pad), (0, 0)), mode="edge")
    gblocks = groupings.reshape(-1, block, groupings.shape[-1])

    def body(_, gb):
        return None, jax.vmap(lambda g: one_fn(mat2, g, inv_group_sizes))(gb)

    _, out = jax.lax.scan(body, None, gblocks)
    return out.reshape(-1)[:n_perms]


def sw_brute(mat2: Array, groupings: Array, inv_group_sizes: Array,
             *, block: int = 32) -> Array:
    """Brute-force s_W for a batch of permutations. (n_perms,) float."""
    return _scan_blocks(sw_brute_one, mat2, groupings, inv_group_sizes, block)


def sw_tiled(mat2: Array, groupings: Array, inv_group_sizes: Array,
             *, tile: int = 64, block: int = 8) -> Array:
    one = functools.partial(sw_tiled_one, tile=tile)
    return _scan_blocks(one, mat2, groupings, inv_group_sizes, block)


# ---------------------------------------------------------------------------
# Beyond-paper: one-hot matmul (MXU) formulation.
# ---------------------------------------------------------------------------

def onehot_perm_factors(groupings_block: Array,
                        inv_group_sizes: Array, dtype) -> Array:
    """E[p,:,g] = sqrt(w_g) * 1[g_p[i] == g] — the (P, n, G) one-hot factor
    shared by every matmul-form s_W variant."""
    n_groups = inv_group_sizes.shape[0]
    sqrt_w = jnp.sqrt(inv_group_sizes).astype(dtype)
    e = jax.nn.one_hot(groupings_block, n_groups, dtype=dtype)
    return e * sqrt_w[None, None, :]


def sw_matmul_contract(mat2_rows: Array, e: Array, e_rows: Array) -> Array:
    """The matmul-form contraction over a block of mat2 rows.

    s[p] = 1/2 * sum_ig (M2_rows @ E[p])[i,g] * E_rows[p,i,g]

    e: (P, n, G) column factors over ALL samples; e_rows: (P, n_local, G)
    row factors aligned with mat2_rows (e itself for the full matrix, a
    row-offset slice for sharded/fused partials). The distance diagonal is
    zero, so the full i!=j sum equals twice the triangle sum; summing the
    partials over disjoint row blocks reconstructs the global statistic.
    The contraction reuses every M2 element across P*G output columns —
    this is the MXU-native dataflow.
    """
    p, n, g = e.shape
    n_local = mat2_rows.shape[0]
    e2d = jnp.transpose(e, (1, 0, 2)).reshape(n, p * g)    # (n, P*G)
    y = mat2_rows @ e2d                                    # on MXU
    s = jnp.sum(y.reshape(n_local, p, g)
                * jnp.transpose(e_rows, (1, 0, 2)), axis=(0, 2))
    return 0.5 * s


def sw_matmul_block(mat2: Array, groupings_block: Array,
                    inv_group_sizes: Array) -> Array:
    """s_W for a block of P permutations via one big matmul."""
    e = onehot_perm_factors(groupings_block, inv_group_sizes, mat2.dtype)
    return sw_matmul_contract(mat2, e, e)


def sw_matmul(mat2: Array, groupings: Array, inv_group_sizes: Array,
              *, perm_block: int = 64) -> Array:
    """MXU formulation over all permutations (scan over perm blocks)."""
    n_perms = groupings.shape[0]
    perm_block = min(perm_block, n_perms)
    pad = (-n_perms) % perm_block
    if pad:
        groupings = jnp.pad(groupings, ((0, pad), (0, 0)), mode="edge")
    gblocks = groupings.reshape(-1, perm_block, groupings.shape[-1])

    def body(_, gb):
        return None, sw_matmul_block(mat2, gb, inv_group_sizes)

    _, out = jax.lax.scan(body, None, gblocks)
    return out.reshape(-1)[:n_perms]


# ---------------------------------------------------------------------------
# Row-sharded partial (for shard_map distribution).
# ---------------------------------------------------------------------------

def sw_rows_partial(mat2_rows: Array, row_offset: Array, groupings: Array,
                    inv_group_sizes: Array, *, block: int = 32) -> Array:
    """Partial s_W over a block of rows [row_offset, row_offset + n_local).

    Each shard sums pairs (i, j) with i local and j > i global. Summing the
    partials over shards (psum along the 'model' axis) yields the full s_W.
    groupings is the FULL (n_perms, n) label array (replicated).
    """
    n_local, n = mat2_rows.shape

    def one(grouping):
        g_rows = jax.lax.dynamic_slice(grouping, (row_offset,), (n_local,))
        w_row = inv_group_sizes[g_rows][:, None]
        same = grouping[None, :] == g_rows[:, None]
        gi = row_offset + jnp.arange(n_local)[:, None]
        gj = jnp.arange(n)[None, :]
        mask = same & (gj > gi)
        return jnp.sum(jnp.where(mask, mat2_rows * w_row, 0.0))

    return _scan_blocks(lambda _m, g, _w: one(g), mat2_rows, groupings,
                        inv_group_sizes, block)


def sw_matmul_rows_partial(mat2_rows: Array, row_offset: Array,
                           groupings: Array, inv_group_sizes: Array,
                           *, perm_block: int = 64) -> Array:
    """Row-sharded partial of the MXU formulation.

    Uses the full (i != j) symmetric sum: each shard computes
    1/2 * sum over its rows i of (M2[i,:] @ E) . E[i,:] — psum over shards
    reconstructs the global statistic exactly (zero diagonal).
    """
    n_local, n = mat2_rows.shape

    def body(_, gb):  # gb: (P, n)
        e = onehot_perm_factors(gb, inv_group_sizes, mat2_rows.dtype)
        p, _, g = e.shape
        e_rows = jax.lax.dynamic_slice(e, (0, row_offset, 0), (p, n_local, g))
        return None, sw_matmul_contract(mat2_rows, e, e_rows)

    n_perms = groupings.shape[0]
    perm_block = min(perm_block, n_perms)
    pad = (-n_perms) % perm_block
    if pad:
        groupings = jnp.pad(groupings, ((0, pad), (0, 0)), mode="edge")
    gblocks = groupings.reshape(-1, perm_block, n)
    _, out = jax.lax.scan(body, None, gblocks)
    return out.reshape(-1)[:n_perms]


# ---------------------------------------------------------------------------
# Design-basis (hat-matrix) contraction: per-column quadratic forms.
#
# The design subsystem (core.design) generalizes the one-hot factor E to an
# arbitrary orthonormal basis V of a model's column space: SS_resid =
# 1/2 <mat2, V V'> = sum_k 1/2 v_k' mat2 v_k, and adonis2-style per-term
# partial SS are (minus) per-column-span sums of the same quadratic forms.
# The dataflow is IDENTICAL to sw_matmul_contract — a tiled matmul against
# mat2 — except the per-column sums are kept separate so the caller can
# slice them into terms.
# ---------------------------------------------------------------------------

def basis_perm_factors(basis: Array, perms: Array) -> Array:
    """V[p] = basis[perms[p], :] — the (P, n, K) row-permuted design-basis
    factor that replaces the one-hot E on the matmul paths (permuting the
    basis rows is vegan's permute-the-observations convention)."""
    return basis[perms]


def sw_cols_contract(mat2_rows: Array, v: Array, v_rows: Array) -> Array:
    """Per-column quadratic forms over a block of mat2 rows.

    s[p, k] = 1/2 * sum_i (M2_rows @ V[p])[i, k] * V_rows[p, i, k]

    v: (P, n, K) permuted basis over ALL samples; v_rows: (P, n_local, K)
    rows aligned with mat2_rows (v itself for the full matrix, a
    row-offset slice for sharded/fused partials). Zero diagonal makes the
    full i != j sum twice the triangle sum; summing partials over disjoint
    row blocks reconstructs the global per-column statistic — exactly the
    contract of sw_matmul_contract, with the column axis kept."""
    p, n, k = v.shape
    n_local = mat2_rows.shape[0]
    v2d = jnp.transpose(v, (1, 0, 2)).reshape(n, p * k)     # (n, P*K)
    y = mat2_rows @ v2d                                     # on MXU
    s = jnp.sum(y.reshape(n_local, p, k)
                * jnp.transpose(v_rows, (1, 0, 2)), axis=0)
    return 0.5 * s                                          # (P, K)


def sw_cols_block(mat2: Array, v: Array) -> Array:
    """(P, K) per-column statistic for one block of permuted bases."""
    return sw_cols_contract(mat2, v, v)


# ---------------------------------------------------------------------------
# Block-sparse basis contraction: one-hot and strata-indicator bases are
# block-sparse (each column's nonzeros live inside a few strata), and
# strata-restricted permutations preserve that support — perms[p][i] stays
# inside stratum(i), so v[p, i, k] can be nonzero only at rows whose
# stratum belongs to column k's unpermuted strata support. That makes the
# support a STATIC host-side property: gather the supported rows once and
# skip every all-zero tile of the contraction.
# ---------------------------------------------------------------------------

def sparse_col_groups(basis, strata):
    """Group basis columns by permutation-invariant row support.

    Returns ((cols, rows), ...): `cols` are column indices sharing one
    support set, `rows` the sorted sample indices whose stratum appears in
    any of those columns' nonzeros. The groups partition the columns.
    Host-side (numpy) — call once per design, outside jit."""
    b = np.asarray(basis)
    s = np.asarray(strata)
    by_support: dict[frozenset, list[int]] = {}
    for k in range(b.shape[1]):
        nz = np.flatnonzero(b[:, k] != 0)
        sup = frozenset(np.unique(s[nz]).tolist())
        by_support.setdefault(sup, []).append(k)
    groups = []
    for sup, cols in sorted(by_support.items(), key=lambda t: t[1][0]):
        rows = np.flatnonzero(np.isin(s, sorted(sup)))
        groups.append((tuple(cols), tuple(int(r) for r in rows)))
    return tuple(groups)


def sw_cols_contract_sparse(mat2_rows: Array, v: Array, v_rows: Array,
                            groups) -> Array:
    """Block-sparse sw_cols_contract: contract each column group against
    only its supported sample columns of mat2_rows.

    Every skipped (row j, column k) term has v[p, j, k] == 0 exactly, so
    each group's gathered contraction bit-matches the dense path (the
    surviving addends keep their order; adding exact zeros is the
    identity). With one group spanning all rows this degrades gracefully
    to the dense contraction."""
    p, n, k = v.shape
    if len(groups) == 1 and len(groups[0][1]) == n:
        return sw_cols_contract(mat2_rows, v, v_rows)
    out = jnp.zeros((p, k), mat2_rows.dtype)
    for cols, rows in groups:
        cols_a = jnp.asarray(cols, jnp.int32)
        rows_a = jnp.asarray(rows, jnp.int32)
        sg = sw_cols_contract(mat2_rows[:, rows_a],
                              v[:, rows_a][:, :, cols_a],
                              v_rows[:, :, cols_a])
        out = out.at[:, cols_a].set(sg)
    return out


def _scan_v_blocks(one_fn: Callable, mat2, vperms: Array, block: int):
    p = vperms.shape[0]
    block = min(block, p)
    pad = (-p) % block
    if pad:
        vperms = jnp.pad(vperms, ((0, pad), (0, 0), (0, 0)), mode="edge")
    vb = vperms.reshape(-1, block, *vperms.shape[1:])

    def body(_, v):
        return None, one_fn(mat2, v)

    _, out = jax.lax.scan(body, None, vb)
    return out.reshape(-1, vperms.shape[-1])[:p]


def sw_cols_matmul(mat2: Array, vperms: Array, *,
                   perm_block: int = 64) -> Array:
    """Per-column statistic over all permutations, matmul form (scan over
    permutation blocks — the design-mode analogue of sw_matmul)."""
    return _scan_v_blocks(sw_cols_block, mat2, vperms, perm_block)


def sw_cols_brute(mat2: Array, vperms: Array, *, block: int = 16) -> Array:
    """Per-column statistic, brute dataflow: every permutation re-streams
    mat2 (the GPU-style Algorithm 3 analogue for dense designs)."""
    def one_block(m2, vb):
        return jax.vmap(
            lambda v: 0.5 * jnp.einsum("ik,ij,jk->k", v, m2, v))(vb)
    return _scan_v_blocks(one_block, mat2, vperms, block)


def sw_cols_rows_partial(mat2_rows: Array, row_offset: Array,
                         vperms: Array, *, perm_block: int = 64) -> Array:
    """Row-sharded partial of the per-column contraction: each shard
    contracts its row block; psum over shards reconstructs (P, K)."""
    n_local = mat2_rows.shape[0]

    def one(m2, vb):
        pb, _, k = vb.shape
        v_rows = jax.lax.dynamic_slice(vb, (0, row_offset, 0),
                                       (pb, n_local, k))
        return sw_cols_contract(m2, vb, v_rows)

    return _scan_v_blocks(one, mat2_rows, vperms, perm_block)
