"""Design-matrix subsystem: the one-hot label path generalized.

The paper's matmul reformulation of the s_W contraction builds the weighted
one-hot factor E (E[i, g] = sqrt(1/n_g) 1[g_i == g]) and computes
s_W = 1/2 <mat2, E E'> on the MXU. That factor is a special case of a much
more general identity (McArdle & Anderson 2001 hat-matrix PERMANOVA): for
ANY model whose hat matrix is H = Q Q' — Q an orthonormal basis of the
design's column space, intercept included — the residual sum of squares of
Anderson's partitioning is a plain matmul contraction against the squared
distance matrix:

    SS_resid(H) = tr[(I - H) G (I - H)] = 1/2 <mat2, H>
                = 1/2 sum_k q_k' mat2 q_k

(G = -1/2 C mat2 C is the Gower-centered matrix; H 1 = 1 and the zero
diagonal of mat2 collapse the trace form). The one-hot E *is* such a Q
(its columns are orthonormal and span [1 | group indicators]), which is
exactly why the paper's one-hot matmul computes s_W. Everything downstream
of this module therefore stays a tiled matmul against D² slabs — the
memory-bound dataflow the paper optimizes is untouched; only the
right-hand-side operand changes.

Sequential (adonis2-style) terms: assemble X = [1 | X_term1 | X_term2 ...]
and Gram-Schmidt each term block against everything before it (fp64 QR /
SVD per block, rank-revealing). Because the blocks are mutually
orthonormal, the cumulative-model residuals telescope per COLUMN:

    SS explained by term t = SS_resid(terms < t) - SS_resid(terms <= t)
                           = -1/2 sum_{k in term t} q_k' mat2 q_k

so one per-column contraction (fstat.sw_cols_contract) yields every
term's partial SS and the full-model residual in a single pass:

    F_t[p] = (SS_t[p] / df_t) / (SS_resid_full[p] / dof_resid)

with permutation p acting by row-permuting Q (equivalently permuting the
distance matrix — vegan's "permute raw observations" convention).

Sample weights fold in as W^(1/2): the basis is an orthonormal basis of
col(W^(1/2) X) with the W^(1/2) factor folded back into the operand
columns, so the contraction against the *raw* mat2 computes the weighted
residual 1/2 <W^(1/2) mat2 W^(1/2), H_w>; the intercept column then gives
the weighted total SS s_T^w = sum_ij w_i w_j d_ij² / (2 sum w). Uniform
weights reduce to the unweighted statistic exactly.

Two compilation modes keep the paper's fast path byte-identical:

  'labels'  single categorical factor, no weights: operands are the raw
            labels + inv_group_sizes — every existing s_W impl (brute /
            tiled / matmul / Pallas / fused megakernel) consumes them
            exactly as before; permutations.permutation_batch_dyn (or the
            strata-restricted generator) permutes labels. The no-strata
            case compiles to the SAME programs as the pre-design repo.
  'dense'   anything else (covariates, multiple factors, weights):
            operands are the (n, K) orthonormal basis plus per-term
            column spans; permutations act as row-index gathers and the
            contraction is the per-column matmul form.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

MODE_LABELS = "labels"
MODE_DENSE = "dense"

# Rank tolerance for the fp64 per-term orthogonalization: singular values
# below RANK_TOL * s_max * sqrt(n) are treated as collinear with earlier
# terms and dropped (their df is absorbed by the terms before them).
RANK_TOL = 1e-10


@dataclasses.dataclass(frozen=True)
class Term:
    """One model term: a contiguous span of orthonormal basis columns.

    df is the RANK INCREMENT the term contributes beyond everything before
    it (a g-level factor after the intercept has df g-1; a covariate
    collinear with earlier terms has df 0 and is reported as such).
    lo/hi index the dense basis columns; in labels mode they are 0/0
    (the one-hot operand is not column-sliced).
    """
    name: str
    kind: str          # 'intercept' | 'factor' | 'covariate'
    df: int
    lo: int = 0
    hi: int = 0


class DesignOperands(NamedTuple):
    """What the s_W implementations actually consume.

    labels mode: `grouping` (n,) int32 + `inv_group_sizes` (G,) f32 — the
    exact operands of the pre-design repo (every registry impl, the Pallas
    kernels and the fused megakernel take them unchanged).
    dense mode: `basis` (n, K) f32 — hat-matrix factor blocks; permuted
    row-gathers of it replace the one-hot G matrix on the matmul paths.
    """
    mode: str
    grouping: Optional[Array]
    inv_group_sizes: Optional[Array]
    n_groups: Optional[int]
    basis: Optional[Array]
    term_cols: Tuple[Tuple[int, int], ...]   # (lo, hi) per term, dense mode


@dataclasses.dataclass
class Design:
    """A compiled PERMANOVA design: terms, permutation scheme, operands."""
    n: int
    mode: str                       # MODE_LABELS | MODE_DENSE
    terms: Tuple[Term, ...]         # term 0 is always the intercept
    dof_resid: int
    # labels mode
    grouping: Optional[Array] = None
    n_groups: Optional[int] = None
    # dense mode (basis64 is the fp64 master used by tests/oracles; basis
    # is the f32 operand with any W^(1/2) factor folded in)
    basis: Optional[Array] = None
    basis64: Optional[np.ndarray] = None
    # shared
    strata: Optional[Array] = None  # (n,) int32 or None (free permutation)
    weights: Optional[np.ndarray] = None

    @property
    def rank(self) -> int:
        """Total model rank, intercept included (== dense basis width)."""
        return sum(t.df for t in self.terms)

    @property
    def k_cols(self) -> int:
        return 0 if self.basis is None else int(self.basis.shape[1])

    @property
    def is_plain_labels(self) -> bool:
        """True when this design IS the pre-refactor fast path: a single
        categorical factor, free permutations — routed through the exact
        label-based programs (bit-identical results, identical HLO)."""
        return self.mode == MODE_LABELS and self.strata is None

    @property
    def operands(self) -> DesignOperands:
        if self.mode == MODE_LABELS:
            from repro.core import permutations
            return DesignOperands(
                mode=MODE_LABELS, grouping=self.grouping,
                inv_group_sizes=permutations.inv_group_sizes(
                    self.grouping, self.n_groups),
                n_groups=self.n_groups, basis=None, term_cols=())
        return DesignOperands(
            mode=MODE_DENSE, grouping=None, inv_group_sizes=None,
            n_groups=self.n_groups, basis=self.basis,
            term_cols=tuple((t.lo, t.hi) for t in self.terms))

    def describe(self) -> str:
        ts = "+".join(f"{t.name}({t.df})" for t in self.terms[1:])
        extra = []
        if self.strata is not None:
            extra.append("strata")
        if self.weights is not None:
            extra.append("weighted")
        tail = f" [{','.join(extra)}]" if extra else ""
        return f"design[{self.mode}] ~ {ts or '1'}{tail}"

    # -- constructors -----------------------------------------------------

    @staticmethod
    def from_labels(grouping, *, n_groups: Optional[int] = None,
                    strata=None, weights=None,
                    name: str = "grouping") -> "Design":
        """The compat shim: a single categorical factor.

        Without weights this compiles to LABELS mode — the operands are the
        caller's label array itself, so every pre-design call site routes
        through here with zero behavior change. Weights force dense mode
        (the one-hot factor is no longer orthonormal under W)."""
        if isinstance(grouping, Design):
            return grouping
        grouping = jnp.asarray(grouping, jnp.int32)
        n = int(grouping.shape[0])
        if n_groups is None:
            n_groups = int(jnp.max(grouping)) + 1
        if weights is not None:
            return build(grouping=grouping, n_groups=n_groups,
                         strata=strata, weights=weights, factor_name=name)
        strata_arr = None if strata is None else jnp.asarray(strata,
                                                             jnp.int32)
        terms = (Term("intercept", "intercept", 1),
                 Term(name, "factor", n_groups - 1))
        return Design(n=n, mode=MODE_LABELS, terms=terms,
                      dof_resid=n - n_groups, grouping=grouping,
                      n_groups=n_groups, strata=strata_arr)


# ---------------------------------------------------------------------------
# Dense-basis construction (fp64 host arithmetic).
# ---------------------------------------------------------------------------

def _orth_block(q_prev: np.ndarray, cols: np.ndarray) -> np.ndarray:
    """Orthonormal basis of cols' component orthogonal to span(q_prev).

    Two projection passes (classical Gram-Schmidt re-orthogonalization)
    then a rank-revealing SVD; fp64 throughout."""
    x = np.asarray(cols, np.float64)
    if x.ndim == 1:
        x = x[:, None]
    for _ in range(2):
        if q_prev.shape[1]:
            x = x - q_prev @ (q_prev.T @ x)
    u, s, _ = np.linalg.svd(x, full_matrices=False)
    if s.size == 0:
        return u[:, :0]
    thresh = RANK_TOL * max(1.0, float(s[0])) * np.sqrt(x.shape[0])
    r = int(np.sum(s > thresh))
    return u[:, :r]


def _one_hot_np(labels: np.ndarray, n_groups: int) -> np.ndarray:
    out = np.zeros((labels.shape[0], n_groups), np.float64)
    out[np.arange(labels.shape[0]), labels] = 1.0
    return out


def _normalize_covariates(covariates, n: int) -> List[Tuple[str, np.ndarray]]:
    """Accepts a dict name->(n,), a list of (name, values), or a plain
    (n,)/(n, c) array (auto-named cov0..)."""
    if covariates is None:
        return []
    if isinstance(covariates, dict):
        items = list(covariates.items())
    elif isinstance(covariates, (list, tuple)) and covariates and \
            isinstance(covariates[0], (list, tuple)) and \
            len(covariates[0]) == 2 and isinstance(covariates[0][0], str):
        items = list(covariates)
    else:
        arr = np.asarray(covariates, np.float64)
        if arr.ndim == 1:
            arr = arr[:, None]
        if arr.ndim != 2 or arr.shape[0] != n:
            raise ValueError(f"covariates must be (n, c) with n={n}; "
                             f"got shape {arr.shape}")
        items = [(f"cov{j}", arr[:, j]) for j in range(arr.shape[1])]
    out = []
    for name, v in items:
        v = np.asarray(v, np.float64).reshape(-1)
        if v.shape[0] != n:
            raise ValueError(f"covariate {name!r} has {v.shape[0]} values, "
                             f"expected {n}")
        out.append((str(name), v))
    return out


def _normalize_factors(factors, grouping, n_groups, factor_name):
    """Ordered (name, labels int64 (n,), n_levels) triples."""
    items = []
    if factors is not None:
        it = factors.items() if isinstance(factors, dict) else factors
        for name, labels in it:
            items.append((str(name), np.asarray(labels, np.int64)))
    if grouping is not None:
        items.append((str(factor_name), np.asarray(grouping, np.int64)))
    out = []
    for name, labels in items:
        levels = int(labels.max()) + 1 if labels.size else 0
        out.append((name, labels, levels))
    if grouping is not None and n_groups is not None:
        name, labels, _ = out[-1]
        out[-1] = (name, labels, int(n_groups))
    return out


def build(*, grouping=None, covariates=None, factors=None, strata=None,
          weights=None, n_groups: Optional[int] = None, n: Optional[int] = None,
          factor_name: str = "grouping", force_dense: bool = False) -> Design:
    """Compile a PERMANOVA design.

    Model term order is adonis2-sequential: covariates first, extra
    factors next, the primary `grouping` factor LAST — so the headline
    factor's partial F is adjusted for every covariate (the partial /
    covariate-PERMANOVA reading). Pass `factors` (ordered mapping) for
    multi-factor models; `grouping` stays the final term.

    A single factor with no covariates/weights compiles to labels mode —
    the pre-design fast path, byte-identical operands — unless
    force_dense=True (the batched multi-study program runs ONE dense
    contraction for every design shape).
    """
    covs = _normalize_covariates(covariates, _infer_n(grouping, covariates,
                                                      n))
    n = _infer_n(grouping, covariates, n)
    facs = _normalize_factors(factors, grouping, n_groups, factor_name)
    if not facs and not covs:
        raise ValueError("design needs at least one factor or covariate")
    single_factor = (len(facs) == 1 and not covs and weights is None
                     and not force_dense)
    if single_factor:
        return Design.from_labels(facs[0][1].astype(np.int32),
                                  n_groups=facs[0][2], strata=strata,
                                  name=facs[0][0])

    w = None
    if weights is not None:
        w = np.asarray(weights, np.float64).reshape(-1)
        if w.shape[0] != n:
            raise ValueError(f"weights must be (n,); got {w.shape}")
        if np.any(w < 0) or not np.any(w > 0):
            raise ValueError("weights must be non-negative with at least "
                             "one positive entry")
    sw = np.sqrt(w) if w is not None else np.ones((n,), np.float64)

    # intercept first, then covariates, then factors (grouping last)
    blocks: List[Tuple[str, str, np.ndarray]] = [
        ("intercept", "intercept", np.ones((n, 1), np.float64))]
    for name, v in covs:
        blocks.append((name, "covariate", v[:, None]))
    for name, labels, levels in facs:
        blocks.append((name, "factor", _one_hot_np(labels, levels)))

    q = np.zeros((n, 0), np.float64)
    terms: List[Term] = []
    for name, kind, cols in blocks:
        qb = _orth_block(q, sw[:, None] * cols)
        lo = q.shape[1]
        q = np.concatenate([q, qb], axis=1)
        terms.append(Term(name, kind, qb.shape[1], lo, q.shape[1]))
    if terms[0].df != 1:  # pragma: no cover - sw has a positive entry
        raise ValueError("degenerate design: empty intercept")
    k = q.shape[1]
    dof_resid = n - k
    if dof_resid <= 0:
        raise ValueError(f"design is saturated: rank {k} >= n={n} leaves "
                         "no residual degrees of freedom")
    basis64 = sw[:, None] * q          # W^(1/2) folded into the operand
    strata_arr = None if strata is None else jnp.asarray(strata, jnp.int32)
    ngrp = facs[-1][2] if facs else None
    grp = (jnp.asarray(facs[-1][1], jnp.int32) if facs else None)
    return Design(n=n, mode=MODE_DENSE, terms=tuple(terms),
                  dof_resid=dof_resid, grouping=grp, n_groups=ngrp,
                  basis=jnp.asarray(basis64, jnp.float32), basis64=basis64,
                  strata=strata_arr, weights=w)


def _infer_n(grouping, covariates, n):
    if n is not None:
        return int(n)
    if grouping is not None:
        return int(np.asarray(grouping).shape[0])
    if covariates is None:
        raise ValueError("cannot infer n: pass grouping, covariates, or n=")
    if isinstance(covariates, dict):
        return int(np.asarray(next(iter(covariates.values()))).shape[0])
    if isinstance(covariates, (list, tuple)) and covariates and \
            isinstance(covariates[0], (list, tuple)):
        return int(np.asarray(covariates[0][1]).shape[0])
    arr = np.asarray(covariates)
    return int(arr.shape[0])


def pad_design(design: Design, n_pad: int) -> Design:
    """Zero-pad a dense design to n_pad rows (ragged multi-study batching).

    Pad rows get EXACTLY-ZERO basis rows, so against a zero-padded mat2
    every padded contraction term contributes +0.0 — float sums are
    bit-identical to the unpadded study (x + 0.0 == x), which is what lets
    the ragged `permanova_many` path report observed per-term F that
    bit-matches the unpadded run. dof bookkeeping keeps the TRUE n."""
    if design.mode != MODE_DENSE:
        raise ValueError("pad_design applies to dense-mode designs")
    if n_pad < design.n:
        raise ValueError(f"n_pad={n_pad} < design.n={design.n}")
    pad = n_pad - design.n
    if pad == 0:
        return design
    basis64 = np.pad(design.basis64, ((0, pad), (0, 0)))
    strata = (None if design.strata is None
              else jnp.pad(design.strata, (0, pad)))
    grp = (None if design.grouping is None
           else jnp.pad(design.grouping, (0, pad)))
    return dataclasses.replace(
        design, basis=jnp.asarray(basis64, jnp.float32), basis64=basis64,
        strata=strata, grouping=grp)


# ---------------------------------------------------------------------------
# Per-term statistic assembly from the per-column contraction output.
# ---------------------------------------------------------------------------

class TermStats(NamedTuple):
    """Per-term statistics over the permutation sweep (leading axes free:
    (..., P) for single studies, (S, P) for batched)."""
    ss_resid: Array        # (..., P) full-model residual SS
    s_t: Array             # (...,)   observed total SS (intercept column)
    ss_terms: Array        # (..., P, T) explained SS per non-intercept term
    f_terms: Array         # (..., P, T) pseudo-F per non-intercept term


def term_stats(s_cols: Array, design: Design,
               dof_resid=None) -> TermStats:
    """Assemble per-term F from the per-column quadratic forms.

    s_cols: (..., P, K) output of the sw_cols contraction, column order =
            design.basis columns (intercept at [lo,hi) of term 0).
    dof_resid: scalar or (...,) per-study residual dof (ragged batches
            use true n_s - rank); defaults to design.dof_resid.
    """
    s_cols = jnp.asarray(s_cols)
    icpt = design.terms[0]
    ss_resid = jnp.sum(s_cols, axis=-1)
    s_t = jnp.sum(s_cols[..., 0, icpt.lo:icpt.hi], axis=-1)
    if dof_resid is None:
        dof_resid = design.dof_resid
    dof_resid = jnp.asarray(dof_resid, s_cols.dtype)
    ss_list, f_list = [], []
    for t in design.terms[1:]:
        ss_t = -jnp.sum(s_cols[..., t.lo:t.hi], axis=-1)
        df_t = max(t.df, 1)          # df 0 (collinear term): F defined 0
        denom = ss_resid / dof_resid[..., None]
        f_t = jnp.where(t.df > 0, (ss_t / df_t) / denom,
                        jnp.zeros_like(ss_t))
        ss_list.append(ss_t)
        f_list.append(f_t)
    return TermStats(ss_resid=ss_resid, s_t=s_t,
                     ss_terms=jnp.stack(ss_list, axis=-1),
                     f_terms=jnp.stack(f_list, axis=-1))


def observed_scols_fp64(mat2: np.ndarray, design: Design) -> np.ndarray:
    """fp64 reference of the observed per-column contraction (tests)."""
    b = design.basis64
    return 0.5 * np.einsum("ik,ij,jk->k", b, np.asarray(mat2, np.float64),
                           b)
