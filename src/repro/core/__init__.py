"""PERMANOVA statistics engine — the paper's primary contribution in JAX.

Public API:
  permanova(dm, grouping, ...)            single-host full test
  permanova_distributed(mesh, dm, ...)    sharded over (pod, data, model)
  fstat.sw_{brute,tiled,matmul}           the paper's hot-loop variants
  distance.distance_matrix(x, metric)     input construction

Both permanova entry points are thin wrappers over repro.engine — the
hardware-aware execution layer (impl registry + planner + streaming
permutation scheduler). Pass sw_impl='auto' (the default) to let the
planner encode the paper's CPU-tiled vs GPU-brute result.
"""

from repro.core import design, fstat, permutations, distance, distributed  # noqa: F401
from repro.core.design import Design, Term  # noqa: F401
from repro.core.permanova import (  # noqa: F401
    PermanovaResult,
    TermResult,
    f_from_sw,
    p_value_from_null,
    permanova,
    s_total,
)
from repro.core.distributed import permanova_distributed, sw_distributed  # noqa: F401
