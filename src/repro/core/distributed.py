"""Distributed PERMANOVA over a (pod, data, model) device mesh.

Mapping (DESIGN.md section 4):
  * 'data' (and 'pod' when present) axes shard the PERMUTATION dimension —
    the paper's "most obvious parallelization target". Work is generated
    shard-locally by folding the PRNG key with GLOBAL permutation indices,
    so no (n_perms, n) label tensor ever crosses the network and recovery /
    re-dispatch is idempotent.
  * 'model' shards the distance-matrix ROWS (a 100k^2 fp32 matrix is 40 GB
    and must be split to fit HBM). Each shard computes a partial s_W over
    its row block; one psum over 'model' reconstructs the statistic.

The only inter-pod traffic is the final (n_perms,) gather — DCN-friendly.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import fstat, permutations, permanova as _permanova

try:  # jax >= 0.5 exposes shard_map at top level
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - version-dependent
    from jax.experimental.shard_map import shard_map as _shard_map

Array = jax.Array


def pad_to_multiple(x: Array, multiple: int, axis: int = 0):
    """Zero-pad axis to a multiple (matrix rows for even model sharding)."""
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def _perm_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")


def _my_perm_range(mesh: Mesh, n_perms_padded: int):
    """(lo, hi) of this shard's global permutation indices (traced)."""
    axes = _perm_axes(mesh)
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    idx = jnp.zeros((), jnp.int32)
    for a in axes:  # row-major linearization over permutation axes
        idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
    per = n_perms_padded // total
    return idx * per, per


def resolve_impl(impl: str, n: int, n_perms: int, n_groups: int) -> str:
    """Map an impl request ('auto' or a registry name) to a concrete
    registry impl via the engine planner."""
    from repro import engine  # deferred: engine imports core modules
    pinned = None if impl == "auto" else impl
    return engine.plan(n, n_perms, n_groups, impl=pinned).impl


def make_sw_shard_fn(mesh: Mesh, *, impl: str = "matmul",
                     n_groups: int, identity_first: bool = True,
                     perm_block: int = 64):
    """Build the shard-local body: generate my permutations, compute my
    row-partial s_W, psum over 'model'. Returns f(mat2_rows, grouping, inv_gs,
    key, n_perms_padded) -> (local_perms,) s_W.

    The row-sharded partial is looked up in the engine registry: the exact
    impl's companion when it has one, else the nearest family member
    (tiled -> brute rows, pallas_* -> matmul rows)."""
    from repro import engine  # deferred: engine imports core modules
    partial_fn = engine.get_sharded(impl)
    tuning_key = ("perm_block" if partial_fn is fstat.sw_matmul_rows_partial
                  else "block")

    def shard_body(mat2_rows, grouping, inv_gs, key, n_perms_padded):
        n_local = mat2_rows.shape[0]
        row_offset = jax.lax.axis_index("model") * n_local
        lo, per = _my_perm_range(mesh, n_perms_padded)
        gperms = permutations.permutation_batch_dyn(
            key, grouping, lo, per, identity_first=identity_first)
        part = partial_fn(mat2_rows, row_offset, gperms, inv_gs,
                          **{tuning_key: perm_block})
        return jax.lax.psum(part, axis_name="model")

    return shard_body


def sw_distributed(mesh: Mesh, mat2: Array, grouping: Array, inv_gs: Array,
                   key: jax.Array, n_perms: int, *, impl: str = "matmul",
                   perm_block: int = 64) -> Array:
    """Full-batch distributed s_W. Returns (n_perms_padded,) with the global
    permutation order; entry 0 is the observed statistic."""
    perm_axes = _perm_axes(mesh)
    perm_ways = 1
    for a in perm_axes:
        perm_ways *= mesh.shape[a]
    model_ways = mesh.shape["model"]
    n_perms_padded = n_perms + ((-n_perms) % perm_ways)
    mat2p, _ = pad_to_multiple(mat2, model_ways, axis=0)
    n_groups = inv_gs.shape[0]

    impl = resolve_impl(impl, mat2.shape[0], n_perms, n_groups)
    body = make_sw_shard_fn(mesh, impl=impl, n_groups=n_groups,
                            perm_block=perm_block)
    fn = _shard_map(
        functools.partial(body, n_perms_padded=n_perms_padded),
        mesh=mesh,
        in_specs=(P("model", None), P(), P(), P()),
        out_specs=P(perm_axes),
    )
    return fn(mat2p, grouping, inv_gs, key)


def permanova_distributed(mesh: Mesh, dm: Array, grouping: Array, *,
                          n_perms: int = 999, key: Optional[jax.Array] = None,
                          n_groups: Optional[int] = None,
                          impl: str = "matmul", perm_block: int = 64):
    """Distributed full PERMANOVA. Semantics match core.permanova.permanova
    (up to permutation count padding, which only adds extra null draws).

    Label normalization routes through the design shim like every other
    entry point; only plain single-factor designs run here (strata /
    covariate / weighted designs shard over the STUDY axis via
    engine.permanova_many(mesh=...) instead of matrix rows)."""
    from repro.core import design as _design  # deferred: light cycle guard
    if key is None:
        key = jax.random.key(0)
    dm = jnp.asarray(dm)
    design = _design.Design.from_labels(grouping, n_groups=n_groups)
    if not design.is_plain_labels:
        raise ValueError(
            "permanova_distributed shards matrix rows for plain "
            "single-factor designs; use engine.permanova_many(mesh=...) "
            "for strata/covariate/weighted designs")
    grouping = design.grouping
    n = dm.shape[0]
    n_groups = design.n_groups
    mat2 = dm * dm
    inv_gs = permutations.inv_group_sizes(grouping, n_groups)
    s_w_all = sw_distributed(mesh, mat2, grouping, inv_gs, key, n_perms + 1,
                             impl=impl, perm_block=perm_block)
    s_t = _permanova.s_total(mat2)
    f_all = _permanova.f_from_sw(s_w_all, s_t, n, n_groups)
    return _permanova.PermanovaResult(
        f_stat=f_all[0],
        p_value=_permanova.p_value_from_null(f_all),
        s_t=s_t,
        s_w=s_w_all[0],
        f_perms=f_all,
        n_objects=n,
        n_groups=n_groups,
        n_perms=int(f_all.shape[0]) - 1,
    )
