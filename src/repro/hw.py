"""Target-hardware constants used by the roofline model.

The container is CPU-only; TPU v5e is the *target*. All roofline terms in
benchmarks/ and roofline/ are derived from compiled HLO + these constants.

Sources: spec-provided numbers (197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI). MI300A constants retained for paper-comparison context
(STREAM triad measurements from the paper's Appendix A2).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_flops_bf16: float  # FLOP/s per chip
    peak_flops_f32: float   # FLOP/s per chip (MXU f32 ~= 1/2 bf16 on v5e-class)
    hbm_bandwidth: float    # B/s per chip
    hbm_bytes: float        # HBM capacity per chip
    ici_link_bandwidth: float  # B/s per link
    ici_links: int          # links per chip
    vmem_bytes: float       # on-chip vector memory
    mxu_tile: int = 128     # systolic array dim
    vpu_lanes: tuple = (8, 128)


TPU_V5E = ChipSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    peak_flops_f32=98.5e12,
    hbm_bandwidth=819e9,
    hbm_bytes=16 * 1024**3,
    ici_link_bandwidth=50e9,
    ici_links=4,
    vmem_bytes=128 * 1024**2,
)

# Paper's machine, for the Fig.1 / STREAM comparison tables only.
MI300A_CPU_STREAM_TRIAD = 0.209e12   # B/s measured (paper App. A2)
MI300A_GPU_STREAM_TRIAD = 3.160e12   # B/s measured (paper App. A2)
MI300A_HBM_PEAK = 5.3e12             # B/s datasheet

# Paper's benchmark workload (Fig. 1)
PAPER_N_DIMS = 25145
PAPER_N_PERMS = 3999

TARGET = TPU_V5E


def ridge_point_bf16(chip: ChipSpec = TARGET) -> float:
    """FLOP/byte where the chip transitions memory-bound -> compute-bound."""
    return chip.peak_flops_bf16 / chip.hbm_bandwidth


def ridge_point_f32(chip: ChipSpec = TARGET) -> float:
    return chip.peak_flops_f32 / chip.hbm_bandwidth
