"""Hardware-aware planner + empirical autotuner for the s_W registry.

The heuristics encode the paper's Figure 1 result as dispatch rules:

  backend   choice                       why
  -------   -------------------------   -------------------------------------
  gpu       brute                       MI300A GPU cores prefer the brute
                                        Algorithm 3 (massive thread-level
                                        parallelism hides the re-stream)
  cpu       tiled  (mat2 > LLC)         MI300A CPU cores want the cache-tiled
            matmul (mat2 fits cache)    Algorithm 2 once the matrix spills
                                        the last-level cache; below that the
                                        BLAS/MXU one-hot form dominates
  tpu       pallas_matmul (n >= 256)    MXU one-hot contraction is the only
            matmul        (small n)     form past the v5e ridge point

`plan()` is pure shape/backend arithmetic — no timing. `autotune()` is the
optional measure-and-cache pass: it times every candidate on a small
permutation sample of the *actual* problem and memoizes the winner per
(backend, shape-bucket), so serving paths pay the measurement once.

The plan also fixes the streaming-permutation chunk: the scheduler executes
`n_perms` in fixed-memory chunks, so the label tensor held live is
(chunk, n) int32 instead of (n_perms, n) — that is what lets single-host
100k..1M-permutation runs fit any memory budget.
"""

from __future__ import annotations

import dataclasses
import json
import logging
import os
import time
import warnings
from typing import Dict, Optional, Sequence, Tuple

import jax

from repro.engine import registry
from repro.obs import metrics as _metrics

_log = logging.getLogger(__name__)
_WARNED: set = set()


def _warn_once(tag: str, msg: str) -> None:
    """Log a cache-health warning once per process (not once per plan():
    a 1000-study serving sweep hitting a disabled cache must not emit
    1000 lines). logging, not warnings — tier-1 runs warning-free."""
    if tag in _WARNED:
        return
    _WARNED.add(tag)
    _log.warning(msg)

# Model constants (bytes). LLC: an MI300A CCD carries 32 MiB L3; once mat2
# spills it the paper's tiled dataflow wins on CPU.
CPU_LLC_BYTES = 32 * 1024 ** 2
DEFAULT_STREAM_BUDGET_BYTES = 256 * 1024 ** 2
MIN_CHUNK = 64
PALLAS_MIN_N = 256


@dataclasses.dataclass(frozen=True)
class Plan:
    """A resolved execution plan for one PERMANOVA problem."""
    impl: str                 # registry name
    backend: str
    tuning: Dict[str, int]    # resolved knobs passed to SwImpl.make
    chunk: int                # permutations per scheduler dispatch
    streaming: bool           # True when n_perms+1 > chunk
    reason: str

    def describe(self) -> str:
        t = ",".join(f"{k}={v}" for k, v in sorted(self.tuning.items()))
        mode = f"stream(chunk={self.chunk})" if self.streaming else "batch"
        return f"{self.impl}[{t}] {mode} on {self.backend}: {self.reason}"


def default_backend() -> str:
    return jax.default_backend()


def _pick_impl(backend: str, n: int,
               n_groups: Optional[int] = None) -> Tuple[str, str]:
    if n_groups is not None:
        measured = measured_impl(backend, n, n_groups)
        if measured is not None:
            return measured, ("persisted autotune measurement "
                              f"({autotune_cache_path()})")
    if backend == "gpu":
        return "brute", "GPU cores prefer brute force (paper Fig. 1)"
    if backend == "tpu":
        if n >= PALLAS_MIN_N:
            return "pallas_matmul", "MXU one-hot contraction past ridge point"
        return "matmul", "problem too small for kernel tiles; XLA matmul form"
    # cpu and anything unknown
    mat2_bytes = 4 * n * n
    if backend == "cpu" and mat2_bytes > CPU_LLC_BYTES:
        return "tiled", (f"mat2 {mat2_bytes/2**20:.0f}MiB spills the "
                         f"{CPU_LLC_BYTES/2**20:.0f}MiB LLC; cache-tiled "
                         "Algorithm 2 wins on CPU (paper Fig. 1)")
    return "matmul", "mat2 cache-resident; one-hot BLAS form amortizes reads"


def chunk_for_budget(n: int, n_perms: int, impl: registry.SwImpl,
                     n_groups: int,
                     budget_bytes: Optional[float] = None,
                     n_cols: Optional[int] = None) -> int:
    """Largest permutation chunk whose LABEL tensor fits the budget.

    The budget governs the streamed state — (chunk, n) int32 labels plus the
    per-perm output — which is the only term that scales with n_perms. The
    resident mat2 and the impl's per-block working set are paid regardless
    of chunking and are deliberately not charged against it (n_groups and
    impl are kept in the signature for footprint-aware callers/tests).

    Dense designs (n_cols = K basis columns) stream a bigger state: the
    (chunk, n) int32 index permutations PLUS the gathered (chunk, n, K)
    f32 basis factor — the workset is sized for K design columns instead
    of G groups, so the chunk shrinks accordingly."""
    del n_groups  # labels dominate the streamed state; see docstring
    budget = DEFAULT_STREAM_BUDGET_BYTES if budget_bytes is None else budget_bytes
    per_perm = 4.0 * n + 8.0
    if n_cols is not None:
        per_perm += 4.0 * n * n_cols + 4.0 * n_cols
    if MIN_CHUNK * per_perm > budget:
        warnings.warn(
            f"label budget {budget/2**20:.2f}MiB cannot hold even the "
            f"minimum chunk ({MIN_CHUNK} perms x {4*n} label bytes) at "
            f"n={n}; proceeding with chunk={MIN_CHUNK} — label memory will "
            f"exceed the budget (impl {impl.name!r})",
            stacklevel=2)
        return min(MIN_CHUNK, n_perms)
    chunk = max(MIN_CHUNK, int(budget // per_perm))
    return min(chunk, n_perms)


def plan(n: int, n_perms: int, n_groups: int, *,
         backend: Optional[str] = None,
         memory_budget_bytes: Optional[float] = None,
         chunk: Optional[int] = None,
         impl: Optional[str] = None,
         tuning: Optional[Dict[str, int]] = None,
         n_cols: Optional[int] = None) -> Plan:
    """Resolve impl + tuning + streaming chunk for one problem.

    n_perms counts TOTAL permutation slots (i.e. n_perms_requested + 1 for
    the observed labels at index 0). `impl`/`chunk` pin those choices and
    let the planner fill in the rest.

    n_cols: set to the design-basis width K for DENSE designs
    (covariates/weights/multi-factor): impl choice is restricted to the
    matmul-family forms that carry a dense companion (the contraction is
    matmul-native; label-equality dataflows like `tiled` do not apply),
    and the streaming chunk is sized for the (chunk, n, K) basis factor.
    """
    backend = backend or default_backend()
    if impl is None:
        if n_cols is not None:
            name, reason = _pick_impl_design(backend)
        else:
            name, reason = _pick_impl(backend, n, n_groups)
    else:
        name, reason = impl, "caller-pinned impl"
    if n_cols is not None:
        resolved_name, _ = registry.resolve_cols(name)
        if resolved_name != name:
            reason += (f"; {name!r} is label-only, dense design runs its "
                       f"{resolved_name!r} companion")
            name = resolved_name
    spec = registry.get(name)
    resolved = dict(spec.tuning)
    if tuning:
        resolved.update({k: v for k, v in tuning.items() if k in resolved})
    if chunk is None:
        chunk = chunk_for_budget(n, n_perms, spec, n_groups,
                                 memory_budget_bytes, n_cols=n_cols)
    chunk = max(1, min(int(chunk), n_perms))
    return Plan(impl=name, backend=backend, tuning=resolved, chunk=chunk,
                streaming=chunk < n_perms, reason=reason)


def _pick_impl_design(backend: str) -> Tuple[str, str]:
    """Impl for DENSE designs: the per-column contraction is a tiled
    matmul against mat2 on every backend except the GPU, where the
    re-streaming brute dataflow mirrors the paper's Fig. 1 result."""
    if backend == "gpu":
        return "brute", ("dense design, GPU: per-perm re-stream "
                         "(Fig. 1 brute analogue)")
    return "matmul", ("dense design: per-column matmul contraction "
                      "(hat-matrix blocks on the MXU/BLAS path)")


# ---------------------------------------------------------------------------
# Empirical autotuner: measure-and-cache on the real operands. Winners are
# memoized in-process AND persisted per host to a JSON cache, which is
# loaded lazily at first plan() and fed back into the heuristic defaults —
# so a serving host pays each measurement once EVER, not once per process.
# ---------------------------------------------------------------------------

_AUTOTUNE_CACHE: Dict[tuple, str] = {}
_PERSIST: Optional[Dict[str, dict]] = None   # lazy-loaded disk cache
_DIRTY: set = set()                          # keys THIS process measured
AUTOTUNE_CACHE_ENV = "REPRO_AUTOTUNE_CACHE"

# Entry schema for the dist|/fusedk| key families. Schema 2 adds the
# precision knobs (feat_bf16/feat_fp8/feat_packed) to the fused cache
# keys and tuning payloads; pre-PR6 entries carry no schema field and
# could silently pin fp32 tile shapes onto fp8/packed runs, so they are
# dropped on load (migrate-or-drop). The s_W shoot-out keys
# ('<backend>|n..|g..') predate and outlive the schema — they are kept.
CACHE_SCHEMA = 2


def _valid_entry(key: str, val) -> bool:
    if not (isinstance(val, dict) and "impl" in val):
        return False
    if key.startswith(("dist|", "fusedk|")):
        return val.get("schema") == CACHE_SCHEMA
    return True


def _bucket(n: int) -> int:
    """Shape bucket: next power of two (timings are stable within one)."""
    b = 1
    while b < n:
        b *= 2
    return b


def autotune_cache_path() -> Optional[str]:
    """Per-host cache file; $REPRO_AUTOTUNE_CACHE overrides ('off' disables)."""
    override = os.environ.get(AUTOTUNE_CACHE_ENV)
    if override:
        return None if override.lower() in ("off", "none", "0") else override
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "autotune.json")


def _persist_key(backend: str, n: int, n_groups: int) -> str:
    return f"{backend}|n{_bucket(n)}|g{n_groups}"


def measured_entry(key: str) -> Optional[dict]:
    """One persisted measurement by raw domain key.

    The cache is shared beyond the s_W shoot-outs: the pipeline planner
    persists stage-1 distance and fused-kernel candidate timings under
    'dist|<backend>|<metric>|<impl>' / 'fusedk|<backend>|<metric>|<impl>'
    keys (satellite of the megakernel PR) and reads them back through
    this accessor to seed its defaults."""
    return load_autotune_cache().get(key)


def record_entry(key: str, entry: dict) -> None:
    """Persist one measurement under an arbitrary domain key.

    `entry` must carry an 'impl' field (the load/save filters key on it);
    dist|/fusedk| entries are stamped with the current CACHE_SCHEMA so
    stale-schema entries from older code are dropped on load.
    Same merge-on-save/best-effort semantics as the s_W autotune path."""
    if "impl" not in entry:
        raise ValueError("autotune cache entries must carry an 'impl' field")
    entry = dict(entry)
    entry.setdefault("schema", CACHE_SCHEMA)
    cache = load_autotune_cache()   # BEFORE marking dirty: the first load
    _DIRTY.add(key)                 # in a process clears _DIRTY
    cache[key] = entry
    _save_autotune_cache()


def load_autotune_cache(*, reload: bool = False) -> Dict[str, dict]:
    """Measurements persisted by previous processes on this host."""
    global _PERSIST
    if _PERSIST is not None and not reload:
        return _PERSIST
    _PERSIST = {}
    _DIRTY.clear()   # fresh view: prior writes belong to the old file
    path = autotune_cache_path()
    if path and os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
            if not isinstance(data, dict):
                raise ValueError(f"expected a JSON object, got "
                                 f"{type(data).__name__}")
            _PERSIST = {k: v for k, v in data.items()
                        if _valid_entry(k, v)}
            dropped = len(data) - len(_PERSIST)
            if dropped:
                _metrics.inc("autotune.cache.stale_dropped", dropped)
                _warn_once(
                    "stale", f"autotune cache {path}: dropped {dropped} "
                    f"entr{'y' if dropped == 1 else 'ies'} with a stale "
                    f"schema (current schema {CACHE_SCHEMA}); they will "
                    "be re-measured")
        except (OSError, ValueError) as e:
            # Corrupt or unreadable (typically a crash mid-write truncated
            # the document): QUARANTINE the file so the next writer starts
            # clean and the evidence survives for debugging, then proceed
            # with an empty cache — a serving process must never die over
            # a cache. Warn once per process.
            _quarantine_corrupt_cache(path, e)
    return _PERSIST


def _quarantine_corrupt_cache(path: str, err: Exception) -> None:
    quarantined = f"{path}.corrupt"
    try:
        os.replace(path, quarantined)
        where = f"; quarantined to {quarantined}"
    except OSError:
        where = " (quarantine rename failed; leaving in place)"
    _metrics.inc("autotune.cache.corrupt_quarantined")
    _warn_once("corrupt",
               f"autotune cache {path} is corrupt ({err}); continuing "
               f"with an empty cache{where}. Entries will be re-measured.")


def _save_autotune_cache() -> None:
    global _PERSIST
    path = autotune_cache_path()
    if not path:
        _warn_once(
            "disabled", f"autotune cache disabled (${AUTOTUNE_CACHE_ENV}); "
            "measurements will not persist across processes")
        return
    if _PERSIST is None:
        return
    try:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        # Merge-on-save: re-read and overlay only the keys THIS process
        # measured (not stale loaded copies). Best-effort, not locked — two
        # processes replacing simultaneously can still drop one bucket
        # (TOCTOU between the read and os.replace); the loser simply
        # re-measures on its next run.
        on_disk: Dict[str, dict] = {}
        if os.path.exists(path):
            try:
                with open(path) as f:
                    data = json.load(f)
                if isinstance(data, dict):
                    on_disk = {k: v for k, v in data.items()
                               if _valid_entry(k, v)}
            except (OSError, ValueError):
                pass
        ours = {k: v for k, v in _PERSIST.items() if k in _DIRTY}
        _PERSIST = {**on_disk, **ours}
        # Atomic publish: serialize to a per-pid temp file, fsync, then
        # os.replace. Readers (and the merge-read above) can only ever
        # observe a complete JSON document — concurrent writers cannot
        # interleave partial writes (the two-writer regression test in
        # tests/test_autotune_cache.py hammers exactly this).
        tmp = f"{path}.tmp.{os.getpid()}"
        try:
            with open(tmp, "w") as f:
                json.dump(_PERSIST, f, indent=2, sort_keys=True)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)            # atomic on POSIX
        except BaseException:
            try:
                os.unlink(tmp)               # never leave partial temps
            except OSError:
                pass
            raise
    except OSError:  # read-only home etc. — cache is best-effort
        pass


def _default_candidates(backend: str):
    cands = registry.names(kind="jnp")
    if backend == "tpu":
        cands = list(cands) + registry.names(kind="pallas")
    return cands


def measured_impl(backend: str, n: int, n_groups: int,
                  candidates: Optional[Sequence[str]] = None) -> Optional[str]:
    """Persisted winner for this (backend, shape-bucket, groups), if any.

    Only trusted when it was measured over (at least) the requested
    candidate set — a winner from a restricted shoot-out must not
    short-circuit a broader one — and when the impl is still registered."""
    entry = load_autotune_cache().get(_persist_key(backend, n, n_groups))
    if not entry:
        _metrics.inc("autotune.cache.miss")
        return None
    wanted = set(candidates if candidates is not None
                 else _default_candidates(backend))
    if not wanted <= set(entry.get("candidates", ())):
        _metrics.inc("autotune.cache.miss")
        return None
    name = entry.get("impl")
    try:
        registry.get(name)
    except KeyError:
        _metrics.inc("autotune.cache.miss")
        return None
    _metrics.inc("autotune.cache.hit")
    return name


def autotune(mat2, grouping, inv_gs, *,
             candidates: Optional[Sequence[str]] = None,
             sample_perms: int = 16,
             key: Optional[jax.Array] = None,
             backend: Optional[str] = None,
             use_cache: bool = True) -> str:
    """Time each candidate impl on a small permutation sample of the actual
    operands and return the fastest name. Winners are memoized per
    (backend, n-bucket, n_groups) so steady-state callers measure once."""
    from repro.core import permutations  # local: avoid import cycle at load

    backend = backend or default_backend()
    n = int(mat2.shape[0])
    n_groups = int(inv_gs.shape[0])
    if candidates is None:
        candidates = _default_candidates(backend)
    cache_key = (backend, _bucket(n), n_groups, tuple(sorted(candidates)))
    if use_cache:
        if cache_key in _AUTOTUNE_CACHE:
            _metrics.inc("autotune.cache.hit")
            return _AUTOTUNE_CACHE[cache_key]
        persisted = measured_impl(backend, n, n_groups, candidates)
        if persisted in candidates:
            _AUTOTUNE_CACHE[cache_key] = persisted
            return persisted

    if key is None:
        key = jax.random.key(0)
    gperms = permutations.permutation_batch(key, grouping, 0, sample_perms)
    best_name, best_t = None, float("inf")
    times_us: Dict[str, float] = {}
    for name in candidates:
        fn = jax.jit(registry.get(name).bound())
        try:
            jax.block_until_ready(fn(mat2, gperms, inv_gs))  # compile+warm
            t0 = time.perf_counter()
            jax.block_until_ready(fn(mat2, gperms, inv_gs))
            t = time.perf_counter() - t0
        except Exception:  # noqa: BLE001 — an impl may not lower here
            continue
        times_us[name] = round(t * 1e6, 1)
        if t < best_t:
            best_name, best_t = name, t
    if best_name is None:
        raise RuntimeError("autotune: no candidate impl ran successfully")
    _metrics.inc("autotune.measured")
    if use_cache:
        _AUTOTUNE_CACHE[cache_key] = best_name
        pkey = _persist_key(backend, n, n_groups)
        prior = load_autotune_cache().get(pkey)
        # never let a restricted shoot-out overwrite a broader measurement
        if prior is None or not \
                set(candidates) < set(prior.get("candidates", ())):
            _DIRTY.add(pkey)
            load_autotune_cache()[pkey] = {
                "impl": best_name,
                "candidates": sorted(candidates),
                "times_us": times_us,
                "n": n,
                "n_groups": n_groups,
                "sample_perms": sample_perms,
            }
            _save_autotune_cache()
    return best_name
