"""Streaming permutation scheduler.

Executes an n_perms-permutation sweep in fixed-memory chunks. Labels are
regenerated ON DEVICE per chunk by folding the PRNG key with GLOBAL
permutation indices — the same trick core.distributed uses across shards —
so a single-host 100k..1M-permutation run never materializes the
(n_perms, n) label tensor. Peak live label memory is (chunk, n) int32,
independent of n_perms; results accumulate into a host-side float32 buffer
(4 bytes/perm).

One jitted step program serves every chunk (the start index is a traced
scalar), so the sweep compiles once.
"""

from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as _obs
from repro.core import permutations

Array = jax.Array


class StreamStats(NamedTuple):
    """Execution evidence for tests/telemetry: how the sweep actually ran."""
    n_total: int
    chunk: int
    n_chunks: int
    peak_label_bytes: int   # (chunk, n) int32 — the live label footprint


@functools.partial(jax.jit, static_argnames=("fn", "chunk", "identity_first"))
def _step(mat2, grouping, inv_gs, key, lo, *, fn, chunk, identity_first):
    gperms = permutations.permutation_batch_dyn(
        key, grouping, lo, chunk, identity_first=identity_first)
    return fn(mat2, gperms, inv_gs)


@functools.partial(jax.jit, static_argnames=("fn", "chunk", "identity_first"))
def _step_strata(mat2, grouping, strata, inv_gs, key, lo, *, fn, chunk,
                 identity_first):
    """The strata-restricted cousin of _step: labels composed with
    within-block index permutations; every label-based impl consumes them
    unchanged. A separate jitted program so the free-permutation path
    stays byte-identical to the pre-design repo."""
    gperms = permutations.strata_label_batch_dyn(
        key, grouping, strata, lo, chunk, identity_first=identity_first)
    return fn(mat2, gperms, inv_gs)


@functools.partial(jax.jit, static_argnames=("fn", "chunk", "identity_first"))
def _step_cols(mat2, basis, strata, key, lo, *, fn, chunk, identity_first):
    """Dense-design step: index permutations (strata-restricted; a
    constant strata vector is the free case) gather basis rows, and the
    per-column contraction returns (chunk, K)."""
    from repro.core import fstat
    perms = permutations.strata_permutation_batch_dyn(
        key, strata, lo, chunk, identity_first=identity_first)
    return fn(mat2, fstat.basis_perm_factors(basis, perms))


# ---------------------------------------------------------------------------
# Serving block programs: masked variants of the chunk steps above.
#
# The always-on server (serve/permanova.py) pads every study up to a SHAPE
# BUCKET so one compiled program serves all requests of that bucket; the
# true sample count rides along as a traced `n_valid` scalar and the
# masked/strata permutation generators keep pad rows inert (PR 4's ragged
# contract). Each step computes s_W (or the per-column statistic) for ONE
# BLOCK of global permutation indices [lo, lo+chunk) — the idempotent unit
# of work the elastic executor dispatches, re-dispatches, and speculates:
# key folding by global index makes a block a pure function of (key, lo),
# so recomputation anywhere is bit-identical.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("fn", "chunk", "identity_first"))
def _step_masked(mat2, grouping, n_valid, inv_gs, key, lo, *, fn, chunk,
                 identity_first):
    gperms = permutations.masked_permutation_batch_dyn(
        key, grouping, n_valid, lo, chunk, identity_first=identity_first)
    return fn(mat2, gperms, inv_gs)


@functools.partial(jax.jit, static_argnames=("fn", "chunk", "identity_first"))
def _step_masked_strata(mat2, grouping, strata, n_valid, inv_gs, key, lo, *,
                        fn, chunk, identity_first):
    st = permutations.masked_strata(strata, n_valid)
    gperms = permutations.strata_label_batch_dyn(
        key, grouping, st, lo, chunk, identity_first=identity_first)
    return fn(mat2, gperms, inv_gs)


@functools.partial(jax.jit, static_argnames=("fn", "chunk", "identity_first"))
def _step_masked_cols(mat2, basis, strata, n_valid, key, lo, *, fn, chunk,
                      identity_first):
    from repro.core import fstat
    st = permutations.masked_strata(strata, n_valid)
    perms = permutations.strata_permutation_batch_dyn(
        key, st, lo, chunk, identity_first=identity_first)
    return fn(mat2, fstat.basis_perm_factors(basis, perms))


@functools.partial(jax.jit, static_argnames=("fn", "chunk", "identity_first"))
def _step_masked_many(mat2, grouping, n_valid, inv_gs, key, lo, *, fn,
                      chunk, identity_first):
    """Batched-bucket label step: the vmapped cousin of `_step_masked`.

    All leading-S operands are stacked same-bucket studies; `n_valid` is a
    traced (S,) vector so one compiled program serves any mix of true
    sample counts within the bucket. Each study draws its labels from ITS
    OWN key folded by the GLOBAL permutation index, so row s of the
    result is bit-identical to an unbatched `_step_masked` call with that
    study's operands (asserted by the serve batched-vs-serial tests)."""
    def one(m2, g, nv, igs, k):
        gperms = permutations.masked_permutation_batch_dyn(
            k, g, nv, lo, chunk, identity_first=identity_first)
        return fn(m2, gperms, igs)
    return jax.vmap(one)(mat2, grouping, n_valid, inv_gs, key)


@functools.partial(jax.jit, static_argnames=("fn", "chunk", "identity_first"))
def _step_masked_strata_many(mat2, grouping, strata, n_valid, inv_gs, key,
                             lo, *, fn, chunk, identity_first):
    def one(m2, g, st, nv, igs, k):
        stm = permutations.masked_strata(st, nv)
        gperms = permutations.strata_label_batch_dyn(
            k, g, stm, lo, chunk, identity_first=identity_first)
        return fn(m2, gperms, igs)
    return jax.vmap(one)(mat2, grouping, strata, n_valid, inv_gs, key)


@functools.partial(jax.jit, static_argnames=("fn", "chunk", "identity_first"))
def _step_masked_cols_many(mat2, basis, strata, n_valid, key, lo, *, fn,
                           chunk, identity_first):
    from repro.core import fstat

    def one(m2, bs, st, nv, k):
        stm = permutations.masked_strata(st, nv)
        perms = permutations.strata_permutation_batch_dyn(
            k, stm, lo, chunk, identity_first=identity_first)
        return fn(m2, fstat.basis_perm_factors(bs, perms))
    return jax.vmap(one)(mat2, basis, strata, n_valid, key)


def sw_block_many(mat2, grouping, n_valid, inv_gs, keys, lo: int, *, fn,
                  block: int, strata=None):
    """One label-mode serving block for a BATCH of same-bucket studies:
    (S, block) s_W values for global permutation indices [lo, lo+block)
    across all S studies in one dispatch. Operands carry a leading study
    axis (shardable over the 'data' mesh axis when the caller device_puts
    them with a NamedSharding); `keys` is the (S,) stack of per-study PRNG
    keys, so study s's column is bit-identical to `sw_block` on study s
    alone. Plain batches pass strata=None."""
    if strata is None:
        return _step_masked_many(mat2, grouping, n_valid, inv_gs, keys,
                                 jnp.int32(lo), fn=fn, chunk=block,
                                 identity_first=True)
    return _step_masked_strata_many(mat2, grouping, strata, n_valid, inv_gs,
                                    keys, jnp.int32(lo), fn=fn, chunk=block,
                                    identity_first=True)


def sw_cols_block_many(mat2, basis, strata, n_valid, keys, lo: int, *, fn,
                       block: int):
    """One dense-design serving block for a batch of same-bucket studies:
    (S, block, K) per-column statistics in one dispatch."""
    return _step_masked_cols_many(mat2, basis, strata, n_valid, keys,
                                  jnp.int32(lo), fn=fn, chunk=block,
                                  identity_first=True)


def sw_block(mat2, grouping, n_valid, inv_gs, key, lo: int, *, fn,
             block: int, strata=None):
    """One label-mode serving block: s_W for global permutation indices
    [lo, lo+block) on a (possibly padded) study. Returns a device array
    of length `block`; callers slice the final ragged block themselves.
    Plain requests pass strata=None; the strata-restricted program is a
    separate jitted step so the free path's draws never change."""
    if strata is None:
        return _step_masked(mat2, grouping, n_valid, inv_gs, key,
                            jnp.int32(lo), fn=fn, chunk=block,
                            identity_first=True)
    return _step_masked_strata(mat2, grouping, strata, n_valid, inv_gs, key,
                               jnp.int32(lo), fn=fn, chunk=block,
                               identity_first=True)


def sw_cols_block(mat2, basis, strata, n_valid, key, lo: int, *, fn,
                  block: int):
    """One dense-design serving block: (block, K) per-column statistics
    for global permutation indices [lo, lo+block)."""
    return _step_masked_cols(mat2, basis, strata, n_valid, key,
                             jnp.int32(lo), fn=fn, chunk=block,
                             identity_first=True)


def sw_streaming(mat2: Array, grouping: Array, inv_gs: Array, key: jax.Array,
                 n_total: int, fn: Callable, *, chunk: int,
                 identity_first: bool = True,
                 strata: Optional[Array] = None,
                 progress: Optional[Callable[[int, int], None]] = None):
    """s_W for global permutation indices [0, n_total) in fixed-size chunks.

    fn: batch impl fn(mat2, groupings, inv_gs) -> (P,) (a registry impl
        bound via SwImpl.bound(), or any compatible callable; must be
        jit-traceable).
    strata: optional (n,) int32 block labels — permutations restricted
        within blocks (core.permutations.strata_permutation_batch); None
        is the pre-design free-permutation program, unchanged.
    Returns (s_w float32 ndarray of shape (n_total,), StreamStats).
    Chunk results beyond n_total (last ragged chunk) are computed and
    discarded — identical labels to any other sweep of the same key, since
    folding is by global index.
    """
    n = int(mat2.shape[0])
    chunk = int(max(1, min(chunk, n_total)))
    out = np.empty((n_total,), np.float32)
    n_chunks = 0
    for lo in range(0, n_total, chunk):
        with _obs.span("engine.sw_chunk", {"lo": lo}):
            if strata is None:
                s = _step(mat2, grouping, inv_gs, key, jnp.int32(lo),
                          fn=fn, chunk=chunk, identity_first=identity_first)
            else:
                s = _step_strata(mat2, grouping, strata, inv_gs, key,
                                 jnp.int32(lo), fn=fn, chunk=chunk,
                                 identity_first=identity_first)
            hi = min(lo + chunk, n_total)
            # np.asarray is the device sync for this chunk — keep it inside
            # the span so chunk wall-time covers completed device work
            out[lo:hi] = np.asarray(s[: hi - lo])
        n_chunks += 1
        if progress is not None:
            progress(hi, n_total)
    stats = StreamStats(n_total=n_total, chunk=chunk, n_chunks=n_chunks,
                        peak_label_bytes=4 * chunk * n)
    _obs.metrics.inc("engine.perm_chunks", n_chunks)
    _obs.metrics.gauge_set("engine.peak_label_bytes",
                           stats.peak_label_bytes)
    return out, stats


@functools.partial(jax.jit, static_argnames=("fn", "n_total",
                                             "identity_first"))
def _batch_step(mat2, grouping, inv_gs, key, *, fn, n_total, identity_first):
    gperms = permutations.permutation_batch(
        key, grouping, 0, n_total, identity_first=identity_first)
    return fn(mat2, gperms, inv_gs)


@functools.partial(jax.jit, static_argnames=("fn", "n_total",
                                             "identity_first"))
def _batch_step_strata(mat2, grouping, strata, inv_gs, key, *, fn, n_total,
                       identity_first):
    gperms = permutations.strata_label_batch_dyn(
        key, grouping, strata, jnp.int32(0), n_total,
        identity_first=identity_first)
    return fn(mat2, gperms, inv_gs)


def sw_batch(mat2: Array, grouping: Array, inv_gs: Array, key: jax.Array,
             n_total: int, fn: Callable, *, identity_first: bool = True,
             strata: Optional[Array] = None):
    """One-shot path for small sweeps: materialize all labels, single
    dispatch. Same key semantics as the streaming path.

    The step is one jitted program keyed on the (memoized) impl callable,
    like the streaming `_step`. The previous eager form re-traced any
    scan inside the impl on EVERY call, so a warm serving process paid a
    fresh jaxpr trace per request — the obs retrace counter caught it."""
    with _obs.span("engine.sw_chunk", {"lo": 0}):
        if strata is None:
            s_w = _batch_step(mat2, grouping, inv_gs, key, fn=fn,
                              n_total=n_total, identity_first=identity_first)
        else:
            s_w = _batch_step_strata(
                mat2, grouping, strata, inv_gs, key, fn=fn, n_total=n_total,
                identity_first=identity_first)
        s_w = _obs.maybe_block(s_w)
    stats = StreamStats(n_total=n_total, chunk=n_total, n_chunks=1,
                        peak_label_bytes=4 * n_total * int(mat2.shape[0]))
    _obs.metrics.inc("engine.perm_chunks", 1)
    _obs.metrics.gauge_set("engine.peak_label_bytes",
                           stats.peak_label_bytes)
    return s_w, stats


# ---------------------------------------------------------------------------
# Dense-design sweeps: per-column contraction of permuted basis factors.
# ---------------------------------------------------------------------------

def sw_cols_streaming(mat2: Array, basis: Array, strata: Array,
                      key: jax.Array, n_total: int, fn: Callable, *,
                      chunk: int, identity_first: bool = True,
                      progress: Optional[Callable[[int, int], None]] = None):
    """Per-column statistic (n_total, K) in fixed-memory chunks.

    The streamed state is (chunk, n) int32 index permutations plus the
    gathered (chunk, n, K) basis factor (the planner sizes the chunk for
    K columns); results accumulate host-side exactly like sw_streaming.
    `strata` is always an array here — pass zeros(n) for free
    permutations (the dense-mode draws come from the strata generator, a
    distinct deterministic stream from the label path's).
    """
    n = int(mat2.shape[0])
    k = int(basis.shape[1])
    chunk = int(max(1, min(chunk, n_total)))
    out = np.empty((n_total, k), np.float32)
    n_chunks = 0
    for lo in range(0, n_total, chunk):
        with _obs.span("engine.sw_chunk", {"lo": lo, "cols": k}):
            s = _step_cols(mat2, basis, strata, key, jnp.int32(lo),
                           fn=fn, chunk=chunk, identity_first=identity_first)
            hi = min(lo + chunk, n_total)
            out[lo:hi] = np.asarray(s[: hi - lo])
        n_chunks += 1
        if progress is not None:
            progress(hi, n_total)
    stats = StreamStats(n_total=n_total, chunk=chunk, n_chunks=n_chunks,
                        peak_label_bytes=4 * chunk * n * (k + 1))
    _obs.metrics.inc("engine.perm_chunks", n_chunks)
    _obs.metrics.gauge_set("engine.peak_label_bytes",
                           stats.peak_label_bytes)
    return out, stats
