"""Unified s_W implementation registry.

The paper's central finding is that the optimal s_W dataflow is
*hardware-dependent*: the MI300A CPU cores want the cache-tiled Algorithm 2
while the GPU cores prefer brute force. Before this module existed the repo
hard-coded implementation choice in three disconnected places (`SW_IMPLS` in
core/permanova.py, `VARIANTS` in kernels/permanova_sw/ops.py, impl strings
in core/distributed.py). The registry is the single source of truth: every
implementation sits behind one batch interface

    fn(mat2, groupings, inv_group_sizes) -> (n_perms,) s_W

with capability metadata (performant backends, working-set model, padding
contract, row-sharded companion) that the planner consumes to pick the right
dataflow for the hardware at hand.

Registered implementations:

  brute / tiled / matmul          pure-jnp forms from core.fstat
  pallas_brute / pallas_permblock / pallas_matmul
                                  the Pallas TPU kernels (interpret mode off
                                  TPU), via kernels.permanova_sw.ops
  brute / matmul `.sharded`       row-sharded partials for shard_map
                                  distribution (core.fstat.sw_*_rows_partial)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Mapping, Optional, Tuple

from repro.core import fstat

JNP_IMPLS = ("brute", "tiled", "matmul")
PALLAS_IMPLS = ("pallas_brute", "pallas_permblock", "pallas_matmul")


@dataclasses.dataclass(frozen=True)
class SwImpl:
    """One s_W implementation plus the metadata the planner dispatches on.

    make(**tuning) binds tuning knobs and returns the batch callable
    fn(mat2, groupings, inv_group_sizes) -> (n_perms,) float32.
    """
    name: str
    kind: str                      # 'jnp' | 'pallas'
    make: Callable[..., Callable]
    backends: Tuple[str, ...]      # backends where this dataflow is the
                                   # *performant* choice (all impls run
                                   # correctly on every backend)
    tuning: Mapping[str, int]      # default tuning knobs accepted by make()
    pad_contract: str              # 'none' (any n accepted as-is) |
                                   # 'internal' (pads n to a tile multiple
                                   # with a sentinel/zero region itself)
    description: str = ""
    sharded: Optional[Callable] = None
    # row-sharded companion with signature
    # (mat2_rows, row_offset, groupings, inv_group_sizes, **tuning) -> (P,)
    cols: Optional[Callable] = None
    # design-basis companion for DENSE designs (core.design): signature
    # (mat2, vperms (P, n, K)) -> (P, K) per-column quadratic forms.
    # Label-mode designs (single categorical factor, with or without
    # strata=) need no companion — every impl consumes permuted labels
    # unchanged. Impls whose dataflow is label-equality-specific (tiled,
    # the Pallas label kernels) leave this None; the planner falls back
    # to a matmul-family companion for dense designs. (The row-sharded
    # dense partial lives in fstat.sw_cols_rows_partial for shard_map
    # callers; matrix-resident dense sharding is a ROADMAP item.)

    def bound(self, **overrides) -> Callable:
        """Resolve tuning (defaults <- overrides) and build the callable.

        Bound callables are memoized per (impl, tuning): the scheduler's
        jitted step keys on the callable's identity, so a stable object
        means repeat run() calls reuse the compiled program instead of
        retracing (and the jit cache stays bounded)."""
        kw = {k: v for k, v in {**self.tuning, **overrides}.items()
              if k in self.tuning}
        cache_key = (self.name, tuple(sorted(kw.items())))
        fn = _BOUND_CACHE.get(cache_key)
        if fn is None:
            fn = _BOUND_CACHE[cache_key] = self.make(**kw)
        return fn


_REGISTRY: dict = {}
_BOUND_CACHE: dict = {}


def register(impl: SwImpl) -> SwImpl:
    if impl.name in _REGISTRY:
        raise ValueError(f"duplicate s_W impl {impl.name!r}")
    _REGISTRY[impl.name] = impl
    return impl


def get(name: str) -> SwImpl:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown s_W impl {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def names(*, backend: Optional[str] = None, kind: Optional[str] = None):
    """Registered impl names, optionally filtered by performant backend."""
    out = []
    for n, impl in _REGISTRY.items():
        if backend is not None and backend not in impl.backends:
            continue
        if kind is not None and impl.kind != kind:
            continue
        out.append(n)
    return sorted(out)


def get_sharded(name: str) -> Callable:
    """Row-sharded partial for `name`, falling back to the nearest family
    member (tiled -> brute rows, pallas_* -> matmul rows) when the exact
    impl has no shard-map companion."""
    impl = get(name)
    if impl.sharded is not None:
        return impl.sharded
    fallback = "matmul" if ("matmul" in name or "permblock" in name) \
        else "brute"
    return get(fallback).sharded


def resolve_cols(name: str) -> Tuple[str, Callable]:
    """(impl name, dense-design companion) for `name`, falling back to the
    jnp matmul form when the exact impl is label-only (tiled and the
    Pallas label kernels route dense designs there — the contraction is
    the same tiled matmul against mat2 either way)."""
    impl = get(name)
    if impl.cols is not None:
        return name, impl.cols
    return "matmul", get("matmul").cols


def bound_cols(name: str, **overrides) -> Callable:
    """Dense-design companion for `name` with tuning bound (memoized, so
    the scheduler's jitted step sees a stable callable — same contract as
    SwImpl.bound)."""
    resolved, fn = resolve_cols(name)
    impl = get(resolved)
    kw = {k: v for k, v in overrides.items() if k in impl.tuning}
    cache_key = ("cols", resolved, tuple(sorted(kw.items())))
    bound = _BOUND_CACHE.get(cache_key)
    if bound is None:
        bound = _BOUND_CACHE[cache_key] = (
            functools.partial(fn, **kw) if kw else fn)
    return bound


# ---------------------------------------------------------------------------
# Registration.
# ---------------------------------------------------------------------------

def _make_jnp(fn):
    def make(**tuning):
        return functools.partial(fn, **tuning) if tuning else fn
    return make


def _make_pallas(variant):
    def make(**tuning):
        from repro.kernels.permanova_sw import ops  # deferred: pallas import
        return ops.make_sw_fn(variant, **tuning)
    return make


register(SwImpl(
    name="brute", kind="jnp", make=_make_jnp(fstat.sw_brute),
    backends=("gpu",), tuning={"block": 32}, pad_contract="none",
    description="paper Algorithm 3 dataflow: every perm re-streams mat2 "
                "(the MI300A GPU winner)",
    sharded=fstat.sw_rows_partial,
    cols=fstat.sw_cols_brute,
))
register(SwImpl(
    name="tiled", kind="jnp", make=_make_jnp(fstat.sw_tiled),
    backends=("cpu",), tuning={"tile": 64, "block": 8}, pad_contract="internal",
    description="paper Algorithm 2 dataflow: cache-tiled loop nest "
                "(the MI300A CPU winner)",
))
register(SwImpl(
    name="matmul", kind="jnp", make=_make_jnp(fstat.sw_matmul),
    backends=("cpu", "tpu"), tuning={"perm_block": 64}, pad_contract="none",
    description="beyond-paper one-hot matmul reformulation (MXU/BLAS-native; "
                "amortizes each mat2 byte over perm_block*G columns)",
    sharded=fstat.sw_matmul_rows_partial,
    cols=fstat.sw_cols_matmul,
))
register(SwImpl(
    name="pallas_brute", kind="pallas", make=_make_pallas("brute"),
    backends=("tpu",), tuning={"tile_r": 256, "tile_c": 256},
    pad_contract="internal",
    description="Pallas transcription of Algorithm 3 (VPU masked "
                "square-accumulate, per-perm mat2 restream)",
))
register(SwImpl(
    name="pallas_permblock", kind="pallas", make=_make_pallas("permblock"),
    backends=("tpu",),
    tuning={"tile_r": 256, "tile_c": 256, "perm_block": 16},
    pad_contract="internal",
    description="paper's CPU tiling insight transplanted to TPU: one "
                "VMEM-resident mat2 tile serves a block of perms",
))
register(SwImpl(
    name="pallas_matmul", kind="pallas", make=_make_pallas("matmul"),
    backends=("tpu",),
    tuning={"tile_r": 256, "tile_c": 256, "perm_block": 16},
    pad_contract="internal",
    description="Pallas MXU one-hot contraction (highest arithmetic "
                "intensity; past the v5e ridge for perm_block*G >= ~512)",
))
