"""Hardware-aware PERMANOVA execution engine.

The paper's result — optimal s_W dataflow depends on the hardware (CPU wants
cache-tiled, GPU wants brute force) — made first-class:

  registry    every s_W implementation behind one batch interface with
              capability metadata (backends, working set, pad contract,
              row-sharded companion)
  planner     backend + shape -> impl + tuning + streaming chunk; optional
              empirical autotune (measure-and-cache on real operands)
  scheduler   fixed-memory streaming permutation sweeps (labels regenerated
              on device per chunk by global-index key folding)
  api         run() single-study entry, permanova_many() batched studies

All repo entry points (core.permanova.permanova, core.distributed, the
launch CLI, benchmarks) route through this package.
"""

from repro.engine import api, planner, registry, scheduler  # noqa: F401
from repro.engine.api import (PermanovaManyResult, design_result,  # noqa: F401
                              permanova_many, run, run_design)
from repro.engine.planner import Plan, autotune, chunk_for_budget, plan  # noqa: F401
from repro.engine.registry import (SwImpl, bound_cols, get,  # noqa: F401
                                   get_sharded, names, resolve_cols)
from repro.engine.scheduler import StreamStats, sw_batch, sw_streaming  # noqa: F401
