"""Engine entry points: every PERMANOVA path in the repo routes through here.

run()              single-host full test; planner-driven impl selection,
                   streaming scheduler for large permutation counts.
permanova_many()   batched multi-study API: vmaps one plan over a stack of
                   distance matrices (the many-users serving scenario).

core.permanova.permanova() and core.distributed.permanova_distributed()
remain the public signatures; they are thin wrappers over this module.
"""

from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Callable, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import permutations
# NOTE: `from repro.core import permanova` would resolve to the *function*
# (the package __init__ rebinds the submodule name); import symbols directly.
from repro.core.permanova import (PermanovaResult, f_from_sw,
                                  p_value_from_null, s_total)
from repro.engine import planner, registry, scheduler

Array = jax.Array


def run(dm: Array, grouping: Array, *, n_perms: int = 999,
        key: Optional[jax.Array] = None, n_groups: Optional[int] = None,
        impl: str = "auto", sw_fn: Optional[Callable] = None,
        memory_budget_bytes: Optional[float] = None,
        chunk: Optional[int] = None, autotune: bool = False,
        backend: Optional[str] = None, tuning: Optional[dict] = None,
        squared: bool = False,
        s_t: Optional[float] = None) -> "PermanovaResult":
    """Full PERMANOVA through the hardware-aware engine.

    impl:  'auto' (planner heuristics; `autotune=True` upgrades to the
           empirical measure-and-cache pass) or any registry name.
    tuning: override the chosen impl's tuning knobs (unknown keys ignored).
    sw_fn: escape hatch — bypass the registry with a custom batch callable.
    memory_budget_bytes / chunk: bound the live label tensor; sweeps larger
           than the chunk run through the streaming scheduler.
    squared: `dm` is already the element-squared matrix mat2 = D∘D (the
           pipeline's streaming builder produces mat2 directly so the raw
           distance matrix is never resident alongside it).
    s_t:   precomputed total sum of squares (the streaming builder
           accumulates it as a Gower marginal); skips one full-matrix
           reduction when provided.
    """
    if key is None:
        key = jax.random.key(0)
    dm = jnp.asarray(dm)
    grouping = jnp.asarray(grouping, dtype=jnp.int32)
    n = dm.shape[0]
    if n_groups is None:
        n_groups = int(jnp.max(grouping)) + 1
    mat2 = dm if squared else dm * dm
    inv_gs = permutations.inv_group_sizes(grouping, n_groups)
    n_total = n_perms + 1

    if sw_fn is not None:
        fn = sw_fn
        pl = planner.plan(n, n_total, n_groups, backend=backend,
                          impl="matmul",  # footprint stand-in for budgeting
                          memory_budget_bytes=memory_budget_bytes,
                          chunk=chunk)
        pl = dataclasses.replace(pl, impl="<custom sw_fn>",
                                 reason="caller-supplied sw_fn")
    else:
        pinned = None if impl == "auto" else impl
        tuned = False
        if autotune and pinned is not None:
            warnings.warn(
                f"autotune=True ignored: impl is pinned to {impl!r} "
                "(use impl='auto' to let measurements pick)", stacklevel=2)
        if pinned is None and autotune:
            pinned = planner.autotune(mat2, grouping, inv_gs,
                                      backend=backend, key=key)
            tuned = True
        pl = planner.plan(n, n_total, n_groups, backend=backend, impl=pinned,
                          memory_budget_bytes=memory_budget_bytes,
                          chunk=chunk, tuning=tuning)
        if tuned:
            pl = dataclasses.replace(
                pl, reason="empirical autotune winner (measured on operands)")
        fn = registry.get(pl.impl).bound(**pl.tuning)

    if pl.streaming:
        s_w_np, stats = scheduler.sw_streaming(
            mat2, grouping, inv_gs, key, n_total, fn, chunk=pl.chunk)
        s_w_all = jnp.asarray(s_w_np)
    else:
        s_w_all, stats = scheduler.sw_batch(
            mat2, grouping, inv_gs, key, n_total, fn)

    s_t = s_total(mat2) if s_t is None else jnp.float32(s_t)
    f_all = f_from_sw(s_w_all, s_t, n, n_groups)
    return PermanovaResult(
        f_stat=f_all[0],
        p_value=p_value_from_null(f_all),
        s_t=s_t,
        s_w=s_w_all[0],
        f_perms=f_all,
        n_objects=n,
        n_groups=n_groups,
        n_perms=n_perms,
        method=f"permanova[{pl.impl}]",
        plan=f"{pl.describe()} chunks={stats.n_chunks}",
    )


# ---------------------------------------------------------------------------
# Batched multi-study API (serving scenario).
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PermanovaManyResult:
    """Stacked results over S studies (leading axis S on every array).

    The shared multi-study result contract for `engine.permanova_many`
    AND `pipeline.pipeline_many`: F, p, effect size R^2, and (when the
    caller asked for ordination) top-k PCoA coordinates with explained
    variance per study.
    """
    f_stat: Array        # (S,)
    p_value: Array       # (S,)
    s_t: Array           # (S,)
    s_w: Array           # (S,)
    f_perms: Array       # (S, n_perms + 1)
    n_objects: int       # common (ragged input: padded) study size n
    n_groups: int
    n_perms: int
    plan: str = ""
    n_valid: Optional[Array] = None   # (S,) per-study sample counts when
                                      # the input was a ragged list
    ordination: object = None         # pipeline.ordination.PCoAResult with
                                      # stacked (S, n, k) coords, or None

    @property
    def r2(self) -> Array:
        """(S,) effect sizes R^2 = s_A / s_T = 1 - s_W / s_T."""
        return 1.0 - self.s_w / self.s_t

    def __len__(self):
        return int(self.f_stat.shape[0])

    def study(self, s: int) -> "PermanovaResult":
        """View one study as a standard PermanovaResult."""
        n_obj = (self.n_objects if self.n_valid is None
                 else int(self.n_valid[s]))
        return PermanovaResult(
            f_stat=self.f_stat[s], p_value=self.p_value[s], s_t=self.s_t[s],
            s_w=self.s_w[s], f_perms=self.f_perms[s],
            n_objects=n_obj, n_groups=self.n_groups,
            n_perms=self.n_perms, method="permanova_many", plan=self.plan,
            ordination=(None if self.ordination is None
                        else self.ordination.study(s)))


def _pad_ragged_studies(dms: Sequence, groupings: Sequence, n_groups: int):
    """Pad a ragged study list to one (S, n_max, n_max) stack.

    Pad distance rows/cols are zero and pad labels carry the SENTINEL
    group `n_groups` — one past the one-hot width, so every s_W form
    sees them contribute exactly nothing (zero one-hot row on the matmul
    path; zero mat2 entries everywhere else)."""
    if len(dms) != len(groupings):
        raise ValueError(f"ragged input: {len(dms)} matrices vs "
                         f"{len(groupings)} groupings")
    sizes = [int(np.asarray(d).shape[0]) for d in dms]
    n = max(sizes)
    s_count = len(dms)
    dm_stack = np.zeros((s_count, n, n), np.float32)
    g_stack = np.full((s_count, n), n_groups, np.int32)     # sentinel pad
    for i, (d, g) in enumerate(zip(dms, groupings)):
        m = sizes[i]
        d = np.asarray(d, np.float32)
        if d.shape != (m, m):
            raise ValueError(f"study {i}: expected square matrix, "
                             f"got {d.shape}")
        dm_stack[i, :m, :m] = d
        g_stack[i, :m] = np.asarray(g, np.int32)
    return (jnp.asarray(dm_stack), jnp.asarray(g_stack),
            jnp.asarray(sizes, jnp.int32))


@functools.lru_cache(maxsize=64)
def _many_program(impl: str, tuning: tuple, ch: int, n_chunks: int,
                  n_total: int, n: int, n_groups: int, ragged: bool):
    """The jitted vmapped multi-study program, cached per static config.

    Rebuilding jax.jit(...) per call would re-trace and re-compile the
    whole chunk-scanned program on every request — fatal for the serving
    scenario this entry point exists for. The registry fn is recreated
    from (impl, tuning) so the cache key is hashable and stable."""
    fn = registry.get(impl).bound(**dict(tuning))

    def one(dm, grouping, study_key, nv):
        mat2 = dm * dm
        inv_gs = permutations.inv_group_sizes(grouping, n_groups)

        def body(_, lo):
            if ragged:   # static: one branch is ever traced
                g = permutations.masked_permutation_batch_dyn(
                    study_key, grouping, nv, lo, ch)
            else:
                g = permutations.permutation_batch_dyn(study_key, grouping,
                                                       lo, ch)
            return None, fn(mat2, g, inv_gs)

        _, sws = jax.lax.scan(body, None, jnp.arange(n_chunks) * ch)
        s_w_all = sws.reshape(-1)[:n_total]
        if ragged:
            s_t = jnp.sum(mat2) / 2.0 / nv
            f_all = f_from_sw(s_w_all, s_t, nv, n_groups)
        else:
            s_t = s_total(mat2)
            f_all = f_from_sw(s_w_all, s_t, n, n_groups)
        return f_all, s_t, s_w_all[0]

    return jax.jit(jax.vmap(one))


def study_axis_padding(mesh, s_count: int):
    """(data_ways, s_pad, wrap_idx) for sharding a study axis over 'data'.

    Study counts that do not divide the axis are wrap-padded (any S
    works, even S < data_ways); callers slice results back to S. Shared
    by engine.permanova_many and pipeline_many's fused path so the two
    multi-study entry points keep one divisibility contract."""
    data_ways = int(mesh.shape.get("data", 1)) if mesh is not None else 0
    if data_ways <= 1:
        return data_ways, 0, None
    s_pad = (-s_count) % data_ways
    idx = jnp.arange(s_count + s_pad) % s_count if s_pad else None
    return data_ways, s_pad, idx


def put_study_sharded(mesh, args):
    """device_put every array with a leading-'data' NamedSharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    def spec(a):
        return NamedSharding(mesh, P(*(["data"] + [None] * (a.ndim - 1))))

    return tuple(jax.device_put(a, spec(a)) for a in args)


def permanova_many(dms: Union[Array, Sequence[Array]],
                   groupings: Union[Array, Sequence[Array]], *,
                   n_groups: int,
                   n_perms: int = 999, key: Optional[jax.Array] = None,
                   impl: str = "auto", chunk: Optional[int] = None,
                   memory_budget_bytes: Optional[float] = None,
                   backend: Optional[str] = None,
                   mesh=None,
                   ordination: Optional[int] = None) -> PermanovaManyResult:
    """PERMANOVA over a stack of studies in one planned, shardable program.

    dms:        (S, n, n) distance matrices — or a RAGGED list of
                (n_s, n_s) matrices, padded internally under one plan
                (pad rows zero, pad labels a sentinel group; per-study
                dof/s_T use the true n_s, recorded in `n_valid`).
    groupings:  (S, n) int labels in [0, n_groups) (a list for ragged
                input); n_groups must be shared — it sets the one-hot
                width (the serving scenario runs many users through one
                study design).
    mesh:       optional jax.sharding.Mesh with a 'data' axis — shards
                the STUDY axis over 'data' (same convention as
                pipeline_many's fused path). Study counts that do not
                divide the axis are padded and sliced. Per-study PRNG
                keys are folded by GLOBAL study index ONCE per dispatch
                before any sharding (the jax 0.4.x shard_map key-folding
                miscompile note in streaming.fused_sw_sharded), so
                sharded == single-host == S separate run() calls,
                bit-identically, regardless of which shard runs a study.
    ordination: optional k — also compute top-k PCoA coordinates per
                study (pipeline.ordination; implicit centered operator,
                no Gower matrix materialized) into `result.ordination`.

    Stacked study s draws its null from fold_in(key, s), so results match
    S independent run(..., key=fold_in(key, s)) calls exactly. Ragged
    studies draw from the masked generator instead (deterministic and
    independent per study, observed F identical to run(); the draws are
    not the unpadded stream — see permutations.masked_permutation_batch_dyn).

    Permutations are chunk-scanned inside the jitted program, so the live
    label tensor is (S, chunk, n) — the same fixed-memory contract as the
    streaming scheduler, vectorized over studies; the engine planner
    still picks the s_W impl and chunk per backend, so each shard runs
    the hardware-aware plan.
    """
    if key is None:
        key = jax.random.key(0)
    ragged = isinstance(dms, (list, tuple))
    if ragged:
        dms, groupings, n_valid = _pad_ragged_studies(dms, groupings,
                                                      n_groups)
    else:
        dms = jnp.asarray(dms)
        groupings = jnp.asarray(groupings, dtype=jnp.int32)
        n_valid = None
    s_count, n = (int(v) for v in groupings.shape)
    n_total = n_perms + 1

    pinned = None if impl == "auto" else impl
    # vmap holds every study's (chunk, n) labels + working set live at once,
    # so the per-study plan gets 1/S of the budget (default included).
    total_budget = (planner.DEFAULT_STREAM_BUDGET_BYTES
                    if memory_budget_bytes is None else memory_budget_bytes)
    per_study_budget = total_budget / s_count
    pl = planner.plan(n, n_total, n_groups, backend=backend, impl=pinned,
                      memory_budget_bytes=per_study_budget, chunk=chunk)
    ch = pl.chunk
    n_chunks = -(-n_total // ch)
    run_many = _many_program(pl.impl, tuple(sorted(pl.tuning.items())),
                             ch, n_chunks, n_total, n, n_groups, ragged)

    nv_arg = (jnp.full((s_count,), n, jnp.float32) if n_valid is None
              else n_valid.astype(jnp.float32))
    study_idx = jnp.arange(s_count)
    args = (dms, groupings, nv_arg)
    where = "vmap"
    data_ways, s_pad, wrap_idx = study_axis_padding(mesh, s_count)
    if wrap_idx is not None:
        # pad the STUDY axis (wrapping, so any S works) by replaying
        # studies; padded results are computed and sliced off below
        args = tuple(jnp.take(a, wrap_idx, axis=0) for a in args)
        study_idx = wrap_idx
    # GLOBAL study index -> per-study key, folded ONCE here, before any
    # sharding (never inside the sharded program: jax 0.4.x miscompile);
    # a padded slot replays its source study's key, so the pad is inert.
    study_keys = jax.vmap(lambda s: jax.random.fold_in(key, s))(study_idx)
    args = (args[0], args[1], study_keys, args[2])
    if data_ways > 1:
        args = put_study_sharded(mesh, args)
        where = (f"vmap@data[{data_ways}]"
                 + (f"+pad{s_pad}" if s_pad else ""))

    f_perms, s_t, s_w = run_many(*args)
    f_perms, s_t, s_w = (a[:s_count] for a in (f_perms, s_t, s_w))
    p_vals = jax.vmap(p_value_from_null)(f_perms)

    ord_res = None
    if ordination is not None:
        # computed OUTSIDE the sharded dispatch (deterministic subspace
        # iteration), so sharded and single-host embeddings are identical
        from repro.pipeline import ordination as _ord  # deferred: cycle
        ord_res = _ord.pcoa_many(dms, int(ordination), n_valid=n_valid)

    return PermanovaManyResult(
        f_stat=f_perms[:, 0], p_value=p_vals, s_t=s_t, s_w=s_w,
        f_perms=f_perms, n_objects=n, n_groups=n_groups, n_perms=n_perms,
        n_valid=n_valid, ordination=ord_res,
        plan=(f"{pl.describe()} studies={s_count}"
              f"{' ragged' if ragged else ''} chunks={n_chunks} [{where}]"))
