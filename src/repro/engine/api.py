"""Engine entry points: every PERMANOVA path in the repo routes through here.

run()              single-host full test; planner-driven impl selection,
                   streaming scheduler for large permutation counts.
permanova_many()   batched multi-study API: vmaps one plan over a stack of
                   distance matrices (the many-users serving scenario).

core.permanova.permanova() and core.distributed.permanova_distributed()
remain the public signatures; they are thin wrappers over this module.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import permutations
# NOTE: `from repro.core import permanova` would resolve to the *function*
# (the package __init__ rebinds the submodule name); import symbols directly.
from repro.core.permanova import (PermanovaResult, f_from_sw,
                                  p_value_from_null, s_total)
from repro.engine import planner, registry, scheduler

Array = jax.Array


def run(dm: Array, grouping: Array, *, n_perms: int = 999,
        key: Optional[jax.Array] = None, n_groups: Optional[int] = None,
        impl: str = "auto", sw_fn: Optional[Callable] = None,
        memory_budget_bytes: Optional[float] = None,
        chunk: Optional[int] = None, autotune: bool = False,
        backend: Optional[str] = None, tuning: Optional[dict] = None,
        squared: bool = False,
        s_t: Optional[float] = None) -> "PermanovaResult":
    """Full PERMANOVA through the hardware-aware engine.

    impl:  'auto' (planner heuristics; `autotune=True` upgrades to the
           empirical measure-and-cache pass) or any registry name.
    tuning: override the chosen impl's tuning knobs (unknown keys ignored).
    sw_fn: escape hatch — bypass the registry with a custom batch callable.
    memory_budget_bytes / chunk: bound the live label tensor; sweeps larger
           than the chunk run through the streaming scheduler.
    squared: `dm` is already the element-squared matrix mat2 = D∘D (the
           pipeline's streaming builder produces mat2 directly so the raw
           distance matrix is never resident alongside it).
    s_t:   precomputed total sum of squares (the streaming builder
           accumulates it as a Gower marginal); skips one full-matrix
           reduction when provided.
    """
    if key is None:
        key = jax.random.key(0)
    dm = jnp.asarray(dm)
    grouping = jnp.asarray(grouping, dtype=jnp.int32)
    n = dm.shape[0]
    if n_groups is None:
        n_groups = int(jnp.max(grouping)) + 1
    mat2 = dm if squared else dm * dm
    inv_gs = permutations.inv_group_sizes(grouping, n_groups)
    n_total = n_perms + 1

    if sw_fn is not None:
        fn = sw_fn
        pl = planner.plan(n, n_total, n_groups, backend=backend,
                          impl="matmul",  # footprint stand-in for budgeting
                          memory_budget_bytes=memory_budget_bytes,
                          chunk=chunk)
        pl = dataclasses.replace(pl, impl="<custom sw_fn>",
                                 reason="caller-supplied sw_fn")
    else:
        pinned = None if impl == "auto" else impl
        tuned = False
        if autotune and pinned is not None:
            warnings.warn(
                f"autotune=True ignored: impl is pinned to {impl!r} "
                "(use impl='auto' to let measurements pick)", stacklevel=2)
        if pinned is None and autotune:
            pinned = planner.autotune(mat2, grouping, inv_gs,
                                      backend=backend, key=key)
            tuned = True
        pl = planner.plan(n, n_total, n_groups, backend=backend, impl=pinned,
                          memory_budget_bytes=memory_budget_bytes,
                          chunk=chunk, tuning=tuning)
        if tuned:
            pl = dataclasses.replace(
                pl, reason="empirical autotune winner (measured on operands)")
        fn = registry.get(pl.impl).bound(**pl.tuning)

    if pl.streaming:
        s_w_np, stats = scheduler.sw_streaming(
            mat2, grouping, inv_gs, key, n_total, fn, chunk=pl.chunk)
        s_w_all = jnp.asarray(s_w_np)
    else:
        s_w_all, stats = scheduler.sw_batch(
            mat2, grouping, inv_gs, key, n_total, fn)

    s_t = s_total(mat2) if s_t is None else jnp.float32(s_t)
    f_all = f_from_sw(s_w_all, s_t, n, n_groups)
    return PermanovaResult(
        f_stat=f_all[0],
        p_value=p_value_from_null(f_all),
        s_t=s_t,
        s_w=s_w_all[0],
        f_perms=f_all,
        n_objects=n,
        n_groups=n_groups,
        n_perms=n_perms,
        method=f"permanova[{pl.impl}]",
        plan=f"{pl.describe()} chunks={stats.n_chunks}",
    )


# ---------------------------------------------------------------------------
# Batched multi-study API (serving scenario).
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PermanovaManyResult:
    """Stacked results over S studies (leading axis S on every array)."""
    f_stat: Array        # (S,)
    p_value: Array       # (S,)
    s_t: Array           # (S,)
    s_w: Array           # (S,)
    f_perms: Array       # (S, n_perms + 1)
    n_objects: int
    n_groups: int
    n_perms: int
    plan: str = ""

    def __len__(self):
        return int(self.f_stat.shape[0])

    def study(self, s: int) -> "PermanovaResult":
        """View one study as a standard PermanovaResult."""
        return PermanovaResult(
            f_stat=self.f_stat[s], p_value=self.p_value[s], s_t=self.s_t[s],
            s_w=self.s_w[s], f_perms=self.f_perms[s],
            n_objects=self.n_objects, n_groups=self.n_groups,
            n_perms=self.n_perms, method="permanova_many", plan=self.plan)


def permanova_many(dms: Array, groupings: Array, *, n_groups: int,
                   n_perms: int = 999, key: Optional[jax.Array] = None,
                   impl: str = "auto", chunk: Optional[int] = None,
                   memory_budget_bytes: Optional[float] = None,
                   backend: Optional[str] = None) -> PermanovaManyResult:
    """PERMANOVA over a stack of studies in one vmapped program.

    dms:        (S, n, n) distance matrices.
    groupings:  (S, n) int labels in [0, n_groups); n_groups must be shared
                (it sets the one-hot width — the serving scenario runs many
                users through one study design).
    Study s draws its null from fold_in(key, s), so results match S
    independent run(..., key=fold_in(key, s)) calls exactly.

    Permutations are chunk-scanned inside the jitted program, so the live
    label tensor is (S, chunk, n) — the same fixed-memory contract as the
    streaming scheduler, vectorized over studies.
    """
    if key is None:
        key = jax.random.key(0)
    dms = jnp.asarray(dms)
    groupings = jnp.asarray(groupings, dtype=jnp.int32)
    s_count, n = groupings.shape
    n_total = n_perms + 1

    pinned = None if impl == "auto" else impl
    # vmap holds every study's (chunk, n) labels + working set live at once,
    # so the per-study plan gets 1/S of the budget (default included).
    total_budget = (planner.DEFAULT_STREAM_BUDGET_BYTES
                    if memory_budget_bytes is None else memory_budget_bytes)
    per_study_budget = total_budget / s_count
    pl = planner.plan(n, n_total, n_groups, backend=backend, impl=pinned,
                      memory_budget_bytes=per_study_budget, chunk=chunk)
    fn = registry.get(pl.impl).bound(**pl.tuning)
    ch = pl.chunk
    n_chunks = -(-n_total // ch)

    def one(dm, grouping, study_key):
        mat2 = dm * dm
        inv_gs = permutations.inv_group_sizes(grouping, n_groups)

        def body(_, lo):
            g = permutations.permutation_batch_dyn(study_key, grouping,
                                                   lo, ch)
            return None, fn(mat2, g, inv_gs)

        _, sws = jax.lax.scan(body, None, jnp.arange(n_chunks) * ch)
        s_w_all = sws.reshape(-1)[:n_total]
        s_t = s_total(mat2)
        f_all = f_from_sw(s_w_all, s_t, n, n_groups)
        return f_all, s_t, s_w_all[0]

    study_keys = jax.vmap(lambda s: jax.random.fold_in(key, s))(
        jnp.arange(s_count))
    f_perms, s_t, s_w = jax.vmap(one)(dms, groupings, study_keys)
    p_vals = jax.vmap(p_value_from_null)(f_perms)
    return PermanovaManyResult(
        f_stat=f_perms[:, 0], p_value=p_vals, s_t=s_t, s_w=s_w,
        f_perms=f_perms, n_objects=n, n_groups=n_groups, n_perms=n_perms,
        plan=f"{pl.describe()} studies={s_count} chunks={n_chunks}")
