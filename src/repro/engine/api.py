"""Engine entry points: every PERMANOVA path in the repo routes through here.

run()              single-host full test; planner-driven impl selection,
                   streaming scheduler for large permutation counts.
permanova_many()   batched multi-study API: vmaps one plan over a stack of
                   distance matrices (the many-users serving scenario).

core.permanova.permanova() and core.distributed.permanova_distributed()
remain the public signatures; they are thin wrappers over this module.
"""

from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Callable, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as _obs
from repro.core import design as design_mod
from repro.core import fstat, permutations
# NOTE: `from repro.core import permanova` would resolve to the *function*
# (the package __init__ rebinds the submodule name); import symbols directly.
from repro.core.permanova import (PermanovaResult, TermResult, f_from_sw,
                                  p_value_from_null, s_total)
from repro.engine import planner, registry, scheduler

Array = jax.Array


def _sw_traffic_bytes(impl: str, n: int, n_total: int, chunk: int,
                      n_cols: int = 0) -> float:
    """Predicted stage-2 traffic for the s_W sweep, per the paper's
    dataflow distinction: 'brute' re-streams the full f32 mat2 once PER
    PERMUTATION (the GPU-style massive-bandwidth layout), everything else
    (tiled/matmul/pallas) reads mat2 once per CHUNK and amortizes it over
    the chunk's permutations. Plus the regenerated (chunk, n) int32 labels
    per chunk — (k+1)-wide on the dense-design per-column path."""
    n_chunks = -(-n_total // max(chunk, 1))
    mat2_passes = n_total if impl == "brute" else n_chunks
    label_bytes = 4 * chunk * n * (n_cols + 1)
    return float(mat2_passes) * 4.0 * n * n + float(n_chunks) * label_bytes


def _sw_span_attrs(impl: str, n: int, n_total: int, chunk: int,
                   n_cols: int = 0):
    """Span attrs for the s_W stage (None while tracing is off, so the
    disabled path allocates nothing)."""
    if not _obs.trace_enabled():
        return None
    return {"impl": impl, "chunk": chunk,
            "predicted_bytes": _sw_traffic_bytes(impl, n, n_total, chunk,
                                                 n_cols)}


def run(dm: Array, grouping: Array, *, n_perms: int = 999,
        key: Optional[jax.Array] = None, n_groups: Optional[int] = None,
        impl: str = "auto", sw_fn: Optional[Callable] = None,
        memory_budget_bytes: Optional[float] = None,
        chunk: Optional[int] = None, autotune: bool = False,
        backend: Optional[str] = None, tuning: Optional[dict] = None,
        squared: bool = False,
        covariates=None, strata=None, weights=None,
        s_t: Optional[float] = None) -> "PermanovaResult":
    """Full PERMANOVA through the hardware-aware engine.

    impl:  'auto' (planner heuristics; `autotune=True` upgrades to the
           empirical measure-and-cache pass) or any registry name.
    tuning: override the chosen impl's tuning knobs (unknown keys ignored).
    sw_fn: escape hatch — bypass the registry with a custom batch callable.
    memory_budget_bytes / chunk: bound the live label tensor; sweeps larger
           than the chunk run through the streaming scheduler.
    squared: `dm` is already the element-squared matrix mat2 = D∘D (the
           pipeline's streaming builder produces mat2 directly so the raw
           distance matrix is never resident alongside it).
    s_t:   precomputed total sum of squares (the streaming builder
           accumulates it as a Gower marginal); skips one full-matrix
           reduction when provided.

    grouping may also be a core.design.Design. Every label-array call
    site routes through Design.from_labels — a plain single-factor design
    (no strata/covariates/weights) unwraps to the exact pre-design label
    path below (same programs, same bits); anything else dispatches to
    run_design().
    """
    if key is None:
        key = jax.random.key(0)
    if covariates is not None or strata is not None or weights is not None:
        if isinstance(grouping, design_mod.Design):
            raise ValueError("pass covariates/strata/weights either to "
                             "run() or inside the Design, not both")
        design = design_mod.build(
            grouping=grouping, covariates=covariates, strata=strata,
            weights=weights, n_groups=n_groups)
    else:
        design = design_mod.Design.from_labels(grouping, n_groups=n_groups)
    if not design.is_plain_labels:
        if sw_fn is not None:
            raise ValueError("sw_fn is not supported with strata/covariate/"
                             "weighted designs; use a registry impl")
        return run_design(dm, design, n_perms=n_perms, key=key, impl=impl,
                          memory_budget_bytes=memory_budget_bytes,
                          chunk=chunk, autotune=autotune, backend=backend,
                          tuning=tuning, squared=squared, s_t=s_t)
    dm = jnp.asarray(dm)
    grouping = design.grouping
    n = dm.shape[0]
    n_groups = design.n_groups
    mat2 = dm if squared else dm * dm
    inv_gs = permutations.inv_group_sizes(grouping, n_groups)
    n_total = n_perms + 1

    if sw_fn is not None:
        fn = sw_fn
        pl = planner.plan(n, n_total, n_groups, backend=backend,
                          impl="matmul",  # footprint stand-in for budgeting
                          memory_budget_bytes=memory_budget_bytes,
                          chunk=chunk)
        pl = dataclasses.replace(pl, impl="<custom sw_fn>",
                                 reason="caller-supplied sw_fn")
    else:
        pinned = None if impl == "auto" else impl
        tuned = False
        if autotune and pinned is not None:
            warnings.warn(
                f"autotune=True ignored: impl is pinned to {impl!r} "
                "(use impl='auto' to let measurements pick)", stacklevel=2)
        if pinned is None and autotune:
            pinned = planner.autotune(mat2, grouping, inv_gs,
                                      backend=backend, key=key)
            tuned = True
        pl = planner.plan(n, n_total, n_groups, backend=backend, impl=pinned,
                          memory_budget_bytes=memory_budget_bytes,
                          chunk=chunk, tuning=tuning)
        if tuned:
            pl = dataclasses.replace(
                pl, reason="empirical autotune winner (measured on operands)")
        fn = registry.get(pl.impl).bound(**pl.tuning)

    ch = pl.chunk if pl.streaming else n_total
    with _obs.span("engine.sw", _sw_span_attrs(pl.impl, n, n_total, ch)):
        if pl.streaming:
            s_w_np, stats = scheduler.sw_streaming(
                mat2, grouping, inv_gs, key, n_total, fn, chunk=pl.chunk)
            s_w_all = jnp.asarray(s_w_np)
        else:
            s_w_all, stats = scheduler.sw_batch(
                mat2, grouping, inv_gs, key, n_total, fn)
    _obs.record_device_memory()

    s_t = s_total(mat2) if s_t is None else jnp.float32(s_t)
    f_all = f_from_sw(s_w_all, s_t, n, n_groups)
    return PermanovaResult(
        f_stat=f_all[0],
        p_value=p_value_from_null(f_all),
        s_t=s_t,
        s_w=s_w_all[0],
        f_perms=f_all,
        n_objects=n,
        n_groups=n_groups,
        n_perms=n_perms,
        method=f"permanova[{pl.impl}]",
        plan=f"{pl.describe()} chunks={stats.n_chunks}",
    )


# ---------------------------------------------------------------------------
# Design path: strata-restricted label sweeps and dense hat-matrix designs.
# ---------------------------------------------------------------------------

def design_result(s_cols, design: "design_mod.Design", *, n_objects: int,
                  n_perms: int, method: str, plan: str,
                  ordination=None) -> PermanovaResult:
    """Assemble the per-term results contract from the per-column sweep.

    s_cols: (n_total, K) per-column quadratic forms (index 0 = observed).
    Headline f_stat/p_value are the LAST term's (the covariate-adjusted
    factor of interest); every non-intercept term lands in `.terms`.
    """
    s_cols = jnp.asarray(s_cols)
    ts = design_mod.term_stats(s_cols, design)
    terms = []
    for i, t in enumerate(design.terms[1:]):
        f_p = ts.f_terms[:, i]
        terms.append(TermResult(
            name=t.name, kind=t.kind, df=t.df, ss=ts.ss_terms[0, i],
            f_stat=f_p[0], p_value=p_value_from_null(f_p),
            r2=ts.ss_terms[0, i] / ts.s_t, f_perms=f_p))
    last = terms[-1]
    return PermanovaResult(
        f_stat=last.f_stat, p_value=last.p_value, s_t=ts.s_t,
        s_w=ts.ss_resid[0], f_perms=last.f_perms, n_objects=n_objects,
        n_groups=(design.n_groups if design.n_groups is not None
                  else design.rank),
        n_perms=n_perms, method=method, plan=plan, terms=tuple(terms),
        ordination=ordination)


def label_design_result(s_w_all, s_t, design: "design_mod.Design", *,
                        n_objects: int, n_perms: int, method: str,
                        plan: str, ordination=None) -> PermanovaResult:
    """Result assembly for LABELS-mode designs (single factor + strata):
    classic F from s_W, with the factor reported as the one term."""
    n_groups = design.n_groups
    f_all = f_from_sw(s_w_all, s_t, n_objects, n_groups)
    factor = design.terms[-1]
    ss_a = s_t - s_w_all[0]
    p_val = p_value_from_null(f_all)
    terms = (TermResult(
        name=factor.name, kind=factor.kind, df=factor.df, ss=ss_a,
        f_stat=f_all[0], p_value=p_val, r2=ss_a / s_t, f_perms=f_all),)
    return PermanovaResult(
        f_stat=f_all[0], p_value=p_val, s_t=s_t, s_w=s_w_all[0],
        f_perms=f_all, n_objects=n_objects, n_groups=n_groups,
        n_perms=n_perms, method=method, plan=plan, terms=terms,
        ordination=ordination)


def design_many_result(s_cols, design: "design_mod.Design", *,
                       dof_resid, n_objects: int, n_groups: int,
                       n_perms: int, n_valid=None, ordination=None,
                       plan: str = "") -> "PermanovaManyResult":
    """Multi-study result assembly from stacked (S, n_total, K) per-column
    sweeps (shared by engine.permanova_many and pipeline_many)."""
    ts = design_mod.term_stats(s_cols, design, dof_resid=dof_resid)
    terms = []
    for i, t in enumerate(design.terms[1:]):
        f_p = ts.f_terms[:, :, i]                 # (S, n_total)
        terms.append(TermResult(
            name=t.name, kind=t.kind, df=t.df, ss=ts.ss_terms[:, 0, i],
            f_stat=f_p[:, 0], p_value=jax.vmap(p_value_from_null)(f_p),
            r2=ts.ss_terms[:, 0, i] / ts.s_t, f_perms=f_p))
    last = terms[-1]
    return PermanovaManyResult(
        f_stat=last.f_stat, p_value=last.p_value, s_t=ts.s_t,
        s_w=ts.ss_resid[:, 0], f_perms=last.f_perms, n_objects=n_objects,
        n_groups=n_groups, n_perms=n_perms, n_valid=n_valid,
        ordination=ordination, terms=tuple(terms), plan=plan)


def run_design(dm: Array, design: "design_mod.Design", *,
               n_perms: int = 999, key: Optional[jax.Array] = None,
               impl: str = "auto",
               memory_budget_bytes: Optional[float] = None,
               chunk: Optional[int] = None, autotune: bool = False,
               backend: Optional[str] = None, tuning: Optional[dict] = None,
               squared: bool = False,
               s_t: Optional[float] = None) -> "PermanovaResult":
    """Full PERMANOVA for a non-plain design (strata / covariates /
    weights / multi-factor) on a resident (squared-)distance matrix.

    Labels-mode designs (single factor + strata=) run the SAME registry
    impls as run() — the paper's brute/tiled/matmul/Pallas dataflows all
    consume strata-permuted labels unchanged. Dense designs run the
    per-column matmul contraction (hat-matrix blocks replacing the
    one-hot G), with the planner's workset model sized for K design
    columns and impl choice restricted to matmul-family companions.
    """
    if key is None:
        key = jax.random.key(0)
    dm = jnp.asarray(dm)
    n = dm.shape[0]
    if design.n != n:
        raise ValueError(f"design is for n={design.n}, matrix is {n}x{n}")
    mat2 = dm if squared else dm * dm
    n_total = n_perms + 1
    pinned = None if impl == "auto" else impl

    if design.mode == design_mod.MODE_LABELS:
        # strata-restricted single factor: every label impl applies
        grouping, n_groups = design.grouping, design.n_groups
        inv_gs = permutations.inv_group_sizes(grouping, n_groups)
        if autotune and pinned is None:
            pinned = planner.autotune(mat2, grouping, inv_gs,
                                      backend=backend, key=key)
        pl = planner.plan(n, n_total, n_groups, backend=backend,
                          impl=pinned,
                          memory_budget_bytes=memory_budget_bytes,
                          chunk=chunk, tuning=tuning)
        fn = registry.get(pl.impl).bound(**pl.tuning)
        ch = pl.chunk if pl.streaming else n_total
        with _obs.span("engine.sw",
                       _sw_span_attrs(pl.impl, n, n_total, ch)):
            if pl.streaming:
                s_w_np, stats = scheduler.sw_streaming(
                    mat2, grouping, inv_gs, key, n_total, fn, chunk=pl.chunk,
                    strata=design.strata)
                s_w_all = jnp.asarray(s_w_np)
            else:
                s_w_all, stats = scheduler.sw_batch(
                    mat2, grouping, inv_gs, key, n_total, fn,
                    strata=design.strata)
        _obs.record_device_memory()
        s_t = s_total(mat2) if s_t is None else jnp.float32(s_t)
        return label_design_result(
            s_w_all, s_t, design, n_objects=n, n_perms=n_perms,
            method=f"permanova[{pl.impl}+strata]",
            plan=f"{pl.describe()} chunks={stats.n_chunks} strata")

    # dense design: per-column contraction against the basis operand
    if autotune:
        warnings.warn(
            "autotune=True ignored for dense designs: the contraction is "
            "the per-column matmul form on every backend", stacklevel=2)
    k = design.k_cols
    pl = planner.plan(n, n_total,
                      design.n_groups if design.n_groups else k,
                      backend=backend, impl=pinned,
                      memory_budget_bytes=memory_budget_bytes,
                      chunk=chunk, tuning=tuning, n_cols=k)
    cols_fn = registry.bound_cols(pl.impl, **pl.tuning)
    strata = (design.strata if design.strata is not None
              else jnp.zeros((n,), jnp.int32))
    with _obs.span("engine.sw",
                   _sw_span_attrs(pl.impl, n, n_total, pl.chunk,
                                  n_cols=k)):
        s_cols, stats = scheduler.sw_cols_streaming(
            mat2, design.basis, strata, key, n_total, cols_fn,
            chunk=pl.chunk)
    _obs.record_device_memory()
    return design_result(
        s_cols, design, n_objects=n, n_perms=n_perms,
        method=f"permanova-design[{pl.impl}]",
        plan=f"{pl.describe()} chunks={stats.n_chunks} cols={k}")


# ---------------------------------------------------------------------------
# Batched multi-study API (serving scenario).
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PermanovaManyResult:
    """Stacked results over S studies (leading axis S on every array).

    The shared multi-study result contract for `engine.permanova_many`
    AND `pipeline.pipeline_many`: F, p, effect size R^2, and (when the
    caller asked for ordination) top-k PCoA coordinates with explained
    variance per study.
    """
    f_stat: Array        # (S,)
    p_value: Array       # (S,)
    s_t: Array           # (S,)
    s_w: Array           # (S,)
    f_perms: Array       # (S, n_perms + 1)
    n_objects: int       # common (ragged input: padded) study size n
    n_groups: int
    n_perms: int
    plan: str = ""
    n_valid: Optional[Array] = None   # (S,) per-study sample counts when
                                      # the input was a ragged list
    ordination: object = None         # pipeline.ordination.PCoAResult with
                                      # stacked (S, n, k) coords, or None
    terms: object = None              # Optional[tuple[TermResult, ...]] on
                                      # the design path — each TermResult
                                      # carries (S,)-leading arrays

    @property
    def r2(self) -> Array:
        """(S,) effect sizes R^2 = s_A / s_T = 1 - s_W / s_T."""
        return 1.0 - self.s_w / self.s_t

    def __len__(self):
        return int(self.f_stat.shape[0])

    def study(self, s: int) -> "PermanovaResult":
        """View one study as a standard PermanovaResult."""
        n_obj = (self.n_objects if self.n_valid is None
                 else int(self.n_valid[s]))
        terms_s = None
        if self.terms is not None:
            terms_s = tuple(dataclasses.replace(
                t, ss=t.ss[s], f_stat=t.f_stat[s], p_value=t.p_value[s],
                r2=t.r2[s], f_perms=t.f_perms[s]) for t in self.terms)
        return PermanovaResult(
            f_stat=self.f_stat[s], p_value=self.p_value[s], s_t=self.s_t[s],
            s_w=self.s_w[s], f_perms=self.f_perms[s],
            n_objects=n_obj, n_groups=self.n_groups,
            n_perms=self.n_perms, method="permanova_many", plan=self.plan,
            terms=terms_s,
            ordination=(None if self.ordination is None
                        else self.ordination.study(s)))


def _pad_ragged_studies(dms: Sequence, groupings: Sequence, n_groups: int,
                        n_pad: Optional[int] = None):
    """Pad a ragged study list to one (S, n_max, n_max) stack.

    Pad distance rows/cols are zero and pad labels carry the SENTINEL
    group `n_groups` — one past the one-hot width, so every s_W form
    sees them contribute exactly nothing (zero one-hot row on the matmul
    path; zero mat2 entries everywhere else).

    n_pad: optional FIXED bucket width — pad to `n_pad` rows instead of
    the batch max, so successive calls with different study mixes keep
    hitting the same compiled program (the serving bucket contract)."""
    if len(dms) != len(groupings):
        raise ValueError(f"ragged input: {len(dms)} matrices vs "
                         f"{len(groupings)} groupings")
    sizes = [int(np.asarray(d).shape[0]) for d in dms]
    n = max(sizes)
    if n_pad is not None:
        if int(n_pad) < n:
            raise ValueError(
                f"n_pad={n_pad} is smaller than the largest study (n={n})")
        n = int(n_pad)
    s_count = len(dms)
    dm_stack = np.zeros((s_count, n, n), np.float32)
    g_stack = np.full((s_count, n), n_groups, np.int32)     # sentinel pad
    for i, (d, g) in enumerate(zip(dms, groupings)):
        m = sizes[i]
        d = np.asarray(d, np.float32)
        if d.shape != (m, m):
            raise ValueError(f"study {i}: expected square matrix, "
                             f"got {d.shape}")
        dm_stack[i, :m, :m] = d
        g_stack[i, :m] = np.asarray(g, np.int32)
    return (jnp.asarray(dm_stack), jnp.asarray(g_stack),
            jnp.asarray(sizes, jnp.int32))


@functools.lru_cache(maxsize=64)
def _many_program(impl: str, tuning: tuple, ch: int, n_chunks: int,
                  n_total: int, n: int, n_groups: int, ragged: bool):
    """The jitted vmapped multi-study program, cached per static config.

    Rebuilding jax.jit(...) per call would re-trace and re-compile the
    whole chunk-scanned program on every request — fatal for the serving
    scenario this entry point exists for. The registry fn is recreated
    from (impl, tuning) so the cache key is hashable and stable."""
    fn = registry.get(impl).bound(**dict(tuning))

    def one(dm, grouping, study_key, nv):
        mat2 = dm * dm
        inv_gs = permutations.inv_group_sizes(grouping, n_groups)

        def body(_, lo):
            if ragged:   # static: one branch is ever traced
                g = permutations.masked_permutation_batch_dyn(
                    study_key, grouping, nv, lo, ch)
            else:
                g = permutations.permutation_batch_dyn(study_key, grouping,
                                                       lo, ch)
            return None, fn(mat2, g, inv_gs)

        _, sws = jax.lax.scan(body, None, jnp.arange(n_chunks) * ch)
        s_w_all = sws.reshape(-1)[:n_total]
        if ragged:
            s_t = jnp.sum(mat2) / 2.0 / nv
            f_all = f_from_sw(s_w_all, s_t, nv, n_groups)
        else:
            s_t = s_total(mat2)
            f_all = f_from_sw(s_w_all, s_t, n, n_groups)
        return f_all, s_t, s_w_all[0]

    return jax.jit(jax.vmap(one))


@functools.lru_cache(maxsize=64)
def _many_program_design(ch: int, n_chunks: int, n_total: int, n: int,
                         k: int, ragged: bool):
    """The jitted vmapped multi-study DENSE-DESIGN program.

    One program per static config (mirrors _many_program): per study, the
    chunk scan draws strata-restricted index permutations by GLOBAL
    permutation index, gathers basis rows, and runs the per-column matmul
    contraction. Ragged studies fold their pad suffix into a sentinel
    stratum (pads permute among themselves; their zero basis rows
    contribute exactly +0.0, so the observed per-term statistics
    bit-match the unpadded study)."""

    def one(dm, basis, strata, study_key, nv_i):
        mat2 = dm * dm
        if ragged:   # static: one branch is ever traced
            strata = permutations.masked_strata(strata, nv_i)

        def body(_, lo):
            perms = permutations.strata_permutation_batch_dyn(
                study_key, strata, lo, ch)
            return None, fstat.sw_cols_block(
                mat2, fstat.basis_perm_factors(basis, perms))

        _, sc = jax.lax.scan(body, None, jnp.arange(n_chunks) * ch)
        return sc.reshape(-1, k)[:n_total]

    return jax.jit(jax.vmap(one))


def _build_study_designs(groupings, covariates, strata, weights, *,
                         n_groups: int, n: int, s_count: int, sizes=None):
    """Per-study dense Designs (padded to n rows), with a shared-structure
    check: every study must compile to the same term spans (same ranks),
    or the stacked program cannot share one column layout."""
    def pick(what, x, s, m):
        if x is None:
            return None
        arr = np.asarray(x[s])
        if arr.shape[0] != m:
            raise ValueError(
                f"study {s}: {what} has {arr.shape[0]} rows, expected "
                f"{m} (per-study design columns must be UNPADDED, aligned "
                "with that study's samples)")
        return arr

    designs = []
    for s in range(s_count):
        m = n if sizes is None else int(sizes[s])
        g_s = pick("groupings", groupings, s, m)
        cov_s = pick("covariates", covariates, s, m)
        if cov_s is not None:
            cov_s = cov_s.astype(np.float64)
        st_s = pick("strata", strata, s, m)
        w_s = pick("weights", weights, s, m)
        if w_s is not None:
            w_s = w_s.astype(np.float64)
        d = design_mod.build(grouping=g_s, covariates=cov_s, strata=st_s,
                             weights=w_s, n_groups=n_groups,
                             force_dense=True)
        designs.append(design_mod.pad_design(d, n))
    spans = [tuple((t.name, t.kind, t.df, t.lo, t.hi) for t in d.terms)
             for d in designs]
    if any(sp != spans[0] for sp in spans[1:]):
        raise ValueError(
            "stacked studies compiled to different design structures "
            "(per-study term ranks differ — e.g. a covariate collinear in "
            "one study only); run such studies individually: "
            f"{sorted(set(spans))}")
    return designs


def _permanova_many_design(dms, groupings, *, covariates, strata, weights,
                           n_groups: int, n_perms: int, key,
                           impl: str, chunk, memory_budget_bytes, backend,
                           mesh, ordination,
                           n_pad=None) -> "PermanovaManyResult":
    """Multi-study dense-design path: stacked or ragged studies, one
    vmapped per-column contraction, study axis shardable over 'data'.

    Every design shape (including strata-only single factors) runs the
    ONE dense program here, so sharded == single-host stays bit-identical
    for the whole design feature set; per-study keys fold by GLOBAL study
    index before sharding, exactly like the label path."""
    ragged = isinstance(dms, (list, tuple))
    if ragged:
        sizes = [int(np.asarray(d).shape[0]) for d in dms]
        dms_pad, _, n_valid = _pad_ragged_studies(dms, groupings, n_groups,
                                                  n_pad=n_pad)
        dms = dms_pad
        s_count, n = (int(v) for v in dms.shape[:2])
    else:
        dms = jnp.asarray(dms)
        sizes = None
        n_valid = None
        s_count, n = (int(v) for v in dms.shape[:2])
    designs = _build_study_designs(
        groupings, covariates, strata, weights, n_groups=n_groups, n=n,
        s_count=s_count, sizes=sizes)
    d0 = designs[0]
    k = d0.k_cols
    n_total = n_perms + 1

    basis_stack = jnp.stack([d.basis for d in designs])
    strata_stack = jnp.stack([
        d.strata if d.strata is not None else jnp.zeros((n,), jnp.int32)
        for d in designs])

    total_budget = (planner.DEFAULT_STREAM_BUDGET_BYTES
                    if memory_budget_bytes is None else memory_budget_bytes)
    pl = planner.plan(n, n_total, n_groups, backend=backend,
                      impl=None if impl == "auto" else impl,
                      memory_budget_bytes=total_budget / s_count,
                      chunk=chunk, n_cols=k)
    ch = pl.chunk
    n_chunks = -(-n_total // ch)
    run_many = _many_program_design(ch, n_chunks, n_total, n, k, ragged)

    nv_i = (jnp.full((s_count,), n, jnp.int32) if n_valid is None
            else n_valid.astype(jnp.int32))
    study_idx = jnp.arange(s_count)
    args = (dms, basis_stack, strata_stack, nv_i)
    where = "vmap"
    data_ways, s_pad, wrap_idx = study_axis_padding(mesh, s_count)
    if wrap_idx is not None:
        args = tuple(jnp.take(a, wrap_idx, axis=0) for a in args)
        study_idx = wrap_idx
    # GLOBAL study index -> per-study key, folded ONCE before any sharding
    # (jax 0.4.x shard_map key-folding miscompile note applies here too)
    study_keys = jax.vmap(lambda s: jax.random.fold_in(key, s))(study_idx)
    args = (args[0], args[1], args[2], study_keys, args[3])
    if data_ways > 1:
        args = put_study_sharded(mesh, args)
        where = (f"vmap@data[{data_ways}]"
                 + (f"+pad{s_pad}" if s_pad else ""))

    attrs = None
    if _obs.trace_enabled():
        attrs = {"studies": s_count, "impl": pl.impl, "where": where,
                 "predicted_bytes": s_count * _sw_traffic_bytes(
                     pl.impl, n, n_total, ch, n_cols=k)}
    with _obs.span("engine.studies", attrs):
        s_cols = _obs.maybe_block(run_many(*args))[:s_count]  # (S, nt, K)
    _obs.metrics.inc("engine.studies", s_count)
    _obs.record_device_memory()

    dof_resid = ((nv_i if n_valid is None else n_valid).astype(jnp.float32)
                 - jnp.float32(d0.rank))

    ord_res = None
    if ordination is not None:
        from repro.pipeline import ordination as _ord  # deferred: cycle
        ord_res = _ord.pcoa_many(dms, int(ordination), n_valid=n_valid)

    return design_many_result(
        s_cols, d0, dof_resid=dof_resid, n_objects=n, n_groups=n_groups,
        n_perms=n_perms, n_valid=n_valid, ordination=ord_res,
        plan=(f"{pl.describe()} studies={s_count} cols={k}"
              f"{' ragged' if ragged else ''} chunks={n_chunks} "
              f"[{where}] ({d0.describe()})"))


def study_axis_padding(mesh, s_count: int):
    """(data_ways, s_pad, wrap_idx) for sharding a study axis over 'data'.

    Study counts that do not divide the axis are wrap-padded (any S
    works, even S < data_ways); callers slice results back to S. Shared
    by engine.permanova_many and pipeline_many's fused path so the two
    multi-study entry points keep one divisibility contract."""
    data_ways = int(mesh.shape.get("data", 1)) if mesh is not None else 0
    if data_ways <= 1:
        return data_ways, 0, None
    s_pad = (-s_count) % data_ways
    idx = jnp.arange(s_count + s_pad) % s_count if s_pad else None
    return data_ways, s_pad, idx


def put_study_sharded(mesh, args):
    """device_put every array with a leading-'data' NamedSharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    def spec(a):
        return NamedSharding(mesh, P(*(["data"] + [None] * (a.ndim - 1))))

    return tuple(jax.device_put(a, spec(a)) for a in args)


def permanova_many(dms: Union[Array, Sequence[Array]],
                   groupings: Union[Array, Sequence[Array]], *,
                   n_groups: int,
                   n_perms: int = 999, key: Optional[jax.Array] = None,
                   impl: str = "auto", chunk: Optional[int] = None,
                   memory_budget_bytes: Optional[float] = None,
                   backend: Optional[str] = None,
                   mesh=None,
                   covariates=None, strata=None, weights=None,
                   ordination: Optional[int] = None,
                   n_pad: Optional[int] = None) -> PermanovaManyResult:
    """PERMANOVA over a stack of studies in one planned, shardable program.

    dms:        (S, n, n) distance matrices — or a RAGGED list of
                (n_s, n_s) matrices, padded internally under one plan
                (pad rows zero, pad labels a sentinel group; per-study
                dof/s_T use the true n_s, recorded in `n_valid`).
    n_pad:      optional fixed BUCKET width for ragged input: studies are
                padded to `n_pad` rows (not the batch max), so repeated
                calls with different study mixes of the same bucket reuse
                one compiled program — the batched-serving entry point
                (`n_valid` stays a traced per-study vector, so no shape
                in the program depends on the mix). Ignored for stacked
                input, which is already uniformly shaped.
    groupings:  (S, n) int labels in [0, n_groups) (a list for ragged
                input); n_groups must be shared — it sets the one-hot
                width (the serving scenario runs many users through one
                study design).
    mesh:       optional jax.sharding.Mesh with a 'data' axis — shards
                the STUDY axis over 'data' (same convention as
                pipeline_many's fused path). Study counts that do not
                divide the axis are padded and sliced. Per-study PRNG
                keys are folded by GLOBAL study index ONCE per dispatch
                before any sharding (the jax 0.4.x shard_map key-folding
                miscompile note in streaming.fused_sw_sharded), so
                sharded == single-host == S separate run() calls,
                bit-identically, regardless of which shard runs a study.
    ordination: optional k — also compute top-k PCoA coordinates per
                study (pipeline.ordination; implicit centered operator,
                no Gower matrix materialized) into `result.ordination`.

    Stacked study s draws its null from fold_in(key, s), so results match
    S independent run(..., key=fold_in(key, s)) calls exactly. Ragged
    studies draw from the masked generator instead (deterministic and
    independent per study, observed F identical to run(); the draws are
    not the unpadded stream — see permutations.masked_permutation_batch_dyn).

    Permutations are chunk-scanned inside the jitted program, so the live
    label tensor is (S, chunk, n) — the same fixed-memory contract as the
    streaming scheduler, vectorized over studies; the engine planner
    still picks the s_W impl and chunk per backend, so each shard runs
    the hardware-aware plan.

    covariates / strata / weights: per-study design columns — stacked
    (S, n, c) / (S, n) arrays, or ragged lists aligned with `dms`. Any of
    them routes the batch through the dense-design program (per-column
    hat-matrix contraction, strata-restricted index permutations; per-
    term statistics in `result.terms`); every study must compile to the
    same design structure. Padded sentinel rows carry zero design rows,
    so observed per-term F bit-matches the unpadded study.
    """
    if key is None:
        key = jax.random.key(0)
    if covariates is not None or strata is not None or weights is not None:
        return _permanova_many_design(
            dms, groupings, covariates=covariates, strata=strata,
            weights=weights, n_groups=n_groups, n_perms=n_perms, key=key,
            impl=impl, chunk=chunk,
            memory_budget_bytes=memory_budget_bytes, backend=backend,
            mesh=mesh, ordination=ordination, n_pad=n_pad)
    ragged = isinstance(dms, (list, tuple))
    if ragged:
        dms, groupings, n_valid = _pad_ragged_studies(dms, groupings,
                                                      n_groups, n_pad=n_pad)
    else:
        dms = jnp.asarray(dms)
        groupings = jnp.asarray(groupings, dtype=jnp.int32)
        n_valid = None
    s_count, n = (int(v) for v in groupings.shape)
    n_total = n_perms + 1

    pinned = None if impl == "auto" else impl
    # vmap holds every study's (chunk, n) labels + working set live at once,
    # so the per-study plan gets 1/S of the budget (default included).
    total_budget = (planner.DEFAULT_STREAM_BUDGET_BYTES
                    if memory_budget_bytes is None else memory_budget_bytes)
    per_study_budget = total_budget / s_count
    pl = planner.plan(n, n_total, n_groups, backend=backend, impl=pinned,
                      memory_budget_bytes=per_study_budget, chunk=chunk)
    ch = pl.chunk
    n_chunks = -(-n_total // ch)
    run_many = _many_program(pl.impl, tuple(sorted(pl.tuning.items())),
                             ch, n_chunks, n_total, n, n_groups, ragged)

    nv_arg = (jnp.full((s_count,), n, jnp.float32) if n_valid is None
              else n_valid.astype(jnp.float32))
    study_idx = jnp.arange(s_count)
    args = (dms, groupings, nv_arg)
    where = "vmap"
    data_ways, s_pad, wrap_idx = study_axis_padding(mesh, s_count)
    if wrap_idx is not None:
        # pad the STUDY axis (wrapping, so any S works) by replaying
        # studies; padded results are computed and sliced off below
        args = tuple(jnp.take(a, wrap_idx, axis=0) for a in args)
        study_idx = wrap_idx
    # GLOBAL study index -> per-study key, folded ONCE here, before any
    # sharding (never inside the sharded program: jax 0.4.x miscompile);
    # a padded slot replays its source study's key, so the pad is inert.
    study_keys = jax.vmap(lambda s: jax.random.fold_in(key, s))(study_idx)
    args = (args[0], args[1], study_keys, args[2])
    if data_ways > 1:
        args = put_study_sharded(mesh, args)
        where = (f"vmap@data[{data_ways}]"
                 + (f"+pad{s_pad}" if s_pad else ""))

    attrs = None
    if _obs.trace_enabled():
        attrs = {"studies": s_count, "impl": pl.impl, "where": where,
                 "predicted_bytes": s_count * _sw_traffic_bytes(
                     pl.impl, n, n_total, ch)}
    with _obs.span("engine.studies", attrs):
        f_perms, s_t, s_w = _obs.maybe_block(run_many(*args))
    _obs.metrics.inc("engine.studies", s_count)
    _obs.record_device_memory()
    f_perms, s_t, s_w = (a[:s_count] for a in (f_perms, s_t, s_w))
    p_vals = jax.vmap(p_value_from_null)(f_perms)

    ord_res = None
    if ordination is not None:
        # computed OUTSIDE the sharded dispatch (deterministic subspace
        # iteration), so sharded and single-host embeddings are identical
        from repro.pipeline import ordination as _ord  # deferred: cycle
        ord_res = _ord.pcoa_many(dms, int(ordination), n_valid=n_valid)

    return PermanovaManyResult(
        f_stat=f_perms[:, 0], p_value=p_vals, s_t=s_t, s_w=s_w,
        f_perms=f_perms, n_objects=n, n_groups=n_groups, n_perms=n_perms,
        n_valid=n_valid, ordination=ord_res,
        plan=(f"{pl.describe()} studies={s_count}"
              f"{' ragged' if ragged else ''} chunks={n_chunks} [{where}]"))
