"""Learning-rate schedules (pure functions of the step counter)."""

from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(*, peak: float, warmup_steps: int, total_steps: int,
                  floor: float = 0.1):
    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * step / jnp.maximum(warmup_steps, 1)
        frac = (step - warmup_steps) / jnp.maximum(
            total_steps - warmup_steps, 1)
        frac = jnp.clip(frac, 0.0, 1.0)
        cos = peak * (floor + (1 - floor) * 0.5
                      * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup_steps, warm, cos)
    return schedule


def constant(value: float):
    def schedule(step):
        return jnp.asarray(value, jnp.float32)
    return schedule
