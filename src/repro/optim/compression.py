"""Gradient compression for cross-pod (DCN) data parallelism.

int8 quantization with per-tensor scale and error-feedback residual
(Seide et al. / EF-SGD): the quantization error is fed back into the next
step's gradient, preserving convergence. Intended for the pod axis, where
link bandwidth is ~10x lower than intra-pod ICI: an all-reduce of int8
gradients moves 4x fewer bytes than fp32 (2x vs bf16).

In the pjit/GSPMD path collectives are implicit, so this module exposes the
shard_map-level primitive used by runtime/elastic training drivers, plus
pure compress/decompress helpers (tested against exactness bounds).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class ErrorFeedbackState(NamedTuple):
    residual: dict    # pytree of fp32 residuals, like grads


def compress_int8(x: jax.Array):
    """(int8 values, fp32 scale). Symmetric per-tensor quantization."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_feedback(grads) -> ErrorFeedbackState:
    return ErrorFeedbackState(residual=jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads))


def error_feedback_compress(grads, state: ErrorFeedbackState):
    """Returns (quantized tree of (q, scale), new_state).

    decompress(quantized) + next-step residual == grads exactly in the
    infinite-step limit; per step the residual carries the rounding error.
    """
    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        q, scale = compress_int8(corrected)
        back = decompress_int8(q, scale)
        return (q, scale), corrected - back

    g_leaves, treedef = jax.tree.flatten(grads)
    r_leaves = treedef.flatten_up_to(state.residual)
    results = [one(g, r) for g, r in zip(g_leaves, r_leaves)]
    quantized = jax.tree.unflatten(treedef, [r[0] for r in results])
    residual = jax.tree.unflatten(treedef, [r[1] for r in results])
    return quantized, ErrorFeedbackState(residual=residual)


def allreduce_compressed(grads, state: ErrorFeedbackState, axis_name: str):
    """shard_map-level compressed all-reduce over `axis_name` (pod axis).

    Quantize -> psum int32 (exact) -> dequantize with the mean scale.
    Scales are psum-averaged; using per-shard scales with int accumulation
    keeps the sum exact in integer space.
    """
    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        q, scale = compress_int8(corrected)
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        scale_sum = jax.lax.psum(scale, axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        mean = total.astype(jnp.float32) * (scale_sum / n) / n
        back = decompress_int8(q, scale)
        return mean, corrected - back

    g_leaves, treedef = jax.tree.flatten(grads)
    r_leaves = treedef.flatten_up_to(state.residual)
    results = [one(g, r) for g, r in zip(g_leaves, r_leaves)]
    reduced = jax.tree.unflatten(treedef, [r[0] for r in results])
    residual = jax.tree.unflatten(treedef, [r[1] for r in results])
    return reduced, ErrorFeedbackState(residual=residual)
