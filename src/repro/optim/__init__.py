from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    adamw,
    adafactor,
    sgdm,
    clip_by_global_norm,
)
from repro.optim.schedules import warmup_cosine, constant  # noqa: F401
from repro.optim.compression import (  # noqa: F401
    compress_int8,
    decompress_int8,
    ErrorFeedbackState,
    error_feedback_compress,
    init_error_feedback,
    allreduce_compressed,
)
from repro.optim.optimizers import apply_updates  # noqa: F401
