"""Optimizers as (init, update) pairs over param pytrees.

AdamW for small/medium archs; Adafactor (factored second moment, optional
momentum off) for the 100B+ archs where full Adam state triples HBM
(DESIGN.md: grok-1/qwen110b/internvl76b dry-runs must fit 16 GB/chip).
Optimizer state inherits the param's sharding (same tree structure), so FSDP
sharding of params automatically ZeRO-shards the states.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable            # params -> opt_state
    update: Callable          # (grads, opt_state, params, lr) -> (updates, opt_state)
    name: str = "opt"


def clip_by_global_norm(grads, max_norm: float):
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(grads)]
    gnorm = jnp.sqrt(sum(leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gnorm


def adamw(*, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        count = state["count"] + 1
        c = count.astype(jnp.float32)

        def upd(g, mu, nu, p):
            g32 = g.astype(jnp.float32)
            mu = b1 * mu + (1 - b1) * g32
            nu = b2 * nu + (1 - b2) * jnp.square(g32)
            mu_hat = mu / (1 - b1 ** c)
            nu_hat = nu / (1 - b2 ** c)
            step = mu_hat / (jnp.sqrt(nu_hat) + eps)
            step = step + weight_decay * p.astype(jnp.float32)
            return (-lr * step).astype(p.dtype), mu, nu

        flat = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
        updates = jax.tree.map(lambda t: t[0], flat,
                               is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree.map(lambda t: t[1], flat,
                          is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree.map(lambda t: t[2], flat,
                          is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"mu": mu, "nu": nu, "count": count}

    return Optimizer(init=init, update=update, name="adamw")


def adafactor(*, eps: float = 1e-30, clip_threshold: float = 1.0,
              decay: float = 0.8, weight_decay: float = 0.0,
              momentum: Optional[float] = None) -> Optimizer:
    """Factored second-moment optimizer (Shazeer & Stern 2018, simplified).

    2D+ params keep row/col second-moment vectors (O(n+m) state instead of
    O(n*m)); 1D params keep a full vector. Optional bf16 first moment.
    """
    def init(params):
        def one(p):
            if p.ndim >= 2:
                st = {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                      "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                      jnp.float32)}
            else:
                st = {"v": jnp.zeros(p.shape, jnp.float32)}
            if momentum is not None:
                st["m"] = jnp.zeros(p.shape, jnp.bfloat16)
            return st

        return {"f": jax.tree.map(one, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        count = state["count"] + 1
        c = count.astype(jnp.float32)
        beta2 = 1.0 - c ** (-decay)

        def one(g, st, p):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if p.ndim >= 2:
                vr = beta2 * st["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * st["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                denom = jnp.mean(vr, axis=-1, keepdims=True)
                r = (vr / jnp.maximum(denom, eps))[..., None]
                u = g32 * jax.lax.rsqrt(jnp.maximum(r * vc[..., None, :],
                                                    eps))
                new = {"vr": vr, "vc": vc}
            else:
                v = beta2 * st["v"] + (1 - beta2) * g2
                u = g32 * jax.lax.rsqrt(jnp.maximum(v, eps))
                new = {"v": v}
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if momentum is not None:
                m = (momentum * st["m"].astype(jnp.float32)
                     + (1 - momentum) * u)
                new["m"] = m.astype(jnp.bfloat16)
                u = m
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype), new

        g_leaves, treedef = jax.tree.flatten(grads)
        s_leaves = treedef.flatten_up_to(state["f"])
        p_leaves = treedef.flatten_up_to(params)
        results = [one(g, s, p)
                   for g, s, p in zip(g_leaves, s_leaves, p_leaves)]
        updates = jax.tree.unflatten(treedef, [r[0] for r in results])
        new_f = jax.tree.unflatten(treedef, [r[1] for r in results])
        return updates, {"f": new_f, "count": count}

    return Optimizer(init=init, update=update, name="adafactor")


def sgdm(*, momentum: float = 0.9) -> Optimizer:
    def init(params):
        return {"m": jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params, lr):
        def one(g, m, p):
            m = momentum * m + g.astype(jnp.float32)
            return (-lr * m).astype(p.dtype), m

        flat = jax.tree.map(one, grads, state["m"], params)
        updates = jax.tree.map(lambda t: t[0], flat,
                               is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"m": m}

    return Optimizer(init=init, update=update, name="sgdm")


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)
