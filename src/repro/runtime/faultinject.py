"""Deterministic fault injection for the elastic serving stack.

Chaos testing a deterministic engine is only useful if the CHAOS itself is
deterministic: every fault here is declared up front (or drawn from a
seeded RNG) and applied against an injected clock, so a failing chaos test
replays bit-identically from its seed. The injector is consumed by
`runtime.elastic.ElasticBlockExecutor` (worker death, per-block delays,
dropped heartbeats, simulated device OOM) and by cache tests
(`corrupt_cache_file`); a "server restart" fault is driven by the tests
themselves through `serve.permanova`'s checkpoint/resume.

Faults supported:
  * kill_worker_after_blocks(w, k)  — worker w stops computing (and
    beating) after completing k blocks; the heartbeat monitor declares it
    dead and its blocks are re-dispatched.
  * delay_block(w, seconds, ...)    — advance the (virtual) clock by
    `seconds` around worker w's blocks: stragglers, deadline pressure.
  * drop_heartbeats(w, count)       — worker w computes but its next
    `count` beats are lost in transit; past the timeout it is declared
    dead even though it did the work (the zombie double-report scenario).
  * oom_at_block(w, block_id, times)— the first `times` attempts of that
    block on worker w raise SimulatedOOM (a transient failure: the retry/
    re-dispatch path must recover).
  * corrupt_cache_file(path)        — truncate a JSON cache mid-document
    (what a crash mid-write leaves behind).
"""

from __future__ import annotations

import dataclasses
import os
from typing import Dict, Optional, Tuple

import numpy as np


class SimulatedOOM(RuntimeError):
    """Injected device OOM — a TRANSIENT failure: the block (or request)
    is expected to succeed when retried/re-dispatched."""


class VirtualClock:
    """Injectable monotonic clock. `advance`/`sleep` move time forward
    explicitly; nothing moves otherwise, so tests control every timeout
    and deadline exactly."""

    def __init__(self, start: float = 0.0):
        self.t = float(start)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        if dt < 0:
            raise ValueError("clocks run forward")
        self.t += float(dt)

    def sleep(self, dt: float) -> None:   # alias: retry backoff "waits"
        self.advance(dt)


@dataclasses.dataclass
class _OOMSpec:
    remaining: int


class FaultInjector:
    """A declared, seeded fault schedule. All hooks are pure functions of
    (schedule state, arguments) — no wall clock, no global RNG."""

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)
        self._kill_after: Dict[int, int] = {}
        self._delays: Dict[Optional[int], float] = {}
        self._drop_beats: Dict[int, int] = {}
        self._ooms: Dict[Tuple[int, int], _OOMSpec] = {}
        self.log: list[str] = []

    # -- declaration ------------------------------------------------------
    def kill_worker_after_blocks(self, worker: int, k: int) -> "FaultInjector":
        self._kill_after[worker] = int(k)
        return self

    def delay_block(self, worker: Optional[int],
                    seconds: float) -> "FaultInjector":
        """Per-block virtual delay for `worker` (None = every worker's
        baseline; a per-worker entry overrides it)."""
        self._delays[worker] = float(seconds)
        return self

    def drop_heartbeats(self, worker: int, count: int) -> "FaultInjector":
        self._drop_beats[worker] = int(count)
        return self

    def oom_at_block(self, worker: int, block_id: int,
                     times: int = 1) -> "FaultInjector":
        self._ooms[(worker, block_id)] = _OOMSpec(remaining=int(times))
        return self

    # -- hooks consumed by the executor ----------------------------------
    def worker_should_die(self, worker: int, blocks_done: int) -> bool:
        k = self._kill_after.get(worker)
        if k is not None and blocks_done >= k:
            self.log.append(f"kill worker={worker} after={k}")
            del self._kill_after[worker]
            return True
        return False

    def block_delay(self, worker: int, block_id: int) -> float:
        return self._delays.get(worker, self._delays.get(None, 0.0))

    def heartbeat_dropped(self, worker: int) -> bool:
        left = self._drop_beats.get(worker, 0)
        if left > 0:
            self._drop_beats[worker] = left - 1
            self.log.append(f"drop-beat worker={worker}")
            return True
        return False

    def maybe_oom(self, worker: int, block_id: int) -> None:
        spec = self._ooms.get((worker, block_id))
        if spec is not None and spec.remaining > 0:
            spec.remaining -= 1
            self.log.append(f"oom worker={worker} block={block_id}")
            raise SimulatedOOM(
                f"injected device OOM (worker {worker}, block {block_id})")

    # -- filesystem faults -------------------------------------------------
    @staticmethod
    def corrupt_cache_file(path: str, *, keep_bytes: Optional[int] = None
                           ) -> str:
        """Truncate a JSON document mid-write (keep roughly half by
        default) — the on-disk state a crash between write() and fsync
        leaves behind. Returns the path."""
        with open(path, "rb") as f:
            data = f.read()
        cut = len(data) // 2 if keep_bytes is None else int(keep_bytes)
        with open(path, "wb") as f:
            f.write(data[:max(1, cut)])
            f.flush()
            os.fsync(f.fileno())
        return path

    def jitter(self, frac: float = 0.5) -> float:
        """Deterministic (seeded) backoff jitter factor in [1, 1+frac)."""
        return 1.0 + float(self.rng.uniform(0.0, frac))
