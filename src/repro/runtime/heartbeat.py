"""Heartbeat-based failure detection with incarnation fencing.

Each worker (host/pod) reports liveness; the monitor declares a worker dead
after `timeout` without a beat and invokes the registered callbacks (elastic
re-mesh, work re-dispatch). On a real cluster the transport is the cluster
coordinator / etcd; here it is an in-process clock so the *policy* layer
(what to do on failure) is exercised end-to-end by tests.

Incarnation semantics (the fencing-token pattern):

  * every worker carries an integer `incarnation`; beats may carry the
    incarnation the worker believes it has;
  * when the scheduler re-dispatches a dead worker's blocks it calls
    `fence(worker_id)`, bumping the incarnation — from that point a beat
    carrying the OLD incarnation is a ZOMBIE (a worker that was declared
    dead, had its work re-assigned, and came back late) and is REJECTED
    (`beat` returns False), so a zombie can never double-report blocks;
  * a genuine re-join (a beat with no incarnation claim, or with the
    current one) flips the worker back alive, bumps the incarnation, and
    fires `on_recovery` exactly once per dead->alive transition.

Beats may also ship a per-host obs.metrics snapshot; `fleet_snapshot()`
merges the latest snapshot from every worker into one coordinator view
(counters sum, gauges max) — the live-fleet-counters follow-on from the
telemetry PR.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Optional


@dataclasses.dataclass
class WorkerState:
    worker_id: int
    last_beat: float
    alive: bool = True
    incarnation: int = 0
    snapshot: Optional[dict] = None   # latest shipped metrics snapshot
    stale_beats: int = 0              # rejected zombie beats


class HeartbeatMonitor:
    def __init__(self, n_workers: int, *, timeout: float = 5.0,
                 clock: Optional[Callable[[], float]] = None):
        self.timeout = timeout
        self.clock = clock or time.monotonic
        now = self.clock()
        self.workers = {i: WorkerState(i, now) for i in range(n_workers)}
        self.on_failure: list[Callable[[int], None]] = []
        self.on_recovery: list[Callable[[int], None]] = []
        self._lock = threading.Lock()

    def beat(self, worker_id: int, incarnation: Optional[int] = None,
             snapshot: Optional[dict] = None) -> bool:
        """Record one liveness beat. Returns False for a STALE beat (the
        carried incarnation predates a `fence()`): the beat is discarded —
        last_beat is not refreshed, no recovery fires, and any work the
        zombie reports alongside it must be dropped by the caller."""
        recovered = False
        with self._lock:
            w = self.workers[worker_id]
            if incarnation is not None and incarnation < w.incarnation:
                w.stale_beats += 1
                return False
            w.last_beat = self.clock()
            if snapshot is not None:
                w.snapshot = snapshot
            if not w.alive:
                w.alive = True
                w.incarnation += 1
                recovered = True
        if recovered:
            # exactly once per dead->alive transition, OUTSIDE the lock
            # (callbacks may call back into the monitor)
            for cb in self.on_recovery:
                cb(worker_id)
        return True

    def fence(self, worker_id: int) -> int:
        """Invalidate the worker's current incarnation (call at re-dispatch
        of a dead worker's blocks). Returns the new incarnation; beats
        carrying any older one are rejected from now on."""
        with self._lock:
            w = self.workers[worker_id]
            w.incarnation += 1
            return w.incarnation

    def incarnation(self, worker_id: int) -> int:
        with self._lock:
            return self.workers[worker_id].incarnation

    def check(self) -> list[int]:
        """Returns newly-dead worker ids and fires failure callbacks."""
        now = self.clock()
        newly_dead = []
        with self._lock:
            for w in self.workers.values():
                if w.alive and now - w.last_beat > self.timeout:
                    w.alive = False
                    newly_dead.append(w.worker_id)
        for wid in newly_dead:
            for cb in self.on_failure:
                cb(wid)
        return newly_dead

    @property
    def alive_workers(self) -> list[int]:
        with self._lock:
            return [w.worker_id for w in self.workers.values() if w.alive]

    def fleet_snapshot(self) -> dict:
        """Coordinator view of the fleet: merge the latest metrics snapshot
        shipped by each worker's beats (counters sum, gauges max, histogram
        moments combine — obs.metrics.merge_snapshots semantics)."""
        from repro.obs import metrics as _metrics
        with self._lock:
            snaps = [w.snapshot for w in self.workers.values()
                     if w.snapshot is not None]
        return _metrics.merge_snapshots(snaps)
