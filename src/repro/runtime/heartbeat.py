"""Heartbeat-based failure detection.

Each worker (host/pod) reports liveness; the monitor declares a worker dead
after `timeout` without a beat and invokes the registered callbacks (elastic
re-mesh, work re-dispatch). On a real cluster the transport is the cluster
coordinator / etcd; here it is an in-process clock so the *policy* layer
(what to do on failure) is exercised end-to-end by tests.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Optional


@dataclasses.dataclass
class WorkerState:
    worker_id: int
    last_beat: float
    alive: bool = True
    incarnation: int = 0


class HeartbeatMonitor:
    def __init__(self, n_workers: int, *, timeout: float = 5.0,
                 clock: Optional[Callable[[], float]] = None):
        self.timeout = timeout
        self.clock = clock or time.monotonic
        now = self.clock()
        self.workers = {i: WorkerState(i, now) for i in range(n_workers)}
        self.on_failure: list[Callable[[int], None]] = []
        self.on_recovery: list[Callable[[int], None]] = []
        self._lock = threading.Lock()

    def beat(self, worker_id: int):
        with self._lock:
            w = self.workers[worker_id]
            w.last_beat = self.clock()
            if not w.alive:
                w.alive = True
                w.incarnation += 1
                for cb in self.on_recovery:
                    cb(worker_id)

    def check(self) -> list[int]:
        """Returns newly-dead worker ids and fires failure callbacks."""
        now = self.clock()
        newly_dead = []
        with self._lock:
            for w in self.workers.values():
                if w.alive and now - w.last_beat > self.timeout:
                    w.alive = False
                    newly_dead.append(w.worker_id)
        for wid in newly_dead:
            for cb in self.on_failure:
                cb(wid)
        return newly_dead

    @property
    def alive_workers(self) -> list[int]:
        with self._lock:
            return [w.worker_id for w in self.workers.values() if w.alive]
