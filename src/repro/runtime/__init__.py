from repro.runtime.heartbeat import HeartbeatMonitor, WorkerState  # noqa: F401
from repro.runtime.elastic import (  # noqa: F401
    AllWorkersDead,
    ElasticBlockExecutor,
    ElasticPermutationRunner,
    ExecReport,
)
from repro.runtime.faultinject import (  # noqa: F401
    FaultInjector,
    SimulatedOOM,
    VirtualClock,
)
from repro.runtime.trainer import FaultTolerantTrainer  # noqa: F401
