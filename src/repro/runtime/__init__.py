from repro.runtime.heartbeat import HeartbeatMonitor, WorkerState  # noqa: F401
from repro.runtime.elastic import ElasticPermutationRunner  # noqa: F401
from repro.runtime.trainer import FaultTolerantTrainer  # noqa: F401
