"""Fault-tolerant training driver: checkpoint/restart + deterministic data.

The loop owns: periodic async checkpoints, failure recovery (restore latest
checkpoint + rewind the data cursor), and a failure-injection hook used by
the integration tests to prove end-state equivalence: a run interrupted by a
failure at step k and restarted MUST produce the same final params as an
uninterrupted run (bitwise, because data and init are deterministic).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data.loader import ShardedLoader
from repro.train.step import TrainState


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class TrainerReport:
    steps_run: int
    restarts: int
    final_step: int
    losses: list


class FaultTolerantTrainer:
    def __init__(self, *, train_step: Callable, init_state: Callable,
                 dataset, ckpt_dir, checkpoint_every: int = 10,
                 keep: int = 3):
        self.train_step = train_step
        self.init_state = init_state
        self.dataset = dataset
        self.manager = CheckpointManager(ckpt_dir, keep=keep)
        self.checkpoint_every = checkpoint_every

    def _fresh(self, seed: int):
        state = self.init_state(jax.random.key(seed))
        loader = ShardedLoader(self.dataset)
        return state, loader

    def run(self, *, n_steps: int, seed: int = 0,
            fail_at_step: Optional[int] = None,
            max_restarts: int = 3) -> TrainerReport:
        restarts = 0
        losses = []
        state, loader = self._resume_or_fresh(seed)
        steps_run = 0
        while int(state.step) < n_steps:
            try:
                if (fail_at_step is not None
                        and int(state.step) == fail_at_step):
                    fail_at_step = None  # fail once
                    raise SimulatedFailure(
                        f"injected failure at step {int(state.step)}")
                batch = next(loader)
                state, metrics = self.train_step(state, batch)
                steps_run += 1
                losses.append(float(metrics["loss"]))
                if int(state.step) % self.checkpoint_every == 0:
                    self.manager.save(
                        state, step=int(state.step),
                        extras={"loader": loader.state(), "seed": seed})
            except SimulatedFailure:
                restarts += 1
                if restarts > max_restarts:
                    raise
                self.manager.wait()
                state, loader = self._resume_or_fresh(seed)
        self.manager.wait()
        return TrainerReport(steps_run=steps_run, restarts=restarts,
                             final_step=int(state.step), losses=losses)

    def _resume_or_fresh(self, seed: int):
        latest = self.manager.latest_step()
        if latest is None:
            return self._fresh(seed)
        template_state, loader = self._fresh(seed)
        state, manifest = self.manager.restore(template_state, step=latest)
        loader.restore(manifest["extras"]["loader"])
        return state, loader
