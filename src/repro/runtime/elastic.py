"""Elastic, fault-tolerant, straggler-mitigated permutation execution.

The PERMANOVA permutation dimension is embarrassingly parallel and
deterministic (grouping p = f(key, p) by fold_in), so the scheduling layer
can treat the job as a bag of idempotent BLOCKS of permutation indices:

  * elastic scaling   — blocks are assigned to whichever workers are alive;
                        workers joining/leaving only changes the assignment
                        map, never the results;
  * fault tolerance   — a dead worker's unfinished blocks return to the
                        queue; any worker recomputes them bit-identically;
  * straggler
    mitigation        — blocks running past `straggler_factor` x the median
                        block time are speculatively re-dispatched; first
                        completion wins (results are identical by
                        construction, so no reconciliation is needed).

This is the cross-node layer ABOVE the per-pod pjit computation: each
"worker" here stands for one pod-level shard_map job (DESIGN.md section 4).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np


@dataclasses.dataclass
class BlockResult:
    block_id: int
    lo: int
    hi: int
    values: np.ndarray
    worker_id: int
    elapsed: float
    speculative: bool = False


class ElasticPermutationRunner:
    def __init__(self, n_perms: int, *, block_size: int = 256,
                 straggler_factor: float = 3.0):
        self.n_perms = n_perms
        self.block_size = block_size
        self.straggler_factor = straggler_factor
        self.blocks = [(i, lo, min(lo + block_size, n_perms))
                       for i, lo in enumerate(range(0, n_perms, block_size))]
        self.results: dict[int, BlockResult] = {}
        self.history: list[str] = []

    def run(self, compute_block: Callable[[int, int, int], np.ndarray], *,
            workers: list[int], fail_at: Optional[dict] = None,
            slow_workers: Optional[dict] = None) -> np.ndarray:
        """Execute all blocks across `workers`.

        compute_block(worker_id, lo, hi) -> (hi-lo,) statistics.
        fail_at: {worker_id: n_blocks_before_death} for failure injection.
        slow_workers: {worker_id: slowdown_factor} for straggler injection.
        """
        fail_at = dict(fail_at or {})
        slow = dict(slow_workers or {})
        alive = list(workers)
        queue = list(self.blocks)
        done_count = {w: 0 for w in workers}
        times: list[float] = []

        while queue:
            if not alive:
                raise RuntimeError("all workers dead")
            next_queue = []
            for idx, (bid, lo, hi) in enumerate(queue):
                w = alive[idx % len(alive)]
                if w in fail_at and done_count[w] >= fail_at[w]:
                    # worker dies mid-assignment: block returns to queue
                    self.history.append(f"fail worker={w} block={bid}")
                    alive.remove(w)
                    del fail_at[w]
                    next_queue.append((bid, lo, hi))
                    continue
                t0 = time.perf_counter()
                vals = compute_block(w, lo, hi)
                elapsed = (time.perf_counter() - t0) * slow.get(w, 1.0)
                median = float(np.median(times)) if times else elapsed
                speculative = bool(
                    times and elapsed > self.straggler_factor * median)
                if speculative:
                    # re-dispatch to the fastest alive worker; identical
                    # result by determinism — first completion wins
                    w2 = min(alive, key=lambda x: slow.get(x, 1.0))
                    vals2 = compute_block(w2, lo, hi)
                    assert np.allclose(vals, vals2), \
                        "idempotence violated"
                    self.history.append(
                        f"straggler block={bid} worker={w} -> {w2}")
                    vals = vals2
                times.append(elapsed)
                done_count[w] = done_count.get(w, 0) + 1
                self.results[bid] = BlockResult(bid, lo, hi, vals, w,
                                                elapsed, speculative)
            queue = next_queue

        out = np.empty((self.n_perms,), dtype=np.float64)
        for r in self.results.values():
            out[r.lo:r.hi] = r.values
        return out
