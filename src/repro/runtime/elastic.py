"""Elastic, fault-tolerant, straggler-mitigated permutation execution.

The PERMANOVA permutation dimension is embarrassingly parallel and
deterministic (grouping p = f(key, p) by fold_in), so the scheduling layer
can treat the job as a bag of idempotent BLOCKS of permutation indices:

  * elastic scaling   — blocks are assigned to whichever workers are alive;
                        workers joining/leaving only changes the assignment
                        map, never the results;
  * fault tolerance   — a dead worker's unfinished blocks return to the
                        queue; any worker recomputes them bit-identically;
  * straggler
    mitigation        — blocks running past `straggler_factor` x the median
                        block time are speculatively re-dispatched; first
                        completion wins (results are identical by
                        construction, so no reconciliation is needed).

This is the cross-node layer ABOVE the per-pod pjit computation: each
"worker" here stands for one pod-level shard_map job (DESIGN.md section 4).

A block's values may carry trailing axes: the batched serving path runs
one bag of permutation blocks across a whole SAME-BUCKET BATCH of
studies (each block computes an (hi-lo, S) slab in one vmapped
dispatch), and every fault-tolerance mechanism — re-dispatch,
speculation, zombie fencing — applies to the slab unchanged, because
the slab is still a pure function of (keys, lo).

`ElasticBlockExecutor` is the serving-grade engine: a deterministic,
single-threaded simulation of the dispatch loop, wired to the
`runtime.heartbeat.HeartbeatMonitor` failure detector (liveness is the
monitor's verdict, not the executor's private knowledge) and to
`runtime.faultinject.FaultInjector` for seeded chaos. It supports partial
runs (deadline `should_stop`), resume from a done-mask (checkpoint/restart),
and commit-time zombie rejection through heartbeat incarnation fencing.
The original `ElasticPermutationRunner` is kept as the minimal
teaching/test harness.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Optional

import numpy as np

from repro import obs as _obs
from repro.runtime.faultinject import FaultInjector, SimulatedOOM
from repro.runtime.heartbeat import HeartbeatMonitor


class AllWorkersDead(RuntimeError):
    """Every worker died and none can rejoin — the request-level retry
    policy decides whether to restart the fleet and re-run."""


@dataclasses.dataclass
class BlockResult:
    block_id: int
    lo: int
    hi: int
    values: np.ndarray
    worker_id: int
    elapsed: float
    speculative: bool = False


@dataclasses.dataclass
class ExecReport:
    """How the bag of blocks actually ran (chaos tests assert on this)."""
    n_blocks: int
    committed: int = 0            # blocks whose results were accepted
    recomputed: int = 0           # blocks re-dispatched after a failure
    speculative: int = 0          # straggler duplicate executions
    transient_failures: int = 0   # SimulatedOOM-style retried faults
    stale_beats_rejected: int = 0  # zombie reports fenced off
    workers_died: list = dataclasses.field(default_factory=list)
    stopped: bool = False         # should_stop() ended the run early
    history: list = dataclasses.field(default_factory=list)


class ElasticBlockExecutor:
    """Run `n_blocks` idempotent blocks over simulated workers with
    heartbeat failure detection, re-dispatch, speculation, and fencing.

    The loop is synchronous and fully deterministic: time only moves
    through the injected clock (fault delays, heartbeat timeouts, retry
    backoff), and all chaos comes from the seeded `FaultInjector` — a
    failing run replays exactly.

    Worker liveness is owned by the HeartbeatMonitor: the executor only
    dispatches to monitor-alive workers, requeues on the monitor's
    failure callback, fences the dead worker's incarnation, and rejects
    any late ("zombie") completion whose beat carries a stale
    incarnation — the block is recomputed bit-identically instead, and
    the zombie's value is checked against the committed one.
    """

    def __init__(self, n_blocks: int, *, workers: int,
                 clock: Optional[Callable[[], float]] = None,
                 heartbeat_timeout: float = 5.0,
                 straggler_factor: float = 3.0,
                 injector: Optional[FaultInjector] = None,
                 monitor: Optional[HeartbeatMonitor] = None,
                 max_transient_retries: int = 8,
                 backoff_s: float = 0.05):
        self.n_blocks = int(n_blocks)
        self.workers = list(range(int(workers)))
        self.clock = clock or time.monotonic
        self.injector = injector or FaultInjector()
        self.monitor = monitor or HeartbeatMonitor(
            len(self.workers), timeout=heartbeat_timeout, clock=self.clock)
        self.heartbeat_timeout = float(self.monitor.timeout)
        self.straggler_factor = float(straggler_factor)
        self.max_transient_retries = int(max_transient_retries)
        self.backoff_s = float(backoff_s)
        self._killed: set = set()
        self._report = ExecReport(n_blocks=self.n_blocks)
        self._believed_inc = {w: self.monitor.incarnation(w)
                              for w in self.workers}
        # blocks computed but whose heartbeat report was dropped:
        # bid -> (worker, believed incarnation at compute time, values)
        self._unreported: dict = {}
        self._requeue: deque = deque()
        self.monitor.on_failure.append(self._on_worker_failure)

    # -- failure path -----------------------------------------------------
    def _on_worker_failure(self, wid: int) -> None:
        """Monitor declared `wid` dead: fence its incarnation (so any
        late report is rejected) and return its unreported blocks to the
        queue for bit-identical recomputation."""
        self.monitor.fence(wid)
        self._report.workers_died.append(wid)
        self._report.history.append(f"dead worker={wid}")
        for bid in sorted(b for b, (w, _, _) in self._unreported.items()
                          if w == wid):
            self._requeue.append(bid)
            self._report.recomputed += 1
            self._report.history.append(f"requeue block={bid} from={wid}")

    def _dispatchable(self) -> list:
        alive = set(self.monitor.alive_workers)
        return [w for w in self.workers
                if w in alive and w not in self._killed]

    def _try_rejoin(self) -> bool:
        """A partitioned (not killed) worker that was declared dead comes
        back: an un-claimed beat re-registers it under a fresh
        incarnation (recovery fires exactly once in the monitor)."""
        alive = set(self.monitor.alive_workers)
        for w in self.workers:
            if w in self._killed or w in alive:
                continue
            if self.monitor.beat(w):        # no incarnation claim: rejoin
                self._believed_inc[w] = self.monitor.incarnation(w)
                self._report.history.append(f"rejoin worker={w}")
                return True
        return False

    def _idle_beats(self) -> None:
        """Monitor-alive, non-killed workers beat once per loop turn
        (drops consumed per attempt — the partition fault)."""
        for w in self._dispatchable():
            if self.injector.heartbeat_dropped(w):
                continue
            if self.monitor.beat(w, incarnation=self._believed_inc[w]):
                self._believed_inc[w] = self.monitor.incarnation(w)

    # -- main loop --------------------------------------------------------
    def run(self, compute_block: Callable[[int, int], np.ndarray],
            block_spans: list, *,
            out: Optional[np.ndarray] = None,
            done: Optional[np.ndarray] = None,
            should_stop: Optional[Callable[[], bool]] = None,
            on_commit: Optional[Callable[[int], None]] = None):
        """Execute all not-yet-done blocks.

        compute_block(lo, hi) -> (hi-lo, ...) values — worker identity is
        deliberately NOT an argument: global-index key folding makes the
        result a pure function of the index range, which is the whole
        fault-tolerance story. Values may carry trailing axes (a block
        bag SPANNING A BATCH of same-bucket studies returns (hi-lo, S)
        slabs — one batched dispatch per block); `out` must then be
        provided with matching trailing shape. Re-dispatch, speculation,
        and zombie fencing treat the whole slab as the idempotent unit.
        block_spans: [(lo, hi)] per block id; `out` spans max hi along
        axis 0.
        done: optional (n_blocks,) bool mask — resume support; completed
        blocks are never recomputed.
        Returns (out, done, ExecReport).
        """
        spans = list(block_spans)
        if len(spans) != self.n_blocks:
            raise ValueError(f"{len(spans)} spans for {self.n_blocks} blocks")
        n_slots = max(hi for _, hi in spans) if spans else 0
        out = np.zeros((n_slots,), np.float32) if out is None else out
        if out.shape[0] < n_slots:
            raise ValueError(
                f"out axis 0 is {out.shape[0]}, spans reach {n_slots}")
        done = (np.zeros((self.n_blocks,), bool) if done is None
                else np.asarray(done, bool).copy())
        self._report = rep = ExecReport(n_blocks=self.n_blocks)
        self._unreported.clear()
        self._requeue = deque()
        pending = deque(b for b in range(self.n_blocks) if not done[b])
        times: list = []
        retries: dict = {}
        done_by = {w: 0 for w in self.workers}   # per-worker commit count
        zombie_seen: set = set()                 # count each zombie once
        rr = 0

        def commit(bid: int, w: int, vals: np.ndarray, elapsed: float,
                   speculative: bool = False) -> None:
            lo, hi = spans[bid]
            vals = np.asarray(vals, np.float32)[: hi - lo]
            if bid in self._unreported:
                # a zombie computed this block too — its (rejected) value
                # must equal the committed one: idempotence by key folding
                _, _, zvals = self._unreported.pop(bid)
                if not np.array_equal(np.asarray(zvals, np.float32)
                                      [: hi - lo], vals):
                    raise AssertionError(
                        f"block {bid}: zombie result differs from "
                        "recomputation — idempotence violated")
            out[lo:hi] = vals
            done[bid] = True
            times.append(elapsed)
            done_by[w] = done_by.get(w, 0) + 1
            rep.committed += 1
            if speculative:
                rep.speculative += 1
            if on_commit is not None:
                on_commit(bid)

        while pending or self._requeue or self._unreported:
            if should_stop is not None and should_stop():
                rep.stopped = True
                break
            # failure detection runs every turn against the injected clock
            self.monitor.check()
            self._idle_beats()
            # resolve held-back reports: a fenced worker's late report is
            # a zombie (rejected, recomputed elsewhere); a still-alive
            # worker re-sends its result with its next successful beat
            alive_now = set(self.monitor.alive_workers)
            for bid in sorted(self._unreported):
                w, inc, vals = self._unreported[bid]
                if inc < self.monitor.incarnation(w):
                    accepted = self.monitor.beat(w, incarnation=inc)
                    assert not accepted, "stale beat must be rejected"
                    if (w, bid) not in zombie_seen:
                        zombie_seen.add((w, bid))
                        rep.stale_beats_rejected += 1
                        rep.history.append(f"zombie rejected worker={w} "
                                           f"block={bid}")
                    if done[bid]:      # already recomputed elsewhere:
                        lo, hi = spans[bid]   # verify and drop
                        if not np.array_equal(
                                np.asarray(vals, np.float32)[: hi - lo],
                                out[lo:hi]):
                            raise AssertionError(
                                f"block {bid}: zombie result differs")
                        del self._unreported[bid]
                elif w in alive_now and not done[bid]:
                    # transport retry: the worker is alive and its
                    # incarnation still valid — re-report the result
                    if self.injector.heartbeat_dropped(w):
                        continue
                    if self.monitor.beat(w, incarnation=inc):
                        self._believed_inc[w] = self.monitor.incarnation(w)
                        rep.history.append(f"late report block={bid} "
                                           f"worker={w}")
                        commit(bid, w, vals, elapsed=0.0)
            queue = self._requeue if self._requeue else pending
            if not queue:
                # only unreported blocks remain: let the partition play out
                self.clock_advance(self.heartbeat_timeout + 1e-3)
                continue
            workers = self._dispatchable()
            if not workers:
                if self._try_rejoin():
                    continue
                if all(w in self._killed for w in self.workers):
                    raise AllWorkersDead(
                        f"all {len(self.workers)} workers dead with "
                        f"{len(queue)} blocks pending")
                # silent-but-alive workers exist; age the clock so the
                # monitor resolves them one way or the other
                self.clock_advance(self.heartbeat_timeout + 1e-3)
                continue
            w = workers[rr % len(workers)]
            rr += 1
            if self.injector.worker_should_die(w, done_by[w]):
                # worker dies silently: it stops beating; the block was
                # never taken, so it simply stays queued. The monitor
                # notices after `timeout` without a beat.
                self._killed.add(w)
                rep.history.append(f"kill worker={w}")
                continue
            bid = queue.popleft()
            lo, hi = spans[bid]
            t0 = self.clock()
            try:
                self.injector.maybe_oom(w, bid)
                vals = compute_block(lo, hi)
            except SimulatedOOM:
                rep.transient_failures += 1
                n_try = retries[bid] = retries.get(bid, 0) + 1
                if n_try > self.max_transient_retries:
                    raise
                # jittered backoff, then back of the queue — round-robin
                # lands the retry on a different worker
                self.clock_advance(self.backoff_s * (2 ** (n_try - 1))
                                   * self.injector.jitter())
                (self._requeue if queue is self._requeue
                 else pending).append(bid)
                rep.history.append(f"oom-requeue block={bid} worker={w}")
                continue
            self.clock_advance(self.injector.block_delay(w, bid))
            elapsed = self.clock() - t0
            # straggler speculation: past factor x median, re-dispatch to
            # the currently-fastest other worker; first completion wins
            # (they are identical by construction — asserted)
            speculative = False
            others = [o for o in self._dispatchable() if o != w]
            median = float(np.median(times)) if times else 0.0
            if (others and median > 0.0
                    and elapsed > self.straggler_factor * median):
                w2 = min(others,
                         key=lambda o: self.injector.block_delay(o, bid))
                vals2 = compute_block(lo, hi)
                self.clock_advance(self.injector.block_delay(w2, bid))
                if not np.array_equal(np.asarray(vals, np.float32),
                                      np.asarray(vals2, np.float32)):
                    raise AssertionError(
                        f"block {bid}: speculative duplicate differs — "
                        "idempotence violated")
                rep.history.append(f"straggler block={bid} "
                                   f"worker={w} -> {w2}")
                w, vals, speculative = w2, vals2, True
            # report: the beat carries the result's fencing token
            if self.injector.heartbeat_dropped(w):
                self._unreported[bid] = (w, self._believed_inc[w], vals)
                rep.history.append(f"unreported block={bid} worker={w}")
                continue
            if not self.monitor.beat(w, incarnation=self._believed_inc[w]):
                rep.stale_beats_rejected += 1   # fenced mid-flight
                rep.history.append(f"stale commit rejected worker={w} "
                                   f"block={bid}")
                if not done[bid]:
                    self._requeue.append(bid)
                continue
            self._believed_inc[w] = self.monitor.incarnation(w)
            commit(bid, w, vals, elapsed, speculative)
        _obs.metrics.inc("elastic.blocks_committed", rep.committed)
        if rep.recomputed:
            _obs.metrics.inc("elastic.blocks_recomputed", rep.recomputed)
        rep.history.extend(self.injector.log)
        return out, done, rep

    def clock_advance(self, dt: float) -> None:
        adv = getattr(self.clock, "advance", None)
        if adv is not None:
            adv(dt)
        # real clocks advance themselves; nothing to do


class ElasticPermutationRunner:
    """Minimal reference harness (predates ElasticBlockExecutor; kept for
    its tests and as the simplest statement of the idempotent-block
    idea)."""

    def __init__(self, n_perms: int, *, block_size: int = 256,
                 straggler_factor: float = 3.0):
        self.n_perms = n_perms
        self.block_size = block_size
        self.straggler_factor = straggler_factor
        self.blocks = [(i, lo, min(lo + block_size, n_perms))
                       for i, lo in enumerate(range(0, n_perms, block_size))]
        self.results: dict[int, BlockResult] = {}
        self.history: list[str] = []

    def run(self, compute_block: Callable[[int, int, int], np.ndarray], *,
            workers: list[int], fail_at: Optional[dict] = None,
            slow_workers: Optional[dict] = None) -> np.ndarray:
        """Execute all blocks across `workers`.

        compute_block(worker_id, lo, hi) -> (hi-lo,) statistics.
        fail_at: {worker_id: n_blocks_before_death} for failure injection.
        slow_workers: {worker_id: slowdown_factor} for straggler injection.
        """
        fail_at = dict(fail_at or {})
        slow = dict(slow_workers or {})
        alive = list(workers)
        queue = list(self.blocks)
        done_count = {w: 0 for w in workers}
        times: list[float] = []

        while queue:
            if not alive:
                raise RuntimeError("all workers dead")
            next_queue = []
            for idx, (bid, lo, hi) in enumerate(queue):
                w = alive[idx % len(alive)]
                if w in fail_at and done_count[w] >= fail_at[w]:
                    # worker dies mid-assignment: block returns to queue
                    self.history.append(f"fail worker={w} block={bid}")
                    alive.remove(w)
                    del fail_at[w]
                    next_queue.append((bid, lo, hi))
                    continue
                t0 = time.perf_counter()
                vals = compute_block(w, lo, hi)
                elapsed = (time.perf_counter() - t0) * slow.get(w, 1.0)
                median = float(np.median(times)) if times else elapsed
                speculative = bool(
                    times and elapsed > self.straggler_factor * median)
                if speculative:
                    # re-dispatch to the fastest alive worker; identical
                    # result by determinism — first completion wins
                    w2 = min(alive, key=lambda x: slow.get(x, 1.0))
                    vals2 = compute_block(w2, lo, hi)
                    assert np.allclose(vals, vals2), \
                        "idempotence violated"
                    self.history.append(
                        f"straggler block={bid} worker={w} -> {w2}")
                    vals = vals2
                times.append(elapsed)
                done_count[w] = done_count.get(w, 0) + 1
                self.results[bid] = BlockResult(bid, lo, hi, vals, w,
                                                elapsed, speculative)
            queue = next_queue
        out = np.empty((self.n_perms,), dtype=np.float64)
        for r in self.results.values():
            out[r.lo:r.hi] = r.values
        return out
