from repro.roofline.analysis import (  # noqa: F401
    RooflineTerms,
    analyze_compiled,
    parse_collective_bytes,
    model_flops,
)
