"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch x shape x mesh) cell — seconds per step if the chip
hit its peak on each subsystem (DESIGN.md / spec):

  compute    = HLO_FLOPs / peak_FLOP/s
  memory     = HLO_bytes / HBM_bw
  collective = collective_bytes / ICI_link_bw

With GSPMD, `compiled.cost_analysis()` describes the PER-DEVICE program, so
dividing by per-chip peaks directly yields the per-step time bound (equal to
the spec's global/(chips x peak) form). collective_bytes is NOT in
cost_analysis: we parse the optimized HLO text and sum operand bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

MODEL_FLOPS = 6*N*D (train) or 2*N*D (inference), N = active params,
D = tokens; the ratio MODEL_FLOPS / (HLO_FLOPs * chips) exposes remat /
redundant-compute waste.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

from repro import hw

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_TYPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _type_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def parse_collective_bytes(hlo_text: str) -> dict:
    """Per-collective-kind operand bytes summed over the module.

    For each instruction line mentioning a collective op, sums the byte
    sizes of type literals appearing AFTER the op name (the operand list);
    falls back to the result type when operands are printed as bare names.
    """
    out = {k: 0 for k in COLLECTIVES}
    counts = {k: 0 for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        for op in COLLECTIVES:
            # match e.g. " = bf16[..] all-gather(" / "all-reduce-start("
            idx = line.find(f" {op}")
            if idx < 0 or f" {op}" not in line:
                continue
            if f"{op}(" not in line and f"{op}-start(" not in line \
                    and f"{op}-done(" not in line:
                continue
            if f"{op}-done(" in line:
                continue  # counted at -start
            tail = line[idx:]
            operand_types = _TYPE_RE.findall(tail)
            if operand_types:
                size = sum(_type_bytes(d, s) for d, s in operand_types)
            else:
                head_types = _TYPE_RE.findall(line[:idx])
                size = sum(_type_bytes(d, s) for d, s in head_types)
            out[op] += size
            counts[op] += 1
            break
    out["_counts"] = counts
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops: float                 # per-device flops (loop-aware)
    hbm_bytes: float             # per-device HBM bytes (loop-aware)
    collective_bytes: float      # per-device collective operand bytes
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    collective_detail: dict
    model_flops_total: float = 0.0
    useful_flops_ratio: float = 0.0
    xla_flops: float = 0.0       # raw cost_analysis (loop bodies once)
    xla_bytes: float = 0.0

    def as_dict(self):
        return dataclasses.asdict(self)


def analyze_compiled(compiled, *, chips: int,
                     chip: hw.ChipSpec = hw.TARGET,
                     dtype_flops: str = "bf16",
                     model_flops_total: float = 0.0) -> RooflineTerms:
    """Authoritative source: the loop-aware HLO-text analyzer (XLA's
    cost_analysis counts while bodies once — see roofline/hlo_cost.py).
    XLA's raw numbers are retained as diagnostics."""
    from repro.roofline.hlo_cost import loop_aware_cost

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # jax<=0.4.x: one dict per device
        cost = cost[0] if cost else {}
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    la = loop_aware_cost(hlo)
    flops = float(la.flops)
    hbm_bytes = float(la.bytes)
    coll_bytes = float(la.coll_bytes)
    coll = dict(la.coll_by_kind)

    peak = (chip.peak_flops_bf16 if dtype_flops == "bf16"
            else chip.peak_flops_f32)
    compute_s = flops / peak
    memory_s = hbm_bytes / chip.hbm_bandwidth
    collective_s = coll_bytes / chip.ici_link_bandwidth
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    ratio = 0.0
    if flops > 0 and model_flops_total > 0:
        ratio = model_flops_total / (flops * chips)
    return RooflineTerms(
        flops=flops, hbm_bytes=hbm_bytes, collective_bytes=coll_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, collective_detail=coll,
        model_flops_total=model_flops_total, useful_flops_ratio=ratio,
        xla_flops=xla_flops, xla_bytes=xla_bytes)


def active_param_fraction_tree(param_axes, cfg):
    """Per-leaf activity factor: MoE expert weights count top_k/E."""
    if cfg.moe_n_experts == 0:
        return None
    frac = cfg.moe_top_k / cfg.moe_n_experts

    def one(axes):
        return frac if "expert" in axes else 1.0

    import jax
    return jax.tree.map(one, param_axes,
                        is_leaf=lambda x: isinstance(x, tuple))


def model_flops(cfg, params_abs, param_axes, *, tokens: int,
                kind: str) -> float:
    """6*N_active*D (train) / 2*N_active*D (inference)."""
    import jax
    import numpy as np
    fracs = active_param_fraction_tree(param_axes, cfg)
    total = 0.0
    leaves = jax.tree.leaves(params_abs)
    if fracs is None:
        frac_leaves = [1.0] * len(leaves)
    else:
        frac_leaves = jax.tree.leaves(fracs)
    for p, f in zip(leaves, frac_leaves):
        total += float(np.prod(p.shape)) * f
    factor = 6.0 if kind == "train" else 2.0
    return factor * total * tokens
