"""Loop-aware cost accounting over optimized HLO text.

XLA's built-in `compiled.cost_analysis()` counts each while-loop BODY ONCE
(verified: a scan of 10 matmuls reports the flops of 1). Every layer stack,
microbatch accumulation, attention chunk and CE chunk in this framework is a
lax.scan, so the built-in numbers undercount by 1-3 orders of magnitude.

This analyzer re-derives flops / HBM bytes / collective bytes from
`compiled.as_text()` with loop multipliers taken from the
`backend_config={"known_trip_count":{"n":...}}` annotation XLA attaches to
`while` instructions. Accounting model (mirrors HLO cost analysis):

  dot         flops = 2 * prod(result_dims) * prod(contracting_dims)
  elementwise flops = result elements (fusions: sum over fused body)
  bytes       operands + results of top-level instructions (fusion
              internals are register-resident); dynamic-(update-)slice
              counts the slice, not the full operand
  collectives operand bytes of all-gather / all-reduce / reduce-scatter /
              all-to-all / collective-permute (x enclosing trip counts)
  while       body cost x known_trip_count
  call/cond   recurse (conditional: max across branches)

Shapes in the per-device SPMD module are already sharded, so totals are
per-chip — exactly what the roofline terms need.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Optional

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\([^()]*\)|\S+)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_TRIP_RE = re.compile(r"\"known_trip_count\":\{\"n\":\"(\d+)\"\}")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "token": 0, "opaque": 0,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e4m3b11fnuz": 1,
    "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "s4": 1, "u4": 1, "s2": 1, "u2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "after-all", "add-dependency", "partition-id",
             "replica-id", "iota", "rng-get-and-update-state", "domain",
             "opt-barrier"}


def _shape_info(type_str: str):
    """(total_bytes, list of per-shape dims). Handles tuples."""
    total = 0
    shapes = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        ds = [int(d) for d in dims.split(",") if d] if dims else []
        n = 1
        for d in ds:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
        shapes.append(ds)
    return total, shapes


def _elems(type_str: str) -> int:
    n_total = 0
    for _, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        n_total += n
    return n_total


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        self.coll_bytes += mult * other.coll_bytes
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] += mult * v


class HloModule:
    def __init__(self, text: str):
        self.computations: dict[str, list[str]] = {}
        self.entry: Optional[str] = None
        self._parse(text)
        self._shape_of: dict[str, str] = {}   # instr name -> result type str
        self._cost_cache: dict[str, Cost] = {}

    def _parse(self, text: str):
        cur = None
        for line in text.splitlines():
            m = _COMP_RE.match(line)
            if m:
                cur = m.group(2)
                self.computations[cur] = []
                if m.group(1):
                    self.entry = cur
                continue
            if cur is not None:
                if line.strip() == "}":
                    cur = None
                    continue
                if line.strip():
                    self.computations[cur].append(line)

    # ------------------------------------------------------------------
    def _instr_cost(self, comp: str, line: str) -> Cost:
        c = Cost()
        m = _INSTR_RE.match(line)
        if not m:
            return c
        name, result_type, op, rest = m.groups()
        self._shape_of[name] = result_type
        res_bytes, res_shapes = _shape_info(result_type)

        if op in _FREE_OPS:
            return c

        # operand names (top-level %refs inside the first paren group)
        operand_names = re.findall(r"%([\w\.\-]+)", rest.split("), ")[0])

        def operand_bytes():
            tot = 0
            for on in operand_names:
                t = self._shape_of.get(on)
                if t:
                    tot += _shape_info(t)[0]
            return tot

        if op == "while":
            body = _BODY_RE.search(rest)
            trip = 1
            tm = _TRIP_RE.search(rest)
            if tm:
                trip = int(tm.group(1))
            if body:
                c.add(self.computation_cost(body.group(1)), mult=trip)
            return c

        if op == "conditional":
            bm = _BRANCHES_RE.search(rest)
            if bm:
                best = Cost()
                for b in re.findall(r"%?([\w\.\-]+)", bm.group(1)):
                    bc = self.computation_cost(b)
                    if bc.flops + bc.bytes > best.flops + best.bytes:
                        best = bc
                c.add(best)
            return c

        if op == "call":
            cm = _CALLS_RE.search(rest)
            if cm:
                c.add(self.computation_cost(cm.group(1)))
            return c

        if op == "fusion":
            cm = _CALLS_RE.search(rest)
            inner_name = cm.group(1) if cm else None
            if inner_name:
                inner = self.computation_cost(inner_name)
                c.flops += inner.flops          # fused flops count
                c.coll_bytes += inner.coll_bytes
                for k, v in inner.coll_by_kind.items():
                    c.coll_by_kind[k] += v
                # Look through fused dynamic-slice: an operand whose fused
                # parameter is consumed only via dynamic-slice contributes
                # the SLICE bytes, not the whole array (scan-over-layers
                # passes full stacked params/residuals into fusions).
                sliced = self._fused_param_slice_bytes(inner_name)
                ob = 0
                for pos, on in enumerate(operand_names):
                    t = self._shape_of.get(on)
                    if not t:
                        continue
                    full = _shape_info(t)[0]
                    ob += min(sliced.get(pos, full), full)
                c.bytes += ob
                # in-place root dynamic-update-slice: count the update,
                # not the whole aliased buffer
                dus = self._fused_root_dus_bytes(inner_name)
                c.bytes += dus if dus is not None else res_bytes
            else:
                c.bytes += operand_bytes() + res_bytes
            return c

        if op == "dot":
            lhs_t = self._shape_of.get(operand_names[0]) if operand_names \
                else None
            contract = 1
            cm = _CONTRACT_RE.search(rest)
            if cm and lhs_t:
                _, lhs_shapes = _shape_info(lhs_t)
                if lhs_shapes:
                    dims = lhs_shapes[0]
                    for idx in cm.group(1).split(","):
                        if idx and int(idx) < len(dims):
                            contract *= dims[int(idx)]
            res_elems = _elems(result_type)
            c.flops += 2.0 * res_elems * contract
            c.bytes += operand_bytes() + res_bytes
            return c

        for coll in COLLECTIVES:
            if op == coll or op == coll + "-start":
                ob = operand_bytes()
                c.coll_bytes += ob
                c.coll_by_kind[coll] += ob
                c.bytes += ob + res_bytes
                return c
        if op.endswith("-done"):
            return c

        if op in ("dynamic-slice",):
            c.bytes += 2 * res_bytes
            return c
        if op in ("dynamic-update-slice",):
            upd = 0
            if len(operand_names) >= 2:
                t = self._shape_of.get(operand_names[1])
                if t:
                    upd = _shape_info(t)[0]
            c.bytes += 2 * upd
            return c
        if op == "scatter":
            upd = 0
            if len(operand_names) >= 3:
                t = self._shape_of.get(operand_names[2])
                if t:
                    upd = _shape_info(t)[0]
            c.bytes += 2 * upd + res_bytes
            c.flops += _elems(result_type)
            return c
        if op == "gather":
            c.bytes += 2 * res_bytes
            return c
        if op == "copy":
            c.bytes += 2 * res_bytes
            return c
        if op in ("convolution",):
            # rare here; approximate as elementwise on the result
            c.flops += 2 * _elems(result_type)
            c.bytes += operand_bytes() + res_bytes
            return c

        # default: elementwise-ish (add, multiply, reduce, select, ...)
        c.flops += _elems(result_type)
        c.bytes += operand_bytes() + res_bytes
        return c

    def _fused_param_slice_bytes(self, comp: str) -> dict:
        """param position -> bytes, for fused params consumed ONLY by
        dynamic-slice / gather (count the slice, not the array)."""
        if not hasattr(self, "_slice_cache"):
            self._slice_cache = {}
        if comp in self._slice_cache:
            return self._slice_cache[comp]
        lines = self.computations.get(comp, [])
        param_pos: dict[str, int] = {}
        uses: dict[str, list] = {}
        for line in lines:
            m = _INSTR_RE.match(line)
            if not m:
                continue
            name, rtype, op, rest = m.groups()
            if op == "parameter":
                pm = re.match(r"(\d+)\)", rest)
                if pm:
                    param_pos[name] = int(pm.group(1))
                continue
            for on in re.findall(r"%([\w\.\-]+)", rest.split("), ")[0]):
                if on in param_pos:
                    uses.setdefault(on, []).append((op, rtype))
        out = {}
        for pname, ulist in uses.items():
            if ulist and all(u[0] in ("dynamic-slice", "gather")
                             for u in ulist):
                out[param_pos[pname]] = sum(
                    _shape_info(u[1])[0] for u in ulist)
        self._slice_cache[comp] = out
        return out

    def _fused_root_dus_bytes(self, comp: str):
        """Update bytes (x2) if the fused root is dynamic-update-slice."""
        for line in self.computations.get(comp, []):
            if "ROOT" not in line:
                continue
            m = _INSTR_RE.match(line)
            if not m or m.group(3) != "dynamic-update-slice":
                return None
            ops = re.findall(r"%([\w\.\-]+)", m.group(4).split("), ")[0])
            if len(ops) >= 2:
                t = self._shape_of.get(ops[1])
                if t:
                    return 2 * _shape_info(t)[0]
            return None
        return None

    def computation_cost(self, comp: str) -> Cost:
        if comp in self._cost_cache:
            return self._cost_cache[comp]
        total = Cost()
        # two passes: register result shapes first (operands may be
        # referenced before textual definition in scheduled HLO? normally
        # defs precede uses, but be safe)
        for line in self.computations.get(comp, []):
            m = _INSTR_RE.match(line)
            if m:
                self._shape_of[m.group(1)] = m.group(2)
        for line in self.computations.get(comp, []):
            total.add(self._instr_cost(comp, line))
        self._cost_cache[comp] = total
        return total

    def entry_cost(self) -> Cost:
        assert self.entry is not None, "no ENTRY computation found"
        return self.computation_cost(self.entry)


def loop_aware_cost(hlo_text: str) -> Cost:
    return HloModule(hlo_text).entry_cost()
