"""Render the EXPERIMENTS.md tables from dry-run artifacts.

  PYTHONPATH=src python -m repro.roofline.report \
      --results results/dryrun_final --write EXPERIMENTS.md
"""

from __future__ import annotations

import argparse
import json
import pathlib

ARCH_ORDER = ["internlm2-1.8b", "qwen1.5-110b", "command-r-35b", "glm4-9b",
              "whisper-base", "grok-1-314b", "qwen2-moe-a2.7b",
              "zamba2-1.2b", "xlstm-350m", "internvl2-76b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

FIX_NOTES = {
    "memory": "dominant=memory: fuse/remove fp32 softmax+norm round-trips "
              "(flash-attention Pallas kernel) or raise arithmetic "
              "intensity per HBM byte",
    "compute": "dominant=compute: near the roof — only algorithmic "
                "reductions (sparsity, distillation) move it",
    "collective": "dominant=collective: cut FSDP regather via larger "
                  "microbatches, overlap collectives with compute, or "
                  "switch the MoE to shard_map expert parallelism",
}


def render_table(headers, rows):
    """Generic column-aligned markdown table (shared with obs.report)."""
    cells = [list(map(str, headers))] + [list(map(str, r)) for r in rows]
    widths = [max(len(row[i]) for row in cells)
              for i in range(len(headers))]
    def fmt(row):
        return "| " + " | ".join(c.ljust(w)
                                 for c, w in zip(row, widths)) + " |"
    sep = "|" + "|".join("-" * (w + 2) for w in widths) + "|"
    return "\n".join([fmt(cells[0]), sep] + [fmt(r) for r in cells[1:]])


def load(results: pathlib.Path, mesh: str):
    out = {}
    for f in results.glob(f"*__{mesh}.json"):
        r = json.loads(f.read_text())
        out[(r["arch"], r["shape"])] = r
    return out


def fmt_row(r):
    if r["status"] == "skip":
        return "SKIP (quadratic attention)", ""
    if r["status"] != "ok":
        return f"ERROR {r.get('error', '')[:40]}", ""
    t = r["roofline"]
    hbm = r["per_device_hbm_bytes"] / 2 ** 30
    fits = "yes" if r["fits_hbm"] else "NO"
    row = (f"{t['compute_s']:.3f} | {t['memory_s']:.3f} | "
           f"{t['collective_s']:.3f} | **{t['dominant']}** | "
           f"{t['useful_flops_ratio']:.3f} | {hbm:.1f} | {fits}")
    return row, FIX_NOTES[t["dominant"]]


def render_roofline(records):
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant |"
        " useful | HBM GiB/dev | fits |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    notes = []
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = records.get((arch, shape))
            if r is None:
                continue
            row, note = fmt_row(r)
            if r["status"] == "ok":
                lines.append(f"| {arch} | {shape} | {row} |")
                notes.append((arch, shape, r["roofline"]["dominant"]))
            else:
                lines.append(f"| {arch} | {shape} | {row} |  |  |  |  |  |")
    lines.append("")
    lines.append("Per-cell 'what moves the dominant term' (one line each):")
    seen = set()
    for arch, shape, dom in notes:
        key = (arch, dom)
        prefix = f"* `{arch}` x `{shape}`: "
        lines.append(prefix + FIX_NOTES[dom])
    return "\n".join(lines)


def render_summary(single, multi):
    def count(recs):
        ok = sum(r["status"] == "ok" for r in recs.values())
        skip = sum(r["status"] == "skip" for r in recs.values())
        err = sum(r["status"] == "error" for r in recs.values())
        fit = sum(r.get("fits_hbm", False) for r in recs.values())
        return ok, skip, err, fit

    s = count(single)
    m = count(multi)
    return (
        f"Single-pod 16x16: {s[0]} compiled OK, {s[1]} skipped by design, "
        f"{s[2]} errors; {s[3]}/{s[0]} fit 16 GiB/chip.\n"
        f"Multi-pod 2x16x16: {m[0]} compiled OK, {m[1]} skipped, "
        f"{m[2]} errors; {m[3]}/{m[0]} fit (the 'pod' axis shards the "
        f"global batch; only gradient/statistic reductions cross pods).")


def render_multipod(records):
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant |"
        " HBM GiB/dev | fits |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = records.get((arch, shape))
            if r is None or r["status"] != "ok":
                continue
            t = r["roofline"]
            hbm = r["per_device_hbm_bytes"] / 2 ** 30
            lines.append(
                f"| {arch} | {shape} | {t['compute_s']:.3f} | "
                f"{t['memory_s']:.3f} | {t['collective_s']:.3f} | "
                f"{t['dominant']} | {hbm:.1f} | "
                f"{'yes' if r['fits_hbm'] else 'NO'} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results/dryrun_final")
    ap.add_argument("--write", default=None)
    args = ap.parse_args()
    results = pathlib.Path(args.results)
    single = load(results, "pod16x16")
    multi = load(results, "pod2x16x16")

    summary = render_summary(single, multi)
    roof = render_roofline(single)
    mp = render_multipod(multi)
    if args.write:
        p = pathlib.Path(args.write)
        text = p.read_text()
        text = text.replace("<!-- DRYRUN_SUMMARY -->", summary)
        text = text.replace("<!-- ROOFLINE_TABLE -->", roof)
        text = text.replace("<!-- MULTIPOD_TABLE -->", mp)
        p.write_text(text)
        print(f"wrote tables into {p}")
    else:
        print(summary)
        print()
        print(roof)
        print()
        print(mp)


if __name__ == "__main__":
    main()
