"""qwen2-moe-a2.7b — 60 routed experts top-4 + shared experts
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]. The shared-expert path is 4x the routed
expert width (shared_expert_intermediate_size = 4 * 1408)."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=151936,
    qkv_bias=True,
    moe_n_experts=60,
    moe_top_k=4,
    moe_n_shared=4,
    moe_d_ff=1408,
    moe_token_chunks=4,
    remat="full",
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    verified="hf",
)

SMOKE = CONFIG.replace(
    name="qwen2-moe-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
    d_ff=64, vocab=256, moe_n_experts=8, moe_top_k=2, moe_n_shared=1,
    moe_d_ff=64, dtype="float32", attn_q_chunk=16,
)
