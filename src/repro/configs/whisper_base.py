"""whisper-base — encoder-decoder audio transformer [arXiv:2212.04356;
unverified]. The conv frame frontend is a STUB per the assignment:
input_specs() provides precomputed (batch, frames, d_model) embeddings.

6L here = 6 encoder + 6 decoder layers (whisper-base layout)."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,              # decoder layers
    enc_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51865,
    norm="layernorm",
    act="gelu",
    qkv_bias=True,
    out_bias=True,
    pos="learned",
    rope_fraction=0.0,
    max_enc_len=4096,
    max_seq=40960,           # decode_32k cache + learned pos table
    source="arXiv:2212.04356",
    verified="unverified",
)

SMOKE = CONFIG.replace(
    name="whisper-smoke",
    n_layers=2, enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_head=16, d_ff=128, vocab=256, max_enc_len=32, max_seq=64,
    dtype="float32", attn_q_chunk=16,
)
