"""glm4-9b — dense GQA (kv=2) with partial RoPE [hf:THUDM/glm-4-9b; hf]."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab=151552,
    qkv_bias=True,           # GLM4 add_qkv_bias
    rope_fraction=0.5,       # GLM applies rotary to half the head dim
    rope_theta=10000.0,
    source="hf:THUDM/glm-4-9b",
    verified="hf",
)

SMOKE = CONFIG.replace(
    name="glm4-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=112, vocab=256, dtype="float32", attn_q_chunk=16,
)
