"""Config dataclasses: architectures and input shapes.

Every assigned architecture is an ArchConfig instance in configs/<id>.py with
the exact public-literature hyperparameters, plus a reduced `smoke()` variant
of the same family for CPU tests. Input-shape cells come from SHAPES below
(the assigned seq_len x global_batch grid).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | hybrid | xlstm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                  # default d_model // n_heads

    # block options
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "swiglu"              # swiglu | gelu
    qkv_bias: bool = False
    out_bias: bool = False
    pos: str = "rope"                # rope | learned | none
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"          # compute/param dtype for dry-runs

    # MoE
    moe_n_experts: int = 0
    moe_top_k: int = 0
    moe_n_shared: int = 0
    moe_d_ff: int = 0
    moe_capacity_factor: float = 1.25
    moe_scan_experts: bool = False   # scan expert dim (bounds FSDP gather)
    moe_token_chunks: int = 1        # scan dispatch over seq chunks
                                     # (bounds scatter/gather transients)

    # SSM (mamba2 / zamba2 hybrid)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_headdim: int = 64
    hybrid_shared_every: int = 6     # shared attn block period (zamba2)

    # xLSTM
    xlstm_pf: int = 2
    xlstm_conv: int = 4
    slstm_every: int = 4             # one sLSTM per this many layers

    # enc-dec (whisper)
    enc_layers: int = 0
    max_enc_len: int = 4096

    # VLM
    n_vision_tokens: int = 0

    # runtime
    max_seq: int = 8192              # learned-pos table size
    remat: str = "dots"
    attn_q_chunk: int = 1024
    ssd_chunk: int = 128
    decode_unroll: bool = False      # python-loop decode layers (no while
                                     # xs double-buffer of the KV cache)
    kv_cache_dtype: str = "auto"      # "auto" follows dtype;
                                      # "float8_e4m3fn" halves decode HBM
    grad_accum_dtype: str = "float32"  # microbatch gradient accumulator
                                       # ("bfloat16" halves it; grok-class)

    @property
    def jnp_kv_dtype(self):
        import jax.numpy as _jnp
        name = self.kv_cache_dtype
        if name == "auto":
            name = self.dtype
        return {"bfloat16": _jnp.bfloat16, "float32": _jnp.float32,
                "float8_e4m3fn": _jnp.float8_e4m3fn}[name]

    # provenance
    source: str = ""
    verified: str = "unverified"     # hf | arxiv | unverified

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    @property
    def jnp_dtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run long_500k? (SSM / hybrid / linear recurrent.)"""
        return self.family in ("hybrid", "xlstm")

    @property
    def has_decoder(self) -> bool:
        return True  # every assigned arch has an autoregressive decoder

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runs?, reason) — the long_500k / encoder-only skip rules."""
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, ("full softmax attention is quadratic; long_500k is "
                       "assigned only to SSM/hybrid/linear archs "
                       "(DESIGN.md section 6)")
    return True, ""
