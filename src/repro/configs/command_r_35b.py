"""command-r-35b — dense GQA, no biases
[hf:CohereForAI/c4ai-command-r-v01; unverified].

The HF config also uses parallel attn+FFN residual and layernorm; the
assigned spec pins only "GQA, no-bias", so we keep the shared sequential
block and note the deviation here (unverified tier)."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab=256000,
    norm="layernorm",
    rope_theta=8_000_000.0,
    source="hf:CohereForAI/c4ai-command-r-v01",
    verified="unverified",
)

SMOKE = CONFIG.replace(
    name="command-r-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=96, vocab=512, dtype="float32", attn_q_chunk=16,
)
