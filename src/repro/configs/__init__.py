from repro.configs.base import ArchConfig, ShapeConfig, SHAPES  # noqa: F401
from repro.configs.registry import (  # noqa: F401
    ARCHS,
    get_arch,
    get_smoke,
    list_archs,
)
