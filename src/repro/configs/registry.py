"""Architecture registry: --arch <id> resolution for launchers and tests."""

from __future__ import annotations

from repro.configs import (
    internlm2_1_8b,
    qwen1_5_110b,
    command_r_35b,
    glm4_9b,
    whisper_base,
    grok_1_314b,
    qwen2_moe_a2_7b,
    zamba2_1_2b,
    xlstm_350m,
    internvl2_76b,
)

_MODULES = {
    "internlm2-1.8b": internlm2_1_8b,
    "qwen1.5-110b": qwen1_5_110b,
    "command-r-35b": command_r_35b,
    "glm4-9b": glm4_9b,
    "whisper-base": whisper_base,
    "grok-1-314b": grok_1_314b,
    "qwen2-moe-a2.7b": qwen2_moe_a2_7b,
    "zamba2-1.2b": zamba2_1_2b,
    "xlstm-350m": xlstm_350m,
    "internvl2-76b": internvl2_76b,
}

ARCHS = {name: mod.CONFIG for name, mod in _MODULES.items()}
SMOKES = {name: mod.SMOKE for name, mod in _MODULES.items()}


def get_arch(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_smoke(name: str):
    return SMOKES[name]


def list_archs():
    return sorted(ARCHS)
