"""zamba2-1.2b — hybrid Mamba2 backbone + shared attention block
[arXiv:2411.15242; hf]. The shared transformer block (one set of weights)
is applied every `hybrid_shared_every` mamba layers; d_ff/heads describe
that shared block. Sub-quadratic: runs long_500k."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_headdim=64,
    hybrid_shared_every=6,
    source="arXiv:2411.15242",
    verified="hf",
)

SMOKE = CONFIG.replace(
    name="zamba2-smoke",
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
    d_ff=128, vocab=256, ssm_state=16, ssm_headdim=16,
    hybrid_shared_every=2, dtype="float32", attn_q_chunk=16, ssd_chunk=8,
)
