"""grok-1-314b — MoE, 8 experts top-2 [hf:xai-org/grok-1; unverified]."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab=131072,
    moe_n_experts=8,
    moe_top_k=2,
    moe_n_shared=0,
    moe_d_ff=32768,
    moe_scan_experts=True,   # 8 x (6144 x 32768) mats: gather one at a time
    moe_capacity_factor=1.0,
    grad_accum_dtype="bfloat16",
    moe_token_chunks=16,
    remat="full",
    kv_cache_dtype="float8_e4m3fn",
    source="hf:xai-org/grok-1",
    verified="unverified",
)

SMOKE = CONFIG.replace(
    name="grok-1-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=256, moe_n_experts=4, moe_top_k=2, moe_d_ff=128,
    dtype="float32", kv_cache_dtype="float32", grad_accum_dtype="float32",
    attn_q_chunk=16,
)
