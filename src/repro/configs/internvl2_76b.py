"""internvl2-76b — VLM: InternViT frontend (STUB) + LLaMA3-70B-class LM
backbone [arXiv:2404.16821; unverified]. input_specs() provides
precomputed patch embeddings (batch, n_vision_tokens, d_model); the LM
consumes [vision prefix | text tokens]."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    rope_theta=500_000.0,
    n_vision_tokens=256,
    remat="full",
    kv_cache_dtype="float8_e4m3fn",  # decode_32k cache fits HBM
    source="arXiv:2404.16821",
    verified="unverified",
)

SMOKE = CONFIG.replace(
    name="internvl2-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab=256, n_vision_tokens=4, dtype="float32", kv_cache_dtype="float32",
    attn_q_chunk=16,
)
