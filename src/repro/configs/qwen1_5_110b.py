"""qwen1.5-110b — dense GQA transformer with QKV bias
[hf:Qwen/Qwen1.5-110B (family: Qwen/Qwen1.5-0.5B); hf]."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=49152,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    remat="full",
    kv_cache_dtype="float8_e4m3fn",  # decode_32k cache fits HBM
    source="hf:Qwen/Qwen1.5-110B",
    verified="hf",
)

SMOKE = CONFIG.replace(
    name="qwen1.5-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=160, vocab=256, dtype="float32", kv_cache_dtype="float32", attn_q_chunk=16,
)
