"""xlstm-350m — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].
d_ff=0: xLSTM blocks carry their own projections (mLSTM pf=2 up/down;
sLSTM a 4/3 GeGLU). Sub-quadratic (recurrent): runs long_500k."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="xlstm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    xlstm_pf=2,
    xlstm_conv=4,
    slstm_every=4,
    pos="none",
    rope_fraction=0.0,
    source="arXiv:2405.04517",
    verified="unverified",
)

SMOKE = CONFIG.replace(
    name="xlstm-smoke",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
    vocab=256, slstm_every=2, dtype="float32",
)
