"""Sharded checkpointing with async write, manifest integrity, and resume.

Layout:  <dir>/step_<N>/
           manifest.json       tree structure, shapes, dtypes, step, extras
           shard_<host>.npz    this host's param/opt leaves (flattened keys)

Design points for 1000+-node deployments (DESIGN.md section 4):
  * every host writes ONLY its own leaves (here: one host = one shard file;
    on a real cluster the process index selects the addressable shards) —
    no single writer bottleneck;
  * writes go to a temp dir + atomic rename, so a failure mid-save never
    corrupts the latest checkpoint;
  * saving runs on a background thread (training overlaps the serialization
    of the PREVIOUS step's state — compute/IO overlap);
  * the manifest stores the data-loader cursor and PRNG key so restart
    resumes the exact data order (paired with the deterministic pipeline).
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
import time
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

# npz cannot round-trip ml_dtypes (bfloat16/float8): store bit-views with
# the true dtype recorded in the manifest.
_VIEW_OF = {
    "bfloat16": np.uint16,
    "float8_e4m3fn": np.uint8,
    "float8_e5m2": np.uint8,
}


def _encode(arr: np.ndarray):
    name = arr.dtype.name
    if name in _VIEW_OF:
        return arr.view(_VIEW_OF[name]), name
    return arr, name


def _decode(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _VIEW_OF:
        return arr.view(getattr(ml_dtypes, dtype_name))
    return arr


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


def save_pytree(tree, directory, *, step: int, extras: Optional[dict] = None,
                host_index: int = 0):
    """Synchronous sharded save with atomic rename."""
    directory = pathlib.Path(directory)
    final = directory / f"step_{step:08d}"
    tmp = directory / f".tmp_step_{step:08d}_{os.getpid()}"
    tmp.mkdir(parents=True, exist_ok=True)

    flat, _ = _flatten_with_paths(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    encoded = {}
    dtypes = {}
    for k, v in arrays.items():
        encoded[k], dtypes[k] = _encode(v)
    np.savez(tmp / f"shard_{host_index}.npz", **encoded)

    manifest = {
        "step": step,
        "time": time.time(),
        "n_leaves": len(arrays),
        "leaves": {k: {"shape": list(v.shape), "dtype": dtypes[k]}
                   for k, v in arrays.items()},
        "extras": extras or {},
        "format": 1,
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


def load_pytree(template, directory, *, step: Optional[int] = None,
                host_index: int = 0):
    """Restore into the structure of `template`. Returns (tree, manifest)."""
    directory = pathlib.Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    d = directory / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    data = np.load(d / f"shard_{host_index}.npz")
    flat, treedef = _flatten_with_paths(template)
    leaves = []
    for key in flat.keys():
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        dtype_name = manifest["leaves"][key]["dtype"]
        leaves.append(jax.numpy.asarray(_decode(data[key], dtype_name)))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest


def latest_step(directory) -> Optional[int]:
    directory = pathlib.Path(directory)
    if not directory.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in directory.glob("step_*")
             if p.name.split("_")[1].isdigit()]
    return max(steps) if steps else None


class CheckpointManager:
    """Async, retention-managed checkpointing."""

    def __init__(self, directory, *, keep: int = 3, host_index: int = 0):
        self.directory = pathlib.Path(directory)
        self.keep = keep
        self.host_index = host_index
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, tree, *, step: int, extras: Optional[dict] = None,
             blocking: bool = False):
        self.wait()  # one in-flight save at a time
        # device->host transfer must happen before the step mutates state
        host_tree = jax.tree.map(np.asarray, tree)

        def work():
            try:
                save_pytree(host_tree, self.directory, step=step,
                            extras=extras, host_index=self.host_index)
                self._gc()
            except BaseException as e:  # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def restore(self, template, *, step: Optional[int] = None):
        return load_pytree(template, self.directory, step=step,
                           host_index=self.host_index)

    def latest_step(self):
        return latest_step(self.directory)

    def _gc(self):
        steps = sorted(p for p in self.directory.glob("step_*"))
        for p in steps[:-self.keep]:
            shutil.rmtree(p, ignore_errors=True)
