"""Serving: jit'd decode/prefill steps + a host-side batched loop with
continuous batching (finished sequences are replaced in place, keeping the
compiled batch shape fixed — the production pattern for fixed-shape XLA).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as _obs

Array = jax.Array


def greedy_sample(logits, key):
    return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)


def temperature_sample(temperature: float = 0.8):
    def sample(logits, key):
        scaled = logits[:, -1, :] / max(temperature, 1e-4)
        return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)
    return sample


def make_serve_step(model, *, sampler: Optional[Callable] = None):
    """serve_step(params, token, caches, cache_len, key)
    -> (next_token, logits, caches). This is the function the decode-shape
    dry-run cells lower (one new token against a seq_len KV cache)."""
    sampler = sampler or greedy_sample

    def serve_step(params, token, caches, cache_len, key_bits):
        key = jax.random.wrap_key_data(key_bits)
        logits, caches = model.decode_step(params, token, caches, cache_len)
        nxt = sampler(logits, key)
        return nxt[:, None], logits, caches

    return serve_step


def make_prefill(model):
    def prefill(params, batch, max_len):
        return model.prefill(params, batch, max_len=max_len)
    return prefill


@dataclasses.dataclass
class Request:
    prompt: np.ndarray
    max_new_tokens: int = 32
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeLoop:
    """Host-side continuous-batching driver over the jit'd steps.

    Slots hold independent sequences; when one finishes, the next queued
    request takes its slot (cache column reset), so the device batch shape
    never changes and nothing recompiles.
    """

    def __init__(self, model, params, *, batch_size: int, max_len: int,
                 sampler=None, eos_id: Optional[int] = None):
        self.model = model
        self.params = params
        self.batch = batch_size
        self.max_len = max_len
        self.eos_id = eos_id
        self.step_fn = jax.jit(make_serve_step(model, sampler=sampler))
        self.caches = model.init_caches(batch=batch_size, max_len=max_len)
        self.slots: list[Optional[Request]] = [None] * batch_size
        self.slot_len = np.zeros(batch_size, np.int32)
        self.tokens = np.zeros((batch_size, 1), np.int32)

    def _admit(self, queue: list[Request]):
        for i in range(self.batch):
            if self.slots[i] is None and queue:
                req = queue.pop(0)
                self.slots[i] = req
                _obs.metrics.inc("serve.requests_admitted")
                # feed the prompt one token at a time (simple; a production
                # engine would run prefill into this slot instead)
                self.slot_len[i] = 0
                self.tokens[i, 0] = req.prompt[0]
                req._prompt_pos = 1

    def run(self, requests: list[Request], *, max_steps: int = 256,
            key=None):
        key = key if key is not None else jax.random.key(0)
        queue = list(requests)
        self._admit(queue)
        steps = 0
        while steps < max_steps and (queue or any(
                s is not None for s in self.slots)):
            with _obs.span("serve.step", {"step": steps}):
                key, sub = jax.random.split(key)
                active_len = int(self.slot_len.max()) if len(
                    self.slot_len) else 0
                nxt, logits, self.caches = self.step_fn(
                    self.params, jnp.asarray(self.tokens), self.caches,
                    jnp.asarray(active_len, jnp.int32),
                    jax.random.key_data(sub))
                # np.asarray syncs the decode step — keep it inside the span
                nxt = np.asarray(nxt)
            for i, req in enumerate(self.slots):
                if req is None:
                    continue
                self.slot_len[i] += 1
                if req._prompt_pos < len(req.prompt):
                    self.tokens[i, 0] = req.prompt[req._prompt_pos]
                    req._prompt_pos += 1
                else:
                    tok = int(nxt[i, 0])
                    req.generated.append(tok)
                    self.tokens[i, 0] = tok
                    if (len(req.generated) >= req.max_new_tokens
                            or (self.eos_id is not None
                                and tok == self.eos_id)
                            or self.slot_len[i] >= self.max_len - 1):
                        req.done = True
                        self.slots[i] = None
                        self.slot_len[i] = 0
                        _obs.metrics.inc("serve.requests_completed")
            self._admit(queue)
            steps += 1
        _obs.metrics.inc("serve.steps", steps)
        return requests
