from repro.serve.engine import (  # noqa: F401
    make_serve_step,
    make_prefill,
    ServeLoop,
    greedy_sample,
    temperature_sample,
)
