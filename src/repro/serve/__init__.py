from repro.serve.engine import (  # noqa: F401
    make_serve_step,
    make_prefill,
    ServeLoop,
    greedy_sample,
    temperature_sample,
)
from repro.serve.permanova import (  # noqa: F401
    PermanovaServer,
    RetryPolicy,
    ServeResult,
    ServerOverloaded,
    StudyRequest,
    mc_pvalue_ci,
    serve_stats_from_events,
)
