"""Always-on multi-tenant PERMANOVA serving with fault tolerance.

A persistent service admitting a stream of studies (arbitrary n, metric,
design) and returning full PERMANOVA results under production failure
modes. The design rests on one property: the permutation dimension is a
bag of idempotent BLOCKS — labels are regenerated on device from
fold_in(key, global_index), so any worker, any retry, any speculative
duplicate, and any post-restart recomputation of a block is bit-identical
by construction. Recovery is therefore exact recomputation, never
approximate reconciliation.

Layers:

  * SHAPE BUCKETS — each request is padded up to a bucket size (next
    power of two by default) and executed by a program compiled once per
    (bucket, n_groups, mode) via the masked block steps in
    engine/scheduler.py; the true sample count is a traced scalar, so a
    warm server re-traces ZERO jaxprs for any request hitting an
    existing bucket (asserted by the obs retrace counter). The planned
    impl per bucket is persisted in the autotune cache under
    `serveplan|...` keys, so plan decisions also survive restarts.
  * ELASTIC EXECUTION — blocks run through
    runtime.elastic.ElasticBlockExecutor, wired to the
    runtime.heartbeat.HeartbeatMonitor failure detector: dead workers'
    blocks are re-dispatched, stragglers are speculatively re-executed,
    zombie completions are fenced off by heartbeat incarnations. All
    chaos comes from the seeded runtime.faultinject.FaultInjector
    against an injected clock.
  * ROBUSTNESS POLICY — bounded admission queue with load shedding and a
    backpressure signal; per-request deadlines with graceful degradation
    (a reduced-n_perms result carrying a Monte-Carlo confidence interval
    for the p-value, flagged `degraded=True`); jittered-backoff retries
    for transient failures (simulated device OOM, full fleet loss);
    checkpoint/resume of partial s_W accumulators through
    checkpoint/manager.py so a restarted server finishes in-flight work
    instead of replaying it.

Determinism note: serving uses the MASKED permutation generators for
every request (pad rows stay inert), so a request's null draws are a
deterministic function of (seed, global index, bucket mask) — identical
across failure modes, fleet sizes, and restarts, but a distinct stream
from the unpadded engine.run() draws (PR 4's ragged contract).
"""

from __future__ import annotations

import dataclasses
import math
import shutil
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro import obs as _obs
from repro.checkpoint import manager as ckpt_mod
from repro.core import design as design_mod
from repro.core import distance as distance_mod
from repro.core import permutations
from repro.core.permanova import (PermanovaResult, TermResult, f_from_sw)
from repro.engine import planner, registry, scheduler
from repro.runtime.elastic import AllWorkersDead, ElasticBlockExecutor
from repro.runtime.faultinject import FaultInjector, SimulatedOOM


# ---------------------------------------------------------------------------
# Request / result contracts.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StudyRequest:
    """One tenant study. Provide a distance matrix (`dm`) or raw features
    (`x` + `metric`); `seed` fixes the permutation stream end to end."""
    grouping: np.ndarray
    dm: Optional[np.ndarray] = None
    x: Optional[np.ndarray] = None
    metric: str = "braycurtis"
    n_groups: Optional[int] = None
    n_perms: int = 999
    seed: int = 0
    strata: Optional[np.ndarray] = None
    covariates: Optional[np.ndarray] = None
    weights: Optional[np.ndarray] = None
    deadline_s: Optional[float] = None
    request_id: str = ""


@dataclasses.dataclass
class ServeResult:
    """Serving envelope around the statistical result.

    status: 'ok' | 'degraded' | 'shed' | 'failed'.
    degraded=True means the deadline cut the sweep short: `result` holds
    statistics over `n_perms_done` permutations and `p_ci` is a
    Monte-Carlo confidence interval for the p-value the full-n_perms run
    would report (the result contract's graceful-degradation flag).
    """
    request_id: str
    status: str
    result: Optional[PermanovaResult] = None
    degraded: bool = False
    n_perms_done: int = 0
    p_ci: Optional[Tuple[float, float]] = None
    error: str = ""
    retries: int = 0
    wall_s: float = 0.0
    bucket: str = ""
    report: object = None      # runtime.elastic.ExecReport of the last try

    @property
    def ok(self) -> bool:
        return self.status in ("ok", "degraded")


@dataclasses.dataclass
class RetryPolicy:
    """Jittered exponential backoff for TRANSIENT failures (simulated
    device OOM escaping block-level retry, or losing the whole fleet)."""
    max_retries: int = 3
    base_backoff_s: float = 0.05
    max_backoff_s: float = 2.0
    jitter: float = 0.5


def mc_pvalue_ci(n_ge: int, m: int, n_perms_full: int,
                 conf: float = 0.95) -> Tuple[float, float]:
    """Predictive CI for the p-value the FULL-n_perms run would report.

    A degraded response completed m of n_perms_full permutations with
    `n_ge` null exceedances. The full run's count is n_ge + B, where B is
    the hits among the permutations the deadline cut off; under a
    Jeffreys Beta(1/2, 1/2) prior on the exceedance probability, B | data
    is beta-binomial. Mapping its conf-level predictive quantiles through
    p = (n_ge + B + 1) / (n_perms_full + 1) yields an interval that
    covers the full run's actual p-value — not merely the limiting
    exceedance probability, which the full run's own Monte-Carlo noise
    can escape.
    """
    m, k, n_full = int(m), int(n_ge), int(n_perms_full)
    rest = max(n_full - m, 0)
    if rest == 0:
        p = (k + 1.0) / (n_full + 1.0)
        return (p, p)
    a, b = k + 0.5, m - k + 0.5
    alpha = 1.0 - conf
    try:
        from scipy.stats import betabinom
        b_lo = int(betabinom.ppf(alpha / 2, rest, a, b))
        b_hi = int(betabinom.ppf(1 - alpha / 2, rest, a, b))
    except Exception:       # no scipy: normal approx to the predictive
        mean = rest * a / (a + b)
        var = (rest * a * b * (a + b + rest)) / ((a + b) ** 2
                                                 * (a + b + 1.0))
        z = 1.959963984540054 if conf >= 0.95 else 1.6448536269514722
        b_lo = max(0, int(math.floor(mean - z * math.sqrt(var))))
        b_hi = min(rest, int(math.ceil(mean + z * math.sqrt(var))))
    return ((k + b_lo + 1.0) / (n_full + 1.0),
            (k + b_hi + 1.0) / (n_full + 1.0))


# ---------------------------------------------------------------------------
# Internal prepared request + shape buckets.
# ---------------------------------------------------------------------------

_MODE_LABELS = "labels"
_MODE_STRATA = "labels_strata"
_MODE_COLS = "cols"


@dataclasses.dataclass
class _Prepared:
    req: StudyRequest
    mode: str
    n: int                      # true sample count
    n_pad: int
    n_groups: int
    k_cols: int                 # 0 on label modes
    n_total: int                # n_perms + 1
    mat2: "jax.Array"           # (n_pad, n_pad) f32, pad rows zero
    grouping: "jax.Array"       # (n_pad,) i32, sentinel-padded
    strata: Optional["jax.Array"]
    basis: Optional["jax.Array"]
    inv_gs: Optional["jax.Array"]
    design: Optional[design_mod.Design]
    s_t: float
    key: "jax.Array"
    n_valid: "jax.Array"


@dataclasses.dataclass
class _Bucket:
    key: tuple
    impl: str
    tuning: dict
    fn: Callable
    hits: int = 0

    def describe(self) -> str:
        n_pad, n_groups, mode, k = self.key
        return (f"bucket(n={n_pad},g={n_groups},{mode}"
                + (f",k={k}" if k else "") + f")->{self.impl}")


def _next_bucket(n: int, sizes: Optional[List[int]]) -> int:
    if sizes:
        for s in sorted(sizes):
            if s >= n:
                return int(s)
    b = 16
    while b < n:
        b *= 2
    return b


class ServerOverloaded(RuntimeError):
    """Raised by submit(..., shed='raise') when the admission queue is
    full — the hard-backpressure signal."""


class PermanovaServer:
    """Always-on multi-tenant PERMANOVA service (see module docstring).

    workers / block: the elastic fleet size and the permutation-block
    granularity (the unit of re-dispatch, speculation, and checkpoint).
    queue_limit: bounded admission queue; submissions past it are SHED.
    clock / injector: injectable time and faults — production uses the
    real monotonic clock and no faults; chaos tests drive both.
    ckpt_dir: enables checkpoint/resume of in-flight partial s_W.
    """

    def __init__(self, *, workers: int = 4, block: int = 128,
                 queue_limit: int = 64,
                 bucket_sizes: Optional[List[int]] = None,
                 backend: Optional[str] = None,
                 heartbeat_timeout: float = 5.0,
                 straggler_factor: float = 4.0,
                 clock: Optional[Callable[[], float]] = None,
                 injector: Optional[FaultInjector] = None,
                 retry: Optional[RetryPolicy] = None,
                 max_transient_retries: int = 8,
                 ckpt_dir=None, checkpoint_every: int = 8,
                 latency_window: int = 512):
        self.workers = int(workers)
        self.block = int(block)
        self.queue_limit = int(queue_limit)
        self.bucket_sizes = bucket_sizes
        self.backend = backend or planner.default_backend()
        self.heartbeat_timeout = float(heartbeat_timeout)
        self.straggler_factor = float(straggler_factor)
        self.clock = clock or time.monotonic
        self.injector = injector
        self.retry = retry or RetryPolicy()
        self.max_transient_retries = int(max_transient_retries)
        self.ckpt_dir = ckpt_dir
        self.checkpoint_every = int(checkpoint_every)
        self._rng = np.random.default_rng(0)     # retry jitter (seeded)
        self._queue: deque = deque()
        self._buckets: Dict[tuple, _Bucket] = {}
        self._lat = deque(maxlen=int(latency_window))  # (t_end, dur_s, ok)
        self._seq = 0

    # -- admission --------------------------------------------------------
    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    @property
    def backpressure(self) -> bool:
        """Soft signal: queue at >= 80% of the admission bound — callers
        should slow down before submissions start shedding."""
        return len(self._queue) >= max(1, int(0.8 * self.queue_limit))

    def submit(self, req: StudyRequest, *, shed: str = "result"):
        """Admit one request. When the bounded queue is full the request
        is SHED: with shed='result' (default) a ServeResult(status='shed')
        is returned immediately; with shed='raise' ServerOverloaded is
        raised (hard backpressure for synchronous callers)."""
        if not req.request_id:
            req.request_id = f"req{self._seq}"
        self._seq += 1
        if len(self._queue) >= self.queue_limit:
            _obs.metrics.inc("serve.requests_shed")
            if shed == "raise":
                raise ServerOverloaded(
                    f"admission queue full ({self.queue_limit})")
            return ServeResult(request_id=req.request_id, status="shed",
                               error="admission queue full")
        self._queue.append(req)
        _obs.metrics.inc("serve.requests_admitted")
        _obs.metrics.gauge_set("serve.queue_depth", len(self._queue))
        return None

    def pump(self, max_requests: Optional[int] = None) -> List[ServeResult]:
        """Process queued requests FIFO; returns their results."""
        out = []
        while self._queue and (max_requests is None
                               or len(out) < max_requests):
            req = self._queue.popleft()
            _obs.metrics.gauge_set("serve.queue_depth", len(self._queue))
            out.append(self.process(req))
        return out

    def serve(self, reqs: List[StudyRequest]) -> List[ServeResult]:
        """Convenience: submit everything (shed results inline), pump."""
        shed = {}
        for i, r in enumerate(reqs):
            res = self.submit(r)
            if res is not None:
                shed[i] = res
        done = self.pump()
        out, it = [], iter(done)
        for i in range(len(reqs)):
            out.append(shed[i] if i in shed else next(it))
        return out

    # -- per-request processing ------------------------------------------
    def process(self, req: StudyRequest) -> ServeResult:
        t0 = self.clock()
        with _obs.span("serve.step", {"request": req.request_id}):
            res = self._process_with_retries(req, t0)
        dur = self.clock() - t0
        res.wall_s = dur
        self._lat.append((self.clock(), dur, res.ok))
        _obs.metrics.inc("serve.steps")
        if res.status in ("ok", "degraded"):
            _obs.metrics.inc("serve.requests_completed")
            if res.degraded:
                _obs.metrics.inc("serve.requests_degraded")
        elif res.status == "failed":
            _obs.metrics.inc("serve.requests_failed")
        return res

    def _process_with_retries(self, req: StudyRequest,
                              t0: float) -> ServeResult:
        policy = self.retry
        last_err = ""
        for attempt in range(policy.max_retries + 1):
            try:
                res = self._execute(req, t0)
                res.retries = attempt
                return res
            except (SimulatedOOM, AllWorkersDead) as e:
                last_err = f"{type(e).__name__}: {e}"
                _obs.metrics.inc("serve.request_retries")
                if attempt >= policy.max_retries:
                    break
                backoff = min(policy.base_backoff_s * (2 ** attempt),
                              policy.max_backoff_s)
                backoff *= 1.0 + policy.jitter * float(self._rng.uniform())
                self._sleep(backoff)
            except Exception as e:          # non-transient: fail fast
                return ServeResult(request_id=req.request_id,
                                   status="failed",
                                   error=f"{type(e).__name__}: {e}",
                                   retries=attempt)
        return ServeResult(request_id=req.request_id, status="failed",
                           error=last_err, retries=policy.max_retries)

    def _sleep(self, dt: float) -> None:
        sleep = getattr(self.clock, "sleep", None)
        (sleep or time.sleep)(dt)

    # -- preparation ------------------------------------------------------
    def _prepare(self, req: StudyRequest) -> _Prepared:
        import jax.numpy as jnp

        if (req.dm is None) == (req.x is None):
            raise ValueError("provide exactly one of dm= or x=")
        grouping = np.asarray(req.grouping, np.int32)
        n = int(grouping.shape[0])
        if req.dm is not None:
            dm = np.asarray(req.dm, np.float32)
        else:
            with _obs.span("serve.stage1", {"metric": req.metric}):
                dm = np.asarray(distance_mod.distance_matrix(
                    jnp.asarray(req.x), req.metric), np.float32)
        if dm.shape != (n, n):
            raise ValueError(f"dm is {dm.shape}, grouping has n={n}")
        n_groups = (int(req.n_groups) if req.n_groups is not None
                    else int(grouping.max()) + 1)

        dense = req.covariates is not None or req.weights is not None
        design = None
        if dense:
            design = design_mod.build(
                grouping=grouping, covariates=req.covariates,
                strata=req.strata, weights=req.weights,
                n_groups=n_groups, force_dense=True)
            mode = _MODE_COLS
        elif req.strata is not None:
            design = design_mod.build(grouping=grouping, strata=req.strata,
                                      n_groups=n_groups)
            mode = (_MODE_STRATA if design.mode == design_mod.MODE_LABELS
                    else _MODE_COLS)
            dense = mode == _MODE_COLS
        else:
            mode = _MODE_LABELS

        n_pad = _next_bucket(n, self.bucket_sizes)
        mat2 = np.zeros((n_pad, n_pad), np.float32)
        mat2[:n, :n] = dm * dm
        g_pad = np.full((n_pad,), n_groups, np.int32)    # sentinel pad
        g_pad[:n] = grouping
        strata_pad = basis = inv_gs = None
        k_cols = 0
        if dense:
            dpad = design_mod.pad_design(design, n_pad)
            basis = jnp.asarray(dpad.basis)
            k_cols = dpad.k_cols
            st = (dpad.strata if dpad.strata is not None
                  else jnp.zeros((n_pad,), jnp.int32))
            strata_pad = jnp.asarray(st, jnp.int32)
            design = dpad
        else:
            inv_gs = permutations.inv_group_sizes(jnp.asarray(g_pad),
                                                  n_groups)
            if mode == _MODE_STRATA:
                st = np.zeros((n_pad,), np.int32)
                st[:n] = np.asarray(design.strata, np.int32)[:n]
                strata_pad = jnp.asarray(st)
        s_t = float(mat2.sum()) / 2.0 / n    # pad rows are zero
        return _Prepared(
            req=req, mode=mode, n=n, n_pad=n_pad, n_groups=n_groups,
            k_cols=k_cols, n_total=int(req.n_perms) + 1,
            mat2=jnp.asarray(mat2), grouping=jnp.asarray(g_pad),
            strata=strata_pad, basis=basis, inv_gs=inv_gs, design=design,
            s_t=s_t, key=jax.random.key(int(req.seed)),
            n_valid=jnp.int32(n))

    # -- bucket / compiled-program cache ---------------------------------
    def _bucket_for(self, p: _Prepared) -> _Bucket:
        key = (p.n_pad, p.n_groups, p.mode, p.k_cols)
        b = self._buckets.get(key)
        if b is not None:
            b.hits += 1
            _obs.metrics.inc("serve.bucket_hits")
            return b
        _obs.metrics.inc("serve.bucket_misses")
        cache_key = (f"serveplan|{self.backend}|n{p.n_pad}|g{p.n_groups}"
                     f"|{p.mode}|k{p.k_cols}")
        impl = tuning = None
        entry = planner.measured_entry(cache_key)
        if entry:
            try:
                spec = registry.get(entry["impl"])
                impl = entry["impl"]
                tuning = {k: v for k, v in (entry.get("tuning") or {})
                          .items() if k in spec.tuning}
            except KeyError:
                impl = None
        if impl is None:
            pl = planner.plan(
                p.n_pad, max(p.n_total, self.block),
                p.n_groups if p.n_groups else max(p.k_cols, 2),
                backend=self.backend, chunk=self.block,
                n_cols=p.k_cols if p.mode == _MODE_COLS else None)
            impl, tuning = pl.impl, dict(pl.tuning)
            planner.record_entry(cache_key, {
                "impl": impl, "tuning": tuning, "block": self.block,
                "reason": pl.reason})
        if p.mode == _MODE_COLS:
            fn = registry.bound_cols(impl, **tuning)
        else:
            fn = registry.get(impl).bound(**tuning)
        b = _Bucket(key=key, impl=impl, tuning=tuning, fn=fn, hits=1)
        self._buckets[key] = b
        return b

    # -- execution --------------------------------------------------------
    def _spans(self, p: _Prepared) -> List[Tuple[int, int]]:
        block = min(self.block, p.n_total)
        return [(lo, min(lo + block, p.n_total))
                for lo in range(0, p.n_total, block)]

    def _compute_block_fn(self, p: _Prepared, b: _Bucket):
        block = min(self.block, p.n_total)
        if p.mode == _MODE_COLS:
            def compute(lo, hi):
                with _obs.span("serve.block", {"lo": lo}):
                    s = scheduler.sw_cols_block(
                        p.mat2, p.basis, p.strata, p.n_valid, p.key, lo,
                        fn=b.fn, block=block)
                    return np.asarray(s)[: hi - lo]
        else:
            def compute(lo, hi):
                with _obs.span("serve.block", {"lo": lo}):
                    s = scheduler.sw_block(
                        p.mat2, p.grouping, p.n_valid, p.inv_gs, p.key, lo,
                        fn=b.fn, block=block, strata=p.strata)
                    return np.asarray(s)[: hi - lo]
        return compute

    def _ckpt_mgr(self, req: StudyRequest):
        if self.ckpt_dir is None:
            return None
        import pathlib
        return ckpt_mod.CheckpointManager(
            pathlib.Path(self.ckpt_dir) / req.request_id, keep=2)

    def _execute(self, req: StudyRequest, t0: float) -> ServeResult:
        p = self._prepare(req)
        b = self._bucket_for(p)
        spans = self._spans(p)
        n_blocks = len(spans)
        out = np.zeros((p.n_total, p.k_cols), np.float32) \
            if p.mode == _MODE_COLS else np.zeros((p.n_total,), np.float32)
        done = np.zeros((n_blocks,), bool)

        mgr = self._ckpt_mgr(req)
        if mgr is not None:
            done, out = self._maybe_resume(mgr, req, done, out, n_blocks)

        deadline = req.deadline_s

        def should_stop() -> bool:
            return (deadline is not None
                    and self.clock() - t0 >= deadline)

        commits_since_ckpt = [0]

        def on_commit(bid: int) -> None:
            # Mirror the commit into the caller-side mask: the executor
            # runs on its own copy of `done` (resume isolation), but it
            # writes `out` in place, so out[spans[bid]] is current here.
            done[bid] = True
            commits_since_ckpt[0] += 1
            if (mgr is not None
                    and commits_since_ckpt[0] % self.checkpoint_every == 0):
                self._checkpoint(mgr, req, out, done)

        exe = ElasticBlockExecutor(
            n_blocks, workers=self.workers, clock=self.clock,
            heartbeat_timeout=self.heartbeat_timeout,
            straggler_factor=self.straggler_factor,
            injector=self.injector or FaultInjector(),
            max_transient_retries=self.max_transient_retries)
        out, done, rep = exe.run(self._compute_block_fn(p, b), spans,
                                 out=out, done=done,
                                 should_stop=should_stop,
                                 on_commit=on_commit)
        if rep.stale_beats_rejected:
            _obs.metrics.inc("serve.zombies_fenced",
                             rep.stale_beats_rejected)
        if not done.all():
            if mgr is not None:
                self._checkpoint(mgr, req, out, done)
            if not done[0]:
                return ServeResult(
                    request_id=req.request_id, status="failed",
                    error="deadline expired before the observed statistic",
                    bucket=b.describe(), report=rep)
            return self._assemble(p, b, out, done, spans, rep,
                                  degraded=True)
        if mgr is not None:
            shutil.rmtree(mgr.directory, ignore_errors=True)   # finished
        return self._assemble(p, b, out, done, spans, rep, degraded=False)

    # -- checkpoint/resume ------------------------------------------------
    def _checkpoint(self, mgr, req: StudyRequest, out: np.ndarray,
                    done: np.ndarray) -> None:
        step = int(done.sum())
        mgr.save({"s_w": out, "done": done.astype(np.uint8)}, step=step,
                 extras={"request_id": req.request_id,
                         "n_perms": int(req.n_perms),
                         "block": self.block, "seed": int(req.seed)},
                 blocking=True)
        _obs.metrics.inc("serve.checkpoints")

    def _maybe_resume(self, mgr, req: StudyRequest, done, out, n_blocks):
        step = mgr.latest_step()
        if step is None:
            return done, out
        try:
            tree, manifest = mgr.restore(
                {"s_w": out, "done": done.astype(np.uint8)})
        except Exception:
            return done, out      # unreadable partial state: recompute
        ex = manifest.get("extras", {})
        if (ex.get("block") != self.block
                or ex.get("n_perms") != int(req.n_perms)
                or ex.get("seed") != int(req.seed)):
            return done, out      # different request config: ignore
        done_l = np.asarray(tree["done"], bool)
        out_l = np.asarray(tree["s_w"], out.dtype)
        if done_l.shape != (n_blocks,) or out_l.shape != out.shape:
            return done, out
        _obs.metrics.inc("serve.resumed_requests")
        _obs.metrics.inc("serve.resumed_blocks", float(done_l.sum()))
        return done_l.copy(), out_l.copy()

    # -- result assembly --------------------------------------------------
    def _assemble(self, p: _Prepared, b: _Bucket, out, done, spans, rep,
                  *, degraded: bool) -> ServeResult:
        idx = np.concatenate([np.arange(lo, hi)
                              for bid, (lo, hi) in enumerate(spans)
                              if done[bid]]) if not done.all() \
            else np.arange(p.n_total)
        m = int(idx.size) - 1                   # completed permutations
        sub = out[idx]
        method_suffix = "+degraded" if degraded else ""
        plan_str = (f"{b.describe()} block={self.block} "
                    f"blocks={len(spans)} workers={self.workers}")
        if p.mode == _MODE_COLS:
            result = self._design_result(p, sub, m, method_suffix, plan_str)
            f_sub = np.asarray(result.f_perms, np.float64)
        else:
            s_w = np.asarray(sub, np.float64)
            f_sub = np.asarray(f_from_sw(
                s_w, p.s_t, p.n, p.n_groups), np.float64)
            n_ge = int(np.sum(f_sub[1:] >= f_sub[0]))
            p_val = (n_ge + 1.0) / (m + 1.0)
            result = PermanovaResult(
                f_stat=f_sub[0], p_value=p_val, s_t=p.s_t, s_w=s_w[0],
                f_perms=f_sub, n_objects=p.n, n_groups=p.n_groups,
                n_perms=m,
                method=f"permanova-serve[{b.impl}]{method_suffix}",
                plan=plan_str)
        ci = None
        if degraded:
            n_ge = int(np.sum(f_sub[1:] >= f_sub[0]))
            ci = mc_pvalue_ci(n_ge, m, int(p.req.n_perms))
        return ServeResult(
            request_id=p.req.request_id,
            status="degraded" if degraded else "ok",
            result=result, degraded=degraded, n_perms_done=m,
            p_ci=ci, bucket=b.describe(), report=rep)

    def _design_result(self, p: _Prepared, s_cols, m: int,
                       method_suffix: str, plan_str: str) -> PermanovaResult:
        design = p.design
        dof_resid = float(p.n - design.rank)
        ts = design_mod.term_stats(s_cols, design, dof_resid=dof_resid)
        terms = []
        f_terms = np.asarray(ts.f_terms, np.float64)
        ss_terms = np.asarray(ts.ss_terms, np.float64)
        s_t = float(np.asarray(ts.s_t))
        for i, t in enumerate(design.terms[1:]):
            f_p = f_terms[:, i]
            n_ge = int(np.sum(f_p[1:] >= f_p[0]))
            terms.append(TermResult(
                name=t.name, kind=t.kind, df=t.df, ss=ss_terms[0, i],
                f_stat=f_p[0], p_value=(n_ge + 1.0) / (m + 1.0),
                r2=ss_terms[0, i] / s_t, f_perms=f_p))
        last = terms[-1]
        return PermanovaResult(
            f_stat=last.f_stat, p_value=last.p_value, s_t=s_t,
            s_w=float(np.asarray(ts.ss_resid)[0]), f_perms=last.f_perms,
            n_objects=p.n,
            n_groups=(design.n_groups if design.n_groups else design.rank),
            n_perms=m,
            method=f"permanova-serve-design[{p.mode}]{method_suffix}",
            plan=plan_str, terms=tuple(terms))

    # -- telemetry --------------------------------------------------------
    def stats(self) -> dict:
        """Rolling serving stats from the internal latency ring: requests
        per second over the window, p50/p99 step latency, queue depth,
        bucket inventory. (serve_stats_from_events computes the same view
        from exported `serve.step` trace spans.)"""
        if not self._lat:
            return {"requests": 0, "requests_per_s": 0.0,
                    "p50_s": 0.0, "p99_s": 0.0,
                    "queue_depth": len(self._queue),
                    "buckets": len(self._buckets)}
        ts = [t for t, _, _ in self._lat]
        durs = sorted(d for _, d, _ in self._lat)
        span_s = max(ts) - min(ts) + durs[-1]
        n = len(durs)
        return {
            "requests": n,
            "requests_per_s": n / span_s if span_s > 0 else float("inf"),
            "p50_s": durs[int(0.50 * (n - 1))],
            "p99_s": durs[int(0.99 * (n - 1))],
            "queue_depth": len(self._queue),
            "buckets": len(self._buckets),
        }


def serve_stats_from_events(events: Optional[list] = None) -> dict:
    """Requests/sec and p50/p99 step latency from `serve.step` trace
    spans (the ROADMAP observability follow-on): pass a trace_event list
    or default to the live obs buffer."""
    evs = _obs.events() if events is None else events
    steps = [e for e in evs
             if e.get("name") == "serve.step" and e.get("ph") == "X"]
    if not steps:
        return {"requests": 0, "requests_per_s": 0.0, "p50_s": 0.0,
                "p99_s": 0.0}
    durs = sorted(e["dur"] / 1e6 for e in steps)
    t_lo = min(e["ts"] for e in steps) / 1e6
    t_hi = max((e["ts"] + e["dur"]) for e in steps) / 1e6
    n = len(durs)
    span_s = max(t_hi - t_lo, 1e-9)
    return {"requests": n, "requests_per_s": n / span_s,
            "p50_s": durs[int(0.50 * (n - 1))],
            "p99_s": durs[int(0.99 * (n - 1))]}
