"""Always-on multi-tenant PERMANOVA serving with fault tolerance.

A persistent service admitting a stream of studies (arbitrary n, metric,
design) and returning full PERMANOVA results under production failure
modes. The design rests on one property: the permutation dimension is a
bag of idempotent BLOCKS — labels are regenerated on device from
fold_in(key, global_index), so any worker, any retry, any speculative
duplicate, and any post-restart recomputation of a block is bit-identical
by construction. Recovery is therefore exact recomputation, never
approximate reconciliation.

Layers:

  * SHAPE BUCKETS — each request is padded up to a bucket size (next
    power of two by default) and executed by a program compiled once per
    (bucket, n_groups, mode) via the masked block steps in
    engine/scheduler.py; the true sample count is a traced scalar, so a
    warm server re-traces ZERO jaxprs for any request hitting an
    existing bucket (asserted by the obs retrace counter). The planned
    impl per bucket is persisted in the autotune cache under
    `serveplan|...` keys, so plan decisions also survive restarts.
  * BATCH COALESCING — queued requests that land in the SAME bucket are
    coalesced into ONE dispatch: operands are stacked along a leading
    study axis (shardable over the 'data' mesh axis) and every
    permutation block runs through the vmapped batched steps
    (scheduler.sw_block_many / sw_cols_block_many). Each study keeps its
    own PRNG key folded by the GLOBAL permutation index, so batched
    results are bit-identical to serial execution of the same requests.
    Blocks span the largest n_perms in the batch; a shorter study's tail
    indices are computed-and-discarded (harmless: draws fold by global
    index). Elastic block bags therefore span the whole batch — a worker
    death loses (block x batch) work, re-dispatched exactly as before.
  * ASYNC ADMISSION — submit() returns a concurrent.futures.Future.
    Background worker threads (start()/stop()) drain the bounded queue,
    coalescing same-bucket neighbours up to `max_batch`, and complete
    the futures; the cooperative single-threaded pump() remains as a
    serial shim (and the bit-identity reference path).
  * ELASTIC EXECUTION — blocks run through
    runtime.elastic.ElasticBlockExecutor, wired to the
    runtime.heartbeat.HeartbeatMonitor failure detector: dead workers'
    blocks are re-dispatched, stragglers are speculatively re-executed,
    zombie completions are fenced off by heartbeat incarnations. All
    chaos comes from the seeded runtime.faultinject.FaultInjector
    against an injected clock.
  * ROBUSTNESS POLICY — bounded admission queue with load shedding and a
    backpressure signal; per-request deadlines with graceful degradation
    (a reduced-n_perms result carrying a Monte-Carlo confidence interval
    for the p-value, flagged `degraded=True`); jittered-backoff retries
    for transient failures (simulated device OOM, full fleet loss);
    checkpoint/resume of partial s_W accumulators through
    checkpoint/manager.py so a restarted server finishes in-flight work
    instead of replaying it. Deadline-degraded requests additionally
    keep their partial s_W in memory and are OPPORTUNISTICALLY RESUMED
    in idle capacity: the permutation tail is finished exactly and the
    full-n_perms result is pushed to `ServeResult.final` (a Future) —
    the degraded answer is an interim, not a dead end.

Determinism note: serving uses the MASKED permutation generators for
every request (pad rows stay inert), so a request's null draws are a
deterministic function of (seed, global index, bucket mask) — identical
across failure modes, fleet sizes, batch compositions, and restarts, but
a distinct stream from the unpadded engine.run() draws (PR 4's ragged
contract). Because the draws depend on the bucket MASK, a checkpoint
written under one `bucket_sizes` configuration is NOT resumable under
another: restart with drifted buckets ignores the checkpoint (warn-once
+ `serve.ckpt_bucket_drift` counter) and recomputes from scratch.
"""

from __future__ import annotations

import dataclasses
import logging
import math
import pathlib
import shutil
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as _obs
from repro.checkpoint import manager as ckpt_mod
from repro.core import design as design_mod
from repro.core import distance as distance_mod
from repro.core import permutations
from repro.core.permanova import (PermanovaResult, TermResult, f_from_sw)
from repro.engine import planner, registry, scheduler
from repro.runtime.elastic import AllWorkersDead, ElasticBlockExecutor
from repro.runtime.faultinject import FaultInjector, SimulatedOOM

_log = logging.getLogger("repro.serve")


# ---------------------------------------------------------------------------
# Request / result contracts.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StudyRequest:
    """One tenant study. Provide a distance matrix (`dm`) or raw features
    (`x` + `metric`); `seed` fixes the permutation stream end to end."""
    grouping: np.ndarray
    dm: Optional[np.ndarray] = None
    x: Optional[np.ndarray] = None
    metric: str = "braycurtis"
    n_groups: Optional[int] = None
    n_perms: int = 999
    seed: int = 0
    strata: Optional[np.ndarray] = None
    covariates: Optional[np.ndarray] = None
    weights: Optional[np.ndarray] = None
    deadline_s: Optional[float] = None
    request_id: str = ""


@dataclasses.dataclass
class ServeResult:
    """Serving envelope around the statistical result.

    status: 'ok' | 'degraded' | 'shed' | 'failed'.
    degraded=True means the deadline cut the sweep short: `result` holds
    statistics over `n_perms_done` permutations and `p_ci` is a
    Monte-Carlo confidence interval for the p-value the full-n_perms run
    would report (the result contract's graceful-degradation flag).
    When the server runs with opportunistic resume (the default),
    `final` is a Future that later receives the EXACT full-n_perms
    ServeResult, computed from the kept partial s_W in idle capacity.
    batched=True marks results produced by a coalesced same-bucket
    dispatch (bit-identical to the serial path by construction).
    """
    request_id: str
    status: str
    result: Optional[PermanovaResult] = None
    degraded: bool = False
    n_perms_done: int = 0
    p_ci: Optional[Tuple[float, float]] = None
    error: str = ""
    retries: int = 0
    wall_s: float = 0.0
    bucket: str = ""
    report: object = None      # runtime.elastic.ExecReport of the last try
    batched: bool = False
    final: Optional[Future] = None

    @property
    def ok(self) -> bool:
        return self.status in ("ok", "degraded")


@dataclasses.dataclass
class RetryPolicy:
    """Jittered exponential backoff for TRANSIENT failures (simulated
    device OOM escaping block-level retry, or losing the whole fleet)."""
    max_retries: int = 3
    base_backoff_s: float = 0.05
    max_backoff_s: float = 2.0
    jitter: float = 0.5


def mc_pvalue_ci(n_ge: int, m: int, n_perms_full: int,
                 conf: float = 0.95,
                 use_scipy: Optional[bool] = None) -> Tuple[float, float]:
    """Predictive CI for the p-value the FULL-n_perms run would report.

    A degraded response completed m of n_perms_full permutations with
    `n_ge` null exceedances. The full run's count is n_ge + B, where B is
    the hits among the permutations the deadline cut off; under a
    Jeffreys Beta(1/2, 1/2) prior on the exceedance probability, B | data
    is beta-binomial. Mapping its conf-level predictive quantiles through
    p = (n_ge + B + 1) / (n_perms_full + 1) yields an interval that
    covers the full run's actual p-value — not merely the limiting
    exceedance probability, which the full run's own Monte-Carlo noise
    can escape.

    The interval is always ordered and brackets the degraded point
    estimate p_hat = (n_ge + 1)/(m + 1), including at the extremes
    (0 hits or all hits): quantiles are clamped into [0, rest] and the
    bounds into [1/(n_perms_full+1), 1], under both the scipy and the
    normal-approximation paths. use_scipy: None (default) tries scipy
    and falls back; True requires scipy; False forces the fallback.
    """
    m, k, n_full = int(m), int(n_ge), int(n_perms_full)
    rest = max(n_full - m, 0)
    if rest == 0:
        p = (k + 1.0) / (n_full + 1.0)
        return (p, p)
    p_hat = (k + 1.0) / (m + 1.0)
    a, b = k + 0.5, m - k + 0.5
    alpha = 1.0 - conf
    b_lo = b_hi = None
    if use_scipy is None or use_scipy:
        try:
            from scipy.stats import betabinom
            q_lo = float(betabinom.ppf(alpha / 2, rest, a, b))
            q_hi = float(betabinom.ppf(1 - alpha / 2, rest, a, b))
            if math.isfinite(q_lo) and math.isfinite(q_hi):
                b_lo, b_hi = int(q_lo), int(q_hi)
        except Exception:
            if use_scipy:
                raise
    if b_lo is None or b_hi is None:   # normal approx to the predictive
        mean = rest * a / (a + b)
        var = (rest * a * b * (a + b + rest)) / ((a + b) ** 2
                                                 * (a + b + 1.0))
        z = 1.959963984540054 if conf >= 0.95 else 1.6448536269514722
        sd = math.sqrt(max(var, 0.0))
        b_lo = int(math.floor(mean - z * sd))
        b_hi = int(math.ceil(mean + z * sd))
    b_lo = min(max(b_lo, 0), rest)
    b_hi = min(max(b_hi, 0), rest)
    if b_lo > b_hi:
        b_lo, b_hi = b_hi, b_lo
    lo = (k + b_lo + 1.0) / (n_full + 1.0)
    hi = (k + b_hi + 1.0) / (n_full + 1.0)
    lo = max(min(lo, p_hat), 1.0 / (n_full + 1.0))
    hi = min(max(hi, p_hat), 1.0)
    return (lo, hi)


# ---------------------------------------------------------------------------
# Internal prepared request + shape buckets.
# ---------------------------------------------------------------------------

_MODE_LABELS = "labels"
_MODE_STRATA = "labels_strata"
_MODE_COLS = "cols"


@dataclasses.dataclass
class _Class:
    """Light request classification: everything the admission layer needs
    to route a request to its bucket WITHOUT touching the distance
    matrix (bucket signature = (n_pad, n_groups, mode, k_cols))."""
    mode: str
    n: int
    n_groups: int
    n_pad: int
    k_cols: int
    design: Optional[design_mod.Design]
    grouping: np.ndarray


@dataclasses.dataclass
class _Prepared:
    """Admission-side request state. Array operands are HOST (numpy)
    arrays: the execution paths device_put them once per dispatch unit —
    per request on the serial path, per stacked batch on the coalesced
    path — so admitting a request costs no eager device traffic. The
    PRNG key is likewise derived from `req.seed` at dispatch (the
    batched path folds a whole batch of seeds in one vmapped call).
    Cols-mode `basis`/`strata` come out of `design.pad_design` as device
    arrays and stay that way (they are bucket-shaped already)."""
    req: StudyRequest
    mode: str
    n: int                      # true sample count
    n_pad: int
    n_groups: int
    k_cols: int                 # 0 on label modes
    n_total: int                # n_perms + 1
    mat2: np.ndarray            # (n_pad, n_pad) f32, pad rows zero
    grouping: np.ndarray        # (n_pad,) i32, sentinel-padded
    strata: Optional["jax.Array"]
    basis: Optional["jax.Array"]
    inv_gs: Optional[np.ndarray]
    design: Optional[design_mod.Design]
    s_t: float
    n_valid: np.int32


@dataclasses.dataclass
class _Bucket:
    key: tuple
    impl: str
    tuning: dict
    fn: Callable
    hits: int = 0

    def describe(self) -> str:
        n_pad, n_groups, mode, k = self.key
        return (f"bucket(n={n_pad},g={n_groups},{mode}"
                + (f",k={k}" if k else "") + f")->{self.impl}")


@dataclasses.dataclass
class _QItem:
    """Admission-queue entry: the request, the caller's future, and the
    lazily computed bucket signature used for coalescing."""
    req: StudyRequest
    future: Optional[Future] = None
    sig: Optional[tuple] = None


@dataclasses.dataclass
class _ResumeWork:
    """A deadline-degraded request's kept partial state, queued for
    opportunistic completion in idle capacity (serial layout)."""
    p: _Prepared
    bucket: _Bucket
    out: np.ndarray
    done: np.ndarray
    spans: List[Tuple[int, int]]
    res: ServeResult
    future: Future


def _next_bucket(n: int, sizes: Optional[List[int]]) -> int:
    if sizes:
        for s in sorted(sizes):
            if s >= n:
                return int(s)
        raise ValueError(
            f"request has n={n} samples but the largest configured bucket "
            f"size is {max(sizes)}; add a larger entry to bucket_sizes= "
            "or pass bucket_sizes=None for open-ended power-of-two "
            "buckets")
    b = 16
    while b < n:
        b *= 2
    return b


class ServerOverloaded(RuntimeError):
    """Raised by submit(..., shed='raise') when the admission queue is
    full — the hard-backpressure signal."""


_drift_warned = False     # warn-once latch for checkpoint bucket drift

_KEYS_VMAPPED = jax.jit(jax.vmap(jax.random.key))


def _stack_request_keys(seeds) -> "jax.Array":
    """(S,) typed PRNG keys for a batch of request seeds in ONE jitted
    dispatch — each row is bit-identical to jax.random.key(seed) on that
    study alone, so the coalesced dispatch draws the same permutations
    as serial serving. Seeds outside uint32 (never produced by the CLI
    or tests, but legal on StudyRequest) fall back to per-study keys."""
    if all(0 <= int(s) < 2 ** 32 for s in seeds):
        return _KEYS_VMAPPED(np.asarray(seeds, np.uint32))
    return jnp.stack([jax.random.key(int(s)) for s in seeds])


class PermanovaServer:
    """Always-on multi-tenant PERMANOVA service (see module docstring).

    workers / block: the elastic fleet size and the permutation-block
    granularity (the unit of re-dispatch, speculation, and checkpoint).
    queue_limit: bounded admission queue; submissions past it are SHED.
    max_batch: coalescing bound — a drain pass batches up to this many
    queued same-bucket requests into one stacked dispatch.
    mesh: optional jax Mesh with a 'data' axis; batched dispatches then
    device_put their study axis sharded over it (wrap-padded to the
    axis size, engine.api's divisibility contract).
    opportunistic_resume: keep degraded requests' partial s_W and finish
    the permutation tail in idle capacity (ServeResult.final).
    clock / injector: injectable time and faults — production uses the
    real monotonic clock and no faults; chaos tests drive both.
    ckpt_dir: enables checkpoint/resume of in-flight partial s_W.
    """

    def __init__(self, *, workers: int = 4, block: int = 128,
                 queue_limit: int = 64,
                 bucket_sizes: Optional[List[int]] = None,
                 backend: Optional[str] = None,
                 max_batch: int = 8,
                 mesh=None,
                 opportunistic_resume: bool = True,
                 heartbeat_timeout: float = 5.0,
                 straggler_factor: float = 4.0,
                 clock: Optional[Callable[[], float]] = None,
                 injector: Optional[FaultInjector] = None,
                 retry: Optional[RetryPolicy] = None,
                 max_transient_retries: int = 8,
                 ckpt_dir=None, checkpoint_every: int = 8,
                 latency_window: int = 512):
        self.workers = int(workers)
        self.block = int(block)
        self.queue_limit = int(queue_limit)
        self.bucket_sizes = bucket_sizes
        self.backend = backend or planner.default_backend()
        self.max_batch = max(1, int(max_batch))
        self.mesh = mesh
        self.opportunistic_resume = bool(opportunistic_resume)
        self.heartbeat_timeout = float(heartbeat_timeout)
        self.straggler_factor = float(straggler_factor)
        self.clock = clock or time.monotonic
        self.injector = injector
        self.retry = retry or RetryPolicy()
        self.max_transient_retries = int(max_transient_retries)
        self.ckpt_dir = ckpt_dir
        self.checkpoint_every = int(checkpoint_every)
        self._rng = np.random.default_rng(0)     # retry jitter (seeded)
        self._lock = threading.RLock()
        self._cv = threading.Condition(self._lock)
        self._exec_lock = threading.RLock()      # one dispatch at a time
        self._queue: deque = deque()             # _QItem entries
        self._resume_q: deque = deque()          # _ResumeWork entries
        self._buckets: Dict[tuple, _Bucket] = {}
        self._lat = deque(maxlen=int(latency_window))  # (t_end, dur_s, ok)
        self._seq = 0
        self._threads: List[threading.Thread] = []
        self._stopping = False
        self._abandon = False
        self._inflight = 0

    # -- admission --------------------------------------------------------
    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    @property
    def backpressure(self) -> bool:
        """Soft signal: queue at >= 80% of the admission bound — callers
        should slow down before submissions start shedding."""
        return len(self._queue) >= max(1, int(0.8 * self.queue_limit))

    def submit(self, req: StudyRequest, *, shed: str = "result") -> Future:
        """Admit one request; returns a Future resolving to its
        ServeResult (completed by pump(), serve(), or the background
        worker threads). When the bounded queue is full the request is
        SHED: with shed='result' (default) the future resolves
        immediately to ServeResult(status='shed'); with shed='raise'
        ServerOverloaded is raised (hard backpressure for synchronous
        callers). A request that cannot fit any configured bucket
        resolves immediately to status='failed' instead of poisoning the
        drain loop."""
        fut: Future = Future()
        with self._cv:
            if not req.request_id:
                req.request_id = f"req{self._seq}"
            self._seq += 1
            if len(self._queue) >= self.queue_limit:
                _obs.metrics.inc("serve.requests_shed")
                if shed == "raise":
                    raise ServerOverloaded(
                        f"admission queue full ({self.queue_limit})")
                fut.set_result(ServeResult(
                    request_id=req.request_id, status="shed",
                    error="admission queue full"))
                return fut
            try:
                n = int(np.asarray(req.grouping).shape[0])
                _next_bucket(n, self.bucket_sizes)
            except ValueError as e:
                _obs.metrics.inc("serve.requests_failed")
                fut.set_result(ServeResult(
                    request_id=req.request_id, status="failed",
                    error=f"ValueError: {e}"))
                return fut
            self._queue.append(_QItem(req=req, future=fut))
            _obs.metrics.inc("serve.requests_admitted")
            _obs.metrics.gauge_set("serve.queue_depth", len(self._queue))
            self._cv.notify()
        return fut

    def pump(self, max_requests: Optional[int] = None) -> List[ServeResult]:
        """Process queued requests FIFO, one at a time; returns their
        results. This is the single-threaded SERIAL shim — no batch
        coalescing — and doubles as the bit-identity reference for the
        batched path."""
        out: List[ServeResult] = []
        while True:
            with self._cv:
                if not self._queue or (max_requests is not None
                                       and len(out) >= max_requests):
                    break
                item = self._queue.popleft()
                _obs.metrics.gauge_set("serve.queue_depth",
                                       len(self._queue))
            res = self.process(item.req)
            self._finish(item, res)
            out.append(res)
        return out

    def drain_batched(self, max_batch: Optional[int] = None
                      ) -> List[ServeResult]:
        """Drain the queue with same-bucket coalescing: each pass pops
        the head request plus every queued request sharing its bucket
        signature (up to max_batch) and executes them as ONE stacked
        dispatch."""
        out: List[ServeResult] = []
        mb = self.max_batch if max_batch is None else max(1, int(max_batch))
        while True:
            batch = self._pop_batch(mb)
            if not batch:
                return out
            out.extend(self._process_batch(batch))

    def serve(self, reqs: List[StudyRequest], *,
              batched: bool = False,
              max_batch: Optional[int] = None) -> List[ServeResult]:
        """Convenience: submit everything, drain, return results in
        request order (shed results land inline). batched=True coalesces
        same-bucket requests into stacked dispatches; the default drains
        serially through pump(). When background workers are running
        (start()), this just submits and waits on the futures."""
        futs = [self.submit(r) for r in reqs]
        if not self._threads:
            if batched:
                self.drain_batched(max_batch)
            else:
                self.pump()
        return [f.result() for f in futs]

    # -- background workers ----------------------------------------------
    def start(self, threads: int = 2) -> None:
        """Start background admission workers: each drains the queue
        (coalescing same-bucket requests up to max_batch), completes
        futures, and — when the queue is empty — opportunistically
        finishes degraded requests' permutation tails."""
        with self._cv:
            if self._threads:
                return
            self._stopping = False
            self._abandon = False
            for i in range(max(1, int(threads))):
                t = threading.Thread(target=self._worker_loop,
                                     name=f"permanova-serve-{i}",
                                     daemon=True)
                t.start()
                self._threads.append(t)

    def stop(self, *, drain: bool = True,
             timeout: Optional[float] = None) -> None:
        """Stop the background workers. drain=True (default) waits for
        the admission and resume queues to empty first; drain=False
        abandons queued work (its futures stay pending)."""
        with self._cv:
            if drain:
                while self._queue or self._resume_q or self._inflight:
                    self._cv.wait(timeout=0.1)
            self._stopping = True
            self._abandon = not drain
            self._cv.notify_all()
        for t in self._threads:
            t.join(timeout=timeout)
        self._threads = []

    def _worker_loop(self) -> None:
        while True:
            with self._cv:
                while (not self._stopping and not self._queue
                       and not self._resume_q):
                    self._cv.wait(timeout=0.2)
                if self._abandon:
                    return
                if self._stopping and not self._queue \
                        and not self._resume_q:
                    return
            batch = self._pop_batch(self.max_batch)
            if batch:
                with self._cv:
                    self._inflight += 1
                try:
                    self._process_batch(batch)
                finally:
                    with self._cv:
                        self._inflight -= 1
                        self._cv.notify_all()
                continue
            work = None
            with self._cv:
                if self._resume_q and not self._queue:
                    work = self._resume_q.popleft()
                    self._inflight += 1
            if work is not None:
                try:
                    self._run_resume(work)
                finally:
                    with self._cv:
                        self._inflight -= 1
                        self._cv.notify_all()

    def _finish(self, item: _QItem, res: ServeResult) -> None:
        if item.future is not None and not item.future.done():
            item.future.set_result(res)
        with self._cv:
            self._cv.notify_all()

    # -- batch coalescing -------------------------------------------------
    def _sig_of(self, item: _QItem) -> Optional[tuple]:
        """Bucket signature of a queued request (cached on the entry).
        Classification failures complete the future as status='failed'
        and return None — one bad request never poisons the drain."""
        if item.sig is not None:
            return item.sig
        try:
            c = self._classify(item.req)
        except Exception as e:
            _obs.metrics.inc("serve.requests_failed")
            self._finish(item, ServeResult(
                request_id=item.req.request_id, status="failed",
                error=f"{type(e).__name__}: {e}"))
            return None
        item.sig = (c.n_pad, c.n_groups, c.mode, c.k_cols)
        return item.sig

    def _pop_batch(self, max_batch: int) -> Optional[List[_QItem]]:
        """Pop the head request plus every queued request with the same
        bucket signature, up to max_batch, preserving FIFO order within
        the batch. Returns None when the queue is empty."""
        with self._cv:
            while self._queue:
                head = self._queue.popleft()
                sig = self._sig_of(head)
                if sig is None:
                    continue
                batch = [head]
                if max_batch > 1 and self._queue:
                    rest: List[_QItem] = []
                    for it in self._queue:
                        s = self._sig_of(it)
                        if s is None:
                            continue
                        if len(batch) < max_batch and s == sig:
                            batch.append(it)
                        else:
                            rest.append(it)
                    self._queue = deque(rest)
                _obs.metrics.gauge_set("serve.queue_depth",
                                       len(self._queue))
                return batch
            return None

    def _process_batch(self, items: List[_QItem]) -> List[ServeResult]:
        """Execute one coalesced batch; completes each item's future and
        returns the results in item order."""
        with self._exec_lock:
            return self._process_batch_locked(items)

    def _process_batch_locked(self, items: List[_QItem]
                              ) -> List[ServeResult]:
        results: Dict[int, ServeResult] = {}
        live: List[Tuple[_QItem, _Prepared]] = []
        for it in items:
            try:
                live.append((it, self._prepare(it.req)))
            except Exception as e:
                r = ServeResult(request_id=it.req.request_id,
                                status="failed",
                                error=f"{type(e).__name__}: {e}")
                _obs.metrics.inc("serve.steps")
                _obs.metrics.inc("serve.requests_failed")
                self._finish(it, r)
                results[id(it)] = r
        # Requests holding a resumable checkpoint peel off to the serial
        # path: their partial state lives in the serial block layout.
        batch = [(it, p) for it, p in live if not self._has_resumable(p)]
        serial = [it for it, p in live if self._has_resumable(p)]
        if len(batch) == 1:
            it = batch[0][0]
            serial.insert(0, it)
            batch = []
        if batch:
            preps = [p for _, p in batch]
            S = len(preps)
            _obs.metrics.inc("serve.batches")
            _obs.metrics.inc("serve.batched_requests", S)
            _obs.metrics.observe("serve.batch_size", S)
            t0 = self.clock()
            t0_ns = time.perf_counter_ns()
            try:
                with _obs.span("serve.batch",
                               {"size": S, "bucket": str(preps[0].n_pad)}):
                    rs = self._execute_batch(preps, t0)
            except Exception as e:   # non-transient batch failure
                rs = [ServeResult(request_id=p.req.request_id,
                                  status="failed",
                                  error=f"{type(e).__name__}: {e}")
                      for p in preps]
            t1_ns = time.perf_counter_ns()
            wall = self.clock() - t0
            for (it, p), r in zip(batch, rs):
                r.wall_s = wall
                self._lat.append((self.clock(), wall, r.ok))
                _obs.emit_complete("serve.step", t0_ns, t1_ns,
                                   {"request": r.request_id, "batch": S})
                _obs.metrics.inc("serve.steps")
                if r.status in ("ok", "degraded"):
                    _obs.metrics.inc("serve.requests_completed")
                    if r.degraded:
                        _obs.metrics.inc("serve.requests_degraded")
                elif r.status == "failed":
                    _obs.metrics.inc("serve.requests_failed")
                self._finish(it, r)
                results[id(it)] = r
        for it in serial:
            r = self.process(it.req)
            self._finish(it, r)
            results[id(it)] = r
        return [results[id(it)] for it in items]

    def _has_resumable(self, p: _Prepared) -> bool:
        if self.ckpt_dir is None:
            return False
        d = pathlib.Path(self.ckpt_dir) / p.req.request_id
        return ckpt_mod.latest_step(d) is not None

    # -- per-request processing ------------------------------------------
    def process(self, req: StudyRequest) -> ServeResult:
        with self._exec_lock:
            t0 = self.clock()
            with _obs.span("serve.step", {"request": req.request_id}):
                res = self._process_with_retries(req, t0)
            dur = self.clock() - t0
            res.wall_s = dur
            self._lat.append((self.clock(), dur, res.ok))
            _obs.metrics.inc("serve.steps")
            if res.status in ("ok", "degraded"):
                _obs.metrics.inc("serve.requests_completed")
                if res.degraded:
                    _obs.metrics.inc("serve.requests_degraded")
            elif res.status == "failed":
                _obs.metrics.inc("serve.requests_failed")
            return res

    def _process_with_retries(self, req: StudyRequest,
                              t0: float) -> ServeResult:
        policy = self.retry
        last_err = ""
        for attempt in range(policy.max_retries + 1):
            try:
                res = self._execute(req, t0)
                res.retries = attempt
                return res
            except (SimulatedOOM, AllWorkersDead) as e:
                last_err = f"{type(e).__name__}: {e}"
                _obs.metrics.inc("serve.request_retries")
                if attempt >= policy.max_retries:
                    break
                backoff = min(policy.base_backoff_s * (2 ** attempt),
                              policy.max_backoff_s)
                backoff *= 1.0 + policy.jitter * float(self._rng.uniform())
                self._sleep(backoff)
            except Exception as e:          # non-transient: fail fast
                return ServeResult(request_id=req.request_id,
                                   status="failed",
                                   error=f"{type(e).__name__}: {e}",
                                   retries=attempt)
        return ServeResult(request_id=req.request_id, status="failed",
                           error=last_err, retries=policy.max_retries)

    def _sleep(self, dt: float) -> None:
        sleep = getattr(self.clock, "sleep", None)
        (sleep or time.sleep)(dt)

    # -- preparation ------------------------------------------------------
    def _classify(self, req: StudyRequest) -> _Class:
        grouping = np.asarray(req.grouping, np.int32)
        n = int(grouping.shape[0])
        n_groups = (int(req.n_groups) if req.n_groups is not None
                    else int(grouping.max()) + 1)
        dense = req.covariates is not None or req.weights is not None
        design = None
        if dense:
            design = design_mod.build(
                grouping=grouping, covariates=req.covariates,
                strata=req.strata, weights=req.weights,
                n_groups=n_groups, force_dense=True)
            mode = _MODE_COLS
        elif req.strata is not None:
            design = design_mod.build(grouping=grouping, strata=req.strata,
                                      n_groups=n_groups)
            mode = (_MODE_STRATA if design.mode == design_mod.MODE_LABELS
                    else _MODE_COLS)
        else:
            mode = _MODE_LABELS
        k_cols = design.k_cols if mode == _MODE_COLS else 0
        n_pad = _next_bucket(n, self.bucket_sizes)
        return _Class(mode=mode, n=n, n_groups=n_groups, n_pad=n_pad,
                      k_cols=k_cols, design=design, grouping=grouping)

    def _prepare(self, req: StudyRequest) -> _Prepared:
        if (req.dm is None) == (req.x is None):
            raise ValueError("provide exactly one of dm= or x=")
        c = self._classify(req)
        n, n_groups, mode, n_pad = c.n, c.n_groups, c.mode, c.n_pad
        design = c.design
        if req.dm is not None:
            dm = np.asarray(req.dm, np.float32)
        else:
            with _obs.span("serve.stage1", {"metric": req.metric}):
                dm = np.asarray(distance_mod.distance_matrix(
                    jnp.asarray(req.x), req.metric), np.float32)
        if dm.shape != (n, n):
            raise ValueError(f"dm is {dm.shape}, grouping has n={n}")

        mat2 = np.zeros((n_pad, n_pad), np.float32)
        mat2[:n, :n] = dm * dm
        g_pad = np.full((n_pad,), n_groups, np.int32)    # sentinel pad
        g_pad[:n] = c.grouping
        strata_pad = basis = inv_gs = None
        k_cols = 0
        if mode == _MODE_COLS:
            dpad = design_mod.pad_design(design, n_pad)
            basis = jnp.asarray(dpad.basis)
            k_cols = dpad.k_cols
            st = (dpad.strata if dpad.strata is not None
                  else jnp.zeros((n_pad,), jnp.int32))
            strata_pad = jnp.asarray(st, jnp.int32)
            design = dpad
        else:
            # host-side twin of permutations.inv_group_sizes: eager jnp
            # bincount/scatter costs ~1.5 ms per request, which would be
            # the admission bottleneck once batching amortises the blocks
            # (same float32 values: integer counts, one IEEE division)
            sizes = np.bincount(g_pad, minlength=n_groups)[:n_groups]
            sizes = sizes.astype(np.float32)
            inv_gs = np.where(
                sizes > 0, 1.0 / np.maximum(sizes, 1.0), 0.0) \
                .astype(np.float32)
            if mode == _MODE_STRATA:
                st = np.zeros((n_pad,), np.int32)
                st[:n] = np.asarray(design.strata, np.int32)[:n]
                strata_pad = st
        s_t = float(mat2.sum()) / 2.0 / n    # pad rows are zero
        return _Prepared(
            req=req, mode=mode, n=n, n_pad=n_pad, n_groups=n_groups,
            k_cols=k_cols, n_total=int(req.n_perms) + 1,
            mat2=mat2, grouping=g_pad,
            strata=strata_pad, basis=basis, inv_gs=inv_gs, design=design,
            s_t=s_t, n_valid=np.int32(n))

    # -- bucket / compiled-program cache ---------------------------------
    def _bucket_for(self, p: _Prepared) -> _Bucket:
        key = (p.n_pad, p.n_groups, p.mode, p.k_cols)
        with self._lock:
            b = self._buckets.get(key)
            if b is not None:
                b.hits += 1
                _obs.metrics.inc("serve.bucket_hits")
                return b
            _obs.metrics.inc("serve.bucket_misses")
            cache_key = (f"serveplan|{self.backend}|n{p.n_pad}|g{p.n_groups}"
                         f"|{p.mode}|k{p.k_cols}")
            impl = tuning = None
            entry = planner.measured_entry(cache_key)
            if entry:
                try:
                    spec = registry.get(entry["impl"])
                    impl = entry["impl"]
                    tuning = {k: v for k, v in (entry.get("tuning") or {})
                              .items() if k in spec.tuning}
                except KeyError:
                    impl = None
            if impl is None:
                pl = planner.plan(
                    p.n_pad, max(p.n_total, self.block),
                    p.n_groups if p.n_groups else max(p.k_cols, 2),
                    backend=self.backend, chunk=self.block,
                    n_cols=p.k_cols if p.mode == _MODE_COLS else None)
                impl, tuning = pl.impl, dict(pl.tuning)
                planner.record_entry(cache_key, {
                    "impl": impl, "tuning": tuning, "block": self.block,
                    "reason": pl.reason})
            if p.mode == _MODE_COLS:
                fn = registry.bound_cols(impl, **tuning)
            else:
                fn = registry.get(impl).bound(**tuning)
            b = _Bucket(key=key, impl=impl, tuning=tuning, fn=fn, hits=1)
            self._buckets[key] = b
            return b

    # -- execution --------------------------------------------------------
    def _spans(self, p: _Prepared) -> List[Tuple[int, int]]:
        block = min(self.block, p.n_total)
        return [(lo, min(lo + block, p.n_total))
                for lo in range(0, p.n_total, block)]

    def _compute_block_fn(self, p: _Prepared, b: _Bucket):
        # one device_put per operand per REQUEST (closed over by every
        # block call) — _Prepared carries host arrays so admission itself
        # does no device traffic
        block = min(self.block, p.n_total)
        key = jax.random.key(int(p.req.seed))
        mat2 = jnp.asarray(p.mat2)
        n_valid = jnp.int32(p.n)
        if p.mode == _MODE_COLS:
            basis, strata = p.basis, p.strata

            def compute(lo, hi):
                with _obs.span("serve.block", {"lo": lo}):
                    s = scheduler.sw_cols_block(
                        mat2, basis, strata, n_valid, key, lo,
                        fn=b.fn, block=block)
                    return np.asarray(s)[: hi - lo]
        else:
            grouping = jnp.asarray(p.grouping)
            inv_gs = jnp.asarray(p.inv_gs)
            strata = jnp.asarray(p.strata) if p.strata is not None else None

            def compute(lo, hi):
                with _obs.span("serve.block", {"lo": lo}):
                    s = scheduler.sw_block(
                        mat2, grouping, n_valid, inv_gs, key, lo,
                        fn=b.fn, block=block, strata=strata)
                    return np.asarray(s)[: hi - lo]
        return compute

    def _ckpt_mgr(self, req: StudyRequest):
        if self.ckpt_dir is None:
            return None
        return ckpt_mod.CheckpointManager(
            pathlib.Path(self.ckpt_dir) / req.request_id, keep=2)

    def _execute(self, req: StudyRequest, t0: float) -> ServeResult:
        p = self._prepare(req)
        b = self._bucket_for(p)
        spans = self._spans(p)
        n_blocks = len(spans)
        out = np.zeros((p.n_total, p.k_cols), np.float32) \
            if p.mode == _MODE_COLS else np.zeros((p.n_total,), np.float32)
        done = np.zeros((n_blocks,), bool)

        mgr = self._ckpt_mgr(req)
        if mgr is not None:
            done, out = self._maybe_resume(mgr, p, done, out, n_blocks)

        deadline = req.deadline_s

        def should_stop() -> bool:
            return (deadline is not None
                    and self.clock() - t0 >= deadline)

        commits_since_ckpt = [0]

        def on_commit(bid: int) -> None:
            # Mirror the commit into the caller-side mask: the executor
            # runs on its own copy of `done` (resume isolation), but it
            # writes `out` in place, so out[spans[bid]] is current here.
            done[bid] = True
            commits_since_ckpt[0] += 1
            if (mgr is not None
                    and commits_since_ckpt[0] % self.checkpoint_every == 0):
                self._checkpoint(mgr, p, out, done)

        exe = ElasticBlockExecutor(
            n_blocks, workers=self.workers, clock=self.clock,
            heartbeat_timeout=self.heartbeat_timeout,
            straggler_factor=self.straggler_factor,
            injector=self.injector or FaultInjector(),
            max_transient_retries=self.max_transient_retries)
        out, done, rep = exe.run(self._compute_block_fn(p, b), spans,
                                 out=out, done=done,
                                 should_stop=should_stop,
                                 on_commit=on_commit)
        if rep.stale_beats_rejected:
            _obs.metrics.inc("serve.zombies_fenced",
                             rep.stale_beats_rejected)
        if not done.all():
            if mgr is not None:
                self._checkpoint(mgr, p, out, done)
            if not done[0]:
                return ServeResult(
                    request_id=req.request_id, status="failed",
                    error="deadline expired before the observed statistic",
                    bucket=b.describe(), report=rep)
            res = self._assemble(p, b, out, done, spans, rep,
                                 degraded=True)
            self._queue_resume(p, b, out, done, spans, res)
            return res
        if mgr is not None:
            shutil.rmtree(mgr.directory, ignore_errors=True)   # finished
        return self._assemble(p, b, out, done, spans, rep, degraded=False)

    # -- batched execution ------------------------------------------------
    def _stack_studies(self, lists, prestacked=()):
        """Stack per-study operands along a leading study axis. Host
        (numpy) operand lists are stacked host-side and shipped in ONE
        device_put per operand per batch; device operands (cols-mode
        basis) stack with jnp; `prestacked` arrays (the vmapped key
        batch) already carry the study axis and are appended verbatim.
        With a 'data' mesh axis configured, wrap-pad the study count up
        to the axis size and device_put with a leading-'data'
        NamedSharding (engine.api's study-axis contract); callers slice
        batch results back to the true S."""
        stacked = [jnp.asarray(np.stack(a))
                   if all(isinstance(x, (np.ndarray, np.generic))
                          for x in a)
                   else jnp.stack(a) for a in lists]
        stacked += list(prestacked)
        if self.mesh is None:
            return stacked
        from repro.engine import api as engine_api
        data_ways, s_pad, wrap = engine_api.study_axis_padding(
            self.mesh, int(stacked[0].shape[0]))
        if data_ways <= 1:
            return stacked
        if s_pad:
            stacked = [a[wrap] for a in stacked]
        return list(engine_api.put_study_sharded(self.mesh, stacked))

    def _execute_batch(self, preps: List[_Prepared],
                       t0: float) -> List[ServeResult]:
        """One coalesced same-bucket dispatch: every permutation block is
        a single vmapped step over the stacked study axis, run through
        the elastic executor as a bag spanning the WHOLE batch. Per-study
        keys keep each column bit-identical to the serial path. Handles
        per-request deadlines (expired members degrade and leave; the
        rest keep going) and batch-level transient retries."""
        bkt = self._bucket_for(preps[0])
        for p in preps[1:]:
            self._bucket_for(p)     # same key: per-request hit accounting
        S = len(preps)
        mode = preps[0].mode
        max_total = max(p.n_total for p in preps)
        block = min(self.block, max_total)
        spans = [(lo, min(lo + block, max_total))
                 for lo in range(0, max_total, block)]
        n_blocks = len(spans)

        keys = _stack_request_keys([p.req.seed for p in preps])
        if mode == _MODE_COLS:
            mat2_b, basis_b, strata_b, nvalid_b, keys_b = \
                self._stack_studies([[p.mat2 for p in preps],
                                     [p.basis for p in preps],
                                     [p.strata for p in preps],
                                     [p.n_valid for p in preps]],
                                    prestacked=(keys,))
            k_cols = preps[0].k_cols
            out = np.zeros((max_total, S, k_cols), np.float32)

            def compute(lo, hi):
                with _obs.span("serve.block", {"lo": lo, "batch": S}):
                    s = scheduler.sw_cols_block_many(
                        mat2_b, basis_b, strata_b, nvalid_b, keys_b, lo,
                        fn=bkt.fn, block=block)
                    return np.asarray(s).transpose(1, 0, 2)[: hi - lo, :S]
        else:
            lists = [[p.mat2 for p in preps], [p.grouping for p in preps],
                     [p.n_valid for p in preps],
                     [p.inv_gs for p in preps]]
            if mode == _MODE_STRATA:
                lists.append([p.strata for p in preps])
            ops = self._stack_studies(lists, prestacked=(keys,))
            mat2_b, grouping_b, nvalid_b, invgs_b = ops[:4]
            strata_b = ops[4] if mode == _MODE_STRATA else None
            keys_b = ops[-1]
            out = np.zeros((max_total, S), np.float32)

            def compute(lo, hi):
                with _obs.span("serve.block", {"lo": lo, "batch": S}):
                    s = scheduler.sw_block_many(
                        mat2_b, grouping_b, nvalid_b, invgs_b, keys_b, lo,
                        fn=bkt.fn, block=block, strata=strata_b)
                    return np.asarray(s).T[: hi - lo, :S]

        done = np.zeros((n_blocks,), bool)
        need = [np.array([lo < p.n_total for (lo, _) in spans], bool)
                for p in preps]
        deadlines = [t0 + p.req.deadline_s
                     if p.req.deadline_s is not None else None
                     for p in preps]
        results: List[Optional[ServeResult]] = [None] * S
        active = set(range(S))
        retries = 0
        policy = self.retry
        while active:
            dls = [deadlines[i] for i in active if deadlines[i] is not None]
            earliest = min(dls) if dls else None

            def should_stop() -> bool:
                return earliest is not None and self.clock() >= earliest

            exe = ElasticBlockExecutor(
                n_blocks, workers=self.workers, clock=self.clock,
                heartbeat_timeout=self.heartbeat_timeout,
                straggler_factor=self.straggler_factor,
                injector=self.injector or FaultInjector(),
                max_transient_retries=self.max_transient_retries)
            try:
                out, done, rep = exe.run(compute, spans, out=out,
                                         done=done,
                                         should_stop=should_stop)
            except (SimulatedOOM, AllWorkersDead) as e:
                retries += 1
                _obs.metrics.inc("serve.request_retries", len(active))
                if retries > policy.max_retries:
                    for i in sorted(active):
                        results[i] = ServeResult(
                            request_id=preps[i].req.request_id,
                            status="failed",
                            error=f"{type(e).__name__}: {e}",
                            retries=retries - 1, batched=True,
                            bucket=bkt.describe())
                    active.clear()
                    break
                backoff = min(policy.base_backoff_s * (2 ** (retries - 1)),
                              policy.max_backoff_s)
                backoff *= 1.0 + policy.jitter * float(self._rng.uniform())
                self._sleep(backoff)
                continue
            if rep.stale_beats_rejected:
                _obs.metrics.inc("serve.zombies_fenced",
                                 rep.stale_beats_rejected)
            for i in sorted(active):
                if bool(done[need[i]].all()):
                    results[i] = self._assemble_from_batch(
                        preps[i], bkt, out, done, spans, rep, i,
                        degraded=False, retries=retries)
                    active.discard(i)
            if not active:
                break
            # should_stop fired: degrade every member past its deadline.
            now = self.clock()
            for i in sorted(active):
                dl = deadlines[i]
                if dl is None or now < dl:
                    continue
                if not done[0]:
                    results[i] = ServeResult(
                        request_id=preps[i].req.request_id,
                        status="failed",
                        error=("deadline expired before the observed "
                               "statistic"),
                        bucket=bkt.describe(), report=rep, batched=True,
                        retries=retries)
                else:
                    results[i] = self._assemble_from_batch(
                        preps[i], bkt, out, done, spans, rep, i,
                        degraded=True, retries=retries)
                active.discard(i)
        return [r for r in results]

    def _assemble_from_batch(self, p: _Prepared, bkt: _Bucket, out, done,
                             spans, rep, i: int, *, degraded: bool,
                             retries: int) -> ServeResult:
        """Slice batch member i back into the serial layout and reuse the
        serial assembly (identical arithmetic => identical results)."""
        if p.mode == _MODE_COLS:
            out_i = np.ascontiguousarray(out[: p.n_total, i, :])
        else:
            out_i = np.ascontiguousarray(out[: p.n_total, i])
        spans_i: List[Tuple[int, int]] = []
        done_i: List[bool] = []
        for bid, (lo, hi) in enumerate(spans):
            if lo >= p.n_total:
                break
            spans_i.append((lo, min(hi, p.n_total)))
            done_i.append(bool(done[bid]))
        done_arr = np.asarray(done_i, bool)
        res = self._assemble(p, bkt, out_i, done_arr, spans_i, rep,
                             degraded=degraded)
        res.batched = True
        res.retries = retries
        if degraded:
            mgr = self._ckpt_mgr(p.req)
            if mgr is not None:
                self._checkpoint(mgr, p, out_i, done_arr)
            self._queue_resume(p, bkt, out_i, done_arr, spans_i, res)
        return res

    # -- opportunistic resume of degraded results -------------------------
    def _queue_resume(self, p: _Prepared, bkt: _Bucket, out, done, spans,
                      res: ServeResult) -> None:
        """Keep a degraded request's partial s_W and queue the
        permutation tail for completion in idle capacity; `res.final`
        receives the exact full-n_perms ServeResult."""
        if not self.opportunistic_resume or bool(np.asarray(done).all()):
            return
        fut: Future = Future()
        res.final = fut
        with self._cv:
            self._resume_q.append(_ResumeWork(
                p=p, bucket=bkt, out=out, done=np.asarray(done, bool),
                spans=list(spans), res=res, future=fut))
            self._cv.notify()
        _obs.metrics.inc("serve.resumes_queued")

    @property
    def resume_backlog(self) -> int:
        return len(self._resume_q)

    def resume_degraded(self, max_items: Optional[int] = None
                        ) -> List[ServeResult]:
        """Synchronously finish queued degraded tails (the cooperative
        twin of the background workers' idle-time resume). Returns the
        exact results, which are also pushed to each ServeResult.final."""
        out: List[ServeResult] = []
        while True:
            with self._cv:
                if not self._resume_q or (max_items is not None
                                          and len(out) >= max_items):
                    return out
                work = self._resume_q.popleft()
            out.append(self._run_resume(work))

    def _run_resume(self, w: _ResumeWork) -> ServeResult:
        with self._exec_lock:
            try:
                exe = ElasticBlockExecutor(
                    len(w.spans), workers=self.workers, clock=self.clock,
                    heartbeat_timeout=self.heartbeat_timeout,
                    straggler_factor=self.straggler_factor,
                    injector=self.injector or FaultInjector(),
                    max_transient_retries=self.max_transient_retries)
                out, done, rep = exe.run(
                    self._compute_block_fn(w.p, w.bucket), w.spans,
                    out=w.out, done=w.done)
                res = self._assemble(w.p, w.bucket, out, done, w.spans,
                                     rep, degraded=False)
                res.retries = w.res.retries
                res.batched = w.res.batched
                _obs.metrics.inc("serve.resumes_completed")
                mgr = self._ckpt_mgr(w.p.req)
                if mgr is not None:
                    shutil.rmtree(mgr.directory, ignore_errors=True)
            except Exception as e:
                res = ServeResult(request_id=w.p.req.request_id,
                                  status="failed",
                                  error=f"{type(e).__name__}: {e}")
            if not w.future.done():
                w.future.set_result(res)
            return res

    # -- checkpoint/resume ------------------------------------------------
    def _checkpoint(self, mgr, p: _Prepared, out: np.ndarray,
                    done: np.ndarray) -> None:
        step = int(done.sum())
        mgr.save({"s_w": out, "done": done.astype(np.uint8)}, step=step,
                 extras={"request_id": p.req.request_id,
                         "n_perms": int(p.req.n_perms),
                         "block": self.block, "seed": int(p.req.seed),
                         "n_pad": int(p.n_pad), "mode": p.mode},
                 blocking=True)
        _obs.metrics.inc("serve.checkpoints")

    def _maybe_resume(self, mgr, p: _Prepared, done, out, n_blocks):
        step = mgr.latest_step()
        if step is None:
            return done, out
        req = p.req
        try:
            tree, manifest = mgr.restore(
                {"s_w": out, "done": done.astype(np.uint8)})
        except Exception:
            return done, out      # unreadable partial state: recompute
        ex = manifest.get("extras", {}) or {}
        # Masked draws depend on the bucket mask: a checkpoint written
        # under a different n_pad is NOT resumable — mixing the streams
        # silently corrupts results. Ignore it and recompute.
        if int(ex.get("n_pad", -1)) != int(p.n_pad):
            self._note_bucket_drift(req, ex.get("n_pad"), p.n_pad)
            return done, out
        if (ex.get("block") != self.block
                or ex.get("n_perms") != int(req.n_perms)
                or ex.get("seed") != int(req.seed)):
            return done, out      # different request config: ignore
        done_l = np.asarray(tree["done"], bool)
        out_l = np.asarray(tree["s_w"], out.dtype)
        if done_l.shape != (n_blocks,) or out_l.shape != out.shape:
            return done, out
        _obs.metrics.inc("serve.resumed_requests")
        _obs.metrics.inc("serve.resumed_blocks", float(done_l.sum()))
        return done_l.copy(), out_l.copy()

    def _note_bucket_drift(self, req: StudyRequest, old_pad,
                           new_pad: int) -> None:
        global _drift_warned
        _obs.metrics.inc("serve.ckpt_bucket_drift")
        if not _drift_warned:
            _drift_warned = True
            _log.warning(
                "ignoring checkpoint for %s: saved bucket n_pad=%s no "
                "longer matches current n_pad=%s (bucket_sizes drift); "
                "recomputing from scratch. Further drops are counted in "
                "serve.ckpt_bucket_drift without logging.",
                req.request_id, old_pad, new_pad)

    # -- result assembly --------------------------------------------------
    def _assemble(self, p: _Prepared, b: _Bucket, out, done, spans, rep,
                  *, degraded: bool) -> ServeResult:
        idx = np.concatenate([np.arange(lo, hi)
                              for bid, (lo, hi) in enumerate(spans)
                              if done[bid]]) if not done.all() \
            else np.arange(p.n_total)
        m = int(idx.size) - 1                   # completed permutations
        sub = out[idx]
        method_suffix = "+degraded" if degraded else ""
        plan_str = (f"{b.describe()} block={self.block} "
                    f"blocks={len(spans)} workers={self.workers}")
        if p.mode == _MODE_COLS:
            result = self._design_result(p, sub, m, method_suffix, plan_str)
            f_sub = np.asarray(result.f_perms, np.float64)
        else:
            s_w = np.asarray(sub, np.float64)
            f_sub = np.asarray(f_from_sw(
                s_w, p.s_t, p.n, p.n_groups), np.float64)
            n_ge = int(np.sum(f_sub[1:] >= f_sub[0]))
            p_val = (n_ge + 1.0) / (m + 1.0)
            result = PermanovaResult(
                f_stat=f_sub[0], p_value=p_val, s_t=p.s_t, s_w=s_w[0],
                f_perms=f_sub, n_objects=p.n, n_groups=p.n_groups,
                n_perms=m,
                method=f"permanova-serve[{b.impl}]{method_suffix}",
                plan=plan_str)
        ci = None
        if degraded:
            n_ge = int(np.sum(f_sub[1:] >= f_sub[0]))
            ci = mc_pvalue_ci(n_ge, m, int(p.req.n_perms))
        return ServeResult(
            request_id=p.req.request_id,
            status="degraded" if degraded else "ok",
            result=result, degraded=degraded, n_perms_done=m,
            p_ci=ci, bucket=b.describe(), report=rep)

    def _design_result(self, p: _Prepared, s_cols, m: int,
                       method_suffix: str, plan_str: str) -> PermanovaResult:
        design = p.design
        dof_resid = float(p.n - design.rank)
        ts = design_mod.term_stats(s_cols, design, dof_resid=dof_resid)
        terms = []
        f_terms = np.asarray(ts.f_terms, np.float64)
        ss_terms = np.asarray(ts.ss_terms, np.float64)
        s_t = float(np.asarray(ts.s_t))
        for i, t in enumerate(design.terms[1:]):
            f_p = f_terms[:, i]
            n_ge = int(np.sum(f_p[1:] >= f_p[0]))
            terms.append(TermResult(
                name=t.name, kind=t.kind, df=t.df, ss=ss_terms[0, i],
                f_stat=f_p[0], p_value=(n_ge + 1.0) / (m + 1.0),
                r2=ss_terms[0, i] / s_t, f_perms=f_p))
        last = terms[-1]
        return PermanovaResult(
            f_stat=last.f_stat, p_value=last.p_value, s_t=s_t,
            s_w=float(np.asarray(ts.ss_resid)[0]), f_perms=last.f_perms,
            n_objects=p.n,
            n_groups=(design.n_groups if design.n_groups else design.rank),
            n_perms=m,
            method=f"permanova-serve-design[{p.mode}]{method_suffix}",
            plan=plan_str, terms=tuple(terms))

    # -- telemetry --------------------------------------------------------
    def stats(self) -> dict:
        """Rolling serving stats from the internal latency ring: requests
        per second over the window, p50/p99 step latency, queue depth,
        bucket inventory. (serve_stats_from_events computes the same view
        from exported `serve.step` trace spans.) Well-defined on empty
        and single-sample windows: a zero-width window (e.g. under a
        virtual clock) reports the duration-sum rate, never inf."""
        if not self._lat:
            return {"requests": 0, "requests_per_s": 0.0,
                    "p50_s": 0.0, "p99_s": 0.0,
                    "queue_depth": len(self._queue),
                    "buckets": len(self._buckets)}
        lat = list(self._lat)
        ts = [t for t, _, _ in lat]
        durs = sorted(d for _, d, _ in lat)
        n = len(durs)
        span_s = max(ts) - min(ts) + durs[-1]
        if span_s <= 0.0:
            span_s = float(sum(durs))
        return {
            "requests": n,
            "requests_per_s": n / span_s if span_s > 0.0 else 0.0,
            "p50_s": durs[int(0.50 * (n - 1))],
            "p99_s": durs[int(0.99 * (n - 1))],
            "queue_depth": len(self._queue),
            "buckets": len(self._buckets),
        }


def serve_stats_from_events(events: Optional[list] = None) -> dict:
    """Requests/sec and p50/p99 step latency from `serve.step` trace
    spans (the ROADMAP observability follow-on): pass a trace_event list
    or default to the live obs buffer. Batched dispatches emit one
    `serve.step` event PER REQUEST over the shared batch window, so the
    requests/sec here reflects coalesced throughput. Empty and
    single-event windows are well-defined (0.0 rps for a zero-width
    window, never inf)."""
    evs = _obs.events() if events is None else events
    steps = [e for e in evs
             if e.get("name") == "serve.step" and e.get("ph") == "X"]
    if not steps:
        return {"requests": 0, "requests_per_s": 0.0, "p50_s": 0.0,
                "p99_s": 0.0}
    durs = sorted(e["dur"] / 1e6 for e in steps)
    t_lo = min(e["ts"] for e in steps) / 1e6
    t_hi = max((e["ts"] + e["dur"]) for e in steps) / 1e6
    n = len(durs)
    span_s = t_hi - t_lo
    if span_s <= 0.0:
        span_s = float(sum(durs))
    return {"requests": n,
            "requests_per_s": n / span_s if span_s > 0.0 else 0.0,
            "p50_s": durs[int(0.50 * (n - 1))],
            "p99_s": durs[int(0.99 * (n - 1))]}
