"""jax.monitoring / device hooks feeding the MetricsRegistry.

Retraces are counted through `jax.monitoring`'s event-duration stream:
every fresh jaxpr trace of a jitted function fires one
`/jax/core/compile/jaxpr_trace_duration` event (warm cache hits fire
none), and every backend compile fires
`/jax/core/compile/backend_compile_duration`. The listener is installed
once per process and is inert while metrics are disabled, so other
listeners and the uninstrumented fast path are untouched.
"""

from __future__ import annotations

from repro.obs import metrics as _metrics

RETRACE_EVENT = "/jax/core/compile/jaxpr_trace_duration"
BACKEND_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"

RETRACES = "jax.retraces"
BACKEND_COMPILES = "jax.backend_compiles"

_installed = False


def _on_event_duration(event: str, duration: float, **kwargs) -> None:
    if not _metrics.active():
        return
    if event == RETRACE_EVENT:
        _metrics.inc(RETRACES)
        _metrics.observe("jax.trace_seconds", duration)
    elif event == BACKEND_COMPILE_EVENT:
        _metrics.inc(BACKEND_COMPILES)
        _metrics.observe("jax.compile_seconds", duration)


def install() -> None:
    """Register the compile-event listener (idempotent; never removed —
    jax.monitoring's clear would nuke third-party listeners too)."""
    global _installed
    if _installed:
        return
    try:
        from jax import monitoring
        monitoring.register_event_duration_secs_listener(_on_event_duration)
    except Exception:      # jax without monitoring: counters just stay 0
        return
    _installed = True


def record_device_memory() -> None:
    """Gauge per-device peak memory where the backend reports it
    (`device.memory_stats()` is None on CPU — silently skipped)."""
    if not _metrics.active():
        return
    try:
        import jax
        devices = jax.devices()
    except Exception:
        return
    for i, d in enumerate(devices):
        try:
            ms = d.memory_stats()
        except Exception:
            ms = None
        if not ms:
            continue
        peak = ms.get("peak_bytes_in_use", ms.get("bytes_in_use"))
        if peak is not None:
            _metrics.gauge_set(f"device{i}.peak_bytes_in_use", float(peak))
