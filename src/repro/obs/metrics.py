"""Process-wide MetricsRegistry: counters, gauges, histograms.

The recording helpers (`inc` / `gauge_set` / `observe`) are gated on one
module bool kept in sync by obs.core.enable/disable, so instrumented hot
paths pay a single flag check while telemetry is off.

Sharded/multi-host runs aggregate by *host-side* merge — `snapshot()` is
plain JSON-able data, and `merge_snapshots()` folds any number of per-host
snapshots into one (sum counters, max gauges, merge histogram moments) —
no psum, no device traffic, no participation of the compiled programs.
"""

from __future__ import annotations

import threading
from typing import Dict, Iterable, Optional

_active = False                    # mirror of core._metrics_on


def set_active(on: bool) -> None:
    global _active
    _active = bool(on)


def active() -> bool:
    return _active


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    __slots__ = ("count", "total", "min", "max")

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0


class MetricsRegistry:
    """Named counters/gauges/histograms behind one lock (cheap: the hot
    instrumented paths increment a handful of times per *dispatch*, not
    per element)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter()
            return c

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge()
            return g

    def histogram(self, name: str) -> Histogram:
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = Histogram()
            return h

    def value(self, name: str, default: float = 0.0) -> float:
        """Current counter value (0.0 when never incremented)."""
        with self._lock:
            c = self._counters.get(name)
            return c.value if c is not None else default

    def gauge_value(self, name: str, default: float = 0.0) -> float:
        with self._lock:
            g = self._gauges.get(name)
            return g.value if g is not None else default

    def snapshot(self) -> dict:
        """JSON-able copy of everything recorded so far."""
        with self._lock:
            return {
                "counters": {k: c.value
                             for k, c in sorted(self._counters.items())},
                "gauges": {k: g.value
                           for k, g in sorted(self._gauges.items())},
                "histograms": {
                    k: {"count": h.count, "total": h.total,
                        "min": h.min, "max": h.max}
                    for k, h in sorted(self._hists.items()) if h.count},
            }

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()

    def merge_snapshot(self, snap: dict) -> None:
        """Fold another host's snapshot into this registry (counters sum,
        gauges take the max — peak semantics — histograms merge moments)."""
        for k, v in (snap.get("counters") or {}).items():
            self.counter(k).inc(v)
        for k, v in (snap.get("gauges") or {}).items():
            g = self.gauge(k)
            g.set(max(g.value, v))
        for k, v in (snap.get("histograms") or {}).items():
            h = self.histogram(k)
            with self._lock:
                h.count += int(v.get("count", 0))
                h.total += float(v.get("total", 0.0))
                h.min = min(h.min, float(v.get("min", h.min)))
                h.max = max(h.max, float(v.get("max", h.max)))


REGISTRY = MetricsRegistry()


def inc(name: str, v: float = 1.0) -> None:
    """Increment a counter (no-op while metrics are disabled)."""
    if _active:
        REGISTRY.counter(name).inc(v)


def gauge_set(name: str, v: float) -> None:
    if _active:
        REGISTRY.gauge(name).set(v)


def observe(name: str, v: float) -> None:
    if _active:
        REGISTRY.histogram(name).observe(v)


def value(name: str, default: float = 0.0) -> float:
    return REGISTRY.value(name, default)


def gauge_value(name: str, default: float = 0.0) -> float:
    return REGISTRY.gauge_value(name, default)


def snapshot() -> dict:
    return REGISTRY.snapshot()


def reset() -> None:
    REGISTRY.reset()


def counter_delta(before: dict, after: Optional[dict] = None) -> dict:
    """after.counters - before.counters (after defaults to a fresh
    snapshot) — the benchmark harness stamps this per suite."""
    after = snapshot() if after is None else after
    b = before.get("counters") or {}
    return {k: v - b.get(k, 0.0)
            for k, v in (after.get("counters") or {}).items()
            if v != b.get(k, 0.0)}


def merge_snapshots(snaps: Iterable[dict]) -> dict:
    """Pure psum-free host-side merge of per-host snapshots: counters
    sum, gauges max (peak semantics), histogram moments combine. Returns
    one snapshot dict of the same shape."""
    merged = MetricsRegistry()
    for s in snaps:
        merged.merge_snapshot(s)
    return merged.snapshot()
