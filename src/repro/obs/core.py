"""Span core: context-var span stack → Chrome trace_event buffer.

Zero-dependency tracing for the execution layers. OFF by default with
near-zero overhead: while disabled, `span()` returns one shared no-op
context manager — no dict, no object, no event is allocated on the hot
path (the scheduler's chunk loop runs through here).

When enabled, every completed span is buffered as a Chrome/Perfetto
`trace_event` dict (`ph: "X"`, microsecond ts/dur) with its nesting depth
and parent recorded from a contextvar span stack, so `obs.trace.export`
writes a file chrome://tracing and Perfetto load directly. When
`jax.profiler.TraceAnnotation` is importable, each span also enters an
annotation of the same name so spans line up with XLA profiler traces.
"""

from __future__ import annotations

import contextlib
import contextvars
import os
import threading
import time
from typing import Optional

# Module-level fast flags: checked on every span()/inc() call, so they are
# plain bools rather than attribute lookups through a config object.
_trace_on = False
_metrics_on = False

_events: list = []                 # completed spans (trace_event dicts)
_events_lock = threading.Lock()
_t0_ns = time.perf_counter_ns()    # trace epoch (ts are relative to this)

# Span-buffer ring cap: a long-running server traces indefinitely, so the
# buffer keeps only the most recent `_max_events` COMPLETE spans (oldest
# dropped first; drops are counted). $REPRO_OBS_MAX_EVENTS overrides the
# default; set_buffer_cap() adjusts at runtime (0/None = unbounded).
_max_events: Optional[int] = int(
    os.environ.get("REPRO_OBS_MAX_EVENTS", "100000")) or None
_dropped_events = 0


def set_buffer_cap(n: Optional[int]) -> None:
    """Cap the completed-span ring buffer at `n` events (None or 0 =
    unbounded). Shrinking below the current buffer length drops the
    oldest spans immediately."""
    global _max_events
    with _events_lock:
        _max_events = int(n) if n else None
        _trim_events_locked()


def buffer_cap() -> Optional[int]:
    return _max_events


def dropped_events() -> int:
    """Spans dropped by the ring cap since the last clear()."""
    return _dropped_events


def _trim_events_locked() -> None:
    global _dropped_events
    if _max_events is not None and len(_events) > _max_events:
        overflow = len(_events) - _max_events
        del _events[:overflow]
        _dropped_events += overflow

_stack: contextvars.ContextVar = contextvars.ContextVar(
    "repro_obs_span_stack", default=())

_TraceAnnotation = None            # resolved lazily at first enable()


class _NoopSpan:
    """Shared do-nothing span for disabled mode (allocation-free)."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NOOP = _NoopSpan()


class _Span:
    __slots__ = ("name", "attrs", "_start_ns", "_token", "_ann")

    def __init__(self, name: str, attrs: Optional[dict]):
        self.name = name
        self.attrs = attrs

    def __enter__(self):
        self._token = _stack.set(_stack.get() + (self.name,))
        self._ann = None
        if _TraceAnnotation is not None:
            try:
                self._ann = _TraceAnnotation(self.name)
                self._ann.__enter__()
            except Exception:       # annotation is best-effort decoration
                self._ann = None
        self._start_ns = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        end_ns = time.perf_counter_ns()
        if self._ann is not None:
            self._ann.__exit__(*exc)
        stack = _stack.get()
        _stack.reset(self._token)
        args = {"depth": len(stack) - 1}
        if len(stack) > 1:
            args["parent"] = stack[-2]
        if self.attrs:
            args.update(self.attrs)
        ev = {
            "name": self.name,
            "cat": "repro",
            "ph": "X",
            "ts": (self._start_ns - _t0_ns) / 1e3,   # microseconds
            "dur": (end_ns - self._start_ns) / 1e3,
            "pid": os.getpid(),
            "tid": threading.get_ident(),
            "args": args,
        }
        with _events_lock:
            _events.append(ev)
            _trim_events_locked()
        return False


def span(name: str, attrs: Optional[dict] = None):
    """Context manager timing one stage.

    attrs: optional dict recorded into the trace event's `args` (e.g.
    `{"predicted_bytes": ...}` feeds the predicted-vs-measured report).
    While tracing is disabled this returns a shared no-op object — hot
    call sites (per-chunk loops) pay one bool check and nothing else.
    """
    if not _trace_on:
        return _NOOP
    return _Span(name, attrs)


def enable(*, trace: bool = True, metrics: bool = True) -> None:
    """Turn telemetry on (idempotent). Installs the jax.monitoring
    compile-event listener the first time metrics are enabled."""
    global _trace_on, _metrics_on, _TraceAnnotation
    _trace_on = bool(trace)
    _metrics_on = bool(metrics)
    if _trace_on and _TraceAnnotation is None:
        try:
            from jax.profiler import TraceAnnotation as _TA
            _TraceAnnotation = _TA
        except Exception:           # jax without profiler: spans still work
            pass
    if _metrics_on:
        from repro.obs import jaxhooks, metrics as _metrics
        _metrics.set_active(True)
        jaxhooks.install()
    _sync_metrics_flag()


def disable() -> None:
    """Turn telemetry off (buffers/counters are kept; see trace.clear /
    metrics.reset)."""
    global _trace_on, _metrics_on
    _trace_on = False
    _metrics_on = False
    _sync_metrics_flag()


def _sync_metrics_flag() -> None:
    from repro.obs import metrics as _metrics
    _metrics.set_active(_metrics_on)


def trace_enabled() -> bool:
    return _trace_on


def metrics_enabled() -> bool:
    return _metrics_on


def enabled() -> bool:
    return _trace_on or _metrics_on


@contextlib.contextmanager
def session(export_path: Optional[str] = None, *, metrics: bool = True):
    """Scoped telemetry: enable for the body, restore the previous state
    after, exporting the trace buffer to `export_path` when given
    (`pipeline(..., trace="out.json")` routes through here)."""
    prev = (_trace_on, _metrics_on)
    enable(trace=True, metrics=metrics)
    try:
        yield
    finally:
        if export_path:
            from repro.obs import trace as _trace
            _trace.export(export_path)
        if prev == (False, False):
            disable()
        else:
            enable(trace=prev[0], metrics=prev[1])


def maybe_block(x):
    """Device sync point: block_until_ready(x) only while tracing, so
    span wall-times measure completed device work without perturbing the
    untraced async dispatch pipeline. Returns x."""
    if _trace_on and x is not None:
        import jax
        jax.block_until_ready(x)
    return x


def device_sync(x, name: str = "sync"):
    """Explicit named sync point: while tracing, a `sync.<name>` span
    records how long the host waited for the device. No-op (and no
    blocking) when disabled."""
    if not _trace_on:
        return x
    import jax
    with span(f"sync.{name}"):
        jax.block_until_ready(x)
    return x


def emit_complete(name: str, start_ns: int, end_ns: int,
                  attrs: Optional[dict] = None) -> None:
    """Append a complete (`ph: "X"`) trace event with caller-supplied
    wall-clock bounds (perf_counter_ns values).

    Batched serving uses this to record one `serve.step` event per
    request in a coalesced dispatch: the requests overlap in time, so
    they cannot be expressed as nested `span()` context managers on the
    contextvar stack. No-op while tracing is disabled.
    """
    if not _trace_on:
        return
    ev = {
        "name": name,
        "cat": "repro",
        "ph": "X",
        "ts": (int(start_ns) - _t0_ns) / 1e3,   # microseconds
        "dur": max(0, int(end_ns) - int(start_ns)) / 1e3,
        "pid": os.getpid(),
        "tid": threading.get_ident(),
        "args": dict(attrs) if attrs else {},
    }
    with _events_lock:
        _events.append(ev)
        _trim_events_locked()


def events() -> list:
    """Snapshot of the completed-span buffer (trace_event dicts)."""
    with _events_lock:
        return list(_events)


def clear() -> None:
    global _dropped_events
    with _events_lock:
        _events.clear()
        _dropped_events = 0
