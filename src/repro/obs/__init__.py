"""obs/ — zero-dependency tracing + metrics for the execution layers.

- `span("stage1.braycurtis")` — contextvar-stacked wall-time spans,
  exported as Chrome/Perfetto trace_event JSON (`obs.trace.export`).
- `metrics` — process-wide counters/gauges/histograms: jit retraces
  (via jax.monitoring), autotune cache hits, predicted traffic bytes,
  permutation chunks, device peak memory.
- `report()` — predicted-vs-measured reconciliation table pairing the
  registry traffic models with measured span times.

Everything is OFF by default; the disabled hot path is one bool check
returning a shared no-op span.
"""

from repro.obs import core, jaxhooks, metrics, trace
from repro.obs.core import (
    buffer_cap,
    clear,
    device_sync,
    disable,
    dropped_events,
    emit_complete,
    enable,
    enabled,
    events,
    maybe_block,
    metrics_enabled,
    session,
    set_buffer_cap,
    span,
    trace_enabled,
)
from repro.obs.jaxhooks import record_device_memory
from repro.obs.report import budget_violations, report, stage_rows

__all__ = [
    "core", "jaxhooks", "metrics", "trace",
    "span", "enable", "disable", "enabled", "session",
    "trace_enabled", "metrics_enabled", "events", "clear",
    "set_buffer_cap", "buffer_cap", "dropped_events", "emit_complete",
    "maybe_block", "device_sync", "record_device_memory",
    "report", "stage_rows", "budget_violations",
]
