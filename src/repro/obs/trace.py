"""Trace-buffer consumers: Chrome/Perfetto export + flat per-stage table.

`export(path)` writes the span buffer in the `trace_event` JSON format
(chrome://tracing and https://ui.perfetto.dev open it directly);
`stage_table()` collapses the same buffer into one row per span name —
the flat view obs.report() reconciles against the predicted-bytes models.
"""

from __future__ import annotations

import json
import os
from typing import Optional

from repro.obs import core as _core

events = _core.events
clear = _core.clear


def export(path: str, *, extra_metadata: Optional[dict] = None) -> str:
    """Write the span buffer as Chrome trace_event JSON; returns `path`."""
    payload = {
        "traceEvents": _core.events(),
        "displayTimeUnit": "ms",
        "otherData": {"source": "repro.obs", **(extra_metadata or {})},
    }
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def stage_table() -> dict:
    """Aggregate completed spans by name.

    Returns {name: {"calls", "total_s", "mean_s", "predicted_bytes"}} —
    predicted_bytes summed from span attrs (0.0 for spans whose call
    sites attach no traffic model).
    """
    table: dict = {}
    for ev in _core.events():
        if ev.get("ph") != "X":
            continue
        row = table.setdefault(ev["name"], {
            "calls": 0, "total_s": 0.0, "predicted_bytes": 0.0})
        row["calls"] += 1
        row["total_s"] += ev.get("dur", 0.0) / 1e6
        row["predicted_bytes"] += float(
            (ev.get("args") or {}).get("predicted_bytes", 0.0))
    for row in table.values():
        row["mean_s"] = row["total_s"] / row["calls"]
    return table
