"""Predicted-vs-measured reconciliation report.

The planner's workset/traffic models (pipeline.registry, the engine's
per-impl mat2 traffic) predict how many bytes each stage should move;
the span buffer records how long each stage actually took. `report()`
pairs the two — predicted bytes / measured wall-time = achieved GB/s —
and flags stages whose achieved bandwidth falls below a configurable
fraction of a reference bandwidth (the paper's MI300A STREAM-triad
numbers, the v5e HBM roof on TPU, or $REPRO_OBS_PEAK_GBPS / the
`peak_gbps=` argument). This is the measured counterpart of
roofline/report.py's model-only tables, rendered through the same
markdown table helper.
"""

from __future__ import annotations

import fnmatch
import os
import sys
from typing import Dict, Optional

from repro.obs import metrics as _metrics
from repro.obs import trace as _trace

PEAK_GBPS_ENV = "REPRO_OBS_PEAK_GBPS"


def budget_violations(budgets: Dict[str, float]) -> list:
    """Check traced span totals against a wall-clock SLO budget table.

    budgets maps an fnmatch pattern over span NAMES (e.g. 'stage1.*',
    'fusedk.chunk') to the maximum TOTAL seconds all matching spans may
    have spent together. Returns one dict per violated entry — empty
    list = every budget held. A pattern matching no spans is not a
    violation (the stage may legitimately not have run)."""
    table = _trace.stage_table()
    out = []
    for pattern, limit_s in budgets.items():
        names = [n for n in table if fnmatch.fnmatch(n, pattern)]
        if not names:
            continue
        total = sum(table[n]["total_s"] for n in names)
        if total > float(limit_s):
            out.append({
                "pattern": pattern,
                "budget_s": float(limit_s),
                "measured_s": total,
                "stages": sorted(names),
            })
    out.sort(key=lambda v: -(v["measured_s"] - v["budget_s"]))
    return out


def reference_gbps(backend: Optional[str] = None) -> float:
    """Reference bandwidth (GB/s) for the below-fraction flag: the env
    override when set, else the paper's number for the backend family."""
    override = os.environ.get(PEAK_GBPS_ENV)
    if override:
        return float(override)
    from repro import hw
    if backend is None:
        try:
            import jax
            backend = jax.default_backend()
        except Exception:
            backend = "cpu"
    if backend == "tpu":
        return hw.TPU_V5E.hbm_bandwidth / 1e9
    if backend == "gpu":
        return hw.MI300A_GPU_STREAM_TRIAD / 1e9
    return hw.MI300A_CPU_STREAM_TRIAD / 1e9


def stage_rows(*, peak_gbps: Optional[float] = None,
               flag_fraction: float = 0.5,
               backend: Optional[str] = None) -> list:
    """One dict per span name carrying a predicted-bytes attr: predicted
    MiB, measured seconds, achieved GB/s, fraction of the reference, and
    the below-fraction flag. Sorted by measured time, slowest first."""
    ref = peak_gbps if peak_gbps is not None else reference_gbps(backend)
    rows = []
    for name, agg in _trace.stage_table().items():
        if agg["predicted_bytes"] <= 0.0:
            continue
        gbps = (agg["predicted_bytes"] / agg["total_s"] / 1e9
                if agg["total_s"] > 0 else 0.0)
        frac = gbps / ref if ref > 0 else 0.0
        rows.append({
            "stage": name,
            "calls": agg["calls"],
            "predicted_mib": agg["predicted_bytes"] / 2**20,
            "measured_s": agg["total_s"],
            "achieved_gbps": gbps,
            "ref_fraction": frac,
            "flagged": frac < flag_fraction,
        })
    rows.sort(key=lambda r: -r["measured_s"])
    return rows


def report(*, peak_gbps: Optional[float] = None, flag_fraction: float = 0.5,
           backend: Optional[str] = None,
           budgets: Optional[Dict[str, float]] = None,
           file=sys.stdout) -> str:
    """Render (and print, unless file=None) the per-stage
    predicted-vs-measured table plus the counter/gauge snapshot.

    budgets: optional SLO table (fnmatch span pattern -> max total
    seconds, see budget_violations) — appends a budget-status section,
    flagging every entry over its limit."""
    from repro.roofline.report import render_table
    ref = peak_gbps if peak_gbps is not None else reference_gbps(backend)
    rows = stage_rows(peak_gbps=ref, flag_fraction=flag_fraction,
                      backend=backend)
    lines = [f"predicted-vs-measured per stage "
             f"(reference {ref:.1f} GB/s, flag below "
             f"{flag_fraction:.0%} of it):"]
    if rows:
        lines.append(render_table(
            ["stage", "calls", "pred MiB", "measured s", "GB/s",
             "of ref", "flag"],
            [[r["stage"], str(r["calls"]), f"{r['predicted_mib']:.2f}",
              f"{r['measured_s']:.4f}", f"{r['achieved_gbps']:.2f}",
              f"{r['ref_fraction']:.1%}",
              "BELOW" if r["flagged"] else ""] for r in rows]))
    else:
        lines.append("  (no traced stages carry a traffic model — run "
                     "with tracing enabled)")

    # untimed spans (no traffic model) still show wall-time
    other = [(n, a) for n, a in sorted(_trace.stage_table().items())
             if a["predicted_bytes"] <= 0.0]
    if other:
        lines.append("")
        lines.append(render_table(
            ["stage (no traffic model)", "calls", "measured s"],
            [[n, str(a["calls"]), f"{a['total_s']:.4f}"]
             for n, a in other]))

    if budgets:
        viol = budget_violations(budgets)
        bad = {v["pattern"]: v for v in viol}
        table = _trace.stage_table()
        lines.append("")
        lines.append("wall-clock SLO budgets:")
        for pattern, limit_s in sorted(budgets.items()):
            names = [n for n in table if fnmatch.fnmatch(n, pattern)]
            total = sum(table[n]["total_s"] for n in names)
            status = ("OVER" if pattern in bad
                      else ("ok" if names else "not run"))
            lines.append(f"  {pattern}: {total:.4f}s of {limit_s:g}s "
                         f"budget [{status}]")

    snap = _metrics.snapshot()
    if snap["counters"] or snap["gauges"] or snap["histograms"]:
        lines.append("")
        lines.append("counters:")
        for k, v in snap["counters"].items():
            lines.append(f"  {k} = {v:g}")
        for k, v in snap["gauges"].items():
            lines.append(f"  {k} = {v:g} (gauge)")
        for k, h in snap["histograms"].items():
            lines.append(f"  {k}: n={h['count']} "
                         f"mean={h['total']/max(h['count'],1):.4g} "
                         f"max={h['max']:.4g}")
    text = "\n".join(lines)
    if file is not None:
        print(text, file=file)
    return text
