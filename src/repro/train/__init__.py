from repro.train.step import (  # noqa: F401
    TrainState,
    make_train_step,
    make_train_state_init,
    default_optimizer_for,
)
