"""Training step: loss -> grad -> clip -> optimizer, with optional
microbatch gradient accumulation (scan over microbatches; one weight update
per global batch — the standard way to fit the assigned global_batch=256 x
4k-seq cells in HBM).

The step is pjit-compiled by launch/train.py and launch/dryrun.py with
in/out shardings derived from param logical axes (sharding/rules.py).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.optim import optimizers as _opt
from repro.utils.tree import tree_count

Array = jax.Array


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: Array

    def tree_flatten(self):  # pragma: no cover - registered below
        return (self.params, self.opt_state, self.step), None


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.params, s.opt_state, s.step), None),
    lambda _, c: TrainState(params=c[0], opt_state=c[1], step=c[2]),
)


def default_optimizer_for(cfg) -> _opt.Optimizer:
    """AdamW below ~10B params; Adafactor above (state must fit HBM)."""
    big = cfg.n_layers * cfg.d_model * cfg.d_model > 40e9 or \
        (cfg.moe_n_experts > 0 and cfg.d_model >= 4096)
    return _opt.adafactor() if big else _opt.adamw()


def make_train_state_init(model, optimizer: _opt.Optimizer):
    def init(key):
        params = model.init(key)
        return TrainState(params=params,
                          opt_state=optimizer.init(params),
                          step=jnp.zeros((), jnp.int32))
    return init


def _split_microbatches(batch, n_micro: int):
    def split(x):
        b = x.shape[0]
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])
    return jax.tree.map(split, batch)


def make_train_step(model, optimizer: _opt.Optimizer, *,
                    schedule: Optional[Callable] = None,
                    grad_clip: float = 1.0,
                    n_microbatches: int = 1,
                    accum_dtype=jnp.float32):
    """Returns train_step(state, batch) -> (state, metrics)."""
    if schedule is None:
        schedule = lambda step: jnp.asarray(3e-4, jnp.float32)

    def loss_fn(params, micro):
        loss, metrics = model.loss(params, micro)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch):
        if n_microbatches > 1:
            micros = _split_microbatches(batch, n_microbatches)

            def accum(carry, micro):
                gsum, lsum = carry
                (loss, _), grads = grad_fn(state.params, micro)
                gsum = jax.tree.map(
                    lambda a, g: a + g.astype(accum_dtype), gsum, grads)
                return (gsum, lsum + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), state.params)
            (gsum, lsum), _ = jax.lax.scan(
                accum, (zeros, jnp.zeros((), jnp.float32)), micros)
            grads = jax.tree.map(lambda g: g / n_microbatches, gsum)
            loss = lsum / n_microbatches
        else:
            (loss, _), grads = grad_fn(state.params, batch)

        grads, gnorm = _opt.clip_by_global_norm(grads, grad_clip)
        lr = schedule(state.step)
        updates, opt_state = optimizer.update(grads, state.opt_state,
                                              state.params, lr)
        params = _opt.apply_updates(state.params, updates)
        new_state = TrainState(params=params, opt_state=opt_state,
                               step=state.step + 1)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return new_state, metrics

    return train_step
