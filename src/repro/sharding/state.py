"""Sharding trees for TrainState (params + optimizer state) and caches.

Optimizer-state axes derive structurally from param axes:
  adamw:     mu/nu mirror params
  adafactor: vr drops the last dim's axis; vc drops the second-to-last
  sgdm:      m mirrors params
so FSDP/TP sharding of a param automatically ZeRO-shards its state.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding.rules import (ShardingRules, logical_to_spec,
                                  rules_for_mesh)
from repro.train.step import TrainState

_IS_AXES = lambda x: isinstance(x, tuple)


def optimizer_state_axes(opt_name: str, param_axes, params_abs):
    if opt_name == "adamw":
        return {"mu": param_axes, "nu": param_axes, "count": ()}
    if opt_name == "sgdm":
        return {"m": param_axes}
    if opt_name == "adafactor":
        def one(axes, p):
            if p.ndim >= 2:
                return {"vr": axes[:-1], "vc": axes[:-2] + axes[-1:]}
            return {"v": axes}

        return {"f": jax.tree.map(one, param_axes, params_abs,
                                  is_leaf=_IS_AXES),
                "count": ()}
    raise ValueError(f"unknown optimizer {opt_name!r}")


def train_state_axes(model, optimizer, state_abs: TrainState):
    param_axes = model.param_axes()
    opt_axes = optimizer_state_axes(optimizer.name, param_axes,
                                    state_abs.params)
    return TrainState(params=param_axes, opt_state=opt_axes, step=())


def axes_to_shardings(axes_tree, abs_tree, mesh: Mesh,
                      rules: ShardingRules | None = None):
    rules = rules or rules_for_mesh(mesh)

    def one(axes, arr):
        spec = logical_to_spec(axes, arr.shape, mesh, rules)
        return NamedSharding(mesh, spec)

    return jax.tree.map(one, axes_tree, abs_tree, is_leaf=_IS_AXES)


def batch_axes(batch_abs):
    """Input-batch logical axes: leading dim is always the global batch."""
    def one(x):
        return ("batch",) + (None,) * (x.ndim - 1)
    return jax.tree.map(one, batch_abs)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
