from repro.sharding.rules import (  # noqa: F401
    ShardingRules,
    RULES_SINGLE_POD,
    RULES_MULTI_POD,
    rules_for_mesh,
    logical_to_spec,
    param_shardings,
    shard_activation,
    set_active,
    get_active,
    no_sharding,
)
