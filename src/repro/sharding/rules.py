"""Logical-axis -> mesh-axis sharding rules.

Strategy (DESIGN.md section 4): FSDP + TP within a pod, pure DP across pods.

  tensor-parallel axes ("vocab", "heads", "kv", "mlp") -> "model"
  FSDP axis ("embed": the d_model dim of weight matrices) -> "data"
  batch -> ("pod", "data")  [pod only when present in the mesh]
  "layers" (scan dim), "expert" and small params -> replicated

A logical axis is silently replicated when the assigned mesh axis size does
not divide the dimension (e.g. kv_heads*d_head=1024 shards 16-way, but a
G=60 expert dim does not; GSPMD handles the rest). Activation constraints go
through shard_activation() which no-ops outside an active mesh context, so
model code runs unchanged in single-device smoke tests.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    rules: dict                     # logical axis -> mesh axis | tuple | None

    def mesh_axes(self, logical: Optional[str], mesh: Mesh):
        if logical is None:
            return None
        ax = self.rules.get(logical)
        if ax is None:
            return None
        axes = ax if isinstance(ax, tuple) else (ax,)
        axes = tuple(a for a in axes if a in mesh.axis_names)
        if not axes:
            return None
        return axes if len(axes) > 1 else axes[0]


RULES_SINGLE_POD = ShardingRules(rules={
    "vocab": "model",
    "heads": "model",
    "kv": "model",
    "mlp": "model",
    "embed": "data",      # FSDP
    "expert": None,       # expert dim replicated; TP inside the expert
    "layers": None,
    "batch": ("data",),
    "moe_capacity": ("data",),  # MoE (E,C,D) buffers: shard capacity like batch
    "act_embed": None,
    "act_heads": "model",
    "act_mlp": "model",
    "act_vocab": "model",
    "seq": None,
    # Megatron-style sequence parallelism: the residual stream between
    # blocks is sharded over 'model' along seq (16x smaller saved
    # activations under remat); attention/MLP interiors re-gather.
    "act_seq": "model",
    # decode KV caches: shard the cache SEQUENCE over 'model' (partial
    # attention + reduction instead of per-step cache all-gathers)
    "kv_seq": "model",
})

RULES_MULTI_POD = ShardingRules(rules={
    **RULES_SINGLE_POD.rules,
    "batch": ("pod", "data"),   # DP across pods; FSDP stays intra-pod
    "moe_capacity": ("pod", "data"),
})


def rules_for_mesh(mesh: Mesh) -> ShardingRules:
    return RULES_MULTI_POD if "pod" in mesh.axis_names else RULES_SINGLE_POD


def _dim_ways(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    out = 1
    for a in axes:
        out *= mesh.shape[a]
    return out


def logical_to_spec(axes_tuple, shape, mesh: Mesh,
                    rules: Optional[ShardingRules] = None) -> P:
    """PartitionSpec for one array given its logical axes + shape.

    Drops any assignment whose mesh-axis product does not divide the dim.
    """
    rules = rules or rules_for_mesh(mesh)
    entries = []
    for dim, logical in zip(shape, axes_tuple):
        ax = rules.mesh_axes(logical, mesh)
        if ax is not None and dim % _dim_ways(mesh, ax) != 0:
            ax = None
        entries.append(ax)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def param_shardings(axes_tree, abstract_tree, mesh: Mesh,
                    rules: Optional[ShardingRules] = None):
    """NamedSharding tree for a param tree (axes tree mirrors it)."""
    rules = rules or rules_for_mesh(mesh)

    def one(axes, arr):
        spec = logical_to_spec(axes, arr.shape, mesh, rules)
        return NamedSharding(mesh, spec)

    return jax.tree.map(one, axes_tree, abstract_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


# ---------------------------------------------------------------------------
# Activation-constraint context (thread-local; no-op without a mesh)
# ---------------------------------------------------------------------------

class _Active(threading.local):
    mesh: Optional[Mesh] = None
    rules: Optional[ShardingRules] = None


_ACTIVE = _Active()


@contextlib.contextmanager
def set_active(mesh: Optional[Mesh], rules: Optional[ShardingRules] = None):
    prev = (_ACTIVE.mesh, _ACTIVE.rules)
    _ACTIVE.mesh = mesh
    _ACTIVE.rules = rules or (rules_for_mesh(mesh) if mesh else None)
    try:
        yield
    finally:
        _ACTIVE.mesh, _ACTIVE.rules = prev


@contextlib.contextmanager
def no_sharding():
    with set_active(None):
        yield


def get_active():
    return _ACTIVE.mesh, _ACTIVE.rules


def shard_activation(x, logical_axes_tuple):
    """with_sharding_constraint via logical axes; identity with no mesh."""
    mesh = _ACTIVE.mesh
    if mesh is None:
        return x
    spec = logical_to_spec(logical_axes_tuple, x.shape, mesh, _ACTIVE.rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
