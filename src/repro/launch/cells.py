"""Cell builder: one (architecture x input-shape x mesh) dry-run unit.

A *cell* bundles the step function to lower (train_step for train shapes,
prefill for prefill shapes, serve_step for decode shapes), ShapeDtypeStruct
stand-ins for every input (`input_specs`), and in/out shardings derived from
the logical-axis rules. launch/dryrun.py lowers and compiles cells;
roofline/ reads the compiled artifacts.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, ArchConfig, ShapeConfig, \
    shape_applicable
from repro.configs.registry import ARCHS, SMOKES
from repro.models.model import build_model
from repro.serve.engine import make_serve_step
from repro.sharding.rules import rules_for_mesh
from repro.sharding.state import (axes_to_shardings, batch_axes,
                                  train_state_axes)
from repro.train.step import (default_optimizer_for, make_train_state_init,
                              make_train_step)

WHISPER_DECODE_ENC_LEN = 1500   # realistic 30 s audio context


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str                   # train | prefill | decode
    fn: Any                     # callable to jit/lower
    args_abs: tuple             # abstract inputs (ShapeDtypeStructs)
    in_shardings: tuple
    out_shardings: Any          # pytree prefix or None (auto)
    n_microbatches: int = 1
    notes: str = ""
    donate_argnums: tuple = ()  # state/caches alias their outputs


def pick_microbatches(cfg: ArchConfig, shape: ShapeConfig,
                      data_ways: int = 16) -> int:
    """Gradient-accumulation depth so train activations fit 16 GB/chip."""
    if not shape.is_train:
        return 1
    if cfg.d_model >= 6144 or cfg.moe_n_experts >= 32:
        nm = 16
    elif cfg.d_model >= 4096:
        nm = 8
    else:
        nm = 4
    # microbatch rows must stay divisible by the batch-sharding ways
    # (data, x pod when present): a smaller micro drops batch sharding
    # and REPLICATES activations per device
    return min(nm, max(shape.global_batch // data_ways, 1))


def input_specs(arch_name: str, shape_name: str, *, smoke: bool = False):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    cfg = (SMOKES if smoke else ARCHS)[arch_name]
    shape = SHAPES[shape_name]
    b, s = shape.global_batch, shape.seq_len
    if smoke:
        b, s = min(b, 4), min(s, 64)
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        if cfg.family == "encdec":
            return {
                "frames": jax.ShapeDtypeStruct((b, min(s, cfg.max_enc_len),
                                                cfg.d_model), cfg.jnp_dtype),
                "tokens": jax.ShapeDtypeStruct((b, s), i32),
                "targets": jax.ShapeDtypeStruct((b, s), i32),
            }
        if cfg.family == "vlm":
            s_text = s - cfg.n_vision_tokens
            return {
                "vision_embeds": jax.ShapeDtypeStruct(
                    (b, cfg.n_vision_tokens, cfg.d_model), cfg.jnp_dtype),
                "tokens": jax.ShapeDtypeStruct((b, s_text), i32),
                "targets": jax.ShapeDtypeStruct((b, s_text), i32),
            }
        return {"tokens": jax.ShapeDtypeStruct((b, s), i32),
                "targets": jax.ShapeDtypeStruct((b, s), i32)}
    # decode: one new token against a seq_len cache
    model = build_model(cfg)
    kw = {}
    if cfg.family == "encdec":
        kw["enc_len"] = min(WHISPER_DECODE_ENC_LEN, cfg.max_enc_len)
    caches = model.init_caches(batch=b, max_len=s, abstract=True, **kw)
    return {
        "token": jax.ShapeDtypeStruct((b, 1), i32),
        "caches": caches,
        "cache_len": jax.ShapeDtypeStruct((), i32),
        "key_bits": jax.ShapeDtypeStruct((2,), jnp.uint32),
    }


def _cache_logical_axes(model, caches):
    cfg = model.cfg

    def kv(tree):
        # decode caches: SEQUENCE-sharded over 'model' (partial attention
        # + reduce beats per-step cache all-gathers; kv lanes replicated)
        return jax.tree.map(lambda x: ("layers", "batch", "kv_seq", None),
                            tree)

    if cfg.family in ("dense", "moe", "vlm"):
        return kv(caches)
    if cfg.family == "encdec":
        return {"self": kv(caches["self"]), "cross": kv(caches["cross"])}
    if cfg.family == "hybrid":
        return {
            "mamba": {"conv": ("layers", "batch", None, "mlp"),
                      "ssm": ("layers", "batch", "heads", None, None)},
            "shared": kv(caches["shared"]),
        }
    if cfg.family == "xlstm":
        out = {}
        if "mlstm" in caches:
            out["mlstm"] = {
                "c": ("layers", "layers", "batch", "heads", None, None),
                "n": ("layers", "layers", "batch", "heads", None),
                "m": ("layers", "layers", "batch", "heads"),
                "conv": ("layers", "layers", "batch", None, "mlp"),
            }
            out["slstm"] = {k: ("layers", "batch", None)
                            for k in ("c", "n", "h", "m")}
        if "mlstm_tail" in caches:
            out["mlstm_tail"] = {
                "c": ("layers", "batch", "heads", None, None),
                "n": ("layers", "batch", "heads", None),
                "m": ("layers", "batch", "heads"),
                "conv": ("layers", "batch", None, "mlp"),
            }
        return out
    raise ValueError(cfg.family)


def build_cell(arch_name: str, shape_name: str, mesh: Mesh, *,
               smoke: bool = False) -> Optional[Cell]:
    cfg = (SMOKES if smoke else ARCHS)[arch_name]
    shape = SHAPES[shape_name]
    runs, reason = shape_applicable(cfg, shape)
    if not runs:
        return Cell(arch=arch_name, shape=shape_name, kind="skip",
                    fn=None, args_abs=(), in_shardings=(),
                    out_shardings=None, notes=f"SKIP: {reason}")
    rules = rules_for_mesh(mesh)
    model = build_model(cfg)
    specs = input_specs(arch_name, shape_name, smoke=smoke)
    repl = NamedSharding(mesh, P())

    if shape.kind in ("train", "prefill"):
        batch_abs = specs
        batch_sh = axes_to_shardings(batch_axes(batch_abs), batch_abs,
                                     mesh, rules)
        if shape.kind == "train":
            data_ways = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
            nm = pick_microbatches(cfg, shape, data_ways=data_ways)
            if smoke:
                nm = 1
            opt = default_optimizer_for(cfg)
            accum_dtype = {"float32": jnp.float32,
                           "bfloat16": jnp.bfloat16}[cfg.grad_accum_dtype]
            step = make_train_step(model, opt, n_microbatches=nm,
                                   accum_dtype=accum_dtype)
            init = make_train_state_init(model, opt)
            state_abs = jax.eval_shape(init, jax.random.key(0))
            state_axes = train_state_axes(model, opt, state_abs)
            state_sh = axes_to_shardings(state_axes, state_abs, mesh, rules)
            return Cell(arch=arch_name, shape=shape_name, kind="train",
                        fn=step, args_abs=(state_abs, batch_abs),
                        in_shardings=(state_sh, batch_sh),
                        out_shardings=(state_sh, repl),
                        n_microbatches=nm,
                        notes=f"optimizer={opt.name} microbatches={nm}",
                        donate_argnums=(0,))
        # prefill
        params_abs = model.abstract_params()
        param_sh = axes_to_shardings(model.param_axes(), params_abs, mesh,
                                     rules)
        max_len = shape.seq_len

        def prefill_fn(params, batch):
            return model.prefill(params, batch, max_len=max_len)

        logits_abs, caches_abs = jax.eval_shape(prefill_fn, params_abs,
                                                batch_abs)
        cache_sh = axes_to_shardings(
            _cache_logical_axes(model, caches_abs), caches_abs, mesh, rules)
        from repro.sharding.rules import logical_to_spec
        logits_sh = NamedSharding(mesh, logical_to_spec(
            ("batch", None, None), logits_abs.shape, mesh, rules))
        return Cell(arch=arch_name, shape=shape_name, kind="prefill",
                    fn=prefill_fn, args_abs=(params_abs, batch_abs),
                    in_shardings=(param_sh, batch_sh),
                    out_shardings=(logits_sh, cache_sh), notes="prefill")

    # decode
    params_abs = model.abstract_params()
    param_sh = axes_to_shardings(model.param_axes(), params_abs, mesh,
                                 rules)
    caches_abs = specs["caches"]
    cache_axes = _cache_logical_axes(model, caches_abs)
    cache_sh = axes_to_shardings(cache_axes, caches_abs, mesh, rules)
    serve = make_serve_step(model)
    token_sh = axes_to_shardings({"t": ("batch", None)},
                                 {"t": specs["token"]}, mesh, rules)["t"]
    return Cell(
        arch=arch_name, shape=shape_name, kind="decode",
        fn=serve,
        args_abs=(params_abs, specs["token"], caches_abs,
                  specs["cache_len"], specs["key_bits"]),
        in_shardings=(param_sh, token_sh, cache_sh, repl, repl),
        out_shardings=(token_sh, repl, cache_sh),
        notes="serve_step: 1 token vs seq_len cache",
        donate_argnums=(2,))
