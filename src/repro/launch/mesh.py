"""Device-mesh construction for the production topology.

Single pod:  (16, 16)      -> ("data", "model")   = 256 chips
Multi-pod:   (2, 16, 16)   -> ("pod", "data", "model") = 512 chips

Functions, never module-level constants — importing this module must not
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).

jax 0.4.x compat: `jax.sharding.AxisType` (and `jax.make_mesh`'s
`axis_types` kwarg) only exist on jax >= 0.5. Same pattern as the
shard_map shim in core/distributed.py: feature-detect once, degrade to the
plain mesh (every axis behaves as Auto there anyway).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5
    from jax.sharding import AxisType as _AxisType
except ImportError:  # pragma: no cover - version-dependent
    _AxisType = None


def make_mesh(shape, axes) -> Mesh:
    """Arbitrary mesh (tests, small hosts), Auto axis types where supported."""
    shape, axes = tuple(shape), tuple(axes)
    if _AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(_AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(*, model_ways: int = 1) -> Mesh:
    """Mesh over whatever devices exist locally (examples/benchmarks)."""
    n = len(jax.devices())
    data = max(n // model_ways, 1)
    return make_mesh((data, model_ways), ("data", "model"))
