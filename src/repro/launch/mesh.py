"""Device-mesh construction for the production topology.

Single pod:  (16, 16)      -> ("data", "model")   = 256 chips
Multi-pod:   (2, 16, 16)   -> ("pod", "data", "model") = 512 chips

Functions, never module-level constants — importing this module must not
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape, axes) -> Mesh:
    """Arbitrary mesh (tests, small hosts)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(*, model_ways: int = 1) -> Mesh:
    """Mesh over whatever devices exist locally (examples/benchmarks)."""
    n = len(jax.devices())
    data = max(n // model_ways, 1)
    return make_mesh((data, model_ways), ("data", "model"))
