"""Training launcher: config-driven, fault-tolerant, checkpointed.

Usage (CPU-host demo sizes; the same entry point drives the production
mesh when real devices exist):

  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
      --smoke --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt

--smoke selects the reduced config of the same family; otherwise the full
assigned config is used (needs a real cluster).
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs.registry import ARCHS, SMOKES
from repro.data.tokens import SyntheticTokenDataset
from repro.models.model import build_model
from repro.optim import adamw
from repro.runtime.trainer import FaultTolerantTrainer
from repro.train.step import (default_optimizer_for, make_train_state_init,
                              make_train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject one failure at this step (recovery demo)")
    args = ap.parse_args()

    cfg = (SMOKES if args.smoke else ARCHS)[args.arch]
    model = build_model(cfg)
    opt = adamw() if args.smoke else default_optimizer_for(cfg)
    from repro.optim import warmup_cosine
    schedule = warmup_cosine(peak=args.lr, warmup_steps=args.steps // 10 + 1,
                             total_steps=args.steps)
    step = jax.jit(make_train_step(model, opt, schedule=schedule,
                                   n_microbatches=args.microbatches))
    ds = SyntheticTokenDataset(vocab=cfg.vocab, seq_len=args.seq,
                               global_batch=args.batch, seed=args.seed)
    trainer = FaultTolerantTrainer(
        train_step=step,
        init_state=make_train_state_init(model, opt),
        dataset=ds, ckpt_dir=args.ckpt_dir,
        checkpoint_every=args.ckpt_every)

    t0 = time.time()
    report = trainer.run(n_steps=args.steps, seed=args.seed,
                         fail_at_step=args.fail_at)
    dt = time.time() - t0
    tok_s = report.steps_run * args.batch * args.seq / dt
    print(f"[train] arch={cfg.name} steps={report.final_step} "
          f"restarts={report.restarts} wall={dt:.1f}s tok/s={tok_s:.0f}")
    print(f"[train] loss: first={report.losses[0]:.4f} "
          f"last={report.losses[-1]:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
