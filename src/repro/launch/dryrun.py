import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes and record memory/cost/roofline artifacts.

  single pod : (16, 16)     ("data", "model")          = 256 chips
  multi-pod  : (2, 16, 16)  ("pod", "data", "model")   = 512 chips

The XLA_FLAGS line above MUST precede any jax import (device count locks at
first init). Only this entry point forces 512 host devices — tests and
benchmarks see the real device count.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2-1.8b \
      --shape train_4k --mesh both --out results/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""

import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax
import numpy as np

from repro import hw
from repro.configs.base import SHAPES
from repro.configs.registry import ARCHS, list_archs
from repro.launch.cells import build_cell, input_specs
from repro.launch.mesh import make_production_mesh
from repro.models.model import build_model
from repro.roofline.analysis import analyze_compiled, model_flops
from repro.sharding.rules import set_active


def _mem_dict(mem):
    return {
        "argument_size_in_bytes": mem.argument_size_in_bytes,
        "output_size_in_bytes": mem.output_size_in_bytes,
        "temp_size_in_bytes": mem.temp_size_in_bytes,
        "alias_size_in_bytes": mem.alias_size_in_bytes,
        "generated_code_size_in_bytes": mem.generated_code_size_in_bytes,
    }


def run_cell(arch: str, shape: str, *, multi_pod: bool, out_dir: pathlib.Path,
             verbose: bool = True) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    record = {"arch": arch, "shape": shape, "mesh": mesh_name,
              "chips": 512 if multi_pod else 256, "status": "?"}
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        cell = build_cell(arch, shape, mesh)
        if cell.kind == "skip":
            record.update(status="skip", notes=cell.notes)
            _write(out_dir, record)
            if verbose:
                print(f"[dryrun] {arch} x {shape} x {mesh_name}: "
                      f"SKIP ({cell.notes})")
            return record
        record["kind"] = cell.kind
        record["notes"] = cell.notes

        with set_active(mesh):
            jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                             out_shardings=cell.out_shardings,
                             donate_argnums=cell.donate_argnums)
            lowered = jitted.lower(*cell.args_abs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cfg = ARCHS[arch]
        sh = SHAPES[shape]
        tokens = (sh.global_batch * sh.seq_len
                  if cell.kind in ("train", "prefill")
                  else sh.global_batch)
        model = build_model(cfg)
        mf = model_flops(cfg, model.abstract_params(), model.param_axes(),
                         tokens=tokens,
                         kind="train" if cell.kind == "train"
                         else "inference")
        terms = analyze_compiled(compiled, chips=record["chips"],
                                 model_flops_total=mf)

        per_dev_hbm = (mem.argument_size_in_bytes
                       + mem.output_size_in_bytes
                       + mem.temp_size_in_bytes
                       - mem.alias_size_in_bytes)
        record.update(
            status="ok",
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory_analysis=_mem_dict(mem),
            per_device_hbm_bytes=int(per_dev_hbm),
            fits_hbm=bool(per_dev_hbm <= hw.TARGET.hbm_bytes),
            roofline=terms.as_dict(),
        )
        if verbose:
            print(f"[dryrun] {arch} x {shape} x {mesh_name}: OK "
                  f"(lower {t_lower:.1f}s compile {t_compile:.1f}s)")
            print(f"  memory_analysis: {mem}")
            print(f"  per-device HBM: {per_dev_hbm/2**30:.2f} GiB "
                  f"(fits 16 GiB: {record['fits_hbm']})")
            print(f"  cost: flops/dev={terms.flops:.3e} "
                  f"bytes/dev={terms.hbm_bytes:.3e} "
                  f"coll/dev={terms.collective_bytes:.3e}")
            print(f"  roofline: compute={terms.compute_s*1e3:.2f}ms "
                  f"memory={terms.memory_s*1e3:.2f}ms "
                  f"collective={terms.collective_s*1e3:.2f}ms "
                  f"-> dominant={terms.dominant} "
                  f"useful_flops_ratio={terms.useful_flops_ratio:.3f}")
    except Exception as e:  # noqa: BLE001 — record and continue
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
        if verbose:
            print(f"[dryrun] {arch} x {shape} x {mesh_name}: "
                  f"ERROR {type(e).__name__}: {e}")
    record["wall_s"] = round(time.time() - t0, 2)
    _write(out_dir, record)
    return record


def _write(out_dir: pathlib.Path, record: dict):
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / (f"{record['arch']}__{record['shape']}__"
                      f"{record['mesh']}.json")
    path.write_text(json.dumps(record, indent=1, default=str))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape id (default: all)")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    out_dir = pathlib.Path(args.out)

    n_ok = n_skip = n_err = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, multi_pod=mp, out_dir=out_dir)
                n_ok += rec["status"] == "ok"
                n_skip += rec["status"] == "skip"
                n_err += rec["status"] == "error"
    print(f"[dryrun] done: ok={n_ok} skip={n_skip} error={n_err}")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
