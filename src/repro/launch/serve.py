"""Serving launcher: batched decode with continuous batching.

  PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
      --smoke --requests 12 --batch 4 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.registry import ARCHS, SMOKES
from repro.models.model import build_model
from repro.serve.engine import Request, ServeLoop, temperature_sample


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = (SMOKES if args.smoke else ARCHS)[args.arch]
    if cfg.family == "encdec":
        raise SystemExit("use a decoder-only arch for the serve demo")
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    rng = np.random.default_rng(args.seed)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, size=(4,))
                    .astype(np.int32), max_new_tokens=args.max_new)
            for _ in range(args.requests)]
    loop = ServeLoop(model, params, batch_size=args.batch,
                     max_len=args.max_len,
                     sampler=temperature_sample(args.temperature))
    t0 = time.time()
    done = loop.run(reqs, max_steps=args.max_len * 4,
                    key=jax.random.key(args.seed))
    dt = time.time() - t0
    n_tok = sum(len(r.generated) for r in done)
    print(f"[serve] arch={cfg.name} requests={len(done)} "
          f"generated={n_tok} tok wall={dt:.1f}s tok/s={n_tok/dt:.1f}")
    for i, r in enumerate(done[:3]):
        print(f"  req{i}: prompt={r.prompt.tolist()} -> "
              f"{r.generated[:12]}{'...' if len(r.generated) > 12 else ''}")
    assert all(r.done for r in done), "unfinished requests"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
