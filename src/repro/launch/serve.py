"""Serving launchers.

  # always-on PERMANOVA service (chaos smoke: inject a worker death and
  # assert the served result is bit-identical to the failure-free run)
  PYTHONPATH=src python -m repro.launch.serve permanova \
      --studies 6 --workers 3 --inject-death --trace serve_trace.json

  # LM decode demo with continuous batching (legacy entry point; running
  # without a subcommand defaults here for backward compatibility)
  PYTHONPATH=src python -m repro.launch.serve lm --arch internlm2-1.8b \
      --smoke --requests 12 --batch 4 --max-new 16
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro import obs


def _lm_args(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--seed", type=int, default=0)


def _pa_args(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--studies", type=int, default=6,
                    help="number of synthetic studies to admit")
    ap.add_argument("--n-min", type=int, default=18)
    ap.add_argument("--n-max", type=int, default=40)
    ap.add_argument("--groups", type=int, default=3)
    ap.add_argument("--n-perms", type=int, default=199)
    ap.add_argument("--workers", type=int, default=3)
    ap.add_argument("--block", type=int, default=32)
    ap.add_argument("--queue-limit", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--inject-death", action="store_true",
                    help="replay the stream with a worker killed mid-"
                         "request and assert bit-identical results")
    ap.add_argument("--batch", type=int, default=0,
                    help="replay the stream with same-bucket requests "
                         "coalesced into batched dispatches of up to this "
                         "many studies; asserts bit-identity against the "
                         "serial run and zero warm retraces")
    ap.add_argument("--trace", default=None,
                    help="write a Chrome trace of the serve session")


def cmd_lm(args: argparse.Namespace) -> int:
    from repro.configs.registry import ARCHS, SMOKES
    from repro.models.model import build_model
    from repro.serve.engine import Request, ServeLoop, temperature_sample

    cfg = (SMOKES if args.smoke else ARCHS)[args.arch]
    if cfg.family == "encdec":
        raise SystemExit("use a decoder-only arch for the serve demo")
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    rng = np.random.default_rng(args.seed)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, size=(4,))
                    .astype(np.int32), max_new_tokens=args.max_new)
            for _ in range(args.requests)]
    loop = ServeLoop(model, params, batch_size=args.batch,
                     max_len=args.max_len,
                     sampler=temperature_sample(args.temperature))
    t0 = time.time()
    done = loop.run(reqs, max_steps=args.max_len * 4,
                    key=jax.random.key(args.seed))
    dt = time.time() - t0
    n_tok = sum(len(r.generated) for r in done)
    print(f"[serve] arch={cfg.name} requests={len(done)} "
          f"generated={n_tok} tok wall={dt:.1f}s tok/s={n_tok/dt:.1f}")
    for i, r in enumerate(done[:3]):
        print(f"  req{i}: prompt={r.prompt.tolist()} -> "
              f"{r.generated[:12]}{'...' if len(r.generated) > 12 else ''}")
    assert all(r.done for r in done), "unfinished requests"
    return 0


def _synth_stream(args: argparse.Namespace) -> list:
    from repro.core.distance import distance_matrix
    from repro.serve.permanova import StudyRequest

    rng = np.random.default_rng(args.seed)
    reqs = []
    for i in range(args.studies):
        n = int(rng.integers(args.n_min, args.n_max + 1))
        x = rng.normal(size=(n, 5)).astype(np.float32)
        g = rng.integers(0, args.groups, size=n).astype(np.int32)
        reqs.append(StudyRequest(
            grouping=g, dm=np.asarray(distance_matrix(x, "euclidean")),
            n_perms=args.n_perms, seed=i, request_id=f"study{i}"))
    return reqs


def _run_stream(args: argparse.Namespace, reqs, injector=None) -> list:
    from repro.serve.permanova import PermanovaServer

    srv = PermanovaServer(workers=args.workers, block=args.block,
                          queue_limit=args.queue_limit, injector=injector)
    return srv.serve(reqs)


def cmd_permanova(args: argparse.Namespace) -> int:
    from repro.runtime.faultinject import FaultInjector
    from repro.serve.permanova import serve_stats_from_events

    reqs = _synth_stream(args)
    with obs.session(args.trace):
        clean = _run_stream(args, reqs)
        stats = serve_stats_from_events(obs.events())
    bad = [r for r in clean if not r.ok]
    for r in clean:
        print(f"[serve.pa] {r.request_id}: status={r.status} "
              f"F={float(r.result.f_stat):.5f} "
              f"p={float(r.result.p_value):.4f} "
              f"bucket={r.bucket} wall={r.wall_s:.2f}s")
    print(f"[serve.pa] requests={stats['requests']} "
          f"rps={stats['requests_per_s']:.2f} "
          f"p50={stats['p50_s'] * 1e3:.1f}ms "
          f"p99={stats['p99_s'] * 1e3:.1f}ms")
    if bad:
        print(f"[serve.pa] FAILED requests: {[r.request_id for r in bad]}")
        return 1
    if args.trace:
        print(f"[serve.pa] trace written to {args.trace}")

    if args.batch:
        # batched smoke: same stream coalesced by shape bucket; the
        # per-request fold_in(key, global_index) draws make the batched
        # dispatch bit-identical to serial serving, and a second warm
        # replay must reuse every traced jaxpr (fixed batch composition)
        from repro.obs import jaxhooks
        from repro.serve.permanova import PermanovaServer

        with obs.session():
            srv = PermanovaServer(workers=args.workers, block=args.block,
                                  queue_limit=args.queue_limit,
                                  max_batch=args.batch)
            batched = srv.serve(reqs, batched=True, max_batch=args.batch)
            for c, b in zip(clean, batched):
                assert b.ok, f"{b.request_id} failed batched: {b.error}"
                assert float(c.result.f_stat) == float(b.result.f_stat), \
                    f"{c.request_id}: F diverged under batching"
                assert float(c.result.p_value) == float(b.result.p_value), \
                    f"{c.request_id}: p diverged under batching"
                assert np.array_equal(np.asarray(c.result.f_perms),
                                      np.asarray(b.result.f_perms)), \
                    f"{c.request_id}: permutation set diverged under batching"
            before = obs.metrics.value(jaxhooks.RETRACES, 0.0)
            warm = srv.serve(reqs, batched=True, max_batch=args.batch)
            after = obs.metrics.value(jaxhooks.RETRACES, 0.0)
            assert all(r.ok for r in warm)
            assert after == before, \
                f"warm batched replay retraced {after - before:.0f} jaxprs"
            n_b = obs.metrics.value("serve.batches", 0.0)
            n_br = obs.metrics.value("serve.batched_requests", 0.0)
        print(f"[serve.pa] batched: max_batch={args.batch} "
              f"batches={n_b:.0f} batched_requests={n_br:.0f} -> "
              f"bit-identical to serial, 0 warm retraces")

    if args.inject_death:
        # chaos smoke: kill worker 0 two blocks into the stream; the
        # idempotent-block contract (global-index key folding) must
        # reconverge to bit-identical statistics
        inj = FaultInjector(seed=args.seed)
        inj.kill_worker_after_blocks(0, 2)
        faulty = _run_stream(args, reqs, injector=inj)
        for c, f in zip(clean, faulty):
            assert f.ok, f"{f.request_id} failed under fault: {f.error}"
            assert float(c.result.f_stat) == float(f.result.f_stat), \
                f"{c.request_id}: F diverged under worker death"
            assert float(c.result.p_value) == float(f.result.p_value), \
                f"{c.request_id}: p diverged under worker death"
            assert np.array_equal(np.asarray(c.result.f_perms),
                                  np.asarray(f.result.f_perms)), \
                f"{c.request_id}: permutation set diverged"
        print(f"[serve.pa] chaos: worker death injected -> "
              f"{len(faulty)} requests bit-identical to the clean run "
              f"(F, p, permutation sets)")
    return 0


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    # backward compat: `python -m repro.launch.serve --smoke ...` predates
    # the subcommands and means the LM demo
    if not argv or argv[0] not in ("lm", "permanova", "-h", "--help"):
        argv.insert(0, "lm")
    ap = argparse.ArgumentParser(prog="repro.launch.serve")
    sub = ap.add_subparsers(dest="cmd", required=True)
    _lm_args(sub.add_parser("lm", help="LM decode demo"))
    _pa_args(sub.add_parser(
        "permanova", help="always-on PERMANOVA service smoke"))
    args = ap.parse_args(argv)
    return {"lm": cmd_lm, "permanova": cmd_permanova}[args.cmd](args)


if __name__ == "__main__":
    raise SystemExit(main())
