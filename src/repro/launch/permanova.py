"""PERMANOVA launcher — the paper's workload as a CLI, routed through the
hardware-aware execution engine.

  PYTHONPATH=src python -m repro.launch.permanova \
      --samples 512 --features 128 --groups 8 --perms 999 \
      --impl auto --metric braycurtis

  # 100k permutations in fixed-memory chunks (no (n_perms, n) label tensor):
  PYTHONPATH=src python -m repro.launch.permanova \
      --samples 512 --perms 100000 --impl auto --budget-mb 64

Scales from laptop smoke runs to the paper's EMP shape
(--samples 25145 --perms 3999) on a real mesh.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import engine
from repro.core.distance import distance_matrix, validate_distance_matrix
from repro.data.microbiome import synthetic_study

IMPL_CHOICES = ["auto", "brute", "tiled", "matmul",
                "pallas_brute", "pallas_permblock", "pallas_matmul"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--samples", type=int, default=512)
    ap.add_argument("--features", type=int, default=128)
    ap.add_argument("--groups", type=int, default=8)
    ap.add_argument("--perms", type=int, default=999)
    ap.add_argument("--effect", type=float, default=1.0)
    ap.add_argument("--metric", default="braycurtis")
    ap.add_argument("--impl", default="auto", choices=IMPL_CHOICES,
                    help="'auto' = hardware-aware planner (CPU-tiled vs "
                         "GPU-brute per the paper); or pin a registry impl")
    ap.add_argument("--autotune", action="store_true",
                    help="empirically measure candidates on the real "
                         "operands instead of trusting the heuristics")
    ap.add_argument("--budget-mb", type=float, default=None,
                    help="label-tensor memory budget; sweeps beyond it "
                         "stream in fixed-size chunks")
    ap.add_argument("--chunk", type=int, default=None,
                    help="pin the streaming chunk (perms per dispatch)")
    ap.add_argument("--kernel", action="store_true",
                    help="legacy alias: maps brute/matmul to the Pallas "
                         "kernel variant (interpret mode off TPU)")
    ap.add_argument("--distributed", action="store_true",
                    help="shard over all local devices")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    impl = args.impl
    if args.kernel and not impl.startswith("pallas_"):
        # legacy flag: force the Pallas kernel family ('tiled' maps to
        # permblock, the kernel carrying the paper's CPU-tiling insight)
        impl = {"auto": "pallas_matmul", "brute": "pallas_brute",
                "tiled": "pallas_permblock", "matmul": "pallas_matmul"}[impl]

    x, grouping = synthetic_study(args.samples, args.features, args.groups,
                                  effect_size=args.effect, seed=args.seed)
    t0 = time.time()
    dm = distance_matrix(jnp.asarray(x), args.metric)
    checks = validate_distance_matrix(dm)
    assert checks["ok"], checks
    t_dm = time.time() - t0

    budget = None if args.budget_mb is None else args.budget_mb * 2**20
    t0 = time.time()
    if args.distributed:
        from repro.core import permanova_distributed
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh()
        res = permanova_distributed(mesh, dm, jnp.asarray(grouping),
                                    n_perms=args.perms, impl=impl,
                                    key=jax.random.key(args.seed))
    else:
        res = engine.run(dm, jnp.asarray(grouping), n_perms=args.perms,
                         impl=impl, key=jax.random.key(args.seed),
                         memory_budget_bytes=budget, chunk=args.chunk,
                         autotune=args.autotune)
    jax.block_until_ready(res.f_perms)
    t_pa = time.time() - t0

    print(f"[permanova] n={args.samples} groups={args.groups} "
          f"perms={res.n_perms} metric={args.metric} impl={impl}"
          f"{' +distributed' if args.distributed else ''}")
    if res.plan:
        print(f"[permanova] plan: {res.plan}")
    print(f"[permanova] distance-matrix {t_dm:.2f}s  "
          f"permutation-test {t_pa:.2f}s "
          f"({res.n_perms / t_pa:.1f} perms/s)")
    print(f"[permanova] F={float(res.f_stat):.6g} "
          f"p={float(res.p_value):.6g}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
