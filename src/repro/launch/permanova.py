"""PERMANOVA launcher — the paper's workload as a CLI, routed through the
hardware-aware execution engine.

  PYTHONPATH=src python -m repro.launch.permanova \
      --samples 512 --features 128 --groups 8 --perms 999 \
      --impl auto --metric braycurtis

  # 100k permutations in fixed-memory chunks (no (n_perms, n) label tensor):
  PYTHONPATH=src python -m repro.launch.permanova \
      --samples 512 --perms 100000 --impl auto --budget-mb 64

  # full pipeline under one joint plan (distance stage + s_W planned
  # together; --materialize fused never holds the (n, n) matrix):
  PYTHONPATH=src python -m repro.launch.permanova \
      --samples 2048 --from-features --materialize auto

  # single-pass megakernel sweep (distance tiles contracted in-kernel),
  # row slabs sharded 2-way over the 'model' mesh axis:
  PYTHONPATH=src python -m repro.launch.permanova \
      --samples 4096 --materialize fused-kernel --shard-rows 2

Scales from laptop smoke runs to the paper's EMP shape
(--samples 25145 --perms 3999) on a real mesh.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import engine, obs, pipeline
from repro.core.distance import distance_matrix, validate_distance_matrix
from repro.data.microbiome import synthetic_study

IMPL_CHOICES = ["auto", "brute", "tiled", "matmul",
                "pallas_brute", "pallas_permblock", "pallas_matmul"]


def _emit_obs(args):
    """Export the trace and/or print the telemetry report, if requested."""
    if args.trace:
        obs.trace.export(args.trace)
        print(f"[permanova] trace written to {args.trace} "
              f"({len(obs.events())} events)")
    if args.metrics:
        obs.report()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--samples", type=int, default=512)
    ap.add_argument("--features", type=int, default=128)
    ap.add_argument("--groups", type=int, default=8)
    ap.add_argument("--perms", type=int, default=999)
    ap.add_argument("--effect", type=float, default=1.0)
    ap.add_argument("--metric", default="braycurtis")
    ap.add_argument("--impl", default="auto", choices=IMPL_CHOICES,
                    help="'auto' = hardware-aware planner (CPU-tiled vs "
                         "GPU-brute per the paper); or pin a registry impl")
    ap.add_argument("--autotune", action="store_true",
                    help="empirically measure candidates on the real "
                         "operands instead of trusting the heuristics")
    ap.add_argument("--budget-mb", type=float, default=None,
                    help="label-tensor memory budget; sweeps beyond it "
                         "stream in fixed-size chunks")
    ap.add_argument("--chunk", type=int, default=None,
                    help="pin the streaming chunk (perms per dispatch)")
    ap.add_argument("--from-features", action="store_true",
                    help="route through the pipeline subsystem: distance "
                         "construction + s_W planned JOINTLY (stage-1 impl, "
                         "materialization, chunking in one plan)")
    ap.add_argument("--materialize", default="auto",
                    choices=["auto", "dense", "stream", "fused",
                             "fused-kernel"],
                    help="pipeline bridge: materialize D, stream D^2 row "
                         "blocks into one buffer, fuse blocks straight "
                         "into the permutation sweep, or run the single-"
                         "pass fused-kernel (distance tiles contracted "
                         "in-kernel; D^2 never resident) — implies "
                         "--from-features")
    ap.add_argument("--fused-impl", default="auto",
                    choices=["auto", "pallas", "xla"],
                    help="fused-kernel implementation: the Pallas "
                         "megakernel (TPU; interpret mode elsewhere) or "
                         "the one-jit XLA sweep")
    ap.add_argument("--feat-precision", default="f32",
                    choices=list(pipeline.registry.PRECISIONS),
                    help="feature-slab storage for the fused-kernel "
                         "sweep: f32, bf16, fp8 (e4m3 + per-metric scale, "
                         "f32 accumulation), or packed (jaccard only: "
                         "presence bits in uint32 words, popcount tiles — "
                         "bit-identical F at 32x fewer feature bytes); "
                         "implies --materialize fused-kernel when not f32")
    ap.add_argument("--shard-rows", type=int, default=None, metavar="N",
                    help="run the fused-kernel sweep over an N-way 'model' "
                         "mesh axis (row slabs sharded, partials psum-"
                         "reduced; remaining devices shard permutations); "
                         "implies --materialize fused-kernel")
    ap.add_argument("--dist-impl", default="auto",
                    help="pin the stage-1 distance impl (e.g. "
                         "'braycurtis.blocked', 'euclidean.pallas'); "
                         "'auto' = pipeline planner")
    ap.add_argument("--features-cache", default=None, metavar="DIR",
                    help="run out of core from a disk slab cache at DIR "
                         "(built from the synthetic study on first use): "
                         "the feature table never lives in memory — slabs "
                         "stream through the async prefetcher into the "
                         "fused sweep; implies the pipeline path")
    ap.add_argument("--cache-format", default="dense",
                    choices=["dense", "csr"],
                    help="slab-cache storage when building --features-"
                         "cache: raw f32 rows, or csr presence structure "
                         "(jaccard only — reads nonzeros, not zeros)")
    ap.add_argument("--slab-rows", type=int, default=None, metavar="R",
                    help="slab height when building --features-cache "
                         "(default: planner's plan_slab_rows for the "
                         "device budget)")
    ap.add_argument("--device-budget-mb", type=float, default=None,
                    help="device-memory budget grading the feature "
                         "residency tier (hbm/host/disk) for "
                         "--features-cache runs; small values force the "
                         "out-of-core sweep")
    ap.add_argument("--pcoa", type=int, default=None, metavar="K",
                    help="also compute the top-K PCoA ordination axes "
                         "(coordinates + explained variance) from the "
                         "same pipeline dataflow — the stream/fused "
                         "bridges never materialize the Gower matrix; "
                         "implies the pipeline path")
    ap.add_argument("--covariates", default=None, metavar="NAMES",
                    help="comma-separated covariate names (synthetic "
                         "standard-normal columns, e.g. 'age,depth') — "
                         "runs the partial/covariate PERMANOVA design "
                         "path: sequential adonis2-style terms, the "
                         "grouping factor last (covariate-adjusted); "
                         "prints a per-term F/R²/p table; implies the "
                         "pipeline path")
    ap.add_argument("--strata", default=None, metavar="NAME[:K]",
                    help="restrict permutations within K synthetic "
                         "blocks (default K=4), e.g. 'site' or 'site:6' "
                         "— vegan's strata=; implies the pipeline path")
    ap.add_argument("--weights", action="store_true",
                    help="weighted PERMANOVA: synthetic positive sample "
                         "weights folded into the design projection; "
                         "implies the pipeline path")
    ap.add_argument("--kernel", action="store_true",
                    help="legacy alias: maps brute/matmul to the Pallas "
                         "kernel variant (interpret mode off TPU)")
    ap.add_argument("--distributed", action="store_true",
                    help="shard over all local devices")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record trace spans across every execution layer "
                         "and write Chrome/Perfetto trace_event JSON to "
                         "PATH (open in chrome://tracing or ui.perfetto."
                         "dev)")
    ap.add_argument("--metrics", action="store_true",
                    help="print the telemetry report after the run: "
                         "per-stage predicted-vs-measured bandwidth table "
                         "plus compile/traffic counters")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.trace or args.metrics:
        obs.enable(trace=bool(args.trace) or args.metrics, metrics=True)

    impl = args.impl
    if args.kernel and not impl.startswith("pallas_"):
        # legacy flag: force the Pallas kernel family ('tiled' maps to
        # permblock, the kernel carrying the paper's CPU-tiling insight)
        impl = {"auto": "pallas_matmul", "brute": "pallas_brute",
                "tiled": "pallas_permblock", "matmul": "pallas_matmul"}[impl]

    x, grouping = synthetic_study(args.samples, args.features, args.groups,
                                  effect_size=args.effect, seed=args.seed)
    budget = None if args.budget_mb is None else args.budget_mb * 2**20

    covariates = strata = weights = None
    design_path = (args.covariates is not None or args.strata is not None
                   or args.weights)
    if design_path:
        from repro.data.microbiome import synthetic_design
        cov_names = (tuple(s for s in args.covariates.split(",") if s)
                     if args.covariates else ())
        n_strata = 0
        if args.strata is not None:
            name, _, kk = args.strata.partition(":")
            n_strata = int(kk) if kk else 4
        covariates, strata, weights = synthetic_design(
            args.samples, covariate_names=cov_names, n_strata=n_strata,
            weighted=args.weights, seed=args.seed)

    fused_tuning = None
    if args.feat_precision != "f32":
        # the precision knobs live on the fused-kernel sweep; route there
        if args.materialize not in ("auto", "fused-kernel"):
            ap.error("--feat-precision applies to the fused-kernel sweep; "
                     "drop --materialize or set it to fused-kernel")
        args.materialize = "fused-kernel"
        fused_tuning = pipeline.registry.precision_tuning(
            args.feat_precision)

    features = jnp.asarray(x)
    if args.features_cache is not None:
        import os
        from repro.data import slabcache
        from repro.pipeline import planner as _pplanner
        dev_budget = (None if args.device_budget_mb is None
                      else args.device_budget_mb * 2**20)
        if os.path.exists(os.path.join(args.features_cache,
                                       slabcache.META_NAME)):
            features = slabcache.SlabCache.open(args.features_cache)
        else:
            rows = args.slab_rows or _pplanner.plan_slab_rows(
                args.samples, args.features,
                device_budget_bytes=dev_budget)
            features = slabcache.build_slab_cache(
                args.features_cache, x, slab_rows=rows,
                fmt=args.cache_format)
            print(f"[permanova] built slab cache {args.features_cache}: "
                  f"{features.n_slabs} slabs x {features.slab_rows} rows, "
                  f"{features.disk_bytes/2**20:.1f} MiB on disk "
                  f"({args.cache_format})")

    if args.from_features or args.materialize != "auto" \
            or args.dist_impl != "auto" or args.shard_rows is not None \
            or args.pcoa is not None or design_path \
            or args.features_cache is not None:
        if args.distributed:
            ap.error("--distributed is not supported with the pipeline "
                     "path (--from-features/--materialize/--dist-impl); "
                     "use --shard-rows for the fused-kernel mesh, or "
                     "precompute the matrix and drop --distributed")
        mesh = None
        if args.shard_rows is not None:
            from repro.launch.mesh import make_host_mesh
            if args.materialize not in ("auto", "fused-kernel"):
                ap.error("--shard-rows runs the fused-kernel sweep; drop "
                         "--materialize or set it to fused-kernel")
            mesh = make_host_mesh(model_ways=args.shard_rows)
        t0 = time.time()
        res = pipeline.pipeline(
            features, jnp.asarray(grouping), metric=args.metric,
            n_perms=args.perms, key=jax.random.key(args.seed),
            dist_impl=args.dist_impl, sw_impl=impl,
            materialize=args.materialize, chunk=args.chunk,
            fused_impl=args.fused_impl, fused_tuning=fused_tuning,
            mesh=mesh, ordination=args.pcoa,
            covariates=covariates, strata=strata, weights=weights,
            memory_budget_bytes=budget, autotune=args.autotune,
            device_budget_bytes=(None if args.device_budget_mb is None
                                 else args.device_budget_mb * 2**20))
        jax.block_until_ready(res.f_perms)
        t_pa = time.time() - t0
        print(f"[permanova] n={args.samples} groups={args.groups} "
              f"perms={res.n_perms} metric={args.metric} pipeline")
        print(f"[permanova] plan: {res.plan}")
        print(f"[permanova] features->p-value {t_pa:.2f}s "
              f"({res.n_perms / t_pa:.1f} perms/s)")
        print(f"[permanova] F={float(res.f_stat):.6g} "
              f"p={float(res.p_value):.6g} R2={float(res.r2):.4g}")
        if res.terms is not None:
            print(f"[permanova] {'term':<12} {'df':>3} {'SS':>10} "
                  f"{'F':>9} {'R2':>8} {'p':>8}")
            for t in res.terms:
                print(f"[permanova] {t.name:<12} {t.df:>3} "
                      f"{float(t.ss):>10.4g} {float(t.f_stat):>9.4g} "
                      f"{float(t.r2):>8.4g} {float(t.p_value):>8.4g}")
        if res.ordination is not None:
            o = res.ordination
            expl = ", ".join(f"{float(v):.3f}" for v in o.explained)
            print(f"[permanova] pcoa[{o.method}] k={o.k} "
                  f"explained=[{expl}] coords={tuple(o.coords.shape)}")
        _emit_obs(args)
        return 0

    t0 = time.time()
    dm = distance_matrix(jnp.asarray(x), args.metric)
    checks = validate_distance_matrix(dm)
    assert checks["ok"], checks
    t_dm = time.time() - t0

    t0 = time.time()
    if args.distributed:
        from repro.core import permanova_distributed
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh()
        res = permanova_distributed(mesh, dm, jnp.asarray(grouping),
                                    n_perms=args.perms, impl=impl,
                                    key=jax.random.key(args.seed))
    else:
        res = engine.run(dm, jnp.asarray(grouping), n_perms=args.perms,
                         impl=impl, key=jax.random.key(args.seed),
                         memory_budget_bytes=budget, chunk=args.chunk,
                         autotune=args.autotune)
    jax.block_until_ready(res.f_perms)
    t_pa = time.time() - t0

    print(f"[permanova] n={args.samples} groups={args.groups} "
          f"perms={res.n_perms} metric={args.metric} impl={impl}"
          f"{' +distributed' if args.distributed else ''}")
    if res.plan:
        print(f"[permanova] plan: {res.plan}")
    print(f"[permanova] distance-matrix {t_dm:.2f}s  "
          f"permutation-test {t_pa:.2f}s "
          f"({res.n_perms / t_pa:.1f} perms/s)")
    print(f"[permanova] F={float(res.f_stat):.6g} "
          f"p={float(res.p_value):.6g}")
    _emit_obs(args)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
