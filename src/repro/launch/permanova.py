"""PERMANOVA launcher — the paper's workload as a CLI.

  PYTHONPATH=src python -m repro.launch.permanova \
      --samples 512 --features 128 --groups 8 --perms 999 \
      --impl matmul --kernel --metric braycurtis

Scales from laptop smoke runs to the paper's EMP shape
(--samples 25145 --perms 3999) on a real mesh.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import permanova
from repro.core.distance import distance_matrix, validate_distance_matrix
from repro.data.microbiome import synthetic_study


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--samples", type=int, default=512)
    ap.add_argument("--features", type=int, default=128)
    ap.add_argument("--groups", type=int, default=8)
    ap.add_argument("--perms", type=int, default=999)
    ap.add_argument("--effect", type=float, default=1.0)
    ap.add_argument("--metric", default="braycurtis")
    ap.add_argument("--impl", default="matmul",
                    choices=["brute", "tiled", "matmul"])
    ap.add_argument("--kernel", action="store_true",
                    help="use the Pallas kernel path (interpret on CPU)")
    ap.add_argument("--distributed", action="store_true",
                    help="shard over all local devices")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    x, grouping = synthetic_study(args.samples, args.features, args.groups,
                                  effect_size=args.effect, seed=args.seed)
    t0 = time.time()
    dm = distance_matrix(jnp.asarray(x), args.metric)
    checks = validate_distance_matrix(dm)
    assert checks["ok"], checks
    t_dm = time.time() - t0

    sw_fn = None
    if args.kernel:
        from repro.kernels.permanova_sw.ops import make_sw_fn
        sw_fn = make_sw_fn(args.impl)

    t0 = time.time()
    if args.distributed:
        from repro.core import permanova_distributed
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh()
        res = permanova_distributed(mesh, dm, jnp.asarray(grouping),
                                    n_perms=args.perms, impl=args.impl,
                                    key=jax.random.key(args.seed))
    else:
        res = permanova(dm, jnp.asarray(grouping), n_perms=args.perms,
                        sw_impl=args.impl, sw_fn=sw_fn,
                        key=jax.random.key(args.seed))
    jax.block_until_ready(res.f_perms)
    t_pa = time.time() - t0

    print(f"[permanova] n={args.samples} groups={args.groups} "
          f"perms={res.n_perms} metric={args.metric} impl={args.impl}"
          f"{' +kernel' if args.kernel else ''}"
          f"{' +distributed' if args.distributed else ''}")
    print(f"[permanova] distance-matrix {t_dm:.2f}s  "
          f"permutation-test {t_pa:.2f}s "
          f"({res.n_perms / t_pa:.1f} perms/s)")
    print(f"[permanova] F={float(res.f_stat):.6g} "
          f"p={float(res.p_value):.6g}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
