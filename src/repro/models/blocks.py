"""Transformer-family blocks + scanned layer stacks.

Homogeneous layer stacks are lax.scan'd over stacked params (compile time
independent of depth — mandatory for the 80-layer archs on the 512-device
dry-run). Heterogeneous stacks (zamba2 hybrid, xlstm interleave) use the
*segmented* pattern: params of the repeating segment are stacked
(n_segments, seg_len, ...) and a python loop over segments runs
[scan(seg) -> special block], keeping compiled size O(segment), not O(L).
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import attention, mlp, moe, nn, ssm, xlstm
from repro.sharding import shard_activation

Array = jax.Array


def _norm(cfg):
    if cfg.norm == "layernorm":
        return nn.layernorm_spec, nn.layernorm
    return nn.rmsnorm_spec, nn.rmsnorm


# ---------------------------------------------------------------------------
# Dense / MoE decoder block
# ---------------------------------------------------------------------------

def decoder_block_spec(cfg, dtype):
    norm_spec, _ = _norm(cfg)
    spec = {
        "ln1": norm_spec(cfg.d_model, dtype=dtype),
        "attn": attention.attention_spec(cfg, dtype),
        "ln2": norm_spec(cfg.d_model, dtype=dtype),
    }
    if cfg.family == "moe":
        spec["ffn"] = moe.moe_spec(cfg, dtype)
    elif cfg.act == "gelu":
        spec["ffn"] = mlp.gelu_mlp_spec(cfg.d_model, cfg.d_ff, cfg.n_layers,
                                        dtype, bias=cfg.out_bias)
    else:
        spec["ffn"] = mlp.swiglu_spec(cfg.d_model, cfg.d_ff, cfg.n_layers,
                                      dtype)
    return spec


def decoder_block(params, cfg, x, positions, *, causal=True,
                  q_chunk=1024):
    """Returns (x, aux, (k, v)) — aux is the MoE balance loss (0 if dense).

    The residual stream is SEQUENCE-PARALLEL over 'model' (Megatron SP):
    the scan carry — which remat saves per layer — is 1/TP the size;
    attention/MLP interiors re-gather via their own activation
    constraints. No-op without an active mesh or when seq %% TP != 0.
    """
    _, norm_fn = _norm(cfg)
    x = shard_activation(x, ("batch", "act_seq", None))
    h, (k, v) = attention.full_attention(
        params["attn"], cfg, norm_fn(params["ln1"], x, eps=cfg.norm_eps),
        positions, causal=causal, q_chunk=q_chunk)
    h = shard_activation(h, ("batch", "act_seq", None))
    x = x + h
    y = norm_fn(params["ln2"], x, eps=cfg.norm_eps)
    if cfg.family == "moe":
        f, aux = moe.moe_ffn(params["ffn"], cfg, y)
    elif cfg.act == "gelu":
        f, aux = mlp.gelu_mlp(params["ffn"], y), jnp.zeros((), jnp.float32)
    else:
        f, aux = mlp.swiglu(params["ffn"], y), jnp.zeros((), jnp.float32)
    f = shard_activation(f, ("batch", "act_seq", None))
    return x + f, aux, (k, v)


def decoder_block_decode(params, cfg, x, cache, cache_len):
    _, norm_fn = _norm(cfg)
    h, cache = attention.decode_attention(
        params["attn"], cfg, norm_fn(params["ln1"], x, eps=cfg.norm_eps),
        cache, cache_len)
    x = x + h
    y = norm_fn(params["ln2"], x, eps=cfg.norm_eps)
    if cfg.family == "moe":
        f, _ = moe.moe_ffn(params["ffn"], cfg, y)
    elif cfg.act == "gelu":
        f = mlp.gelu_mlp(params["ffn"], y)
    else:
        f = mlp.swiglu(params["ffn"], y)
    return x + f, cache


def decoder_block_decode_readonly(params, cfg, x, cache, cache_len):
    """Decode block that does NOT write the cache; returns (x, k_new,
    v_new) for a single batched cache update at the end of the step."""
    _, norm_fn = _norm(cfg)
    h, k_new, v_new = attention.decode_attention_readonly(
        params["attn"], cfg, norm_fn(params["ln1"], x, eps=cfg.norm_eps),
        cache, cache_len)
    x = x + h
    y = norm_fn(params["ln2"], x, eps=cfg.norm_eps)
    if cfg.family == "moe":
        f, _ = moe.moe_ffn(params["ffn"], cfg, y)
    elif cfg.act == "gelu":
        f = mlp.gelu_mlp(params["ffn"], y)
    else:
        f = mlp.swiglu(params["ffn"], y)
    return x + f, k_new, v_new


# ---------------------------------------------------------------------------
# Scanned stacks
# ---------------------------------------------------------------------------

def _maybe_remat(fn, policy: Optional[str]):
    if policy is None or policy == "none":
        return fn
    if policy == "full":
        return jax.checkpoint(fn)
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    raise ValueError(policy)


def stack_forward(stacked, cfg, x, positions, *, causal=True, q_chunk=1024,
                  remat: Optional[str] = "dots", collect_kv=False):
    """scan the decoder stack. Returns (x, aux_sum, stacked (k, v) or None)."""

    def body(carry, layer_params):
        x, aux = carry
        x, a, kv = decoder_block(layer_params, cfg, x, positions,
                                 causal=causal, q_chunk=q_chunk)
        out = kv if collect_kv else None
        return (x, aux + a), out

    body = _maybe_remat(body, remat)
    (x, aux), kvs = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                 stacked)
    return x, aux, kvs


def stack_decode(stacked, cfg, x, caches, cache_len):
    """scan decode across layers; caches: {'k': (L,B,S,KV), 'v': ...}."""

    def body(x, inp):
        layer_params, cache = inp
        x, cache = decoder_block_decode(layer_params, cfg, x, cache,
                                        cache_len)
        return x, cache

    x, caches = jax.lax.scan(body, x, (stacked, caches))
    return x, caches


def stack_decode_readonly(stacked, cfg, x, caches, cache_len, *,
                          unroll: bool = False):
    """Decode across layers reading caches without rewriting them; emits
    per-layer new k/v (L, B, 1, KV) for one batched DUS by the caller.

    unroll=True python-loops the layers: no while-loop xs buffering (XLA
    CPU double-buffers scanned cache slices — ~2x cache HBM), at the cost
    of compiled-code size O(L). The decode body is small, so unrolled
    compiles stay tractable even at 80 layers."""
    if unroll:
        n_layers = jax.tree.leaves(stacked)[0].shape[0]
        k_news, v_news = [], []
        for l in range(n_layers):
            layer_params = jax.tree.map(lambda p: p[l], stacked)
            cache = jax.tree.map(lambda c: c[l], caches)
            x, k_new, v_new = decoder_block_decode_readonly(
                layer_params, cfg, x, cache, cache_len)
            k_news.append(k_new)
            v_news.append(v_new)
        return x, jnp.stack(k_news), jnp.stack(v_news)

    def body(x, inp):
        layer_params, cache = inp
        x, k_new, v_new = decoder_block_decode_readonly(
            layer_params, cfg, x, cache, cache_len)
        return x, (k_new, v_new)

    x, (k_news, v_news) = jax.lax.scan(body, x, (stacked, caches))
    return x, k_news, v_news


def write_cache_column(caches, k_news, v_news, cache_len):
    """One dynamic-update-slice per cache tensor: insert the (L, B, 1, KV)
    new column at cache_len."""
    return {
        "k": jax.lax.dynamic_update_slice(
            caches["k"], k_news.astype(caches["k"].dtype),
            (0, 0, cache_len, 0)),
        "v": jax.lax.dynamic_update_slice(
            caches["v"], v_news.astype(caches["v"].dtype),
            (0, 0, cache_len, 0)),
    }


# ---------------------------------------------------------------------------
# Encoder block (whisper encoder: bidirectional, pre-LN)
# ---------------------------------------------------------------------------

def encoder_block_spec(cfg, dtype):
    norm_spec, _ = _norm(cfg)
    return {
        "ln1": norm_spec(cfg.d_model, dtype=dtype),
        "attn": attention.attention_spec(cfg, dtype),
        "ln2": norm_spec(cfg.d_model, dtype=dtype),
        "ffn": mlp.gelu_mlp_spec(cfg.d_model, cfg.d_ff, cfg.enc_layers,
                                 dtype, bias=cfg.out_bias),
    }


def encoder_block(params, cfg, x, positions, *, q_chunk=1024):
    _, norm_fn = _norm(cfg)
    h, _ = attention.full_attention(
        params["attn"], cfg, norm_fn(params["ln1"], x, eps=cfg.norm_eps),
        positions, causal=False, q_chunk=q_chunk)
    x = x + h
    y = norm_fn(params["ln2"], x, eps=cfg.norm_eps)
    return x + mlp.gelu_mlp(params["ffn"], y)


def encoder_stack(stacked, cfg, x, positions, *, q_chunk=1024,
                  remat="dots"):
    def body(x, layer_params):
        return encoder_block(layer_params, cfg, x, positions,
                             q_chunk=q_chunk), None

    body = _maybe_remat(body, remat)
    x, _ = jax.lax.scan(body, x, stacked)
    return x


# ---------------------------------------------------------------------------
# Enc-dec decoder block (self-attn + cross-attn + FFN)
# ---------------------------------------------------------------------------

def encdec_block_spec(cfg, dtype):
    norm_spec, _ = _norm(cfg)
    return {
        "ln1": norm_spec(cfg.d_model, dtype=dtype),
        "self": attention.attention_spec(cfg, dtype),
        "lnx": norm_spec(cfg.d_model, dtype=dtype),
        "cross": attention.attention_spec(cfg, dtype),
        "ln2": norm_spec(cfg.d_model, dtype=dtype),
        "ffn": mlp.gelu_mlp_spec(cfg.d_model, cfg.d_ff, cfg.n_layers, dtype,
                                 bias=cfg.out_bias),
    }


def encdec_block(params, cfg, x, enc_out, positions, *, q_chunk=1024):
    _, norm_fn = _norm(cfg)
    h, kv = attention.full_attention(
        params["self"], cfg, norm_fn(params["ln1"], x, eps=cfg.norm_eps),
        positions, causal=True, q_chunk=q_chunk)
    x = x + h
    x = x + attention.cross_attention(
        params["cross"], cfg, norm_fn(params["lnx"], x, eps=cfg.norm_eps),
        enc_out=enc_out)
    y = norm_fn(params["ln2"], x, eps=cfg.norm_eps)
    return x + mlp.gelu_mlp(params["ffn"], y), kv


def encdec_stack(stacked, cfg, x, enc_out, positions, *, q_chunk=1024,
                 remat="dots", collect_kv=False):
    def body(x, layer_params):
        x, kv = encdec_block(layer_params, cfg, x, enc_out, positions,
                             q_chunk=q_chunk)
        return x, (kv if collect_kv else None)

    body = _maybe_remat(body, remat)
    x, kvs = jax.lax.scan(body, x, stacked)
    return x, kvs


def encdec_block_decode(params, cfg, x, self_cache, cross_kv, cache_len):
    _, norm_fn = _norm(cfg)
    h, self_cache = attention.decode_attention(
        params["self"], cfg, norm_fn(params["ln1"], x, eps=cfg.norm_eps),
        self_cache, cache_len)
    x = x + h
    x = x + attention.cross_attention(
        params["cross"], cfg, norm_fn(params["lnx"], x, eps=cfg.norm_eps),
        kv_flat=cross_kv)
    y = norm_fn(params["ln2"], x, eps=cfg.norm_eps)
    return x + mlp.gelu_mlp(params["ffn"], y), self_cache


def encdec_stack_decode(stacked, cfg, x, self_caches, cross_kvs, cache_len):
    def body(x, inp):
        layer_params, cache, ckv = inp
        x, cache = encdec_block_decode(layer_params, cfg, x, cache, ckv,
                                       cache_len)
        return x, cache

    x, self_caches = jax.lax.scan(body, x, (stacked, self_caches, cross_kvs))
    return x, self_caches


# ---------------------------------------------------------------------------
# Mamba2 block (zamba2 backbone)
# ---------------------------------------------------------------------------

def mamba_block_spec(cfg, dtype):
    norm_spec, _ = _norm(cfg)
    return {
        "ln": norm_spec(cfg.d_model, dtype=dtype),
        "mixer": ssm.mamba2_spec(cfg, dtype),
    }


def mamba_block(params, cfg, x, *, chunk=128, state=None):
    _, norm_fn = _norm(cfg)
    y, new_state = ssm.mamba2_forward(
        params["mixer"], cfg, norm_fn(params["ln"], x, eps=cfg.norm_eps),
        chunk=chunk, state=state)
    return x + y, new_state


def mamba_block_decode(params, cfg, x, state):
    _, norm_fn = _norm(cfg)
    y, new_state = ssm.mamba2_decode(
        params["mixer"], cfg, norm_fn(params["ln"], x, eps=cfg.norm_eps),
        state)
    return x + y, new_state


def mamba_stack(stacked, cfg, x, *, chunk=128, remat="dots"):
    def body(x, layer_params):
        x, _ = mamba_block(layer_params, cfg, x, chunk=chunk)
        return x, None

    body = _maybe_remat(body, remat)
    x, _ = jax.lax.scan(body, x, stacked)
    return x


def mamba_stack_decode(stacked, cfg, x, states):
    def body(x, inp):
        layer_params, st = inp
        x, st = mamba_block_decode(layer_params, cfg, x, st)
        return x, st

    x, states = jax.lax.scan(body, x, (stacked, states))
    return x, states


def mamba_stack_prefill(stacked, cfg, x, *, chunk=128, remat="dots"):
    """scan the stack collecting each layer's final (conv, ssm) state."""

    def body(x, layer_params):
        x, st = mamba_block(layer_params, cfg, x, chunk=chunk)
        return x, st

    body = _maybe_remat(body, remat)
    x, states = jax.lax.scan(body, x, stacked)
    return x, states


# ---------------------------------------------------------------------------
# xLSTM blocks (pre-norm residual wrappers)
# ---------------------------------------------------------------------------

def mlstm_block_spec(cfg, dtype):
    norm_spec, _ = _norm(cfg)
    return {"ln": norm_spec(cfg.d_model, dtype=dtype),
            "cell": xlstm.mlstm_spec(cfg, dtype)}


def mlstm_block(params, cfg, x, *, chunk=256):
    _, norm_fn = _norm(cfg)
    return x + xlstm.mlstm_forward(
        params["cell"], cfg, norm_fn(params["ln"], x, eps=cfg.norm_eps),
        chunk=chunk)


def mlstm_block_decode(params, cfg, x, state):
    _, norm_fn = _norm(cfg)
    y, state = xlstm.mlstm_decode(
        params["cell"], cfg, norm_fn(params["ln"], x, eps=cfg.norm_eps),
        state)
    return x + y, state


def slstm_block_spec(cfg, dtype):
    norm_spec, _ = _norm(cfg)
    return {"ln": norm_spec(cfg.d_model, dtype=dtype),
            "cell": xlstm.slstm_spec(cfg, dtype)}


def slstm_block(params, cfg, x, *, state=None):
    _, norm_fn = _norm(cfg)
    y, new_state = xlstm.slstm_forward(
        params["cell"], cfg, norm_fn(params["ln"], x, eps=cfg.norm_eps),
        state=state)
    return x + y, new_state


def slstm_block_decode(params, cfg, x, state):
    _, norm_fn = _norm(cfg)
    y, state = xlstm.slstm_decode(
        params["cell"], cfg, norm_fn(params["ln"], x, eps=cfg.norm_eps),
        state)
    return x + y, state


def mlstm_stack(stacked, cfg, x, *, chunk=256, remat="dots"):
    def body(x, layer_params):
        return mlstm_block(layer_params, cfg, x, chunk=chunk), None

    body = _maybe_remat(body, remat)
    x, _ = jax.lax.scan(body, x, stacked)
    return x


def mlstm_stack_decode(stacked, cfg, x, states):
    def body(x, inp):
        layer_params, st = inp
        x, st = mlstm_block_decode(layer_params, cfg, x, st)
        return x, st

    x, states = jax.lax.scan(body, x, (stacked, states))
    return x, states


def mlstm_stack_prefill(stacked, cfg, x, *, chunk=256, remat="dots"):
    _, norm_fn = _norm(cfg)

    def body(x, layer_params):
        y, st = xlstm.mlstm_forward(
            layer_params["cell"], cfg,
            norm_fn(layer_params["ln"], x, eps=cfg.norm_eps),
            chunk=chunk, return_state=True)
        return x + y, st

    body = _maybe_remat(body, remat)
    x, states = jax.lax.scan(body, x, stacked)
    return x, states
