from repro.models.model import build_model, LMModel  # noqa: F401
