"""Rotary position embeddings (RoPE), with partial-rotary support (GLM4
applies RoPE to half the head dim) and configurable theta."""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def rope_freqs(d_rot: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, d_rot, 2, dtype=jnp.float32)
                            / d_rot))


def apply_rope(x: Array, positions: Array, *, theta: float = 10000.0,
               fraction: float = 1.0) -> Array:
    """x: (..., seq, heads, d_head); positions: broadcastable to (..., seq)."""
    d_head = x.shape[-1]
    d_rot = int(d_head * fraction)
    d_rot -= d_rot % 2
    if d_rot == 0:
        return x
    xr, xp = x[..., :d_rot], x[..., d_rot:]
    freqs = rope_freqs(d_rot, theta)                      # (d_rot/2,)
    angles = positions[..., None, None].astype(jnp.float32) * freqs
    cos = jnp.cos(angles).astype(x.dtype)
    sin = jnp.sin(angles).astype(x.dtype)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    xr = jnp.stack([r1, r2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([xr, xp], axis=-1)
