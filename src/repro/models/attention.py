"""Grouped-query attention: training/prefill (chunked, memory-bounded) and
single-token decode against a KV cache.

Memory strategy: full (S x S) score materialization is impossible at the
assigned shapes (32k prefill), so prefill/training attention scans over
query chunks with an fp32 online softmax over key blocks — the
FlashAttention recurrence expressed in pure JAX (the Pallas splash kernel is
a TPU-runtime drop-in; the lax.scan form is what we can validate on CPU and
what XLA pipelines well).

Layouts:
  q:       (B, S, H, Dh)
  k, v:    (B, S, KVH, Dh)
  cache:   (B, Smax, KVH*Dh) flattened so the head dim shards over 'model'
           even when KVH < model-axis size (DESIGN.md: decode sharding).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import nn, rope
from repro.sharding import shard_activation

Array = jax.Array
NEG_INF = -1e30


def attention_spec(cfg, dtype):
    d = cfg.d_model
    h = cfg.n_heads * cfg.d_head
    kvh = cfg.n_kv_heads * cfg.d_head
    return {
        "wq": nn.dense_spec(d, h, "embed", "heads", bias=cfg.qkv_bias,
                            dtype=dtype),
        "wk": nn.dense_spec(d, kvh, "embed", "kv", bias=cfg.qkv_bias,
                            dtype=dtype),
        "wv": nn.dense_spec(d, kvh, "embed", "kv", bias=cfg.qkv_bias,
                            dtype=dtype),
        "wo": nn.dense_spec(h, d, "heads", "embed", bias=cfg.out_bias,
                            dtype=dtype, init="fanin_deep",
                            scale=1.0 / max(cfg.n_layers, 1) ** 0.5),
    }


def _project_qkv(params, cfg, x, positions):
    b, s, _ = x.shape
    q = nn.dense(params["wq"], x).reshape(b, s, cfg.n_heads, cfg.d_head)
    k = nn.dense(params["wk"], x).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    v = nn.dense(params["wv"], x).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    if cfg.rope_fraction > 0:
        q = rope.apply_rope(q, positions, theta=cfg.rope_theta,
                            fraction=cfg.rope_fraction)
        k = rope.apply_rope(k, positions, theta=cfg.rope_theta,
                            fraction=cfg.rope_fraction)
    return q, k, v


def _repeat_kv(k, n_heads):
    """(B,S,KVH,Dh) -> (B,S,H,Dh) repeating each kv head onto its group.

    Repeat-KV keeps the HEAD dim intact so it shards over 'model' even when
    KVH < mesh ways (KVH=8 on a 16-way axis): the fp32 attention logits
    stay head-sharded instead of replicating — 16x smaller score buffers
    (the grouped (KVH, G) layout defeats GSPMD propagation).
    """
    b, s, kvh, dh = k.shape
    rep = jnp.broadcast_to(k[:, :, :, None, :],
                           (b, s, kvh, n_heads // kvh, dh))
    return rep.reshape(b, s, n_heads, dh)


def _attend_block(q, k, v, mask, softmax_scale):
    """One (q-chunk x full-kv) attention with fp32 softmax.

    q: (B,Sq,H,Dh)  k,v: (B,Sk,H,Dh) (kv pre-repeated)  mask broadcastable
    to (B,H,Sq,Sk) or None.
    """
    logits = jnp.einsum("bqhd,bshd->bhqs", q, k).astype(jnp.float32)
    logits *= softmax_scale
    if mask is not None:
        logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhqs,bshd->bqhd", probs, v)
    return out


def full_attention(params, cfg, x, positions, *, causal=True,
                   q_chunk: int = 1024, segment_mask=None):
    """Training / prefill attention, scanned over query chunks.

    Peak score memory = q_chunk * S per (batch, head) instead of S^2.
    Returns (out, (k, v)) so prefill can seed the decode cache.
    """
    b, s, d = x.shape
    q, k, v = _project_qkv(params, cfg, x, positions)
    q = shard_activation(q, ("batch", None, "heads", None))
    k_rep = shard_activation(_repeat_kv(k, cfg.n_heads),
                             ("batch", None, "heads", None))
    v_rep = shard_activation(_repeat_kv(v, cfg.n_heads),
                             ("batch", None, "heads", None))
    scale = cfg.d_head ** -0.5

    q_chunk = min(q_chunk, s)
    if s % q_chunk != 0:
        q_chunk = s  # fallback: irregular lengths take the unchunked path
    n_chunks = s // q_chunk
    kv_pos = jnp.arange(s)

    def one_chunk(ci, qc):
        q_pos = ci * q_chunk + jnp.arange(q_chunk)
        if causal:
            m = (kv_pos[None, :] <= q_pos[:, None])[None, None]
        else:
            m = None
        if segment_mask is not None:
            sm = segment_mask(q_pos, kv_pos)
            m = sm if m is None else (m & sm)
        return _attend_block(qc, k_rep, v_rep, m, scale)

    if n_chunks == 1:
        out = one_chunk(0, q)
    else:
        qs = q.reshape(b, n_chunks, q_chunk, *q.shape[2:])
        qs = jnp.moveaxis(qs, 1, 0)

        def body(ci, qc):
            return ci + 1, one_chunk(ci, qc)

        _, outs = jax.lax.scan(body, 0, qs)
        out = jnp.moveaxis(outs, 0, 1).reshape(b, s, *q.shape[2:])

    out = out.reshape(b, s, cfg.n_heads * cfg.d_head)
    out = shard_activation(out, ("batch", None, "heads"))
    return nn.dense(params["wo"], out), (k, v)


# ---------------------------------------------------------------------------
# Decode path
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class KVCacheSpec:
    batch: int
    max_len: int
    n_kv_heads: int
    d_head: int
    dtype: object = jnp.bfloat16

    def zeros(self):
        flat = self.n_kv_heads * self.d_head
        return {
            "k": jnp.zeros((self.batch, self.max_len, flat), self.dtype),
            "v": jnp.zeros((self.batch, self.max_len, flat), self.dtype),
        }

    def abstract(self):
        flat = self.n_kv_heads * self.d_head
        return {
            "k": jax.ShapeDtypeStruct((self.batch, self.max_len, flat),
                                      self.dtype),
            "v": jax.ShapeDtypeStruct((self.batch, self.max_len, flat),
                                      self.dtype),
        }

    @property
    def logical_axes(self):
        return {"k": ("batch", None, "kv"), "v": ("batch", None, "kv")}


def decode_attention_readonly(params, cfg, x, cache, cache_len):
    """One-token decode WITHOUT writing the cache.

    Attends over cache positions [0, cache_len) plus the current token's
    own k/v, and returns (out, k_new, v_new) so the caller batches ONE
    dynamic-update-slice per step across all layers — the scan then reads
    the cache as a streamed input instead of carrying a second full-size
    output buffer (halves decode HBM residency; see launch/cells.py).
    """
    b = x.shape[0]
    positions = jnp.full((b, 1), cache_len, dtype=jnp.int32)
    q, k_new, v_new = _project_qkv(params, cfg, x, positions)

    s_max = cache["k"].shape[1]
    k = _repeat_kv(cache["k"].reshape(b, s_max, cfg.n_kv_heads,
                                      cfg.d_head).astype(x.dtype),
                   cfg.n_heads)
    v = _repeat_kv(cache["v"].reshape(b, s_max, cfg.n_kv_heads,
                                      cfg.d_head).astype(x.dtype),
                   cfg.n_heads)
    scale = cfg.d_head ** -0.5

    logits_c = jnp.einsum("bqhd,bshd->bhqs", q.astype(k.dtype),
                          k).astype(jnp.float32) * scale
    valid = (jnp.arange(s_max) < cache_len)[None, None, None, :]
    logits_c = jnp.where(valid, logits_c, NEG_INF)
    kn = k_new.astype(k.dtype).reshape(b, 1, cfg.n_kv_heads, cfg.d_head)
    vn = v_new.astype(v.dtype).reshape(b, 1, cfg.n_kv_heads, cfg.d_head)
    kn_r = _repeat_kv(kn, cfg.n_heads)
    vn_r = _repeat_kv(vn, cfg.n_heads)
    logit_self = jnp.einsum("bqhd,bshd->bhqs", q.astype(k.dtype),
                            kn_r).astype(jnp.float32) * scale
    logits = jnp.concatenate([logits_c, logit_self], axis=-1)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhqs,bshd->bqhd", probs[..., :-1], v) \
        + jnp.einsum("bhqs,bshd->bqhd", probs[..., -1:], vn_r)
    out = out.reshape(b, 1, cfg.n_heads * cfg.d_head).astype(x.dtype)
    y = nn.dense(params["wo"], out)
    return y, kn.reshape(b, 1, -1), vn.reshape(b, 1, -1)


def decode_attention(params, cfg, x, cache, cache_len):
    """One-token decode: x (B, 1, D); cache k/v (B, Smax, KVH*Dh).

    Returns (out (B,1,D), updated cache). Writes the new k/v at cache_len.
    """
    b = x.shape[0]
    positions = jnp.full((b, 1), cache_len, dtype=jnp.int32)
    q, k_new, v_new = _project_qkv(params, cfg, x, positions)

    flat = cfg.n_kv_heads * cfg.d_head
    k_cache = jax.lax.dynamic_update_slice(
        cache["k"], k_new.reshape(b, 1, flat).astype(cache["k"].dtype),
        (0, cache_len, 0))
    v_cache = jax.lax.dynamic_update_slice(
        cache["v"], v_new.reshape(b, 1, flat).astype(cache["v"].dtype),
        (0, cache_len, 0))
    # cache layout: sequence-sharded over 'model' (matches launch/cells
    # decode sharding) — partial attention + reduce, no cache gathers
    k_cache = shard_activation(k_cache, ("batch", "kv_seq", None))
    v_cache = shard_activation(v_cache, ("batch", "kv_seq", None))

    s_max = cache["k"].shape[1]
    k = _repeat_kv(k_cache.reshape(b, s_max, cfg.n_kv_heads,
                                   cfg.d_head).astype(x.dtype),
                   cfg.n_heads)
    v = _repeat_kv(v_cache.reshape(b, s_max, cfg.n_kv_heads,
                                   cfg.d_head).astype(x.dtype),
                   cfg.n_heads)

    valid = (jnp.arange(s_max) <= cache_len)[None, None, None, :]
    out = _attend_block(q.astype(k.dtype), k, v, valid, cfg.d_head ** -0.5)
    out = out.reshape(b, 1, cfg.n_heads * cfg.d_head).astype(x.dtype)
    y = nn.dense(params["wo"], out)
    return y, {"k": k_cache, "v": v_cache}


def cross_attention(params, cfg, x, enc_out=None, kv_flat=None):
    """Encoder-decoder cross attention (whisper). No positional rotation,
    no causal mask. Either enc_out (B,Se,D) — k/v computed here — or
    precomputed flattened kv_flat {'k','v'}: (B,Se,KVH*Dh)."""
    b, s, _ = x.shape
    q = nn.dense(params["wq"], x).reshape(b, s, cfg.n_heads, cfg.d_head)
    if kv_flat is None:
        se = enc_out.shape[1]
        k = nn.dense(params["wk"], enc_out).reshape(
            b, se, cfg.n_kv_heads, cfg.d_head)
        v = nn.dense(params["wv"], enc_out).reshape(
            b, se, cfg.n_kv_heads, cfg.d_head)
    else:
        se = kv_flat["k"].shape[1]
        k = kv_flat["k"].reshape(b, se, cfg.n_kv_heads,
                                 cfg.d_head).astype(x.dtype)
        v = kv_flat["v"].reshape(b, se, cfg.n_kv_heads,
                                 cfg.d_head).astype(x.dtype)
    k = _repeat_kv(k, cfg.n_heads)
    v = _repeat_kv(v, cfg.n_heads)
    out = _attend_block(q.astype(k.dtype), k, v, None, cfg.d_head ** -0.5)
    out = out.reshape(b, s, cfg.n_heads * cfg.d_head).astype(x.dtype)
    return nn.dense(params["wo"], out)


def cross_kv(params, cfg, enc_out):
    """Precompute flattened cross-attention K/V from encoder output."""
    b, se, _ = enc_out.shape
    flat = cfg.n_kv_heads * cfg.d_head
    return {"k": nn.dense(params["wk"], enc_out).reshape(b, se, flat),
            "v": nn.dense(params["wv"], enc_out).reshape(b, se, flat)}


def seed_cache(cache, k, v, *, start: int = 0):
    """Write prefill k/v (B,S,KVH,Dh) into a decode cache at position start."""
    b, s, kvh, dh = k.shape
    kf = k.reshape(b, s, kvh * dh).astype(cache["k"].dtype)
    vf = v.reshape(b, s, kvh * dh).astype(cache["v"].dtype)
    return {
        "k": jax.lax.dynamic_update_slice(cache["k"], kf, (0, start, 0)),
        "v": jax.lax.dynamic_update_slice(cache["v"], vf, (0, start, 0)),
    }
