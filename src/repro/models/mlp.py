"""Dense MLP blocks: SwiGLU (LLaMA-family default) and GELU (whisper/ViT)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import nn
from repro.sharding import shard_activation

Array = jax.Array


def swiglu_spec(d_model: int, d_ff: int, n_layers: int, dtype):
    return {
        "w_gate": nn.dense_spec(d_model, d_ff, "embed", "mlp", dtype=dtype),
        "w_up": nn.dense_spec(d_model, d_ff, "embed", "mlp", dtype=dtype),
        "w_down": nn.dense_spec(d_ff, d_model, "mlp", "embed", dtype=dtype,
                                init="fanin_deep",
                                scale=1.0 / max(n_layers, 1) ** 0.5),
    }


def swiglu(params, x: Array) -> Array:
    g = nn.dense(params["w_gate"], x)
    u = nn.dense(params["w_up"], x)
    h = jax.nn.silu(g) * u
    h = shard_activation(h, ("batch", None, "mlp"))
    return nn.dense(params["w_down"], h)


def gelu_mlp_spec(d_model: int, d_ff: int, n_layers: int, dtype,
                  *, bias: bool = True):
    return {
        "w_in": nn.dense_spec(d_model, d_ff, "embed", "mlp", bias=bias,
                              dtype=dtype),
        "w_out": nn.dense_spec(d_ff, d_model, "mlp", "embed", bias=bias,
                               dtype=dtype, init="fanin_deep",
                               scale=1.0 / max(n_layers, 1) ** 0.5),
    }


def gelu_mlp(params, x: Array) -> Array:
    h = jax.nn.gelu(nn.dense(params["w_in"], x))
    h = shard_activation(h, ("batch", None, "mlp"))
    return nn.dense(params["w_out"], h)
