"""Minimal module system: param specs with logical sharding axes.

Design (DESIGN.md section 7): parameters are plain nested dicts of jax arrays;
every layer declares a parallel *spec tree* of ParamSpec entries carrying the
logical axis names of each dimension. Sharding rules (sharding/rules.py) map
logical axes -> mesh axes, so distribution strategy is data, not code.

Logical axes used across the zoo:
  "vocab"   embedding rows / logits columns        -> tensor-parallel
  "embed"   the d_model dimension of weight mats   -> FSDP (sharded over data)
  "heads"   flattened n_heads*d_head projections   -> tensor-parallel
  "kv"      flattened kv_heads*d_head projections  -> tensor-parallel
  "mlp"     the d_ff dimension                     -> tensor-parallel
  "expert"  MoE expert dimension                   -> expert-parallel (opt)
  "layers"  stacked-scan layer dimension           -> never sharded
  None      replicated
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    axes: tuple                 # logical axis per dim (str | None)
    init: str = "normal"        # normal | zeros | ones | fanin | fanin_deep
    dtype: Any = jnp.float32
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_one(key, spec: ParamSpec) -> Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "normal":
        return (spec.scale * 0.02) * jax.random.normal(
            key, spec.shape, jnp.float32).astype(spec.dtype)
    if spec.init in ("fanin", "fanin_deep"):
        fan_in = spec.shape[0] if len(spec.shape) == 1 else math.prod(
            spec.shape[:-1])
        std = spec.scale / math.sqrt(max(fan_in, 1))
        return (std * jax.random.normal(key, spec.shape, jnp.float32)
                ).astype(spec.dtype)
    raise ValueError(f"unknown init {spec.init!r}")


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(key, spec_tree):
    """Materialize a spec tree into a param tree (split key per leaf)."""
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    out = [_init_one(k, s) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, out)


def abstract_params(spec_tree):
    """ShapeDtypeStruct tree (dry-run: no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), spec_tree,
        is_leaf=is_spec)


def logical_axes(spec_tree):
    """Tree of logical-axis tuples mirroring the param tree."""
    return jax.tree.map(lambda s: s.axes, spec_tree, is_leaf=is_spec)


def cast_spec_tree(spec_tree, dtype):
    """Return a spec tree with floating dtypes replaced (bf16 dry-runs)."""
    def _cast(s: ParamSpec):
        if jnp.issubdtype(s.dtype, jnp.floating):
            return dataclasses.replace(s, dtype=dtype)
        return s
    return jax.tree.map(_cast, spec_tree, is_leaf=is_spec)


def stack_specs(spec_tree, n: int):
    """Prepend a stacked 'layers' dim to every spec (for lax.scan stacks)."""
    def _stack(s: ParamSpec):
        return dataclasses.replace(
            s, shape=(n,) + s.shape, axes=("layers",) + s.axes)
    return jax.tree.map(_stack, spec_tree, is_leaf=is_spec)


def init_stacked(key, spec_tree, n: int):
    """Init n layers' params stacked along axis 0 (vmap over layer keys)."""
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: init_params(k, spec_tree))(keys)


# ---------------------------------------------------------------------------
# Common primitives
# ---------------------------------------------------------------------------

def dense_spec(d_in: int, d_out: int, ax_in: Optional[str],
               ax_out: Optional[str], *, bias: bool = False,
               dtype=jnp.float32, init: str = "fanin", scale: float = 1.0):
    spec = {"w": ParamSpec((d_in, d_out), (ax_in, ax_out), init=init,
                           dtype=dtype, scale=scale)}
    if bias:
        spec["b"] = ParamSpec((d_out,), (ax_out,), init="zeros", dtype=dtype)
    return spec


def dense(params, x: Array) -> Array:
    y = x @ params["w"]
    if "b" in params:
        y = y + params["b"]
    return y


def rmsnorm_spec(d: int, dtype=jnp.float32):
    return {"scale": ParamSpec((d,), ("embed",), init="ones", dtype=dtype)}


def rmsnorm(params, x: Array, *, eps: float = 1e-5) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def layernorm_spec(d: int, dtype=jnp.float32):
    return {"scale": ParamSpec((d,), ("embed",), init="ones", dtype=dtype),
            "bias": ParamSpec((d,), ("embed",), init="zeros", dtype=dtype)}


def layernorm(params, x: Array, *, eps: float = 1e-5) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(
        jnp.float32)
    return y.astype(dt)


def embedding_spec(vocab: int, d: int, dtype=jnp.float32):
    return {"table": ParamSpec((vocab, d), ("vocab", "embed"), init="normal",
                               dtype=dtype)}


def embed(params, tokens: Array) -> Array:
    return jnp.take(params["table"], tokens, axis=0)
