"""Mixture-of-Experts FFN with capacity-bounded scatter dispatch.

Covers both assigned MoE architectures:
  grok-1-314b     8 experts, top-2, no shared experts
  qwen2-moe-a2.7b 60 routed experts top-4 + shared experts (always-on)

Dispatch: tokens are routed top-k; each (token, choice) is assigned a slot
inside its expert's capacity buffer via a cumulative-count rank. Tokens past
capacity are dropped (their combine weight is zero) — the GShard/Switch
convention. The dense (T, E, C) dispatch tensor is NEVER materialized: we
scatter token vectors into the (E, C, D) buffer with one `.at[].add`, so
peak memory is O(E*C*D + T*D), which is what makes 1M-token batches
feasible (DESIGN.md section 7).

Parallelism: expert weights carry ("expert", "embed", "mlp") logical axes —
"mlp" is tensor-parallel inside each expert (works for any expert count);
when n_experts divides the 'model' axis the rules can map "expert" to it for
classic expert parallelism instead.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import nn
from repro.sharding import shard_activation

Array = jax.Array


def moe_spec(cfg, dtype):
    e, d, f = cfg.moe_n_experts, cfg.d_model, cfg.moe_d_ff
    spec = {
        "router": nn.dense_spec(d, e, "embed", None, dtype=jnp.float32),
        "w_gate": nn.ParamSpec((e, d, f), ("expert", "embed", "mlp"),
                               init="fanin", dtype=dtype),
        "w_up": nn.ParamSpec((e, d, f), ("expert", "embed", "mlp"),
                             init="fanin", dtype=dtype),
        "w_down": nn.ParamSpec((e, f, d), ("expert", "mlp", "embed"),
                               init="fanin", dtype=dtype,
                               scale=1.0 / max(cfg.n_layers, 1) ** 0.5),
    }
    if cfg.moe_n_shared > 0:
        from repro.models import mlp
        spec["shared"] = mlp.swiglu_spec(
            d, cfg.moe_d_ff * cfg.moe_n_shared, cfg.n_layers, dtype)
        spec["shared_gate"] = nn.dense_spec(d, 1, "embed", None,
                                            dtype=jnp.float32)
    return spec


def _route(router_params, x2d, n_experts: int, top_k: int):
    """Router: returns (weights (T,k) f32, expert ids (T,k) i32, aux loss)."""
    logits = nn.dense(router_params, x2d.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, top_k)
    weights = weights / jnp.maximum(
        jnp.sum(weights, axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balancing auxiliary loss
    density = jnp.mean(jax.nn.one_hot(ids[:, 0], n_experts), axis=0)
    density_proxy = jnp.mean(probs, axis=0)
    aux = jnp.sum(density * density_proxy) * (n_experts ** 2) / n_experts
    return weights, ids, aux


def _dispatch_indices(ids, n_experts: int, capacity: int):
    """Slot of each (token, choice) within its expert's capacity buffer.

    position = rank of this (token, choice) among all assignments to the
    same expert, in (token, choice) order. Ranks >= capacity are dropped.

    Sort-based ranking: O(T*k log) compute, O(T*k) memory. The dense
    one-hot cumsum alternative materializes a (T*k, E) int32 tensor —
    ~252 GB for the 1M-token x 60-expert qwen2-moe train cell — so it is
    deliberately avoided (DESIGN.md section 7).
    """
    t, k = ids.shape
    flat = ids.reshape(-1)                                 # (T*k,)
    n = flat.shape[0]
    order = jnp.argsort(flat, stable=True)                 # group by expert
    sorted_ids = flat[order]
    counts = jnp.bincount(flat, length=n_experts)
    offsets = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                               jnp.cumsum(counts)[:-1]])
    rank_sorted = jnp.arange(n, dtype=jnp.int32) \
        - offsets[sorted_ids].astype(jnp.int32)
    pos = jnp.zeros((n,), jnp.int32).at[order].set(rank_sorted)
    keep = pos < capacity
    return pos.reshape(t, k), keep.reshape(t, k)


def moe_ffn(params, cfg, x: Array) -> tuple[Array, Array]:
    """(B, S, D) -> (B, S, D); also returns the load-balance aux loss.

    cfg.moe_token_chunks > 1 scans the WHOLE dispatch+FFN over sequence
    chunks: capacity, scatter/gather buffers and their backward cotangents
    shrink by the chunk count (grok-class models at 1M-token prefill).
    Routing is per-token, so chunking is exact up to capacity-drop
    boundaries (each chunk gets its own capacity budget).
    """
    nc = max(getattr(cfg, "moe_token_chunks", 1), 1)
    b, s, d = x.shape
    if nc > 1 and s % nc == 0:
        xs = jnp.moveaxis(x.reshape(b, nc, s // nc, d), 1, 0)

        def body(aux, xc):
            yc, a = _moe_ffn_flat(params, cfg, xc)
            return aux + a, yc

        aux, ys = jax.lax.scan(body, jnp.zeros((), jnp.float32), xs)
        return jnp.moveaxis(ys, 0, 1).reshape(b, s, d), aux / nc
    return _moe_ffn_flat(params, cfg, x)


def _moe_ffn_flat(params, cfg, x: Array) -> tuple[Array, Array]:
    b, s, d = x.shape
    t = b * s
    e, k = cfg.moe_n_experts, cfg.moe_top_k
    capacity = int(cfg.moe_capacity_factor * t * k / e) + 1
    # explicit token-dim sharding: merging (batch, seq) through the
    # reshape loses tuple-axis ((pod, data)) sharding in GSPMD otherwise
    x2d = shard_activation(x.reshape(t, d), ("moe_capacity", None))

    weights, ids, aux = _route(params["router"], x2d, e, k)
    pos, keep = _dispatch_indices(ids, e, capacity)
    weights = weights * keep.astype(weights.dtype)

    # scatter tokens into (E, C, D) expert buffers
    buf = jnp.zeros((e, capacity, d), x.dtype)
    tok_idx = jnp.broadcast_to(jnp.arange(t)[:, None], (t, k)).reshape(-1)
    e_idx = ids.reshape(-1)
    c_idx = jnp.where(keep.reshape(-1), pos.reshape(-1), capacity - 1)
    src = jnp.where(keep.reshape(-1)[:, None], x2d[tok_idx],
                    jnp.zeros((), x.dtype))
    src = shard_activation(src, ("moe_capacity", None))
    buf = buf.at[e_idx, c_idx].add(src, mode="drop")
    buf = shard_activation(buf, ("expert", "moe_capacity", None))

    # per-expert FFN ("mlp" dim tensor-parallel). When moe_scan_experts
    # is set (grok-1: 8 experts x 32768-wide FFN), lax.scan over the
    # expert dim bounds the FSDP weight-gather working set to ONE
    # expert's matrices instead of all E at once.
    if cfg.moe_scan_experts:
        @jax.checkpoint  # recompute per-expert intermediates in backward
        def one_expert(_, wb):
            wg, wu, wd, be = wb
            ge = shard_activation(be @ wg, ("moe_capacity", "mlp"))
            ue = shard_activation(be @ wu, ("moe_capacity", "mlp"))
            he = shard_activation(jax.nn.silu(ge) * ue,
                                  ("moe_capacity", "mlp"))
            return None, he @ wd

        _, y_buf = jax.lax.scan(
            one_expert, None,
            (params["w_gate"], params["w_up"], params["w_down"], buf))
    else:
        g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
        u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
        h = jax.nn.silu(g) * u
        h = shard_activation(h, ("expert", "moe_capacity", "mlp"))
        y_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    y_buf = shard_activation(y_buf, ("expert", "moe_capacity", None))

    # combine: gather each (token, choice) slot back, weight, and sum over k
    y_tk = y_buf[e_idx, c_idx]                             # (T*k, D)
    y_tk = shard_activation(y_tk, ("moe_capacity", None))
    y_tk = y_tk * weights.reshape(-1)[:, None].astype(y_tk.dtype)
    y = jnp.sum(y_tk.reshape(t, k, d), axis=1)

    if "shared" in params:
        from repro.models import mlp
        gate = jax.nn.sigmoid(
            nn.dense(params["shared_gate"], x2d.astype(jnp.float32)))
        y = y + (mlp.swiglu(params["shared"], x2d)
                 * gate.astype(y.dtype))
    return y.reshape(b, s, d), aux
