"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, strictly recurrent).

mLSTM training/prefill uses the stabilized PARALLEL form (attention-like
O(S^2) with gate-derived decay matrix) — quadratic in the chunk but MXU
friendly; decode uses the recurrent form with (C, n, m) state, O(1) per
token, which is why xlstm-350m runs the long_500k shape.

sLSTM has no parallel form (true recurrence with exponential gating); it is
a lax.scan over time. The assigned xlstm-350m interleaves one sLSTM block
per `slstm_every` mLSTM blocks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import nn
from repro.sharding import shard_activation

Array = jax.Array
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_spec(cfg, dtype):
    d = cfg.d_model
    d_inner = cfg.xlstm_pf * d                 # projection factor 2
    h = cfg.n_heads
    dh = d_inner // h
    return {
        "up": nn.dense_spec(d, 2 * d_inner, "embed", "mlp", dtype=dtype),
        "conv_w": nn.ParamSpec((cfg.xlstm_conv, d_inner), (None, "mlp"),
                               init="fanin", dtype=dtype),
        "conv_b": nn.ParamSpec((d_inner,), ("mlp",), init="zeros",
                               dtype=dtype),
        # row-parallel: input dim carries the model shard ("mlp"); mapping
        # the output to "heads" too would double-assign the mesh axis
        "wq": nn.dense_spec(d_inner, d_inner, "mlp", None, dtype=dtype),
        "wk": nn.dense_spec(d_inner, d_inner, "mlp", None, dtype=dtype),
        "wv": nn.dense_spec(d_inner, d_inner, "mlp", None, dtype=dtype),
        "w_i": nn.dense_spec(d_inner, h, "mlp", None, dtype=jnp.float32),
        "w_f": nn.dense_spec(d_inner, h, "mlp", None, dtype=jnp.float32),
        "norm": nn.rmsnorm_spec(d_inner, dtype=dtype),
        "down": nn.dense_spec(d_inner, d, "mlp", "embed", dtype=dtype,
                              init="fanin_deep",
                              scale=1.0 / max(cfg.n_layers, 1) ** 0.5),
    }


def _causal_conv1d(x, w, b):
    k = w.shape[0]
    pad = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def mlstm_chunked(q, k, v, i_gate, f_gate, *, chunk: int = 256, state=None):
    """Chunkwise-parallel stabilized mLSTM.

    Same recurrence structure as SSD: intra-chunk parallel (decay matrix D
    from cumulative log-f + input gates, running-max stabilized) plus an
    inter-chunk (C, n, m) state carried by lax.scan. O(S * chunk) memory —
    the full O(S^2) parallel form is infeasible at the 4k/32k shapes.

    q,k,v: (B,S,H,Dh); i_gate,f_gate: (B,S,H) raw pre-activations.
    Returns (out (B,S,H,Dh), final_state {c,n,m}).
    """
    b, s, h, dh = q.shape
    chunk = min(chunk, s)
    while s % chunk != 0:   # largest divisor of s not exceeding the request
        chunk -= 1
    nc = s // chunk
    k = k * (dh ** -0.5)

    if state is None:
        c0 = jnp.zeros((b, h, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, h, dh), jnp.float32)
        m0 = jnp.full((b, h), NEG_INF, jnp.float32)
    else:
        c0, n0, m0 = (state["c"].astype(jnp.float32),
                      state["n"].astype(jnp.float32),
                      state["m"].astype(jnp.float32))

    def chunkify(x_):
        return jnp.moveaxis(x_.reshape(b, nc, chunk, *x_.shape[2:]), 1, 0)

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def body(carry, inp):
        c, n, m = carry
        qc, kc, vc, ic, fc = inp                          # (B,L,H,*) / (B,L,H)
        log_f = jax.nn.log_sigmoid(fc.astype(jnp.float32))
        cum_f = jnp.cumsum(log_f, axis=1)                 # (B,L,H) inclusive
        # intra-chunk decay D[t,s'] = F_t - F_s' + i_s'  (s' <= t)
        dmat = (cum_f[:, :, None, :] - cum_f[:, None, :, :]
                + ic.astype(jnp.float32)[:, None, :, :])  # (B,T,S,H)
        dmat = jnp.where(tri[None, :, :, None], dmat, NEG_INF)
        m_intra = jnp.max(dmat, axis=2)                   # (B,T,H)
        m_inter = cum_f + m[:, None, :]                   # (B,T,H)
        m_t = jnp.maximum(m_intra, m_inter)
        dexp = jnp.exp(dmat - m_t[:, :, None, :])
        scores = jnp.einsum("bthd,bshd->btsh", qc, kc).astype(jnp.float32)
        scores = scores * dexp
        inter_scale = jnp.exp(m_inter - m_t)              # (B,T,H)
        out_intra = jnp.einsum("btsh,bshd->bthd",
                               scores.astype(vc.dtype), vc)
        # c layout is (B, H, d_v, e_k): contract q with the K dim (e)
        out_inter = jnp.einsum("bthe,bhde->bthd", qc.astype(jnp.float32), c)
        num = (out_intra.astype(jnp.float32)
               + inter_scale[..., None] * out_inter)
        den_intra = jnp.sum(scores, axis=2)               # (B,T,H)
        den_inter = jnp.einsum("bthe,bhe->bth",
                               qc.astype(jnp.float32), n)
        den = jnp.abs(den_intra + inter_scale * den_inter)
        den = jnp.maximum(den, jnp.exp(-m_t))
        out = num / jnp.maximum(den[..., None], 1e-6)

        # chunk-end state update
        f_last = cum_f[:, -1, :]                          # (B,H)
        decay_s = f_last[:, None, :] - cum_f \
            + ic.astype(jnp.float32)                      # (B,L,H)
        m_new = jnp.maximum(f_last + m, jnp.max(decay_s, axis=1))
        w_s = jnp.exp(decay_s - m_new[:, None, :])        # (B,L,H)
        carry_scale = jnp.exp(f_last + m - m_new)         # (B,H)
        c_new = (carry_scale[..., None, None] * c
                 + jnp.einsum("blh,blhd,blhe->bhde",
                              w_s, vc.astype(jnp.float32),
                              kc.astype(jnp.float32)))
        n_new = (carry_scale[..., None] * n
                 + jnp.einsum("blh,blhd->bhd", w_s,
                              kc.astype(jnp.float32)))
        return (c_new, n_new, m_new), out

    inputs = (chunkify(q), chunkify(k), chunkify(v),
              chunkify(i_gate), chunkify(f_gate))
    (c_f, n_f, m_f), outs = jax.lax.scan(body, (c0, n0, m0), inputs)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, h, dh)
    return out.astype(v.dtype), {"c": c_f, "n": n_f, "m": m_f}


def mlstm_forward(params, cfg, x, *, chunk: int = 256, state=None,
                  return_state: bool = False):
    b, s, d = x.shape
    d_inner = cfg.xlstm_pf * d
    h = cfg.n_heads
    dh = d_inner // h
    xz = nn.dense(params["up"], x)
    xi_raw, z = jnp.split(xz, 2, axis=-1)
    conv_state = None if state is None else state["conv"]
    kw = params["conv_w"].shape[0]
    if conv_state is not None:
        xp = jnp.concatenate([conv_state, xi_raw], axis=1)
        xc = sum(xp[:, i:i + s, :] * params["conv_w"][i] for i in range(kw))
        xi = jax.nn.silu(xc + params["conv_b"])
    else:
        xi = _causal_conv1d(xi_raw, params["conv_w"], params["conv_b"])
    q = nn.dense(params["wq"], xi).reshape(b, s, h, dh)
    k = nn.dense(params["wk"], xi).reshape(b, s, h, dh)
    v = nn.dense(params["wv"], xi).reshape(b, s, h, dh)
    i_gate = nn.dense(params["w_i"], xi.astype(jnp.float32))
    f_gate = nn.dense(params["w_f"], xi.astype(jnp.float32))
    mstate = None if state is None else {k_: state[k_]
                                         for k_ in ("c", "n", "m")}
    o, new_state = mlstm_chunked(q, k, v, i_gate, f_gate, chunk=chunk,
                                 state=mstate)
    o = o.reshape(b, s, d_inner)
    o = nn.rmsnorm(params["norm"], o, eps=cfg.norm_eps)
    o = o * jax.nn.silu(z)
    o = shard_activation(o, ("batch", None, "mlp"))
    y = nn.dense(params["down"], o)
    if return_state:
        if conv_state is None:
            pad = jnp.zeros((b, kw - 1, d_inner), xi_raw.dtype)
            xp_full = jnp.concatenate([pad, xi_raw], axis=1)
        else:
            xp_full = jnp.concatenate([conv_state, xi_raw], axis=1)
        new_state = dict(new_state)
        new_state["conv"] = xp_full[:, -(kw - 1):, :]
        return y, new_state
    return y


def mlstm_state_spec(cfg, batch: int, dtype=jnp.float32):
    d_inner = cfg.xlstm_pf * cfg.d_model
    h = cfg.n_heads
    dh = d_inner // h
    return {
        "c": jax.ShapeDtypeStruct((batch, h, dh, dh), dtype),
        "n": jax.ShapeDtypeStruct((batch, h, dh), dtype),
        "m": jax.ShapeDtypeStruct((batch, h), dtype),
        "conv": jax.ShapeDtypeStruct(
            (batch, cfg.xlstm_conv - 1, d_inner), dtype),
    }


def mlstm_decode(params, cfg, x, state):
    """Recurrent mLSTM step. x: (B,1,D). State: c (B,H,Dh,Dh), n, m, conv."""
    b, _, d = x.shape
    d_inner = cfg.xlstm_pf * d
    h = cfg.n_heads
    dh = d_inner // h
    xz = nn.dense(params["up"], x)
    xi, z = jnp.split(xz, 2, axis=-1)
    k_w = params["conv_w"].shape[0]
    xp = jnp.concatenate([state["conv"], xi], axis=1)
    xc = sum(xp[:, i:i + 1, :] * params["conv_w"][i] for i in range(k_w))
    xc = jax.nn.silu(xc + params["conv_b"])
    new_conv = xp[:, -(k_w - 1):, :]

    q = nn.dense(params["wq"], xc).reshape(b, h, dh)
    k = nn.dense(params["wk"], xc).reshape(b, h, dh) * (dh ** -0.5)
    v = nn.dense(params["wv"], xc).reshape(b, h, dh)
    i_raw = nn.dense(params["w_i"], xc.astype(jnp.float32))[:, 0]   # (B,H)
    f_raw = nn.dense(params["w_f"], xc.astype(jnp.float32))[:, 0]

    log_f = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(log_f + state["m"], i_raw)
    i_s = jnp.exp(i_raw - m_new)
    f_s = jnp.exp(log_f + state["m"] - m_new)

    c_new = (f_s[..., None, None] * state["c"]
             + i_s[..., None, None] * jnp.einsum("bhd,bhe->bhde", v, k))
    n_new = f_s[..., None] * state["n"] + i_s[..., None] * k
    hnum = jnp.einsum("bhde,bhe->bhd", c_new, q)
    hden = jnp.maximum(jnp.abs(jnp.einsum("bhe,bhe->bh", n_new, q)),
                       jnp.exp(-m_new))
    o = (hnum / hden[..., None]).reshape(b, 1, d_inner).astype(x.dtype)
    o = nn.rmsnorm(params["norm"], o, eps=cfg.norm_eps) * jax.nn.silu(z)
    y = nn.dense(params["down"], o)
    return y, {"c": c_new, "n": n_new, "m": m_new, "conv": new_conv}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_spec(cfg, dtype):
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    gates = {}
    for g in ("i", "f", "z", "o"):
        gates[f"w_{g}"] = nn.dense_spec(d, d, "embed", "heads", dtype=dtype)
        gates[f"r_{g}"] = nn.ParamSpec((h, dh, dh), (None, "heads", None),
                                       init="fanin", dtype=dtype)
        gates[f"b_{g}"] = nn.ParamSpec((d,), ("heads",), init="zeros",
                                       dtype=jnp.float32)
    ff = max(1, int(cfg.d_model * 4 // 3))
    gates["norm"] = nn.rmsnorm_spec(d, dtype=dtype)
    gates["ff_up"] = nn.dense_spec(d, 2 * ff, "embed", "mlp", dtype=dtype)
    gates["ff_down"] = nn.dense_spec(ff, d, "mlp", "embed", dtype=dtype,
                                     init="fanin_deep",
                                     scale=1.0 / max(cfg.n_layers, 1) ** 0.5)
    return gates


def slstm_state_spec(cfg, batch: int, dtype=jnp.float32):
    d = cfg.d_model
    h = cfg.n_heads
    return {
        "c": jax.ShapeDtypeStruct((batch, d), dtype),
        "n": jax.ShapeDtypeStruct((batch, d), dtype),
        "h": jax.ShapeDtypeStruct((batch, d), dtype),
        "m": jax.ShapeDtypeStruct((batch, d), dtype),
    }


def _slstm_cell(params, cfg, x_t, state):
    """One sLSTM step. x_t: (B, D)."""
    b, d = x_t.shape
    h = cfg.n_heads
    dh = d // h
    h_prev = state["h"].reshape(b, h, dh)

    def gate(name):
        wx = nn.dense(params[f"w_{name}"], x_t).reshape(b, h, dh)
        rh = jnp.einsum("bhd,hde->bhe", h_prev,
                        params[f"r_{name}"].astype(h_prev.dtype))
        return (wx + rh).reshape(b, d).astype(jnp.float32) \
            + params[f"b_{name}"]

    i_raw, f_raw, z_raw, o_raw = gate("i"), gate("f"), gate("z"), gate("o")
    log_f = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(log_f + state["m"], i_raw)
    i_s = jnp.exp(i_raw - m_new)
    f_s = jnp.exp(log_f + state["m"] - m_new)
    c_new = f_s * state["c"] + i_s * jnp.tanh(z_raw)
    n_new = f_s * state["n"] + i_s
    h_new = jax.nn.sigmoid(o_raw) * c_new / jnp.maximum(n_new, 1e-6)
    return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}


def slstm_forward(params, cfg, x, *, state=None):
    """Recurrent scan over time. x: (B,S,D). Returns (y, final_state)."""
    b, s, d = x.shape
    if state is None:
        state = {k: jnp.zeros((b, d), jnp.float32)
                 for k in ("c", "n", "h", "m")}

    def body(st, x_t):
        new = _slstm_cell(params, cfg, x_t, st)
        return new, new["h"]

    final, hs = jax.lax.scan(body, state, jnp.moveaxis(x, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    y = nn.rmsnorm(params["norm"], y, eps=cfg.norm_eps)
    up = nn.dense(params["ff_up"], y)
    a, g = jnp.split(up, 2, axis=-1)
    y = nn.dense(params["ff_down"], jax.nn.gelu(a) * g)
    return y, final


def slstm_decode(params, cfg, x, state):
    new = _slstm_cell(params, cfg, x[:, 0, :], state)
    y = new["h"][:, None, :].astype(x.dtype)
    y = nn.rmsnorm(params["norm"], y, eps=cfg.norm_eps)
    up = nn.dense(params["ff_up"], y)
    a, g = jnp.split(up, 2, axis=-1)
    return nn.dense(params["ff_down"], jax.nn.gelu(a) * g), new
