"""Top-level language models for every assigned architecture family.

  DecoderLM  dense | moe | vlm   (vlm = dense + precomputed vision prefix)
  HybridLM   zamba2: scanned mamba2 segments + a SHARED attention block
  XLSTMLM    interleaved mLSTM / sLSTM segments
  EncDecLM   whisper: encoder stack + cross-attending decoder

Uniform interface (consumed by train/serve/launch):
  param_specs() / init(key) / abstract_params()
  loss(params, batch)                       -> (scalar, metrics)
  init_caches(batch, max_len[, abstract])   -> decode caches / states
  prefill(params, batch)                    -> (last_logits, caches)
  decode_step(params, token, caches, cache_len) -> (logits, caches)

The LM head loss is CHUNKED over the sequence (never materializes the full
(B, S, V) logits — 1M tokens x 152k vocab would be ~0.6 TB; DESIGN.md sec 7).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention, blocks, nn
from repro.sharding import shard_activation

Array = jax.Array


# ---------------------------------------------------------------------------
# Shared pieces
# ---------------------------------------------------------------------------

def _unembed_spec(cfg, dtype):
    return {"w": nn.ParamSpec((cfg.d_model, cfg.vocab), ("embed", "vocab"),
                              init="fanin", dtype=dtype)}


def chunked_cross_entropy(x, targets, mask, w_unembed, *, chunk: int = 1024):
    """Mean NLL over masked positions, scanned over sequence chunks.

    x: (B,S,D) final hidden; targets: (B,S) int32; mask: (B,S) float32.
    """
    b, s, d = x.shape
    chunk = min(chunk, s)
    if s % chunk != 0:
        chunk = s
    nc = s // chunk
    xc = jnp.moveaxis(x.reshape(b, nc, chunk, d), 1, 0)
    tc = jnp.moveaxis(targets.reshape(b, nc, chunk), 1, 0)
    mc = jnp.moveaxis(mask.reshape(b, nc, chunk), 1, 0)

    def body(carry, inp):
        tot, cnt = carry
        xcc, tcc, mcc = inp
        logits = (xcc @ w_unembed).astype(jnp.float32)
        logits = shard_activation(logits, ("batch", None, "act_vocab"))
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tcc[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mcc
        return (tot + jnp.sum(nll), cnt + jnp.sum(mcc)), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xc, tc, mc))
    return tot / jnp.maximum(cnt, 1.0)


def _positions(b, s, offset=0):
    return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, :] + offset,
                            (b, s))


def _logits_last(cfg, params, h_last):
    """(B,1,D) -> (B,1,V) logits for decode/prefill outputs."""
    return (h_last @ params["unembed"]["w"]).astype(jnp.float32)


class BaseLM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.dtype = cfg.jnp_dtype

    # --- params -----------------------------------------------------------
    def param_specs(self):
        raise NotImplementedError

    def init(self, key):
        return nn.init_params(key, self.param_specs())

    def abstract_params(self):
        return nn.abstract_params(self.param_specs())

    def param_axes(self):
        return nn.logical_axes(self.param_specs())

    # --- API defaults ------------------------------------------------------
    def loss(self, params, batch):
        raise NotImplementedError

    def init_caches(self, batch: int, max_len: int, abstract: bool = False):
        raise NotImplementedError

    def cache_axes(self, caches):
        """Logical axes tree for decode caches (batch/kv sharding)."""
        def one(x):
            if x.ndim >= 3:
                return ("layers", "batch") + (None,) * (x.ndim - 3) + ("kv",)
            return (None,) * x.ndim
        return jax.tree.map(one, caches)


# ---------------------------------------------------------------------------
# DecoderLM: dense | moe | vlm
# ---------------------------------------------------------------------------

class DecoderLM(BaseLM):
    def param_specs(self):
        cfg, dt = self.cfg, self.dtype
        spec = {
            "embed": nn.embedding_spec(cfg.vocab, cfg.d_model, dtype=dt),
            "layers": nn.stack_specs(blocks.decoder_block_spec(cfg, dt),
                                     cfg.n_layers),
            "final_norm": (nn.layernorm_spec if cfg.norm == "layernorm"
                           else nn.rmsnorm_spec)(cfg.d_model, dtype=dt),
            "unembed": _unembed_spec(cfg, dt),
        }
        return spec

    def _final_norm(self, params, h):
        fn = nn.layernorm if self.cfg.norm == "layernorm" else nn.rmsnorm
        return fn(params["final_norm"], h, eps=self.cfg.norm_eps)

    def _embed_input(self, params, batch):
        cfg = self.cfg
        h = nn.embed(params["embed"], batch["tokens"]).astype(self.dtype)
        n_vis = 0
        if cfg.family == "vlm" and "vision_embeds" in batch:
            vis = batch["vision_embeds"].astype(self.dtype)
            h = jnp.concatenate([vis, h], axis=1)
            n_vis = vis.shape[1]
        return shard_activation(h, ("batch", None, "act_embed")), n_vis

    def _backbone(self, params, h, positions, collect_kv=False):
        cfg = self.cfg
        h, aux, kvs = blocks.stack_forward(
            params["layers"], cfg, h, positions, causal=True,
            q_chunk=cfg.attn_q_chunk, remat=cfg.remat, collect_kv=collect_kv)
        return self._final_norm(params, h), aux, kvs

    def loss(self, params, batch):
        cfg = self.cfg
        h, n_vis = self._embed_input(params, batch)
        b, s, _ = h.shape
        h, aux, _ = self._backbone(params, h, _positions(b, s))
        if n_vis:
            h = h[:, n_vis:, :]
        mask = batch.get("loss_mask")
        if mask is None:
            mask = jnp.ones(batch["targets"].shape, jnp.float32)
        ce = chunked_cross_entropy(h, batch["targets"], mask,
                                   params["unembed"]["w"])
        loss = ce + 0.01 * aux
        return loss, {"ce": ce, "aux": aux}

    # --- serving -----------------------------------------------------------
    def init_caches(self, batch: int, max_len: int, abstract: bool = False):
        cfg = self.cfg
        spec = attention.KVCacheSpec(batch, max_len, cfg.n_kv_heads,
                                     cfg.d_head, dtype=cfg.jnp_kv_dtype)
        one = spec.abstract() if abstract else spec.zeros()

        def stack(x):
            if abstract:
                return jax.ShapeDtypeStruct((cfg.n_layers,) + x.shape,
                                            x.dtype)
            return jnp.zeros((cfg.n_layers,) + x.shape, x.dtype)

        return jax.tree.map(stack, one)

    def prefill(self, params, batch, max_len: Optional[int] = None):
        cfg = self.cfg
        h, n_vis = self._embed_input(params, batch)
        b, s, _ = h.shape
        h, _, kvs = self._backbone(params, h, _positions(b, s),
                                   collect_kv=True)
        k, v = kvs  # (L, B, S, KVH, Dh)
        flat = cfg.n_kv_heads * cfg.d_head
        kvdt = cfg.jnp_kv_dtype
        caches = {"k": k.reshape(cfg.n_layers, b, s, flat).astype(kvdt),
                  "v": v.reshape(cfg.n_layers, b, s, flat).astype(kvdt)}
        if max_len is not None and max_len > s:
            pad = max_len - s
            caches = jax.tree.map(
                lambda c: jnp.pad(c, ((0, 0), (0, 0), (0, pad), (0, 0))),
                caches)
        logits = _logits_last(cfg, params, h[:, -1:, :])
        return logits, caches

    def decode_step(self, params, token, caches, cache_len):
        cfg = self.cfg
        h = nn.embed(params["embed"], token).astype(self.dtype)
        h, k_news, v_news = blocks.stack_decode_readonly(
            params["layers"], cfg, h, caches, cache_len,
            unroll=cfg.decode_unroll)
        caches = blocks.write_cache_column(caches, k_news, v_news,
                                           cache_len)
        h = self._final_norm(params, h)
        return _logits_last(cfg, params, h), caches


# ---------------------------------------------------------------------------
# HybridLM: zamba2 — mamba segments + shared attention block
# ---------------------------------------------------------------------------

class HybridLM(BaseLM):
    def _segments(self):
        cfg = self.cfg
        seg = cfg.hybrid_shared_every
        q, r = divmod(cfg.n_layers, seg)
        return seg, q, r

    def param_specs(self):
        cfg, dt = self.cfg, self.dtype
        return {
            "embed": nn.embedding_spec(cfg.vocab, cfg.d_model, dtype=dt),
            "mamba": nn.stack_specs(blocks.mamba_block_spec(cfg, dt),
                                    cfg.n_layers),
            "shared_attn": blocks.decoder_block_spec(
                dataclasses.replace(cfg, family="dense"), dt),
            "final_norm": nn.rmsnorm_spec(cfg.d_model, dtype=dt),
            "unembed": _unembed_spec(cfg, dt),
        }

    def n_shared_invocations(self):
        _, q, _ = self._segments()
        return q

    def _split_stacked(self, stacked):
        seg, q, r = self._segments()
        head = jax.tree.map(
            lambda p: p[:q * seg].reshape(q, seg, *p.shape[1:]), stacked)
        tail = (jax.tree.map(lambda p: p[q * seg:], stacked)
                if r else None)
        return head, tail

    def _forward(self, params, h, positions):
        cfg = self.cfg
        dense_cfg = dataclasses.replace(cfg, family="dense")
        head, tail = self._split_stacked(params["mamba"])
        seg, q, r = self._segments()
        for i in range(q):
            seg_params = jax.tree.map(lambda p: p[i], head)
            h = blocks.mamba_stack(seg_params, cfg, h, chunk=cfg.ssd_chunk,
                                   remat=cfg.remat)
            h, _, _ = blocks.stack_forward(  # shared block: 1-layer "stack"
                jax.tree.map(lambda p: p[None], params["shared_attn"]),
                dense_cfg, h, positions, q_chunk=cfg.attn_q_chunk,
                remat=cfg.remat)
        if tail is not None:
            h = blocks.mamba_stack(tail, cfg, h, chunk=cfg.ssd_chunk,
                                   remat=cfg.remat)
        return nn.rmsnorm(params["final_norm"], h, eps=cfg.norm_eps)

    def loss(self, params, batch):
        h = nn.embed(params["embed"], batch["tokens"]).astype(self.dtype)
        h = shard_activation(h, ("batch", None, "act_embed"))
        b, s, _ = h.shape
        h = self._forward(params, h, _positions(b, s))
        mask = batch.get("loss_mask")
        if mask is None:
            mask = jnp.ones(batch["targets"].shape, jnp.float32)
        ce = chunked_cross_entropy(h, batch["targets"], mask,
                                   params["unembed"]["w"])
        return ce, {"ce": ce}

    # --- serving -----------------------------------------------------------
    def init_caches(self, batch: int, max_len: int, abstract: bool = False):
        from repro.models import ssm as _ssm
        cfg = self.cfg
        seg, q, r = self._segments()
        mamba_one = _ssm.mamba2_state_spec(cfg, batch, dtype=self.dtype)

        def stack(x, n):
            if abstract:
                return jax.ShapeDtypeStruct((n,) + x.shape, x.dtype)
            return jnp.zeros((n,) + x.shape, x.dtype)

        mamba_states = jax.tree.map(
            functools.partial(stack, n=cfg.n_layers), mamba_one)
        kv = attention.KVCacheSpec(batch, max_len, cfg.n_kv_heads,
                                   cfg.d_head, dtype=cfg.jnp_kv_dtype)
        one = kv.abstract() if abstract else kv.zeros()
        shared = jax.tree.map(functools.partial(stack, n=q), one)
        return {"mamba": mamba_states, "shared": shared}

    def prefill(self, params, batch, max_len: Optional[int] = None):
        """Process the prompt, returning (last logits, decode caches)."""
        cfg = self.cfg
        dense_cfg = dataclasses.replace(cfg, family="dense")
        tokens = batch["tokens"]
        b, s = tokens.shape
        max_len = max_len or s
        h = nn.embed(params["embed"], tokens).astype(self.dtype)
        h = shard_activation(h, ("batch", None, "act_embed"))
        positions = _positions(b, s)
        head, tail = self._split_stacked(params["mamba"])
        seg, q, r = self._segments()
        flat = cfg.n_kv_heads * cfg.d_head
        m_states, sh_k, sh_v = [], [], []
        for i in range(q):
            seg_params = jax.tree.map(lambda p: p[i], head)
            h, st = blocks.mamba_stack_prefill(seg_params, cfg, h,
                                               chunk=cfg.ssd_chunk,
                                               remat=cfg.remat)
            m_states.append(st)
            h, _, kvs = blocks.stack_forward(
                jax.tree.map(lambda p: p[None], params["shared_attn"]),
                dense_cfg, h, positions, q_chunk=cfg.attn_q_chunk,
                remat=cfg.remat, collect_kv=True)
            k, v = kvs  # (1, B, S, KVH, Dh)
            sh_k.append(k.reshape(b, s, flat).astype(cfg.jnp_kv_dtype))
            sh_v.append(v.reshape(b, s, flat).astype(cfg.jnp_kv_dtype))
        if tail is not None:
            h, st_tail = blocks.mamba_stack_prefill(tail, cfg, h,
                                                    chunk=cfg.ssd_chunk,
                                                    remat=cfg.remat)
            m_states.append(st_tail)
        mamba = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                             *m_states)
        pad = max_len - s
        shared = {
            "k": jnp.pad(jnp.stack(sh_k), ((0, 0), (0, 0), (0, pad),
                                           (0, 0))),
            "v": jnp.pad(jnp.stack(sh_v), ((0, 0), (0, 0), (0, pad),
                                           (0, 0))),
        }
        h = nn.rmsnorm(params["final_norm"], h, eps=cfg.norm_eps)
        logits = _logits_last(cfg, params, h[:, -1:, :])
        return logits, {"mamba": mamba, "shared": shared}

    def decode_step(self, params, token, caches, cache_len):
        cfg = self.cfg
        dense_cfg = dataclasses.replace(cfg, family="dense")
        h = nn.embed(params["embed"], token).astype(self.dtype)
        head, tail = self._split_stacked(params["mamba"])
        seg, q, r = self._segments()
        m_states = caches["mamba"]
        m_head = jax.tree.map(
            lambda p: p[:q * seg].reshape(q, seg, *p.shape[1:]), m_states)
        m_tail = (jax.tree.map(lambda p: p[q * seg:], m_states)
                  if r else None)
        new_head, new_shared = [], []
        for i in range(q):
            seg_params = jax.tree.map(lambda p: p[i], head)
            seg_state = jax.tree.map(lambda p: p[i], m_head)
            h, st = blocks.mamba_stack_decode(seg_params, cfg, h, seg_state)
            new_head.append(st)
            sh_cache = jax.tree.map(lambda c: c[i], caches["shared"])
            h, sh_cache = blocks.decoder_block_decode(
                params["shared_attn"], dense_cfg, h, sh_cache, cache_len)
            new_shared.append(sh_cache)
        new_mamba = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                                 *new_head)
        if m_tail is not None:
            h, st_tail = blocks.mamba_stack_decode(tail, cfg, h, m_tail)
            new_mamba = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], axis=0),
                new_mamba, st_tail)
        new_shared = jax.tree.map(lambda *xs: jnp.stack(xs), *new_shared)
        h = nn.rmsnorm(params["final_norm"], h, eps=cfg.norm_eps)
        logits = _logits_last(cfg, params, h)
        return logits, {"mamba": new_mamba, "shared": new_shared}


# ---------------------------------------------------------------------------
# XLSTMLM
# ---------------------------------------------------------------------------

class XLSTMLM(BaseLM):
    def _segments(self):
        cfg = self.cfg
        every = max(cfg.slstm_every, 1)
        n_seg, rem = divmod(cfg.n_layers, every)
        return every, n_seg, rem  # each segment: (every-1) mLSTM + 1 sLSTM

    def param_specs(self):
        cfg, dt = self.cfg, self.dtype
        every, n_seg, rem = self._segments()
        spec = {
            "embed": nn.embedding_spec(cfg.vocab, cfg.d_model, dtype=dt),
            "final_norm": nn.rmsnorm_spec(cfg.d_model, dtype=dt),
            "unembed": _unembed_spec(cfg, dt),
        }
        if n_seg:
            m_spec = nn.stack_specs(blocks.mlstm_block_spec(cfg, dt),
                                    every - 1)
            spec["mlstm"] = nn.stack_specs(m_spec, n_seg)
            spec["slstm"] = nn.stack_specs(blocks.slstm_block_spec(cfg, dt),
                                           n_seg)
        if rem:
            spec["mlstm_tail"] = nn.stack_specs(
                blocks.mlstm_block_spec(cfg, dt), rem)
        return spec

    def _forward(self, params, h):
        cfg = self.cfg
        every, n_seg, rem = self._segments()
        for i in range(n_seg):
            seg = jax.tree.map(lambda p: p[i], params["mlstm"])
            h = blocks.mlstm_stack(seg, cfg, h, remat=cfg.remat)
            sl = jax.tree.map(lambda p: p[i], params["slstm"])
            h, _ = blocks.slstm_block(sl, cfg, h)
        if rem:
            h = blocks.mlstm_stack(params["mlstm_tail"], cfg, h,
                                   remat=cfg.remat)
        return nn.rmsnorm(params["final_norm"], h, eps=cfg.norm_eps)

    def loss(self, params, batch):
        h = nn.embed(params["embed"], batch["tokens"]).astype(self.dtype)
        h = shard_activation(h, ("batch", None, "act_embed"))
        h = self._forward(params, h)
        mask = batch.get("loss_mask")
        if mask is None:
            mask = jnp.ones(batch["targets"].shape, jnp.float32)
        ce = chunked_cross_entropy(h, batch["targets"], mask,
                                   params["unembed"]["w"])
        return ce, {"ce": ce}

    def init_caches(self, batch: int, max_len: int, abstract: bool = False):
        from repro.models import xlstm as _x
        cfg = self.cfg
        every, n_seg, rem = self._segments()
        m_one = _x.mlstm_state_spec(cfg, batch, dtype=jnp.float32)
        s_one = _x.slstm_state_spec(cfg, batch, dtype=jnp.float32)

        def stack(x, dims):
            if abstract:
                return jax.ShapeDtypeStruct(dims + x.shape, x.dtype)
            return jnp.zeros(dims + x.shape, x.dtype)

        out = {}
        if n_seg:
            out["mlstm"] = jax.tree.map(
                lambda x: stack(x, (n_seg, every - 1)), m_one)
            out["slstm"] = jax.tree.map(lambda x: stack(x, (n_seg,)), s_one)
        if rem:
            out["mlstm_tail"] = jax.tree.map(lambda x: stack(x, (rem,)),
                                             m_one)
        return out

    def prefill(self, params, batch, max_len: Optional[int] = None):
        cfg = self.cfg
        every, n_seg, rem = self._segments()
        tokens = batch["tokens"]
        h = nn.embed(params["embed"], tokens).astype(self.dtype)
        h = shard_activation(h, ("batch", None, "act_embed"))
        m_states, s_states = [], []
        for i in range(n_seg):
            seg = jax.tree.map(lambda p: p[i], params["mlstm"])
            h, st = blocks.mlstm_stack_prefill(seg, cfg, h,
                                               remat=cfg.remat)
            m_states.append(st)
            sl = jax.tree.map(lambda p: p[i], params["slstm"])
            h, sst = blocks.slstm_block(sl, cfg, h)
            s_states.append(sst)
        caches = {}
        if n_seg:
            caches["mlstm"] = jax.tree.map(lambda *xs: jnp.stack(xs),
                                           *m_states)
            caches["slstm"] = jax.tree.map(lambda *xs: jnp.stack(xs),
                                           *s_states)
        if rem:
            h, st_tail = blocks.mlstm_stack_prefill(
                params["mlstm_tail"], cfg, h, remat=cfg.remat)
            caches["mlstm_tail"] = st_tail
        h = nn.rmsnorm(params["final_norm"], h, eps=cfg.norm_eps)
        return _logits_last(cfg, params, h[:, -1:, :]), caches

    def decode_step(self, params, token, caches, cache_len):
        cfg = self.cfg
        every, n_seg, rem = self._segments()
        h = nn.embed(params["embed"], token).astype(self.dtype)
        new_m, new_s = [], []
        for i in range(n_seg):
            seg = jax.tree.map(lambda p: p[i], params["mlstm"])
            st = jax.tree.map(lambda p: p[i], caches["mlstm"])
            h, st = blocks.mlstm_stack_decode(seg, cfg, h, st)
            new_m.append(st)
            sl = jax.tree.map(lambda p: p[i], params["slstm"])
            sst = jax.tree.map(lambda p: p[i], caches["slstm"])
            h, sst = blocks.slstm_block_decode(sl, cfg, h, sst)
            new_s.append(sst)
        out = {}
        if n_seg:
            out["mlstm"] = jax.tree.map(lambda *xs: jnp.stack(xs), *new_m)
            out["slstm"] = jax.tree.map(lambda *xs: jnp.stack(xs), *new_s)
        if rem:
            h, st_tail = blocks.mlstm_stack_decode(
                params["mlstm_tail"], cfg, h, caches["mlstm_tail"])
            out["mlstm_tail"] = st_tail
        h = nn.rmsnorm(params["final_norm"], h, eps=cfg.norm_eps)
        return _logits_last(cfg, params, h), out


# ---------------------------------------------------------------------------
# EncDecLM (whisper)
# ---------------------------------------------------------------------------

class EncDecLM(BaseLM):
    def param_specs(self):
        cfg, dt = self.cfg, self.dtype
        norm_spec = (nn.layernorm_spec if cfg.norm == "layernorm"
                     else nn.rmsnorm_spec)
        return {
            "enc_pos": nn.ParamSpec((cfg.max_enc_len, cfg.d_model),
                                    (None, "embed"), init="normal",
                                    dtype=dt),
            "enc_layers": nn.stack_specs(blocks.encoder_block_spec(cfg, dt),
                                         cfg.enc_layers),
            "enc_norm": norm_spec(cfg.d_model, dtype=dt),
            "embed": nn.embedding_spec(cfg.vocab, cfg.d_model, dtype=dt),
            "dec_pos": nn.ParamSpec((cfg.max_seq, cfg.d_model),
                                    (None, "embed"), init="normal",
                                    dtype=dt),
            "dec_layers": nn.stack_specs(blocks.encdec_block_spec(cfg, dt),
                                         cfg.n_layers),
            "final_norm": norm_spec(cfg.d_model, dtype=dt),
            "unembed": _unembed_spec(cfg, dt),
        }

    def _norm_fn(self):
        return nn.layernorm if self.cfg.norm == "layernorm" else nn.rmsnorm

    def encode(self, params, frames):
        cfg = self.cfg
        b, se, _ = frames.shape
        h = frames.astype(self.dtype) + params["enc_pos"][None, :se, :]
        h = shard_activation(h, ("batch", None, "act_embed"))
        h = blocks.encoder_stack(params["enc_layers"], cfg, h,
                                 _positions(b, se),
                                 q_chunk=cfg.attn_q_chunk, remat=cfg.remat)
        return self._norm_fn()(params["enc_norm"], h, eps=cfg.norm_eps)

    def loss(self, params, batch):
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        b, s = tokens.shape
        h = nn.embed(params["embed"], tokens).astype(self.dtype)
        h = h + params["dec_pos"][None, :s, :]
        h, _ = blocks.encdec_stack(params["dec_layers"], cfg, h, enc_out,
                                   _positions(b, s),
                                   q_chunk=cfg.attn_q_chunk, remat=cfg.remat)
        h = self._norm_fn()(params["final_norm"], h, eps=cfg.norm_eps)
        mask = batch.get("loss_mask")
        if mask is None:
            mask = jnp.ones(batch["targets"].shape, jnp.float32)
        ce = chunked_cross_entropy(h, batch["targets"], mask,
                                   params["unembed"]["w"])
        return ce, {"ce": ce}

    def init_caches(self, batch: int, max_len: int, abstract: bool = False,
                    enc_len: Optional[int] = None):
        cfg = self.cfg
        enc_len = enc_len or min(cfg.max_enc_len, 1500)
        kv = attention.KVCacheSpec(batch, max_len, cfg.n_kv_heads,
                                   cfg.d_head, dtype=cfg.jnp_kv_dtype)
        one = kv.abstract() if abstract else kv.zeros()
        cross_kv = attention.KVCacheSpec(batch, enc_len, cfg.n_kv_heads,
                                         cfg.d_head, dtype=cfg.jnp_kv_dtype)
        cone = cross_kv.abstract() if abstract else cross_kv.zeros()

        def stack(x):
            if abstract:
                return jax.ShapeDtypeStruct((cfg.n_layers,) + x.shape,
                                            x.dtype)
            return jnp.zeros((cfg.n_layers,) + x.shape, x.dtype)

        return {"self": jax.tree.map(stack, one),
                "cross": jax.tree.map(stack, cone)}

    def prefill(self, params, batch, max_len: Optional[int] = None):
        """Encode frames + run the decoder prompt, seeding self/cross
        caches for decode."""
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        b, s = tokens.shape
        max_len = max_len or s
        h = nn.embed(params["embed"], tokens).astype(self.dtype)
        h = h + params["dec_pos"][None, :s, :]
        h, kvs = blocks.encdec_stack(params["dec_layers"], cfg, h, enc_out,
                                     _positions(b, s),
                                     q_chunk=cfg.attn_q_chunk,
                                     remat=cfg.remat, collect_kv=True)
        k, v = kvs  # (L, B, S, KVH, Dh)
        flat = cfg.n_kv_heads * cfg.d_head
        pad = max_len - s
        kvdt = cfg.jnp_kv_dtype
        self_caches = {
            "k": jnp.pad(k.reshape(cfg.n_layers, b, s, flat),
                         ((0, 0), (0, 0), (0, pad), (0, 0))).astype(kvdt),
            "v": jnp.pad(v.reshape(cfg.n_layers, b, s, flat),
                         ((0, 0), (0, 0), (0, pad), (0, 0))).astype(kvdt),
        }

        def fill_cross(_, lp):
            return None, attention.cross_kv(lp["cross"], cfg, enc_out)

        _, cross = jax.lax.scan(fill_cross, None, params["dec_layers"])
        cross = jax.tree.map(lambda c: c.astype(cfg.jnp_kv_dtype), cross)
        h = self._norm_fn()(params["final_norm"], h, eps=cfg.norm_eps)
        logits = _logits_last(cfg, params, h[:, -1:, :])
        return logits, {"self": self_caches, "cross": cross}

    def decode_step(self, params, token, caches, cache_len):
        cfg = self.cfg
        b = token.shape[0]
        h = nn.embed(params["embed"], token).astype(self.dtype)
        pos = jnp.take(params["dec_pos"],
                       jnp.full((1,), cache_len, jnp.int32), axis=0)
        h = h + pos[None, :, :]
        h, self_caches = blocks.encdec_stack_decode(
            params["dec_layers"], cfg, h, caches["self"], caches["cross"],
            cache_len)
        h = self._norm_fn()(params["final_norm"], h, eps=cfg.norm_eps)
        return _logits_last(cfg, params, h), {"self": self_caches,
                                              "cross": caches["cross"]}


FAMILIES = {
    "dense": DecoderLM,
    "moe": DecoderLM,
    "vlm": DecoderLM,
    "hybrid": HybridLM,
    "xlstm": XLSTMLM,
    "encdec": EncDecLM,
}


def build_model(cfg: ArchConfig) -> BaseLM:
    return FAMILIES[cfg.family](cfg)


LMModel = BaseLM
