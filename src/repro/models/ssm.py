"""Mamba2 (SSD) blocks — the zamba2 hybrid backbone and the long-context
(sub-quadratic) path of the zoo.

Training/prefill uses the chunked SSD algorithm (Mamba2 paper, "minimal
SSD"): intra-chunk quadratic term (MXU matmuls over chunk length) + an
inter-chunk state recurrence (lax.scan over chunks). O(S * L) compute and
O(1) state, which is what makes the long_500k shape feasible where softmax
attention is not (DESIGN.md section 6).

Decode keeps (conv_state, ssm_state) per layer and advances one token in
O(d_inner * d_state).

Simplifications vs the reference CUDA implementation (documented per the
hardware-adaptation rule): n_groups = 1 (B, C shared across heads), no
norm-before-gate variant, sequence length must divide the chunk size.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import nn
from repro.sharding import shard_activation

Array = jax.Array


def mamba2_spec(cfg, dtype):
    d = cfg.d_model
    d_inner = cfg.ssm_expand * d
    nh = d_inner // cfg.ssm_headdim
    n = cfg.ssm_state
    conv_dim = d_inner + 2 * n
    return {
        "in_proj": nn.dense_spec(d, 2 * d_inner + 2 * n + nh, "embed",
                                 "mlp", dtype=dtype),
        "conv_w": nn.ParamSpec((cfg.ssm_conv, conv_dim), (None, "mlp"),
                               init="fanin", dtype=dtype),
        "conv_b": nn.ParamSpec((conv_dim,), ("mlp",), init="zeros",
                               dtype=dtype),
        "a_log": nn.ParamSpec((nh,), (None,), init="zeros",
                              dtype=jnp.float32),
        "d_skip": nn.ParamSpec((nh,), (None,), init="ones",
                               dtype=jnp.float32),
        "dt_bias": nn.ParamSpec((nh,), (None,), init="zeros",
                                dtype=jnp.float32),
        "norm": nn.rmsnorm_spec(d_inner, dtype=dtype),
        "out_proj": nn.dense_spec(d_inner, d, "mlp", "embed", dtype=dtype,
                                  init="fanin_deep",
                                  scale=1.0 / max(cfg.n_layers, 1) ** 0.5),
    }


def _split_proj(cfg, zxbcdt):
    d_inner = cfg.ssm_expand * cfg.d_model
    nh = d_inner // cfg.ssm_headdim
    n = cfg.ssm_state
    z, xbc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner + 2 * n], axis=-1)
    return z, xbc, dt  # dt: (..., nh)


def _segsum(a):
    """(..., l) log-decays -> (..., l, l) lower-tri cumulative sums."""
    l = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def _causal_conv(xbc, conv_w, conv_b, *, conv_state=None):
    """Depthwise causal conv, width K. xbc: (B, S, C); conv_w: (K, C)."""
    k = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[-1]), xbc.dtype)
    else:
        pad = conv_state
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(xp[:, i:i + xbc.shape[1], :] * conv_w[i] for i in range(k))
    new_state = xp[:, -(k - 1):, :] if k > 1 else pad
    return jax.nn.silu(out + conv_b), new_state


def ssd_chunked(x, log_a, b_mat, c_mat, *, chunk: int, initial_state=None):
    """Chunked SSD scan.

    x:      (B, S, H, P)  dt-scaled inputs
    log_a:  (B, S, H)     per-step log decay (<= 0)
    b_mat:  (B, S, N)     input->state projection (shared across heads)
    c_mat:  (B, S, N)     state->output projection
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    while s % chunk != 0:   # largest divisor of s not exceeding the request
        chunk -= 1
    nc = s // chunk

    xc = x.reshape(bsz, nc, chunk, h, p)
    ac = log_a.reshape(bsz, nc, chunk, h).transpose(0, 3, 1, 2)  # (B,H,C,L)
    bc = b_mat.reshape(bsz, nc, chunk, n)
    cc = c_mat.reshape(bsz, nc, chunk, n)

    a_cum = jnp.cumsum(ac, axis=-1)                                # (B,H,C,L)
    l_mat = jnp.exp(_segsum(ac))                                   # (B,H,C,L,L)

    # 1. intra-chunk (diagonal blocks)
    scores = jnp.einsum("bczn,bcln->bczl", cc, bc)
    y_diag = jnp.einsum("bczl,bhczl,bclhp->bczhp", scores, l_mat, xc)

    # 2. per-chunk end states
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)                # (B,H,C,L)
    states = jnp.einsum("bhcl,bcln,bclhp->bchpn", decay_states, bc, xc)

    # 3. inter-chunk recurrence (scan over chunks)
    chunk_decay = jnp.exp(a_cum[..., -1])                          # (B,H,C)
    if initial_state is None:
        s0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    else:
        s0 = initial_state.astype(jnp.float32)

    def body(carry, inp):
        st, dec = inp                                              # (B,H,P,N),(B,H)
        prev = carry
        new = dec[..., None, None] * prev + st.astype(jnp.float32)
        return new, prev

    st_seq = jnp.moveaxis(states, 1, 0)                            # (C,B,H,P,N)
    dec_seq = jnp.moveaxis(chunk_decay, 2, 0)                      # (C,B,H)
    final, prevs = jax.lax.scan(body, s0, (st_seq, dec_seq))
    prev_states = jnp.moveaxis(prevs, 0, 1)                        # (B,C,H,P,N)

    # 4. inter-chunk contribution
    decay_out = jnp.exp(a_cum)                                     # (B,H,C,L)
    y_off = jnp.einsum("bczn,bchpn,bhcz->bczhp", cc,
                       prev_states.astype(x.dtype), decay_out.astype(x.dtype))

    y = (y_diag + y_off).reshape(bsz, s, h, p).astype(x.dtype)
    return y, final.astype(x.dtype)


def mamba2_forward(params, cfg, x, *, chunk: int = 128, state=None):
    """Full-sequence Mamba2 mixer. Returns (y, (conv_state, ssm_state))."""
    bsz, s, d = x.shape
    d_inner = cfg.ssm_expand * d
    nh = d_inner // cfg.ssm_headdim
    n = cfg.ssm_state

    zxbcdt = nn.dense(params["in_proj"], x)
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    conv_state = None if state is None else state["conv"]
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                 conv_state=conv_state)
    xs, b_mat, c_mat = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"])                      # (B,S,H)
    a = -jnp.exp(params["a_log"])                                  # (H,) < 0
    log_a = dt * a                                                 # (B,S,H)

    xh = xs.reshape(bsz, s, nh, cfg.ssm_headdim)
    xdt = xh * dt[..., None].astype(xh.dtype)
    ssm_state = None if state is None else state["ssm"]
    y, final = ssd_chunked(xdt, log_a, b_mat, c_mat, chunk=min(chunk, s),
                           initial_state=ssm_state)
    y = y + xh * params["d_skip"][None, None, :, None].astype(xh.dtype)
    y = y.reshape(bsz, s, d_inner)
    y = nn.rmsnorm(params["norm"], y * jax.nn.silu(z), eps=cfg.norm_eps)
    y = shard_activation(y, ("batch", None, "mlp"))
    return nn.dense(params["out_proj"], y), {"conv": new_conv, "ssm": final}


def mamba2_decode(params, cfg, x, state):
    """One-token step. x: (B, 1, D); state: {'conv': (B,K-1,C), 'ssm':
    (B,H,P,N)}. O(1) in sequence length — this is what makes long_500k
    decode run where attention cannot."""
    bsz, _, d = x.shape
    d_inner = cfg.ssm_expand * d
    nh = d_inner // cfg.ssm_headdim
    n = cfg.ssm_state

    zxbcdt = nn.dense(params["in_proj"], x)
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                 conv_state=state["conv"])
    xs, b_mat, c_mat = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])
    decay = jnp.exp(dt * a)[:, 0]                                  # (B,H)

    xh = xs.reshape(bsz, nh, cfg.ssm_headdim)
    xdt = xh * dt[:, 0, :, None].astype(xh.dtype)
    outer = jnp.einsum("bhp,bn->bhpn", xdt, b_mat[:, 0])
    new_ssm = decay[..., None, None].astype(xh.dtype) * state["ssm"] + outer
    y = jnp.einsum("bhpn,bn->bhp", new_ssm, c_mat[:, 0])
    y = y + xh * params["d_skip"][None, :, None].astype(xh.dtype)
    y = y.reshape(bsz, 1, d_inner)
    y = nn.rmsnorm(params["norm"], y * jax.nn.silu(z), eps=cfg.norm_eps)
    return (nn.dense(params["out_proj"], y),
            {"conv": new_conv, "ssm": new_ssm})


def mamba2_state_spec(cfg, batch: int, dtype=jnp.float32):
    d_inner = cfg.ssm_expand * cfg.d_model
    nh = d_inner // cfg.ssm_headdim
    conv_dim = d_inner + 2 * cfg.ssm_state
    return {
        "conv": jax.ShapeDtypeStruct((batch, cfg.ssm_conv - 1, conv_dim),
                                     dtype),
        "ssm": jax.ShapeDtypeStruct(
            (batch, nh, cfg.ssm_headdim, cfg.ssm_state), dtype),
    }
