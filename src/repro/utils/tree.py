"""Small pytree utilities shared across subsystems."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_count(tree) -> int:
    """Total number of array elements in a pytree."""
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(tree) if hasattr(x, "shape")))


def tree_bytes(tree) -> int:
    """Total bytes of a pytree of arrays / ShapeDtypeStructs."""
    total = 0
    for x in jax.tree.leaves(tree):
        if hasattr(x, "shape") and hasattr(x, "dtype"):
            total += int(np.prod(x.shape)) * np.dtype(x.dtype).itemsize
    return total


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_cast(tree, dtype):
    """Cast all floating leaves to dtype, leave integer leaves alone."""
    def _cast(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree.map(_cast, tree)


def tree_norm(tree) -> jax.Array:
    """Global L2 norm across a pytree."""
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))
