from repro.utils.tree import (  # noqa: F401
    tree_bytes,
    tree_count,
    tree_norm,
    tree_zeros_like,
    tree_cast,
)
from repro.utils.timing import Timer, TimingStats, time_fn  # noqa: F401
