"""Wall-clock timing helpers for the benchmark harness (CPU-host numbers)."""

from __future__ import annotations

import time

import jax


class Timer:
    """Context-manager timer; .elapsed in seconds."""

    def __enter__(self):
        self.start = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.elapsed = time.perf_counter() - self.start


def time_fn(fn, *args, iters: int = 5, warmup: int = 2, **kwargs) -> float:
    """Median wall-time (seconds) of fn(*args), block_until_ready'd."""
    for _ in range(warmup):
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]
