"""Wall-clock timing helpers for the benchmark harness (CPU-host numbers)."""

from __future__ import annotations

import dataclasses
import statistics
import time

import jax


class Timer:
    """Context-manager timer; .elapsed in seconds."""

    def __enter__(self):
        self.start = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.elapsed = time.perf_counter() - self.start


@dataclasses.dataclass(frozen=True)
class TimingStats:
    """Repeat-measurement summary from `time_fn`.

    Floats coerce to the median, so legacy `float(time_fn(...))` call
    sites (and arithmetic via .median) keep their old meaning.
    """
    median: float
    min: float
    mean: float
    std: float
    n: int
    trimmed: int = 0

    def __float__(self) -> float:
        return self.median


def time_fn(fn, *args, iters: int = 5, warmup: int = 2, trim: int = 0,
            **kwargs) -> TimingStats:
    """Time fn(*args), block_until_ready'd, over `iters` repeats.

    trim: drop the `trim` slowest AND `trim` fastest measurements before
    summarizing (symmetric trim — robust to scheduler noise on shared
    hosts). Requires iters > 2*trim.

    Returns TimingStats; use `.median` (or float()) where a scalar is
    needed.
    """
    if iters <= 2 * trim:
        raise ValueError(f"iters={iters} must exceed 2*trim={2 * trim}")
    for _ in range(warmup):
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    kept = times[trim: len(times) - trim] if trim else times
    return TimingStats(
        median=statistics.median(kept),
        min=kept[0],
        mean=statistics.fmean(kept),
        std=statistics.pstdev(kept) if len(kept) > 1 else 0.0,
        n=len(kept),
        trimmed=trim,
    )
