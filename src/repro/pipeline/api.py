"""Pipeline entry points: raw abundance table → F statistic and p-value.

pipeline()        one study: (n, d) features + (n,) labels, all the way to
                  the permutation p-value under one PipelinePlan.
pipeline_many()   stacked studies through ONE plan (the serving scenario):
                  (S, n, d) features + (S, n) labels.

Both route stage 2 through the hardware-aware engine; stage 1 and the
bridge (dense / stream / fused) come from this package. `permanova()`
delegates here when handed features instead of a matrix, and the launch
CLI exposes it as `--from-features`.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro import engine
from repro.core import permutations
from repro.core.permanova import (PermanovaResult, f_from_sw,
                                  p_value_from_null)
from repro.pipeline import planner as _planner
from repro.pipeline import registry as _registry
from repro.pipeline import streaming as _streaming

Array = jax.Array


def pipeline(x: Array, grouping: Array, *, metric: str = "braycurtis",
             n_perms: int = 999, key: Optional[jax.Array] = None,
             n_groups: Optional[int] = None,
             dist_impl: str = "auto", sw_impl: str = "auto",
             materialize: str = "auto", row_block: Optional[int] = None,
             chunk: Optional[int] = None,
             memory_budget_bytes: Optional[float] = None,
             matrix_budget_bytes: Optional[float] = None,
             slab_budget_bytes: Optional[float] = None,
             dist_tuning: Optional[Dict[str, int]] = None,
             sw_tuning: Optional[Dict[str, int]] = None,
             backend: Optional[str] = None,
             autotune: bool = False) -> PermanovaResult:
    """Full features→p-value PERMANOVA under one joint plan.

    x:           (n, d) abundance table (raw features, NOT distances).
    materialize: 'auto' | 'dense' | 'stream' | 'fused' — whether the (n, n)
                 matrix is built outright, streamed into a single buffer,
                 or never materialized at all.
    Remaining knobs mirror engine.run(); budgets split per stage
    (matrix/slab for distances, memory_budget_bytes for s_W labels).
    For a fixed key every materialization produces the same F and p-value
    (to fp32 accumulation order).
    """
    if key is None:
        key = jax.random.key(0)
    x = jnp.asarray(x)
    if x.ndim != 2:
        raise ValueError(f"features must be (n, d); got shape {x.shape}")
    grouping = jnp.asarray(grouping, dtype=jnp.int32)
    n, d = x.shape
    if n_groups is None:
        n_groups = int(jnp.max(grouping)) + 1
    n_total = n_perms + 1

    pl = _planner.plan_pipeline(
        n, d, n_total, n_groups, metric=metric, backend=backend,
        dist_impl=dist_impl, materialize=materialize, row_block=row_block,
        matrix_budget_bytes=matrix_budget_bytes,
        slab_budget_bytes=slab_budget_bytes,
        memory_budget_bytes=memory_budget_bytes,
        sw_impl=sw_impl, chunk=chunk, sw_tuning=sw_tuning)
    dspec = _registry.get(pl.dist_impl)
    # planner-resolved tuning (row block folded in) <- caller overrides
    prepare, rows_fn, dense_fn = dspec.bound(
        **{**pl.dist_tuning, **(dist_tuning or {})})

    if pl.materialize == "dense":
        dm = dense_fn(x)
        res = engine.run(dm, grouping, n_perms=n_perms, key=key,
                         n_groups=n_groups, impl=sw_impl,
                         memory_budget_bytes=memory_budget_bytes,
                         chunk=chunk, autotune=autotune, backend=backend,
                         tuning=sw_tuning)
    elif pl.materialize == "stream":
        mat2, gower = _streaming.build_mat2_streaming(
            prepare(x), rows_fn, block=pl.row_block)
        mat2_dev = jnp.asarray(mat2)
        del mat2   # free the host buffer: ONE sustained (n, n) resident
                   # (the handoff copy itself is transiently 2x; the fused
                   # bridge is the option that never holds (n, n) at all)
        res = engine.run(mat2_dev, grouping, n_perms=n_perms,
                         key=key, n_groups=n_groups, impl=sw_impl,
                         memory_budget_bytes=memory_budget_bytes,
                         chunk=chunk, autotune=autotune, backend=backend,
                         tuning=sw_tuning, squared=True, s_t=gower.s_t)
    elif pl.materialize == "fused":
        if autotune:
            warnings.warn(
                "autotune=True ignored: the fused bridge computes s_W in "
                "its one-hot matmul form (use materialize='stream'/'dense' "
                "to let measurements pick the s_W impl)", stacklevel=2)
        inv_gs = permutations.inv_group_sizes(grouping, n_groups)
        s_w, s_t, stats = _streaming.fused_sw(
            prepare(x), rows_fn, grouping, inv_gs, key, n_total,
            row_block=pl.row_block, chunk=pl.sw.chunk)
        f_all = f_from_sw(jnp.asarray(s_w, jnp.float32),
                          jnp.float32(s_t), n, n_groups)
        res = PermanovaResult(
            f_stat=f_all[0], p_value=p_value_from_null(f_all),
            s_t=jnp.float32(s_t), s_w=jnp.asarray(s_w[0], jnp.float32),
            f_perms=f_all, n_objects=n, n_groups=n_groups, n_perms=n_perms,
            method="pipeline[fused]",
            plan=(f"rows={stats.row_block}x{stats.n_row_blocks} "
                  f"chunks={stats.n_chunks} slab="
                  f"{stats.peak_slab_bytes/2**20:.1f}MiB"))
    else:  # pragma: no cover - planner validates
        raise ValueError(pl.materialize)

    if pl.materialize == "fused":
        # the fused bridge IS stage 2; the joint plan string is authoritative
        executed_sw = pl.sw.impl
        plan_str = f"{pl.describe()} :: {res.plan}"
    else:
        # engine.run planned stage 2 (autotune may have overridden ours) —
        # report its record once instead of a possibly-contradicting copy
        executed_sw = (res.method.split("[", 1)[1].rstrip("]")
                       if "[" in res.method else pl.sw.impl)
        plan_str = f"{pl.describe_stage1()} | {pl.reason} :: {res.plan}"
    return dataclasses.replace(
        res,
        method=f"pipeline[{pl.dist_impl}->{pl.materialize}->{executed_sw}]",
        plan=plan_str)


# ---------------------------------------------------------------------------
# Batched multi-study pipeline (serving scenario).
# ---------------------------------------------------------------------------

def pipeline_many(xs: Array, groupings: Array, *, n_groups: int,
                  metric: str = "braycurtis", n_perms: int = 999,
                  key: Optional[jax.Array] = None,
                  dist_impl: str = "auto", sw_impl: str = "auto",
                  row_block: Optional[int] = None,
                  chunk: Optional[int] = None,
                  memory_budget_bytes: Optional[float] = None,
                  matrix_budget_bytes: Optional[float] = None,
                  backend: Optional[str] = None
                  ) -> engine.PermanovaManyResult:
    """Stacked studies features→p-values through ONE joint plan.

    xs:         (S, n, d) abundance tables.
    groupings:  (S, n) int labels in [0, n_groups) (shared design width,
                like engine.permanova_many).
    Distance matrices are built study-by-study with the planned stage-1
    impl (lax.map bounds peak distance transients to one study's), then the
    stack runs through the engine's vmapped multi-study program. Study s
    draws its null from fold_in(key, s) — identical to S independent
    pipeline() calls.

    NOTE: the batched path always materializes the full (S, n, n) stack of
    distance matrices (the vmapped s_W program consumes it); the stream /
    fused bridges are single-study only for now. A stack bigger than the
    matrix budget warns — split the studies or fall back to per-study
    pipeline() calls.
    """
    if key is None:
        key = jax.random.key(0)
    xs = jnp.asarray(xs)
    if xs.ndim != 3:
        raise ValueError(f"stacked features must be (S, n, d); "
                         f"got shape {xs.shape}")
    groupings = jnp.asarray(groupings, dtype=jnp.int32)
    s_count, n, d = xs.shape
    n_total = n_perms + 1

    pl = _planner.plan_pipeline(
        n, d, n_total, n_groups, metric=metric, backend=backend,
        dist_impl=dist_impl, row_block=row_block, materialize="dense",
        matrix_budget_bytes=matrix_budget_bytes,
        memory_budget_bytes=memory_budget_bytes,
        sw_impl=sw_impl, chunk=chunk)
    stack_bytes = 4 * s_count * n * n
    budget = (_planner.DEFAULT_MATRIX_BUDGET_BYTES
              if matrix_budget_bytes is None else matrix_budget_bytes)
    if stack_bytes > budget:
        warnings.warn(
            f"pipeline_many materializes the full (S, n, n) stack "
            f"({stack_bytes/2**20:.0f}MiB), exceeding the matrix budget "
            f"({budget/2**20:.0f}MiB); stream/fused bridges are not yet "
            "implemented for the batched path — split the studies or run "
            "pipeline() per study", stacklevel=2)
    dspec = _registry.get(pl.dist_impl)
    _, _, dense_fn = dspec.bound(**pl.dist_tuning)

    dms = jax.lax.map(dense_fn, xs)        # one study's transients at a time
    res = engine.permanova_many(
        dms, groupings, n_groups=n_groups, n_perms=n_perms, key=key,
        impl=sw_impl, chunk=chunk,
        memory_budget_bytes=memory_budget_bytes, backend=backend)
    res.plan = (f"{pl.dist_impl} -> dense(batched lax.map) -> "
                f"{res.plan}")
    return res
